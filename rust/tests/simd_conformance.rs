//! SIMD / carry-save conformance suite (the bit-identity contract of the
//! vectorized hot paths).
//!
//! Two families of properties:
//!
//! 1. **Dispatch invariance** — every vectorized kernel (quantize,
//!    dequantize, FWHT, bulk bit I/O via the frame pipelines) must be
//!    bit-identical to its scalar reference, across lane-multiple and
//!    non-lane-multiple dimensions, with subnormals and ±0 in the input.
//!    On AVX2 hardware with the `simd` feature these compare real vector
//!    output against the scalar path; under `--no-default-features` (or
//!    on non-x86 hosts) both sides take the scalar path and the suite
//!    degenerates to a self-consistency check of the override plumbing.
//!
//! 2. **Carry-save fold invariance** — the [`SlotPartial`] carry-save
//!    accumulator must produce bit-identical state, wire bytes, and
//!    finishes under adversarial merge groupings: deep right-nested
//!    trees, fan-in-1 chains through empties, random pairings, silent
//!    holders interleaved everywhere, and mixed-scale contributions that
//!    force window flushes into the spill tier.

use dme::protocol::config::ProtocolConfig;
use dme::protocol::quantizer::{self, Span};
use dme::protocol::{run_round, Encoder, Frame, RoundCtx, SlotPartial};
use dme::rng::Pcg64;
use dme::rotation::hadamard;
use dme::simd;
use std::sync::Mutex;

/// Tests that toggle the global scalar override serialize on this lock.
/// A race could not produce a false failure (both paths are asserted
/// bit-identical), but it could silently downgrade a "vector" side to a
/// scalar run and weaken the comparison.
static DISPATCH: Mutex<()> = Mutex::new(());

fn dispatch_lock() -> std::sync::MutexGuard<'static, ()> {
    DISPATCH.lock().unwrap_or_else(|e| e.into_inner())
}

fn with_forced_scalar<T>(force: bool, f: impl FnOnce() -> T) -> T {
    let prev = simd::set_force_scalar(force);
    let out = f();
    simd::set_force_scalar(prev);
    out
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Gaussian data salted with the values lane tails must get right:
/// ±0, subnormals (including the smallest), and large-but-safe
/// magnitudes. Magnitudes stay ≤ 1e18 so a d ≤ 2^18 FWHT cannot
/// overflow to infinity.
fn adversarial(d: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg64::new(seed);
    let mut x = vec![0.0f32; d];
    rng.fill_gaussian_f32(&mut x);
    let specials: [f32; 10] = [
        0.0,
        -0.0,
        f32::MIN_POSITIVE,
        -f32::MIN_POSITIVE,
        1.0e-45,  // smallest positive subnormal
        -1.0e-45,
        f32::MIN_POSITIVE / 2.0, // mid-range subnormal
        1.0e-30,
        1.0e18,
        -1.0e18,
    ];
    for (i, &s) in specials.iter().enumerate() {
        let at = (i * 7 + 3) % d.max(1);
        if at < d {
            x[at] = s;
        }
    }
    x
}

/// Every registry family, including the wrappers (8+ specs as required
/// by the conformance checklist).
const SPECS: &[&str] = &[
    "float32",
    "binary",
    "klevel:k=2",
    "klevel:k=16",
    "klevel:k=16,span=norm",
    "rotated:k=2",
    "rotated:k=16",
    "varlen:k=17",
    "varlen:k=17,coder=huffman",
    "qsgd:k=8",
    "drive",
    "correlated:k=16",
    "correlated:base=rotated,k=16",
    "klevel:k=8,q=0.5",
    "klevel:k=16,p=0.5",
];

#[test]
fn quantize_kernels_match_scalar_reference() {
    let _g = dispatch_lock();
    let dims: [usize; 12] =
        [1, 7, 8, 9, 255, 256, 257, 4095, 4096, 4099, 1 << 18, (1 << 18) + 3];
    for (i, &d) in dims.iter().enumerate() {
        let x = adversarial(d, 100 + i as u64);
        let mut u = vec![0.0f32; d];
        Pcg64::new(200 + i as u64).fill_uniform_f32(&mut u);
        for span in [Span::MinMax, Span::Norm] {
            let (xmin, s) = quantizer::grid_params(&x, span);
            for k in [2u32, 3, 16, 17, 1024, 65535] {
                let mut vec_bins = Vec::new();
                with_forced_scalar(false, || {
                    quantizer::quantize_into(&x, &u, xmin, s, k, &mut vec_bins)
                });
                let mut ref_bins = vec![0u32; d];
                quantizer::quantize_bins_scalar(&x, &u, xmin, s, k, &mut ref_bins);
                assert_eq!(vec_bins, ref_bins, "quantize d={d} k={k} span={span:?}");
                // Dequantize back onto a non-zero accumulator (the +=
                // form is what the decode path uses).
                let mut vec_acc = vec![0.125f32; d];
                let mut ref_acc = vec![0.125f32; d];
                with_forced_scalar(false, || {
                    quantizer::dequantize_add(&vec_bins, xmin, s, k, &mut vec_acc)
                });
                quantizer::dequantize_add_scalar(&ref_bins, xmin, s, k, &mut ref_acc);
                assert_eq!(
                    bits(&vec_acc),
                    bits(&ref_acc),
                    "dequantize_add d={d} k={k} span={span:?}"
                );
            }
        }
    }
}

#[test]
fn fwht_matches_scalar_reference() {
    let _g = dispatch_lock();
    for e in 0..=18u32 {
        let d = 1usize << e;
        let mut vector = adversarial(d, 300 + e as u64);
        let mut scalar = vector.clone();
        with_forced_scalar(false, || hadamard::fwht(&mut vector));
        hadamard::fwht_scalar(&mut scalar);
        assert_eq!(bits(&vector), bits(&scalar), "fwht d=2^{e}");
    }
}

#[test]
fn frames_and_estimates_are_dispatch_invariant() {
    let _g = dispatch_lock();
    for &d in &[256usize, 257, 4096, 4099, 1 << 18] {
        let n = if d >= 1 << 18 { 2 } else { 4 };
        let xs: Vec<Vec<f32>> = (0..n as u64).map(|i| adversarial(d, 7 * d as u64 + i)).collect();
        for spec in SPECS {
            // The largest dim only for the base families; the sampling
            // wrappers reuse the same inner kernels.
            if d >= 1 << 18 && (spec.contains(",p=") || spec.contains(",q=")) {
                continue;
            }
            let proto = ProtocolConfig::parse(spec, d).unwrap().build().unwrap();
            let ctx = RoundCtx::new(1, 77);
            let state = proto.prepare(&ctx);
            // Frame-level: every client's wire bits match across paths.
            let mut enc = Encoder::new(proto.as_ref(), &state);
            let mut frame = Frame::empty();
            for (i, x) in xs.iter().enumerate() {
                let vector = with_forced_scalar(false, || {
                    enc.encode_into(i as u64, x, &mut frame)
                        .then(|| (frame.bytes.clone(), frame.bit_len))
                });
                let scalar = with_forced_scalar(true, || {
                    enc.encode_into(i as u64, x, &mut frame)
                        .then(|| (frame.bytes.clone(), frame.bit_len))
                });
                assert_eq!(vector, scalar, "spec={spec} d={d} client={i}: frame diverged");
            }
            // Round-level: estimate and bit count match across paths
            // (covers decode + finish, including the inverse rotation).
            let (vec_est, vec_bits) =
                with_forced_scalar(false, || run_round(proto.as_ref(), &ctx, &xs).unwrap());
            let (sca_est, sca_bits) =
                with_forced_scalar(true, || run_round(proto.as_ref(), &ctx, &xs).unwrap());
            assert_eq!(vec_bits, sca_bits, "spec={spec} d={d}: uplink bits diverged");
            assert_eq!(
                bits(&vec_est),
                bits(&sca_est),
                "spec={spec} d={d}: estimate not bit-identical across dispatch paths"
            );
        }
    }
}

// ---------------------------------------------------------------------
// Carry-save fold conformance
// ---------------------------------------------------------------------

/// Build a deterministic, adversarial set of slot partials: decoded
/// frames at assorted weights, direct mixed-scale contributions that
/// force carry-save window flushes into the spill tier, and silent
/// holders interleaved throughout. Rebuilt identically per grouping so
/// groupings never share state.
fn adversarial_partials(d: usize) -> Vec<SlotPartial> {
    let proto = ProtocolConfig::parse("klevel:k=16", d).unwrap().build().unwrap();
    let ctx = RoundCtx::new(2, 91);
    let state = proto.prepare(&ctx);
    let mut enc = Encoder::new(proto.as_ref(), &state);
    let xs: Vec<Vec<f32>> = (0..6u64).map(|i| adversarial(d, 900 + i)).collect();
    let weights = [1.0f32, 1.0, 0.5, 3.5e37, 1.2e-40, 7.25];
    let mut parts: Vec<SlotPartial> = xs
        .iter()
        .enumerate()
        .zip(&weights)
        .map(|((i, x), &w)| {
            let f = enc.encode(i as u64, x).unwrap();
            SlotPartial::decode(proto.as_ref(), &state, &f, w).unwrap()
        })
        .collect();
    // Mixed-scale direct contributions: huge then tiny at the same
    // coordinates, so the second add lands limbs away from the first
    // window base and must flush.
    let mut rng = Pcg64::new(41);
    for (scale, weight) in [(3.0e38f32, 1.0f32), (1.0e-44, 1.0), (1.0, 2.5e20), (1.0e19, 1.0e19)]
    {
        let mut p = SlotPartial::empty(d);
        let mut v = vec![0.0f32; d];
        rng.fill_gaussian_f32(&mut v);
        for val in v.iter_mut() {
            *val = (*val * scale).clamp(-3.4e38, 3.4e38);
        }
        p.add_decoded(&v, weight, 1).unwrap();
        parts.push(p);
    }
    // Silent holders interleaved at every third position.
    let dim = parts[0].internal_dim();
    for at in (0..parts.len()).step_by(3).rev() {
        parts.insert(at, SlotPartial::silent(dim));
    }
    parts
}

#[test]
fn carry_save_fold_survives_adversarial_groupings() {
    for d in [16usize, 96] {
        let parts = adversarial_partials(d);
        let dim = parts[0].internal_dim();
        // Reference: flat left fold.
        let mut flat = SlotPartial::empty(dim);
        for p in &parts {
            flat.merge(p).unwrap();
        }
        let flat_wire = flat.to_bytes().unwrap();

        // Deep right-nested tree: p0 + (p1 + (p2 + (...))).
        let mut right = parts.last().unwrap().clone();
        for p in parts.iter().rev().skip(1) {
            let mut node = p.clone();
            node.merge(&right).unwrap();
            right = node;
        }
        assert_eq!(right, flat, "d={d}: deep right-nested fold diverged");

        // Fan-in-1 chain: each contribution passes through its own
        // single-child empty node before joining the trunk.
        let mut chain = SlotPartial::empty(dim);
        for p in &parts {
            let mut lone = SlotPartial::empty(dim);
            lone.merge(p).unwrap();
            chain.merge(&lone).unwrap();
        }
        assert_eq!(chain, flat, "d={d}: fan-in-1 chain diverged");

        // Random pairings: repeatedly merge a random adjacent pair.
        let mut rng = Pcg64::new(0xfeed + d as u64);
        let mut pool = parts.clone();
        while pool.len() > 1 {
            let i = rng.next_below(pool.len() as u32 - 1) as usize;
            let other = pool.remove(i + 1);
            pool[i].merge(&other).unwrap();
        }
        assert_eq!(pool[0], flat, "d={d}: random pairing fold diverged");

        // Wire stability: every grouping serializes to the same bytes,
        // and the bytes round-trip to equal state.
        assert_eq!(right.to_bytes().unwrap(), flat_wire, "d={d}: wire bytes diverged");
        assert_eq!(chain.to_bytes().unwrap(), flat_wire, "d={d}: wire bytes diverged");
        let back = SlotPartial::from_bytes(&flat_wire).unwrap();
        assert_eq!(back.to_bytes().unwrap(), flat_wire, "d={d}: wire round-trip unstable");
        assert_eq!(back, flat, "d={d}: deserialized partial diverged");
    }
}

#[test]
fn carry_save_spill_preserves_finish_bits() {
    // Contributions whose scales differ by hundreds of binary orders of
    // magnitude force the carry-save window to flush into the dense
    // spill tier; the finish must still be bit-identical no matter how
    // the adds are grouped across partials.
    let d = 24;
    let proto = ProtocolConfig::parse("float32", d).unwrap().build().unwrap();
    let ctx = RoundCtx::new(0, 5);
    let state = proto.prepare(&ctx);
    let scales: [f32; 7] = [3.0e38, 1.0, 1.0e-44, 2.0e19, 5.0e-20, 1.0e10, 1.0];
    let mut rng = Pcg64::new(77);
    let rows: Vec<Vec<f32>> = scales
        .iter()
        .map(|&s| {
            let mut v = vec![0.0f32; d];
            rng.fill_gaussian_f32(&mut v);
            for val in v.iter_mut() {
                *val = (*val * s).clamp(-3.4e38, 3.4e38);
            }
            v
        })
        .collect();
    // One partial per row vs all rows in one partial vs two halves.
    let mut per_row = SlotPartial::empty(d);
    for row in &rows {
        let mut p = SlotPartial::empty(d);
        p.add_decoded(row, 1.0, 1).unwrap();
        per_row.merge(&p).unwrap();
    }
    let mut single = SlotPartial::empty(d);
    for row in &rows {
        single.add_decoded(row, 1.0, 1).unwrap();
    }
    let mut halves = SlotPartial::empty(d);
    for chunk in rows.chunks(2) {
        let mut p = SlotPartial::empty(d);
        for row in chunk {
            p.add_decoded(row, 1.0, 1).unwrap();
        }
        halves.merge(&p).unwrap();
    }
    assert_eq!(per_row, single, "per-row vs single-partial state diverged");
    assert_eq!(halves, single, "halved grouping diverged");
    let (a, fa) = single.finish(proto.as_ref(), &state);
    let (b, fb) = per_row.finish(proto.as_ref(), &state);
    let (c, fc) = halves.finish(proto.as_ref(), &state);
    assert_eq!(bits(&a), bits(&b), "finish bits diverged (per-row)");
    assert_eq!(bits(&a), bits(&c), "finish bits diverged (halves)");
    assert_eq!(fa.to_bits(), fb.to_bits());
    assert_eq!(fa.to_bits(), fc.to_bits());
}
