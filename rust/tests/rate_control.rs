//! Conformance suite for the rate-control tier (tag-5 `SpecChange` and
//! the planner).
//!
//! The load-bearing contract: a **mid-session spec switch is
//! bit-identical to restarting a fresh session at the new spec** and
//! driving it through the same round numbers — over flat and depth-2
//! tree topologies, loopback and TCP. Every bit of a round depends only
//! on `(seed, round, client_id, spec, data)`; the switch rebuilds every
//! node's protocol handle with no carried state, and these tests prove
//! the plumbing actually delivers that on every tier.
//!
//! Plus the planner acceptance check: at equal budgets of 1, 2, and 4
//! bits/dim the predicted-MSE ordering reproduces the paper's frontier —
//! π_sb (Θ(d/n)) ≻ π_srk (O(log d / n)) ≻ π_svk (O(1/n)).

use dme::coordinator::aggregator::spawn_local_tree;
use dme::coordinator::leader::{spawn_local_cluster, Leader};
use dme::coordinator::topology::Topology;
use dme::coordinator::transport::TcpHub;
use dme::coordinator::worker::{mean_update, Worker};
use dme::protocol::config::{Kind, ProtocolConfig};
use dme::rate::{Objective, Plan};
use dme::rng::Pcg64;

const SEED: u64 = 41;

fn gaussian_shards(n: usize, d: usize, seed: u64) -> Vec<Vec<Vec<f32>>> {
    let mut rng = Pcg64::new(seed);
    (0..n)
        .map(|_| {
            let mut x = vec![0.0f32; d];
            rng.fill_gaussian_f32(&mut x);
            vec![x]
        })
        .collect()
}

fn bits_of(means: &[Vec<f32>]) -> Vec<Vec<u32>> {
    means.iter().map(|m| m.iter().map(|v| v.to_bits()).collect()).collect()
}

/// Drive `leader` through rounds `[lo, hi)`, returning each round's
/// estimate bits.
fn drive(leader: &mut Leader, lo: u64, hi: u64, dim: usize) -> Vec<Vec<Vec<u32>>> {
    (lo..hi)
        .map(|r| bits_of(&leader.round(r, dim as u32, &[]).unwrap().means))
        .collect()
}

/// The spec pairs every topology is checked over: fixed-width →
/// rotated, entropy-coded → fixed-width, a switch *into* a sampled
/// wrapper (private sampling streams must come up exactly as a fresh
/// session's would), and switches into/out of each frontier family
/// (the round-scoped correlated offset stream and DRIVE's rotation
/// must come up exactly as a fresh session's would, too).
const SWITCHES: [(&str, &str); 6] = [
    ("klevel:k=16", "rotated:k=8"),
    ("varlen:k=8", "binary"),
    ("rotated:k=4", "klevel:k=4,p=0.5"),
    ("klevel:k=16", "drive"),
    ("rotated:k=8", "correlated:base=rotated,k=16"),
    ("drive", "correlated:k=4"),
];

#[test]
fn flat_mid_session_switch_matches_fresh_session() {
    let d = 32;
    let n = 7;
    for (from, to) in SWITCHES {
        let shards = gaussian_shards(n, d, 5);
        let proto = ProtocolConfig::parse(from, d).unwrap().build().unwrap();
        let (mut leader, handles) =
            spawn_local_cluster(proto, shards.clone(), mean_update(), SEED);
        drive(&mut leader, 0, 2, d);
        leader.switch_spec(to, 2).unwrap();
        let after = drive(&mut leader, 2, 4, d);
        assert_eq!(
            leader.metrics().spec_changes,
            vec![(2, to.to_string())],
            "switch not recorded in metrics"
        );
        leader.shutdown().unwrap();
        for h in handles {
            h.join().unwrap().unwrap();
        }

        // Fresh session at the new spec, same seed, same round numbers.
        let proto = ProtocolConfig::parse(to, d).unwrap().build().unwrap();
        let (mut fresh, handles) = spawn_local_cluster(proto, shards, mean_update(), SEED);
        let want = drive(&mut fresh, 2, 4, d);
        fresh.shutdown().unwrap();
        for h in handles {
            h.join().unwrap().unwrap();
        }
        assert_eq!(after, want, "{from} -> {to}: switched session diverged from fresh");
    }
}

#[test]
fn tree_mid_session_switch_matches_fresh_session() {
    // Depth-2 tree: the SpecChange must relay through the aggregator
    // tier, every node rebuilding before the next RoundStart.
    let d = 32;
    let n = 11;
    for (from, to) in SWITCHES {
        let topo = Topology::uniform(n as u64, 4, 2).unwrap();
        let shards = gaussian_shards(n, d, 9);
        let proto = ProtocolConfig::parse(from, d).unwrap().build().unwrap();
        let (mut leader, tree) =
            spawn_local_tree(proto, shards.clone(), mean_update(), SEED, &topo, 2, None)
                .unwrap();
        drive(&mut leader, 0, 2, d);
        leader.switch_spec(to, 2).unwrap();
        let after = drive(&mut leader, 2, 4, d);
        leader.shutdown().unwrap();
        tree.join().unwrap();

        let topo = Topology::uniform(n as u64, 4, 2).unwrap();
        let proto = ProtocolConfig::parse(to, d).unwrap().build().unwrap();
        let (mut fresh, tree) =
            spawn_local_tree(proto, shards, mean_update(), SEED, &topo, 2, None).unwrap();
        let want = drive(&mut fresh, 2, 4, d);
        fresh.shutdown().unwrap();
        tree.join().unwrap();
        assert_eq!(after, want, "{from} -> {to}: tree switch diverged from fresh");
    }
}

#[test]
fn tcp_mid_session_switch_matches_fresh_session() {
    // Real sockets: the tag-5 message crosses the wire serialization,
    // and the result must equal a fresh *loopback* session at the new
    // spec — proving both switch conformance and transport neutrality.
    let d = 16;
    let n = 3;
    let (from, to) = ("klevel:k=16", "rotated:k=8");
    let shards = gaussian_shards(n, d, 21);

    let binding = TcpHub::bind("127.0.0.1:0").unwrap();
    let addr = binding.local_addr().unwrap();
    let mut worker_handles = Vec::new();
    for (i, shard) in shards.iter().cloned().enumerate() {
        let proto = ProtocolConfig::parse(from, d).unwrap().build().unwrap();
        worker_handles.push(std::thread::spawn(move || {
            Worker {
                client_id: i as u64,
                shard,
                protocol: proto,
                update: mean_update(),
                seed: SEED,
            }
            .run_tcp(&addr.to_string())
        }));
    }
    let hub = binding.accept(n).unwrap();
    let proto = ProtocolConfig::parse(from, d).unwrap().build().unwrap();
    let mut leader = Leader::new(proto, Box::new(hub), SEED);
    drive(&mut leader, 0, 2, d);
    leader.switch_spec(to, 2).unwrap();
    let after = drive(&mut leader, 2, 4, d);
    leader.shutdown().unwrap();
    for h in worker_handles {
        h.join().unwrap().unwrap();
    }

    let proto = ProtocolConfig::parse(to, d).unwrap().build().unwrap();
    let (mut fresh, handles) = spawn_local_cluster(proto, shards, mean_update(), SEED);
    let want = drive(&mut fresh, 2, 4, d);
    fresh.shutdown().unwrap();
    for h in handles {
        h.join().unwrap().unwrap();
    }
    assert_eq!(after, want, "TCP switch diverged from a fresh loopback session");
}

#[test]
fn invalid_switch_errors_without_disturbing_the_session() {
    let d = 16;
    let shards = gaussian_shards(4, d, 3);
    let proto = ProtocolConfig::parse("klevel:k=8", d).unwrap().build().unwrap();
    let (mut leader, handles) = spawn_local_cluster(proto, shards.clone(), mean_update(), SEED);
    drive(&mut leader, 0, 1, d);
    // Grammar and build failures error locally, before any broadcast...
    assert!(leader.switch_spec("nonsense", 1).is_err());
    assert!(leader.switch_spec("rotated:k=16,q=0.5", 1).is_err());
    assert!(leader.metrics().spec_changes.is_empty());
    // ...so the session continues at the old spec, bit-identical to an
    // undisturbed one.
    let after = drive(&mut leader, 1, 2, d);
    leader.shutdown().unwrap();
    for h in handles {
        h.join().unwrap().unwrap();
    }
    let proto = ProtocolConfig::parse("klevel:k=8", d).unwrap().build().unwrap();
    let (mut fresh, handles) = spawn_local_cluster(proto, shards, mean_update(), SEED);
    let want = drive(&mut fresh, 1, 2, d);
    fresh.shutdown().unwrap();
    for h in handles {
        h.join().unwrap().unwrap();
    }
    assert_eq!(after, want);
}

#[test]
fn planner_reproduces_the_papers_ordering_at_equal_budgets() {
    // Acceptance criterion: at budgets of 1, 2, and 4 bits/dim the
    // family bests order binary ≻ rotated ≻ varlen by predicted MSE —
    // the Θ(d/n) vs O(log d / n) vs O(1/n) frontier of PAPER.md.
    let (d, n) = (1024usize, 64usize);
    for budget in [1.0f64, 2.0, 4.0] {
        let plan = Plan::solve(budget * d as f64, d, n, Objective::MinMse).unwrap();
        let binary = plan
            .best_in_kind(Kind::Binary)
            .unwrap_or_else(|| panic!("no binary spec fits {budget} bits/dim"));
        let rotated = plan
            .best_in_kind(Kind::Rotated)
            .unwrap_or_else(|| panic!("no rotated spec fits {budget} bits/dim"));
        let varlen = plan
            .best_in_kind(Kind::Varlen)
            .unwrap_or_else(|| panic!("no varlen spec fits {budget} bits/dim"));
        assert!(
            varlen.predicted_mse < rotated.predicted_mse,
            "budget {budget}: varlen `{}` ({:.3e}) must beat rotated `{}` ({:.3e})",
            varlen.spec,
            varlen.predicted_mse,
            rotated.spec,
            rotated.predicted_mse
        );
        assert!(
            rotated.predicted_mse < binary.predicted_mse,
            "budget {budget}: rotated `{}` ({:.3e}) must beat binary `{}` ({:.3e})",
            rotated.spec,
            rotated.predicted_mse,
            binary.spec,
            binary.predicted_mse
        );
        // And the overall choice is at least as good as every family best.
        let chosen = plan.chosen_spec().expect("budget must be feasible");
        assert!(chosen.predicted_mse <= varlen.predicted_mse);
        assert!(chosen.bits_per_client <= plan.budget_bits_per_client);
    }
}

#[test]
fn switched_session_controller_loop_end_to_end() {
    // A miniature auto-rate session: plan, run at the chosen spec,
    // switch when the controller says so, keep serving. Exercises the
    // Plan -> RateController -> Leader::switch_spec loop the serve
    // command wires together.
    use dme::rate::RateController;
    let d = 64;
    let n = 6;
    let plan = Plan::solve(4.0 * d as f64, d, n, Objective::MinMse).unwrap();
    let mut ctl = RateController::new(plan).unwrap();
    let first = ctl.active_spec().spec.clone();
    let shards = gaussian_shards(n, d, 77);
    let mut cfg = ctl.active_spec().cfg.clone();
    cfg.dim = d;
    let (mut leader, handles) =
        spawn_local_cluster(cfg.build().unwrap(), shards, mean_update(), SEED);
    let mut switched = Vec::new();
    for r in 0..4u64 {
        let out = leader.round(r, d as u32, &[]).unwrap();
        let est = out.means.first().cloned().unwrap_or_default();
        if let Some(spec) = ctl.observe(r, out.uplink_bits, n, &est) {
            leader.switch_spec(&spec, r + 1).unwrap();
            switched.push(spec);
        }
    }
    leader.shutdown().unwrap();
    for h in handles {
        h.join().unwrap().unwrap();
    }
    assert_eq!(ctl.history().len(), 4);
    // Realized bits of the fixed-width chosen specs match predictions,
    // so a well-calibrated plan must not flap.
    assert!(
        switched.len() <= 1,
        "controller flapped: started at `{first}`, switched through {switched:?}"
    );
}
