//! Conformance suite for the leader's streaming aggregation pipeline.
//!
//! The contract: for every protocol spec, every upload arrival order,
//! and every decode-thread count, `aggregate_uploads_streaming` produces
//! a `RoundOutcome` bit-identical to `aggregate_uploads_reference` — the
//! retained pre-streaming sorted-decode path. Covers multi-slot uploads,
//! ragged slot counts, mixed weights, silent (sampled) frames, and
//! workers with empty shards.

use std::sync::Arc;

use dme::coordinator::leader::{
    aggregate_uploads_reference, aggregate_uploads_streaming, RoundOutcome,
};
use dme::coordinator::transport::{Message, WeightedFrame};
use dme::coordinator::worker::{UpdateFn, Worker};
use dme::protocol::config::ProtocolConfig;
use dme::protocol::{Protocol, RoundCtx, RoundState};
use dme::rng::Pcg64;

const SPECS: &[&str] = &[
    "float32",
    "binary",
    "klevel:k=2",
    "klevel:k=16",
    "klevel:k=16,span=norm",
    "rotated:k=2",
    "rotated:k=16",
    "varlen:k=4",
    "varlen:k=17",
    "varlen:k=17,coder=huffman",
    "qsgd:k=8",
    "klevel:k=8,q=0.5",
    "klevel:k=16,p=0.5",
    "varlen:k=17,p=0.25",
];

/// A multi-slot weighted update: worker `i` contributes `1 + i % 3`
/// slots (ragged), with weights mixing 1.0 and non-1.0 values.
fn multi_slot_update() -> UpdateFn {
    Arc::new(|_broadcast, dim, shard| {
        if shard.is_empty() {
            return Vec::new();
        }
        let d = dim as usize;
        let tag = shard[0][0].abs();
        let n_slots = 1 + (tag as usize) % 3;
        (0..n_slots)
            .map(|s| {
                let v: Vec<f32> = shard[0]
                    .iter()
                    .take(d)
                    .map(|&x| x + s as f32 * 0.25)
                    .collect();
                let weight = if (tag as usize + s) % 2 == 0 { 1.0 } else { 2.0 + s as f32 };
                (v, weight)
            })
            .collect()
    })
}

/// Build every worker's upload for one round of `spec` — exactly what
/// the transport would deliver to the leader, minus the transport.
fn build_uploads(
    spec: &str,
    d: usize,
    n: usize,
    seed: u64,
) -> (Arc<dyn Protocol>, RoundState, Vec<(u64, Vec<WeightedFrame>)>) {
    let mut rng = Pcg64::new(seed ^ 0x5eed);
    let mut uploads = Vec::with_capacity(n);
    for i in 0..n {
        let shard = if i == n - 1 {
            Vec::new() // one worker with no data: uploads zero frames
        } else {
            let mut x = vec![0.0f32; d];
            rng.fill_gaussian_f32(&mut x);
            x[0] = i as f32; // drives the ragged slot count in the update
            vec![x]
        };
        let proto = ProtocolConfig::parse(spec, d).unwrap().build().unwrap();
        let worker = Worker {
            client_id: i as u64,
            shard,
            protocol: proto,
            update: multi_slot_update(),
            seed,
        };
        match worker.step(0, d as u32, &[]).unwrap() {
            Message::Upload { client, frames, .. } => uploads.push((client, frames)),
            _ => unreachable!("step always yields Upload"),
        }
    }
    let proto = ProtocolConfig::parse(spec, d).unwrap().build().unwrap();
    let state = proto.prepare(&RoundCtx::new(0, seed));
    (proto, state, uploads)
}

fn assert_outcomes_bit_identical(a: &RoundOutcome, b: &RoundOutcome, what: &str) {
    assert_eq!(a.uplink_bits, b.uplink_bits, "{what}: uplink_bits");
    assert_eq!(a.n_frames, b.n_frames, "{what}: n_frames");
    assert_eq!(a.weights, b.weights, "{what}: weights");
    assert_eq!(a.means.len(), b.means.len(), "{what}: slot count");
    for (slot, (x, y)) in a.means.iter().zip(&b.means).enumerate() {
        assert_eq!(
            x.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            y.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "{what}: slot {slot} means diverge"
        );
    }
}

/// Deterministic "random" permutation of upload order.
fn permute<T>(mut items: Vec<T>, key: u64) -> Vec<T> {
    let mut rng = Pcg64::new(key);
    let mut out = Vec::with_capacity(items.len());
    while !items.is_empty() {
        let i = (rng.next_u64() % items.len() as u64) as usize;
        out.push(items.swap_remove(i));
    }
    out
}

#[test]
fn streaming_bit_identical_for_all_specs_orders_and_thread_counts() {
    let d = 48;
    let n = 7;
    for spec in SPECS {
        let (proto, state, uploads) = build_uploads(spec, d, n, 77);
        let want =
            aggregate_uploads_reference(proto.as_ref(), &state, uploads.clone()).unwrap();
        assert!(want.means.len() >= 2, "{spec}: expected multi-slot round");

        let mut orders = vec![uploads.clone()];
        let mut reversed = uploads.clone();
        reversed.reverse();
        orders.push(reversed);
        orders.push(permute(uploads.clone(), 0xfeed));
        for (o, order) in orders.into_iter().enumerate() {
            for threads in [1usize, 2, 8] {
                let got =
                    aggregate_uploads_streaming(proto.as_ref(), &state, &order, threads).unwrap();
                assert_outcomes_bit_identical(
                    &got,
                    &want,
                    &format!("spec={spec} order={o} threads={threads}"),
                );
            }
        }
    }
}

#[test]
fn streaming_leader_round_matches_reference_over_loopback() {
    // End to end: the full Leader::round (streaming pipeline, several
    // decode widths) against the reference aggregation on the same
    // uploads, reconstructed from identical worker state.
    use dme::coordinator::leader::spawn_local_cluster;

    let d = 32;
    let n = 6;
    for spec in ["rotated:k=16", "varlen:k=17", "klevel:k=16,p=0.5"] {
        let (proto, state, uploads) = build_uploads(spec, d, n, 91);
        let want =
            aggregate_uploads_reference(proto.as_ref(), &state, uploads).unwrap();

        for threads in [1usize, 3] {
            let mut rng = Pcg64::new(91 ^ 0x5eed);
            let shards: Vec<Vec<Vec<f32>>> = (0..n)
                .map(|i| {
                    if i == n - 1 {
                        Vec::new()
                    } else {
                        let mut x = vec![0.0f32; d];
                        rng.fill_gaussian_f32(&mut x);
                        x[0] = i as f32;
                        vec![x]
                    }
                })
                .collect();
            let proto = ProtocolConfig::parse(spec, d).unwrap().build().unwrap();
            let (mut leader, handles) =
                spawn_local_cluster(proto, shards, multi_slot_update(), 91);
            leader.set_decode_threads(threads);
            let got = leader.round(0, d as u32, &[]).unwrap();
            assert_outcomes_bit_identical(
                &got,
                &want,
                &format!("spec={spec} threads={threads} (full leader)"),
            );
            leader.shutdown().unwrap();
            for h in handles {
                h.join().unwrap().unwrap();
            }
        }
    }
}
