//! Partial-round conformance: a real-stack round that finalizes from a
//! surviving subset `S` under `BarrierPolicy::Partial` must produce
//! **bit for bit** the estimate of the paper's Lemma 8 sampled-mean
//! estimator at p̂ = |S|/n — executable as the client-sampling wrapper
//! (`protocol::sampling`) folded by the flat sequential reference
//! `aggregate_uploads_reference`.
//!
//! The trick that makes the two sides comparable frame for frame: pick
//! a round whose sampling coins are a *fixed point* — at p = s/n,
//! exactly `s` clients transmit. Run the sampled wrapper over all `n`
//! clients (sampled-out ones upload the zero-bit placeholder frame, the
//! real worker's silent convention, so the fold divides by n·p̂ = s),
//! and run the bare protocol over the real stack with exactly that
//! survivor set answering (the partial barrier counts |S| = s
//! contributors). Same frames, same exact fixed-point fold, and —
//! because s/n is dyadic at n = 16 — the same divisor in every bit,
//! across flat and depth-2 trees, both TCP transports, and decode
//! thread counts.
//!
//! Also here: the scenario engine's replay contract — the same seed
//! must reproduce the same trajectory rows over the real swarm.

#![cfg(target_os = "linux")]

use std::collections::HashSet;
use std::net::SocketAddr;
use std::sync::Arc;
use std::time::Duration;

use dme::coordinator::leader::{
    aggregate_uploads_reference, BarrierPolicy, ChildKey, Leader, RoundOutcome,
};
use dme::coordinator::swarm::{Swarm, SwarmAction};
use dme::coordinator::transport::{
    Envelope, HubBinding, Message, TcpEndpoint, Transport, WeightedFrame,
};
use dme::coordinator::worker::{mean_update, Worker};
use dme::coordinator::Aggregator;
use dme::protocol::config::ProtocolConfig;
use dme::protocol::sampling::SampledProtocol;
use dme::protocol::{EncodeScratch, Encoder, Frame, Protocol, RoundCtx};
use dme::scenario::data::client_vector;
use dme::scenario::{run_scenario, DataPlan, FaultPlan, ScenarioSpec};

const N: usize = 16;
const DIM: usize = 32;

/// The deterministic client population both sides of the conformance
/// diff hold — clustered, so losing clients actually moves the mean.
fn population(seed: u64) -> Vec<Vec<f32>> {
    (0..N as u64)
        .map(|i| client_vector(DataPlan::Clustered, seed, i, DIM))
        .collect()
}

/// Scan rounds for a *fixed point* of the sampling coin: a round where,
/// at p = s/n, the wrapper's transmit set has exactly `s` members
/// (mid-range `s` only, so the partial round is neither empty nor
/// trivial). For that round the wrapper's transmit set and the real
/// stack's survivor set can be made to coincide.
fn survivor_fixed_point(
    inner: &Arc<dyn Protocol>,
    seed: u64,
    xs: &[Vec<f32>],
) -> (u64, usize, Vec<u64>) {
    let n = xs.len();
    for round in 0..512u64 {
        let ctx = RoundCtx::new(round, seed);
        for s in (n / 4).max(2)..=3 * n / 4 {
            let wrapper = SampledProtocol::new(inner.clone(), s as f64 / n as f64);
            let state = wrapper.prepare(&ctx);
            let mut enc = Encoder::new(&wrapper, &state);
            let survivors: Vec<u64> = (0..n as u64)
                .filter(|&i| enc.encode(i, &xs[i as usize]).is_some())
                .collect();
            if survivors.len() == s {
                return (round, s, survivors);
            }
        }
    }
    panic!("no mid-range sampling fixed point in 512 rounds for seed {seed}");
}

/// The Lemma 8 executable reference: all `n` clients run the sampled
/// wrapper at p; sampled-out clients upload the zero-bit placeholder
/// frame, so the fold counts n holders and the wrapper's finish divides
/// by n·p — the sampled-mean estimator of PAPER.md, Lemma 8.
fn sampled_reference(
    inner: Arc<dyn Protocol>,
    seed: u64,
    round: u64,
    p: f64,
    xs: &[Vec<f32>],
) -> RoundOutcome {
    let wrapper = SampledProtocol::new(inner, p);
    let ctx = RoundCtx::new(round, seed);
    let state = wrapper.prepare(&ctx);
    let mut enc = Encoder::new(&wrapper, &state);
    let uploads: Vec<(u64, Vec<WeightedFrame>)> = xs
        .iter()
        .enumerate()
        .map(|(i, x)| {
            let frames = match enc.encode(i as u64, x) {
                Some(frame) => vec![WeightedFrame { frame, weight: 1.0 }],
                None => vec![WeightedFrame { frame: Frame::new(Vec::new(), 0), weight: 0.0 }],
            };
            (i as u64, frames)
        })
        .collect();
    aggregate_uploads_reference(&wrapper, &state, uploads).unwrap()
}

/// Swarm TCP clients for `[base_id, base_id + n)`: survivors answer
/// through the real `Worker` encode path, everyone else stays silent
/// every round — the deterministic partial-round population.
fn spawn_survivor_swarm(
    addr: SocketAddr,
    base_id: u64,
    n: usize,
    protocol: Arc<dyn Protocol>,
    seed: u64,
    xs: Vec<Vec<f32>>,
    survivors: HashSet<u64>,
) -> Swarm {
    let mut workers: Vec<Worker> = (0..n as u64)
        .map(|i| Worker {
            client_id: base_id + i,
            shard: vec![xs[(base_id + i) as usize].clone()],
            protocol: protocol.clone(),
            update: mean_update(),
            seed,
        })
        .collect();
    let mut scratch = EncodeScratch::default();
    Swarm::spawn_actions(addr, n, 1, move |slot, env: &Envelope| match &env.msg {
        Message::RoundStart { round, shared_seed, dim, payload } => {
            let worker = &mut workers[slot];
            if !survivors.contains(&worker.client_id) {
                return SwarmAction::Silent;
            }
            match worker.step_seeded(env.session, *round, *shared_seed, *dim, payload, &mut scratch)
            {
                Ok(reply) => SwarmAction::Reply(Envelope { session: env.session, msg: reply }),
                Err(_) => SwarmAction::Hangup,
            }
        }
        _ => SwarmAction::Silent,
    })
    .unwrap()
}

/// One real partial round over a flat tree: swarm TCP clients, a leader
/// barrier armed with a deadline and `BarrierPolicy::Partial`, and the
/// non-survivors simply never answering. Returns the round outcome and
/// the recorded participation p̂.
fn run_flat_partial(
    transport: Transport,
    decode_threads: usize,
    proto: &Arc<dyn Protocol>,
    seed: u64,
    round: u64,
    xs: &[Vec<f32>],
    survivors: &[u64],
) -> (RoundOutcome, f64) {
    let n = xs.len();
    let binding = HubBinding::bind(transport, "127.0.0.1:0").unwrap();
    let addr = binding.local_addr().unwrap();
    let surv: HashSet<u64> = survivors.iter().copied().collect();
    let swarm = spawn_survivor_swarm(addr, 0, n, proto.clone(), seed, xs.to_vec(), surv);
    let hub = binding.accept(n).unwrap();
    let expected = (0..n as u64).map(ChildKey::Client).collect();
    let mut leader = Leader::new(proto.clone(), hub, seed)
        .with_decode_threads(decode_threads)
        .with_round_timeout(Duration::from_millis(300))
        .with_expected_children(expected)
        .with_barrier_policy(BarrierPolicy::Partial);
    let out = leader.round(round, DIM as u32, &[]).unwrap();
    let p_hat = leader.metrics().rounds.last().unwrap().participation;
    leader.shutdown().unwrap();
    swarm.join().unwrap();
    (out, p_hat)
}

/// The same partial round over a depth-2 tree: two aggregators with
/// their own partial barriers feed the root. The root estimate must
/// still equal the flat reference bit for bit — the exact fold
/// composes, and so does the partial-round contract.
fn run_depth2_partial(
    transport: Transport,
    decode_threads: usize,
    proto: &Arc<dyn Protocol>,
    seed: u64,
    round: u64,
    xs: &[Vec<f32>],
    survivors: &[u64],
) -> (RoundOutcome, f64) {
    let n = xs.len();
    let span_len = (n / 2) as u64;
    let surv: HashSet<u64> = survivors.iter().copied().collect();
    let leader_binding = HubBinding::bind(transport, "127.0.0.1:0").unwrap();
    let leader_addr = leader_binding.local_addr().unwrap().to_string();
    let mut swarms = Vec::new();
    let mut agg_threads = Vec::new();
    for agg_id in 0..2u64 {
        let (lo, hi) = (agg_id * span_len, (agg_id + 1) * span_len);
        let child_binding = HubBinding::bind(transport, "127.0.0.1:0").unwrap();
        let child_addr = child_binding.local_addr().unwrap();
        swarms.push(spawn_survivor_swarm(
            child_addr,
            lo,
            span_len as usize,
            proto.clone(),
            seed,
            xs.to_vec(),
            surv.clone(),
        ));
        let up_addr = leader_addr.clone();
        let agg_proto = proto.clone();
        agg_threads.push(std::thread::spawn(move || {
            let hub = child_binding.accept(span_len as usize).unwrap();
            let mut up = TcpEndpoint::connect(&up_addr).unwrap();
            let report = Aggregator::new(agg_proto, seed, agg_id, (lo, hi))
                .with_level(0)
                .with_decode_threads(decode_threads)
                .with_round_timeout(Duration::from_millis(300))
                .with_barrier_policy(BarrierPolicy::Partial)
                .run(hub, &mut up);
            report.unwrap();
        }));
    }
    let hub = leader_binding.accept(2).unwrap();
    let expected = (0..2u64)
        .map(|id| ChildKey::Aggregator { id, span: (id * span_len, (id + 1) * span_len) })
        .collect();
    let mut leader = Leader::new(proto.clone(), hub, seed)
        .with_decode_threads(decode_threads)
        .with_round_timeout(Duration::from_millis(900))
        .with_expected_children(expected)
        .with_barrier_policy(BarrierPolicy::Partial);
    let out = leader.round(round, DIM as u32, &[]).unwrap();
    let p_hat = leader.metrics().rounds.last().unwrap().participation;
    leader.shutdown().unwrap();
    for handle in agg_threads {
        handle.join().unwrap();
    }
    for swarm in swarms {
        swarm.join().unwrap();
    }
    (out, p_hat)
}

#[test]
fn partial_round_matches_lemma8_sampled_reference() {
    let seed = 2017;
    let inner = ProtocolConfig::parse("rotated:k=16", DIM).unwrap().build().unwrap();
    let xs = population(seed);
    let (round, s, survivors) = survivor_fixed_point(&inner, seed, &xs);
    let p_hat = s as f64 / N as f64;
    let want = sampled_reference(inner.clone(), seed, round, p_hat, &xs);
    assert_eq!(want.n_frames, s, "reference must transmit exactly the fixed-point set");
    for transport in [Transport::Threads, Transport::Reactor] {
        for dt in [1usize, 4] {
            let (flat, p_flat) =
                run_flat_partial(transport, dt, &inner, seed, round, &xs, &survivors);
            assert_eq!(flat.means, want.means, "flat/{transport}/t={dt}: != Lemma 8 ref");
            assert_eq!(flat.uplink_bits, want.uplink_bits);
            assert_eq!(flat.n_frames, s);
            assert_eq!(p_flat, p_hat, "flat/{transport}: participation != |S|/n");
            let (tree, p_tree) =
                run_depth2_partial(transport, dt, &inner, seed, round, &xs, &survivors);
            assert_eq!(tree.means, want.means, "depth2/{transport}/t={dt}: != Lemma 8 ref");
            assert_eq!(p_tree, p_hat, "depth2/{transport}: participation != |S|/n");
        }
    }
}

#[test]
fn partial_round_correlated_offsets_stay_unbiased_under_churn() {
    // The frontier families under churn. For correlated quantization the
    // claim is that dropped clients' *unused* shared rounding offsets
    // cannot bias (or even perturb) the partial estimator — and
    // bit-equality with the Lemma 8 sampled reference is the strongest
    // form of it: the surviving ranks draw exactly the offsets a
    // fresh sampled run at p̂ = |S|/n would give them, no matter which
    // ranks went silent, and the estimator stays the (unbiased)
    // sampled mean. DRIVE rides along: its round-shared rotation must
    // survive churn the same way.
    for spec in ["correlated:k=8", "correlated:base=rotated,k=8", "drive"] {
        let seed = 2025;
        let inner = ProtocolConfig::parse(spec, DIM).unwrap().build().unwrap();
        let xs = population(seed);
        let (round, s, survivors) = survivor_fixed_point(&inner, seed, &xs);
        let p_hat = s as f64 / N as f64;
        let want = sampled_reference(inner.clone(), seed, round, p_hat, &xs);
        assert_eq!(want.n_frames, s, "{spec}: reference must transmit the fixed-point set");
        let (flat, p_flat) =
            run_flat_partial(Transport::Threads, 2, &inner, seed, round, &xs, &survivors);
        assert_eq!(flat.means, want.means, "{spec} flat: != Lemma 8 reference");
        assert_eq!(flat.n_frames, s, "{spec} flat: wrong survivor count");
        assert_eq!(p_flat, p_hat, "{spec} flat: participation != |S|/n");
        let (tree, p_tree) =
            run_depth2_partial(Transport::Threads, 2, &inner, seed, round, &xs, &survivors);
        assert_eq!(tree.means, want.means, "{spec} depth2: != Lemma 8 reference");
        assert_eq!(p_tree, p_hat, "{spec} depth2: participation != |S|/n");
    }
}

#[test]
fn scenario_rows_replay_bit_for_bit() {
    // Seed 11 is a verified partial-round seed for this plan: rounds 0
    // and 1 each drop exactly two of the eight clients, so both rows
    // exercise the Lemma 8 path at p̂ = 6/8.
    let seed = 11;
    let spec = ScenarioSpec {
        name: "replay".to_string(),
        protocol: "rotated:k=16".to_string(),
        n_clients: 8,
        dim: DIM,
        fanout: 0,
        rounds: 2,
        timeout: Duration::from_millis(250),
        transport: Transport::Threads,
        decode_threads: 2,
        faults: FaultPlan::parse("drop=0.2", seed).unwrap(),
        data: DataPlan::Clustered,
        seed,
    };
    let a = run_scenario(&spec).unwrap();
    let b = run_scenario(&spec).unwrap();
    assert_eq!(a.rows, b.rows, "same seed must replay the same trajectory");
    assert_eq!(a.rows.len(), 2);
    for row in &a.rows {
        assert_eq!(row.participation, 0.75, "round {}: p̂ != 6/8", row.round);
        assert_eq!(row.duplicate_uploads, 0);
        assert!(row.sq_error.is_finite(), "round {} lost its estimate", row.round);
        assert!(row.uplink_bits > 0);
    }
}
