//! Transport robustness: framing under adversarial delivery schedules,
//! connect backoff, and the reactor's scaling contract.
//!
//! These tests drive the hubs with raw `TcpStream`s (not `TcpEndpoint`)
//! so the byte boundaries on the wire are exactly what the test says
//! they are: one byte per `write`, a length prefix split mid-field, a
//! forged oversized prefix.

use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

use dme::coordinator::transport::{
    HubBinding, Message, TcpEndpoint, Transport, TransportHub, WeightedFrame,
};
use dme::protocol::Frame;

/// Every TCP hub implementation this platform can run.
fn transports_under_test() -> Vec<Transport> {
    #[cfg(target_os = "linux")]
    {
        vec![Transport::Threads, Transport::Reactor]
    }
    #[cfg(not(target_os = "linux"))]
    {
        vec![Transport::Threads]
    }
}

fn upload(client: u64, round: u64) -> Message {
    Message::Upload {
        client,
        round,
        frames: vec![WeightedFrame { frame: Frame::new(vec![0xA5; 7], 53), weight: 1.0 }],
    }
}

fn framed(msg: &Message) -> Vec<u8> {
    let body = msg.to_bytes().unwrap();
    let mut out = (body.len() as u32).to_le_bytes().to_vec();
    out.extend_from_slice(&body);
    out
}

#[test]
fn dribbled_one_byte_writes_survive_both_transports() {
    // The cruelest legal TCP delivery: every byte in its own segment,
    // so every message boundary — including the u32 length prefix
    // itself — is split. Both hubs must reassemble exactly.
    let msgs = vec![
        upload(1, 0),
        Message::SpecChange { round: 1, spec: "binary".into() },
        upload(2, 1),
    ];
    for transport in transports_under_test() {
        let binding = HubBinding::bind(transport, "127.0.0.1:0").unwrap();
        let addr = binding.local_addr().unwrap();
        let wire: Vec<u8> = msgs.iter().flat_map(|m| framed(m)).collect();
        let client = std::thread::spawn(move || {
            let mut stream = TcpStream::connect(addr).unwrap();
            stream.set_nodelay(true).unwrap();
            for b in wire {
                stream.write_all(&[b]).unwrap();
            }
            stream
        });
        let mut hub = binding.accept(1).unwrap();
        for want in &msgs {
            let got = hub.recv().unwrap();
            assert_eq!(
                got.to_bytes().unwrap(),
                want.to_bytes().unwrap(),
                "{transport}: message mangled by dribbled delivery"
            );
        }
        assert_eq!(
            hub.bytes_moved().1,
            msgs.iter().map(|m| m.framed_len()).sum::<u64>(),
            "{transport}: uplink accounting under dribbled delivery"
        );
        drop(client.join().unwrap());
    }
}

#[test]
fn oversized_length_prefix_rejected_on_both_transports() {
    // A forged u32::MAX length prefix must kill the connection before
    // any frame-sized allocation, on both hubs; with that lone worker
    // dead, recv reports disconnection instead of hanging.
    for transport in transports_under_test() {
        let binding = HubBinding::bind(transport, "127.0.0.1:0").unwrap();
        let addr = binding.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let mut stream = TcpStream::connect(addr).unwrap();
            stream.write_all(&u32::MAX.to_le_bytes()).unwrap();
            stream
        });
        let mut hub = binding.accept(1).unwrap();
        assert!(
            hub.recv().is_err(),
            "{transport}: oversized length prefix must error recv, not hang or allocate"
        );
        drop(client.join().unwrap());
    }
}

#[test]
fn connect_backoff_waits_for_late_listener() {
    // Reserve a port, drop it, and only rebind 150 ms later — the
    // worker-starts-before-leader race. Backoff must ride it out.
    let placeholder = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = placeholder.local_addr().unwrap();
    drop(placeholder);
    let server = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(150));
        let listener = TcpListener::bind(addr).unwrap();
        let (stream, _) = listener.accept().unwrap();
        stream
    });
    let ep = TcpEndpoint::connect_with_backoff(&addr.to_string(), 8);
    assert!(ep.is_ok(), "backoff should outlast a 150 ms bind race: {:?}", ep.err());
    drop(server.join().unwrap());
}

#[test]
fn connect_backoff_failure_names_address_and_attempts() {
    // Nothing ever listens: the final error must say where we tried and
    // how many times, and the retries must actually have waited.
    let placeholder = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = placeholder.local_addr().unwrap().to_string();
    drop(placeholder);
    let start = Instant::now();
    let err = TcpEndpoint::connect_with_backoff(&addr, 2).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains(&addr), "error must name the address: {msg}");
    assert!(msg.contains("3 attempt"), "error must count attempts: {msg}");
    // Two sleeps happened: 50 ms + 100 ms.
    assert!(
        start.elapsed() >= Duration::from_millis(140),
        "backoff returned too fast: {:?}",
        start.elapsed()
    );
}

#[cfg(target_os = "linux")]
#[test]
fn reactor_sustains_n_2048_round_with_flat_thread_count() {
    // The scaling contract in one test: a full broadcast + 2048-upload
    // round through one reactor hub, with the process's thread count
    // staying O(1) — the swarm multiplexes all 2048 clients on a single
    // thread, the hub serves them on a single thread.
    use dme::coordinator::swarm::Swarm;

    dme::coordinator::reactor::raise_nofile_limit();
    let n = 2048usize;
    let binding = HubBinding::bind(Transport::Reactor, "127.0.0.1:0").unwrap();
    let addr = binding.local_addr().unwrap();
    let swarm = Swarm::spawn(addr, n, move |i, msg| match msg {
        Message::RoundStart { round, .. } => {
            Some(Message::Upload { client: i as u64, round: *round, frames: vec![] })
        }
        _ => None,
    })
    .unwrap();
    let mut hub = binding.accept(n).unwrap();
    hub.broadcast(&Message::RoundStart { round: 0, dim: 8, payload: vec![0.5f32; 8].into() })
        .unwrap();
    let mut seen = vec![false; n];
    for _ in 0..n {
        match hub.recv().unwrap() {
            Message::Upload { client, .. } => {
                assert!(!seen[client as usize], "client {client} uploaded twice");
                seen[client as usize] = true;
            }
            other => panic!("expected Upload, got {other:?}"),
        }
    }
    let threads = thread_count();
    assert!(
        threads < 64,
        "thread count {threads} with {n} live connections — the hub is not O(1) threads"
    );
    drop(hub); // broadcasts Shutdown; the swarm drains and exits
    let report = swarm.join().unwrap();
    assert_eq!(report.connected, n);
    assert_eq!(report.replies_sent, n as u64);
}

#[cfg(target_os = "linux")]
fn thread_count() -> usize {
    let status = std::fs::read_to_string("/proc/self/status").unwrap();
    status
        .lines()
        .find_map(|l| l.strip_prefix("Threads:"))
        .expect("Threads: line in /proc/self/status")
        .trim()
        .parse()
        .unwrap()
}
