//! Transport robustness: framing under adversarial delivery schedules,
//! the versioned envelope's rejection contract, connect backoff, and the
//! reactor's scaling contract.
//!
//! The adversarial tests drive the hubs with raw `TcpStream`s (not
//! `TcpEndpoint`) so the byte boundaries on the wire are exactly what
//! the test says they are: one byte per `write`, a length prefix split
//! mid-field, a forged oversized prefix, a corrupted envelope header.
//!
//! The envelope contract under test: every tag round-trips with its
//! session id preserved verbatim on every transport; truncation and
//! trailing garbage are parse errors at every byte boundary; a wrong
//! magic or a future version is a **typed** [`WireError`] surfaced to
//! the hub's consumer (never a silent connection kill); an envelope
//! addressed to a session the receiver does not host is a typed
//! [`WireError::UnknownSession`] from the session router, after which
//! the link keeps working.

use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

use dme::coordinator::session::SessionMux;
use dme::coordinator::transport::{
    Envelope, HubBinding, LoopbackHub, Message, TcpEndpoint, Transport, TransportHub,
    WeightedFrame, WireError, WIRE_VERSION,
};
use dme::protocol::{Frame, SlotPartial};

/// Every TCP hub implementation this platform can run.
fn transports_under_test() -> Vec<Transport> {
    #[cfg(target_os = "linux")]
    {
        vec![Transport::Threads, Transport::Reactor]
    }
    #[cfg(not(target_os = "linux"))]
    {
        vec![Transport::Threads]
    }
}

fn upload(client: u64, round: u64) -> Message {
    Message::Upload {
        client,
        round,
        frames: vec![WeightedFrame { frame: Frame::new(vec![0xA5; 7], 53), weight: 1.0 }],
    }
}

fn framed(msg: &Message) -> Vec<u8> {
    let body = msg.to_bytes().unwrap();
    let mut out = (body.len() as u32).to_le_bytes().to_vec();
    out.extend_from_slice(&body);
    out
}

/// One message of every wire tag (1 = RoundStart, 2 = Upload,
/// 3 = Shutdown, 4 = PartialUpload, 5 = SpecChange).
fn all_tags() -> Vec<Message> {
    let slot = SlotPartial::from_decoded(&[1.0, -2.0, 0.5], 1.0, 1).unwrap();
    vec![
        Message::RoundStart { round: 3, shared_seed: 17, dim: 8, payload: vec![0.5f32; 8].into() },
        upload(1, 3),
        Message::Shutdown,
        Message::PartialUpload {
            agg_id: 9,
            round: 4,
            span: (0, 8),
            uplink_bits: 321,
            n_frames: 1,
            shard: (0, 3),
            slots: vec![slot],
        },
        Message::SpecChange { round: 5, spec: "klevel:k=16".into() },
    ]
}

#[test]
fn envelope_sessions_round_trip_for_every_tag_on_every_transport() {
    let sessions = [0u16, 1, 0xBEEF, u16::MAX];
    // Byte level: the envelope header carries the session verbatim and
    // framed_len matches the serialized size plus the length prefix.
    for msg in all_tags() {
        for &s in &sessions {
            let env = Envelope { session: s, msg: msg.clone() };
            let bytes = env.to_bytes().unwrap();
            assert_eq!(bytes.len() as u64 + 4, env.framed_len());
            let back = Envelope::from_bytes(&bytes).unwrap();
            assert_eq!(back.session, s);
            assert_eq!(back.msg.to_bytes().unwrap(), msg.to_bytes().unwrap());
        }
    }
    // Loopback: endpoint → hub preserves the session for every tag.
    let (mut hub, eps) = LoopbackHub::new(1);
    for msg in all_tags() {
        for &s in &sessions {
            eps[0].send_session(s, msg.clone()).unwrap();
            let env = hub.recv_env().unwrap();
            assert_eq!(env.session, s);
            assert_eq!(env.msg.to_bytes().unwrap(), msg.to_bytes().unwrap());
        }
    }
    // Both TCP hubs: upstream for every tag × session, then one
    // downstream broadcast on a non-root session.
    for transport in transports_under_test() {
        let binding = HubBinding::bind(transport, "127.0.0.1:0").unwrap();
        let addr = binding.local_addr().unwrap().to_string();
        let client = std::thread::spawn(move || {
            let mut ep = TcpEndpoint::connect(&addr).unwrap();
            for msg in all_tags() {
                for s in [0u16, 1, 0xBEEF, u16::MAX] {
                    ep.send_session(s, &msg).unwrap();
                }
            }
            let env = ep.recv_envelope().unwrap();
            (env.session, env.msg.to_bytes().unwrap())
        });
        let mut hub = binding.accept(1).unwrap();
        for msg in all_tags() {
            for &s in &sessions {
                let env = hub.recv_env().unwrap();
                assert_eq!(env.session, s, "{transport}: session mangled upstream");
                assert_eq!(
                    env.msg.to_bytes().unwrap(),
                    msg.to_bytes().unwrap(),
                    "{transport}: message mangled upstream"
                );
            }
        }
        let down = Message::RoundStart {
            round: 9,
            shared_seed: 17,
            dim: 4,
            payload: vec![1.0f32; 4].into(),
        };
        hub.broadcast_session(7, &down).unwrap();
        let (s, bytes) = client.join().unwrap();
        assert_eq!(s, 7, "{transport}: session mangled downstream");
        assert_eq!(bytes, down.to_bytes().unwrap(), "{transport}: message mangled downstream");
    }
}

#[test]
fn truncated_envelopes_rejected_at_every_boundary_for_every_tag() {
    // Truncation anywhere — inside the envelope header, inside the tag
    // payload — and trailing garbage are both parse errors for every
    // tag; the untouched serialization still parses.
    for msg in all_tags() {
        let env = Envelope { session: 3, msg };
        let bytes = env.to_bytes().unwrap();
        for cut in 0..bytes.len() {
            assert!(
                Envelope::from_bytes(&bytes[..cut]).is_err(),
                "truncation at {cut}/{} parsed",
                bytes.len()
            );
        }
        let mut long = bytes.clone();
        long.push(0);
        assert!(Envelope::from_bytes(&long).is_err(), "trailing garbage parsed");
        assert!(Envelope::from_bytes(&bytes).is_ok());
    }
}

#[test]
fn bad_magic_and_future_version_are_typed_rejections_on_every_transport() {
    // Byte level: the parser names the exact failure for every tag.
    for msg in all_tags() {
        let good = Envelope::root(msg).to_bytes().unwrap();
        let mut alien = good.clone();
        alien[0] = b'X';
        match Envelope::from_bytes(&alien).unwrap_err().downcast_ref::<WireError>() {
            Some(WireError::BadMagic(m)) => assert_eq!(m[0], b'X'),
            other => panic!("expected BadMagic, got {other:?}"),
        }
        let mut future = good.clone();
        future[2] = WIRE_VERSION + 1;
        match Envelope::from_bytes(&future).unwrap_err().downcast_ref::<WireError>() {
            Some(WireError::UnknownVersion(v)) => assert_eq!(*v, WIRE_VERSION + 1),
            other => panic!("expected UnknownVersion, got {other:?}"),
        }
    }
    // Both TCP hubs: a correctly framed but corrupted envelope must
    // surface the typed error to recv — reported, not a silent kill.
    for transport in transports_under_test() {
        for (corrupt, want_magic) in [(0usize, true), (2usize, false)] {
            let binding = HubBinding::bind(transport, "127.0.0.1:0").unwrap();
            let addr = binding.local_addr().unwrap();
            let client = std::thread::spawn(move || {
                let mut stream = TcpStream::connect(addr).unwrap();
                let mut wire = framed(&upload(4, 0));
                // Offset 4 skips the length prefix; the envelope header
                // starts there (magic at +0, version at +2).
                if want_magic {
                    wire[4 + corrupt] = b'Z';
                } else {
                    wire[4 + corrupt] = WIRE_VERSION + 7;
                }
                stream.write_all(&wire).unwrap();
                stream
            });
            let mut hub = binding.accept(1).unwrap();
            let err = hub.recv().unwrap_err();
            match err.downcast_ref::<WireError>() {
                Some(WireError::BadMagic(_)) => {
                    assert!(want_magic, "{transport}: wrong rejection kind")
                }
                Some(WireError::UnknownVersion(v)) => {
                    assert!(!want_magic, "{transport}: wrong rejection kind");
                    assert_eq!(*v, WIRE_VERSION + 7, "{transport}");
                }
                other => panic!("{transport}: expected a typed WireError, got {other:?}"),
            }
            drop(client.join().unwrap());
        }
    }
}

#[test]
fn round_start_shared_seed_survives_the_wire_and_rejects_stale_peers() {
    // The shared-randomness handshake rides tag 1: the seed must come
    // back verbatim; a forged byte inside the seed field lands *in the
    // seed* (it cannot shift the fields after it); and a v1 peer — whose
    // tag-1 layout has no seed at all — is a typed version rejection,
    // never a misparse of the seed bytes as the float count.
    let seed = 0x0102_0304_0506_0708u64;
    let m = Message::RoundStart {
        round: 3,
        shared_seed: seed,
        dim: 8,
        payload: vec![0.5f32; 8].into(),
    };
    let bytes = m.to_bytes().unwrap();
    match Message::from_bytes(&bytes).unwrap() {
        Message::RoundStart { shared_seed, .. } => assert_eq!(shared_seed, seed),
        other => panic!("expected RoundStart, got {other:?}"),
    }
    // The seed field sits after the envelope header (6) and round (8).
    let mut forged = bytes.clone();
    forged[6 + 8] ^= 0xff;
    match Message::from_bytes(&forged).unwrap() {
        Message::RoundStart { round, shared_seed, dim, payload } => {
            assert_eq!((round, dim), (3, 8));
            assert_eq!(&payload[..], &[0.5f32; 8]);
            assert_ne!(shared_seed, seed, "forgery must land in the seed field");
        }
        other => panic!("expected RoundStart, got {other:?}"),
    }
    let mut stale = bytes;
    stale[2] = 1;
    match Envelope::from_bytes(&stale).unwrap_err().downcast_ref::<WireError>() {
        Some(WireError::UnknownVersion(v)) => assert_eq!(*v, 1),
        other => panic!("expected UnknownVersion for the v1 layout, got {other:?}"),
    }
}

#[test]
fn unknown_session_is_a_typed_rejection_and_the_link_survives() {
    // The session router's half of the contract, over a real socket: an
    // envelope addressed to an unhosted session surfaces as a typed
    // UnknownSession to the receiving view, and the connection keeps
    // delivering — the very next message on a hosted session arrives.
    for transport in transports_under_test() {
        let binding = HubBinding::bind(transport, "127.0.0.1:0").unwrap();
        let addr = binding.local_addr().unwrap().to_string();
        let client = std::thread::spawn(move || {
            let mut ep = TcpEndpoint::connect(&addr).unwrap();
            ep.send_session(9, &upload(0, 0)).unwrap();
            ep.send_session(1, &upload(0, 0)).unwrap();
            ep
        });
        let hub = binding.accept(1).unwrap();
        let mux = SessionMux::new(hub);
        let mut view = mux.view(1);
        let err = view.recv_env().unwrap_err();
        match err.downcast_ref::<WireError>() {
            Some(WireError::UnknownSession(s)) => assert_eq!(*s, 9, "{transport}"),
            other => panic!("{transport}: expected UnknownSession, got {other:?}"),
        }
        let env = view.recv_env().unwrap();
        assert_eq!(env.session, 1, "{transport}: link must survive the rejection");
        drop(client.join().unwrap());
    }
}

#[test]
fn dribbled_one_byte_writes_survive_both_transports() {
    // The cruelest legal TCP delivery: every byte in its own segment,
    // so every message boundary — including the u32 length prefix
    // itself — is split. Both hubs must reassemble exactly.
    let msgs = vec![
        upload(1, 0),
        Message::SpecChange { round: 1, spec: "binary".into() },
        upload(2, 1),
    ];
    for transport in transports_under_test() {
        let binding = HubBinding::bind(transport, "127.0.0.1:0").unwrap();
        let addr = binding.local_addr().unwrap();
        let wire: Vec<u8> = msgs.iter().flat_map(|m| framed(m)).collect();
        let client = std::thread::spawn(move || {
            let mut stream = TcpStream::connect(addr).unwrap();
            stream.set_nodelay(true).unwrap();
            for b in wire {
                stream.write_all(&[b]).unwrap();
            }
            stream
        });
        let mut hub = binding.accept(1).unwrap();
        for want in &msgs {
            let got = hub.recv().unwrap();
            assert_eq!(
                got.to_bytes().unwrap(),
                want.to_bytes().unwrap(),
                "{transport}: message mangled by dribbled delivery"
            );
        }
        assert_eq!(
            hub.bytes_moved().1,
            msgs.iter().map(|m| m.framed_len()).sum::<u64>(),
            "{transport}: uplink accounting under dribbled delivery"
        );
        drop(client.join().unwrap());
    }
}

#[test]
fn oversized_length_prefix_rejected_on_both_transports() {
    // A forged u32::MAX length prefix must kill the connection before
    // any frame-sized allocation, on both hubs; with that lone worker
    // dead, recv reports disconnection instead of hanging.
    for transport in transports_under_test() {
        let binding = HubBinding::bind(transport, "127.0.0.1:0").unwrap();
        let addr = binding.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let mut stream = TcpStream::connect(addr).unwrap();
            stream.write_all(&u32::MAX.to_le_bytes()).unwrap();
            stream
        });
        let mut hub = binding.accept(1).unwrap();
        assert!(
            hub.recv().is_err(),
            "{transport}: oversized length prefix must error recv, not hang or allocate"
        );
        drop(client.join().unwrap());
    }
}

#[test]
fn connect_backoff_waits_for_late_listener() {
    // Reserve a port, drop it, and only rebind 150 ms later — the
    // worker-starts-before-leader race. Backoff must ride it out.
    let placeholder = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = placeholder.local_addr().unwrap();
    drop(placeholder);
    let server = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(150));
        let listener = TcpListener::bind(addr).unwrap();
        let (stream, _) = listener.accept().unwrap();
        stream
    });
    let ep = TcpEndpoint::connect_with_backoff(&addr.to_string(), 8);
    assert!(ep.is_ok(), "backoff should outlast a 150 ms bind race: {:?}", ep.err());
    drop(server.join().unwrap());
}

#[test]
fn connect_backoff_failure_names_address_and_attempts() {
    // Nothing ever listens: the final error must say where we tried and
    // how many times, and the retries must actually have waited.
    let placeholder = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = placeholder.local_addr().unwrap().to_string();
    drop(placeholder);
    let start = Instant::now();
    let err = TcpEndpoint::connect_with_backoff(&addr, 2).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains(&addr), "error must name the address: {msg}");
    assert!(msg.contains("3 attempt"), "error must count attempts: {msg}");
    // Two sleeps happened: 50 ms + 100 ms.
    assert!(
        start.elapsed() >= Duration::from_millis(140),
        "backoff returned too fast: {:?}",
        start.elapsed()
    );
}

#[cfg(target_os = "linux")]
#[test]
fn reactor_sustains_n_2048_round_with_flat_thread_count() {
    // The scaling contract in one test: a full broadcast + 2048-upload
    // round through one reactor hub, with the process's thread count
    // staying O(1) — the swarm multiplexes all 2048 clients on a single
    // thread, the hub serves them on a single thread.
    use dme::coordinator::swarm::Swarm;

    dme::coordinator::reactor::raise_nofile_limit();
    let n = 2048usize;
    let binding = HubBinding::bind(Transport::Reactor, "127.0.0.1:0").unwrap();
    let addr = binding.local_addr().unwrap();
    let swarm = Swarm::spawn(addr, n, move |i, msg| match msg {
        Message::RoundStart { round, .. } => {
            Some(Message::Upload { client: i as u64, round: *round, frames: vec![] })
        }
        _ => None,
    })
    .unwrap();
    let mut hub = binding.accept(n).unwrap();
    hub.broadcast(&Message::RoundStart {
        round: 0,
        shared_seed: 17,
        dim: 8,
        payload: vec![0.5f32; 8].into(),
    })
    .unwrap();
    let mut seen = vec![false; n];
    for _ in 0..n {
        match hub.recv().unwrap() {
            Message::Upload { client, .. } => {
                assert!(!seen[client as usize], "client {client} uploaded twice");
                seen[client as usize] = true;
            }
            other => panic!("expected Upload, got {other:?}"),
        }
    }
    let threads = thread_count();
    assert!(
        threads < 64,
        "thread count {threads} with {n} live connections — the hub is not O(1) threads"
    );
    drop(hub); // broadcasts Shutdown; the swarm drains and exits
    let report = swarm.join().unwrap();
    assert_eq!(report.connected, n);
    assert_eq!(report.replies_sent, n as u64);
}

#[cfg(target_os = "linux")]
fn thread_count() -> usize {
    let status = std::fs::read_to_string("/proc/self/status").unwrap();
    status
        .lines()
        .find_map(|l| l.strip_prefix("Threads:"))
        .expect("Threads: line in /proc/self/status")
        .trim()
        .parse()
        .unwrap()
}
