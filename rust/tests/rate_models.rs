//! Property suite for the rate models (`rate::model`): the calibrated
//! predictors must track real rounds across every protocol family, both
//! test dimensions, and both client counts.
//!
//! Contracts (satellite spec):
//! * empirical MSE from real rounds ≤ calibrated `predicted_mse` ×
//!   `MSE_SLACK`. The calibration probe and the test rounds are
//!   independent draws of a per-round error whose mean they both
//!   estimate from a handful of rounds; for the sampled wrappers the
//!   binomial client count makes single-round MSE swing by tens of
//!   percent, so the slack is 3× (documented here, deterministic under
//!   the fixed seeds).
//! * `predicted_uplink_bits` within 10% of realized
//!   `RoundMetrics::uplink_bits`. For client-sampled specs (p < 1) the
//!   realized count is binomial, so the tolerance widens by 3σ of the
//!   sampling noise — exact formulas stay at 10%.
//!
//! Everything runs through `run_round` — the same engine the `estimate`
//! CLI and the coordinator's conformance baseline use; a final check
//! drives a real loopback cluster and compares against the leader's
//! `RoundMetrics::uplink_bits` literally.

use dme::coordinator::leader::spawn_local_cluster;
use dme::coordinator::worker::mean_update;
use dme::data::synthetic;
use dme::protocol::config::ProtocolConfig;
use dme::protocol::{run_round, RoundCtx};
use dme::rate::Calibration;
use dme::stats;

const MSE_SLACK: f64 = 3.0;
const BITS_TOL: f64 = 0.10;
const TRIALS: u64 = 3;

const SPECS: [&str; 13] = [
    "float32",
    "binary",
    "klevel:k=4",
    "klevel:k=16",
    "rotated:k=4",
    "rotated:k=16",
    "varlen:k=8",
    "varlen:span=norm", // k defaults to sqrt(d)+1 — Theorem 4's regime
    "varlen:k=16,coder=huffman",
    "qsgd:k=8",
    "klevel:k=16,p=0.5",
    "klevel:k=8,q=0.5",
    "varlen:k=8,p=0.25",
];

#[test]
fn calibrated_models_track_real_rounds_across_specs_dims_and_ns() {
    for d in [1usize << 8, 1 << 12] {
        // One calibration per dimension: fitted once, reused for every
        // (spec, n) — the way the planner consumes it.
        let mut cal = Calibration::new(1234).with_probe(8, 4);
        for n in [16usize, 256] {
            let data = synthetic::gaussian(n, d, 7 + d as u64 + n as u64);
            let truth = stats::true_mean(&data.rows);
            let avg_sq = stats::avg_norm_sq(&data.rows);
            for spec in SPECS {
                let cfg = ProtocolConfig::parse(spec, d).unwrap();
                cal.fit(&cfg).unwrap();
                let proto = cfg.build().unwrap();
                let mut err = stats::Running::new();
                let mut bits = stats::Running::new();
                // Client-sampled specs transmit a binomial number of
                // frames per round; average realized bits over more
                // rounds so the comparison tests the model, not one
                // coin-flip draw. More rounds at small n (where the
                // speaker count swings hardest), fewer at large n —
                // the tolerance below adapts to the count either way.
                let bits_trials = if cfg.p < 1.0 { (384 / n).clamp(8, 24) as u64 } else { TRIALS };
                for t in 0..bits_trials {
                    let ctx = RoundCtx::new(t, 99);
                    let (est, b) = run_round(proto.as_ref(), &ctx, &data.rows).unwrap();
                    if t < TRIALS {
                        err.push(stats::sq_error(&est, &truth));
                    }
                    bits.push(b as f64);
                }

                // (a) Empirical MSE under the calibrated prediction. The
                // absolute epsilon covers float32, whose predicted MSE
                // is exactly 0 while real rounds carry f32 summation
                // noise.
                let pred_mse = cal.predicted_mse(&cfg, n, avg_sq);
                assert!(
                    err.mean() <= pred_mse * MSE_SLACK + 1e-9 * avg_sq,
                    "{spec} d={d} n={n}: empirical MSE {:.3e} exceeds calibrated \
                     prediction {:.3e} x{MSE_SLACK}",
                    err.mean(),
                    pred_mse
                );

                // (b) Predicted bits vs realized uplink bits.
                let pred_bits = cal.predicted_bits(&cfg) * n as f64;
                let tol = if cfg.p < 1.0 {
                    // Binomial speaker count: widen by 3σ of the
                    // relative sampling noise over the averaged rounds
                    // (the prediction side is noise-free — the fitter
                    // probes the p=1 twin and scales by p analytically).
                    BITS_TOL
                        + 3.0
                            * ((1.0 - cfg.p) / (cfg.p * n as f64 * bits_trials as f64)).sqrt()
                } else {
                    BITS_TOL
                };
                let rel = (pred_bits - bits.mean()).abs() / bits.mean().max(1.0);
                assert!(
                    rel <= tol,
                    "{spec} d={d} n={n}: predicted {pred_bits:.0} bits vs realized {:.0} \
                     ({:.1}% off, tol {:.1}%)",
                    bits.mean(),
                    rel * 100.0,
                    tol * 100.0
                );
            }
        }
    }
}

#[test]
fn predictions_match_leader_round_metrics_literally() {
    // The satellite names RoundMetrics::uplink_bits — drive a real
    // coordinator and read the field itself.
    let d = 256;
    let n = 12;
    for spec in ["binary", "rotated:k=16", "varlen:k=8"] {
        let cfg = ProtocolConfig::parse(spec, d).unwrap();
        let mut cal = Calibration::new(5).with_probe(8, 4);
        cal.fit(&cfg).unwrap();
        let pred_total = cal.predicted_bits(&cfg) * n as f64;

        let mut rng = dme::rng::Pcg64::new(31);
        let shards: Vec<Vec<Vec<f32>>> = (0..n)
            .map(|_| {
                let mut x = vec![0.0f32; d];
                rng.fill_gaussian_f32(&mut x);
                vec![x]
            })
            .collect();
        let (mut leader, handles) =
            spawn_local_cluster(cfg.build().unwrap(), shards, mean_update(), 8);
        for r in 0..2 {
            leader.round(r, d as u32, &[]).unwrap();
        }
        leader.shutdown().unwrap();
        for h in handles {
            h.join().unwrap().unwrap();
        }
        for m in &leader.metrics().rounds {
            let rel = (pred_total - m.uplink_bits as f64).abs() / m.uplink_bits as f64;
            assert!(
                rel <= 0.10,
                "{spec}: predicted {pred_total:.0} vs RoundMetrics::uplink_bits {} \
                 ({:.1}% off)",
                m.uplink_bits,
                rel * 100.0
            );
        }
    }
}
