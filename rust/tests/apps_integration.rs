//! Application-level integration: the paper's §7 experiments, shrunk to CI
//! scale, asserting the figures' qualitative *shape* (who wins, and that
//! quantized runs track the exact-uplink baseline).

use dme::apps::kmeans::{self, KMeansConfig};
use dme::apps::power_iteration::{self, PowerConfig};
use dme::data::synthetic;
use dme::protocol::config::ProtocolConfig;
use dme::protocol::{run_round, RoundCtx};
use dme::stats;

#[test]
fn figure1_shape_rotation_wins_on_unbalanced_data() {
    // The Figure 1 claim: on unbalanced data, rotated quantization beats
    // uniform by a wide margin at equal bits, most dramatically at low k.
    let d = 256;
    let data = synthetic::unbalanced(200, d, 100.0, 1);
    let truth = stats::true_mean(&data.rows);
    for k in [2u32, 16] {
        let mut mses = Vec::new();
        for spec in [format!("klevel:k={k}"), format!("rotated:k={k}")] {
            let proto = ProtocolConfig::parse(&spec, d).unwrap().build().unwrap();
            let mut err = stats::Running::new();
            for t in 0..6 {
                let ctx = RoundCtx::new(t, 2);
                let (est, _) = run_round(proto.as_ref(), &ctx, &data.rows).unwrap();
                err.push(stats::sq_error(&est, &truth));
            }
            mses.push(err.mean());
        }
        let (uniform, rotated) = (mses[0], mses[1]);
        assert!(
            rotated < uniform / 3.0,
            "k={k}: rotated {rotated} should be << uniform {uniform}"
        );
    }
}

#[test]
fn figure2_shape_quantized_kmeans_tracks_float32_mnist_like() {
    let data = synthetic::mnist_like(300, 7);
    let d = data.dim;
    let cfg = KMeansConfig { n_centers: 10, n_clients: 10, iters: 5, seed: 17 };
    let run_obj = |spec: &str| {
        let proto = ProtocolConfig::parse(spec, d).unwrap().build().unwrap();
        kmeans::run(&data.rows, proto, &cfg).unwrap()
    };
    let exact = run_obj("float32");
    let exact_obj = exact.rounds.last().unwrap().objective;
    // Image-valued centers ([0,1] pixels) have min-max range ~1, so plain
    // k-level already quantizes them well; rotation spreads the (large)
    // norm across coordinates and carries a higher noise floor on this
    // data — the same effect Figure 1 shows in reverse on unbalanced data.
    for (spec, factor) in [("varlen:k=16", 1.15), ("klevel:k=16", 1.15), ("rotated:k=16", 2.5)] {
        let result = run_obj(spec);
        let obj = result.rounds.last().unwrap().objective;
        assert!(
            obj < exact_obj * factor,
            "{spec}: objective {obj} vs float32 {exact_obj} (factor {factor})"
        );
        // and at far fewer bits than float32 (bits_per_dim_per_iter
        // aggregates all 10 clients x 10 centers: float32 = 3200/dim/iter)
        assert!(
            result.bits_per_dim_per_iter < exact.bits_per_dim_per_iter / 5.0,
            "{spec}: {} vs float32 {}",
            result.bits_per_dim_per_iter,
            exact.bits_per_dim_per_iter
        );
    }
    assert!(exact.bits_per_dim_per_iter > 3100.0); // 100 frames x 32 bits/dim
}

#[test]
fn figure3_shape_quantized_power_iteration_cifar_like() {
    let data = synthetic::cifar_like(400, 9);
    let d = data.dim;
    let cfg = PowerConfig { n_clients: 50, iters: 8, seed: 29 };
    let run_dist = |spec: &str| {
        let proto = ProtocolConfig::parse(spec, d).unwrap().build().unwrap();
        power_iteration::run(&data.rows, proto, &cfg).unwrap()
    };
    let exact = run_dist("float32");
    let exact_dist = exact.rounds.last().unwrap().eig_dist;
    for spec in ["rotated:k=32", "varlen:k=32"] {
        let result = run_dist(spec);
        let dist = result.rounds.last().unwrap().eig_dist;
        // quantized runs converge near the exact run's distance
        assert!(
            dist < exact_dist + 0.1,
            "{spec}: eig dist {dist} vs float32 {exact_dist}"
        );
    }
}

#[test]
fn varlen_beats_uniform_at_equal_or_less_communication() {
    // The §7 conclusion: "variable-length coding achieves the lowest
    // quantization error in most of the settings".
    let data = synthetic::mnist_like(200, 3);
    let d = data.dim;
    let truth = stats::true_mean(&data.rows);
    let measure = |spec: &str| {
        let proto = ProtocolConfig::parse(spec, d).unwrap().build().unwrap();
        let mut err = stats::Running::new();
        let mut bits = stats::Running::new();
        for t in 0..5 {
            let ctx = RoundCtx::new(t, 4);
            let (est, b) = run_round(proto.as_ref(), &ctx, &data.rows).unwrap();
            err.push(stats::sq_error(&est, &truth));
            bits.push(b as f64);
        }
        (err.mean(), bits.mean())
    };
    // The §4 claim in its exact form: same quantizer (same k, same span,
    // same private streams → identical bins and MSE), strictly fewer bits
    // thanks to entropy coding.
    let (mse_uniform, bits_uniform) = measure("klevel:k=33");
    let (mse_varlen, bits_varlen) = measure("varlen:k=33,span=minmax");
    assert!(
        (mse_varlen - mse_uniform).abs() <= 1e-6 + 0.01 * mse_uniform,
        "same quantizer must give same MSE: {mse_varlen} vs {mse_uniform}"
    );
    assert!(
        bits_varlen < bits_uniform * 0.85,
        "varlen bits {bits_varlen} should undercut fixed-width {bits_uniform}"
    );
}
