//! Multi-tenant session conformance: one transport, one aggregator
//! tree, several concurrent estimation sessions.
//!
//! The contract under test: a tenant hosted by a `SessionMux`-backed
//! tree is **bit-identical** to the same session run solo over its own
//! flat cluster — the encoder's RNG streams are keyed by (client, slot,
//! session), the per-slot folds are exact, and nothing a co-tenant does
//! (interleaved rounds, a different spec, a mid-session `SpecChange`)
//! may leak into another session's estimate. Per-session byte
//! accounting must partition the shared wire exactly.

use std::sync::Arc;

use dme::coordinator::aggregator::spawn_mux_tree;
use dme::coordinator::leader::{ChildKey, Leader, RoundOutcome};
use dme::coordinator::topology::Topology;
use dme::coordinator::transport::LoopbackHub;
use dme::coordinator::worker::{mean_update, UpdateFn, Worker};
use dme::protocol::config::ProtocolConfig;
use dme::protocol::Protocol;
use dme::rng::Pcg64;

const D: usize = 16;
const N: usize = 6;
const SEED: u64 = 29;
const ROUNDS: u64 = 3;

fn gaussian_shards(n: usize, d: usize, seed: u64) -> Vec<Vec<Vec<f32>>> {
    let mut rng = Pcg64::new(seed);
    (0..n)
        .map(|_| {
            let mut x = vec![0.0f32; d];
            rng.fill_gaussian_f32(&mut x);
            vec![x]
        })
        .collect()
}

fn proto_for(spec: &str) -> Arc<dyn Protocol> {
    ProtocolConfig::parse(spec, D).unwrap().build().unwrap()
}

fn assert_outcomes_bit_identical(a: &RoundOutcome, b: &RoundOutcome, what: &str) {
    assert_eq!(a.uplink_bits, b.uplink_bits, "{what}: uplink_bits");
    assert_eq!(a.n_frames, b.n_frames, "{what}: n_frames");
    assert_eq!(a.weights, b.weights, "{what}: weights");
    assert_eq!(a.means.len(), b.means.len(), "{what}: slot count");
    for (slot, (x, y)) in a.means.iter().zip(&b.means).enumerate() {
        assert_eq!(
            x.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            y.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "{what}: slot {slot} means diverge"
        );
    }
}

/// Run `session` solo: a flat loopback cluster of plain workers with a
/// leader pinned to that session id, optionally switching to `switch`
/// before round 1 — the single-tenant reference every muxed tenant must
/// reproduce bit for bit.
fn solo_outcomes(
    session: u16,
    spec: &str,
    shards: &[Vec<Vec<f32>>],
    update: &UpdateFn,
    switch: Option<&str>,
) -> Vec<RoundOutcome> {
    let (hub, endpoints) = LoopbackHub::new(N);
    let mut handles = Vec::new();
    for (i, ep) in endpoints.into_iter().enumerate() {
        let worker = Worker {
            client_id: i as u64,
            shard: shards[i].clone(),
            protocol: proto_for(spec),
            update: update.clone(),
            seed: SEED,
        };
        handles.push(std::thread::spawn(move || worker.run_loopback(ep)));
    }
    let mut leader = Leader::new(proto_for(spec), Box::new(hub), SEED)
        .with_session(session)
        .with_expected_children((0..N as u64).map(ChildKey::Client).collect());
    let mut out = Vec::new();
    for r in 0..ROUNDS {
        if r == 1 {
            if let Some(to) = switch {
                leader.switch_spec(to, r).unwrap();
            }
        }
        out.push(leader.round(r, D as u32, &[]).unwrap());
    }
    leader.shutdown().unwrap();
    for h in handles {
        h.join().unwrap().unwrap();
    }
    out
}

#[test]
fn muxed_tenants_are_bit_identical_to_solo_sessions() {
    // Two tenants with different specs share one depth-2 tree; rounds
    // are interleaved with alternating drive order so every round parks
    // the other tenant's envelopes at least once. Each tenant must be
    // bit-identical to its solo flat run, and the per-session byte
    // accounting must partition the hub's totals exactly.
    let update = mean_update();
    let shards = gaussian_shards(N, D, SEED ^ 0xABCD);
    // One tenant per frontier family: DRIVE's shared rotation and the
    // correlated offset stream both key off the round's wire
    // `shared_seed`, so muxing must leave each bit-identical to solo.
    let specs = [(1u16, "drive"), (2u16, "correlated:k=16")];
    let solo: Vec<Vec<RoundOutcome>> = specs
        .iter()
        .map(|(s, spec)| solo_outcomes(*s, spec, &shards, &update, None))
        .collect();

    let tenants: Vec<(u16, Arc<dyn Protocol>)> =
        specs.iter().map(|(s, spec)| (*s, proto_for(spec))).collect();
    let topo = Topology::uniform(N as u64, 3, 2).unwrap();
    let (mux, mut leaders, tree) =
        spawn_mux_tree(&tenants, shards, update.clone(), SEED, &topo, 2, None).unwrap();
    let mut got: Vec<Vec<RoundOutcome>> = vec![Vec::new(); leaders.len()];
    for r in 0..ROUNDS {
        let order: Vec<usize> = if r % 2 == 0 {
            (0..leaders.len()).collect()
        } else {
            (0..leaders.len()).rev().collect()
        };
        for i in order {
            got[i].push(leaders[i].round(r, D as u32, &[]).unwrap());
        }
    }
    for leader in &mut leaders {
        leader.shutdown().unwrap();
    }
    tree.join().unwrap();

    for (i, (s, spec)) in specs.iter().enumerate() {
        for (r, (g, w)) in got[i].iter().zip(&solo[i]).enumerate() {
            assert_outcomes_bit_identical(
                g,
                w,
                &format!("tenant {s} ({spec}) round {r} diverges from its solo run"),
            );
        }
    }

    // The shared wire splits exactly: per-session bytes are non-zero
    // and sum to the underlying hub's totals.
    let (total_down, total_up) = mux.bytes_moved();
    let mut sum_down = 0u64;
    let mut sum_up = 0u64;
    for (s, _) in &specs {
        let (down, up) = mux.session_bytes(*s);
        assert!(down > 0 && up > 0, "session {s} moved no bytes");
        sum_down += down;
        sum_up += up;
    }
    assert_eq!(sum_down, total_down, "downlink bytes must partition by session");
    assert_eq!(sum_up, total_up, "uplink bytes must partition by session");
}

#[test]
fn muxed_tenants_survive_a_sharded_root() {
    // Session multiplexing composes with dimension sharding: the same
    // two-tenant contract over a tree whose root children each answer
    // with one PartialUpload per shard range, per session.
    let update = mean_update();
    let shards = gaussian_shards(N, D, SEED ^ 0x5111);
    let specs = [(1u16, "klevel:k=16"), (2u16, "varlen:k=17")];
    let solo: Vec<Vec<RoundOutcome>> = specs
        .iter()
        .map(|(s, spec)| solo_outcomes(*s, spec, &shards, &update, None))
        .collect();
    let tenants: Vec<(u16, Arc<dyn Protocol>)> =
        specs.iter().map(|(s, spec)| (*s, proto_for(spec))).collect();
    let topo = Topology::uniform(N as u64, 3, 2).unwrap().with_dim_shards(3).unwrap();
    let (_mux, mut leaders, tree) =
        spawn_mux_tree(&tenants, shards, update, SEED, &topo, 2, None).unwrap();
    let mut got: Vec<Vec<RoundOutcome>> = vec![Vec::new(); leaders.len()];
    for r in 0..ROUNDS {
        for (i, leader) in leaders.iter_mut().enumerate() {
            got[i].push(leader.round(r, D as u32, &[]).unwrap());
        }
    }
    for leader in &mut leaders {
        leader.shutdown().unwrap();
    }
    tree.join().unwrap();
    for (i, (s, spec)) in specs.iter().enumerate() {
        for (r, (g, w)) in got[i].iter().zip(&solo[i]).enumerate() {
            assert_outcomes_bit_identical(
                g,
                w,
                &format!("sharded mux tenant {s} ({spec}) round {r}"),
            );
        }
    }
}

#[test]
fn spec_change_on_one_tenant_leaves_the_other_bit_identical() {
    // The isolation contract for mid-session retuning: tenant 1 switches
    // spec before round 1 (the rate controller's move), tenant 2 keeps
    // its spec — and tenant 2's every round stays bit-identical to a
    // solo run that never saw any SpecChange, while tenant 1 matches a
    // solo run that made the same switch.
    let update = mean_update();
    let shards = gaussian_shards(N, D, SEED ^ 0xABCD);
    let from = "klevel:k=16";
    let to = "klevel:k=4";
    let bystander = "rotated:k=16";
    let want_switched = solo_outcomes(1, from, &shards, &update, Some(to));
    let want_bystander = solo_outcomes(2, bystander, &shards, &update, None);

    let tenants: Vec<(u16, Arc<dyn Protocol>)> =
        vec![(1u16, proto_for(from)), (2u16, proto_for(bystander))];
    let topo = Topology::uniform(N as u64, 3, 2).unwrap();
    let (_mux, mut leaders, tree) =
        spawn_mux_tree(&tenants, shards, update, SEED, &topo, 2, None).unwrap();
    let mut got: Vec<Vec<RoundOutcome>> = vec![Vec::new(); 2];
    for r in 0..ROUNDS {
        if r == 1 {
            leaders[0].switch_spec(to, r).unwrap();
        }
        for (i, leader) in leaders.iter_mut().enumerate() {
            got[i].push(leader.round(r, D as u32, &[]).unwrap());
        }
    }
    for leader in &mut leaders {
        leader.shutdown().unwrap();
    }
    tree.join().unwrap();

    for (r, (g, w)) in got[0].iter().zip(&want_switched).enumerate() {
        assert_outcomes_bit_identical(g, w, &format!("switched tenant round {r}"));
    }
    for (r, (g, w)) in got[1].iter().zip(&want_bystander).enumerate() {
        assert_outcomes_bit_identical(
            g,
            w,
            &format!("bystander tenant round {r} — the co-tenant's SpecChange leaked"),
        );
    }
    assert_eq!(leaders[0].protocol_name(), proto_for(to).name());
}
