//! Integration: the AOT-compiled JAX/Pallas artifacts executed via PJRT
//! must agree with the native Rust implementations — same rotation, same
//! bins from the same uniforms, and protocols built on the PJRT backend
//! must interoperate bit-for-bit with native-decoded frames.
//!
//! Requires `make artifacts` (skipped with a message otherwise).

use std::sync::Arc;

use dme::protocol::config::ProtocolConfig;
use dme::protocol::quantizer::Span;
use dme::protocol::{run_round, RoundCtx};
use dme::rng::Pcg64;
use dme::runtime::{artifacts::Manifest, ComputeBackend, NativeBackend, PjrtBackend};
use dme::stats;

fn artifacts_present() -> bool {
    Manifest::default_dir().join("manifest.tsv").exists()
}

macro_rules! require_artifacts {
    () => {
        if !artifacts_present() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
    };
}

fn gauss(d: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg64::new(seed);
    let mut x = vec![0.0f32; d];
    rng.fill_gaussian_f32(&mut x);
    x
}

fn signs(d: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg64::new(seed);
    let mut s = vec![0.0f32; d];
    rng.fill_rademacher(&mut s);
    s
}

fn uniforms(d: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg64::new(seed);
    let mut u = vec![0.0f32; d];
    rng.fill_uniform_f32(&mut u);
    u
}

#[test]
fn rotate_fwd_matches_native_all_dims() {
    require_artifacts!();
    let pjrt = PjrtBackend::new().expect("pjrt backend");
    let native = NativeBackend;
    for d in [16usize, 64, 256, 512, 1024] {
        let x = gauss(d, d as u64);
        let s = signs(d, d as u64 + 1);
        let zp = pjrt.rotate_fwd(&x, &s).expect("pjrt rotate");
        let zn = native.rotate_fwd(&x, &s).expect("native rotate");
        for (j, (a, b)) in zp.iter().zip(&zn).enumerate() {
            assert!(
                (a - b).abs() < 1e-3,
                "d={d} coord {j}: pjrt {a} vs native {b}"
            );
        }
        // and the inverse round-trips
        let back = pjrt.rotate_inv(&zp, &s).expect("pjrt inverse");
        for (j, (a, b)) in back.iter().zip(&x).enumerate() {
            assert!((a - b).abs() < 1e-3, "d={d} inv coord {j}: {a} vs {b}");
        }
    }
}

#[test]
fn quantize_bins_match_native_exactly() {
    require_artifacts!();
    let pjrt = PjrtBackend::new().expect("pjrt backend");
    let native = NativeBackend;
    for d in [16usize, 256] {
        for k in [2u32, 16, 33] {
            for span in [Span::MinMax, Span::Norm] {
                let x = gauss(d, 7 + d as u64 + k as u64);
                let u = uniforms(d, 9 + k as u64);
                let qp = pjrt.quantize(&x, &u, span, k).expect("pjrt quantize");
                let qn = native.quantize(&x, &u, span, k).expect("native quantize");
                assert!((qp.xmin - qn.xmin).abs() < 1e-5, "xmin d={d} k={k}");
                assert!(
                    (qp.s - qn.s).abs() < 1e-3 * qn.s.abs().max(1.0),
                    "s d={d} k={k}: {} vs {}",
                    qp.s,
                    qn.s
                );
                // Bins may differ only where x sits exactly on a grid edge
                // (f32 rounding); require >= 99% exact agreement.
                let same = qp.bins.iter().zip(&qn.bins).filter(|(a, b)| a == b).count();
                assert!(
                    same * 100 >= d * 99,
                    "d={d} k={k} span={span:?}: only {same}/{d} bins agree"
                );
            }
        }
    }
}

#[test]
fn fused_encode_rotated_matches_native_composition() {
    require_artifacts!();
    let pjrt = PjrtBackend::new().expect("pjrt backend");
    let native = NativeBackend;
    let d = 256;
    let x = gauss(d, 21);
    let s = signs(d, 22);
    let u = uniforms(d, 23);
    let qp = pjrt.encode_rotated(&x, &s, &u, 16).expect("pjrt fused");
    let qn = native.encode_rotated(&x, &s, &u, 16).expect("native fused");
    let same = qp.bins.iter().zip(&qn.bins).filter(|(a, b)| a == b).count();
    assert!(same * 100 >= d * 99, "only {same}/{d} bins agree");
}

#[test]
fn decode_sum_artifact_matches_manual() {
    require_artifacts!();
    let pjrt = PjrtBackend::new().expect("pjrt backend");
    let d = 64;
    let rows = 8; // compiled decode batch
    let k = 16u32;
    let mut bins = Vec::new();
    let mut xmin = Vec::new();
    let mut s = Vec::new();
    let mut rng = Pcg64::new(31);
    for _ in 0..rows {
        for _ in 0..d {
            bins.push(rng.next_below(k) as f32);
        }
        xmin.push(rng.gaussian() as f32);
        s.push(rng.next_f32() + 0.1);
    }
    let got = pjrt
        .decode_sum(bins.clone(), xmin.clone(), s.clone(), k, d)
        .expect("decode_sum");
    for j in 0..d {
        let mut want = 0.0f64;
        for r in 0..rows {
            want += xmin[r] as f64 + bins[r * d + j] as f64 * s[r] as f64 / (k - 1) as f64;
        }
        assert!(
            (got[j] as f64 - want).abs() < 1e-3,
            "coord {j}: {} vs {want}",
            got[j]
        );
    }
}

#[test]
fn protocols_on_pjrt_backend_interoperate_with_native() {
    require_artifacts!();
    let pjrt: Arc<dyn ComputeBackend> = Arc::new(PjrtBackend::new().expect("pjrt backend"));
    let d = 256;
    let n = 6;
    let xs: Vec<Vec<f32>> = (0..n).map(|i| gauss(d, 100 + i as u64)).collect();
    let truth = stats::true_mean(&xs);
    for spec in ["klevel:k=16", "rotated:k=16", "varlen:k=17"] {
        let ctx = RoundCtx::new(0, 555);
        let native_proto = ProtocolConfig::parse(spec, d).unwrap().build().unwrap();
        let pjrt_proto = ProtocolConfig::parse(spec, d)
            .unwrap()
            .with_backend(pjrt.clone())
            .build()
            .unwrap();
        let (est_n, bits_n) = run_round(native_proto.as_ref(), &ctx, &xs).unwrap();
        let (est_p, bits_p) = run_round(pjrt_proto.as_ref(), &ctx, &xs).unwrap();
        // Same uniforms -> same bins (up to grid-edge f32 ties) -> nearly
        // identical frames; identical bit cost is exact for fixed-width.
        if spec.starts_with("klevel") || spec.starts_with("rotated") {
            assert_eq!(bits_n, bits_p, "spec={spec}");
        }
        let err_n = stats::sq_error(&est_n, &truth);
        let err_p = stats::sq_error(&est_p, &truth);
        assert!(
            (err_n - err_p).abs() <= 0.1 * err_n.max(1e-9) + 1e-9,
            "spec={spec}: native err {err_n} vs pjrt err {err_p}"
        );
        // both within the analytic bound
        let bound = native_proto.mse_bound(n, stats::avg_norm_sq(&xs));
        if let Some(b) = bound {
            assert!(err_p <= b * 3.0, "spec={spec}: pjrt err {err_p} vs bound {b}");
        }
    }
}

#[test]
fn pjrt_unsupported_dim_is_clean_error() {
    require_artifacts!();
    let pjrt = PjrtBackend::new().expect("pjrt backend");
    let err = pjrt
        .rotate_fwd(&gauss(32, 1), &signs(32, 2))
        .expect_err("dim 32 is not compiled");
    let msg = format!("{err:#}");
    assert!(msg.contains("make artifacts"), "unhelpful error: {msg}");
}
