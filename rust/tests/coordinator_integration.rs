//! Coordinator integration: full leader/worker rounds over both transports
//! (loopback threads and real TCP sockets), with byte accounting and the
//! protocol stack in between.
//!
//! TCP tests bind port 0 and read the real address back from the
//! listener — no hardcoded ports (parallel test runs would collide) and
//! no sleeps (a bound listener is the ready signal: connects queue in
//! the OS backlog before `accept` runs).

use std::sync::Arc;

use dme::coordinator::leader::{spawn_local_cluster, Leader};
use dme::coordinator::transport::{HubBinding, TcpHub, Transport, TransportHub};
use dme::coordinator::worker::{mean_update, Worker};
use dme::protocol::config::ProtocolConfig;
use dme::rng::Pcg64;
use dme::stats;

fn shards(n: usize, d: usize, seed: u64) -> Vec<Vec<Vec<f32>>> {
    let mut rng = Pcg64::new(seed);
    (0..n)
        .map(|_| {
            let mut x = vec![0.0f32; d];
            rng.fill_gaussian_f32(&mut x);
            vec![x]
        })
        .collect()
}

#[test]
fn loopback_mean_estimation_multi_round_all_protocols() {
    let d = 64;
    let n = 8;
    for spec in ["binary", "klevel:k=32", "rotated:k=32", "varlen:k=9"] {
        let sh = shards(n, d, 3);
        let client_vecs: Vec<Vec<f32>> = sh.iter().map(|s| s[0].clone()).collect();
        let truth = stats::true_mean(&client_vecs);
        let proto = ProtocolConfig::parse(spec, d).unwrap().build().unwrap();
        let bound = proto.mse_bound(n, stats::avg_norm_sq(&client_vecs));
        let (mut leader, handles) = spawn_local_cluster(proto, sh, mean_update(), 7);
        let mut errs = Vec::new();
        for r in 0..20 {
            let out = leader.round(r, d as u32, &[]).unwrap();
            errs.push(stats::sq_error(&out.means[0], &truth));
        }
        let mse: f64 = errs.iter().sum::<f64>() / errs.len() as f64;
        if let Some(b) = bound {
            assert!(mse <= b * 1.3, "{spec}: coordinator mse {mse} vs bound {b}");
        }
        assert_eq!(leader.metrics().rounds.len(), 20);
        assert!(leader.metrics().rounds_per_sec() > 0.0);
        leader.shutdown().unwrap();
        for h in handles {
            h.join().unwrap().unwrap();
        }
    }
}

/// Run one round of `spec` over loopback; returns (means, down, up).
fn loopback_round(
    spec: &str,
    d: usize,
    sh: Vec<Vec<Vec<f32>>>,
    seed: u64,
) -> (Vec<Vec<f32>>, u64, u64) {
    let proto = ProtocolConfig::parse(spec, d).unwrap().build().unwrap();
    let (mut leader, handles) = spawn_local_cluster(proto, sh, mean_update(), seed);
    let out = leader.round(0, d as u32, &[]).unwrap();
    let m = leader.metrics().rounds.last().unwrap();
    let (down, up) = (m.cum_down_bytes, m.cum_up_bytes);
    leader.shutdown().unwrap();
    for h in handles {
        h.join().unwrap().unwrap();
    }
    (out.means, down, up)
}

/// Every TCP hub implementation this platform can run: what the
/// conformance suites sweep so threads and reactor stay interchangeable.
fn transports_under_test() -> Vec<Transport> {
    #[cfg(target_os = "linux")]
    {
        vec![Transport::Threads, Transport::Reactor]
    }
    #[cfg(not(target_os = "linux"))]
    {
        vec![Transport::Threads]
    }
}

/// Run one round of `spec` over real TCP sockets on the given transport;
/// returns (means, down, up).
fn tcp_round(
    transport: Transport,
    spec: &str,
    d: usize,
    sh: Vec<Vec<Vec<f32>>>,
    seed: u64,
) -> (Vec<Vec<f32>>, u64, u64) {
    let n = sh.len();
    let binding = HubBinding::bind(transport, "127.0.0.1:0").unwrap();
    let addr = binding.local_addr().unwrap().to_string();
    let spec_owned = spec.to_string();
    let leader_thread = std::thread::spawn(move || {
        let proto = ProtocolConfig::parse(&spec_owned, d).unwrap().build().unwrap();
        let hub = binding.accept(n).unwrap();
        let mut leader = Leader::new(proto, hub, seed);
        let out = leader.round(0, d as u32, &[]).unwrap();
        let m = leader.metrics().rounds.last().unwrap();
        let bytes = (m.cum_down_bytes, m.cum_up_bytes);
        leader.shutdown().unwrap();
        (out.means, bytes)
    });
    let mut worker_threads = Vec::new();
    for (i, shard) in sh.into_iter().enumerate() {
        let addr = addr.clone();
        let spec_owned = spec.to_string();
        worker_threads.push(std::thread::spawn(move || {
            let proto = ProtocolConfig::parse(&spec_owned, d).unwrap().build().unwrap();
            Worker { client_id: i as u64, shard, protocol: proto, update: mean_update(), seed }
                .run_tcp(&addr)
                .unwrap();
        }));
    }
    let (means, (down, up)) = leader_thread.join().unwrap();
    for t in worker_threads {
        t.join().unwrap();
    }
    (means, down, up)
}

fn bits_of(means: &[Vec<f32>]) -> Vec<Vec<u32>> {
    means.iter().map(|m| m.iter().map(|v| v.to_bits()).collect()).collect()
}

#[test]
fn tcp_cluster_end_to_end() {
    // Real sockets: 3 worker threads connect to a TCP leader (port 0)
    // and run 5 rounds of rotated mean estimation.
    let d = 64;
    let n = 3;
    let sh = shards(n, d, 5);
    let client_vecs: Vec<Vec<f32>> = sh.iter().map(|s| s[0].clone()).collect();
    let truth = stats::true_mean(&client_vecs);

    let binding = TcpHub::bind("127.0.0.1:0").unwrap();
    let addr = binding.local_addr().unwrap().to_string();
    let leader_thread = std::thread::spawn(move || {
        let proto = ProtocolConfig::parse("rotated:k=64", d).unwrap().build().unwrap();
        let hub = binding.accept(n).unwrap();
        assert_eq!(hub.n_workers(), n);
        let mut leader = Leader::new(proto, Box::new(hub), 99).with_decode_threads(2);
        let mut last = Vec::new();
        for r in 0..5 {
            let out = leader.round(r, d as u32, &[]).unwrap();
            assert_eq!(out.n_frames, n);
            last = out.means[0].clone();
        }
        let (down, up) = (
            leader.metrics().rounds.last().unwrap().cum_down_bytes,
            leader.metrics().rounds.last().unwrap().cum_up_bytes,
        );
        assert!(down > 0 && up > 0, "byte accounting missing");
        leader.shutdown().unwrap();
        last
    });
    let mut worker_threads = Vec::new();
    for (i, shard) in sh.into_iter().enumerate() {
        let addr = addr.clone();
        worker_threads.push(std::thread::spawn(move || {
            let proto = ProtocolConfig::parse("rotated:k=64", d).unwrap().build().unwrap();
            let w = Worker {
                client_id: i as u64,
                shard,
                protocol: proto,
                update: mean_update(),
                seed: 99,
            };
            w.run_tcp(&addr).unwrap();
        }));
    }
    let est = leader_thread.join().unwrap();
    for t in worker_threads {
        t.join().unwrap();
    }
    let err = stats::sq_error(&est, &truth);
    let scale = stats::avg_norm_sq(&client_vecs);
    assert!(err < scale * 0.05, "tcp estimate err {err} vs scale {scale}");
}

#[test]
fn loopback_and_tcp_bit_identical_all_protocols() {
    // The transport-conformance guarantee: a loopback round and a TCP
    // round with identical seeds and shards produce bit-identical means
    // AND identical byte accounting (all hubs account framed wire
    // bytes), for every protocol spec the registry can build — on every
    // TCP transport (thread-per-connection and the epoll reactor), so
    // the two TCP hubs are also transitively identical to each other.
    let specs = [
        "float32",
        "binary",
        "klevel:k=2",
        "klevel:k=16",
        "klevel:k=16,span=norm",
        "rotated:k=2",
        "rotated:k=16",
        "varlen:k=4",
        "varlen:k=17",
        "varlen:k=17,coder=huffman",
        "qsgd:k=8",
        "klevel:k=8,q=0.5",
        "klevel:k=16,p=0.5",
        "varlen:k=17,p=0.25",
    ];
    let d = 32;
    let n = 4;
    let transports = transports_under_test();
    for spec in specs {
        let sh = shards(n, d, 11);
        let (loop_means, loop_down, loop_up) = loopback_round(spec, d, sh.clone(), 123);
        for &transport in &transports {
            let (tcp_means, tcp_down, tcp_up) = tcp_round(transport, spec, d, sh.clone(), 123);
            assert_eq!(
                bits_of(&loop_means),
                bits_of(&tcp_means),
                "{spec}/{transport}: transports disagree on the decoded mean"
            );
            assert_eq!(loop_up, tcp_up, "{spec}/{transport}: uplink accounting diverges");
            assert_eq!(loop_down, tcp_down, "{spec}/{transport}: downlink accounting diverges");
        }
    }
}

#[test]
fn uneven_shards_and_silent_workers() {
    // Workers with empty shards upload zero frames; the round still closes.
    let d = 16;
    let mut sh = shards(3, d, 13);
    sh.push(Vec::new()); // a worker with no data
    let proto = ProtocolConfig::parse("klevel:k=8", d).unwrap().build().unwrap();
    let (mut leader, handles) = spawn_local_cluster(proto, sh, mean_update(), 5);
    let out = leader.round(0, d as u32, &[]).unwrap();
    assert_eq!(out.n_frames, 3);
    assert_eq!(out.means.len(), 1);
    leader.shutdown().unwrap();
    for h in handles {
        h.join().unwrap().unwrap();
    }
}

#[test]
fn pjrt_backend_through_full_coordinator() {
    // The E2E requirement: protocol encode running on the AOT-compiled
    // JAX/Pallas executables, inside the threaded coordinator.
    if !dme::runtime::artifacts::Manifest::default_dir().join("manifest.tsv").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let d = 256;
    let n = 4;
    let backend: Arc<dyn dme::runtime::ComputeBackend> =
        Arc::new(dme::runtime::PjrtBackend::new().unwrap());
    let proto = ProtocolConfig::parse("rotated:k=16", d)
        .unwrap()
        .with_backend(backend)
        .build()
        .unwrap();
    let sh = shards(n, d, 17);
    let client_vecs: Vec<Vec<f32>> = sh.iter().map(|s| s[0].clone()).collect();
    let truth = stats::true_mean(&client_vecs);
    let (mut leader, handles) = spawn_local_cluster(proto, sh, mean_update(), 55);
    let mut errs = Vec::new();
    for r in 0..5 {
        let out = leader.round(r, d as u32, &[]).unwrap();
        errs.push(stats::sq_error(&out.means[0], &truth));
    }
    let mse: f64 = errs.iter().sum::<f64>() / errs.len() as f64;
    let scale = stats::avg_norm_sq(&client_vecs);
    assert!(mse < scale * 0.05, "pjrt coordinator mse {mse} vs scale {scale}");
    leader.shutdown().unwrap();
    for h in handles {
        h.join().unwrap().unwrap();
    }
}
