//! Cross-protocol conformance suite: properties every protocol must
//! satisfy, run against every spec the config registry can build.

use dme::protocol::config::ProtocolConfig;
use dme::protocol::{run_round, run_round_par, Frame, RoundCtx};
use dme::rng::Pcg64;
use dme::stats;

const SPECS: &[&str] = &[
    "float32",
    "binary",
    "klevel:k=2",
    "klevel:k=16",
    "klevel:k=16,span=norm",
    "rotated:k=2",
    "rotated:k=16",
    "varlen:k=4",
    "varlen:k=17",
    "varlen:k=17,coder=huffman",
    "qsgd:k=8",
    "drive",
    "drive:p=0.5",
    "correlated:k=4",
    "correlated:k=16,strata=8",
    "correlated:base=rotated,k=16",
    "correlated:k=4,p=0.5",
    "klevel:k=8,q=0.5",
    "klevel:k=16,p=0.5",
    "varlen:k=17,p=0.25",
];

fn clients(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Pcg64::new(seed);
    (0..n)
        .map(|_| {
            let mut x = vec![0.0f32; d];
            rng.fill_gaussian_f32(&mut x);
            x
        })
        .collect()
}

#[test]
fn every_protocol_estimates_every_dim() {
    // Includes non-power-of-two dims (rotation pads) and tiny dims.
    for d in [1usize, 2, 5, 31, 64, 100] {
        let xs = clients(4, d, d as u64);
        let truth = stats::true_mean(&xs);
        for spec in SPECS {
            if *spec == "varlen:k=4" && d == 1 {
                // k=4 > sqrt(1)+1 fine; keep it — nothing to skip actually.
            }
            let proto = ProtocolConfig::parse(spec, d).unwrap().build().unwrap();
            let ctx = RoundCtx::new(0, 9);
            let (est, _) = run_round(proto.as_ref(), &ctx, &xs).unwrap();
            assert_eq!(est.len(), d, "spec={spec} d={d}");
            assert!(est.iter().all(|v| v.is_finite()), "spec={spec} d={d}");
            // sanity scale: the estimate is in the ballpark of the truth
            let err = stats::sq_error(&est, &truth);
            let scale = stats::avg_norm_sq(&xs).max(1e-9);
            assert!(err <= scale * 10.0, "spec={spec} d={d}: err {err} vs scale {scale}");
        }
    }
}

#[test]
fn unbiasedness_over_rounds_all_protocols() {
    let d = 32;
    let xs = clients(6, d, 5);
    let truth = stats::true_mean(&xs);
    for spec in SPECS {
        let proto = ProtocolConfig::parse(spec, d).unwrap().build().unwrap();
        let trials = if spec.contains("p=") { 1200 } else { 400 };
        let mut sums = vec![0.0f64; d];
        for t in 0..trials {
            let ctx = RoundCtx::new(t, 31);
            let (est, _) = run_round(proto.as_ref(), &ctx, &xs).unwrap();
            for (s, &e) in sums.iter_mut().zip(&est) {
                *s += e as f64;
            }
        }
        // Per-coordinate tolerance scaled by the protocol's MSE bound.
        let bound = proto
            .mse_bound(xs.len(), stats::avg_norm_sq(&xs))
            .unwrap_or(1.0)
            .max(1e-6);
        let tol = 6.0 * (bound / trials as f64).sqrt() + 0.02;
        for (j, &s) in sums.iter().enumerate() {
            let mean = s / trials as f64;
            assert!(
                (mean - truth[j] as f64).abs() < tol,
                "spec={spec} coord {j}: {mean} vs {} (tol {tol})",
                truth[j]
            );
        }
    }
}

#[test]
fn mse_bounds_hold_for_all_protocols() {
    let d = 64;
    let xs = clients(8, d, 7);
    let avg = stats::avg_norm_sq(&xs);
    let truth = stats::true_mean(&xs);
    for spec in SPECS {
        let proto = ProtocolConfig::parse(spec, d).unwrap().build().unwrap();
        let Some(bound) = proto.mse_bound(xs.len(), avg) else { continue };
        if bound == 0.0 {
            continue; // float32
        }
        let mut err = stats::Running::new();
        for t in 0..200 {
            let ctx = RoundCtx::new(t, 13);
            let (est, _) = run_round(proto.as_ref(), &ctx, &xs).unwrap();
            err.push(stats::sq_error(&est, &truth));
        }
        assert!(
            err.mean() <= bound * 1.1,
            "spec={spec}: measured {} > bound {bound}",
            err.mean()
        );
    }
}

#[test]
fn frames_are_deterministic_and_client_distinct() {
    let d = 48;
    let xs = clients(2, d, 11);
    for spec in SPECS {
        if spec.contains("p=") {
            continue; // sampling may silence clients
        }
        let proto = ProtocolConfig::parse(spec, d).unwrap().build().unwrap();
        let ctx = RoundCtx::new(4, 21);
        let f1 = proto.encode(&ctx, 0, &xs[0]).unwrap();
        let f2 = proto.encode(&ctx, 0, &xs[0]).unwrap();
        assert_eq!(f1.bytes, f2.bytes, "spec={spec} not deterministic");
        assert_eq!(f1.bit_len, f2.bit_len);
    }
}

#[test]
fn garbage_frames_never_panic() {
    // Decoders must return Err (or a wrong-but-finite result), never panic.
    let d = 64;
    let mut rng = Pcg64::new(99);
    for spec in SPECS {
        let proto = ProtocolConfig::parse(spec, d).unwrap().build().unwrap();
        let ctx = RoundCtx::new(0, 1);
        for len in [0usize, 1, 7, 64, 1024] {
            let mut bytes = vec![0u8; len];
            for b in bytes.iter_mut() {
                *b = rng.next_u32() as u8;
            }
            let frame = Frame::new(bytes, len as u64 * 8);
            let mut acc = proto.new_accumulator();
            // Must not panic; error or garbage-but-finite both acceptable.
            let _ = proto.accumulate(&ctx, &frame, &mut acc);
            assert!(acc.sum.iter().all(|v| v.is_finite() || v.is_nan() || v.is_infinite()));
        }
    }
}

#[test]
fn run_round_par_bit_identical_to_sequential_all_protocols() {
    // The round engine's determinism guarantee: the f32 merge tree depends
    // only on the client count, so every thread count must produce
    // bit-identical estimates and identical bit totals.
    for (n, d) in [(1usize, 33usize), (5, 64), (64, 100)] {
        let xs = clients(n, d, (n + d) as u64);
        for spec in SPECS {
            let proto = ProtocolConfig::parse(spec, d).unwrap().build().unwrap();
            let ctx = RoundCtx::new(2, 77);
            let (est, bits) = run_round(proto.as_ref(), &ctx, &xs).unwrap();
            let seq_bits: Vec<u32> = est.iter().map(|v| v.to_bits()).collect();
            for threads in [1usize, 2, 8] {
                let (est_p, bits_p) =
                    run_round_par(proto.as_ref(), &ctx, &xs, threads).unwrap();
                assert_eq!(bits, bits_p, "spec={spec} n={n} threads={threads}");
                let par_bits: Vec<u32> = est_p.iter().map(|v| v.to_bits()).collect();
                assert_eq!(
                    seq_bits, par_bits,
                    "spec={spec} n={n} d={d} threads={threads}: estimates diverge"
                );
            }
        }
    }
}

#[test]
fn rotation_sampled_exactly_once_per_round() {
    // The round-session guarantee: prepare() is the only public-stream
    // draw, shared by every client's encode and the server's inverse
    // rotation. The counter is thread-local, and the engine prepares on
    // the calling thread, so concurrent tests don't interfere.
    let d = 96;
    let xs = clients(32, d, 9);
    for spec in ["rotated:k=2", "rotated:k=16", "drive", "correlated:base=rotated,k=16"] {
        let proto = ProtocolConfig::parse(spec, d).unwrap().build().unwrap();
        let ctx = RoundCtx::new(1, 13);
        let before = dme::rng::public_stream_draws();
        run_round(proto.as_ref(), &ctx, &xs).unwrap();
        assert_eq!(
            dme::rng::public_stream_draws() - before,
            1,
            "spec={spec}: sequential round should sample the rotation once"
        );
        let before = dme::rng::public_stream_draws();
        run_round_par(proto.as_ref(), &ctx, &xs, 4).unwrap();
        assert_eq!(
            dme::rng::public_stream_draws() - before,
            1,
            "spec={spec}: parallel round should sample the rotation once"
        );
    }
    // Protocols without a shared rotation draw none at all — including
    // correlated-over-klevel, whose shared offsets come from the
    // dedicated correlated stream, not the public rotation stream.
    for spec in ["klevel:k=16", "correlated:k=16"] {
        let proto = ProtocolConfig::parse(spec, d).unwrap().build().unwrap();
        let before = dme::rng::public_stream_draws();
        run_round(proto.as_ref(), &RoundCtx::new(0, 5), &xs).unwrap();
        assert_eq!(dme::rng::public_stream_draws() - before, 0, "spec={spec}");
    }
}

#[test]
fn bit_accounting_matches_frame_lengths() {
    let d = 128;
    let xs = clients(5, d, 13);
    for spec in SPECS {
        if spec.contains("p=") {
            continue;
        }
        let proto = ProtocolConfig::parse(spec, d).unwrap().build().unwrap();
        let ctx = RoundCtx::new(0, 2);
        let manual: u64 = (0..5)
            .map(|i| proto.encode(&ctx, i as u64, &xs[i]).unwrap().bit_len)
            .sum();
        let (_, reported) = run_round(proto.as_ref(), &ctx, &xs).unwrap();
        assert_eq!(manual, reported, "spec={spec}");
    }
}
