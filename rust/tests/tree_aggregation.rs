//! Conformance suite for the hierarchical aggregation tier.
//!
//! The contract: for every protocol spec, fan-in, tree depth, decode
//! thread count, and transport, the root estimate of a tree of
//! partial-merging aggregators is **bit-identical** to the flat
//! sequential specification `aggregate_uploads_reference`. The per-slot
//! fold is exact (fixed-point), so this holds by construction — these
//! tests prove the whole pipeline (decode pools, wire serialization,
//! barrier mixing of `Upload`/`PartialUpload`, both hubs) preserves it.
//!
//! Also covered: dimension sharding (the tier below the root splits its
//! exact fold into per-range `PartialUpload`s the root concatenates —
//! same bit-identity contract for every shard count × tree shape ×
//! arrival order × thread count), silent (sampled-out) frames
//! interleaved across tiers, per-tier byte accounting (root ingress
//! strictly below flat at n = 4096 simulated clients), hub-identical
//! accounting for `PartialUpload` traffic, adversarial wire payloads,
//! and the barrier timeout naming missing children.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

use dme::coordinator::aggregator::{aggregate_tree, spawn_local_tree, Aggregator};
use dme::coordinator::leader::{
    aggregate_uploads_reference, BarrierPolicy, ChildKey, Leader, RoundOutcome,
};
use dme::coordinator::topology::Topology;
use dme::coordinator::transport::{
    HubBinding, LoopbackHub, Message, TcpEndpoint, Transport, TransportHub, WeightedFrame,
};
use dme::coordinator::worker::{mean_update, UpdateFn, Worker};
use dme::protocol::config::ProtocolConfig;
use dme::protocol::{Protocol, RoundCtx, RoundState, SlotPartial};
use dme::rng::Pcg64;
use dme::testkit::{check, run_prop};

/// The protocol families of the paper's table (§2–§5 + baselines) plus
/// the frontier families: fixed-width, rotated, entropy-coded,
/// comparator, DRIVE, correlated quantization, and both sampling
/// wrappers.
const SPECS: &[&str] = &[
    "float32",
    "binary",
    "klevel:k=16",
    "rotated:k=16",
    "varlen:k=17",
    "qsgd:k=8",
    "drive",
    "correlated:k=16",
    "correlated:base=rotated,k=16",
    "klevel:k=16,p=0.5",
    "klevel:k=8,q=0.5",
];

/// A multi-slot weighted update: worker `i` contributes `1 + i % 3`
/// slots (ragged), with weights mixing 1.0 and non-1.0 values.
fn multi_slot_update() -> UpdateFn {
    Arc::new(|_broadcast, dim, shard| {
        if shard.is_empty() {
            return Vec::new();
        }
        let d = dim as usize;
        let tag = shard[0][0].abs();
        let n_slots = 1 + (tag as usize) % 3;
        (0..n_slots)
            .map(|s| {
                let v: Vec<f32> =
                    shard[0].iter().take(d).map(|&x| x + s as f32 * 0.25).collect();
                let weight = if (tag as usize + s) % 2 == 0 { 1.0 } else { 2.0 + s as f32 };
                (v, weight)
            })
            .collect()
    })
}

/// Deterministic shards: worker `n-1` holds no data (uploads zero
/// frames); the others hold one tagged gaussian vector driving the
/// ragged slot count.
fn make_shards(n: usize, d: usize, seed: u64) -> Vec<Vec<Vec<f32>>> {
    let mut rng = Pcg64::new(seed ^ 0x5eed);
    (0..n)
        .map(|i| {
            if i == n - 1 {
                Vec::new()
            } else {
                let mut x = vec![0.0f32; d];
                rng.fill_gaussian_f32(&mut x);
                x[0] = i as f32;
                vec![x]
            }
        })
        .collect()
}

/// Build every worker's upload for `round` of `spec` — exactly what the
/// transports would deliver, minus the transports.
fn build_uploads(
    spec: &str,
    d: usize,
    round: u64,
    shards: &[Vec<Vec<f32>>],
    update: &UpdateFn,
    seed: u64,
) -> (Arc<dyn Protocol>, RoundState, Vec<(u64, Vec<WeightedFrame>)>) {
    let mut uploads = Vec::with_capacity(shards.len());
    for (i, shard) in shards.iter().enumerate() {
        let proto = ProtocolConfig::parse(spec, d).unwrap().build().unwrap();
        let worker = Worker {
            client_id: i as u64,
            shard: shard.clone(),
            protocol: proto,
            update: update.clone(),
            seed,
        };
        match worker.step(round, d as u32, &[]).unwrap() {
            Message::Upload { client, frames, .. } => uploads.push((client, frames)),
            _ => unreachable!("step always yields Upload"),
        }
    }
    let proto = ProtocolConfig::parse(spec, d).unwrap().build().unwrap();
    let state = proto.prepare(&RoundCtx::new(round, seed));
    (proto, state, uploads)
}

fn assert_outcomes_bit_identical(a: &RoundOutcome, b: &RoundOutcome, what: &str) {
    assert_eq!(a.uplink_bits, b.uplink_bits, "{what}: uplink_bits");
    assert_eq!(a.n_frames, b.n_frames, "{what}: n_frames");
    assert_eq!(a.weights, b.weights, "{what}: weights");
    assert_eq!(a.means.len(), b.means.len(), "{what}: slot count");
    for (slot, (x, y)) in a.means.iter().zip(&b.means).enumerate() {
        assert_eq!(
            x.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            y.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "{what}: slot {slot} means diverge"
        );
    }
}

#[test]
fn tree_matches_flat_reference_full_grid() {
    // The full acceptance grid, through the transportless simulator:
    // every hop still crosses the real PartialUpload wire serialization.
    let d = 32;
    let n = 36;
    let seed = 77;
    let shards = make_shards(n, d, seed);
    let update = multi_slot_update();
    for spec in SPECS {
        let (proto, state, uploads) = build_uploads(spec, d, 0, &shards, &update, seed);
        let want =
            aggregate_uploads_reference(proto.as_ref(), &state, uploads.clone()).unwrap();
        assert!(want.means.len() >= 2, "{spec}: expected a multi-slot round");
        for fan_in in [1usize, 7, 32] {
            for depth in [2usize, 3] {
                let topo = Topology::uniform(n as u64, fan_in, depth).unwrap();
                for threads in [1usize, 4] {
                    let got =
                        aggregate_tree(proto.as_ref(), &state, &uploads, &topo, threads).unwrap();
                    assert_outcomes_bit_identical(
                        &got.outcome,
                        &want,
                        &format!("spec={spec} fan_in={fan_in} depth={depth} threads={threads}"),
                    );
                    assert_eq!(got.tier_ingress.len(), depth);
                }
            }
        }
    }
}

#[test]
fn sharded_tree_matches_flat_reference_full_grid() {
    // The dimension-sharding acceptance grid: for every shard count ×
    // tree shape × upload arrival order × decode thread count, the
    // root's concatenation of the per-shard exact folds is bit-identical
    // to the unsharded flat reference. Shard counts deliberately include
    // values that do not divide the dimension.
    let d = 32;
    let n = 36;
    let seed = 77;
    let shards = make_shards(n, d, seed);
    let update = multi_slot_update();
    for spec in ["klevel:k=16", "rotated:k=16", "varlen:k=17", "klevel:k=16,p=0.5"] {
        let (proto, state, uploads) = build_uploads(spec, d, 0, &shards, &update, seed);
        let want =
            aggregate_uploads_reference(proto.as_ref(), &state, uploads.clone()).unwrap();
        // Arrival orders: as-built, reversed, odd client ids first.
        let mut reversed = uploads.clone();
        reversed.reverse();
        let mut odds_first = uploads.clone();
        odds_first.sort_by_key(|(c, _)| (c % 2 == 0, *c));
        for (o_idx, order) in [&uploads, &reversed, &odds_first].into_iter().enumerate() {
            for n_shards in [2u32, 3, 5, 8] {
                for (fan_in, depth) in [(7usize, 2usize), (32, 2), (7, 3)] {
                    let topo = Topology::uniform(n as u64, fan_in, depth)
                        .unwrap()
                        .with_dim_shards(n_shards)
                        .unwrap();
                    for threads in [1usize, 4] {
                        let got = aggregate_tree(proto.as_ref(), &state, order, &topo, threads)
                            .unwrap();
                        assert_outcomes_bit_identical(
                            &got.outcome,
                            &want,
                            &format!(
                                "spec={spec} shards={n_shards} fan_in={fan_in} depth={depth} \
                                 order={o_idx} threads={threads}"
                            ),
                        );
                        assert_eq!(got.tier_ingress.len(), depth);
                    }
                }
            }
        }
    }
}

#[test]
fn sharded_loopback_tree_full_stack_matches_reference() {
    // Live threads over loopback hubs with a sharded root: each
    // root-child aggregator slices its exact fold into `n_shards`
    // PartialUploads on its single upstream connection, the root
    // barrier counts messages rather than children, and the
    // concatenated estimate stays bit-identical across two rounds.
    let d = 32;
    let n = 14;
    let seed = 91;
    let shards = make_shards(n, d, seed);
    let update = multi_slot_update();
    for spec in ["klevel:k=16", "rotated:k=16"] {
        let mut wants = Vec::new();
        for round in 0..2u64 {
            let (proto, state, uploads) = build_uploads(spec, d, round, &shards, &update, seed);
            wants.push(aggregate_uploads_reference(proto.as_ref(), &state, uploads).unwrap());
        }
        for n_shards in [2u32, 3, 5] {
            for (fan_in, depth) in [(7usize, 2usize), (7, 3)] {
                let topo = Topology::uniform(n as u64, fan_in, depth)
                    .unwrap()
                    .with_dim_shards(n_shards)
                    .unwrap();
                let proto = ProtocolConfig::parse(spec, d).unwrap().build().unwrap();
                let (mut leader, tree) = spawn_local_tree(
                    proto,
                    shards.clone(),
                    update.clone(),
                    seed,
                    &topo,
                    2,
                    None,
                )
                .unwrap();
                for (round, want) in wants.iter().enumerate() {
                    let got = leader.round(round as u64, d as u32, &[]).unwrap();
                    assert_outcomes_bit_identical(
                        &got,
                        want,
                        &format!(
                            "sharded loopback spec={spec} shards={n_shards} fan_in={fan_in} \
                             depth={depth} round={round}"
                        ),
                    );
                }
                leader.shutdown().unwrap();
                let reports = tree.join().unwrap();
                assert_eq!(reports.len(), topo.n_aggregators());
                // Only the tier feeding the root shards its report.
                let top = topo.levels().len() - 1;
                for r in &reports {
                    let want_shards = if r.level == top { n_shards } else { 1 };
                    assert_eq!(
                        r.dim_shards, want_shards,
                        "aggregator {} at level {} reports wrong shard count",
                        r.agg_id, r.level
                    );
                }
            }
        }
    }
}

#[test]
fn loopback_tree_full_stack_matches_reference() {
    // Full-stack over the loopback hub: real worker threads, real
    // aggregator threads with their own decode pools, real barrier
    // mixing — the same grid, two rounds each.
    let d = 32;
    let n = 14;
    let seed = 91;
    let shards = make_shards(n, d, seed);
    let update = multi_slot_update();
    for spec in SPECS {
        let mut wants = Vec::new();
        for round in 0..2u64 {
            let (proto, state, uploads) = build_uploads(spec, d, round, &shards, &update, seed);
            wants.push(aggregate_uploads_reference(proto.as_ref(), &state, uploads).unwrap());
        }
        for fan_in in [1usize, 7, 32] {
            for depth in [2usize, 3] {
                for threads in [1usize, 4] {
                    let topo = Topology::uniform(n as u64, fan_in, depth).unwrap();
                    let proto = ProtocolConfig::parse(spec, d).unwrap().build().unwrap();
                    let (mut leader, tree) = spawn_local_tree(
                        proto,
                        shards.clone(),
                        update.clone(),
                        seed,
                        &topo,
                        threads,
                        None,
                    )
                    .unwrap();
                    for (round, want) in wants.iter().enumerate() {
                        let got = leader.round(round as u64, d as u32, &[]).unwrap();
                        assert_outcomes_bit_identical(
                            &got,
                            want,
                            &format!(
                                "loopback spec={spec} fan_in={fan_in} depth={depth} \
                                 threads={threads} round={round}"
                            ),
                        );
                    }
                    leader.shutdown().unwrap();
                    let reports = tree.join().unwrap();
                    assert_eq!(reports.len(), topo.n_aggregators());
                }
            }
        }
    }
}

/// Run two rounds of `spec` over a real TCP tree (leader + aggregators +
/// workers as separate sockets) on the given transport; returns outcomes
/// and root ingress bytes.
fn tcp_tree_rounds(
    transport: Transport,
    spec: &str,
    d: usize,
    shards: &[Vec<Vec<f32>>],
    update: &UpdateFn,
    seed: u64,
    topo: &Topology,
) -> (Vec<RoundOutcome>, u64) {
    assert_eq!(topo.depth(), 2, "helper wires one aggregator tier");
    let tier = &topo.levels()[0];
    let leader_binding = HubBinding::bind(transport, "127.0.0.1:0").unwrap();
    let leader_addr = leader_binding.local_addr().unwrap().to_string();

    // Aggregators: bind, report their worker-facing address, accept
    // their children, then connect upstream.
    let (addr_tx, addr_rx) = mpsc::channel::<(usize, String)>();
    let mut agg_threads = Vec::new();
    for (idx, spec_node) in tier.iter().enumerate() {
        let spec_s = spec.to_string();
        let leader_addr = leader_addr.clone();
        let addr_tx = addr_tx.clone();
        let (span, id, n_children) = (spec_node.span, spec_node.id, spec_node.children.len());
        agg_threads.push(std::thread::spawn(move || {
            let proto = ProtocolConfig::parse(&spec_s, d).unwrap().build().unwrap();
            let binding = HubBinding::bind(transport, "127.0.0.1:0").unwrap();
            addr_tx.send((idx, binding.local_addr().unwrap().to_string())).unwrap();
            let hub = binding.accept(n_children).unwrap();
            let mut up = TcpEndpoint::connect(&leader_addr).unwrap();
            Aggregator::new(proto, seed, id, span)
                .with_level(0)
                .with_decode_threads(2)
                .run(hub, &mut up)
                .unwrap()
        }));
    }
    drop(addr_tx);
    let mut agg_addrs = vec![String::new(); tier.len()];
    for _ in 0..tier.len() {
        let (idx, addr) = addr_rx.recv().unwrap();
        agg_addrs[idx] = addr;
    }

    // Workers: each connects to the aggregator owning its span.
    let mut worker_threads = Vec::new();
    for (c, shard) in shards.iter().enumerate() {
        let idx = tier.iter().position(|s| (c as u64) < s.span.1).unwrap();
        let addr = agg_addrs[idx].clone();
        let spec_s = spec.to_string();
        let shard = shard.clone();
        let update = update.clone();
        worker_threads.push(std::thread::spawn(move || {
            let proto = ProtocolConfig::parse(&spec_s, d).unwrap().build().unwrap();
            Worker { client_id: c as u64, shard, protocol: proto, update, seed }
                .run_tcp(&addr)
                .unwrap();
        }));
    }

    let proto = ProtocolConfig::parse(spec, d).unwrap().build().unwrap();
    let hub = leader_binding.accept(tier.len()).unwrap();
    let mut leader = Leader::new(proto, hub, seed).with_decode_threads(2);
    let mut outcomes = Vec::new();
    for round in 0..2u64 {
        outcomes.push(leader.round(round, d as u32, &[]).unwrap());
    }
    let (_, root_up) = leader.bytes_moved();
    leader.shutdown().unwrap();
    for h in agg_threads {
        h.join().unwrap();
    }
    for h in worker_threads {
        h.join().unwrap();
    }
    (outcomes, root_up)
}

/// Every TCP hub implementation this platform can run.
fn transports_under_test() -> Vec<Transport> {
    #[cfg(target_os = "linux")]
    {
        vec![Transport::Threads, Transport::Reactor]
    }
    #[cfg(not(target_os = "linux"))]
    {
        vec![Transport::Threads]
    }
}

#[test]
fn tcp_tree_matches_reference_with_identical_accounting() {
    // Real sockets for every spec at (fan-in 7, depth 2), on every TCP
    // transport (thread-per-connection and the epoll reactor):
    // bit-identical to the flat reference, AND the root hub's ingress
    // bytes equal the loopback tree's — all hubs account framed wire
    // bytes, so the two TCP transports are also identical to each other.
    let d = 32;
    let n = 10;
    let seed = 123;
    let shards = make_shards(n, d, seed);
    let update = multi_slot_update();
    let topo = Topology::uniform(n as u64, 7, 2).unwrap();
    let transports = transports_under_test();
    for spec in SPECS {
        let mut wants = Vec::new();
        for round in 0..2u64 {
            let (proto, state, uploads) = build_uploads(spec, d, round, &shards, &update, seed);
            wants.push(aggregate_uploads_reference(proto.as_ref(), &state, uploads).unwrap());
        }
        let mut root_ups = Vec::new();
        for &transport in &transports {
            let (tcp_outcomes, tcp_root_up) =
                tcp_tree_rounds(transport, spec, d, &shards, &update, seed, &topo);
            for (round, (got, want)) in tcp_outcomes.iter().zip(&wants).enumerate() {
                assert_outcomes_bit_identical(
                    got,
                    want,
                    &format!("tcp/{transport} spec={spec} round={round}"),
                );
            }
            root_ups.push(tcp_root_up);
        }
        // Loopback twin with identical seeds and shards.
        let proto = ProtocolConfig::parse(spec, d).unwrap().build().unwrap();
        let (mut leader, tree) =
            spawn_local_tree(proto, shards.clone(), update.clone(), seed, &topo, 2, None)
                .unwrap();
        for (round, want) in wants.iter().enumerate() {
            let got = leader.round(round as u64, d as u32, &[]).unwrap();
            assert_outcomes_bit_identical(&got, want, &format!("loop spec={spec} round={round}"));
        }
        let (_, loop_root_up) = leader.bytes_moved();
        leader.shutdown().unwrap();
        tree.join().unwrap();
        for (&transport, &tcp_root_up) in transports.iter().zip(&root_ups) {
            assert_eq!(
                tcp_root_up, loop_root_up,
                "{spec}/{transport}: root ingress accounting diverges between hubs"
            );
        }
    }
}

#[test]
fn sparse_silent_slots_interleave_across_tiers() {
    // Sampling protocols produce silent frames (bit_len 0) that still
    // count as slot holders. Scatter them across a depth-3 tree and
    // check the tree agrees with the flat reference — and that the
    // scenario really exercises silence.
    let d = 24;
    let n = 24;
    let seed = 41;
    let shards = make_shards(n, d, seed);
    let update = multi_slot_update();
    let spec = "klevel:k=16,p=0.4";
    let (proto, state, uploads) = build_uploads(spec, d, 0, &shards, &update, seed);
    let n_silent: usize = uploads
        .iter()
        .flat_map(|(_, frames)| frames.iter())
        .filter(|wf| wf.frame.bit_len == 0)
        .count();
    assert!(n_silent > 0, "scenario must contain silent frames");
    let want = aggregate_uploads_reference(proto.as_ref(), &state, uploads.clone()).unwrap();
    assert!(want.n_frames > 0);
    for fan_in in [3usize, 9] {
        let topo = Topology::uniform(n as u64, fan_in, 3).unwrap();
        let got = aggregate_tree(proto.as_ref(), &state, &uploads, &topo, 4).unwrap();
        assert_outcomes_bit_identical(&got.outcome, &want, &format!("fan_in={fan_in}"));
    }
    // Full stack too: silent frames crossing two aggregator tiers.
    let topo = Topology::uniform(n as u64, 5, 3).unwrap();
    let proto = ProtocolConfig::parse(spec, d).unwrap().build().unwrap();
    let (mut leader, tree) =
        spawn_local_tree(proto, shards, update, seed, &topo, 2, None).unwrap();
    let got = leader.round(0, d as u32, &[]).unwrap();
    assert_outcomes_bit_identical(&got, &want, "loopback depth-3 sampling");
    leader.shutdown().unwrap();
    tree.join().unwrap();
}

#[test]
fn mixed_worker_and_aggregator_children_at_root() {
    // The leader accepts Upload and PartialUpload in the same barrier:
    // client 0 reports directly, clients 1..4 go through an aggregator.
    let d = 16;
    let seed = 19;
    let spec = "rotated:k=16";
    let shards = make_shards(4, d, seed);
    let update = multi_slot_update();
    let (proto, state, uploads) = build_uploads(spec, d, 0, &shards, &update, seed);
    let want = aggregate_uploads_reference(proto.as_ref(), &state, uploads).unwrap();

    let (hub, mut root_eps) = LoopbackHub::new(2);
    let ep_agg = root_eps.pop().unwrap();
    let ep_w0 = root_eps.pop().unwrap();
    let mk_worker = |c: usize| Worker {
        client_id: c as u64,
        shard: shards[c].clone(),
        protocol: ProtocolConfig::parse(spec, d).unwrap().build().unwrap(),
        update: update.clone(),
        seed,
    };
    let w0 = mk_worker(0);
    let h_w0 = std::thread::spawn(move || w0.run_loopback(ep_w0));
    let (agg_hub, agg_eps) = LoopbackHub::new(3);
    let mut worker_handles = vec![h_w0];
    for (i, ep) in agg_eps.into_iter().enumerate() {
        let w = mk_worker(i + 1);
        worker_handles.push(std::thread::spawn(move || w.run_loopback(ep)));
    }
    let agg_proto = ProtocolConfig::parse(spec, d).unwrap().build().unwrap();
    let h_agg = std::thread::spawn(move || {
        let mut ep = ep_agg;
        Aggregator::new(agg_proto, seed, 100, (1, 4)).run(Box::new(agg_hub), &mut ep)
    });
    let mut leader = Leader::new(proto, Box::new(hub), seed).with_expected_children(vec![
        ChildKey::Client(0),
        ChildKey::Aggregator { id: 100, span: (1, 4) },
    ]);
    let got = leader.round(0, d as u32, &[]).unwrap();
    assert_outcomes_bit_identical(&got, &want, "mixed barrier");
    leader.shutdown().unwrap();
    h_agg.join().unwrap().unwrap();
    for h in worker_handles {
        h.join().unwrap().unwrap();
    }
}

#[test]
fn root_ingress_shrinks_at_depth2_with_4096_simulated_clients() {
    // The scaling claim made measurable: at n = 4096 the root's ingress
    // bytes under a depth-2 tree are strictly below the flat topology's
    // O(n · frames) — while the estimate stays bit-identical.
    let d = 128;
    let n = 4096u64;
    let spec = "klevel:k=16";
    let proto = ProtocolConfig::parse(spec, d).unwrap().build().unwrap();
    let ctx = RoundCtx::new(0, 7);
    let state = proto.prepare(&ctx);
    let mut enc = dme::protocol::Encoder::new(proto.as_ref(), &state);
    let mut rng = Pcg64::new(3);
    let uploads: Vec<(u64, Vec<WeightedFrame>)> = (0..n)
        .map(|i| {
            let mut x = vec![0.0f32; d];
            rng.fill_gaussian_f32(&mut x);
            let frame = enc.encode(i, &x).unwrap();
            (i, vec![WeightedFrame { frame, weight: 1.0 }])
        })
        .collect();
    let flat = aggregate_tree(proto.as_ref(), &state, &uploads, &Topology::flat(n), 4).unwrap();
    let topo = Topology::uniform(n, 256, 2).unwrap();
    let tree = aggregate_tree(proto.as_ref(), &state, &uploads, &topo, 4).unwrap();
    assert_outcomes_bit_identical(&tree.outcome, &flat.outcome, "n=4096 depth-2");
    let (flat_root, tree_root) = (flat.tier_ingress[0], tree.tier_ingress[0]);
    assert!(
        tree_root < flat_root,
        "root ingress must shrink: tree {tree_root} vs flat {flat_root}"
    );
    // The workers' edge cost is unchanged — the tree moves it, not hides it.
    assert_eq!(tree.tier_ingress[1], flat_root);
}

#[test]
fn partial_upload_accounting_identical_on_both_hubs() {
    // One real PartialUpload through each hub: both must account exactly
    // framed_len, so tree runs report identical bytes over loopback and
    // TCP.
    let mut slot = SlotPartial::from_decoded(&[0.5, -1.25, 3.0], 1.0, 1).unwrap();
    slot.merge(&SlotPartial::from_decoded(&[2.0, 0.125, -0.5], 2.0, 1).unwrap()).unwrap();
    let msg = Message::PartialUpload {
        agg_id: 5,
        round: 2,
        span: (0, 64),
        uplink_bits: 4096,
        n_frames: 2,
        shard: (0, 3),
        slots: vec![slot],
    };
    let framed = msg.framed_len();
    assert_eq!(framed, msg.to_bytes().unwrap().len() as u64 + 4);

    // Loopback: endpoint send accounts the uplink.
    let (mut hub, eps) = LoopbackHub::new(1);
    eps[0].send(msg.clone()).unwrap();
    hub.recv().unwrap();
    assert_eq!(hub.bytes_moved().1, framed);

    // TCP: reader-side accounting after a real socket crossing, on both
    // TCP hub implementations.
    for transport in transports_under_test() {
        let binding = HubBinding::bind(transport, "127.0.0.1:0").unwrap();
        let addr = binding.local_addr().unwrap().to_string();
        let msg2 = msg.clone();
        let sender = std::thread::spawn(move || {
            let mut ep = TcpEndpoint::connect(&addr).unwrap();
            ep.send(&msg2).unwrap();
            // Wait for shutdown so the hub's reader sees an orderly close.
            ep.recv().unwrap()
        });
        let mut hub = binding.accept(1).unwrap();
        match hub.recv().unwrap() {
            Message::PartialUpload { agg_id, slots, .. } => {
                assert_eq!(agg_id, 5);
                assert_eq!(slots.len(), 1);
            }
            other => panic!("expected PartialUpload, got {other:?}"),
        }
        assert_eq!(
            hub.bytes_moved().1,
            framed,
            "{transport}: TCP accounting diverges from loopback"
        );
        hub.broadcast(&Message::Shutdown).unwrap();
        sender.join().unwrap();
    }
}

#[test]
fn adversarial_partial_upload_payloads() {
    // Property: random well-formed PartialUploads round-trip exactly;
    // random corruptions — truncation, trailing bytes, bad version —
    // are rejected by the parser, and messages that violate the wire
    // invariants are rejected by Message::validate on both hub types
    // (loopback checks on send, TCP checks inside to_bytes).
    run_prop("partial_upload_wire", 40, |g| {
        let dim = g.usize_in(1..=24);
        let n_parts = g.usize_in(1..=5);
        let mut slot = SlotPartial::empty(dim);
        for _ in 0..n_parts {
            let vals = g.vec_f32(dim..=dim, -8.0, 8.0);
            let w = if g.usize_in(0..=1) == 0 { 1.0 } else { g.f32_in(0.25, 4.0) };
            slot.merge(&SlotPartial::from_decoded(&vals, w, 1).map_err(|e| e.to_string())?)
                .map_err(|e| e.to_string())?;
        }
        let msg = Message::PartialUpload {
            agg_id: g.rng().next_u64(),
            round: g.rng().next_u64() % 1000,
            // Wide enough for the merged slot's holder count.
            span: (4, 4 + n_parts as u64 + g.rng().next_u64() % 64),
            uplink_bits: g.rng().next_u64() % (1 << 40),
            n_frames: n_parts as u64,
            shard: (0, dim as u32),
            slots: vec![slot.clone(), SlotPartial::silent(dim)],
        };
        let bytes = msg.to_bytes().map_err(|e| e.to_string())?;
        check(bytes.len() as u64 == msg.wire_len(), "wire_len mismatch")?;
        let back = Message::from_bytes(&bytes).map_err(|e| e.to_string())?;
        let Message::PartialUpload { slots, .. } = back else {
            return Err("variant changed on the wire".into());
        };
        check(slots[0] == slot, "slot state changed on the wire")?;
        // Random truncation is always rejected.
        let cut = g.usize_in(0..=bytes.len() - 1);
        check(Message::from_bytes(&bytes[..cut]).is_err(), format!("truncation {cut} passed"))?;
        // Trailing garbage is always rejected.
        let mut long = bytes.clone();
        long.push(g.rng().next_u64() as u8);
        check(Message::from_bytes(&long).is_err(), "trailing garbage passed")?;
        // An inverted span must be refused before it reaches any wire.
        let bad = Message::PartialUpload {
            agg_id: 0,
            round: 0,
            span: (9, 3),
            uplink_bits: 0,
            n_frames: 0,
            shard: (0, 0),
            slots: vec![],
        };
        check(bad.validate().is_err(), "validate accepted inverted span")?;
        check(bad.to_bytes().is_err(), "TCP serialization accepted inverted span")?;
        let (mut hub, eps) = LoopbackHub::new(1);
        check(hub.broadcast(&bad).is_err(), "loopback broadcast accepted inverted span")?;
        check(eps[0].send(bad).is_err(), "loopback send accepted inverted span")?;
        // A span too narrow for its slots' holder counts must be refused
        // on send...
        let forged = Message::PartialUpload {
            agg_id: 0,
            round: 0,
            span: (7, 7),
            uplink_bits: 0,
            n_frames: n_parts as u64,
            shard: (0, dim as u32),
            slots: vec![slot.clone()],
        };
        check(forged.validate().is_err(), "validate accepted holders beyond span")?;
        // ...and on parse: narrow a valid message's span bytes (offsets
        // after the 6-byte envelope header: 22..30 = span.0,
        // 30..38 = span.1) down to an empty span.
        let mut narrowed = bytes.clone();
        let lo: [u8; 8] = narrowed[22..30].try_into().unwrap();
        narrowed[30..38].copy_from_slice(&lo);
        check(Message::from_bytes(&narrowed).is_err(), "parser accepted holders beyond span")?;
        // A shard range that disagrees with the slot dims must be
        // refused on parse too: widen shard.1 (bytes 58..62, after
        // span and the two u64 counters) by one coordinate.
        let mut widened = bytes.clone();
        let hi = u32::from_le_bytes(widened[58..62].try_into().unwrap());
        widened[58..62].copy_from_slice(&(hi + 1).to_le_bytes());
        check(Message::from_bytes(&widened).is_err(), "parser accepted misaligned shard range")
    });
}

#[test]
fn barrier_timeout_names_missing_children() {
    // One worker answers, the other stays silent: a leader armed with a
    // timeout must fail the round and name exactly the missing child;
    // the healthy path (both answer) still works afterwards with the
    // default wait-forever behavior left untouched elsewhere.
    let d = 8;
    let proto = ProtocolConfig::parse("klevel:k=4", d).unwrap().build().unwrap();
    let (hub, mut eps) = LoopbackHub::new(2);
    let ep_silent = eps.pop().unwrap(); // client 1's endpoint — held, never answered
    let ep_live = eps.pop().unwrap();
    let live = Worker {
        client_id: 0,
        shard: vec![vec![1.0; d]],
        protocol: proto.clone(),
        update: mean_update(),
        seed: 3,
    };
    let h_live = std::thread::spawn(move || live.run_loopback(ep_live));
    let mut leader = Leader::new(proto, Box::new(hub), 3)
        .with_round_timeout(Duration::from_millis(200))
        .with_expected_children(vec![ChildKey::Client(0), ChildKey::Client(1)]);
    let err = leader.round(0, d as u32, &[]).unwrap_err().to_string();
    assert!(err.contains("timed out"), "unexpected error: {err}");
    assert!(err.contains("client 1"), "must name the missing client: {err}");
    assert!(!err.contains("client 0,"), "must not blame the live client: {err}");
    // The silent endpoint got the RoundStart; drain and release it so
    // shutdown can complete.
    drop(ep_silent);
    let _ = leader.shutdown();
    h_live.join().unwrap().unwrap();
}

#[test]
fn barrier_recovers_after_timeout_when_late_upload_arrives() {
    // The retry path the timeout feature promises: a worker that answers
    // a round *after* its barrier timed out must not poison the next
    // round — the stale upload is dropped at the barrier and the
    // superseding round completes with every child.
    let d = 8;
    let proto = ProtocolConfig::parse("klevel:k=4", d).unwrap().build().unwrap();
    let (hub, mut eps) = LoopbackHub::new(2);
    let ep_slow = eps.pop().unwrap(); // client 1's endpoint — driven manually
    let ep_live = eps.pop().unwrap();
    let live = Worker {
        client_id: 0,
        shard: vec![vec![1.0; d]],
        protocol: proto.clone(),
        update: mean_update(),
        seed: 3,
    };
    let h_live = std::thread::spawn(move || live.run_loopback(ep_live));
    let slow = Worker {
        client_id: 1,
        shard: vec![vec![2.0; d]],
        protocol: proto.clone(),
        update: mean_update(),
        seed: 3,
    };
    let mut leader = Leader::new(proto, Box::new(hub), 3)
        .with_round_timeout(Duration::from_millis(200))
        .with_expected_children(vec![ChildKey::Client(0), ChildKey::Client(1)]);
    let err = leader.round(0, d as u32, &[]).unwrap_err().to_string();
    assert!(err.contains("client 1"), "must name the missing client: {err}");
    // The slow worker answers round 0 late: its upload sits in the hub's
    // queue ahead of anything round 1 produces.
    let Message::RoundStart { round, dim, .. } = ep_slow.recv().unwrap() else {
        panic!("expected RoundStart");
    };
    assert_eq!(round, 0);
    ep_slow.send(slow.step(0, dim, &[]).unwrap()).unwrap();
    // Round 1 must drop the stale upload and complete with both children.
    let h_slow = std::thread::spawn(move || {
        let Message::RoundStart { round, dim, .. } = ep_slow.recv().unwrap() else {
            panic!("expected RoundStart");
        };
        ep_slow.send(slow.step(round, dim, &[]).unwrap()).unwrap();
        let _ = ep_slow.recv(); // drain Shutdown
    });
    let out = leader.round(1, d as u32, &[]).unwrap();
    assert_eq!(out.n_frames, 2, "both children must land in the recovered round");
    leader.shutdown().unwrap();
    h_slow.join().unwrap();
    h_live.join().unwrap().unwrap();
}

#[test]
fn duplicate_same_round_upload_is_dropped_and_counted() {
    // The same-round sibling of the stale-upload contract above: a
    // client that answers the *current* round twice (a reconnect
    // re-send, or a retry racing its own first answer) must be folded
    // exactly once — the barrier drops the copy, counts it in
    // `duplicate_uploads`, and the estimate stays bit-identical to the
    // fold-each-client-once reference.
    let d = 8;
    let seed = 11;
    let proto = ProtocolConfig::parse("klevel:k=4", d).unwrap().build().unwrap();
    let (hub, eps) = LoopbackHub::new(3);
    let w = |id: u64, fill: f32| Worker {
        client_id: id,
        shard: vec![vec![fill; d]],
        protocol: proto.clone(),
        update: mean_update(),
        seed,
    };
    // Clients 0 and 1 answer round 0 before the barrier even opens —
    // client 1 twice (the two `step` calls are bit-identical). Client 2
    // stays silent so the deadline must expire, which forces the barrier
    // to read every queued message: the duplicate cannot dodge it by
    // arriving after the barrier has filled.
    eps[0].send(w(0, 1.0).step(0, d as u32, &[]).unwrap()).unwrap();
    eps[1].send(w(1, 2.0).step(0, d as u32, &[]).unwrap()).unwrap();
    eps[1].send(w(1, 2.0).step(0, d as u32, &[]).unwrap()).unwrap();
    let expected = (0..3u64).map(ChildKey::Client).collect();
    let mut leader = Leader::new(proto.clone(), Box::new(hub), seed)
        .with_round_timeout(Duration::from_millis(200))
        .with_barrier_policy(BarrierPolicy::Partial)
        .with_expected_children(expected);
    let out = leader.round(0, d as u32, &[]).unwrap();
    let m = leader.metrics().rounds.last().unwrap();
    assert_eq!(m.duplicate_uploads, 1, "the dropped copy must be counted");
    assert_eq!(m.participation, 2.0 / 3.0, "duplicates must not inflate participation");
    assert_eq!(out.n_frames, 2, "exactly two distinct children folded");
    // Bit for bit: the round equals folding each distinct client once.
    let ctx = RoundCtx::new(0, seed);
    let state = proto.prepare(&ctx);
    let mut uploads = Vec::new();
    for worker in [w(0, 1.0), w(1, 2.0)] {
        match worker.step(0, d as u32, &[]).unwrap() {
            Message::Upload { client, frames, .. } => uploads.push((client, frames)),
            other => panic!("expected an Upload, got {other:?}"),
        }
    }
    let want = aggregate_uploads_reference(proto.as_ref(), &state, uploads).unwrap();
    assert_eq!(out.means, want.means, "the duplicate copy must not shift the estimate");
    drop(eps);
    let _ = leader.shutdown();
}

#[test]
fn aggregator_survives_barrier_timeout_and_tree_recovers() {
    // The tree-shaped version of the recovery contract: an aggregator
    // whose own barrier times out must NOT die (that would turn one
    // transiently slow worker into the loss of the whole tree) — it
    // skips the round, the leader's deadline names it, and the next
    // round completes with every client present.
    let d = 8;
    let spec = "klevel:k=4";
    let seed = 11;
    let proto = ProtocolConfig::parse(spec, d).unwrap().build().unwrap();
    // Root hub: one aggregator child covering clients [0, 2).
    let (root_hub, mut root_eps) = LoopbackHub::new(1);
    let ep_agg = root_eps.pop().unwrap();
    // Aggregator hub: a live worker (client 0) plus a manually driven
    // endpoint standing in for a slow client 1.
    let (agg_hub, mut agg_eps) = LoopbackHub::new(2);
    let ep_slow = agg_eps.pop().unwrap();
    let ep_live = agg_eps.pop().unwrap();
    let mk_worker = |c: u64| Worker {
        client_id: c,
        shard: vec![vec![c as f32 + 1.0; d]],
        protocol: ProtocolConfig::parse(spec, d).unwrap().build().unwrap(),
        update: mean_update(),
        seed,
    };
    let live = mk_worker(0);
    let h_live = std::thread::spawn(move || live.run_loopback(ep_live));
    let agg_proto = ProtocolConfig::parse(spec, d).unwrap().build().unwrap();
    let h_agg = std::thread::spawn(move || {
        let mut ep = ep_agg;
        Aggregator::new(agg_proto, seed, 7, (0, 2))
            .with_round_timeout(Duration::from_millis(100))
            .run(Box::new(agg_hub), &mut ep)
    });
    let mut leader = Leader::new(proto, Box::new(root_hub), seed)
        .with_round_timeout(Duration::from_millis(1000))
        .with_expected_children(vec![ChildKey::Aggregator { id: 7, span: (0, 2) }]);
    // Round 0: client 1 never answers, the aggregator's 100 ms deadline
    // expires, it skips the round, and the leader's deadline names it.
    let err = leader.round(0, d as u32, &[]).unwrap_err().to_string();
    assert!(err.contains("aggregator 7"), "must name the silent aggregator: {err}");
    // Client 1 answers round 0 late, then serves round 1 properly.
    let slow = mk_worker(1);
    let Message::RoundStart { round, dim, .. } = ep_slow.recv().unwrap() else {
        panic!("expected RoundStart");
    };
    assert_eq!(round, 0);
    ep_slow.send(slow.step(0, dim, &[]).unwrap()).unwrap();
    let h_slow = std::thread::spawn(move || {
        let Message::RoundStart { round, dim, .. } = ep_slow.recv().unwrap() else {
            panic!("expected RoundStart");
        };
        ep_slow.send(slow.step(round, dim, &[]).unwrap()).unwrap();
        let _ = ep_slow.recv(); // drain Shutdown
    });
    let out = leader.round(1, d as u32, &[]).unwrap();
    assert_eq!(out.n_frames, 2, "tree must recover with every client present");
    leader.shutdown().unwrap();
    let report = h_agg.join().unwrap().unwrap();
    assert_eq!(report.agg_id, 7);
    h_slow.join().unwrap();
    h_live.join().unwrap().unwrap();
}
