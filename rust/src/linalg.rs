//! Small dense linear-algebra helpers used by the protocols and the
//! application drivers (no external BLAS; everything here is `f32` slices).

/// Dot product.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
}

/// Squared ℓ₂ norm (accumulated in f64 for stability).
#[inline]
pub fn norm_sq(x: &[f32]) -> f64 {
    x.iter().map(|&v| v as f64 * v as f64).sum()
}

/// ℓ₂ norm.
#[inline]
pub fn norm(x: &[f32]) -> f64 {
    norm_sq(x).sqrt()
}

/// Squared ℓ₂ distance between two vectors.
#[inline]
pub fn dist_sq(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = x as f64 - y as f64;
            d * d
        })
        .sum()
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// `x *= alpha`.
#[inline]
pub fn scale(x: &mut [f32], alpha: f32) {
    for v in x {
        *v *= alpha;
    }
}

/// Normalize `x` to unit ℓ₂ norm in place; returns the original norm.
/// A zero vector is left untouched.
pub fn normalize(x: &mut [f32]) -> f64 {
    let n = norm(x);
    if n > 0.0 {
        scale(x, (1.0 / n) as f32);
    }
    n
}

/// (min, max) of a slice. Panics on empty input.
#[inline]
pub fn min_max(x: &[f32]) -> (f32, f32) {
    assert!(!x.is_empty(), "min_max of empty slice");
    let mut lo = x[0];
    let mut hi = x[0];
    for &v in &x[1..] {
        if v < lo {
            lo = v;
        }
        if v > hi {
            hi = v;
        }
    }
    (lo, hi)
}

/// Single-pass per-vector statistics: everything the quantizer's grid
/// rules need from one scan of the data.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct VectorStats {
    pub lo: f32,
    pub hi: f32,
    /// Squared ℓ₂ norm, accumulated in f64 exactly like [`norm_sq`].
    pub norm_sq: f64,
}

/// Compute min, max, and squared norm in **one pass** over `x` — fused
/// so `grid_params(Span::Norm)` (and the calibration probes that sit on
/// it) scan the input once instead of twice. Bit-identical to calling
/// [`min_max`] and [`norm_sq`] separately: the comparisons and the f64
/// accumulation run in the same element order (the extra compare against
/// `x[0]` itself is a no-op for every value, including NaN and ±0).
/// The min/max lattice is deliberately left scalar in both dispatch
/// paths: a lane-parallel `min`/`max` reduction can return the *other*
/// zero when ±0.0 tie — a different `xmin` bit pattern in the frame
/// header — so the sequential order is part of the wire contract.
/// Panics on empty input.
pub fn vector_stats(x: &[f32]) -> VectorStats {
    assert!(!x.is_empty(), "vector_stats of empty slice");
    let mut lo = x[0];
    let mut hi = x[0];
    let mut nsq = 0.0f64;
    for &v in x {
        if v < lo {
            lo = v;
        }
        if v > hi {
            hi = v;
        }
        nsq += v as f64 * v as f64;
    }
    VectorStats { lo, hi, norm_sq: nsq }
}

/// Index of the minimum value (first occurrence). Panics on empty input.
pub fn argmin(x: &[f64]) -> usize {
    assert!(!x.is_empty(), "argmin of empty slice");
    let mut best = 0;
    for i in 1..x.len() {
        if x[i] < x[best] {
            best = i;
        }
    }
    best
}

/// Mean of `rows` (each a d-vector) → d-vector. Panics if rows is empty.
pub fn mean_of(rows: &[&[f32]]) -> Vec<f32> {
    assert!(!rows.is_empty());
    let d = rows[0].len();
    let mut acc = vec![0.0f64; d];
    for r in rows {
        debug_assert_eq!(r.len(), d);
        for (a, &v) in acc.iter_mut().zip(r.iter()) {
            *a += v as f64;
        }
    }
    let inv = 1.0 / rows.len() as f64;
    acc.iter().map(|&v| (v * inv) as f32).collect()
}

/// Dense symmetric matvec `y = (Aᵀ A / n) v` given data rows of A — the
/// covariance-style operator used by power iteration. `rows` are the data
/// points; computes `(1/rows.len()) Σ_i x_i (x_i · v)`.
pub fn cov_matvec(rows: &[Vec<f32>], v: &[f32]) -> Vec<f32> {
    let d = v.len();
    let mut y = vec![0.0f32; d];
    for x in rows {
        let c = dot(x, v) as f32;
        axpy(c, x, &mut y);
    }
    let inv = 1.0 / rows.len().max(1) as f32;
    scale(&mut y, inv);
    y
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_and_norms() {
        let a = [1.0, 2.0, 3.0];
        let b = [4.0, -5.0, 6.0];
        assert_eq!(dot(&a, &b), 12.0);
        assert_eq!(norm_sq(&a), 14.0);
        assert!((norm(&a) - 14.0f64.sqrt()).abs() < 1e-12);
        assert_eq!(dist_sq(&a, &a), 0.0);
    }

    #[test]
    fn axpy_scale_normalize() {
        let x = [1.0f32, 0.0, -1.0];
        let mut y = [1.0f32, 1.0, 1.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [3.0, 1.0, -1.0]);
        scale(&mut y, 0.5);
        assert_eq!(y, [1.5, 0.5, -0.5]);
        let mut v = [3.0f32, 4.0];
        let n = normalize(&mut v);
        assert!((n - 5.0).abs() < 1e-12);
        assert!((norm(&v) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn normalize_zero_vector_is_noop() {
        let mut z = [0.0f32; 4];
        assert_eq!(normalize(&mut z), 0.0);
        assert_eq!(z, [0.0; 4]);
    }

    #[test]
    fn min_max_and_argmin() {
        assert_eq!(min_max(&[3.0, -1.0, 2.0]), (-1.0, 3.0));
        assert_eq!(argmin(&[3.0, -1.0, 2.0]), 1);
        assert_eq!(argmin(&[1.0, 1.0]), 0);
    }

    #[test]
    fn vector_stats_matches_separate_passes() {
        let mut rng = crate::rng::Pcg64::new(41);
        for d in [1usize, 2, 7, 8, 9, 255, 256, 1000] {
            let mut x = vec![0.0f32; d];
            rng.fill_gaussian_f32(&mut x);
            // Sprinkle the awkward values the quantizer must survive.
            if d >= 4 {
                x[0] = -0.0;
                x[1] = 0.0;
                x[2] = f32::from_bits(1); // smallest subnormal
                x[3] = -f32::MIN_POSITIVE;
            }
            let st = vector_stats(&x);
            let (lo, hi) = min_max(&x);
            assert_eq!(st.lo.to_bits(), lo.to_bits(), "d={d}");
            assert_eq!(st.hi.to_bits(), hi.to_bits(), "d={d}");
            assert_eq!(st.norm_sq.to_bits(), norm_sq(&x).to_bits(), "d={d}");
        }
        // ±0 tie-break: the first-seen zero wins in both.
        let z = [0.0f32, -0.0];
        let st = vector_stats(&z);
        assert_eq!(st.lo.to_bits(), min_max(&z).0.to_bits());
        assert_eq!(st.hi.to_bits(), min_max(&z).1.to_bits());
    }

    #[test]
    fn mean_of_rows() {
        let r1 = [0.0f32, 2.0];
        let r2 = [4.0f32, 6.0];
        let m = mean_of(&[&r1, &r2]);
        assert_eq!(m, vec![2.0, 4.0]);
    }

    #[test]
    fn cov_matvec_matches_manual() {
        let rows = vec![vec![1.0f32, 0.0], vec![0.0f32, 2.0]];
        let v = [1.0f32, 1.0];
        let y = cov_matvec(&rows, &v);
        // (x1 (x1·v) + x2 (x2·v)) / 2 = ([1,0]*1 + [0,2]*2) / 2 = [0.5, 2.0]
        assert_eq!(y, vec![0.5, 2.0]);
    }
}
