//! Rate control: analytic MSE/communication models, a bit-budget
//! planner, and a live controller that retunes the protocol mid-session.
//!
//! The paper's whole point is the MSE-vs-communication frontier — π_sb
//! at Θ(d/n) MSE for ~1 bit/dim, π_srk at O((log d)/n), π_svk at O(1/n)
//! for a constant number of bits per dimension. This module turns those
//! theorems into an optimizer: *given a bit budget, which protocol
//! configuration minimizes MSE?* — the framing of Konečný & Richtárik's
//! "Randomized Distributed Mean Estimation: Accuracy vs Communication".
//!
//! Three layers:
//!
//! * [`model`] — closed-form predictors `predicted_mse` /
//!   [`model::predicted_uplink_bits`] for every [`Kind`], implementing
//!   the paper's bounds (see the theorem map below), plus a one-shot
//!   empirical [`model::Calibration`] fitter that runs small probe
//!   rounds through the *real* encode path and stores per-spec
//!   correction factors.
//! * [`planner`] — [`planner::Plan::solve`] enumerates the discrete
//!   spec space (kind × k grid × coder × sampling p/q), returns the
//!   Pareto frontier and the arg-min spec under the budget as a
//!   replayable [`ProtocolConfig`], exportable as JSON
//!   (`dme tune`); [`planner::MultiTenantPlan::solve`] water-fills a
//!   shared uplink budget over several tenants' frontiers (`dme serve
//!   --tenants`), funding the steepest weighted ΔMSE/Δbits step until
//!   the pool is dry — with an explicit error, never a silent starve,
//!   when even the cheapest specs don't fit.
//! * [`controller`] — a per-session [`controller::RateController`] that
//!   observes realized `RoundMetrics::uplink_bits` and a decode-side
//!   MSE proxy each round and switches the active spec between rounds
//!   via the tag-5 `SpecChange` message (`dme serve --auto-rate`).
//!
//! # Theorem map (predictor → paper claim, PAPER.md)
//!
//! | predictor | protocol | claim |
//! |-----------|----------|-------|
//! | MSE `d/(2n)·B̄` | π_sb (binary) | Theorem 1 (= Lemma 3's bound): Θ(d/n) at 1 bit/dim |
//! | MSE `d/(2n(k−1)²)·B̄` | π_sk (klevel), π_svk (varlen) | Theorem 2 |
//! | MSE `(2 ln d̃ + 2)/(n(k−1)²)·B̄` | π_srk (rotated, padded dim d̃) | Theorem 3: O((log d)/n) |
//! | bits `d + 64` | π_sb | Lemma 1 (32-bit headers) |
//! | bits `d⌈log₂k⌉ + 64` | π_sk | Lemma 5 |
//! | bits `d(2 + log₂((k−1)²/2d + 1.25)) + k-hist + 64` | π_svk | Theorem 4's entropy-coded rate: O(1) bits/dim at k = √d |
//! | MSE `E/p + (1−p)/(np)·B̄` | π_p sampling wrapper | Lemma 8 (bits scale by p) |
//! | MSE `(π/2 − 1)(1 + 8/√d̃)·B̄` | drive (padded dim d̃) | DRIVE Thm 5.4 (arXiv 2105.08339): constant NMSE at 1 bit/dim; n-free because clients share one rotation |
//! | bits `d̃ + 32` | drive | one sign bit per padded coordinate + a single scale header |
//! | MSE = base family's bound | correlated (over klevel or rotated) | arXiv 2203.04925: anti-correlated offsets are marginally uniform with non-positive pairwise covariance — never worse than the independent twin; the measured gain surfaces through `Calibration` |
//! | bits = base family's frame | correlated | shared offsets cost zero wire bits |
//!
//! `B̄` is the clients' average squared norm. The coordinate-sampling
//! wrapper mirrors Lemma 8 coordinate-wise, and the QSGD comparator uses
//! the same grid-width variance bound its `mse_bound` documents. Every
//! closed form is an upper bound; the [`model::Calibration`] fitter
//! shrinks each spec's prediction onto the measured behavior of the real
//! encode path, so planner choices reflect realized bits and error, not
//! just worst cases.

pub mod controller;
pub mod model;
pub mod planner;

pub use controller::{ControllerStep, RateController};
pub use model::{predicted_mse, predicted_uplink_bits, Calibration, SpecCalibration};
pub use planner::{
    MultiTenantPlan, Objective, Plan, PlannedSpec, TenantAllocation, TenantDemand,
};

#[allow(unused_imports)] // doc links
use crate::protocol::config::{Kind, ProtocolConfig};
