//! The live rate controller: per-session feedback loop that watches each
//! round's realized uplink bits and a decode-side MSE proxy, recalibrates
//! the active spec's bit prediction from what actually crossed the wire,
//! and switches the session's protocol between rounds (via the leader's
//! tag-5 `SpecChange` broadcast) when the plan says a better spec fits
//! the budget — `dme serve --auto-rate --budget-bits`.

use std::collections::HashMap;

use anyhow::{ensure, Result};

use super::model;
use super::planner::{Plan, PlannedSpec};

/// One observed round in the controller's log.
#[derive(Clone, Debug)]
pub struct ControllerStep {
    pub round: u64,
    /// Spec active during this round.
    pub spec: String,
    /// Realized uplink bits per client this round.
    pub bits_per_client: f64,
    /// Decode-side MSE proxy: squared distance between this round's
    /// estimate and the running mean of all previous rounds' estimates.
    /// For repeated estimation of a stationary mean this tracks the
    /// protocol's per-round MSE (each round's error is independent);
    /// it is observability — reported, not a switching signal, because
    /// a single round's proxy is far noisier than the calibrated model.
    pub mse_proxy: Option<f64>,
    /// Spec switched to *after* this round, if the controller retuned.
    pub switched_to: Option<String>,
    /// Observed participation p̂ this round (1.0 for a full round).
    pub participation: f64,
}

/// Per-session rate controller over a solved [`Plan`].
///
/// Policy (deterministic, convergent):
/// * the active spec's predicted bits are replaced by an exponential
///   blend of what the wire actually carried (sampling makes realized
///   bits stochastic; the blend smooths them),
/// * each round the plan's objective re-runs with those observed bits;
///   the controller switches when the active spec has outgrown the
///   budget, or when another spec's predicted MSE beats the active one
///   by more than the hysteresis margin (5% — prevents flapping between
///   near-ties),
/// * observed bits stick to a spec once measured, so a spec that
///   overran the budget is not re-chosen on its optimistic prediction.
pub struct RateController {
    plan: Plan,
    active: usize,
    /// candidate index → observed bits/client blend.
    observed_bits: HashMap<usize, f64>,
    /// Running mean of round estimates (slot 0), for the MSE proxy.
    est_mean: Vec<f64>,
    est_rounds: u64,
    history: Vec<ControllerStep>,
    /// Required relative predicted-MSE improvement before switching.
    min_gain: f64,
    /// EMA of observed participation p̂ (α = 1/2; the first observation
    /// replaces the default outright). `None` until a round reports.
    participation: Option<f64>,
}

impl RateController {
    /// Build over a solved plan; errors if the plan found no feasible
    /// spec (nothing fits the budget — say so up front, not mid-session).
    pub fn new(plan: Plan) -> Result<Self> {
        let active = plan.chosen.ok_or_else(|| {
            anyhow::anyhow!(
                "no spec fits {:.1} bits/client (d={}): raise --budget-bits",
                plan.budget_bits_per_client,
                plan.dim
            )
        })?;
        ensure!(!plan.candidates.is_empty(), "plan has no candidates");
        Ok(RateController {
            plan,
            active,
            observed_bits: HashMap::new(),
            est_mean: Vec::new(),
            est_rounds: 0,
            history: Vec::new(),
            min_gain: 0.05,
            participation: None,
        })
    }

    /// The spec the session should currently run.
    pub fn active_spec(&self) -> &PlannedSpec {
        &self.plan.candidates[self.active]
    }

    /// The observed-round log.
    pub fn history(&self) -> &[ControllerStep] {
        &self.history
    }

    /// The controller's current participation estimate (EMA of observed
    /// p̂; 1.0 before any round reported).
    pub fn participation(&self) -> f64 {
        self.participation.unwrap_or(1.0)
    }

    /// Effective bits/client of candidate `i` at the current
    /// participation estimate. Observed specs report what the wire
    /// actually carried — churn already priced in. Unobserved specs'
    /// predictions assume full participation, so Lemma 8's cost side
    /// (`C(π_p̂) = p̂·C(π)`) scales them down: under churn, more of the
    /// frontier fits the budget.
    fn effective_bits(&self, i: usize) -> f64 {
        match self.observed_bits.get(&i) {
            Some(&b) => b,
            None => self.plan.candidates[i].bits_per_client * self.participation(),
        }
    }

    /// Candidate `i`'s predicted MSE with the Lemma 8 participation
    /// penalty at the current p̂ estimate (the plan's predictions are
    /// normalized to avg ‖X‖² = 1, so the wrapper is applied the same
    /// way). The transform `x ↦ x/p̂ + c` is order-preserving, so the
    /// re-ranking story is really about the bits side — but the gain
    /// hysteresis compares MSE magnitudes, and those must be priced at
    /// the participation the session actually gets.
    fn effective_mse(&self, i: usize) -> f64 {
        model::mse_with_participation(
            self.plan.candidates[i].predicted_mse,
            self.plan.n,
            1.0,
            self.participation(),
        )
    }

    /// Feed one completed round. Returns the spec string to switch to
    /// before the next round, or `None` to stay.
    pub fn observe(
        &mut self,
        round: u64,
        uplink_bits: u64,
        n_clients: usize,
        estimate: &[f32],
    ) -> Option<String> {
        self.observe_with_participation(round, uplink_bits, n_clients, estimate, 1.0)
    }

    /// [`Self::observe`] with the round's observed participation rate
    /// p̂ (from `RoundMetrics::participation`): partial rounds feed the
    /// Lemma 8 sampling model back into the frontier, so the plan
    /// re-solves for the population that actually answers.
    pub fn observe_with_participation(
        &mut self,
        round: u64,
        uplink_bits: u64,
        n_clients: usize,
        estimate: &[f32],
        p_hat: f64,
    ) -> Option<String> {
        let p_hat = p_hat.clamp(f64::MIN_POSITIVE, 1.0);
        self.participation = Some(match self.participation {
            Some(prev) => 0.5 * prev + 0.5 * p_hat,
            None => p_hat,
        });
        let ran_spec = self.active_spec().spec.clone();
        let realized = uplink_bits as f64 / n_clients.max(1) as f64;
        // Blend realized into the active spec's bits (EMA, α = 1/2; the
        // first observation replaces the prediction outright).
        let blended = match self.observed_bits.get(&self.active) {
            Some(prev) => 0.5 * prev + 0.5 * realized,
            None => realized,
        };
        self.observed_bits.insert(self.active, blended);

        // Decode-side MSE proxy against the running estimate mean.
        let proxy = if self.est_rounds > 0 && self.est_mean.len() == estimate.len() {
            Some(
                estimate
                    .iter()
                    .zip(&self.est_mean)
                    .map(|(&e, &m)| (e as f64 - m) * (e as f64 - m))
                    .sum::<f64>(),
            )
        } else {
            None
        };
        if self.est_mean.len() != estimate.len() {
            self.est_mean = vec![0.0; estimate.len()];
            self.est_rounds = 0;
        }
        self.est_rounds += 1;
        let inv = 1.0 / self.est_rounds as f64;
        for (m, &e) in self.est_mean.iter_mut().zip(estimate) {
            *m += (e as f64 - *m) * inv;
        }

        // Re-run the objective with observed bits in place of
        // predictions, both sides priced at the participation EMA.
        let budget = self.plan.budget_bits_per_client;
        let best = (0..self.plan.candidates.len())
            .filter(|&i| self.effective_bits(i) <= budget)
            .min_by(|&a, &b| {
                self.effective_mse(a)
                    .total_cmp(&self.effective_mse(b))
                    .then(self.effective_bits(a).total_cmp(&self.effective_bits(b)))
                    .then(self.plan.candidates[a].spec.cmp(&self.plan.candidates[b].spec))
            });
        let active_over_budget = self.effective_bits(self.active) > budget;
        let switched_to = match best {
            Some(best) if best != self.active => {
                let gain = 1.0
                    - self.effective_mse(best)
                        / self.effective_mse(self.active).max(f64::MIN_POSITIVE);
                if active_over_budget || gain > self.min_gain {
                    self.active = best;
                    Some(self.plan.candidates[best].spec.clone())
                } else {
                    None
                }
            }
            _ => None,
        };
        self.history.push(ControllerStep {
            round,
            spec: ran_spec,
            bits_per_client: realized,
            mse_proxy: proxy,
            switched_to: switched_to.clone(),
            participation: p_hat,
        });
        switched_to
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rate::planner::Objective;

    fn plan(budget_bits_per_dim: f64) -> Plan {
        Plan::solve(budget_bits_per_dim * 256.0, 256, 32, Objective::MinMse).unwrap()
    }

    #[test]
    fn stays_put_when_realized_matches_predicted() {
        let mut ctl = RateController::new(plan(4.0)).unwrap();
        let spec = ctl.active_spec().spec.clone();
        let bits = ctl.active_spec().bits_per_client;
        let est = vec![0.5f32; 8];
        for r in 0..5 {
            let sw = ctl.observe(r, (bits * 32.0) as u64, 32, &est);
            assert!(sw.is_none(), "round {r} switched needlessly to {sw:?}");
        }
        assert_eq!(ctl.active_spec().spec, spec);
        // Proxy appears from round 1, and is ~0 for identical estimates.
        assert!(ctl.history()[0].mse_proxy.is_none());
        assert!(ctl.history()[1].mse_proxy.unwrap() < 1e-12);
    }

    #[test]
    fn switches_down_when_realized_bits_overrun_budget() {
        let mut ctl = RateController::new(plan(3.0)).unwrap();
        let first = ctl.active_spec().spec.clone();
        // The wire reports 4x the prediction: the active spec no longer
        // fits, the controller must move to a cheaper one and the
        // overrun spec must keep its observed cost (no flap back).
        let overrun = (ctl.active_spec().bits_per_client * 4.0 * 32.0) as u64;
        let est = vec![0.1f32; 8];
        let sw = ctl.observe(0, overrun, 32, &est);
        let second = sw.expect("must switch off an over-budget spec");
        assert_ne!(second, first);
        assert!(ctl.active_spec().bits_per_client <= 3.0 * 256.0);
        // Now realized matches the new spec: steady state.
        let ok = (ctl.active_spec().bits_per_client * 32.0) as u64;
        for r in 1..4 {
            assert!(ctl.observe(r, ok, 32, &est).is_none(), "flapped at round {r}");
        }
    }

    #[test]
    fn participation_ema_tracks_partial_rounds() {
        let mut ctl = RateController::new(plan(4.0)).unwrap();
        let est = vec![0.3f32; 8];
        let bits = ctl.active_spec().bits_per_client;
        assert_eq!(ctl.participation(), 1.0);
        // Half the clients answered: realized bits halve with them
        // (Lemma 8's cost side), and the EMA's first observation
        // replaces the default outright.
        ctl.observe_with_participation(0, (bits * 0.5 * 32.0) as u64, 32, &est, 0.5);
        assert!((ctl.participation() - 0.5).abs() < 1e-12);
        // A recovered full round blends halfway back (α = 1/2).
        let bits = ctl.active_spec().bits_per_client;
        ctl.observe_with_participation(1, (bits * 32.0) as u64, 32, &est, 1.0);
        assert!((ctl.participation() - 0.75).abs() < 1e-12);
        assert_eq!(ctl.history()[0].participation, 0.5);
        assert_eq!(ctl.history()[1].participation, 1.0);
        // The plain observe path is the p̂ = 1 special case.
        let bits = ctl.active_spec().bits_per_client;
        ctl.observe(2, (bits * 32.0) as u64, 32, &est);
        assert_eq!(ctl.history()[2].participation, 1.0);
    }

    #[test]
    fn refuses_an_unmeetable_budget() {
        let plan = Plan::solve(1.0, 1024, 8, Objective::MinMse).unwrap();
        assert!(RateController::new(plan).is_err());
    }
}
