//! The live rate controller: per-session feedback loop that watches each
//! round's realized uplink bits and a decode-side MSE proxy, recalibrates
//! the active spec's bit prediction from what actually crossed the wire,
//! and switches the session's protocol between rounds (via the leader's
//! tag-5 `SpecChange` broadcast) when the plan says a better spec fits
//! the budget — `dme serve --auto-rate --budget-bits`.

use std::collections::HashMap;

use anyhow::{ensure, Result};

use super::planner::{Plan, PlannedSpec};

/// One observed round in the controller's log.
#[derive(Clone, Debug)]
pub struct ControllerStep {
    pub round: u64,
    /// Spec active during this round.
    pub spec: String,
    /// Realized uplink bits per client this round.
    pub bits_per_client: f64,
    /// Decode-side MSE proxy: squared distance between this round's
    /// estimate and the running mean of all previous rounds' estimates.
    /// For repeated estimation of a stationary mean this tracks the
    /// protocol's per-round MSE (each round's error is independent);
    /// it is observability — reported, not a switching signal, because
    /// a single round's proxy is far noisier than the calibrated model.
    pub mse_proxy: Option<f64>,
    /// Spec switched to *after* this round, if the controller retuned.
    pub switched_to: Option<String>,
}

/// Per-session rate controller over a solved [`Plan`].
///
/// Policy (deterministic, convergent):
/// * the active spec's predicted bits are replaced by an exponential
///   blend of what the wire actually carried (sampling makes realized
///   bits stochastic; the blend smooths them),
/// * each round the plan's objective re-runs with those observed bits;
///   the controller switches when the active spec has outgrown the
///   budget, or when another spec's predicted MSE beats the active one
///   by more than the hysteresis margin (5% — prevents flapping between
///   near-ties),
/// * observed bits stick to a spec once measured, so a spec that
///   overran the budget is not re-chosen on its optimistic prediction.
pub struct RateController {
    plan: Plan,
    active: usize,
    /// candidate index → observed bits/client blend.
    observed_bits: HashMap<usize, f64>,
    /// Running mean of round estimates (slot 0), for the MSE proxy.
    est_mean: Vec<f64>,
    est_rounds: u64,
    history: Vec<ControllerStep>,
    /// Required relative predicted-MSE improvement before switching.
    min_gain: f64,
}

impl RateController {
    /// Build over a solved plan; errors if the plan found no feasible
    /// spec (nothing fits the budget — say so up front, not mid-session).
    pub fn new(plan: Plan) -> Result<Self> {
        let active = plan.chosen.ok_or_else(|| {
            anyhow::anyhow!(
                "no spec fits {:.1} bits/client (d={}): raise --budget-bits",
                plan.budget_bits_per_client,
                plan.dim
            )
        })?;
        ensure!(!plan.candidates.is_empty(), "plan has no candidates");
        Ok(RateController {
            plan,
            active,
            observed_bits: HashMap::new(),
            est_mean: Vec::new(),
            est_rounds: 0,
            history: Vec::new(),
            min_gain: 0.05,
        })
    }

    /// The spec the session should currently run.
    pub fn active_spec(&self) -> &PlannedSpec {
        &self.plan.candidates[self.active]
    }

    /// The observed-round log.
    pub fn history(&self) -> &[ControllerStep] {
        &self.history
    }

    fn effective_bits(&self, i: usize) -> f64 {
        *self.observed_bits.get(&i).unwrap_or(&self.plan.candidates[i].bits_per_client)
    }

    /// Feed one completed round. Returns the spec string to switch to
    /// before the next round, or `None` to stay.
    pub fn observe(
        &mut self,
        round: u64,
        uplink_bits: u64,
        n_clients: usize,
        estimate: &[f32],
    ) -> Option<String> {
        let ran_spec = self.active_spec().spec.clone();
        let realized = uplink_bits as f64 / n_clients.max(1) as f64;
        // Blend realized into the active spec's bits (EMA, α = 1/2; the
        // first observation replaces the prediction outright).
        let blended = match self.observed_bits.get(&self.active) {
            Some(prev) => 0.5 * prev + 0.5 * realized,
            None => realized,
        };
        self.observed_bits.insert(self.active, blended);

        // Decode-side MSE proxy against the running estimate mean.
        let proxy = if self.est_rounds > 0 && self.est_mean.len() == estimate.len() {
            Some(
                estimate
                    .iter()
                    .zip(&self.est_mean)
                    .map(|(&e, &m)| (e as f64 - m) * (e as f64 - m))
                    .sum::<f64>(),
            )
        } else {
            None
        };
        if self.est_mean.len() != estimate.len() {
            self.est_mean = vec![0.0; estimate.len()];
            self.est_rounds = 0;
        }
        self.est_rounds += 1;
        let inv = 1.0 / self.est_rounds as f64;
        for (m, &e) in self.est_mean.iter_mut().zip(estimate) {
            *m += (e as f64 - *m) * inv;
        }

        // Re-run the objective with observed bits in place of predictions.
        let budget = self.plan.budget_bits_per_client;
        let best = (0..self.plan.candidates.len())
            .filter(|&i| self.effective_bits(i) <= budget)
            .min_by(|&a, &b| {
                self.plan.candidates[a]
                    .predicted_mse
                    .total_cmp(&self.plan.candidates[b].predicted_mse)
                    .then(self.effective_bits(a).total_cmp(&self.effective_bits(b)))
                    .then(self.plan.candidates[a].spec.cmp(&self.plan.candidates[b].spec))
            });
        let active_over_budget = self.effective_bits(self.active) > budget;
        let switched_to = match best {
            Some(best) if best != self.active => {
                let gain = 1.0
                    - self.plan.candidates[best].predicted_mse
                        / self.plan.candidates[self.active].predicted_mse.max(f64::MIN_POSITIVE);
                if active_over_budget || gain > self.min_gain {
                    self.active = best;
                    Some(self.plan.candidates[best].spec.clone())
                } else {
                    None
                }
            }
            _ => None,
        };
        self.history.push(ControllerStep {
            round,
            spec: ran_spec,
            bits_per_client: realized,
            mse_proxy: proxy,
            switched_to: switched_to.clone(),
        });
        switched_to
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rate::planner::Objective;

    fn plan(budget_bits_per_dim: f64) -> Plan {
        Plan::solve(budget_bits_per_dim * 256.0, 256, 32, Objective::MinMse).unwrap()
    }

    #[test]
    fn stays_put_when_realized_matches_predicted() {
        let mut ctl = RateController::new(plan(4.0)).unwrap();
        let spec = ctl.active_spec().spec.clone();
        let bits = ctl.active_spec().bits_per_client;
        let est = vec![0.5f32; 8];
        for r in 0..5 {
            let sw = ctl.observe(r, (bits * 32.0) as u64, 32, &est);
            assert!(sw.is_none(), "round {r} switched needlessly to {sw:?}");
        }
        assert_eq!(ctl.active_spec().spec, spec);
        // Proxy appears from round 1, and is ~0 for identical estimates.
        assert!(ctl.history()[0].mse_proxy.is_none());
        assert!(ctl.history()[1].mse_proxy.unwrap() < 1e-12);
    }

    #[test]
    fn switches_down_when_realized_bits_overrun_budget() {
        let mut ctl = RateController::new(plan(3.0)).unwrap();
        let first = ctl.active_spec().spec.clone();
        // The wire reports 4x the prediction: the active spec no longer
        // fits, the controller must move to a cheaper one and the
        // overrun spec must keep its observed cost (no flap back).
        let overrun = (ctl.active_spec().bits_per_client * 4.0 * 32.0) as u64;
        let est = vec![0.1f32; 8];
        let sw = ctl.observe(0, overrun, 32, &est);
        let second = sw.expect("must switch off an over-budget spec");
        assert_ne!(second, first);
        assert!(ctl.active_spec().bits_per_client <= 3.0 * 256.0);
        // Now realized matches the new spec: steady state.
        let ok = (ctl.active_spec().bits_per_client * 32.0) as u64;
        for r in 1..4 {
            assert!(ctl.observe(r, ok, 32, &est).is_none(), "flapped at round {r}");
        }
    }

    #[test]
    fn refuses_an_unmeetable_budget() {
        let plan = Plan::solve(1.0, 1024, 8, Objective::MinMse).unwrap();
        assert!(RateController::new(plan).is_err());
    }
}
