//! The bit-budget planner: enumerate the discrete spec space, predict
//! each candidate's (bits, MSE) with the [`super::model`] forms, and
//! solve for the best spec under a communication budget — the paper's
//! MSE-vs-bits frontier as an optimizer (`dme tune`).

use anyhow::{ensure, Result};

use super::model::{self, Calibration};
use crate::protocol::config::{Kind, ProtocolConfig};
use crate::protocol::correlated::CorrBase;
use crate::protocol::quantizer::Span;
use crate::protocol::varlen::Coder;

/// What the planner optimizes, subject to the per-client bit budget.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Objective {
    /// Minimize predicted MSE s.t. predicted bits/client ≤ budget.
    MinMse,
    /// Minimize predicted bits/client s.t. predicted MSE ≤ `max_mse`
    /// (MSE normalized to avg ‖X‖² = 1; the budget still applies as an
    /// upper bound — pass `f64::INFINITY` to disable it).
    MinBits { max_mse: f64 },
}

/// One enumerated candidate with its predictions.
#[derive(Clone, Debug)]
pub struct PlannedSpec {
    pub cfg: ProtocolConfig,
    /// The exact spec-grammar string (`ProtocolConfig::to_string`):
    /// copy-pasteable into every `--protocol` flag and `SpecChange`.
    pub spec: String,
    /// Predicted expected uplink bits per client (calibrated when the
    /// plan was calibrated).
    pub bits_per_client: f64,
    /// Predicted MSE at the plan's `n`, normalized to avg ‖X‖² = 1.
    pub predicted_mse: f64,
}

impl PlannedSpec {
    fn from_cfg(cfg: ProtocolConfig, n: usize, cal: Option<&Calibration>) -> Self {
        let (bits, mse) = match cal {
            Some(c) => (c.predicted_bits(&cfg), c.predicted_mse(&cfg, n, 1.0)),
            None => (model::predicted_uplink_bits(&cfg), model::predicted_mse(&cfg, n, 1.0)),
        };
        PlannedSpec { spec: cfg.to_string(), cfg, bits_per_client: bits, predicted_mse: mse }
    }

    /// Bits per dimension per client (the paper's frontier axis).
    pub fn bits_per_dim(&self) -> f64 {
        self.bits_per_client / self.cfg.dim as f64
    }
}

/// A solved plan: every candidate (sorted by predicted bits), the Pareto
/// frontier over (bits, MSE), and the objective's arg-min.
#[derive(Clone, Debug)]
pub struct Plan {
    pub dim: usize,
    pub n: usize,
    pub budget_bits_per_client: f64,
    pub objective: Objective,
    /// All candidates, sorted by `bits_per_client` ascending (ties by
    /// MSE, then spec string — fully deterministic).
    pub candidates: Vec<PlannedSpec>,
    /// Indices into `candidates` on the Pareto frontier: strictly
    /// decreasing MSE as bits increase.
    pub frontier: Vec<usize>,
    /// Index of the objective's arg-min, if any candidate is feasible.
    pub chosen: Option<usize>,
    /// Whether predictions were empirically calibrated.
    pub calibrated: bool,
}

/// The discrete spec space: kind × k grid × coder × span (π_svk) ×
/// client-sampling p × coordinate-sampling q. The k grid carries the
/// power-of-two ladder the fixed-width protocols live on (any other k
/// pays ⌈log₂k⌉ for less accuracy), intermediate values and √d + 1 for
/// π_svk (whose rate moves smoothly in k), and the sampling grids fill
/// the frontier below each family's cheapest full-participation point.
fn candidate_grid(dim: usize) -> Vec<ProtocolConfig> {
    const P_GRID: [f64; 6] = [1.0, 0.75, 0.5, 0.375, 0.25, 0.125];
    const Q_GRID: [f64; 3] = [1.0, 0.5, 0.25];
    let mut ks: Vec<u32> = vec![2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64, 96, 128];
    let sqrt_d = (dim as f64).sqrt() as u32 + 1;
    if !ks.contains(&sqrt_d) {
        ks.push(sqrt_d);
    }
    ks.sort_unstable();
    ks.retain(|&k| (k as u64) <= 2 * dim as u64 + 1); // finer grids than coords are pointless
    let mut out = Vec::new();
    for p in P_GRID {
        let base = |kind: Kind| {
            let mut c = ProtocolConfig::new(kind, dim);
            c.p = p;
            c
        };
        out.push(base(Kind::Float32));
        out.push(base(Kind::Binary));
        // DRIVE has no k knob: one sign bit per padded coordinate. Its
        // p-ladder populates the extreme sub-bit-per-dim regime nothing
        // else reaches with constant (rather than Θ(d/n)) NMSE.
        out.push(base(Kind::Drive));
        for &k in &ks {
            out.push(base(Kind::Rotated).with_k(k));
            out.push(base(Kind::Qsgd).with_k(k));
            // Correlated quantization over both base quantizers, at the
            // default stratification: same frame cost as the base, never
            // worse MSE (calibration reveals the measured gain).
            out.push(base(Kind::Correlated).with_k(k));
            out.push({
                let mut c = base(Kind::Correlated).with_k(k);
                c.base = CorrBase::Rotated;
                c
            });
            for q in Q_GRID {
                let mut c = base(Kind::KLevel).with_k(k);
                c.q = q;
                out.push(c);
                for coder in [Coder::Arithmetic, Coder::Huffman] {
                    for span in [Span::MinMax, Span::Norm] {
                        let mut c = base(Kind::Varlen).with_k(k).with_coder(coder);
                        c.span = span;
                        c.q = q;
                        out.push(c);
                    }
                }
            }
        }
    }
    out
}

impl Plan {
    /// Solve analytically: enumerate the grid, predict with the paper's
    /// closed forms, compute the frontier and the objective's arg-min.
    /// `budget_bits_per_client` is the per-client uplink budget (the CLI
    /// multiplies its per-dim budget by d).
    pub fn solve(
        budget_bits_per_client: f64,
        dim: usize,
        n: usize,
        objective: Objective,
    ) -> Result<Plan> {
        ensure!(dim >= 1, "dim must be >= 1");
        ensure!(n >= 1, "clients must be >= 1");
        ensure!(budget_bits_per_client > 0.0, "budget must be > 0");
        let candidates: Vec<PlannedSpec> = candidate_grid(dim)
            .into_iter()
            .map(|cfg| PlannedSpec::from_cfg(cfg, n, None))
            .collect();
        let mut plan = Plan {
            dim,
            n,
            budget_bits_per_client,
            objective,
            candidates,
            frontier: Vec::new(),
            chosen: None,
            calibrated: false,
        };
        plan.resolve();
        Ok(plan)
    }

    /// Re-predict every candidate through an empirical [`Calibration`]
    /// (probe rounds through the real encode path, cached per spec) and
    /// re-solve. The planner then ranks by measured behavior instead of
    /// worst-case bounds.
    pub fn calibrate(&mut self, cal: &mut Calibration) -> Result<()> {
        for c in &mut self.candidates {
            cal.fit(&c.cfg)?;
            *c = PlannedSpec::from_cfg(c.cfg.clone(), self.n, Some(&*cal));
        }
        self.calibrated = true;
        self.resolve();
        Ok(())
    }

    /// Deterministic sort + frontier + arg-min.
    fn resolve(&mut self) {
        self.candidates.sort_by(|a, b| {
            a.bits_per_client
                .total_cmp(&b.bits_per_client)
                .then(a.predicted_mse.total_cmp(&b.predicted_mse))
                .then(a.spec.cmp(&b.spec))
        });
        self.frontier.clear();
        let mut best = f64::INFINITY;
        for (i, c) in self.candidates.iter().enumerate() {
            if c.predicted_mse < best {
                best = c.predicted_mse;
                self.frontier.push(i);
            }
        }
        self.chosen = match self.objective {
            Objective::MinMse => self
                .feasible()
                .min_by(|(_, a), (_, b)| {
                    a.predicted_mse
                        .total_cmp(&b.predicted_mse)
                        .then(a.bits_per_client.total_cmp(&b.bits_per_client))
                        .then(a.spec.cmp(&b.spec))
                })
                .map(|(i, _)| i),
            Objective::MinBits { max_mse } => self
                .feasible()
                .filter(|(_, c)| c.predicted_mse <= max_mse)
                .min_by(|(_, a), (_, b)| {
                    a.bits_per_client
                        .total_cmp(&b.bits_per_client)
                        .then(a.predicted_mse.total_cmp(&b.predicted_mse))
                        .then(a.spec.cmp(&b.spec))
                })
                .map(|(i, _)| i),
        };
    }

    fn feasible(&self) -> impl Iterator<Item = (usize, &PlannedSpec)> {
        let budget = self.budget_bits_per_client;
        self.candidates
            .iter()
            .enumerate()
            .filter(move |(_, c)| c.bits_per_client <= budget)
    }

    /// The objective's arg-min, if any candidate met the constraints.
    pub fn chosen_spec(&self) -> Option<&PlannedSpec> {
        self.chosen.map(|i| &self.candidates[i])
    }

    /// The Pareto-frontier candidates, cheapest first.
    pub fn frontier_specs(&self) -> impl Iterator<Item = &PlannedSpec> {
        self.frontier.iter().map(|&i| &self.candidates[i])
    }

    /// Best in-budget candidate of one protocol family — how the paper's
    /// ordering (π_sb ≻ π_srk ≻ π_svk in MSE at equal budget) is read
    /// off a plan.
    pub fn best_in_kind(&self, kind: Kind) -> Option<&PlannedSpec> {
        self.feasible()
            .filter(|(_, c)| c.cfg.kind == kind)
            .min_by(|(_, a), (_, b)| {
                a.predicted_mse
                    .total_cmp(&b.predicted_mse)
                    .then(a.bits_per_client.total_cmp(&b.bits_per_client))
            })
            .map(|(_, c)| c)
    }

    /// Machine-readable export (the `dme tune --json` / CI artifact
    /// format): scope, the chosen spec, and the full frontier.
    pub fn to_json(&self) -> String {
        fn spec_json(c: &PlannedSpec) -> String {
            format!(
                "{{\"spec\":\"{}\",\"bits_per_client\":{:.3},\"bits_per_dim\":{:.6},\
                 \"predicted_mse\":{:.6e}}}",
                c.spec,
                c.bits_per_client,
                c.bits_per_dim(),
                c.predicted_mse
            )
        }
        let frontier: Vec<String> = self.frontier_specs().map(spec_json).collect();
        let chosen = match self.chosen_spec() {
            Some(c) => spec_json(c),
            None => "null".to_string(),
        };
        format!(
            "{{\n  \"dim\": {},\n  \"clients\": {},\n  \"budget_bits_per_client\": {:.3},\n  \
             \"calibrated\": {},\n  \"n_candidates\": {},\n  \"chosen\": {},\n  \
             \"frontier\": [\n    {}\n  ]\n}}\n",
            self.dim,
            self.n,
            self.budget_bits_per_client,
            self.calibrated,
            self.candidates.len(),
            chosen,
            frontier.join(",\n    ")
        )
    }
}

/// One tenant's ask for the multi-tenant allocator: the scope its
/// frontier is solved at, and how much one unit of its MSE is worth
/// relative to the other tenants.
#[derive(Clone, Debug)]
pub struct TenantDemand {
    /// Wire session id (must be unique across the demand set).
    pub session: u16,
    pub dim: usize,
    pub n: usize,
    /// Relative importance weight (> 0, finite): scales the tenant's
    /// marginal MSE reduction when bidding for the next bit.
    pub weight: f64,
}

/// One tenant's slice of a solved [`MultiTenantPlan`].
#[derive(Clone, Debug)]
pub struct TenantAllocation {
    pub session: u16,
    /// The operating point the allocator landed on — always a point of
    /// this tenant's own Pareto frontier.
    pub spec: PlannedSpec,
}

/// A solved multi-tenant allocation: every tenant sits on its own
/// frontier, the floor was feasible, and no tenant can advance one more
/// frontier step within the leftover budget (greedy water-filling
/// optimality for discrete frontiers).
#[derive(Clone, Debug)]
pub struct MultiTenantPlan {
    /// The shared per-client uplink pool (bits per client per round,
    /// summed across tenants).
    pub budget_bits_per_client: f64,
    /// Per-tenant operating points, sorted by session id.
    pub allocations: Vec<TenantAllocation>,
    /// Σ allocated bits per client across tenants (≤ budget).
    pub spent_bits_per_client: f64,
}

impl MultiTenantPlan {
    /// Water-fill a shared uplink budget over per-tenant Pareto
    /// frontiers. Every tenant starts at its frontier's cheapest point
    /// (an error if even those floors overflow the budget — a tenant
    /// must never be silently starved below its cheapest legal spec);
    /// then, while budget remains, the tenant with the steepest weighted
    /// marginal gain `weight · ΔMSE / Δbits` advances one frontier step.
    /// Ties break to the lowest session id, so the allocation is fully
    /// deterministic in the demand set.
    pub fn solve(budget_bits_per_client: f64, tenants: &[TenantDemand]) -> Result<MultiTenantPlan> {
        ensure!(!tenants.is_empty(), "at least one tenant is required");
        ensure!(
            budget_bits_per_client > 0.0 && budget_bits_per_client.is_finite(),
            "budget must be positive and finite"
        );
        for (i, t) in tenants.iter().enumerate() {
            ensure!(t.weight > 0.0 && t.weight.is_finite(), "tenant {} weight invalid", t.session);
            ensure!(
                tenants[..i].iter().all(|u| u.session != t.session),
                "duplicate tenant session {}",
                t.session
            );
        }
        // Each tenant's full frontier, cheapest first (budget-independent).
        let mut fronts: Vec<Vec<PlannedSpec>> = Vec::with_capacity(tenants.len());
        for t in tenants {
            let plan = Plan::solve(f64::MAX, t.dim, t.n, Objective::MinMse)?;
            fronts.push(plan.frontier_specs().cloned().collect());
        }
        // Floor: everyone at their cheapest point, or the pool is too
        // small to host this tenant set at all.
        let mut idx = vec![0usize; tenants.len()];
        let mut spent: f64 = fronts.iter().map(|f| f[0].bits_per_client).sum();
        ensure!(
            spent <= budget_bits_per_client,
            "infeasible floor: the tenants' cheapest specs already need {:.1} bits/client \
             against a budget of {:.1}",
            spent,
            budget_bits_per_client
        );
        // Greedy water-filling: repeatedly fund the steepest affordable
        // marginal improvement.
        loop {
            let mut best: Option<(f64, u16, usize)> = None; // (gain rate, session, tenant idx)
            for (i, t) in tenants.iter().enumerate() {
                let cur = &fronts[i][idx[i]];
                let Some(next) = fronts[i].get(idx[i] + 1) else { continue };
                let dbits = next.bits_per_client - cur.bits_per_client;
                if spent + dbits > budget_bits_per_client {
                    continue;
                }
                let dmse = cur.predicted_mse - next.predicted_mse; // > 0 on a frontier
                let rate = t.weight * dmse / dbits.max(f64::MIN_POSITIVE);
                let wins = match best {
                    None => true,
                    Some((r, s, _)) => rate > r || (rate == r && t.session < s),
                };
                if wins {
                    best = Some((rate, t.session, i));
                }
            }
            let Some((_, _, i)) = best else { break };
            spent += fronts[i][idx[i] + 1].bits_per_client - fronts[i][idx[i]].bits_per_client;
            idx[i] += 1;
        }
        let mut allocations: Vec<TenantAllocation> = tenants
            .iter()
            .zip(&fronts)
            .zip(&idx)
            .map(|((t, front), &k)| TenantAllocation {
                session: t.session,
                spec: front[k].clone(),
            })
            .collect();
        allocations.sort_by_key(|a| a.session);
        Ok(MultiTenantPlan {
            budget_bits_per_client,
            allocations,
            spent_bits_per_client: spent,
        })
    }

    /// The allocation for `session`, if that tenant was in the demand set.
    pub fn for_session(&self, session: u16) -> Option<&TenantAllocation> {
        self.allocations.iter().find(|a| a.session == session)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_is_deterministic_and_replayable() {
        let a = candidate_grid(256);
        let b = candidate_grid(256);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x, y);
        }
        // Every candidate builds and its spec string replays exactly.
        for cfg in a.iter().take(200) {
            cfg.build().unwrap_or_else(|e| panic!("{cfg} fails to build: {e}"));
            let back = ProtocolConfig::parse(&cfg.to_string(), 256).unwrap();
            assert_eq!(&back, cfg);
        }
    }

    #[test]
    fn frontier_is_monotone_and_chosen_is_feasible() {
        let plan = Plan::solve(4.0 * 1024.0, 1024, 64, Objective::MinMse).unwrap();
        let frontier: Vec<_> = plan.frontier_specs().collect();
        assert!(frontier.len() >= 5, "frontier too small: {}", frontier.len());
        for w in frontier.windows(2) {
            assert!(w[0].bits_per_client <= w[1].bits_per_client);
            assert!(w[0].predicted_mse > w[1].predicted_mse, "frontier not strictly improving");
        }
        let chosen = plan.chosen_spec().expect("4 bits/dim must be feasible");
        assert!(chosen.bits_per_client <= plan.budget_bits_per_client);
        // Nothing feasible beats the chosen MSE.
        for c in &plan.candidates {
            if c.bits_per_client <= plan.budget_bits_per_client {
                assert!(c.predicted_mse >= chosen.predicted_mse);
            }
        }
        // float32 wins any budget that fits it (MSE 0), and needs 32/dim.
        let rich = Plan::solve(33.0 * 1024.0, 1024, 64, Objective::MinMse).unwrap();
        assert_eq!(rich.chosen_spec().unwrap().cfg.kind, Kind::Float32);
    }

    #[test]
    fn one_bit_per_dim_budget_reaches_the_drive_family() {
        // At 1 bit/dim no full-participation frame fits: π_sb needs
        // d + 64, every k-level family d⌈log₂k⌉ + 64, DRIVE itself
        // d̃ + 32. The pre-frontier grid could only offer Lemma-8-sampled
        // variants, whose (1−p)/(np) penalty dwarfs a small cohort —
        // DRIVE's constant-NMSE point at p = 0.75 is the analytic winner
        // there (closed forms, fully deterministic).
        let d = 1024usize;
        let plan = Plan::solve(d as f64, d, 2, Objective::MinMse).unwrap();
        let chosen = plan.chosen_spec().expect("1 bit/dim must be feasible");
        assert_eq!(chosen.cfg.kind, Kind::Drive, "expected drive, got {}", chosen.spec);
        // The correlated family is enumerated right alongside it.
        let has_corr = |b: CorrBase| {
            plan.candidates.iter().any(|c| c.cfg.kind == Kind::Correlated && c.cfg.base == b)
        };
        assert!(has_corr(CorrBase::Rotated));
        assert!(has_corr(CorrBase::KLevel));
        // At large n aggressive sampling may out-predict the worst-case
        // n-free DRIVE bound, but DRIVE stays the only family whose
        // full-participation point fits just above 1 bit/dim.
        let plan64 = Plan::solve(1.05 * d as f64, d, 64, Objective::MinMse).unwrap();
        let best_drive = plan64.best_in_kind(Kind::Drive).expect("drive must fit 1.05 bits/dim");
        assert_eq!(best_drive.cfg.p, 1.0, "full participation fits: {}", best_drive.spec);
        for kind in [Kind::Binary, Kind::KLevel, Kind::Rotated, Kind::Correlated] {
            if let Some(best) = plan64.best_in_kind(kind) {
                assert!(best.cfg.p < 1.0, "{kind:?} full frames cannot fit 1.05 bits/dim");
            }
        }
    }

    #[test]
    fn min_bits_objective_respects_mse_target() {
        let target = 1e-2;
        let plan =
            Plan::solve(f64::INFINITY, 1024, 64, Objective::MinBits { max_mse: target }).unwrap();
        let chosen = plan.chosen_spec().expect("target must be reachable");
        assert!(chosen.predicted_mse <= target);
        for c in &plan.candidates {
            if c.predicted_mse <= target {
                assert!(c.bits_per_client >= chosen.bits_per_client);
            }
        }
    }

    #[test]
    fn impossible_budget_yields_no_choice() {
        let plan = Plan::solve(0.5, 1024, 64, Objective::MinMse).unwrap();
        assert!(plan.chosen_spec().is_none(), "half a bit per client fits nothing");
        assert!(!plan.frontier.is_empty(), "the frontier is budget-independent");
    }

    fn demand(session: u16, weight: f64) -> TenantDemand {
        TenantDemand { session, dim: 256, n: 32, weight }
    }

    #[test]
    fn equal_tenants_split_the_pool_symmetrically() {
        let budget = 2.0 * 2.0 * 256.0; // 2 bits/dim each
        let mt = MultiTenantPlan::solve(budget, &[demand(1, 1.0), demand(2, 1.0)]).unwrap();
        assert_eq!(mt.allocations.len(), 2);
        assert!(mt.spent_bits_per_client <= budget);
        // Identical demands end within one greedy step of each other
        // (the budget can run out mid-alternation, never further apart).
        let plan = Plan::solve(f64::MAX, 256, 32, Objective::MinMse).unwrap();
        let front: Vec<_> = plan.frontier_specs().collect();
        let pos = |spec: &str| front.iter().position(|c| c.spec == spec).unwrap();
        let i = pos(&mt.allocations[0].spec.spec);
        let j = pos(&mt.allocations[1].spec.spec);
        assert!(i.abs_diff(j) <= 1, "equal tenants drifted apart: {i} vs {j}");
        // And the result replays bit-for-bit (deterministic tie-breaks).
        let again = MultiTenantPlan::solve(budget, &[demand(1, 1.0), demand(2, 1.0)]).unwrap();
        for (a, b) in mt.allocations.iter().zip(&again.allocations) {
            assert_eq!(a.spec.spec, b.spec.spec);
        }
    }

    #[test]
    fn allocation_is_maximal_within_budget() {
        let budget = 3.0 * 256.0; // tight: forces the greedy loop to stop mid-frontier
        let demands = [demand(1, 1.0), demand(2, 0.25)];
        let mt = MultiTenantPlan::solve(budget, &demands).unwrap();
        assert!(mt.spent_bits_per_client <= budget);
        // No tenant can take one more frontier step in the leftover
        // (mirrors the solver's own affordability expression exactly).
        for (t, alloc) in demands.iter().zip(&mt.allocations) {
            let plan = Plan::solve(f64::MAX, t.dim, t.n, Objective::MinMse).unwrap();
            let front: Vec<_> = plan.frontier_specs().collect();
            let k = front
                .iter()
                .position(|c| c.spec == alloc.spec.spec)
                .expect("allocation must sit on the tenant's own frontier");
            if let Some(next) = front.get(k + 1) {
                let step = next.bits_per_client - front[k].bits_per_client;
                assert!(
                    mt.spent_bits_per_client + step > budget,
                    "tenant {} left a fundable step unfunded",
                    t.session
                );
            }
        }
    }

    #[test]
    fn weight_buys_accuracy() {
        // A tenant that values accuracy 100x more must end at least as
        // far along its frontier (never behind) as its light peer.
        let budget = 4.0 * 256.0;
        let mt = MultiTenantPlan::solve(budget, &[demand(1, 100.0), demand(2, 1.0)]).unwrap();
        let heavy = mt.for_session(1).unwrap();
        let light = mt.for_session(2).unwrap();
        assert!(heavy.spec.predicted_mse <= light.spec.predicted_mse);
        assert!(heavy.spec.bits_per_client >= light.spec.bits_per_client);
    }

    #[test]
    fn infeasible_floor_is_an_error_not_a_starved_tenant() {
        // Three tenants cannot share half a bit per dim: the cheapest
        // legal specs already overflow, and that is a typed refusal.
        let budget = 0.5 * 256.0;
        let err = MultiTenantPlan::solve(budget, &[demand(1, 1.0), demand(2, 1.0), demand(3, 1.0)]);
        assert!(err.is_err());
        assert!(format!("{:#}", err.unwrap_err()).contains("infeasible floor"));
    }

    #[test]
    fn invalid_demand_sets_are_rejected() {
        assert!(MultiTenantPlan::solve(1024.0, &[]).is_err());
        assert!(MultiTenantPlan::solve(1024.0, &[demand(1, 0.0)]).is_err());
        assert!(MultiTenantPlan::solve(1024.0, &[demand(1, f64::NAN)]).is_err());
        assert!(MultiTenantPlan::solve(1024.0, &[demand(1, 1.0), demand(1, 2.0)]).is_err());
        assert!(MultiTenantPlan::solve(f64::INFINITY, &[demand(1, 1.0)]).is_err());
    }
}
