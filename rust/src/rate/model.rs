//! Closed-form rate/distortion predictors per protocol kind, and the
//! one-shot empirical calibration fitter.
//!
//! The analytic forms implement the paper's bounds (see the theorem map
//! in [`crate::rate`]): exact bit counts for the fixed-width protocols
//! (Lemmas 1 and 5 — these match the encoder to the bit), the Theorem 4
//! entropy-coded rate for π_svk, and the Theorem 1–3 / Lemma 8 MSE
//! bounds. Bounds are worst-case; [`Calibration::fit`] runs small probe
//! rounds through the *real* encode/decode path and stores per-spec
//! multiplicative correction factors, so calibrated predictions track
//! measured behavior (`tests/rate_models.rs` is the property suite:
//! empirical MSE stays below the calibrated prediction, and predicted
//! bits land within 10% of realized `RoundMetrics::uplink_bits`).

use std::collections::HashMap;

use anyhow::{ensure, Result};

use crate::coding::histogram;
use crate::data::synthetic;
use crate::protocol::config::{Kind, ProtocolConfig};
use crate::protocol::correlated::CorrBase;
use crate::protocol::varlen::Coder;
use crate::protocol::{run_round_with_scratch, EncodeScratch, Frame, RoundCtx};
use crate::stats;

/// Fixed-width bits per coordinate for a k-level grid: ⌈log₂ k⌉.
fn bits_per_coord(k: u32) -> f64 {
    debug_assert!(k >= 2);
    (32 - (k - 1).leading_zeros()) as f64
}

/// Binary entropy in bits (0 at q ∈ {0, 1}).
fn h2(q: f64) -> f64 {
    if q <= 0.0 || q >= 1.0 {
        0.0
    } else {
        -(q * q.log2() + (1.0 - q) * (1.0 - q).log2())
    }
}

/// Predicted **expected uplink payload bits per client** for `cfg`, at
/// the client edge (what sums into `RoundMetrics::uplink_bits / n`).
///
/// Exact for the fixed-width protocols (Lemma 1: π_sb = d + 64; Lemma 5:
/// π_sk = d⌈log₂k⌉ + 64; π_srk pays the padded dimension; float32 =
/// 32d; DRIVE = d̃ + 32 — one sign bit per padded coordinate plus a
/// single scale header; correlated pays exactly its base quantizer's
/// frame, offsets cost zero wire bits). π_svk uses Theorem 4's
/// entropy-coded rate plus the histogram side information; QSGD uses a
/// Gaussian-heuristic Elias-γ length.
/// Client sampling (π_p) scales the expectation by p; coordinate
/// sampling changes nothing for fixed-width frames (the encoder still
/// transmits every coordinate of the zeroed vector) and shrinks only
/// π_svk's entropy.
pub fn predicted_uplink_bits(cfg: &ProtocolConfig) -> f64 {
    let d = cfg.dim as f64;
    let k = cfg.effective_k().max(2);
    let kf = k as f64;
    let header = 2.0 * 32.0;
    let base = match cfg.kind {
        Kind::Float32 => 32.0 * d,
        Kind::Binary => d + header,
        Kind::KLevel => d * bits_per_coord(k) + header,
        Kind::Rotated => {
            let padded = cfg.dim.next_power_of_two() as f64;
            padded * bits_per_coord(k) + header
        }
        Kind::Drive => {
            // One sign bit per padded coordinate + a single 32-bit scale
            // (half the header of the k-level frames: no xmin scalar).
            let padded = cfg.dim.next_power_of_two() as f64;
            padded + 32.0
        }
        Kind::Correlated => {
            // The correlated offsets change *where* coordinates round,
            // not how many bits the frame carries: the cost is exactly
            // the base quantizer's fixed-width frame.
            let idim = match cfg.base {
                CorrBase::KLevel => d,
                CorrBase::Rotated => cfg.dim.next_power_of_two() as f64,
            };
            idim * bits_per_coord(k) + header
        }
        Kind::Varlen => {
            // Entropy-coded rate per coordinate, 2 + log₂(ρ² + 1.25)
            // where ρ is the per-coordinate spread over the bin width.
            // For the norm span (s = √2‖x‖) ρ² = (k−1)²/2d — Theorem 4
            // verbatim. The min-max span's width is range/(k−1) with a
            // Gaussian range of ≈ 2√(2 ln d) per-coordinate sigmas, so
            // ρ² = (k−1)²/(8 ln d). A q-sparsified vector pays the rate
            // on the surviving q-fraction plus ~h2(q) per coordinate for
            // the zero pattern (heuristic — the calibration fitter
            // corrects the constants against the real coder).
            let rho_sq = match cfg.span {
                crate::protocol::quantizer::Span::Norm => {
                    (kf - 1.0) * (kf - 1.0) / (2.0 * d)
                }
                crate::protocol::quantizer::Span::MinMax => {
                    (kf - 1.0) * (kf - 1.0) / (8.0 * d.max(2.0).ln())
                }
            };
            let r1 = 2.0 + (rho_sq + 1.25).log2();
            let coder_slack = match cfg.coder {
                Coder::Arithmetic => 0.0,
                // Huffman rounds each code word up to whole bits.
                Coder::Huffman => 0.1,
            };
            let per_coord = cfg.q * (r1 + coder_slack) + h2(cfg.q);
            d * per_coord + histogram::paper_bound_bits(cfg.dim as u64, k as u64) + header
        }
        Kind::Qsgd => {
            // Elias-γ over stochastic levels of |x_i|(k−1)/‖x‖. For
            // near-isotropic data E|x_i|/‖x‖ ≈ √(2/π)/√d, so levels are
            // Bernoulli-ish with rate λ = (k−1)/√d: one stop bit for
            // level 0, ~(3 + 2log₂(1+λ)) bits (γ code + sign) otherwise.
            // Heuristic — calibrated against the real encoder.
            let lambda = (kf - 1.0) / d.sqrt();
            let p1 = (0.8 * lambda).min(1.0);
            d * (1.0 + p1 * (3.0 + 2.0 * (1.0 + lambda).log2())) + 32.0
        }
    };
    // Lemma 8: a sampled client transmits with probability p.
    base * cfg.p
}

/// Predicted worst-case MSE for `cfg` with `n` clients whose average
/// squared norm is `avg_norm_sq` — Theorems 1–3 for the base protocols,
/// Lemma 8 for the sampling wrapper (and its coordinate-wise mirror for
/// q), matching each protocol's `mse_bound` exactly.
pub fn predicted_mse(cfg: &ProtocolConfig, n: usize, avg_norm_sq: f64) -> f64 {
    let d = cfg.dim as f64;
    let nf = n as f64;
    let k = cfg.effective_k().max(2);
    let km1 = (k - 1) as f64;
    let base = match cfg.kind {
        Kind::Float32 => 0.0,
        Kind::Binary => d / (2.0 * nf) * avg_norm_sq,
        Kind::KLevel | Kind::Varlen => d / (2.0 * nf * km1 * km1) * avg_norm_sq,
        Kind::Rotated => {
            let padded = cfg.dim.next_power_of_two() as f64;
            (2.0 * padded.ln() + 2.0) / (nf * km1 * km1) * avg_norm_sq
        }
        Kind::Drive => {
            // DRIVE Thm 5.4 regime with the finite-d Hadamard slack —
            // intentionally n-free (clients share one rotation, so the
            // worst case gets no 1/n averaging); must stay byte-identical
            // to `DriveProtocol::mse_bound`.
            let padded = cfg.dim.next_power_of_two() as f64;
            (std::f64::consts::FRAC_PI_2 - 1.0) * (1.0 + 8.0 / padded.sqrt()) * avg_norm_sq
        }
        Kind::Correlated => {
            // Honest base-family worst case: anti-correlated offsets are
            // marginally uniform with non-positive pairwise covariance,
            // so the family is never *worse* than its independent twin —
            // the measured gain surfaces through `Calibration`, not the
            // bound. Must stay byte-identical to
            // `CorrelatedProtocol::mse_bound`.
            match cfg.base {
                CorrBase::KLevel => d / (2.0 * nf * km1 * km1) * avg_norm_sq,
                CorrBase::Rotated => {
                    let padded = cfg.dim.next_power_of_two() as f64;
                    (2.0 * padded.ln() + 2.0) / (nf * km1 * km1) * avg_norm_sq
                }
            }
        }
        Kind::Qsgd => d / (4.0 * nf * km1 * km1) * avg_norm_sq,
    };
    // Coordinate sampling (inner wrapper), then client sampling (outer) —
    // the same stacking order `ProtocolConfig::build` applies.
    let base = if cfg.q < 1.0 {
        base / cfg.q + (1.0 - cfg.q) / (nf * cfg.q) * avg_norm_sq
    } else {
        base
    };
    if cfg.p < 1.0 {
        base / cfg.p + (1.0 - cfg.p) / (nf * cfg.p) * avg_norm_sq
    } else {
        base
    }
}

/// Lemma 8's sampling wrapper applied to an already-computed MSE
/// prediction, at an *observed* participation rate p̂ rather than a
/// planned sampling rate: `base/p̂ + (1−p̂)/(n·p̂) · avg_norm_sq`.
/// This is what a partial round (`coordinator::leader`,
/// `BarrierPolicy::Partial`) does to any protocol's error — churn is
/// client sampling the scheduler didn't ask for — so the controller
/// re-ranks its frontier by pushing every candidate's full-participation
/// prediction through this at the EMA of observed p̂.
pub fn mse_with_participation(base: f64, n: usize, avg_norm_sq: f64, p_hat: f64) -> f64 {
    if p_hat >= 1.0 || p_hat <= 0.0 {
        return base;
    }
    let nf = (n as f64).max(1.0);
    base / p_hat + (1.0 - p_hat) / (nf * p_hat) * avg_norm_sq
}

/// [`predicted_mse`] composed with [`mse_with_participation`]: the
/// analytic worst-case MSE of `cfg` when only a p̂ fraction of the `n`
/// enrolled clients answers each round.
pub fn predicted_mse_at_participation(
    cfg: &ProtocolConfig,
    n: usize,
    avg_norm_sq: f64,
    p_hat: f64,
) -> f64 {
    mse_with_participation(predicted_mse(cfg, n, avg_norm_sq), n, avg_norm_sq, p_hat)
}

/// Per-spec multiplicative corrections fitted by [`Calibration::fit`]:
/// `calibrated = analytic × factor`. Both MSE and its analytic bound
/// scale exactly as 1/n, and the bit formulas are per-client, so a
/// factor fitted at the probe's small n transfers to any n at the same
/// dimension.
#[derive(Clone, Copy, Debug)]
pub struct SpecCalibration {
    pub bits_factor: f64,
    pub mse_factor: f64,
    /// Probe rounds the fit averaged over.
    pub probe_rounds: u64,
}

impl Default for SpecCalibration {
    fn default() -> Self {
        SpecCalibration { bits_factor: 1.0, mse_factor: 1.0, probe_rounds: 0 }
    }
}

/// Probe inputs for one dimension: generated and scanned **once**, then
/// shared by every spec fitted at that dimension. The scan is the same
/// fused single pass the quantizer's grid rules use
/// ([`crate::linalg::vector_stats`] yields each row's squared norm
/// alongside min/max), so a `dme tune` plan that fits hundreds of
/// candidate specs per dimension reads the probe data once instead of
/// re-scanning it per spec.
struct ProbeSet {
    rows: Vec<Vec<f32>>,
    truth: Vec<f32>,
    avg_norm_sq: f64,
}

/// One-shot empirical fitter: runs small probe rounds through the real
/// encode path ([`run_round_with_scratch`], the same engine experiments
/// use, with the per-round encode scratch held across fits) on Gaussian
/// probe data and stores per-spec correction factors, keyed by
/// `(spec string, dim)`. Fitting is deterministic for a given seed.
pub struct Calibration {
    seed: u64,
    n_probe: usize,
    trials: u64,
    factors: HashMap<String, SpecCalibration>,
    /// Per-dimension probe data, generated + scanned once (see [`ProbeSet`]).
    probes: HashMap<usize, ProbeSet>,
    /// Encode scratch + frame reused by every probe round this fitter runs.
    scratch: EncodeScratch,
    frame: Frame,
}

impl Calibration {
    /// Default probe: 8 clients × 4 rounds per spec — small enough to
    /// fit a few hundred specs in well under a second at d ≈ 1024.
    pub fn new(seed: u64) -> Self {
        Calibration {
            seed,
            n_probe: 8,
            trials: 4,
            factors: HashMap::new(),
            probes: HashMap::new(),
            scratch: EncodeScratch::default(),
            frame: Frame::empty(),
        }
    }

    /// Override the probe shape (tests use more rounds for tight fits).
    pub fn with_probe(mut self, n_probe: usize, trials: u64) -> Self {
        self.n_probe = n_probe.max(2);
        self.trials = trials.max(1);
        self
    }

    fn key(cfg: &ProtocolConfig) -> String {
        format!("{}#d{}", cfg, cfg.dim)
    }

    /// Fit (or return the cached) correction factors for `cfg` by
    /// running probe rounds through the real encode/decode path.
    pub fn fit(&mut self, cfg: &ProtocolConfig) -> Result<SpecCalibration> {
        let key = Self::key(cfg);
        if let Some(c) = self.factors.get(&key) {
            return Ok(*c);
        }
        ensure!(cfg.dim >= 1, "calibration needs dim >= 1");
        let proto = cfg.build()?;
        // Same probe data for every spec at a given dim: factors stay
        // comparable across the planner's candidate set, and the rows are
        // generated and scanned exactly once per dimension (one fused
        // `vector_stats` pass per row yields the squared norms).
        if !self.probes.contains_key(&cfg.dim) {
            let data = synthetic::gaussian(self.n_probe, cfg.dim, self.seed ^ cfg.dim as u64);
            let truth = stats::true_mean(&data.rows);
            let avg_norm_sq = data
                .rows
                .iter()
                .map(|r| crate::linalg::vector_stats(r).norm_sq)
                .sum::<f64>()
                / data.rows.len() as f64;
            self.probes.insert(cfg.dim, ProbeSet { rows: data.rows, truth, avg_norm_sq });
        }
        let probe = &self.probes[&cfg.dim];
        let avg_sq = probe.avg_norm_sq;
        let mut err = stats::Running::new();
        let mut bits = stats::Running::new();
        for t in 0..self.trials {
            let ctx = RoundCtx::new(t, self.seed);
            let (est, b) = run_round_with_scratch(
                proto.as_ref(),
                &ctx,
                &probe.rows,
                &mut self.scratch,
                &mut self.frame,
            )?;
            err.push(stats::sq_error(&est, &probe.truth));
            bits.push(b as f64 / self.n_probe as f64);
        }
        // Bits are calibrated on the p = 1 twin: the sampling wrapper's
        // expected cost is exactly p × the inner cost (Lemma 8), while a
        // sampled probe would fold binomial speaker-count noise straight
        // into the correction factor. The frame cost being calibrated is
        // the same either way — silent clients simply skip the encoder.
        // Fitting the twin through `self.fit` caches it under its own
        // key, so every p-variant of an inner spec (and the p = 1
        // candidate itself) shares one probe.
        let raw_mse = predicted_mse(cfg, self.n_probe, avg_sq);
        // Factors are clamped: a probe fluke must not convince the
        // planner a spec is free (or ruinous).
        let bits_factor = if cfg.p < 1.0 {
            let mut twin = cfg.clone();
            twin.p = 1.0;
            self.fit(&twin)?.bits_factor
        } else {
            let raw_bits = predicted_uplink_bits(cfg);
            if bits.mean() > 0.0 && raw_bits > 0.0 {
                (bits.mean() / raw_bits).clamp(0.05, 20.0)
            } else {
                1.0
            }
        };
        let mse_factor = if raw_mse > 0.0 { (err.mean() / raw_mse).clamp(0.0, 10.0) } else { 0.0 };
        let cal = SpecCalibration { bits_factor, mse_factor, probe_rounds: self.trials };
        self.factors.insert(key, cal);
        Ok(cal)
    }

    /// Fitted factors for `cfg`, if [`Calibration::fit`] ran.
    pub fn get(&self, cfg: &ProtocolConfig) -> Option<&SpecCalibration> {
        self.factors.get(&Self::key(cfg))
    }

    /// Calibrated expected uplink bits per client (analytic if unfitted).
    pub fn predicted_bits(&self, cfg: &ProtocolConfig) -> f64 {
        let f = self.get(cfg).map(|c| c.bits_factor).unwrap_or(1.0);
        predicted_uplink_bits(cfg) * f
    }

    /// Calibrated MSE prediction (analytic bound if unfitted). The 1/n
    /// scaling is exact in both the bound and the estimator, so the
    /// probe-n fit transfers to any `n`.
    pub fn predicted_mse(&self, cfg: &ProtocolConfig, n: usize, avg_norm_sq: f64) -> f64 {
        match self.get(cfg) {
            Some(c) if c.mse_factor > 0.0 => predicted_mse(cfg, n, avg_norm_sq) * c.mse_factor,
            Some(_) => predicted_mse(cfg, n, avg_norm_sq), // float32: exact zero bound
            None => predicted_mse(cfg, n, avg_norm_sq),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::{run_round, Protocol};

    /// The fixed-width predictions are exact, to the bit, against the
    /// real encoders (Lemmas 1 and 5; π_srk pays the padded dimension).
    #[test]
    fn fixed_width_bit_predictions_are_exact() {
        let mut rng = crate::rng::Pcg64::new(9);
        for (spec, d) in [
            ("float32", 100usize),
            ("binary", 100),
            ("klevel:k=4", 64),
            ("klevel:k=16", 100),
            ("klevel:k=17", 100),
            ("rotated:k=16", 100), // pads to 128
            ("rotated:k=4", 256),
            ("drive", 100), // pads to 128: 128 + 32 bits
            ("drive", 256),
            ("correlated:k=4", 64),
            ("correlated:k=16,strata=8", 100),
            ("correlated:base=rotated,k=16", 100), // pads to 128
        ] {
            let cfg = ProtocolConfig::parse(spec, d).unwrap();
            let proto = cfg.build().unwrap();
            let mut x = vec![0.0f32; d];
            rng.fill_gaussian_f32(&mut x);
            let frame = proto.encode(&RoundCtx::new(0, 3), 0, &x).unwrap();
            assert_eq!(
                predicted_uplink_bits(&cfg),
                frame.bit_len as f64,
                "spec={spec} d={d}"
            );
        }
    }

    #[test]
    fn mse_predictions_match_protocol_bounds() {
        // The model's closed forms must agree with each protocol's own
        // mse_bound (the single source of truth the experiments verify).
        // Swept programmatically over every kind × k × span × p × q ×
        // dim the builder accepts, so a future change to any protocol's
        // bound cannot silently desynchronize the planner.
        use crate::protocol::quantizer::Span;
        let mut n_checked = 0usize;
        for kind in Kind::ALL {
            for d in [65usize, 128, 1000] {
                for k in [2u32, 5, 16, 33] {
                    for span in [Span::MinMax, Span::Norm] {
                        for p in [1.0f64, 0.5, 0.125] {
                            for q in [1.0f64, 0.25] {
                                let mut cfg = ProtocolConfig::new(kind, d);
                                cfg.k = k;
                                cfg.span = span;
                                cfg.p = p;
                                cfg.q = q;
                                let Ok(proto) = cfg.build() else {
                                    continue; // e.g. rotated + q < 1
                                };
                                for n in [4usize, 64] {
                                    let avg = 3.7;
                                    let got = predicted_mse(&cfg, n, avg);
                                    match proto.mse_bound(n, avg) {
                                        Some(want) if want > 0.0 => {
                                            assert!(
                                                (got - want).abs()
                                                    <= 1e-12 * want.abs().max(1.0),
                                                "cfg={cfg} d={d} n={n}: model {got} vs \
                                                 protocol bound {want}"
                                            );
                                            n_checked += 1;
                                        }
                                        // float32 (and its wrappers' base
                                        // term): the model must agree it
                                        // is the exact-transmission case.
                                        _ => assert!(
                                            got >= 0.0,
                                            "cfg={cfg}: negative predicted MSE"
                                        ),
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        assert!(n_checked > 500, "sweep unexpectedly small ({n_checked})");
        assert_eq!(predicted_mse(&ProtocolConfig::float32(128), 16, 3.7), 0.0);
    }

    #[test]
    fn sampling_scales_bits_by_p() {
        let base = ProtocolConfig::parse("klevel:k=16", 64).unwrap();
        let half = ProtocolConfig::parse("klevel:k=16,p=0.5", 64).unwrap();
        assert_eq!(predicted_uplink_bits(&half), predicted_uplink_bits(&base) * 0.5);
        // Coordinate sampling leaves fixed-width frames untouched.
        let q = ProtocolConfig::parse("klevel:k=16,q=0.5", 64).unwrap();
        assert_eq!(predicted_uplink_bits(&q), predicted_uplink_bits(&base));
        // ...but shrinks varlen's entropy.
        let v = ProtocolConfig::parse("varlen:k=8", 256).unwrap();
        let vq = ProtocolConfig::parse("varlen:k=8,q=0.25", 256).unwrap();
        assert!(predicted_uplink_bits(&vq) < predicted_uplink_bits(&v));
    }

    #[test]
    fn calibration_tracks_the_real_coder() {
        // varlen's analytic rate is a worst-case bound; the calibrated
        // prediction must land on the measured bits (same probe seed ⇒
        // deterministic).
        let cfg = ProtocolConfig::parse("varlen:k=17", 256).unwrap();
        let mut cal = Calibration::new(11);
        let fit = cal.fit(&cfg).unwrap();
        assert!(fit.bits_factor < 1.0, "Theorem 4 bound should overshoot the real coder");
        let proto = cfg.build().unwrap();
        let data = synthetic::gaussian(8, 256, 999);
        let ctx = RoundCtx::new(7, 5);
        let (_, bits) = run_round(proto.as_ref(), &ctx, &data.rows).unwrap();
        let measured = bits as f64 / 8.0;
        let pred = cal.predicted_bits(&cfg);
        assert!(
            (pred - measured).abs() / measured < 0.10,
            "calibrated {pred} vs measured {measured}"
        );
        // Fit results are cached.
        let again = cal.fit(&cfg).unwrap();
        assert_eq!(again.bits_factor, fit.bits_factor);
    }
}
