//! Runtime dispatch for the vectorized hot paths.
//!
//! The per-coordinate loops (stochastic quantization, the FWHT
//! butterflies, frame bit pack/unpack) each exist twice: a scalar
//! reference implementation — the executable specification every
//! conformance suite diffs against — and an explicitly vectorized
//! `std::arch` twin that must be **bit-identical** to it. This module
//! decides, once, which one runs:
//!
//! * Compile time: the `simd` cargo feature (on by default) compiles the
//!   `std::arch` kernels at all. `--no-default-features` builds the
//!   scalar reference only — the forced-scalar CI leg.
//! * Run time: [`use_x86_vector`] requires `avx2` via
//!   `is_x86_feature_detected!` (cached after the first call), so the
//!   same binary is correct on any x86-64 — older machines simply take
//!   the scalar path. Non-x86 targets always report `false`.
//! * Override: [`set_force_scalar`] flips every dispatch back to the
//!   scalar reference at run time. Benches use it to measure the scalar
//!   baseline and the vector path *in the same process* (the ≥3×
//!   acceptance gate in `benches/micro.rs`), and the conformance suite
//!   uses it to drive full encode/decode pipelines down both paths.
//!
//! Because both paths produce identical bits, flipping the override —
//! even while other threads are mid-encode — can never change an
//! observable result, only which (equivalent) instructions compute it.

use std::sync::atomic::{AtomicBool, Ordering};

/// When `true`, every dispatch point takes the scalar reference path
/// regardless of CPU features. Relaxed ordering is enough: the flag only
/// selects between bit-identical implementations.
static FORCE_SCALAR: AtomicBool = AtomicBool::new(false);

/// Force (or stop forcing) the scalar reference path process-wide.
/// Intended for benches and conformance tests; returns the previous
/// value so callers can restore it.
pub fn set_force_scalar(force: bool) -> bool {
    FORCE_SCALAR.swap(force, Ordering::Relaxed)
}

/// Is the scalar override currently active?
pub fn force_scalar() -> bool {
    FORCE_SCALAR.load(Ordering::Relaxed)
}

/// Does this build + CPU support the AVX2 kernels at all (ignoring the
/// scalar override)? Cached after the first call.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
pub fn x86_vector_available() -> bool {
    static AVAILABLE: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *AVAILABLE.get_or_init(|| std::arch::is_x86_feature_detected!("avx2"))
}

/// Scalar-only build or non-x86 target: the vector kernels don't exist.
#[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
pub fn x86_vector_available() -> bool {
    false
}

/// Should a dispatch point take the AVX2 kernel right now? This is the
/// single gate every vectorized hot path checks (one relaxed atomic load
/// plus a cached feature bit — negligible next to any loop it guards).
#[inline]
pub fn use_x86_vector() -> bool {
    x86_vector_available() && !force_scalar()
}

/// Human-readable name of the active dispatch target, for bench labels
/// and logs.
pub fn active_path() -> &'static str {
    if use_x86_vector() {
        "avx2"
    } else {
        "scalar"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn force_scalar_round_trips() {
        let prev = set_force_scalar(true);
        assert!(force_scalar());
        assert!(!use_x86_vector());
        assert_eq!(active_path(), "scalar");
        set_force_scalar(false);
        assert!(!force_scalar());
        // Whatever the CPU supports, the gate must agree with the
        // availability probe once the override is off.
        assert_eq!(use_x86_vector(), x86_vector_available());
        set_force_scalar(prev);
    }
}
