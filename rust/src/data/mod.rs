//! Dataset generators for the paper's experiments.
//!
//! The paper evaluates on (a) synthetic Gaussians, (b) the *unbalanced*
//! Gaussian of Figure 1 (last dimension ~ N(100, 1)), and (c) MNIST
//! (d = 1024) / CIFAR (d = 512). This environment has no network access,
//! so (c) is substituted with deterministic generators that match the
//! properties the experiments actually exercise — dimension, norm
//! distribution, and coordinate correlation structure (see DESIGN.md §3:
//! the experiments quantize client→server *update vectors*; no label
//! semantics are used). A loader for local `.f32` files is provided for
//! users who want to run on the real datasets.

pub mod synthetic;

use crate::rng::Pcg64;

/// A dataset: `n` rows of dimension `d`, plus provenance for reports.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub rows: Vec<Vec<f32>>,
    pub dim: usize,
}

impl Dataset {
    pub fn new(name: impl Into<String>, rows: Vec<Vec<f32>>) -> Self {
        let dim = rows.first().map(|r| r.len()).unwrap_or(0);
        debug_assert!(rows.iter().all(|r| r.len() == dim));
        Dataset { name: name.into(), rows, dim }
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Split rows round-robin across `n_clients` shards (the distributed
    /// setting: each client holds a disjoint subset).
    pub fn shard(&self, n_clients: usize) -> Vec<Vec<Vec<f32>>> {
        let mut shards = vec![Vec::new(); n_clients];
        for (i, row) in self.rows.iter().enumerate() {
            shards[i % n_clients].push(row.clone());
        }
        shards
    }

    /// Load a raw little-endian f32 matrix from disk (`rows × dim`).
    pub fn from_f32_file(
        path: impl AsRef<std::path::Path>,
        dim: usize,
    ) -> anyhow::Result<Self> {
        let bytes = std::fs::read(&path)?;
        anyhow::ensure!(bytes.len() % (4 * dim) == 0, "file size not a multiple of 4*dim");
        let n = bytes.len() / (4 * dim);
        let mut rows = Vec::with_capacity(n);
        for i in 0..n {
            let mut row = Vec::with_capacity(dim);
            for j in 0..dim {
                let off = (i * dim + j) * 4;
                row.push(f32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()));
            }
            rows.push(row);
        }
        Ok(Dataset::new(
            path.as_ref().file_name().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default(),
            rows,
        ))
    }
}

/// Normalize all rows into the unit ball `S^d` (the paper's minimax
/// setting assumes ‖X_i‖₂ ≤ 1) by dividing by the max norm.
pub fn normalize_to_unit_ball(rows: &mut [Vec<f32>]) {
    let max_norm = rows
        .iter()
        .map(|r| crate::linalg::norm(r))
        .fold(0.0f64, f64::max);
    if max_norm > 0.0 {
        let inv = (1.0 / max_norm) as f32;
        for r in rows.iter_mut() {
            crate::linalg::scale(r, inv);
        }
    }
}

/// Convenience: a fresh deterministic RNG for dataset generation, domain-
/// separated from protocol randomness.
pub fn data_rng(seed: u64) -> Pcg64 {
    Pcg64::new(crate::rng::mix(&[seed, 0xda7a_da7a_da7a_da7a]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_round_robin() {
        let rows: Vec<Vec<f32>> = (0..10).map(|i| vec![i as f32]).collect();
        let ds = Dataset::new("t", rows);
        let shards = ds.shard(3);
        assert_eq!(shards[0].len(), 4);
        assert_eq!(shards[1].len(), 3);
        assert_eq!(shards[2].len(), 3);
        assert_eq!(shards[1][0][0], 1.0);
    }

    #[test]
    fn normalize_unit_ball() {
        let mut rows = vec![vec![3.0f32, 4.0], vec![0.3, 0.4]];
        normalize_to_unit_ball(&mut rows);
        assert!((crate::linalg::norm(&rows[0]) - 1.0).abs() < 1e-6);
        assert!(crate::linalg::norm(&rows[1]) < 0.2);
    }

    #[test]
    fn f32_file_roundtrip() {
        let dir = std::env::temp_dir().join(format!("dme_data_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.f32");
        let mut bytes = Vec::new();
        for v in [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(&path, &bytes).unwrap();
        let ds = Dataset::from_f32_file(&path, 3).unwrap();
        assert_eq!(ds.len(), 2);
        assert_eq!(ds.rows[1], vec![4.0, 5.0, 6.0]);
        assert!(Dataset::from_f32_file(&path, 4).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
