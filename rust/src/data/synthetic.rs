//! Synthetic dataset generators (all deterministic in the seed).
//!
//! * [`gaussian`] — iid N(0, 1) rows: the generic benchmark data.
//! * [`unbalanced`] — Figure 1's dataset: 1000 points, d = 256, first 255
//!   dims N(0,1), last dim N(100,1).
//! * [`unit_sphere`] — uniform on the unit sphere (the §3 motivating case
//!   where max−min is already O(√(log d / d))).
//! * [`mnist_like`] / [`cifar_like`] — stand-ins for the paper's MNIST
//!   (d=1024) and CIFAR (d=512): mixtures of class prototypes with
//!   structured (smooth) correlations and per-class noise, matching the
//!   dimension and the clustered geometry that Lloyd's / power iteration
//!   experiments exercise. See DESIGN.md §3 for the substitution rationale.

use super::{data_rng, Dataset};
use crate::linalg;

/// `n` iid standard-Gaussian rows of dimension `d`.
pub fn gaussian(n: usize, d: usize, seed: u64) -> Dataset {
    let mut rng = data_rng(seed);
    let rows = (0..n)
        .map(|_| {
            let mut x = vec![0.0f32; d];
            rng.fill_gaussian_f32(&mut x);
            x
        })
        .collect();
    Dataset::new(format!("gaussian(n={n},d={d})"), rows)
}

/// Figure 1's unbalanced data: dims 0..d−1 ~ N(0,1), last dim ~ N(μ,1).
pub fn unbalanced(n: usize, d: usize, mu: f32, seed: u64) -> Dataset {
    let mut rng = data_rng(seed ^ 0x1);
    let rows = (0..n)
        .map(|_| {
            let mut x = vec![0.0f32; d];
            rng.fill_gaussian_f32(&mut x);
            x[d - 1] += mu;
            x
        })
        .collect();
    Dataset::new(format!("unbalanced(n={n},d={d},mu={mu})"), rows)
}

/// Uniform on the unit sphere.
pub fn unit_sphere(n: usize, d: usize, seed: u64) -> Dataset {
    let mut rng = data_rng(seed ^ 0x2);
    let rows = (0..n)
        .map(|_| {
            let mut x = vec![0.0f32; d];
            rng.fill_gaussian_f32(&mut x);
            linalg::normalize(&mut x);
            x
        })
        .collect();
    Dataset::new(format!("sphere(n={n},d={d})"), rows)
}

/// Shared engine for the image-like generators: `classes` smooth
/// prototypes on a `side × side` grid, plus correlated noise, clipped to
/// [0, 1] like pixel intensities, with a small fraction of near-zero
/// background pixels (images are sparse at the margins).
fn image_like(
    name: &str,
    n: usize,
    side: usize,
    classes: usize,
    noise: f32,
    seed: u64,
) -> Dataset {
    let d = side * side;
    let mut rng = data_rng(seed ^ 0x3);
    // Class prototypes: sums of random smooth 2-D bumps.
    let mut protos = Vec::with_capacity(classes);
    for _ in 0..classes {
        let mut proto = vec![0.0f32; d];
        let bumps = 3 + rng.next_below(4) as usize;
        for _ in 0..bumps {
            let cx = rng.next_f32() * side as f32;
            let cy = rng.next_f32() * side as f32;
            let sigma = 1.5 + rng.next_f32() * (side as f32 / 4.0);
            let amp = 0.4 + rng.next_f32() * 0.6;
            for yy in 0..side {
                for xx in 0..side {
                    let dx = xx as f32 - cx;
                    let dy = yy as f32 - cy;
                    let g = (-(dx * dx + dy * dy) / (2.0 * sigma * sigma)).exp();
                    proto[yy * side + xx] += amp * g;
                }
            }
        }
        for v in proto.iter_mut() {
            *v = v.clamp(0.0, 1.0);
        }
        protos.push(proto);
    }
    // Rows: prototype + smooth jitter + pixel noise, clipped to [0, 1].
    let rows = (0..n)
        .map(|i| {
            let c = i % classes;
            let shift = (rng.next_f32() - 0.5) * 2.0; // per-sample brightness
            let mut x = protos[c].clone();
            for v in x.iter_mut() {
                let eps = rng.gaussian() as f32 * noise;
                *v = (*v * (1.0 + 0.1 * shift) + eps).clamp(0.0, 1.0);
            }
            x
        })
        .collect();
    Dataset::new(format!("{name}(n={n},d={d})"), rows)
}

/// MNIST stand-in: 32×32 = 1024 dims (the paper pads MNIST to d = 1024),
/// 10 classes, sparse smooth strokes.
pub fn mnist_like(n: usize, seed: u64) -> Dataset {
    image_like("mnist_like", n, 32, 10, 0.08, seed)
}

/// CIFAR stand-in: 512 dims (the paper uses d = 512 features), 10 classes,
/// denser textures. 512 is not a square; generate 32×16 grid.
pub fn cifar_like(n: usize, seed: u64) -> Dataset {
    let mut ds = image_like("cifar_like", n, 32, 10, 0.15, seed ^ 0x9);
    // Crop each 1024-dim image to its top half -> d = 512.
    for r in ds.rows.iter_mut() {
        r.truncate(512);
    }
    Dataset::new(format!("cifar_like(n={n},d=512)"), ds.rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats;

    #[test]
    fn gaussian_moments_sane() {
        let ds = gaussian(200, 64, 1);
        assert_eq!(ds.dim, 64);
        let avg = stats::avg_norm_sq(&ds.rows);
        // E||x||^2 = d
        assert!((avg - 64.0).abs() < 8.0, "avg={avg}");
    }

    #[test]
    fn deterministic_in_seed() {
        assert_eq!(gaussian(5, 8, 42).rows, gaussian(5, 8, 42).rows);
        assert_ne!(gaussian(5, 8, 42).rows, gaussian(5, 8, 43).rows);
    }

    #[test]
    fn unbalanced_last_dim_dominates() {
        let ds = unbalanced(100, 256, 100.0, 7);
        let mean_last: f64 =
            ds.rows.iter().map(|r| r[255] as f64).sum::<f64>() / ds.len() as f64;
        assert!((mean_last - 100.0).abs() < 1.0, "mean_last={mean_last}");
        let mean_first: f64 =
            ds.rows.iter().map(|r| r[0] as f64).sum::<f64>() / ds.len() as f64;
        assert!(mean_first.abs() < 1.0);
    }

    #[test]
    fn sphere_rows_unit_norm() {
        let ds = unit_sphere(50, 128, 3);
        for r in &ds.rows {
            assert!((crate::linalg::norm(r) - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn image_like_in_pixel_range_and_clustered() {
        let ds = mnist_like(100, 5);
        assert_eq!(ds.dim, 1024);
        for r in &ds.rows {
            assert!(r.iter().all(|&v| (0.0..=1.0).contains(&v)));
        }
        // Same-class rows must be closer than cross-class rows on average.
        let d_same = crate::linalg::dist_sq(&ds.rows[0], &ds.rows[10]);
        let d_cross = crate::linalg::dist_sq(&ds.rows[0], &ds.rows[5]);
        assert!(
            d_same < d_cross,
            "same-class {d_same} should be < cross-class {d_cross}"
        );
    }

    #[test]
    fn cifar_like_dimension() {
        let ds = cifar_like(20, 1);
        assert_eq!(ds.dim, 512);
        assert_eq!(ds.len(), 20);
    }
}
