//! `dme` — the coordinator CLI.
//!
//! ```text
//! dme estimate  --dim 256 --clients 100 --protocol rotated:k=16 [--trials 20]
//!               [--data gaussian|unbalanced|sphere|mnist|cifar] [--backend pjrt]
//! dme kmeans    --data mnist --clients 10 --centers 10 --iters 10 --protocol varlen
//! dme power     --data cifar --clients 100 --iters 10 --protocol rotated:k=32
//! dme serve     --addr 0.0.0.0:7070 --workers 4 --dim 256 --protocol varlen --rounds 10
//!               [--decode-threads N]   (0 = all cores; any value is bit-identical)
//!               [--timeout-ms 30000]   (round barrier deadline; 0 = wait forever)
//!               [--fanout 16 --depth 2]  (single-process loopback tree instead of TCP)
//! dme aggregate --parent host:7070 --listen 0.0.0.0:7071 --children 16 --span 0:16
//!               --dim 256 --protocol varlen [--id N] [--decode-threads N] [--timeout-ms N]
//! dme worker    --connect host:7071 --dim 256 --protocol varlen [--points 100]
//! dme info
//! ```
//!
//! `--protocol` specs: `float32 | binary | klevel:k=16 | rotated:k=16 |
//! varlen[:k=17][,coder=huffman] | <any>:p=0.25` (client sampling).

use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use dme::apps::{kmeans, power_iteration};
use dme::cli::{parse_span, Args};
use dme::coordinator::aggregator::{spawn_local_tree, Aggregator, LocalTree};
use dme::coordinator::leader::Leader;
use dme::coordinator::metrics::format_tier_table;
use dme::coordinator::topology::Topology;
use dme::coordinator::transport::{TcpEndpoint, TcpHub};
use dme::coordinator::worker::{mean_update, Worker};
use dme::data::{synthetic, Dataset};
use dme::protocol::config::ProtocolConfig;
use dme::protocol::{run_round, RoundCtx};
use dme::runtime::{artifacts::Manifest, ComputeBackend, PjrtBackend};
use dme::stats;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let args = Args::from_env()?;
    match args.command() {
        Some("estimate") => cmd_estimate(&args),
        Some("kmeans") => cmd_kmeans(&args),
        Some("power") => cmd_power(&args),
        Some("serve") => cmd_serve(&args),
        Some("aggregate") => cmd_aggregate(&args),
        Some("worker") => cmd_worker(&args),
        Some("info") => cmd_info(&args),
        Some(other) => {
            bail!(
                "unknown command `{other}` (try: estimate kmeans power serve aggregate worker info)"
            )
        }
        None => {
            println!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "dme — Distributed Mean Estimation with Limited Communication (ICML 2017)

commands:
  estimate   one-shot distributed mean estimation; reports MSE & bits
  kmeans     distributed Lloyd's with quantized uplink (paper Fig. 2)
  power      distributed power iteration with quantized uplink (paper Fig. 3)
  serve      TCP leader (workers/aggregators connect), or a single-process
             loopback aggregation tree with --fanout/--depth
  aggregate  TCP aggregation-tier node: accepts its children's uploads,
             merges them exactly, forwards one PartialUpload upstream
  worker     TCP worker process (point --connect at a leader or aggregator)
  info       show compiled artifacts and available backends

see README.md for all flags.";

fn build_protocol(args: &Args, dim: usize) -> Result<Arc<dyn dme::Protocol>> {
    let spec = args.get("protocol", "rotated:k=16".to_string())?;
    let mut cfg = ProtocolConfig::parse(&spec, dim)?;
    if args.get("backend", "native".to_string())?.as_str() == "pjrt" {
        let backend: Arc<dyn ComputeBackend> =
            Arc::new(PjrtBackend::new().context("starting PJRT backend")?);
        cfg = cfg.with_backend(backend);
    }
    cfg.build()
}

fn load_data(args: &Args, n: usize, dim: usize, seed: u64) -> Result<Dataset> {
    let name = args.get("data", "gaussian".to_string())?;
    Ok(match name.as_str() {
        "gaussian" => synthetic::gaussian(n, dim, seed),
        "unbalanced" => synthetic::unbalanced(n, dim, 100.0, seed),
        "sphere" => synthetic::unit_sphere(n, dim, seed),
        "mnist" => synthetic::mnist_like(n, seed),
        "cifar" => synthetic::cifar_like(n, seed),
        path => Dataset::from_f32_file(path, dim)
            .with_context(|| format!("loading `{path}` as raw f32 rows of dim {dim}"))?,
    })
}

fn cmd_estimate(args: &Args) -> Result<()> {
    let dim = args.get("dim", 256usize)?;
    let n = args.get("clients", 100usize)?;
    let trials = args.get("trials", 20u64)?;
    let seed = args.get("seed", 42u64)?;
    let data = load_data(args, n, dim, seed)?;
    let dim = data.dim; // mnist/cifar override --dim
    let proto = build_protocol(args, dim)?;
    args.reject_unknown()?;

    let truth = stats::true_mean(&data.rows);
    let avg_sq = stats::avg_norm_sq(&data.rows);
    let mut err = stats::Running::new();
    let mut bits = stats::Running::new();
    for t in 0..trials {
        let ctx = RoundCtx::new(t, seed);
        let (est, b) = run_round(proto.as_ref(), &ctx, &data.rows)?;
        err.push(stats::sq_error(&est, &truth));
        bits.push(b as f64);
    }
    println!("protocol       : {}", proto.name());
    println!("data           : {} (n={n}, d={dim})", data.name);
    println!("trials         : {trials}");
    println!("MSE            : {:.6e} ± {:.1e}", err.mean(), err.ci95());
    if let Some(bound) = proto.mse_bound(n, avg_sq) {
        println!(
            "analytic bound : {:.6e}  (measured/bound = {:.3})",
            bound,
            err.mean() / bound.max(1e-300)
        );
    }
    println!("bits/client    : {:.1}", bits.mean() / n as f64);
    println!("bits/dim/client: {:.3}", bits.mean() / (n * dim) as f64);
    Ok(())
}

fn cmd_kmeans(args: &Args) -> Result<()> {
    let n_points = args.get("points", 1000usize)?;
    let dim = args.get("dim", 1024usize)?;
    let seed = args.get("seed", 17u64)?;
    let data = load_data(args, n_points, dim, seed)?;
    let proto = build_protocol(args, data.dim)?;
    let cfg = kmeans::KMeansConfig {
        n_centers: args.get("centers", 10usize)?,
        n_clients: args.get("clients", 10usize)?,
        iters: args.get("iters", 10usize)?,
        seed,
    };
    args.reject_unknown()?;
    println!(
        "distributed Lloyd's: {} on {} ({} clients, {} centers)",
        proto.name(),
        data.name,
        cfg.n_clients,
        cfg.n_centers
    );
    let result = kmeans::run(&data.rows, proto, &cfg)?;
    println!("{:>5} {:>16} {:>14} {:>12}", "iter", "objective", "cum kbits", "bits/dim");
    for r in &result.rounds {
        println!(
            "{:>5} {:>16.4} {:>14.1} {:>12.2}",
            r.iter,
            r.objective,
            r.cum_bits as f64 / 1e3,
            r.cum_bits as f64 / data.dim as f64
        );
    }
    println!("avg bits/dim/iter: {:.3}", result.bits_per_dim_per_iter);
    Ok(())
}

fn cmd_power(args: &Args) -> Result<()> {
    let n_points = args.get("points", 1000usize)?;
    let dim = args.get("dim", 512usize)?;
    let seed = args.get("seed", 29u64)?;
    let data = load_data(args, n_points, dim, seed)?;
    let proto = build_protocol(args, data.dim)?;
    let cfg = power_iteration::PowerConfig {
        n_clients: args.get("clients", 100usize)?,
        iters: args.get("iters", 10usize)?,
        seed,
    };
    args.reject_unknown()?;
    println!(
        "distributed power iteration: {} on {} ({} clients)",
        proto.name(),
        data.name,
        cfg.n_clients
    );
    let result = power_iteration::run(&data.rows, proto, &cfg)?;
    println!("{:>5} {:>16} {:>14} {:>12}", "iter", "eig distance", "cum kbits", "bits/dim");
    for r in &result.rounds {
        println!(
            "{:>5} {:>16.6} {:>14.1} {:>12.2}",
            r.iter,
            r.eig_dist,
            r.cum_bits as f64 / 1e3,
            r.cum_bits as f64 / data.dim as f64
        );
    }
    Ok(())
}

/// Drive `rounds` rounds of `leader`, print each outcome, then shut the
/// tree down and print the cumulative metrics — shared by the TCP and
/// loopback-tree branches of `dme serve`.
fn run_rounds(leader: &mut Leader, rounds: u64, dim: usize) -> Result<()> {
    for r in 0..rounds {
        let out = leader.round(r, dim as u32, &[])?;
        println!(
            "round {r}: {} frames, {:.1} kbit uplink, mean[0..4] = {:?}",
            out.n_frames,
            out.uplink_bits as f64 / 1e3,
            &out.means.first().map(|m| m[..m.len().min(4)].to_vec()).unwrap_or_default()
        );
    }
    leader.shutdown()?;
    println!("{}", leader.metrics().summary());
    Ok(())
}

/// Width of the streaming decode pools; 0 = one per core. Every value
/// produces bit-identical round outcomes.
fn resolve_decode_threads(args: &Args) -> Result<usize> {
    Ok(match args.get("decode-threads", 1usize)? {
        0 => std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1),
        n => n,
    })
}

fn cmd_serve(args: &Args) -> Result<()> {
    let addr = args.opt("addr");
    let n_workers = args.get("workers", 2usize)?;
    let dim = args.get("dim", 256usize)?;
    let rounds = args.get("rounds", 10u64)?;
    let seed = args.get("seed", 42u64)?;
    let decode_threads = resolve_decode_threads(args)?;
    // Round-barrier deadline; 0 keeps the default wait-forever behavior.
    let timeout_ms = args.get("timeout-ms", 0u64)?;
    let round_timeout = (timeout_ms > 0).then(|| Duration::from_millis(timeout_ms));
    // --fanout > 0 switches to the single-process loopback tree; --depth
    // only means anything there.
    let fanout = args.get("fanout", 0usize)?;
    let depth = args.opt("depth");
    let proto = build_protocol(args, dim)?;

    if fanout > 0 {
        if let Some(addr) = addr {
            bail!(
                "--addr {addr} makes no sense with --fanout: the tree runs entirely \
                 in-process over loopback (drop --addr, or drop --fanout for a TCP leader)"
            );
        }
        let data = load_data(args, n_workers, dim, seed)?;
        args.reject_unknown()?;
        if data.dim != dim {
            bail!("--data {} has dim {}, but --dim is {dim}", data.name, data.dim);
        }
        let depth: usize = match &depth {
            None => 2,
            Some(s) => s.parse().with_context(|| format!("--depth {s}"))?,
        };
        let topo = Topology::uniform(n_workers as u64, fanout, depth)?;
        println!("loopback tree: {} ({})", topo.describe(), proto.name());
        let shards: Vec<Vec<Vec<f32>>> = data.rows.into_iter().map(|row| vec![row]).collect();
        let (mut leader, tree) = spawn_local_tree(
            proto,
            shards,
            mean_update(),
            seed,
            &topo,
            decode_threads,
            round_timeout,
        )?;
        run_rounds(&mut leader, rounds, dim)?;
        let n_levels = tree.n_levels;
        let leader_bytes = leader.bytes_moved();
        let reports = tree.join()?;
        let tiers =
            LocalTree::tier_metrics(n_levels, leader.metrics(), leader_bytes, &reports);
        print!("{}", format_tier_table(&tiers));
        return Ok(());
    }

    args.reject_unknown()?;
    if let Some(depth) = depth {
        bail!("--depth {depth} only applies with --fanout (the loopback tree)");
    }
    let addr = addr.unwrap_or_else(|| "127.0.0.1:7070".to_string());
    println!(
        "leader: listening on {addr} for {n_workers} children ({}, {decode_threads} decode threads)",
        proto.name()
    );
    let hub = TcpHub::listen(&addr, n_workers)?;
    let mut leader = Leader::new(proto, Box::new(hub), seed).with_decode_threads(decode_threads);
    if let Some(t) = round_timeout {
        leader = leader.with_round_timeout(t);
    }
    run_rounds(&mut leader, rounds, dim)
}

fn cmd_aggregate(args: &Args) -> Result<()> {
    let parent = args.require("parent")?;
    let listen = args.require("listen")?;
    let children = args.get("children", 2usize)?;
    let span = parse_span(&args.require("span")?)?;
    let dim = args.get("dim", 256usize)?;
    let seed = args.get("seed", 42u64)?;
    // Default id: the span's first client. Sibling spans are disjoint, so
    // unlike a process id this cannot collide across hosts/containers.
    let agg_id = args.get("id", span.0)?;
    let decode_threads = resolve_decode_threads(args)?;
    let timeout_ms = args.get("timeout-ms", 0u64)?;
    let proto = build_protocol(args, dim)?;
    args.reject_unknown()?;
    println!(
        "aggregator {agg_id} [{}..{}): listening on {listen} for {children} children, \
         parent {parent} ({}, {decode_threads} decode threads)",
        span.0,
        span.1,
        proto.name()
    );
    // Accept our children first, then connect upstream — the parent's
    // accept loop is what gates round start, so ordering is safe.
    let hub = TcpHub::listen(&listen, children)?;
    let mut up = TcpEndpoint::connect(&parent)?;
    let mut agg = Aggregator::new(proto, seed, agg_id, span).with_decode_threads(decode_threads);
    if timeout_ms > 0 {
        agg = agg.with_round_timeout(Duration::from_millis(timeout_ms));
    }
    let report = agg.run(Box::new(hub), &mut up)?;
    println!("{}", report.metrics.summary());
    println!(
        "ingress {} bytes from {} children; egress accounted by the parent",
        report.up_bytes, children
    );
    Ok(())
}

fn cmd_worker(args: &Args) -> Result<()> {
    let addr = args.require("connect")?;
    let dim = args.get("dim", 256usize)?;
    let n_points = args.get("points", 100usize)?;
    let client_id = args.get("id", std::process::id() as u64)?;
    let seed = args.get("seed", 42u64)?;
    let proto = build_protocol(args, dim)?;
    let data = load_data(args, n_points, dim, seed ^ client_id)?;
    args.reject_unknown()?;
    println!("worker {client_id}: connecting to {addr} ({})", proto.name());
    let worker = Worker {
        client_id,
        shard: data.rows,
        protocol: proto,
        update: mean_update(),
        seed,
    };
    worker.run_tcp(&addr)
}

fn cmd_info(args: &Args) -> Result<()> {
    args.reject_unknown()?;
    println!("dme {} — Distributed Mean Estimation (ICML 2017)", env!("CARGO_PKG_VERSION"));
    let dir = Manifest::default_dir();
    match Manifest::load(&dir) {
        Ok(m) => {
            println!("artifacts      : {} entries in {}", m.len(), dir.display());
            println!("compiled dims  : {:?}", m.dims());
            match PjrtBackend::new() {
                Ok(_) => println!("pjrt backend   : available (CPU)"),
                Err(e) => println!("pjrt backend   : UNAVAILABLE ({e})"),
            }
        }
        Err(e) => println!("artifacts      : none ({e})"),
    }
    println!("native backend : available");
    println!("protocols      : float32 binary klevel rotated varlen qsgd (+wrappers p= q=)");
    Ok(())
}
