//! `dme` — the coordinator CLI.
//!
//! ```text
//! dme estimate  --dim 256 --clients 100 --protocol rotated:k=16 [--trials 20]
//!               [--data gaussian|unbalanced|sphere|mnist|cifar] [--backend pjrt]
//! dme kmeans    --data mnist --clients 10 --centers 10 --iters 10 --protocol varlen
//! dme power     --data cifar --clients 100 --iters 10 --protocol rotated:k=32
//! dme tune      --dim 1024 --clients 64 --budget-bits 4 [--mse-target 1e-2]
//!               [--analytic] [--json PATH]   (rate planner: frontier + chosen spec)
//! dme serve     --addr 0.0.0.0:7070 --workers 4 --dim 256 --protocol varlen --rounds 10
//!               [--decode-threads N]   (0 = all cores; any value is bit-identical)
//!               [--timeout-ms 30000]   (round barrier deadline; 0 = wait forever)
//!               [--transport reactor|threads]  (TCP hub; default reactor on Linux)
//!               [--fanout 16 --depth 2]  (single-process loopback tree instead of TCP)
//!               [--shards 4]   (root-child aggregators report one exact fold per
//!                               dimension range; bit-identical to unsharded)
//!               [--tenants 2]  (multiplex T concurrent sessions over one loopback
//!                               tree; prints the per-tenant table)
//!               [--auto-rate --budget-bits 4]  (rate controller picks + retunes the spec;
//!                               with --tenants the pool is water-filled across tenants
//!                               and each tenant gets its own controller)
//! dme simulate  --seed 7 --matrix [--json BENCH_scenarios.json]   (built-in CI matrix)
//! dme simulate  --seed 7 --workers 24 --dim 64 --fanout 3 --rounds 4 --timeout-ms 200
//!               --faults drop=0.2,straggle=0.1:80ms,flap=2 --data clustered
//!               [--protocol rotated:k=16] [--transport reactor|threads]
//!               (deterministic fault scenarios over the real stack, Lemma 8
//!                partial rounds; --seed is REQUIRED so every run replays)
//! dme aggregate --parent host:7070 --listen 0.0.0.0:7071 --children 16 --span 0:16
//!               --dim 256 --protocol varlen [--id N] [--decode-threads N] [--timeout-ms N]
//!               [--transport reactor|threads] [--connect-retries N]
//! dme worker    --connect host:7071 --dim 256 --protocol varlen [--points 100]
//!               [--connect-retries N]  (capped-backoff connect, default ≈5 s total)
//! dme info
//! ```
//!
//! `--protocol` specs: `float32 | binary | klevel:k=16 | rotated:k=16 |
//! varlen[:k=17][,coder=huffman] | <any>:p=0.25` (client sampling).

use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};

use dme::apps::{kmeans, power_iteration};
use dme::cli::{parse_span, Args};
use dme::coordinator::aggregator::{spawn_local_tree, spawn_mux_tree, Aggregator, LocalTree};
use dme::coordinator::leader::Leader;
use dme::coordinator::metrics::{
    format_tenant_table, format_tier_table, ExperimentMetrics, TenantMetrics,
};
use dme::coordinator::topology::Topology;
use dme::coordinator::transport::{DEFAULT_CONNECT_RETRIES, HubBinding, TcpEndpoint, Transport};
use dme::coordinator::worker::{mean_update, Worker};
use dme::data::{synthetic, Dataset};
use dme::protocol::config::{Kind, ProtocolConfig};
use dme::protocol::{run_round, RoundCtx};
use dme::rate::{
    Calibration, MultiTenantPlan, Objective, Plan, RateController, TenantDemand,
};
use dme::runtime::{artifacts::Manifest, ComputeBackend, PjrtBackend};
use dme::stats;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let args = Args::from_env()?;
    match args.command() {
        Some("estimate") => cmd_estimate(&args),
        Some("kmeans") => cmd_kmeans(&args),
        Some("power") => cmd_power(&args),
        Some("tune") => cmd_tune(&args),
        Some("serve") => cmd_serve(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("aggregate") => cmd_aggregate(&args),
        Some("worker") => cmd_worker(&args),
        Some("info") => cmd_info(&args),
        Some(other) => {
            bail!(
                "unknown command `{other}` \
                 (try: estimate kmeans power tune serve simulate aggregate worker info)"
            )
        }
        None => {
            println!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "dme — Distributed Mean Estimation with Limited Communication (ICML 2017)

commands:
  estimate   one-shot distributed mean estimation; reports MSE & bits
  kmeans     distributed Lloyd's with quantized uplink (paper Fig. 2)
  power      distributed power iteration with quantized uplink (paper Fig. 3)
  tune       rate planner: the predicted MSE-vs-bits frontier and the best
             spec under a bit budget (copy-pasteable into --protocol)
  serve      TCP leader (workers/aggregators connect), or a single-process
             loopback aggregation tree with --fanout/--depth; --shards S
             splits each root-child aggregator's report into S dimension
             ranges (bit-identical); --tenants T multiplexes T concurrent
             sessions over the one tree and prints the per-tenant table
             (--budget-bits water-fills the shared pool across tenants);
             --auto-rate lets the rate controller pick and retune the spec
             mid-session; --transport reactor|threads picks the TCP hub
             (default: the epoll reactor on Linux)
  simulate   deterministic fault scenarios (churn, stragglers, mid-round
             disconnects, flapping aggregators, non-IID data) over the real
             transports, with Lemma 8 partial-round recovery; --seed is
             required (every fault coin and client vector is keyed by it),
             --matrix runs the built-in CI matrix, --json writes the
             trajectory document (Linux only: the swarm driver is epoll)
  aggregate  TCP aggregation-tier node: accepts its children's uploads,
             merges them exactly, forwards one PartialUpload upstream
  worker     TCP worker process (point --connect at a leader or aggregator;
             --connect-retries N waits with capped backoff for the parent)
  info       show compiled artifacts and available backends

see README.md for all flags.";

fn build_protocol(args: &Args, dim: usize) -> Result<Arc<dyn dme::Protocol>> {
    let spec = args.get("protocol", "rotated:k=16".to_string())?;
    let mut cfg = ProtocolConfig::parse(&spec, dim)?;
    if args.get("backend", "native".to_string())?.as_str() == "pjrt" {
        let backend: Arc<dyn ComputeBackend> =
            Arc::new(PjrtBackend::new().context("starting PJRT backend")?);
        cfg = cfg.with_backend(backend);
    }
    cfg.build()
}

fn load_data(args: &Args, n: usize, dim: usize, seed: u64) -> Result<Dataset> {
    let name = args.get("data", "gaussian".to_string())?;
    Ok(match name.as_str() {
        "gaussian" => synthetic::gaussian(n, dim, seed),
        "unbalanced" => synthetic::unbalanced(n, dim, 100.0, seed),
        "sphere" => synthetic::unit_sphere(n, dim, seed),
        "mnist" => synthetic::mnist_like(n, seed),
        "cifar" => synthetic::cifar_like(n, seed),
        path => Dataset::from_f32_file(path, dim)
            .with_context(|| format!("loading `{path}` as raw f32 rows of dim {dim}"))?,
    })
}

fn cmd_estimate(args: &Args) -> Result<()> {
    let dim = args.get("dim", 256usize)?;
    let n = args.get("clients", 100usize)?;
    let trials = args.get("trials", 20u64)?;
    let seed = args.get("seed", 42u64)?;
    let data = load_data(args, n, dim, seed)?;
    let dim = data.dim; // mnist/cifar override --dim
    let proto = build_protocol(args, dim)?;
    args.reject_unknown()?;

    let truth = stats::true_mean(&data.rows);
    let avg_sq = stats::avg_norm_sq(&data.rows);
    let mut err = stats::Running::new();
    let mut bits = stats::Running::new();
    for t in 0..trials {
        let ctx = RoundCtx::new(t, seed);
        let (est, b) = run_round(proto.as_ref(), &ctx, &data.rows)?;
        err.push(stats::sq_error(&est, &truth));
        bits.push(b as f64);
    }
    println!("protocol       : {}", proto.name());
    println!("data           : {} (n={n}, d={dim})", data.name);
    println!("trials         : {trials}");
    println!("MSE            : {:.6e} ± {:.1e}", err.mean(), err.ci95());
    if let Some(bound) = proto.mse_bound(n, avg_sq) {
        println!(
            "analytic bound : {:.6e}  (measured/bound = {:.3})",
            bound,
            err.mean() / bound.max(1e-300)
        );
    }
    println!("bits/client    : {:.1}", bits.mean() / n as f64);
    println!("bits/dim/client: {:.3}", bits.mean() / (n * dim) as f64);
    Ok(())
}

fn cmd_kmeans(args: &Args) -> Result<()> {
    let n_points = args.get("points", 1000usize)?;
    let dim = args.get("dim", 1024usize)?;
    let seed = args.get("seed", 17u64)?;
    let data = load_data(args, n_points, dim, seed)?;
    let proto = build_protocol(args, data.dim)?;
    let cfg = kmeans::KMeansConfig {
        n_centers: args.get("centers", 10usize)?,
        n_clients: args.get("clients", 10usize)?,
        iters: args.get("iters", 10usize)?,
        seed,
    };
    args.reject_unknown()?;
    println!(
        "distributed Lloyd's: {} on {} ({} clients, {} centers)",
        proto.name(),
        data.name,
        cfg.n_clients,
        cfg.n_centers
    );
    let result = kmeans::run(&data.rows, proto, &cfg)?;
    println!("{:>5} {:>16} {:>14} {:>12}", "iter", "objective", "cum kbits", "bits/dim");
    for r in &result.rounds {
        println!(
            "{:>5} {:>16.4} {:>14.1} {:>12.2}",
            r.iter,
            r.objective,
            r.cum_bits as f64 / 1e3,
            r.cum_bits as f64 / data.dim as f64
        );
    }
    println!("avg bits/dim/iter: {:.3}", result.bits_per_dim_per_iter);
    Ok(())
}

fn cmd_power(args: &Args) -> Result<()> {
    let n_points = args.get("points", 1000usize)?;
    let dim = args.get("dim", 512usize)?;
    let seed = args.get("seed", 29u64)?;
    let data = load_data(args, n_points, dim, seed)?;
    let proto = build_protocol(args, data.dim)?;
    let cfg = power_iteration::PowerConfig {
        n_clients: args.get("clients", 100usize)?,
        iters: args.get("iters", 10usize)?,
        seed,
    };
    args.reject_unknown()?;
    println!(
        "distributed power iteration: {} on {} ({} clients)",
        proto.name(),
        data.name,
        cfg.n_clients
    );
    let result = power_iteration::run(&data.rows, proto, &cfg)?;
    println!("{:>5} {:>16} {:>14} {:>12}", "iter", "eig distance", "cum kbits", "bits/dim");
    for r in &result.rounds {
        println!(
            "{:>5} {:>16.6} {:>14.1} {:>12.2}",
            r.iter,
            r.eig_dist,
            r.cum_bits as f64 / 1e3,
            r.cum_bits as f64 / data.dim as f64
        );
    }
    Ok(())
}

/// Rate planner CLI: print the predicted MSE-vs-bits frontier, the
/// paper's per-family ordering at the budget, and the chosen spec —
/// optionally exporting the machine-readable plan (`--json PATH`, the
/// CI's BENCH_rate_frontier.json artifact).
fn cmd_tune(args: &Args) -> Result<()> {
    let dim = args.get("dim", 1024usize)?;
    let n = args.get("clients", 64usize)?;
    let budget_per_dim: f64 = args.get("budget-bits", 4.0f64)?;
    let seed = args.get("seed", 42u64)?;
    let mse_target = args.get_opt::<f64>("mse-target")?;
    let analytic = args.bool("analytic")?;
    let json_path = args.opt("json");
    args.reject_unknown()?;

    let objective = match mse_target {
        Some(t) => Objective::MinBits { max_mse: t },
        None => Objective::MinMse,
    };
    let mut plan = Plan::solve(budget_per_dim * dim as f64, dim, n, objective)?;
    if !analytic {
        // One-shot empirical calibration: probe rounds through the real
        // encode path, per spec (deterministic for a fixed seed).
        let mut cal = Calibration::new(seed);
        plan.calibrate(&mut cal)?;
    }

    println!(
        "rate plan: d={dim}, n={n}, budget {budget_per_dim} bits/dim \
         ({:.0} bits/client), {} candidates ({})",
        plan.budget_bits_per_client,
        plan.candidates.len(),
        if plan.calibrated { "calibrated" } else { "analytic bounds" },
    );
    let mut rows = Vec::new();
    for c in plan.frontier_specs() {
        let marker = match plan.chosen_spec() {
            Some(ch) if ch.spec == c.spec => " <= chosen",
            _ if c.bits_per_client <= plan.budget_bits_per_client => "",
            _ => " (over budget)",
        };
        rows.push(vec![
            c.spec.clone(),
            format!("{:.0}", c.bits_per_client),
            format!("{:.3}", c.bits_per_dim()),
            format!("{:.3e}{marker}", c.predicted_mse),
        ]);
    }
    dme::bench::print_table(
        "Pareto frontier (predicted MSE at avg ||X||^2 = 1)",
        &["spec", "bits/client", "bits/dim", "predicted MSE"],
        &rows,
    );
    // The paper's ordering at this budget (π_sb ≻ π_srk ≻ π_svk), now
    // over *every* enumerated family: derived from Kind::ALL so a new
    // protocol family can never be silently missing from this table.
    let mut fam = Vec::new();
    for kind in Kind::ALL {
        if let Some(best) = plan.best_in_kind(kind) {
            fam.push(vec![
                kind.name().to_string(),
                best.spec.clone(),
                format!("{:.3}", best.bits_per_dim()),
                format!("{:.3e}", best.predicted_mse),
            ]);
        }
    }
    dme::bench::print_table(
        "Family bests under the budget (one row per protocol family)",
        &["family", "best spec", "bits/dim", "predicted MSE"],
        &fam,
    );
    // Budget regimes: sweep a bits/dim ladder and collapse consecutive
    // budgets won by the same family — the planner's answer to "which
    // family should I run at *my* budget?". The winner at each rung is
    // the last feasible frontier point (min predicted MSE within budget).
    let ladder = [0.5, 0.75, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0, 8.0, 12.0, 16.0, 24.0, 32.0];
    let mut regimes: Vec<(String, f64, f64, String)> = Vec::new();
    for b in ladder {
        let budget = b * dim as f64;
        let Some(win) = plan.frontier_specs().filter(|c| c.bits_per_client <= budget).last()
        else {
            continue;
        };
        let family = win.cfg.kind.name().to_string();
        match regimes.last_mut() {
            Some((f, _, hi, spec)) if *f == family => {
                *hi = b;
                *spec = win.spec.clone();
            }
            _ => regimes.push((family, b, b, win.spec.clone())),
        }
    }
    let regime_rows: Vec<Vec<String>> = regimes
        .into_iter()
        .map(|(family, lo, hi, spec)| {
            let span = if lo == hi { format!("{lo}") } else { format!("{lo} .. {hi}") };
            vec![family, span, spec]
        })
        .collect();
    dme::bench::print_table(
        "Budget regimes (bits/dim ladder -> winning family)",
        &["family", "bits/dim regime", "winning spec at regime top"],
        &regime_rows,
    );
    match plan.chosen_spec() {
        Some(c) => {
            println!(
                "\nchosen spec : {}\n  predicted : {:.3e} MSE, {:.1} bits/client \
                 ({:.3} bits/dim)\n  replay    : dme estimate --dim {dim} --clients {n} \
                 --protocol '{}'",
                c.spec, c.predicted_mse, c.bits_per_client, c.bits_per_dim(), c.spec
            );
        }
        None => println!(
            "\nno spec satisfies the constraints (budget {budget_per_dim} bits/dim\
             {}); the frontier above shows what each extra bit buys",
            match mse_target {
                Some(t) => format!(", MSE target {t:.3e}"),
                None => String::new(),
            }
        ),
    }
    if let Some(path) = json_path {
        std::fs::write(&path, plan.to_json()).with_context(|| format!("writing {path}"))?;
        println!("plan written to {path}");
    }
    Ok(())
}

/// Drive `rounds` rounds of `leader`, print each outcome, then shut the
/// tree down and print the cumulative metrics — shared by the TCP and
/// loopback-tree branches of `dme serve`. With a rate controller
/// (`--auto-rate`), each round's realized bits and estimate feed back
/// into it, and a recommended switch is broadcast (tag-5 `SpecChange`)
/// before the next round.
fn run_rounds(
    leader: &mut Leader,
    rounds: u64,
    dim: usize,
    // Total clients behind the leader — NOT leader.n_workers(), which in
    // tree mode counts direct children (top-level aggregators) and would
    // inflate the controller's realized bits/client by the fan-in.
    n_clients: usize,
    mut controller: Option<RateController>,
) -> Result<()> {
    for r in 0..rounds {
        let out = leader.round(r, dim as u32, &[])?;
        println!(
            "round {r}: {} frames, {:.1} kbit uplink, mean[0..4] = {:?}",
            out.n_frames,
            out.uplink_bits as f64 / 1e3,
            &out.means.first().map(|m| m[..m.len().min(4)].to_vec()).unwrap_or_default()
        );
        if let Some(ctl) = controller.as_mut() {
            let est = out.means.first().map(|m| m.as_slice()).unwrap_or(&[]);
            // Partial rounds report p̂ < 1; the controller re-prices its
            // frontier with the Lemma 8 sampling model at that rate.
            let p_hat = leader.metrics().rounds.last().map(|m| m.participation).unwrap_or(1.0);
            if let Some(spec) =
                ctl.observe_with_participation(r, out.uplink_bits, n_clients, est, p_hat)
            {
                if r + 1 < rounds {
                    println!("  auto-rate: switching to `{spec}` from round {}", r + 1);
                    leader.switch_spec(&spec, r + 1)?;
                }
            }
        }
    }
    leader.shutdown()?;
    println!("{}", leader.metrics().summary());
    if let Some(ctl) = controller {
        let rows: Vec<Vec<String>> = ctl
            .history()
            .iter()
            .map(|s| {
                vec![
                    s.round.to_string(),
                    s.spec.clone(),
                    format!("{:.1}", s.bits_per_client),
                    format!("{:.2}", s.participation),
                    s.mse_proxy.map(|p| format!("{p:.3e}")).unwrap_or_else(|| "--".into()),
                    s.switched_to.clone().unwrap_or_default(),
                ]
            })
            .collect();
        dme::bench::print_table(
            "auto-rate trajectory (proxy = est. round MSE from estimate dispersion)",
            &["round", "spec", "bits/client", "p̂", "mse proxy", "switched to"],
            &rows,
        );
    }
    Ok(())
}

/// Width of the streaming decode pools; 0 = one per core. Every value
/// produces bit-identical round outcomes.
fn resolve_decode_threads(args: &Args) -> Result<usize> {
    Ok(match args.get("decode-threads", 1usize)? {
        0 => std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1),
        n => n,
    })
}

fn cmd_serve(args: &Args) -> Result<()> {
    let addr = args.opt("addr");
    let n_workers = args.get("workers", 2usize)?;
    let dim = args.get("dim", 256usize)?;
    let rounds = args.get("rounds", 10u64)?;
    let seed = args.get("seed", 42u64)?;
    let decode_threads = resolve_decode_threads(args)?;
    // Round-barrier deadline; 0 keeps the default wait-forever behavior.
    let timeout_ms = args.get("timeout-ms", 0u64)?;
    let round_timeout = (timeout_ms > 0).then(|| Duration::from_millis(timeout_ms));
    // --fanout > 0 switches to the single-process loopback tree; --depth
    // only means anything there.
    let fanout = args.get("fanout", 0usize)?;
    let depth = args.opt("depth");
    // --shards S splits each root-child aggregator's report into S
    // dimension ranges (independent exact folds the root concatenates
    // bit-identically); --tenants T multiplexes T concurrent sessions
    // over the one loopback tree.
    let dim_shards: u32 = args.get("shards", 1u32)?;
    if dim_shards > 1 && fanout == 0 {
        bail!(
            "--shards {dim_shards} needs --fanout: only an aggregator tier can shard the \
             dimension (flat workers upload full-width frames)"
        );
    }
    let tenants = args.get("tenants", 1usize)?;
    if tenants > 1 {
        if let Some(addr) = addr {
            bail!(
                "--addr {addr} makes no sense with --tenants: the multiplexed session runs \
                 entirely in-process over loopback"
            );
        }
        return cmd_serve_tenants(args, tenants);
    }
    // --auto-rate: the rate controller picks the starting spec under
    // --budget-bits (bits/dim) and may broadcast tag-5 spec switches
    // between rounds as realized bits come in.
    let auto_rate = args.bool("auto-rate")?;
    let controller = if auto_rate {
        if let Some(spec) = args.opt("protocol") {
            bail!(
                "--protocol {spec} conflicts with --auto-rate (the controller picks the \
                 spec; drop one of the two)"
            );
        }
        if args.opt("backend").is_some() {
            bail!("--backend is not available with --auto-rate (spec rebuilds are native)");
        }
        let budget: f64 = args
            .get_opt("budget-bits")?
            .ok_or_else(|| anyhow::anyhow!("--auto-rate needs --budget-bits (bits/dim)"))?;
        let plan = Plan::solve(budget * dim as f64, dim, n_workers, Objective::MinMse)?;
        let ctl = RateController::new(plan)?;
        println!(
            "auto-rate: budget {budget} bits/dim -> starting at `{}` \
             (predicted {:.3e} MSE, {:.1} bits/client)",
            ctl.active_spec().spec,
            ctl.active_spec().predicted_mse,
            ctl.active_spec().bits_per_client,
        );
        Some(ctl)
    } else {
        None
    };
    let proto = match &controller {
        Some(ctl) => {
            let mut cfg = ctl.active_spec().cfg.clone();
            cfg.dim = dim;
            cfg.build()?
        }
        None => build_protocol(args, dim)?,
    };

    if fanout > 0 {
        if let Some(addr) = addr {
            bail!(
                "--addr {addr} makes no sense with --fanout: the tree runs entirely \
                 in-process over loopback (drop --addr, or drop --fanout for a TCP leader)"
            );
        }
        let data = load_data(args, n_workers, dim, seed)?;
        args.reject_unknown()?;
        if data.dim != dim {
            bail!("--data {} has dim {}, but --dim is {dim}", data.name, data.dim);
        }
        let depth: usize = match &depth {
            None => 2,
            Some(s) => s.parse().with_context(|| format!("--depth {s}"))?,
        };
        let topo = Topology::uniform(n_workers as u64, fanout, depth)?
            .with_dim_shards(dim_shards)?;
        println!("loopback tree: {} ({})", topo.describe(), proto.name());
        let shards: Vec<Vec<Vec<f32>>> = data.rows.into_iter().map(|row| vec![row]).collect();
        let (mut leader, tree) = spawn_local_tree(
            proto,
            shards,
            mean_update(),
            seed,
            &topo,
            decode_threads,
            round_timeout,
        )?;
        run_rounds(&mut leader, rounds, dim, n_workers, controller)?;
        let n_levels = tree.n_levels;
        let leader_bytes = leader.bytes_moved();
        let reports = tree.join()?;
        let tiers =
            LocalTree::tier_metrics(n_levels, leader.metrics(), leader_bytes, &reports);
        print!("{}", format_tier_table(&tiers));
        return Ok(());
    }

    let transport: Transport = args.get("transport", Transport::default())?;
    args.reject_unknown()?;
    if let Some(depth) = depth {
        bail!("--depth {depth} only applies with --fanout (the loopback tree)");
    }
    let addr = addr.unwrap_or_else(|| "127.0.0.1:7070".to_string());
    println!(
        "leader: listening on {addr} for {n_workers} children \
         ({}, {decode_threads} decode threads, {transport} transport)",
        proto.name()
    );
    let hub = HubBinding::bind(transport, &addr)?.accept(n_workers)?;
    let mut leader = Leader::new(proto, hub, seed).with_decode_threads(decode_threads);
    if let Some(t) = round_timeout {
        leader = leader.with_round_timeout(t);
    }
    run_rounds(&mut leader, rounds, dim, n_workers, controller)
}

/// `dme serve --tenants T`: T concurrent sessions multiplexed over one
/// loopback tree (or a flat loopback cluster when `--fanout` is absent).
/// With `--budget-bits` the multi-tenant allocator water-fills the
/// shared uplink pool over the tenants' Pareto frontiers to pick each
/// tenant's starting spec; `--auto-rate` additionally gives each tenant
/// its own `RateController`, retuning within its allocated share.
/// Prints the per-tenant table (bytes, realized vs allocated bits, MSE
/// proxy) and the per-tier rollup.
fn cmd_serve_tenants(args: &Args, tenants: usize) -> Result<()> {
    let n_workers = args.get("workers", 2usize)?;
    let dim = args.get("dim", 256usize)?;
    let rounds = args.get("rounds", 10u64)?;
    let seed = args.get("seed", 42u64)?;
    let decode_threads = resolve_decode_threads(args)?;
    let timeout_ms = args.get("timeout-ms", 0u64)?;
    let round_timeout = (timeout_ms > 0).then(|| Duration::from_millis(timeout_ms));
    let fanout = args.get("fanout", 0usize)?;
    let depth: usize = match args.opt("depth") {
        None => 2,
        Some(s) => s.parse().with_context(|| format!("--depth {s}"))?,
    };
    let dim_shards: u32 = args.get("shards", 1u32)?;
    let auto_rate = args.bool("auto-rate")?;
    let budget = args.get_opt::<f64>("budget-bits")?;
    ensure!(tenants <= u16::MAX as usize, "--tenants caps at {}", u16::MAX);
    if auto_rate && budget.is_none() {
        bail!("--auto-rate needs --budget-bits (bits/dim, the shared tenant pool)");
    }
    if budget.is_some() {
        if let Some(spec) = args.opt("protocol") {
            bail!(
                "--protocol {spec} conflicts with --budget-bits under --tenants (the \
                 allocator picks each tenant's spec; drop one of the two)"
            );
        }
        if args.opt("backend").is_some() {
            bail!("--backend is not available with the tenant allocator (spec builds are native)");
        }
    }

    // Tenant wire sessions 1..=T (0 is the single-tenant root session).
    let sessions: Vec<u16> = (1..=tenants as u16).collect();
    let mut tenant_protos: Vec<(u16, Arc<dyn dme::Protocol>)> = Vec::with_capacity(tenants);
    let mut controllers: Vec<Option<RateController>> = Vec::with_capacity(tenants);
    // Planner view per tenant: (allocated bits/client, predicted MSE).
    let mut planned: Vec<(f64, f64)> = Vec::with_capacity(tenants);
    if let Some(b) = budget {
        let demands: Vec<TenantDemand> = sessions
            .iter()
            .map(|&s| TenantDemand { session: s, dim, n: n_workers, weight: 1.0 })
            .collect();
        let pool = b * dim as f64;
        let mt = MultiTenantPlan::solve(pool, &demands)?;
        println!(
            "tenant pool: {b} bits/dim shared by {tenants} tenants -> \
             {:.0}/{:.0} bits/client allocated",
            mt.spent_bits_per_client, pool
        );
        for &s in &sessions {
            let alloc = mt.for_session(s).expect("every demanded session is allocated");
            println!(
                "  tenant {s}: `{}` (predicted {:.3e} MSE, {:.1} bits/client)",
                alloc.spec.spec, alloc.spec.predicted_mse, alloc.spec.bits_per_client
            );
            tenant_protos.push((s, alloc.spec.cfg.build()?));
            planned.push((alloc.spec.bits_per_client, alloc.spec.predicted_mse));
            controllers.push(if auto_rate {
                // Each tenant retunes inside its own allocated share.
                let solo =
                    Plan::solve(alloc.spec.bits_per_client, dim, n_workers, Objective::MinMse)?;
                Some(RateController::new(solo)?)
            } else {
                None
            });
        }
    } else {
        for &s in &sessions {
            tenant_protos.push((s, build_protocol(args, dim)?));
            planned.push((0.0, 0.0));
            controllers.push(None);
        }
    }

    let data = load_data(args, n_workers, dim, seed)?;
    args.reject_unknown()?;
    if data.dim != dim {
        bail!("--data {} has dim {}, but --dim is {dim}", data.name, data.dim);
    }
    let topo = if fanout > 0 {
        Topology::uniform(n_workers as u64, fanout, depth)?.with_dim_shards(dim_shards)?
    } else {
        // Flat multiplexed cluster: every MuxWorker reports to the root.
        Topology::uniform(n_workers as u64, n_workers.max(1), 1)?
    };
    println!("multiplexed loopback tree: {} x {tenants} tenants", topo.describe());
    let shards: Vec<Vec<Vec<f32>>> = data.rows.into_iter().map(|row| vec![row]).collect();
    let (mux, mut leaders, tree) = spawn_mux_tree(
        &tenant_protos,
        shards,
        mean_update(),
        seed,
        &topo,
        decode_threads,
        round_timeout,
    )?;
    // One driver thread interleaves the tenants' rounds; the mux parks
    // any envelope that arrives while another tenant holds the barrier.
    for r in 0..rounds {
        for (i, leader) in leaders.iter_mut().enumerate() {
            let out = leader.round(r, dim as u32, &[])?;
            println!(
                "round {r} tenant {}: {} frames, {:.1} kbit uplink",
                sessions[i],
                out.n_frames,
                out.uplink_bits as f64 / 1e3
            );
            if let Some(ctl) = controllers[i].as_mut() {
                let est = out.means.first().map(|m| m.as_slice()).unwrap_or(&[]);
                if let Some(spec) = ctl.observe(r, out.uplink_bits, n_workers, est) {
                    if r + 1 < rounds {
                        println!(
                            "  tenant {} auto-rate: switching to `{spec}` from round {}",
                            sessions[i],
                            r + 1
                        );
                        leader.switch_spec(&spec, r + 1)?;
                    }
                }
            }
        }
    }
    for leader in leaders.iter_mut() {
        leader.shutdown()?;
    }
    let rows: Vec<TenantMetrics> = leaders
        .iter()
        .enumerate()
        .map(|(i, leader)| {
            let (down, up) = mux.session_bytes(sessions[i]);
            TenantMetrics {
                session: sessions[i],
                spec: leader.protocol_name(),
                rounds: leader.metrics().rounds.len(),
                down_bytes: down,
                up_bytes: up,
                realized_bits: leader.metrics().avg_bits_per_round(),
                allocated_bits: planned[i].0 * n_workers as f64,
                mse_proxy: planned[i].1,
            }
        })
        .collect();
    print!("{}", format_tenant_table(&rows));
    // Per-tier rollup: the root row carries every tenant's rounds and
    // the hub's full (all-tenant) byte tally.
    let mut root_metrics = ExperimentMetrics::default();
    for leader in &leaders {
        for m in &leader.metrics().rounds {
            root_metrics.push(m.clone());
        }
    }
    let n_levels = tree.n_levels;
    let reports = tree.join()?;
    let tiers = LocalTree::tier_metrics(n_levels, &root_metrics, mux.bytes_moved(), &reports);
    print!("{}", format_tier_table(&tiers));
    Ok(())
}

/// `dme simulate`: deterministic fault scenarios over the real stack
/// (see `dme::scenario`). `--seed` is *required*: every fault coin and
/// client vector is keyed by it, so a scenario without a seed could
/// never replay — exactly what the flag contract forbids.
#[cfg(target_os = "linux")]
fn cmd_simulate(args: &Args) -> Result<()> {
    use dme::scenario::{self, DataPlan, FaultPlan, ScenarioSpec};
    let seed: u64 = args
        .require("seed")
        .context(
            "dme simulate needs --seed: fault plans and client data are keyed by it, \
             and an unseeded scenario could not replay",
        )?
        .parse()
        .map_err(|e| anyhow::anyhow!("--seed must be an unsigned integer: {e}"))?;
    let matrix = args.bool("matrix")?;
    let json_path = args.opt("json");
    let specs = if matrix {
        scenario::builtin_matrix(seed)?
    } else {
        let timeout_ms = args.get("timeout-ms", 200u64)?;
        ensure!(timeout_ms > 0, "scenarios need a barrier deadline (--timeout-ms > 0)");
        let faults_spec = args.get("faults", String::new())?;
        vec![ScenarioSpec {
            name: args.get("name", "adhoc".to_string())?,
            protocol: args.get("protocol", "rotated:k=16".to_string())?,
            n_clients: args.get("workers", 16usize)?,
            dim: args.get("dim", 64usize)?,
            fanout: args.get("fanout", 0usize)?,
            rounds: args.get("rounds", 5u64)?,
            timeout: Duration::from_millis(timeout_ms),
            transport: args.get("transport", Transport::default())?,
            decode_threads: resolve_decode_threads(args)?,
            faults: FaultPlan::parse(&faults_spec, seed)?,
            data: DataPlan::parse(&args.get("data", "iid".to_string())?)?,
            seed,
        }]
    };
    args.reject_unknown()?;
    let trajectories = scenario::run_matrix(&specs)?;
    for t in &trajectories {
        let rows: Vec<Vec<String>> = t
            .rows
            .iter()
            .zip(&t.wall_ms)
            .map(|(r, &wall)| {
                vec![
                    r.round.to_string(),
                    format!("{:.2}", r.participation),
                    r.duplicate_uploads.to_string(),
                    format!("{:.3e}", r.sq_error),
                    format!("{:.3e}", r.predicted_mse),
                    format!("{:.1}", r.uplink_bits as f64 / 1e3),
                    format!("{wall:.0}"),
                ]
            })
            .collect();
        dme::bench::print_table(
            &format!(
                "scenario {} ({}, n={}, fanout={}, {}, data={}, faults={})",
                t.name, t.protocol, t.n_clients, t.fanout, t.transport, t.data, t.faults
            ),
            &["round", "p̂", "dups", "sq error", "Lemma 8 pred", "kbit up", "wall ms"],
            &rows,
        );
        println!(
            "  mean p̂ {:.2}; measured MSE {:.3e} vs {:.3e} predicted (slack {}x)",
            t.mean_participation(),
            t.mean_measured_mse(),
            t.mean_predicted_mse(),
            t.slack
        );
        t.check_slack()?;
    }
    if let Some(path) = json_path {
        scenario::write_scenarios_json(&path, &trajectories)?;
        println!("trajectories written to {path}");
    }
    Ok(())
}

#[cfg(not(target_os = "linux"))]
fn cmd_simulate(_args: &Args) -> Result<()> {
    bail!("dme simulate needs Linux: the scenario engine drives the epoll swarm client driver")
}

fn cmd_aggregate(args: &Args) -> Result<()> {
    let parent = args.require("parent")?;
    let listen = args.require("listen")?;
    let children = args.get("children", 2usize)?;
    let span = parse_span(&args.require("span")?)?;
    let dim = args.get("dim", 256usize)?;
    let seed = args.get("seed", 42u64)?;
    // Default id: the span's first client. Sibling spans are disjoint, so
    // unlike a process id this cannot collide across hosts/containers.
    let agg_id = args.get("id", span.0)?;
    let decode_threads = resolve_decode_threads(args)?;
    let timeout_ms = args.get("timeout-ms", 0u64)?;
    let transport: Transport = args.get("transport", Transport::default())?;
    let retries = args.get("connect-retries", DEFAULT_CONNECT_RETRIES)?;
    let proto = build_protocol(args, dim)?;
    args.reject_unknown()?;
    println!(
        "aggregator {agg_id} [{}..{}): listening on {listen} for {children} children, \
         parent {parent} ({}, {decode_threads} decode threads, {transport} transport)",
        span.0,
        span.1,
        proto.name()
    );
    // Accept our children first, then connect upstream — the parent's
    // accept loop is what gates round start, so ordering is safe. The
    // upstream connect retries with backoff so a tree can be launched
    // leaves-first without racing the parent's bind.
    let hub = HubBinding::bind(transport, &listen)?.accept(children)?;
    let mut up = TcpEndpoint::connect_with_backoff(&parent, retries)?;
    let mut agg = Aggregator::new(proto, seed, agg_id, span).with_decode_threads(decode_threads);
    if timeout_ms > 0 {
        agg = agg.with_round_timeout(Duration::from_millis(timeout_ms));
    }
    let report = agg.run(hub, &mut up)?;
    println!("{}", report.metrics.summary());
    println!(
        "ingress {} bytes from {} children; egress accounted by the parent",
        report.up_bytes, children
    );
    Ok(())
}

fn cmd_worker(args: &Args) -> Result<()> {
    let addr = args.require("connect")?;
    let dim = args.get("dim", 256usize)?;
    let n_points = args.get("points", 100usize)?;
    let client_id = args.get("id", std::process::id() as u64)?;
    let seed = args.get("seed", 42u64)?;
    let retries = args.get("connect-retries", DEFAULT_CONNECT_RETRIES)?;
    let proto = build_protocol(args, dim)?;
    let data = load_data(args, n_points, dim, seed ^ client_id)?;
    args.reject_unknown()?;
    println!("worker {client_id}: connecting to {addr} ({})", proto.name());
    let worker = Worker {
        client_id,
        shard: data.rows,
        protocol: proto,
        update: mean_update(),
        seed,
    };
    worker.run_tcp_with_retries(&addr, retries)
}

fn cmd_info(args: &Args) -> Result<()> {
    args.reject_unknown()?;
    println!("dme {} — Distributed Mean Estimation (ICML 2017)", env!("CARGO_PKG_VERSION"));
    let dir = Manifest::default_dir();
    match Manifest::load(&dir) {
        Ok(m) => {
            println!("artifacts      : {} entries in {}", m.len(), dir.display());
            println!("compiled dims  : {:?}", m.dims());
            match PjrtBackend::new() {
                Ok(_) => println!("pjrt backend   : available (CPU)"),
                Err(e) => println!("pjrt backend   : UNAVAILABLE ({e})"),
            }
        }
        Err(e) => println!("artifacts      : none ({e})"),
    }
    println!("native backend : available");
    println!("protocols      : float32 binary klevel rotated varlen qsgd (+wrappers p= q=)");
    Ok(())
}
