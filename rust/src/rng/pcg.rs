//! PCG-XSH-RR 64/32: small, fast, statistically strong PRNG
//! (O'Neill, "PCG: A Family of Simple Fast Space-Efficient Statistically
//! Good Algorithms for Random Number Generation", 2014).
//!
//! We use the 64-bit-state / 32-bit-output member and compose two outputs
//! for `next_u64`. Gaussian variates come from Box–Muller with a cached
//! second sample.

/// PCG-XSH-RR 64/32 generator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u64,
    inc: u64,
    /// Cached second Box–Muller Gaussian sample.
    gauss_spare: Option<f64>,
}

const PCG_MULT: u64 = 6_364_136_223_846_793_005;
const PCG_DEFAULT_INC: u64 = 1_442_695_040_888_963_407;

impl Pcg64 {
    /// Create a generator from a 64-bit seed (default stream).
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, PCG_DEFAULT_INC >> 1)
    }

    /// Create a generator with an explicit stream selector.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let inc = (stream << 1) | 1;
        let mut rng = Pcg64 { state: 0, inc, gauss_spare: None };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Next 32 uniform random bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64 uniform random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)` with 24 bits of mantissa randomness (f32).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in `[0, 1)` with 53 bits of mantissa randomness (f64).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Unbiased uniform integer in `[0, bound)` (Lemire rejection method).
    pub fn next_below(&mut self, bound: u32) -> u32 {
        assert!(bound > 0, "next_below(0)");
        loop {
            let x = self.next_u32();
            let m = (x as u64).wrapping_mul(bound as u64);
            let lo = m as u32;
            if lo >= bound || lo >= bound.wrapping_neg() % bound {
                return (m >> 32) as u32;
            }
        }
    }

    /// Bernoulli(p) coin.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Rademacher variate: ±1 with probability 1/2 each.
    #[inline]
    pub fn rademacher(&mut self) -> f32 {
        if self.next_u32() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Standard normal via Box–Muller (caches the paired sample).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // u1 in (0, 1] to keep ln finite.
        let u1 = 1.0 - self.next_f64();
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Fill `dst` with iid uniforms in `[0, 1)`.
    pub fn fill_uniform_f32(&mut self, dst: &mut [f32]) {
        for v in dst {
            *v = self.next_f32();
        }
    }

    /// Fill `dst` with iid standard normals.
    pub fn fill_gaussian_f32(&mut self, dst: &mut [f32]) {
        for v in dst {
            *v = self.gaussian() as f32;
        }
    }

    /// Fill `dst` with iid Rademacher ±1 entries (the diagonal of `D`).
    pub fn fill_rademacher(&mut self, dst: &mut [f32]) {
        // Draw 32 signs per u32 for speed; this is on the round hot path.
        let mut i = 0;
        while i < dst.len() {
            let mut bits = self.next_u32();
            let n = 32.min(dst.len() - i);
            for v in &mut dst[i..i + n] {
                *v = if bits & 1 == 0 { 1.0 } else { -1.0 };
                bits >>= 1;
            }
            i += n;
        }
    }

    /// Sample `m` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, m: usize) -> Vec<usize> {
        assert!(m <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..m {
            let j = i + self.next_below((n - i) as u32) as usize;
            idx.swap(i, j);
        }
        idx.truncate(m);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Pcg64::new(123);
        let mut b = Pcg64::new(123);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same <= 1);
    }

    #[test]
    fn uniform_f32_in_range_and_roughly_uniform() {
        let mut rng = Pcg64::new(9);
        let n = 100_000;
        let mut sum = 0.0f64;
        for _ in 0..n {
            let x = rng.next_f32();
            assert!((0.0..1.0).contains(&x));
            sum += x as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn next_below_unbiased_small_bound() {
        let mut rng = Pcg64::new(17);
        let mut counts = [0u32; 3];
        for _ in 0..30_000 {
            counts[rng.next_below(3) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "counts={counts:?}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Pcg64::new(31);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let z = rng.gaussian();
            s1 += z;
            s2 += z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn rademacher_fill_is_balanced() {
        let mut rng = Pcg64::new(41);
        let mut buf = vec![0.0f32; 100_000];
        rng.fill_rademacher(&mut buf);
        let pos = buf.iter().filter(|&&x| x == 1.0).count();
        assert!(buf.iter().all(|&x| x == 1.0 || x == -1.0));
        assert!((pos as f64 - 50_000.0).abs() < 1500.0);
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut rng = Pcg64::new(5);
        let idx = rng.sample_indices(100, 30);
        assert_eq!(idx.len(), 30);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 30);
        assert!(idx.iter().all(|&i| i < 100));
    }
}
