//! Deterministic random number generation with explicit public/private
//! randomness streams.
//!
//! The paper's model (§1.2) distinguishes **public randomness** (shared by
//! all clients and the server — used for the rotation matrix `R = HD`) from
//! **private randomness** (per-client — used for stochastic rounding and
//! sampling coins). We realize both from a single experiment seed by
//! domain-separated key derivation, so every run is exactly reproducible:
//!
//! * public stream of round `t`: `Pcg64::new(mix(seed, PUBLIC_TAG, t))`
//! * private stream of client `i` in round `t`:
//!   `Pcg64::new(mix(seed, PRIVATE_TAG, t, i))`
//!
//! No external `rand` crate: PCG-XSH-RR 64/32 (O'Neill 2014) plus
//! SplitMix64 for seeding/mixing, and Box–Muller for Gaussians.

mod pcg;

pub use pcg::Pcg64;

/// Domain tag for public (shared) randomness streams.
pub const PUBLIC_TAG: u64 = 0x9e37_79b9_7f4a_7c15;
/// Domain tag for private (per-client) randomness streams.
pub const PRIVATE_TAG: u64 = 0xbf58_476d_1ce4_e5b9;
/// Domain tag for the correlated-quantization offset stream: shared
/// randomness that all clients of a round derive identically (from the
/// `shared_seed` the wire's `RoundStart` carries) and then *partition*
/// among themselves, so their stochastic-rounding offsets are
/// anti-correlated rather than independent (arXiv 2203.04925).
pub const CORRELATED_TAG: u64 = 0x94d0_49bb_1331_11eb;

/// SplitMix64 step: the standard 64-bit finalizer used both as a tiny PRNG
/// and as the mixing function for key derivation.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Mix an arbitrary list of words into a single 64-bit key
/// (domain-separated seed derivation).
pub fn mix(words: &[u64]) -> u64 {
    let mut state = 0x853c_49e6_748f_ea9b;
    let mut out = 0;
    for &w in words {
        state ^= w;
        out = splitmix64(&mut state);
    }
    out
}

thread_local! {
    /// Per-thread count of public-stream derivations — test
    /// instrumentation for the round-session guarantee that the shared
    /// rotation is sampled exactly once per round (see
    /// [`crate::protocol::Protocol::prepare`]).
    static PUBLIC_STREAM_DRAWS: std::cell::Cell<u64> = const { std::cell::Cell::new(0) };
}

/// How many public streams *this thread* has derived so far. Tests diff
/// this counter around a round to assert the rotation is sampled exactly
/// once per round; thread-local so concurrent tests don't interfere.
pub fn public_stream_draws() -> u64 {
    PUBLIC_STREAM_DRAWS.with(|c| c.get())
}

/// The shared (public) stream for round `round` under experiment `seed`.
/// Every party can derive this identically — it plays the role of the
/// shared random seed footnote 1 of the paper describes.
pub fn public_stream(seed: u64, round: u64) -> Pcg64 {
    PUBLIC_STREAM_DRAWS.with(|c| c.set(c.get() + 1));
    Pcg64::new(mix(&[seed, PUBLIC_TAG, round]))
}

/// The private stream of `client` for round `round`. Only used client-side;
/// the server never observes it (it only sees the transmitted bits).
pub fn private_stream(seed: u64, round: u64, client: u64) -> Pcg64 {
    Pcg64::new(mix(&[seed, PRIVATE_TAG, round, client]))
}

/// The round's shared correlated-offset stream: every client derives it
/// identically from the `shared_seed` carried in `RoundStart`, then takes
/// its own stratum of the partition (see
/// [`crate::protocol::correlated`]). Deliberately *not* routed through
/// [`public_stream`]: it must not perturb the public draw counter the
/// rotation-sampled-exactly-once tests observe, and the server never
/// needs it (decode only sees the transmitted bins).
pub fn correlated_stream(seed: u64, round: u64) -> Pcg64 {
    Pcg64::new(mix(&[seed, CORRELATED_TAG, round]))
}

/// Bits of a combined stream id reserved for the client id (the low
/// field). See [`client_slot_stream_id`].
pub const CLIENT_ID_BITS: u32 = 32;
/// Bits of a combined stream id reserved for the slot index (the middle
/// field, above the client id).
pub const SLOT_BITS: u32 = 16;
/// Bits of a combined stream id reserved for the session (tenant) id —
/// the high field. A `u16` session id always fits by construction, so
/// only the client and slot fields can overflow.
pub const SESSION_BITS: u32 = 64 - CLIENT_ID_BITS - SLOT_BITS;

/// Pack a session id, a client id, and an upload slot index into a single
/// private-stream id with disjoint bit fields, so every
/// `(session, client, slot)` triple owns a distinct randomness stream.
/// Without the session field, two tenants' clients with equal client ids
/// would share private rounding noise — a cross-tenant correctness and
/// privacy bug. The packing is *checked*: a field that overflows its
/// budget is an explicit error, never a silent collision that would merge
/// two streams.
pub fn client_slot_stream_id(session: u16, client: u64, slot: u64) -> anyhow::Result<u64> {
    anyhow::ensure!(
        client < 1u64 << CLIENT_ID_BITS,
        "client id {client} does not fit the {CLIENT_ID_BITS}-bit stream-id field; \
         ids this large would alias another client's private randomness"
    );
    anyhow::ensure!(
        slot < 1u64 << SLOT_BITS,
        "slot index {slot} does not fit the {SLOT_BITS}-bit stream-id field"
    );
    // session: u16 == SESSION_BITS bits; cannot overflow by construction.
    const _: () = assert!(SESSION_BITS == 16);
    Ok(client | (slot << CLIENT_ID_BITS) | ((session as u64) << (CLIENT_ID_BITS + SLOT_BITS)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_values_differ_and_are_deterministic() {
        let mut a = 1u64;
        let mut b = 1u64;
        let x1 = splitmix64(&mut a);
        let x2 = splitmix64(&mut a);
        assert_ne!(x1, x2);
        assert_eq!(splitmix64(&mut b), x1);
    }

    #[test]
    fn mix_is_order_sensitive() {
        assert_ne!(mix(&[1, 2]), mix(&[2, 1]));
        assert_ne!(mix(&[1]), mix(&[1, 0]));
    }

    #[test]
    fn public_stream_is_shared_private_is_not() {
        let mut s1 = public_stream(7, 3);
        let mut s2 = public_stream(7, 3);
        assert_eq!(s1.next_u64(), s2.next_u64());
        let mut p1 = private_stream(7, 3, 0);
        let mut p2 = private_stream(7, 3, 1);
        assert_ne!(p1.next_u64(), p2.next_u64());
    }

    #[test]
    fn correlated_stream_is_shared_and_does_not_count_as_public_draw() {
        let before = public_stream_draws();
        let mut a = correlated_stream(7, 3);
        let mut b = correlated_stream(7, 3);
        assert_eq!(public_stream_draws(), before, "must not perturb the public draw counter");
        assert_eq!(a.next_u64(), b.next_u64());
        // Domain-separated from the public and private streams of the
        // same (seed, round), and round-scoped.
        let mut p = public_stream(7, 3);
        let mut q = private_stream(7, 3, 0);
        let mut c = correlated_stream(7, 4);
        let x = correlated_stream(7, 3).next_u64();
        assert_ne!(x, p.next_u64());
        assert_ne!(x, q.next_u64());
        assert_ne!(x, c.next_u64());
    }

    #[test]
    fn streams_change_across_rounds() {
        let mut a = public_stream(7, 0);
        let mut b = public_stream(7, 1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn client_slot_stream_ids_are_injective() {
        // Distinct (session, client, slot) triples map to distinct ids —
        // including the triples an unchecked `client | slot << k` packing
        // would collide on (client ids with bits at or above position k),
        // and equal (client, slot) pairs under different sessions.
        let mut seen = std::collections::HashSet::new();
        for session in [0u16, 1, u16::MAX] {
            for client in [0u64, 1, 2, (1 << CLIENT_ID_BITS) - 1] {
                for slot in [0u64, 1, 2, (1 << SLOT_BITS) - 1] {
                    assert!(
                        seen.insert(client_slot_stream_id(session, client, slot).unwrap()),
                        "collision at session={session} client={client} slot={slot}"
                    );
                }
            }
        }
    }

    #[test]
    fn client_slot_stream_id_overflow_is_an_error() {
        // The original regression case: an overflowing client id used to
        // silently alias (client 0, slot 1); still rejected at the new
        // (narrower) field boundary, as is an overflowing slot.
        assert!(client_slot_stream_id(0, 1 << CLIENT_ID_BITS, 0).is_err());
        assert!(client_slot_stream_id(0, 1 << 40, 0).is_err());
        assert!(client_slot_stream_id(0, 0, 1 << SLOT_BITS).is_err());
        // Boundary values are fine, for every session id.
        assert_eq!(client_slot_stream_id(0, 0, 0).unwrap(), 0);
        assert!(client_slot_stream_id(
            u16::MAX,
            (1 << CLIENT_ID_BITS) - 1,
            (1 << SLOT_BITS) - 1
        )
        .is_ok());
    }

    #[test]
    fn session_field_separates_equal_client_slot_pairs() {
        // Two tenants' clients with equal (client, slot) must not share a
        // stream id: the session field occupies its own disjoint bits.
        let a = client_slot_stream_id(1, 7, 3).unwrap();
        let b = client_slot_stream_id(2, 7, 3).unwrap();
        assert_ne!(a, b);
        // The low fields are untouched by the session: masking the
        // session bits off recovers the same (client, slot) packing.
        let mask = (1u64 << (CLIENT_ID_BITS + SLOT_BITS)) - 1;
        assert_eq!(a & mask, b & mask);
    }
}
