//! Tiny CLI argument parser (clap is not in the offline crate set):
//! `--key value`, `--key=value`, boolean `--flag`, positionals, and the
//! `lo:hi` span syntax the aggregation-tier commands use.

use std::collections::HashMap;

use anyhow::{bail, ensure, Context, Result};

/// Parse a client span `lo:hi` (half-open, `lo ≤ hi`) as used by
/// `dme aggregate --span`.
pub fn parse_span(s: &str) -> Result<(u64, u64)> {
    let (lo, hi) = s.split_once(':').with_context(|| format!("span `{s}` is not `lo:hi`"))?;
    let lo: u64 = lo.trim().parse().with_context(|| format!("span lo `{lo}`"))?;
    let hi: u64 = hi.trim().parse().with_context(|| format!("span hi `{hi}`"))?;
    ensure!(lo <= hi, "span `{s}` is inverted");
    Ok((lo, hi))
}

/// Parsed command-line arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
    /// Flags we were asked for (to report unknown leftovers).
    consumed: std::cell::RefCell<std::collections::HashSet<String>>,
}

impl Args {
    /// Parse from raw args (excluding argv[0]).
    pub fn parse(raw: impl IntoIterator<Item = String>) -> Result<Self> {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if rest.is_empty() {
                    bail!("stray `--`");
                }
                if let Some((k, v)) = rest.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    args.flags.insert(rest.to_string(), v);
                } else {
                    args.flags.insert(rest.to_string(), "true".to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    /// First positional (the subcommand), if any.
    pub fn command(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    /// Typed flag with default.
    pub fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        self.consumed.borrow_mut().insert(key.to_string());
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--{key} {v}: {e}")),
        }
    }

    /// Required string flag.
    pub fn require(&self, key: &str) -> Result<String> {
        self.consumed.borrow_mut().insert(key.to_string());
        self.flags
            .get(key)
            .cloned()
            .with_context(|| format!("missing required --{key}"))
    }

    /// Optional string flag.
    pub fn opt(&self, key: &str) -> Option<String> {
        self.consumed.borrow_mut().insert(key.to_string());
        self.flags.get(key).cloned()
    }

    /// Typed optional flag: `Ok(None)` when absent, parse errors surfaced
    /// (unlike [`Args::get`], which needs a default).
    pub fn get_opt<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        self.consumed.borrow_mut().insert(key.to_string());
        match self.flags.get(key) {
            None => Ok(None),
            Some(v) => {
                v.parse().map(Some).map_err(|e| anyhow::anyhow!("--{key} {v}: {e}"))
            }
        }
    }

    /// Boolean flag (present or `--key true/false`).
    pub fn bool(&self, key: &str) -> Result<bool> {
        self.get(key, false)
    }

    /// Error on any flag nobody asked about (catches typos).
    pub fn reject_unknown(&self) -> Result<()> {
        let consumed = self.consumed.borrow();
        let unknown: Vec<&String> =
            self.flags.keys().filter(|k| !consumed.contains(*k)).collect();
        if !unknown.is_empty() {
            bail!("unknown flags: {unknown:?}");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn positional_and_flags() {
        let a = parse(&["kmeans", "--clients", "10", "--protocol=rotated:k=16", "--verbose"]);
        assert_eq!(a.command(), Some("kmeans"));
        assert_eq!(a.get("clients", 0usize).unwrap(), 10);
        assert_eq!(a.require("protocol").unwrap(), "rotated:k=16");
        assert!(a.bool("verbose").unwrap());
        assert!(!a.bool("quiet").unwrap());
        assert_eq!(a.get("iters", 7u32).unwrap(), 7);
    }

    #[test]
    fn typed_errors() {
        let a = parse(&["--clients", "ten"]);
        assert!(a.get("clients", 0usize).is_err());
        assert!(a.require("nope").is_err());
    }

    #[test]
    fn typed_optionals() {
        let a = parse(&["--budget-bits", "2.5"]);
        assert_eq!(a.get_opt::<f64>("budget-bits").unwrap(), Some(2.5));
        assert_eq!(a.get_opt::<f64>("mse-target").unwrap(), None);
        let bad = parse(&["--budget-bits", "lots"]);
        assert!(bad.get_opt::<f64>("budget-bits").is_err());
    }

    #[test]
    fn unknown_flag_detection() {
        let a = parse(&["--known", "1", "--typo", "2"]);
        a.get("known", 0usize).unwrap();
        assert!(a.reject_unknown().is_err());
        a.get("typo", 0usize).unwrap();
        assert!(a.reject_unknown().is_ok());
    }

    #[test]
    fn flag_followed_by_flag_is_boolean() {
        let a = parse(&["--a", "--b", "3"]);
        assert!(a.bool("a").unwrap());
        assert_eq!(a.get("b", 0u32).unwrap(), 3);
    }

    #[test]
    fn span_syntax() {
        assert_eq!(parse_span("0:128").unwrap(), (0, 128));
        assert_eq!(parse_span("7:7").unwrap(), (7, 7));
        assert_eq!(parse_span(" 3 : 9 ").unwrap(), (3, 9));
        assert!(parse_span("9:3").is_err(), "inverted");
        assert!(parse_span("12").is_err(), "no separator");
        assert!(parse_span("a:b").is_err(), "not numeric");
    }
}
