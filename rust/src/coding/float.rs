//! Scalar float codecs for frame headers.
//!
//! Each client's frame carries two scalars (`X_i^min` and the span `s_i`,
//! Lemma 1 / Lemma 5) — the `Õ(1)` term of the per-client cost. Two modes:
//!
//! * [`ScalarCodec::Exact32`] — raw IEEE-754 bits (the "in practice r is
//!   32 or 64" convention the paper notes after Lemma 1). Default.
//! * [`ScalarCodec::Uniform`] — the paper's analytic construction: `r` bits
//!   for a value in `[-N, N]`, worst-case error `N/2^{r-1}`, matching the
//!   `3 log₂(dn) + 1` bit budget discussion. Used by the theory benches to
//!   reproduce the exact Õ(1) accounting.

use anyhow::Result;

use super::bitio::{BitReader, BitWriter};

/// Header scalar codec.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ScalarCodec {
    /// Exact 32-bit IEEE float (32 bits on the wire).
    Exact32,
    /// Uniform mid-rise quantizer: `bits` bits over `[-bound, bound]`.
    Uniform { bits: u32, bound: f32 },
}

impl ScalarCodec {
    /// The analytic choice of Lemma 1: enough bits that header error is
    /// O(N/(nd)³) and thus negligible: `3·log₂(nd) + 1` bits.
    pub fn lemma1(n: usize, d: usize, bound: f32) -> Self {
        let bits = (3.0 * ((n * d) as f64).log2()).ceil() as u32 + 1;
        ScalarCodec::Uniform { bits: bits.clamp(1, 48), bound }
    }

    /// Wire cost in bits of one scalar.
    pub fn bits(&self) -> u32 {
        match self {
            ScalarCodec::Exact32 => 32,
            ScalarCodec::Uniform { bits, .. } => *bits,
        }
    }

    /// Encode `v`; returns the value the decoder will see (callers must
    /// quantize *with* the same value the server reconstructs, otherwise
    /// bins computed against the exact scalar would decode inconsistently).
    pub fn put(&self, w: &mut BitWriter, v: f32) -> f32 {
        match *self {
            ScalarCodec::Exact32 => {
                w.put_f32(v);
                v
            }
            ScalarCodec::Uniform { bits, bound } => {
                let levels = ((1u64 << bits) - 1) as f64;
                let clamped = v.clamp(-bound, bound) as f64;
                let t = (clamped + bound as f64) / (2.0 * bound as f64);
                let idx = (t * levels).round() as u64;
                w.put_bits(idx, bits);
                (idx as f64 / levels * 2.0 * bound as f64 - bound as f64) as f32
            }
        }
    }

    /// Decode one scalar.
    pub fn get(&self, r: &mut BitReader) -> Result<f32> {
        match *self {
            ScalarCodec::Exact32 => r.get_f32(),
            ScalarCodec::Uniform { bits, bound } => {
                let levels = ((1u64 << bits) - 1) as f64;
                let idx = r.get_bits(bits)?;
                Ok((idx as f64 / levels * 2.0 * bound as f64 - bound as f64) as f32)
            }
        }
    }

    /// Worst-case absolute reconstruction error for in-range values.
    pub fn max_error(&self) -> f32 {
        match *self {
            ScalarCodec::Exact32 => 0.0,
            ScalarCodec::Uniform { bits, bound } => {
                let levels = ((1u64 << bits) - 1) as f32;
                bound / levels // half-step of 2*bound/levels
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{check, run_prop};

    #[test]
    fn exact32_is_lossless() {
        let c = ScalarCodec::Exact32;
        let mut w = BitWriter::new();
        let echo = c.put(&mut w, -1.234e-5);
        assert_eq!(echo, -1.234e-5);
        let (bytes, bits) = w.finish();
        assert_eq!(bits, 32);
        let mut r = BitReader::with_bit_len(&bytes, bits);
        assert_eq!(c.get(&mut r).unwrap(), -1.234e-5);
    }

    #[test]
    fn uniform_error_within_bound_and_encoder_decoder_agree() {
        let c = ScalarCodec::Uniform { bits: 10, bound: 4.0 };
        for v in [-4.0f32, -3.3, 0.0, 0.001, 2.5, 4.0] {
            let mut w = BitWriter::new();
            let echo = c.put(&mut w, v);
            let (bytes, bits) = w.finish();
            assert_eq!(bits, 10);
            let mut r = BitReader::with_bit_len(&bytes, bits);
            let got = c.get(&mut r).unwrap();
            assert_eq!(got, echo, "encoder echo must equal decoded value");
            assert!((got - v).abs() <= c.max_error() + 1e-6, "v={v} got={got}");
        }
    }

    #[test]
    fn uniform_clamps_out_of_range() {
        let c = ScalarCodec::Uniform { bits: 8, bound: 1.0 };
        let mut w = BitWriter::new();
        let echo = c.put(&mut w, 100.0);
        assert!((echo - 1.0).abs() < 1e-6);
    }

    #[test]
    fn lemma1_budget_matches_formula() {
        let c = ScalarCodec::lemma1(10, 1024, 1.0);
        // 3*log2(10240)+1 = 3*13.32+1 -> ceil = 41
        assert_eq!(c.bits(), 41);
    }

    #[test]
    fn prop_uniform_roundtrip_error_bound() {
        run_prop("float_uniform", 300, |g| {
            // beyond ~22 bits the grid step drops under f32 ulp and the
            // reconstruction is limited by float representation, not the
            // codec; cap the sweep where the analytic bound is meaningful.
            let bits = g.u32_in(2..=22);
            let bound = g.f32_in(0.1, 100.0);
            let c = ScalarCodec::Uniform { bits, bound };
            let v = g.f32_in(-bound, bound);
            let mut w = BitWriter::new();
            let echo = c.put(&mut w, v);
            let (bytes, blen) = w.finish();
            let mut r = BitReader::with_bit_len(&bytes, blen);
            let got = c.get(&mut r).map_err(|e| e.to_string())?;
            check(got == echo, format!("echo {echo} != decoded {got}"))?;
            check(
                (got - v).abs() <= c.max_error() * 1.01 + 1e-6,
                format!("bits={bits} bound={bound} v={v} got={got} err>{}", c.max_error()),
            )
        });
    }
}
