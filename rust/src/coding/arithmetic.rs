//! Static arithmetic coding w.r.t. the bin distribution `p_r = h_r / d`
//! (paper §4, following MacKay [19]: payload ≤ d·H(p) + 2 bits).
//!
//! Classic 32-bit integer arithmetic coder (CACM-87 style) with pending-bit
//! underflow handling. The model is *static*: both sides build the same
//! cumulative-frequency table from the histogram carried in the frame
//! header, so the coder itself transmits nothing but the payload.

use anyhow::{bail, ensure, Result};

use super::bitio::{BitReader, BitWriter};

const PRECISION: u32 = 32;
const TOP: u64 = 1 << PRECISION; // exclusive upper bound of the interval
const HALF: u64 = TOP / 2;
const QUARTER: u64 = TOP / 4;
const THREE_QUARTERS: u64 = 3 * (TOP / 4);
const MASK: u64 = TOP - 1;

/// Cumulative-frequency model shared by encoder and decoder.
#[derive(Clone, Debug)]
pub struct CumTable {
    /// cum[s]..cum[s+1] is symbol s's slice of [0, total).
    cum: Vec<u64>,
    total: u64,
    /// Direct scaled→symbol map (built when total is small, i.e. always
    /// for per-vector histograms where total = d): turns the per-symbol
    /// binary search into one indexed load on the decode hot path.
    lut: Vec<u32>,
    /// floor(2^64 / total): reciprocal for exact division-by-total via
    /// multiply + fixup (two u64 divides per symbol otherwise).
    magic: u64,
}

/// Exact `x / total` using the precomputed reciprocal: the multiply gives
/// an underestimate by at most 2; fix up with subtractions.
#[inline]
fn div_by_total(x: u64, total: u64, magic: u64) -> u64 {
    let mut q = ((x as u128 * magic as u128) >> 64) as u64;
    let mut r = x - q * total;
    while r >= total {
        q += 1;
        r -= total;
    }
    q
}

impl CumTable {
    pub fn from_histogram(hist: &[u64]) -> Result<Self> {
        ensure!(!hist.is_empty(), "empty histogram");
        let mut cum = Vec::with_capacity(hist.len() + 1);
        let mut acc = 0u64;
        cum.push(0);
        for &h in hist {
            acc += h;
            cum.push(acc);
        }
        ensure!(acc > 0, "histogram has no mass");
        // total must fit the coder's precision headroom: range/total >= 1.
        ensure!(acc < (1 << 30), "histogram total too large for 32-bit coder");
        let mut lut = Vec::new();
        if acc <= (1 << 20) {
            lut.reserve(acc as usize);
            for (s, &h) in hist.iter().enumerate() {
                lut.extend(std::iter::repeat_n(s as u32, h as usize));
            }
        }
        // magic = floor(2^64 / total) (saturated to u64::MAX for total=1,
        // where the fixup loop still lands on the exact quotient).
        let magic = ((1u128 << 64) / acc as u128).min(u64::MAX as u128) as u64;
        Ok(CumTable { cum, total: acc, lut, magic })
    }

    #[inline]
    pub fn total(&self) -> u64 {
        self.total
    }

    #[inline]
    fn span(&self, s: u32) -> (u64, u64) {
        (self.cum[s as usize], self.cum[s as usize + 1])
    }

    /// Symbol whose slice contains `scaled`.
    #[inline]
    fn find(&self, scaled: u64) -> u32 {
        if !self.lut.is_empty() {
            return self.lut[scaled as usize];
        }
        self.find_bsearch(scaled)
    }

    /// Binary-search fallback for very large totals (no LUT).
    #[inline]
    fn find_bsearch(&self, scaled: u64) -> u32 {
        // partition_point: first index with cum[i+1] > scaled
        let mut lo = 0usize;
        let mut hi = self.cum.len() - 2;
        while lo < hi {
            let mid = (lo + hi) / 2;
            if self.cum[mid + 1] <= scaled {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        lo as u32
    }
}

/// Encode `data` under the static model; appends to `w`.
pub fn encode(w: &mut BitWriter, model: &CumTable, data: &[u32]) -> Result<()> {
    let mut low: u64 = 0;
    let mut high: u64 = MASK;
    let mut pending: u64 = 0;

    let put = |w: &mut BitWriter, bit: bool, pending: &mut u64| {
        w.put_bit(bit);
        // batch the pending run (all !bit) in <=64-bit strokes
        let fill = if bit { 0u64 } else { u64::MAX };
        while *pending > 0 {
            let n = (*pending).min(64) as u32;
            w.put_bits(fill, n);
            *pending -= n as u64;
        }
    };

    for &s in data {
        ensure!((s as usize) < model.cum.len() - 1, "symbol {s} out of alphabet");
        let (c_lo, c_hi) = model.span(s);
        ensure!(c_hi > c_lo, "symbol {s} has zero frequency");
        let range = high - low + 1;
        high = low + div_by_total(range * c_hi, model.total, model.magic) - 1;
        low += div_by_total(range * c_lo, model.total, model.magic);
        loop {
            if high < HALF {
                put(w, false, &mut pending);
            } else if low >= HALF {
                put(w, true, &mut pending);
                low -= HALF;
                high -= HALF;
            } else if low >= QUARTER && high < THREE_QUARTERS {
                pending += 1;
                low -= QUARTER;
                high -= QUARTER;
            } else {
                break;
            }
            low <<= 1;
            high = (high << 1) | 1;
            debug_assert!(high <= MASK && low <= MASK);
        }
    }
    // Flush: two disambiguating bits (plus pendings).
    pending += 1;
    if low < QUARTER {
        put(w, false, &mut pending);
    } else {
        put(w, true, &mut pending);
    }
    Ok(())
}

/// Decode exactly `count` symbols from `r` under the static model.
///
/// The reader may be a shared frame buffer: the decoder consumes the
/// payload bits plus up to `PRECISION` lookahead bits that the encoder
/// never wrote (it reads zeros past end-of-frame, matching the encoder's
/// implicit trailing zeros). Callers placing data *after* an arithmetic
/// payload in the same frame must delimit it by position, not adjacency —
/// in this crate the arithmetic payload is always last in the frame.
pub fn decode(r: &mut BitReader, model: &CumTable, count: usize, out: &mut Vec<u32>) -> Result<()> {
    let mut low: u64 = 0;
    let mut high: u64 = MASK;
    let mut value: u64 = 0;
    for _ in 0..PRECISION {
        value = (value << 1) | r.get_bit_or_zero() as u64;
    }
    out.reserve(count);
    for _ in 0..count {
        let range = high - low + 1;
        let scaled = ((value - low + 1) * model.total - 1) / range;
        if scaled >= model.total {
            bail!("arithmetic decode: scaled value out of range (corrupt frame)");
        }
        let s = model.find(scaled);
        let (c_lo, c_hi) = model.span(s);
        high = low + div_by_total(range * c_hi, model.total, model.magic) - 1;
        low += div_by_total(range * c_lo, model.total, model.magic);
        loop {
            if high < HALF {
                // nothing
            } else if low >= HALF {
                low -= HALF;
                high -= HALF;
                value -= HALF;
            } else if low >= QUARTER && high < THREE_QUARTERS {
                low -= QUARTER;
                high -= QUARTER;
                value -= QUARTER;
            } else {
                break;
            }
            low <<= 1;
            high = (high << 1) | 1;
            value = (value << 1) | r.get_bit_or_zero() as u64;
        }
        out.push(s);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::histogram_entropy_bits;
    use crate::testkit::{check, run_prop};

    fn hist_of(data: &[u32], k: usize) -> Vec<u64> {
        let mut h = vec![0u64; k];
        for &s in data {
            h[s as usize] += 1;
        }
        h
    }

    fn roundtrip(data: &[u32], k: usize) -> u64 {
        let hist = hist_of(data, k);
        let model = CumTable::from_histogram(&hist).unwrap();
        let mut w = BitWriter::new();
        encode(&mut w, &model, data).unwrap();
        let (bytes, bits) = w.finish();
        let mut r = BitReader::with_bit_len(&bytes, bits);
        let mut out = Vec::new();
        decode(&mut r, &model, data.len(), &mut out).unwrap();
        assert_eq!(out, data, "roundtrip mismatch");
        bits
    }

    #[test]
    fn simple_roundtrip() {
        roundtrip(&[0, 1, 2, 1, 0, 2, 2, 2], 3);
    }

    #[test]
    fn single_symbol_stream_costs_almost_nothing() {
        let data = vec![3u32; 1000];
        let bits = roundtrip(&data, 8);
        assert!(bits <= 2, "bits={bits}");
    }

    #[test]
    fn payload_close_to_entropy_bound() {
        // Theorem-4 accounting: payload <= d*H + 2 bits.
        let mut data = Vec::new();
        for (s, c) in [(0u32, 900usize), (1, 50), (2, 25), (3, 25)] {
            data.extend(std::iter::repeat_n(s, c));
        }
        let hist = hist_of(&data, 4);
        let h = histogram_entropy_bits(&hist);
        let bits = roundtrip(&data, 4);
        assert!(
            (bits as f64) <= h * data.len() as f64 + 2.0 + 1e-6,
            "bits={bits} entropy bound={}",
            h * data.len() as f64 + 2.0
        );
    }

    #[test]
    fn beats_huffman_on_very_skewed_data() {
        let mut data = vec![0u32; 5000];
        data.push(1);
        let hist = hist_of(&data, 2);
        let bits_arith = roundtrip(&data, 2);
        let code = super::super::huffman::HuffmanCode::from_histogram(&hist).unwrap();
        let bits_huff = code.payload_bits(&data);
        assert!(bits_arith < bits_huff / 50, "arith={bits_arith} huff={bits_huff}");
    }

    #[test]
    fn unseen_symbol_rejected() {
        let model = CumTable::from_histogram(&[5, 0, 3]).unwrap();
        let mut w = BitWriter::new();
        assert!(encode(&mut w, &model, &[1]).is_err());
        assert!(encode(&mut w, &model, &[7]).is_err());
    }

    #[test]
    fn corrupt_frame_detected_or_differs() {
        let data = vec![0u32, 1, 2, 2, 1, 0, 1, 2, 2, 2];
        let hist = hist_of(&data, 3);
        let model = CumTable::from_histogram(&hist).unwrap();
        let mut w = BitWriter::new();
        encode(&mut w, &model, &data).unwrap();
        let (mut bytes, bits) = w.finish();
        bytes[0] ^= 0x80; // flip the first payload bit
        let mut r = BitReader::with_bit_len(&bytes, bits);
        let mut out = Vec::new();
        let res = decode(&mut r, &model, data.len(), &mut out);
        assert!(res.is_err() || out != data);
    }

    #[test]
    fn prop_roundtrip_and_entropy_bound() {
        run_prop("arith_roundtrip", 120, |g| {
            let k = g.usize_in(1..=64);
            let n = g.usize_in(1..=600);
            let data: Vec<u32> = (0..n)
                .map(|_| {
                    let x = g.rng().next_f32();
                    ((x * x * x * k as f32) as u32).min(k as u32 - 1)
                })
                .collect();
            let hist = hist_of(&data, k);
            let model = CumTable::from_histogram(&hist).map_err(|e| e.to_string())?;
            let mut w = BitWriter::new();
            encode(&mut w, &model, &data).map_err(|e| e.to_string())?;
            let (bytes, bits) = w.finish();
            let mut r = BitReader::with_bit_len(&bytes, bits);
            let mut out = Vec::new();
            decode(&mut r, &model, n, &mut out).map_err(|e| e.to_string())?;
            check(out == data, "decode mismatch")?;
            let h = histogram_entropy_bits(&hist);
            // d*H + 2 plus a little slack for integer-division model error
            let bound = h * n as f64 + 2.0 + 0.01 * n as f64 + 8.0;
            check((bits as f64) <= bound, format!("bits={bits} > bound={bound}"))
        });
    }
}
