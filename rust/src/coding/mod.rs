//! Bit-exact entropy-coding substrates for the variable-length protocol
//! (paper §4) and the wire frames of every protocol.
//!
//! * [`bitio`] — MSB-first bit-level writer/reader.
//! * [`float`] — r-bit scalar quantizer for frame headers (`X_min`, `s_i`),
//!   the `Õ(1)` part of each client's cost (Lemma 1).
//! * [`elias`] — Elias γ/δ universal integer codes (reference [11]; used as
//!   a histogram-header mode and as a QSGD-style comparator).
//! * [`huffman`] — canonical Huffman coding over the bin histogram.
//! * [`arithmetic`] — static arithmetic (range) coding w.r.t. `p_r = h_r/d`,
//!   the coder Theorem 4's analysis assumes.
//! * [`histogram`] — the `h_r` header: enumerative code achieving exactly
//!   `⌈log₂ C(d+k−1, k−1)⌉` bits (the bound used in Theorem 4), plus
//!   cheaper practical modes.

pub mod arithmetic;
pub mod bignum;
pub mod bitio;
pub mod elias;
pub mod float;
pub mod histogram;
pub mod huffman;

pub use bitio::{BitReader, BitWriter};

/// Entropy of a histogram in bits per symbol: `Σ (h/d) log2(d/h)`.
/// This is the payload rate arithmetic coding approaches (MacKay [19]).
pub fn histogram_entropy_bits(hist: &[u64]) -> f64 {
    let total: u64 = hist.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let d = total as f64;
    hist.iter()
        .filter(|&&h| h > 0)
        .map(|&h| {
            let p = h as f64 / d;
            -p * p.log2()
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entropy_uniform_is_log_k() {
        let h = vec![8u64; 4];
        assert!((histogram_entropy_bits(&h) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn entropy_degenerate_is_zero() {
        assert_eq!(histogram_entropy_bits(&[32, 0, 0]), 0.0);
        assert_eq!(histogram_entropy_bits(&[]), 0.0);
        assert_eq!(histogram_entropy_bits(&[0, 0]), 0.0);
    }
}
