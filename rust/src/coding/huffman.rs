//! Canonical Huffman coding over a known symbol histogram.
//!
//! The paper (§4) proposes "arithmetic or Huffman coding corresponding to
//! the distribution p_r = h_r/d". The decoder rebuilds the identical code
//! from the histogram transmitted in the frame header, so no code table is
//! ever sent. Codes are *canonical* (sorted by (length, symbol)) which
//! makes encoder/decoder agreement trivial and decoding table-driven.

use anyhow::{bail, ensure, Result};

use super::bitio::{BitReader, BitWriter};

/// Maximum supported code length. With d ≤ 2²⁰ coordinates per vector a
/// Huffman code cannot be deeper than ~fib⁻¹(d) ≈ 30; 48 is safely above
/// anything reachable and keeps the decode accelerations simple.
const MAX_LEN: usize = 48;

/// A canonical Huffman code built from symbol counts.
#[derive(Clone, Debug)]
pub struct HuffmanCode {
    /// Code length per symbol (0 = symbol absent).
    lens: Vec<u8>,
    /// Codeword per symbol (valid when lens[s] > 0), MSB-aligned to len.
    codes: Vec<u64>,
    /// Symbols sorted by (len, symbol) — canonical decode order.
    sorted_syms: Vec<u32>,
    /// first_code[l] = first canonical codeword of length l.
    first_code: [u64; MAX_LEN + 1],
    /// first_idx[l] = index into sorted_syms of the first length-l symbol.
    first_idx: [u32; MAX_LEN + 1],
    /// Number of distinct symbols with nonzero count.
    distinct: usize,
}

impl HuffmanCode {
    /// Build from a histogram (`hist[s]` = occurrences of symbol `s`).
    ///
    /// Degenerate cases: an empty histogram is rejected; a single distinct
    /// symbol gets a zero-length code (encoding emits no bits — the count
    /// and histogram fully determine the payload).
    pub fn from_histogram(hist: &[u64]) -> Result<Self> {
        let k = hist.len();
        ensure!(k >= 1, "empty histogram");
        ensure!(k <= u32::MAX as usize, "histogram too large");
        let distinct = hist.iter().filter(|&&h| h > 0).count();
        ensure!(distinct >= 1, "histogram has no symbols");

        let mut lens = vec![0u8; k];
        if distinct == 1 {
            // Zero-bit code: nothing to emit; decoder replays the symbol.
            let s = hist.iter().position(|&h| h > 0).unwrap();
            let mut code = HuffmanCode {
                lens,
                codes: vec![0; k],
                sorted_syms: vec![s as u32],
                first_code: [0; MAX_LEN + 1],
                first_idx: [0; MAX_LEN + 1],
                distinct,
            };
            code.lens[s] = 0;
            return Ok(code);
        }

        // --- Huffman tree via two-queue merge over count-sorted leaves ---
        // nodes: (count, node_id); children recorded for length assignment.
        let mut leaves: Vec<(u64, u32)> = hist
            .iter()
            .enumerate()
            .filter(|(_, &h)| h > 0)
            .map(|(s, &h)| (h, s as u32))
            .collect();
        leaves.sort_unstable();
        let n_leaves = leaves.len();
        // parent[i] for node i; leaves are 0..n_leaves, internal follow.
        let mut parent = vec![u32::MAX; 2 * n_leaves - 1];
        let mut leaf_q: std::collections::VecDeque<(u64, u32)> =
            leaves.iter().cloned().map(|(c, _)| (c, 0u32)).collect();
        // assign node ids to leaves in sorted order
        for (i, item) in leaf_q.iter_mut().enumerate() {
            item.1 = i as u32;
        }
        let mut merge_q: std::collections::VecDeque<(u64, u32)> = Default::default();
        let mut next_id = n_leaves as u32;
        let pop_min =
            |a: &mut std::collections::VecDeque<(u64, u32)>,
             b: &mut std::collections::VecDeque<(u64, u32)>| {
                match (a.front(), b.front()) {
                    (Some(&x), Some(&y)) => {
                        if x.0 <= y.0 {
                            a.pop_front().unwrap()
                        } else {
                            b.pop_front().unwrap()
                        }
                    }
                    (Some(_), None) => a.pop_front().unwrap(),
                    (None, Some(_)) => b.pop_front().unwrap(),
                    (None, None) => unreachable!("both queues empty"),
                }
            };
        while leaf_q.len() + merge_q.len() > 1 {
            let x = pop_min(&mut leaf_q, &mut merge_q);
            let y = pop_min(&mut leaf_q, &mut merge_q);
            parent[x.1 as usize] = next_id;
            parent[y.1 as usize] = next_id;
            merge_q.push_back((x.0 + y.0, next_id));
            next_id += 1;
        }
        // depth of each leaf = code length
        for (i, &(_, sym)) in leaves.iter().enumerate() {
            let mut depth = 0u8;
            let mut node = i as u32;
            while parent[node as usize] != u32::MAX {
                node = parent[node as usize];
                depth += 1;
            }
            ensure!((depth as usize) <= MAX_LEN, "huffman code too deep: {depth}");
            lens[sym as usize] = depth;
        }

        Self::from_lengths(lens)
    }

    /// Build the canonical code tables from per-symbol lengths.
    fn from_lengths(lens: Vec<u8>) -> Result<Self> {
        let k = lens.len();
        let distinct = lens.iter().filter(|&&l| l > 0).count();
        let mut sorted_syms: Vec<u32> = (0..k as u32).filter(|&s| lens[s as usize] > 0).collect();
        sorted_syms.sort_by_key(|&s| (lens[s as usize], s));

        let mut bl_count = [0u64; MAX_LEN + 1];
        for &l in &lens {
            if l > 0 {
                bl_count[l as usize] += 1;
            }
        }
        let mut first_code = [0u64; MAX_LEN + 1];
        let mut code = 0u64;
        for l in 1..=MAX_LEN {
            code = (code + bl_count[l - 1]) << 1;
            first_code[l] = code;
        }
        let mut first_idx = [0u32; MAX_LEN + 1];
        let mut idx = 0u32;
        for l in 1..=MAX_LEN {
            first_idx[l] = idx;
            idx += bl_count[l] as u32;
        }
        let mut codes = vec![0u64; k];
        let mut next = first_code;
        for &s in &sorted_syms {
            let l = lens[s as usize] as usize;
            codes[s as usize] = next[l];
            next[l] += 1;
        }
        Ok(HuffmanCode { lens, codes, sorted_syms, first_code, first_idx, distinct })
    }

    /// Code length (bits) of `symbol`; 0 if absent from the histogram.
    pub fn len_of(&self, symbol: u32) -> u8 {
        self.lens[symbol as usize]
    }

    /// Total payload bits to encode `data` under this code.
    pub fn payload_bits(&self, data: &[u32]) -> u64 {
        data.iter().map(|&s| self.lens[s as usize] as u64).sum()
    }

    /// Encode a symbol stream.
    pub fn encode(&self, w: &mut BitWriter, data: &[u32]) -> Result<()> {
        if self.distinct == 1 {
            // zero bits per symbol
            for &s in data {
                ensure!(
                    self.sorted_syms[0] == s,
                    "symbol {s} not in single-symbol histogram"
                );
            }
            return Ok(());
        }
        for &s in data {
            let l = self.lens[s as usize];
            ensure!(l > 0, "symbol {s} has zero frequency in histogram");
            w.put_bits(self.codes[s as usize], l as u32);
        }
        Ok(())
    }

    /// Decode exactly `count` symbols.
    pub fn decode(&self, r: &mut BitReader, count: usize, out: &mut Vec<u32>) -> Result<()> {
        out.reserve(count);
        if self.distinct == 1 {
            let s = self.sorted_syms[0];
            out.extend(std::iter::repeat_n(s, count));
            return Ok(());
        }
        for _ in 0..count {
            let mut code = 0u64;
            let mut l = 0usize;
            loop {
                code = (code << 1) | r.get_bit()? as u64;
                l += 1;
                if l > MAX_LEN {
                    bail!("huffman decode: code longer than MAX_LEN");
                }
                // Canonical property: codes of length l occupy
                // [first_code[l], first_code[l] + bl_count[l]). We can test
                // membership via the next length's first_code shifted down.
                let count_l = self.count_at(l);
                if count_l > 0 && code < self.first_code[l] + count_l {
                    let off = (code - self.first_code[l]) as u32;
                    out.push(self.sorted_syms[(self.first_idx[l] + off) as usize]);
                    break;
                }
            }
        }
        Ok(())
    }

    #[inline]
    fn count_at(&self, l: usize) -> u64 {
        let hi = if l == MAX_LEN {
            self.sorted_syms.len() as u32
        } else {
            self.first_idx[l + 1]
        };
        (hi - self.first_idx[l]) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::histogram_entropy_bits;
    use crate::testkit::{check, run_prop};

    fn hist_of(data: &[u32], k: usize) -> Vec<u64> {
        let mut h = vec![0u64; k];
        for &s in data {
            h[s as usize] += 1;
        }
        h
    }

    fn roundtrip(data: &[u32], k: usize) -> (Vec<u32>, u64) {
        let hist = hist_of(data, k);
        let code = HuffmanCode::from_histogram(&hist).unwrap();
        let mut w = BitWriter::new();
        code.encode(&mut w, data).unwrap();
        let (bytes, bits) = w.finish();
        assert_eq!(bits, code.payload_bits(data));
        let mut r = BitReader::with_bit_len(&bytes, bits);
        let mut out = Vec::new();
        code.decode(&mut r, data.len(), &mut out).unwrap();
        (out, bits)
    }

    #[test]
    fn simple_roundtrip() {
        let data = vec![0, 1, 1, 2, 2, 2, 2, 3];
        let (out, _) = roundtrip(&data, 4);
        assert_eq!(out, data);
    }

    #[test]
    fn single_symbol_uses_zero_bits() {
        let data = vec![5u32; 100];
        let (out, bits) = roundtrip(&data, 8);
        assert_eq!(out, data);
        assert_eq!(bits, 0);
    }

    #[test]
    fn two_symbols_one_bit_each() {
        let data = vec![0, 1, 0, 1, 1];
        let (out, bits) = roundtrip(&data, 2);
        assert_eq!(out, data);
        assert_eq!(bits, 5);
    }

    #[test]
    fn skewed_distribution_beats_fixed_width() {
        // 97% zeros over k=16: fixed width is 4 bits/sym; huffman ~1.
        let mut data = vec![0u32; 970];
        data.extend((0..30).map(|i| 1 + (i % 15) as u32));
        let (out, bits) = roundtrip(&data, 16);
        assert_eq!(out, data);
        assert!(bits < 2 * data.len() as u64, "bits={bits}");
    }

    #[test]
    fn encode_rejects_unseen_symbol() {
        let hist = vec![3, 0, 1];
        let code = HuffmanCode::from_histogram(&hist).unwrap();
        let mut w = BitWriter::new();
        assert!(code.encode(&mut w, &[1]).is_err());
    }

    #[test]
    fn empty_histogram_rejected() {
        assert!(HuffmanCode::from_histogram(&[]).is_err());
        assert!(HuffmanCode::from_histogram(&[0, 0]).is_err());
    }

    #[test]
    fn within_one_bit_of_entropy_per_symbol() {
        // Huffman optimality: payload <= (H + 1) * n.
        let mut data = Vec::new();
        for (s, c) in [(0u32, 500usize), (1, 250), (2, 125), (3, 125)] {
            data.extend(std::iter::repeat_n(s, c));
        }
        let hist = hist_of(&data, 4);
        let code = HuffmanCode::from_histogram(&hist).unwrap();
        let h = histogram_entropy_bits(&hist);
        let bits = code.payload_bits(&data) as f64;
        assert!(bits <= (h + 1.0) * data.len() as f64 + 1e-9);
        // this distribution is dyadic: huffman == entropy exactly
        assert!((bits - h * data.len() as f64).abs() < 1e-6);
    }

    #[test]
    fn prop_roundtrip_random_streams() {
        run_prop("huffman_roundtrip", 150, |g| {
            let k = g.usize_in(1..=64);
            let n = g.usize_in(1..=800);
            // random skew: draw symbols from a squared distribution
            let data: Vec<u32> = (0..n)
                .map(|_| {
                    let x = g.rng().next_f32();
                    ((x * x * k as f32) as u32).min(k as u32 - 1)
                })
                .collect();
            let hist = hist_of(&data, k);
            let code = HuffmanCode::from_histogram(&hist).map_err(|e| e.to_string())?;
            let mut w = BitWriter::new();
            code.encode(&mut w, &data).map_err(|e| e.to_string())?;
            let (bytes, bits) = w.finish();
            let mut r = BitReader::with_bit_len(&bytes, bits);
            let mut out = Vec::new();
            code.decode(&mut r, data.len(), &mut out).map_err(|e| e.to_string())?;
            check(out == data, "decode mismatch")?;
            // optimality sanity: within 1 bit/symbol of entropy
            let h = histogram_entropy_bits(&hist);
            check(
                bits as f64 <= (h + 1.0) * n as f64 + 1e-9,
                format!("bits={bits} entropy={h} n={n}"),
            )
        });
    }
}
