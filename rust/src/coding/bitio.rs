//! MSB-first bit-level I/O.
//!
//! Every protocol frame in this crate is produced through [`BitWriter`] so
//! the communication cost we report is the cost of the bits we actually
//! emit (plus the final byte padding, which we track separately: MSE/cost
//! experiments use `bit_len`, the transport uses `bytes`).

use anyhow::{bail, Result};

/// Accumulates bits MSB-first into a byte buffer.
#[derive(Clone, Debug, Default)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// Bits still FREE in the final byte (0 = byte complete), 0..8.
    free: u8,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(bits: usize) -> Self {
        BitWriter { buf: Vec::with_capacity(bits.div_ceil(8)), free: 0 }
    }

    /// Start a writer over a recycled buffer: the buffer is cleared but
    /// its capacity is kept. The round-session encoders reuse one frame
    /// allocation per client this way.
    pub fn over(mut buf: Vec<u8>) -> Self {
        buf.clear();
        BitWriter { buf, free: 0 }
    }

    /// Total bits written so far.
    #[inline]
    pub fn bit_len(&self) -> u64 {
        self.buf.len() as u64 * 8 - self.free as u64
    }

    /// Append a single bit.
    #[inline]
    pub fn put_bit(&mut self, bit: bool) {
        if self.free == 0 {
            self.buf.push(0);
            self.free = 8;
        }
        // Bits fill from the MSB of the current byte downward; free==0
        // means the byte is complete and the next bit opens a fresh one.
        self.free -= 1;
        if bit {
            *self.buf.last_mut().unwrap() |= 1 << self.free;
        }
    }

    /// Append the low `n` bits of `value`, MSB-first. `n <= 64`.
    ///
    /// Word-wise fast path: fills the current partial byte, then emits
    /// whole bytes directly (the fixed-width protocols write millions of
    /// 1–6-bit fields; bit-by-bit was the encode bottleneck).
    pub fn put_bits(&mut self, value: u64, mut n: u32) {
        debug_assert!(n <= 64);
        if n == 0 {
            return;
        }
        let value = if n == 64 { value } else { value & ((1u64 << n) - 1) };
        // 1. top up the current partial byte
        if self.free > 0 {
            let take = n.min(self.free as u32);
            let chunk = (value >> (n - take)) as u8;
            self.free -= take as u8;
            *self.buf.last_mut().unwrap() |= chunk << self.free;
            n -= take;
            if n == 0 {
                return;
            }
        }
        // 2. whole bytes
        while n >= 8 {
            n -= 8;
            self.buf.push((value >> n) as u8);
        }
        // 3. tail bits open a fresh byte
        if n > 0 {
            self.free = 8 - n as u8;
            self.buf.push(((value & ((1 << n) - 1)) as u8) << self.free);
        }
    }

    /// Append `values.len()` fixed-width fields, MSB-first — bit-identical
    /// to calling [`put_bits`](Self::put_bits) once per value, but with
    /// the stream state kept in a u64 accumulator so the per-field cost
    /// is a shift/or plus amortized byte stores (this is the frame
    /// bit-pack hot path for the k-level protocols). `width <= 32`.
    ///
    /// Invariant that keeps the accumulator in bounds: whole bytes are
    /// flushed *before* the next field is shifted in, so at the shift
    /// point at most 7 bits are pending and `7 + 32 < 64`.
    pub fn put_bits_bulk(&mut self, values: &[u32], width: u32) {
        debug_assert!(width <= 32);
        if width == 0 || values.is_empty() {
            return;
        }
        self.buf.reserve((values.len() * width as usize) / 8 + 1);
        let mask = (1u64 << width) - 1;
        let mut acc: u64 = 0;
        let mut nbits: u32 = 0;
        // Absorb the current partial byte so the flush loop below stays
        // byte-aligned against the buffer.
        if self.free > 0 {
            let last = self.buf.pop().unwrap();
            nbits = 8 - self.free as u32;
            acc = (last >> self.free) as u64;
            self.free = 0;
        }
        for &v in values {
            acc = (acc << width) | (v as u64 & mask);
            nbits += width;
            while nbits >= 8 {
                nbits -= 8;
                self.buf.push((acc >> nbits) as u8);
            }
            acc &= (1u64 << nbits) - 1;
        }
        if nbits > 0 {
            self.free = (8 - nbits) as u8;
            self.buf.push((acc as u8) << self.free);
        }
    }

    /// Append a full byte (fast path when aligned).
    pub fn put_u8(&mut self, v: u8) {
        if self.free == 0 {
            self.buf.push(v);
        } else {
            self.put_bits(v as u64, 8);
        }
    }

    /// Append an f32 as its 32 raw bits (headers store full-precision
    /// floats by default, like the 32-bit-float convention in Lemma 1).
    pub fn put_f32(&mut self, v: f32) {
        self.put_bits(v.to_bits() as u64, 32);
    }

    /// Finish, returning (bytes, exact bit length).
    pub fn finish(self) -> (Vec<u8>, u64) {
        let bits = self.bit_len();
        (self.buf, bits)
    }
}

/// Reads bits MSB-first from a byte slice.
pub struct BitReader<'a> {
    buf: &'a [u8],
    /// Next bit position.
    pos: u64,
    /// Total valid bits (callers may pass the writer's exact `bit_len`).
    len: u64,
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        BitReader { buf, pos: 0, len: buf.len() as u64 * 8 }
    }

    /// Reader over an exact number of valid bits.
    pub fn with_bit_len(buf: &'a [u8], bits: u64) -> Self {
        debug_assert!(bits <= buf.len() as u64 * 8);
        BitReader { buf, pos: 0, len: bits }
    }

    #[inline]
    pub fn bits_remaining(&self) -> u64 {
        self.len - self.pos
    }

    #[inline]
    pub fn position(&self) -> u64 {
        self.pos
    }

    /// Read one bit.
    #[inline]
    pub fn get_bit(&mut self) -> Result<bool> {
        if self.pos >= self.len {
            bail!("BitReader: out of bits at {}", self.pos);
        }
        let byte = self.buf[(self.pos / 8) as usize];
        let bit = (byte >> (7 - (self.pos % 8))) & 1 == 1;
        self.pos += 1;
        Ok(bit)
    }

    /// Read one bit, returning 0 past end-of-stream. The arithmetic decoder
    /// needs this: its final state legitimately drains past the last
    /// written bit (the encoder's implicit trailing zeros).
    #[inline]
    pub fn get_bit_or_zero(&mut self) -> bool {
        if self.pos >= self.len {
            self.pos += 1;
            return false;
        }
        let byte = self.buf[(self.pos / 8) as usize];
        let bit = (byte >> (7 - (self.pos % 8))) & 1 == 1;
        self.pos += 1;
        bit
    }

    /// Read `n` bits MSB-first into the low bits of a u64. `n <= 64`.
    ///
    /// Word-wise fast path mirroring [`BitWriter::put_bits`].
    pub fn get_bits(&mut self, mut n: u32) -> Result<u64> {
        debug_assert!(n <= 64);
        if n == 0 {
            return Ok(0);
        }
        if self.pos + n as u64 > self.len {
            bail!("BitReader: out of bits reading {n} at {}", self.pos);
        }
        let mut v = 0u64;
        // 1. finish the current partial byte
        let offset = (self.pos % 8) as u32;
        if offset != 0 {
            let avail = 8 - offset;
            let byte = self.buf[(self.pos / 8) as usize];
            let take = n.min(avail);
            let chunk = (byte >> (avail - take)) & ((1u16 << take) - 1) as u8;
            v = chunk as u64;
            self.pos += take as u64;
            n -= take;
        }
        // 2. whole bytes
        while n >= 8 {
            v = (v << 8) | self.buf[(self.pos / 8) as usize] as u64;
            self.pos += 8;
            n -= 8;
        }
        // 3. leading bits of the next byte
        if n > 0 {
            let byte = self.buf[(self.pos / 8) as usize];
            v = (v << n) | (byte >> (8 - n)) as u64;
            self.pos += n as u64;
        }
        Ok(v)
    }

    /// Read `out.len()` fixed-width fields, MSB-first — bit-identical to
    /// calling [`get_bits`](Self::get_bits) once per field, including the
    /// error position on stream under-run (the slow path re-runs the
    /// per-field reads so the failing offset in the message matches).
    /// `width <= 32`. This is the frame bit-unpack hot path.
    pub fn get_bits_bulk(&mut self, width: u32, out: &mut [u32]) -> Result<()> {
        debug_assert!(width <= 32);
        if width == 0 {
            out.fill(0);
            return Ok(());
        }
        let total = width as u64 * out.len() as u64;
        if self.pos + total > self.len {
            for o in out.iter_mut() {
                *o = self.get_bits(width)? as u32;
            }
            return Ok(());
        }
        let mut acc: u64 = 0;
        let mut nbits: u32 = 0;
        let mut byte_idx = (self.pos / 8) as usize;
        let offset = (self.pos % 8) as u32;
        if offset != 0 {
            let avail = 8 - offset;
            acc = (self.buf[byte_idx] & ((1u16 << avail) - 1) as u8) as u64;
            nbits = avail;
            byte_idx += 1;
        }
        for o in out.iter_mut() {
            // Refill whole bytes until a field fits: nbits < 32 before,
            // so nbits <= 39 after — consumed high bits above `nbits`
            // are garbage but the extraction mask ignores them.
            while nbits < width {
                acc = (acc << 8) | self.buf[byte_idx] as u64;
                byte_idx += 1;
                nbits += 8;
            }
            nbits -= width;
            *o = ((acc >> nbits) & ((1u64 << width) - 1)) as u32;
        }
        self.pos += total;
        Ok(())
    }

    pub fn get_f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.get_bits(32)? as u32))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{check, run_prop};

    #[test]
    fn single_bits_roundtrip() {
        let mut w = BitWriter::new();
        let pattern = [true, false, true, true, false, false, true, false, true];
        for &b in &pattern {
            w.put_bit(b);
        }
        assert_eq!(w.bit_len(), 9);
        let (bytes, bits) = w.finish();
        assert_eq!(bytes.len(), 2);
        let mut r = BitReader::with_bit_len(&bytes, bits);
        for &b in &pattern {
            assert_eq!(r.get_bit().unwrap(), b);
        }
        assert!(r.get_bit().is_err());
    }

    #[test]
    fn msb_first_layout() {
        let mut w = BitWriter::new();
        w.put_bits(0b101, 3);
        let (bytes, _) = w.finish();
        assert_eq!(bytes, vec![0b1010_0000]);
    }

    #[test]
    fn put_bits_get_bits_various_widths() {
        let mut w = BitWriter::new();
        w.put_bits(0x3, 2);
        w.put_bits(0xdead_beef, 32);
        w.put_bits(0x1_ffff_ffff, 33);
        w.put_bits(u64::MAX, 64);
        let (bytes, bits) = w.finish();
        let mut r = BitReader::with_bit_len(&bytes, bits);
        assert_eq!(r.get_bits(2).unwrap(), 0x3);
        assert_eq!(r.get_bits(32).unwrap(), 0xdead_beef);
        assert_eq!(r.get_bits(33).unwrap(), 0x1_ffff_ffff);
        assert_eq!(r.get_bits(64).unwrap(), u64::MAX);
    }

    #[test]
    fn f32_roundtrip_exact() {
        let vals = [0.0f32, -0.0, 1.5, -3.25e7, f32::MIN_POSITIVE, f32::MAX];
        let mut w = BitWriter::new();
        w.put_bit(true); // misalign on purpose
        for &v in &vals {
            w.put_f32(v);
        }
        let (bytes, bits) = w.finish();
        let mut r = BitReader::with_bit_len(&bytes, bits);
        r.get_bit().unwrap();
        for &v in &vals {
            assert_eq!(r.get_f32().unwrap().to_bits(), v.to_bits());
        }
    }

    #[test]
    fn aligned_byte_fast_path() {
        let mut w = BitWriter::new();
        w.put_u8(0xab);
        w.put_u8(0xcd);
        let (bytes, bits) = w.finish();
        assert_eq!(bytes, vec![0xab, 0xcd]);
        assert_eq!(bits, 16);
    }

    #[test]
    fn get_bit_or_zero_past_end() {
        let mut w = BitWriter::new();
        w.put_bit(true);
        let (bytes, bits) = w.finish();
        let mut r = BitReader::with_bit_len(&bytes, bits);
        assert!(r.get_bit_or_zero());
        assert!(!r.get_bit_or_zero());
        assert!(!r.get_bit_or_zero());
    }

    #[test]
    fn prop_random_bit_sequences_roundtrip() {
        run_prop("bitio_roundtrip", 200, |g| {
            let n = g.usize_in(0..=512);
            let mut bits_in = Vec::with_capacity(n);
            let mut w = BitWriter::new();
            for _ in 0..n {
                let b = g.rng().next_u32() & 1 == 1;
                bits_in.push(b);
                w.put_bit(b);
            }
            let (bytes, bits) = w.finish();
            check(bits == n as u64, format!("bit_len {bits} != {n}"))?;
            let mut r = BitReader::with_bit_len(&bytes, bits);
            for (i, &b) in bits_in.iter().enumerate() {
                if r.get_bit().map_err(|e| e.to_string())? != b {
                    return Err(format!("bit {i} mismatch"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_bulk_pack_matches_per_value_put_bits() {
        run_prop("bitio_bulk_pack", 200, |g| {
            let width = g.u32_in(1..=32);
            let n = g.usize_in(0..=300);
            let misalign = g.u32_in(0..=13);
            let vals: Vec<u32> =
                (0..n).map(|_| g.rng().next_u64() as u32 & mask32(width)).collect();

            let mut wa = BitWriter::new();
            let mut wb = BitWriter::new();
            wa.put_bits(0x155, misalign.min(9));
            wb.put_bits(0x155, misalign.min(9));
            wa.put_bits_bulk(&vals, width);
            for &v in &vals {
                wb.put_bits(v as u64, width);
            }
            // Trailing odd bits must land identically too.
            wa.put_bit(true);
            wb.put_bit(true);
            let (ba, la) = wa.finish();
            let (bb, lb) = wb.finish();
            check(la == lb, format!("bit_len {la} != {lb}"))?;
            check(ba == bb, format!("bytes differ (w={width}, n={n})"))
        });
    }

    #[test]
    fn prop_bulk_unpack_matches_per_value_get_bits() {
        run_prop("bitio_bulk_unpack", 200, |g| {
            let width = g.u32_in(1..=32);
            let n = g.usize_in(0..=300);
            let misalign = g.u32_in(0..=13).min(9);
            let vals: Vec<u32> =
                (0..n).map(|_| g.rng().next_u64() as u32 & mask32(width)).collect();
            let mut w = BitWriter::new();
            w.put_bits(0x0f3, misalign);
            w.put_bits_bulk(&vals, width);
            let (bytes, bits) = w.finish();

            let mut r = BitReader::with_bit_len(&bytes, bits);
            r.get_bits(misalign).map_err(|e| e.to_string())?;
            let mut got = vec![0u32; n];
            r.get_bits_bulk(width, &mut got).map_err(|e| e.to_string())?;
            check(got == vals, format!("values differ (w={width}, n={n})"))?;
            check(
                r.bits_remaining() == 0,
                format!("reader left {} bits", r.bits_remaining()),
            )
        });
    }

    #[test]
    fn bulk_unpack_underrun_reports_same_error_as_per_value() {
        let mut w = BitWriter::new();
        w.put_bits_bulk(&[1, 2, 3], 5);
        let (bytes, bits) = w.finish();
        let mut out = [0u32; 4]; // one field too many
        let mut ra = BitReader::with_bit_len(&bytes, bits);
        let ea = ra.get_bits_bulk(5, &mut out).unwrap_err().to_string();
        let mut rb = BitReader::with_bit_len(&bytes, bits);
        let eb = (0..4)
            .map(|_| rb.get_bits(5).map(|_| ()))
            .collect::<Result<Vec<_>>>()
            .unwrap_err()
            .to_string();
        assert_eq!(ea, eb);
    }

    #[test]
    fn bulk_zero_width_is_noop() {
        let mut w = BitWriter::new();
        w.put_bit(true);
        w.put_bits_bulk(&[7, 7], 0);
        assert_eq!(w.bit_len(), 1);
        let (bytes, bits) = w.finish();
        let mut r = BitReader::with_bit_len(&bytes, bits);
        let mut out = [9u32; 2];
        r.get_bits_bulk(0, &mut out).unwrap();
        assert_eq!(out, [0, 0]);
        assert_eq!(r.position(), 0);
    }

    fn mask32(width: u32) -> u32 {
        (((1u64 << width) - 1) & u32::MAX as u64) as u32
    }

    #[test]
    fn prop_mixed_width_writes_roundtrip() {
        run_prop("bitio_mixed_widths", 200, |g| {
            let m = g.usize_in(1..=64);
            let mut vals = Vec::new();
            let mut w = BitWriter::new();
            for _ in 0..m {
                let width = g.u32_in(1..=64);
                let v = g.rng().next_u64() & (u64::MAX >> (64 - width));
                vals.push((v, width));
                w.put_bits(v, width);
            }
            let (bytes, bits) = w.finish();
            let mut r = BitReader::with_bit_len(&bytes, bits);
            for &(v, width) in &vals {
                let got = r.get_bits(width).map_err(|e| e.to_string())?;
                check(got == v, format!("width={width}: {got:#x} != {v:#x}"))?;
            }
            Ok(())
        });
    }
}
