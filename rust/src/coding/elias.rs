//! Elias γ and δ universal integer codes (Elias 1975, the paper's
//! reference [11] — the coding QSGD builds on).
//!
//! Used here (a) as one of the histogram-header modes in [`super::histogram`]
//! and (b) as a standalone comparator coder in the ablation benches.
//! Both code positive integers `n >= 1`; helpers for `u64 >= 0` shift by one.

use anyhow::Result;

use super::bitio::{BitReader, BitWriter};

/// Number of bits in the γ code of `n` (n >= 1): `2⌊log₂n⌋ + 1`.
pub fn gamma_len(n: u64) -> u32 {
    debug_assert!(n >= 1);
    2 * (63 - n.leading_zeros()) + 1
}

/// Encode `n >= 1` in Elias γ.
pub fn put_gamma(w: &mut BitWriter, n: u64) {
    assert!(n >= 1, "elias gamma encodes n >= 1");
    let bits = 64 - n.leading_zeros(); // position of MSB + 1
    for _ in 0..bits - 1 {
        w.put_bit(false);
    }
    w.put_bits(n, bits);
}

/// Decode one Elias γ value.
pub fn get_gamma(r: &mut BitReader) -> Result<u64> {
    let mut zeros = 0u32;
    while !r.get_bit()? {
        zeros += 1;
        anyhow::ensure!(zeros < 64, "malformed gamma code (>= 64 leading zeros)");
    }
    let rest = if zeros == 0 { 0 } else { r.get_bits(zeros)? };
    Ok((1u64 << zeros) | rest)
}

/// Number of bits in the δ code of `n` (n >= 1).
pub fn delta_len(n: u64) -> u32 {
    debug_assert!(n >= 1);
    let nb = 63 - n.leading_zeros(); // ⌊log₂ n⌋
    gamma_len(nb as u64 + 1) + nb
}

/// Encode `n >= 1` in Elias δ (γ-coded bit-length, then the mantissa).
pub fn put_delta(w: &mut BitWriter, n: u64) {
    assert!(n >= 1, "elias delta encodes n >= 1");
    let nb = 63 - n.leading_zeros(); // ⌊log₂ n⌋
    put_gamma(w, nb as u64 + 1);
    if nb > 0 {
        w.put_bits(n & !(1u64 << nb), nb); // mantissa without leading 1
    }
}

/// Decode one Elias δ value.
pub fn get_delta(r: &mut BitReader) -> Result<u64> {
    let nb = get_gamma(r)? - 1;
    anyhow::ensure!(nb < 64, "malformed delta code");
    let mantissa = if nb == 0 { 0 } else { r.get_bits(nb as u32)? };
    Ok((1u64 << nb) | mantissa)
}

/// δ-encode a non-negative integer (shifts by one).
pub fn put_delta_u64(w: &mut BitWriter, n: u64) {
    put_delta(w, n + 1);
}

/// Decode the non-negative-integer variant.
pub fn get_delta_u64(r: &mut BitReader) -> Result<u64> {
    Ok(get_delta(r)? - 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{check, run_prop};

    #[test]
    fn gamma_known_codewords() {
        // classic table: 1->"1", 2->"010", 3->"011", 4->"00100"
        for (n, expect_bits, expect_len) in
            [(1u64, 0b1u64, 1u32), (2, 0b010, 3), (3, 0b011, 3), (4, 0b00100, 5)]
        {
            let mut w = BitWriter::new();
            put_gamma(&mut w, n);
            let (bytes, bits) = w.finish();
            assert_eq!(bits, expect_len as u64, "n={n}");
            assert_eq!(gamma_len(n), expect_len);
            let mut r = BitReader::with_bit_len(&bytes, bits);
            assert_eq!(r.get_bits(expect_len).unwrap(), expect_bits, "n={n}");
        }
    }

    #[test]
    fn delta_known_lengths() {
        // delta lengths: 1->1, 2->4, 3->4, 4->5, 8->8, 16->9
        for (n, len) in [(1u64, 1u32), (2, 4), (3, 4), (4, 5), (8, 8), (16, 9)] {
            assert_eq!(delta_len(n), len, "n={n}");
            let mut w = BitWriter::new();
            put_delta(&mut w, n);
            assert_eq!(w.bit_len(), len as u64, "n={n}");
        }
    }

    #[test]
    fn gamma_rejects_zero() {
        let result = std::panic::catch_unwind(|| {
            let mut w = BitWriter::new();
            put_gamma(&mut w, 0);
        });
        assert!(result.is_err());
    }

    #[test]
    fn boundary_values_roundtrip() {
        let vals = [1u64, 2, 3, 4, 7, 8, 255, 256, u32::MAX as u64, 1 << 62];
        for &v in &vals {
            let mut w = BitWriter::new();
            put_gamma(&mut w, v);
            put_delta(&mut w, v);
            let (bytes, bits) = w.finish();
            let mut r = BitReader::with_bit_len(&bytes, bits);
            assert_eq!(get_gamma(&mut r).unwrap(), v);
            assert_eq!(get_delta(&mut r).unwrap(), v);
        }
    }

    #[test]
    fn prop_gamma_delta_roundtrip_with_reported_len() {
        run_prop("elias_roundtrip", 300, |g| {
            let n = g.usize_in(1..=40);
            let mut vals = Vec::new();
            let mut w = BitWriter::new();
            let mut expect_bits = 0u64;
            for _ in 0..n {
                // bias toward small values but cover the whole range
                let shift = g.u32_in(0..=62);
                let v = (g.rng().next_u64() >> shift).max(1);
                vals.push(v);
                put_gamma(&mut w, v);
                put_delta_u64(&mut w, v - 1);
                expect_bits += gamma_len(v) as u64 + delta_len(v) as u64;
            }
            let (bytes, bits) = w.finish();
            check(bits == expect_bits, format!("len {bits} != predicted {expect_bits}"))?;
            let mut r = BitReader::with_bit_len(&bytes, bits);
            for &v in &vals {
                let a = get_gamma(&mut r).map_err(|e| e.to_string())?;
                let b = get_delta_u64(&mut r).map_err(|e| e.to_string())?;
                check(a == v, format!("gamma {a} != {v}"))?;
                check(b == v - 1, format!("delta {b} != {}", v - 1))?;
            }
            Ok(())
        });
    }
}
