//! Minimal arbitrary-precision unsigned integer, just big enough for the
//! enumerative histogram code: `C(d+k-1, k-1)` at d=4096, k=65 is ~2^300,
//! far past u128. Little-endian u64 limbs; only the operations the
//! combinatorial ranking needs (add, sub, cmp, mul/div by small, bit I/O).

use anyhow::Result;

use super::bitio::{BitReader, BitWriter};

/// Little-endian multi-limb unsigned integer.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BigUint {
    /// Invariant: no trailing zero limbs (canonical form); empty = 0.
    limbs: Vec<u64>,
}

impl BigUint {
    pub fn zero() -> Self {
        BigUint { limbs: Vec::new() }
    }

    pub fn one() -> Self {
        BigUint { limbs: vec![1] }
    }

    pub fn from_u64(v: u64) -> Self {
        if v == 0 {
            Self::zero()
        } else {
            BigUint { limbs: vec![v] }
        }
    }

    pub fn is_zero(&self) -> bool {
        self.limbs.is_empty()
    }

    fn trim(&mut self) {
        while self.limbs.last() == Some(&0) {
            self.limbs.pop();
        }
    }

    /// Number of significant bits (0 for zero).
    pub fn bits(&self) -> u32 {
        match self.limbs.last() {
            None => 0,
            Some(&top) => (self.limbs.len() as u32 - 1) * 64 + (64 - top.leading_zeros()),
        }
    }

    pub fn cmp_big(&self, other: &BigUint) -> std::cmp::Ordering {
        use std::cmp::Ordering;
        match self.limbs.len().cmp(&other.limbs.len()) {
            Ordering::Equal => {}
            ord => return ord,
        }
        for i in (0..self.limbs.len()).rev() {
            match self.limbs[i].cmp(&other.limbs[i]) {
                Ordering::Equal => {}
                ord => return ord,
            }
        }
        std::cmp::Ordering::Equal
    }

    pub fn add_assign(&mut self, other: &BigUint) {
        let mut carry = 0u64;
        for i in 0..other.limbs.len().max(self.limbs.len()) {
            if i >= self.limbs.len() {
                self.limbs.push(0);
            }
            let b = other.limbs.get(i).copied().unwrap_or(0);
            let (s1, c1) = self.limbs[i].overflowing_add(b);
            let (s2, c2) = s1.overflowing_add(carry);
            self.limbs[i] = s2;
            carry = (c1 as u64) + (c2 as u64);
        }
        if carry > 0 {
            self.limbs.push(carry);
        }
    }

    /// `self -= other`; panics if other > self (caller guarantees order).
    pub fn sub_assign(&mut self, other: &BigUint) {
        debug_assert!(self.cmp_big(other) != std::cmp::Ordering::Less, "bignum underflow");
        let mut borrow = 0u64;
        for i in 0..self.limbs.len() {
            let b = other.limbs.get(i).copied().unwrap_or(0);
            let (d1, b1) = self.limbs[i].overflowing_sub(b);
            let (d2, b2) = d1.overflowing_sub(borrow);
            self.limbs[i] = d2;
            borrow = (b1 as u64) + (b2 as u64);
        }
        assert_eq!(borrow, 0, "bignum underflow");
        self.trim();
    }

    pub fn mul_small(&mut self, m: u64) {
        if m == 0 {
            self.limbs.clear();
            return;
        }
        let mut carry = 0u128;
        for limb in &mut self.limbs {
            let prod = *limb as u128 * m as u128 + carry;
            *limb = prod as u64;
            carry = prod >> 64;
        }
        while carry > 0 {
            self.limbs.push(carry as u64);
            carry >>= 64;
        }
    }

    /// `self /= q`, returning the remainder.
    pub fn div_small(&mut self, q: u64) -> u64 {
        assert!(q > 0, "division by zero");
        let mut rem = 0u128;
        for limb in self.limbs.iter_mut().rev() {
            let cur = (rem << 64) | *limb as u128;
            *limb = (cur / q as u128) as u64;
            rem = cur % q as u128;
        }
        self.trim();
        rem as u64
    }

    /// Write exactly `width` bits of the value, MSB-first. Requires
    /// `self.bits() <= width`.
    pub fn put_bits(&self, w: &mut BitWriter, width: u32) {
        debug_assert!(self.bits() <= width, "value does not fit width");
        for i in (0..width).rev() {
            let limb = (i / 64) as usize;
            let bit = self
                .limbs
                .get(limb)
                .map(|&l| (l >> (i % 64)) & 1 == 1)
                .unwrap_or(false);
            w.put_bit(bit);
        }
    }

    /// Read a `width`-bit MSB-first value.
    pub fn get_bits(r: &mut BitReader, width: u32) -> Result<Self> {
        let mut v = BigUint::zero();
        let n_limbs = width.div_ceil(64) as usize;
        v.limbs.resize(n_limbs, 0);
        for i in (0..width).rev() {
            if r.get_bit()? {
                v.limbs[(i / 64) as usize] |= 1 << (i % 64);
            }
        }
        v.trim();
        Ok(v)
    }

    /// Lossy conversion for display/tests.
    pub fn to_f64(&self) -> f64 {
        self.limbs
            .iter()
            .rev()
            .fold(0.0f64, |acc, &l| acc * 2.0f64.powi(64) + l as f64)
    }
}

/// Number of compositions of `m` into `q` non-negative parts:
/// `C(m + q - 1, q - 1)`; for q = 0 it is 1 iff m == 0.
pub fn comp_count(m: u64, q: u64) -> BigUint {
    if q == 0 {
        return if m == 0 { BigUint::one() } else { BigUint::zero() };
    }
    // C(m + q - 1, q - 1) built multiplicatively: prod_{i=1..q-1} (m+i)/i —
    // each prefix is itself a binomial, so the division is exact.
    let mut c = BigUint::one();
    for i in 1..q {
        c.mul_small(m + i);
        let rem = c.div_small(i);
        debug_assert_eq!(rem, 0, "binomial division must be exact");
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{check, run_prop};

    #[test]
    fn small_arithmetic() {
        let mut a = BigUint::from_u64(u64::MAX);
        a.add_assign(&BigUint::one());
        assert_eq!(a.limbs, vec![0, 1]);
        assert_eq!(a.bits(), 65);
        a.sub_assign(&BigUint::one());
        assert_eq!(a, BigUint::from_u64(u64::MAX));
    }

    #[test]
    fn mul_div_roundtrip_across_limbs() {
        let mut a = BigUint::from_u64(0x1234_5678_9abc_def0);
        for m in [3u64, 1 << 40, 999_999_937] {
            a.mul_small(m);
        }
        let mut b = a.clone();
        assert_eq!(b.div_small(999_999_937), 0);
        assert_eq!(b.div_small(1 << 40), 0);
        assert_eq!(b.div_small(3), 0);
        assert_eq!(b, BigUint::from_u64(0x1234_5678_9abc_def0));
        assert!(a.cmp_big(&b) == std::cmp::Ordering::Greater);
    }

    #[test]
    fn comp_count_known_values() {
        // C(m+q-1, q-1): compositions of 4 into 3 parts = C(6,2) = 15
        assert_eq!(comp_count(4, 3).to_f64(), 15.0);
        assert_eq!(comp_count(0, 3).to_f64(), 1.0);
        assert_eq!(comp_count(5, 1).to_f64(), 1.0);
        assert_eq!(comp_count(0, 0).to_f64(), 1.0);
        assert!(comp_count(3, 0).is_zero());
        // C(1056, 32) ~ 6.3e61: check bit-length ballpark (205 bits)
        let big = comp_count(1024, 33);
        assert!((200..=210).contains(&big.bits()), "bits={}", big.bits());
    }

    #[test]
    fn bit_io_roundtrip() {
        let mut v = BigUint::one();
        for i in 1..40u64 {
            v.mul_small(i * 7 + 1);
        }
        let width = v.bits() + 3;
        let mut w = BitWriter::new();
        v.put_bits(&mut w, width);
        let (bytes, bits) = w.finish();
        assert_eq!(bits, width as u64);
        let mut r = BitReader::with_bit_len(&bytes, bits);
        let got = BigUint::get_bits(&mut r, width).unwrap();
        assert_eq!(got, v);
    }

    #[test]
    fn zero_io() {
        let z = BigUint::zero();
        let mut w = BitWriter::new();
        z.put_bits(&mut w, 10);
        let (bytes, bits) = w.finish();
        let mut r = BitReader::with_bit_len(&bytes, bits);
        assert!(BigUint::get_bits(&mut r, 10).unwrap().is_zero());
    }

    #[test]
    fn prop_add_sub_mul_div_consistency() {
        run_prop("bignum_ops", 200, |g| {
            let mut a = BigUint::from_u64(g.rng().next_u64());
            let mut ops: Vec<u64> = Vec::new();
            for _ in 0..g.usize_in(1..=12) {
                let m = g.rng().next_u64() >> 33 | 1; // odd-ish, nonzero
                ops.push(m);
                a.mul_small(m);
            }
            let mut b = a.clone();
            for &m in ops.iter().rev() {
                let rem = b.div_small(m);
                check(rem == 0, format!("rem={rem}"))?;
            }
            // b should equal the original seed value
            let mut c = b.clone();
            c.add_assign(&BigUint::from_u64(5));
            c.sub_assign(&BigUint::from_u64(5));
            check(c == b, "add/sub inverse failed")
        });
    }
}
