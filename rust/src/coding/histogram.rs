//! The `h_r` histogram header of the variable-length protocol (§4).
//!
//! A frame must tell the server how many coordinates landed in each of the
//! k bins before the entropy-coded payload can be decoded. Theorem 4 budgets
//! `⌈log₂ C(d+k−1, k−1)⌉ ≤ k log₂((d+k)e/k)` bits for this. We implement:
//!
//! * **Enumerative mode** — the exact information-theoretic code: rank the
//!   composition `(h_0, …, h_{k−1})` of d in lexicographic order and send
//!   the rank in exactly `⌈log₂ C(d+k−1, k−1)⌉` bits (bignum ranking).
//! * **Elias-δ mode** — each count as δ(h_r + 1); shorter when the
//!   histogram is very skewed (most bins empty).
//!
//! The encoder computes both, sends a 1-bit selector, then the cheaper one.
//! Both sides know (d, k) from the protocol config; they are not resent.

use anyhow::{ensure, Result};

use super::bignum::{comp_count, BigUint};
use super::bitio::{BitReader, BitWriter};
use super::elias;

/// Bits the enumerative code uses for a (d, k) histogram (excl. selector):
/// exactly `⌈log₂ C(d+k−1, k−1)⌉`.
pub fn enumerative_bits(d: u64, k: u64) -> u32 {
    rank_width(d, k)
}

fn rank_width(d: u64, k: u64) -> u32 {
    // Width = ceil(log2 N) where N = number of compositions: the rank is in
    // [0, N), so (N-1).bits() is exactly the needed width.
    let mut n = comp_count(d, k);
    if n.is_zero() {
        return 0;
    }
    n.sub_assign(&BigUint::one());
    n.bits()
}

/// Lexicographic rank of the composition `hist` (sum d, k parts).
fn rank(hist: &[u64], d: u64) -> BigUint {
    let k = hist.len() as u64;
    let mut rank = BigUint::zero();
    let mut rem = d;
    for (r, &h) in hist.iter().enumerate().take(hist.len() - 1) {
        let parts_after = k - r as u64 - 1;
        // term(v) = comp_count(rem - v, parts_after), added for v < h.
        let mut term = comp_count(rem, parts_after);
        for v in 0..h {
            rank.add_assign(&term);
            // term(v+1) = term(v) * (rem - v) / (rem - v + parts_after - 1)
            let m = rem - v;
            term.mul_small(m);
            let q = m + parts_after - 1;
            let r0 = term.div_small(q);
            debug_assert_eq!(r0, 0, "ratio update must be exact");
        }
        rem -= h;
    }
    rank
}

/// Inverse of [`rank`]: reconstruct the composition from its rank.
fn unrank(mut rank: BigUint, d: u64, k: usize) -> Vec<u64> {
    let mut hist = vec![0u64; k];
    let mut rem = d;
    for r in 0..k - 1 {
        let parts_after = (k - r - 1) as u64;
        let mut term = comp_count(rem, parts_after);
        let mut v = 0u64;
        while !term.is_zero() && rank.cmp_big(&term) != std::cmp::Ordering::Less {
            rank.sub_assign(&term);
            let m = rem - v;
            term.mul_small(m);
            let q = m + parts_after - 1;
            let r0 = term.div_small(q);
            debug_assert_eq!(r0, 0);
            v += 1;
        }
        hist[r] = v;
        rem -= v;
    }
    hist[k - 1] = rem;
    hist
}

/// Encode `hist` (must sum to `d`). Returns bits written.
pub fn encode(w: &mut BitWriter, hist: &[u64], d: u64) -> Result<u64> {
    ensure!(!hist.is_empty(), "empty histogram");
    let sum: u64 = hist.iter().sum();
    ensure!(sum == d, "histogram sums to {sum}, expected {d}");
    let k = hist.len() as u64;

    let enum_bits = rank_width(d, k) as u64;
    let delta_bits: u64 = hist.iter().map(|&h| elias::delta_len(h + 1) as u64).sum();

    let before = w.bit_len();
    if enum_bits <= delta_bits {
        w.put_bit(false); // selector 0: enumerative
        rank(hist, d).put_bits(w, enum_bits as u32);
    } else {
        w.put_bit(true); // selector 1: elias-delta
        for &h in hist {
            elias::put_delta(w, h + 1);
        }
    }
    Ok(w.bit_len() - before)
}

/// Decode a histogram with known (d, k).
pub fn decode(r: &mut BitReader, d: u64, k: usize) -> Result<Vec<u64>> {
    ensure!(k >= 1, "k must be >= 1");
    let selector = r.get_bit()?;
    let hist = if !selector {
        let width = rank_width(d, k as u64);
        let rank = BigUint::get_bits(r, width)?;
        unrank(rank, d, k)
    } else {
        let mut hist = Vec::with_capacity(k);
        for _ in 0..k {
            let v = elias::get_delta(r)?;
            ensure!(v >= 1, "malformed histogram count");
            hist.push(v - 1);
        }
        hist
    };
    let sum: u64 = hist.iter().sum();
    ensure!(sum == d, "decoded histogram sums to {sum}, expected {d}");
    Ok(hist)
}

/// The paper's analytic header bound: `k log₂((d+k)e/k)` bits (Theorem 4).
pub fn paper_bound_bits(d: u64, k: u64) -> f64 {
    k as f64 * (((d + k) as f64 * std::f64::consts::E) / k as f64).log2()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{check, run_prop};

    fn roundtrip(hist: &[u64]) -> u64 {
        let d: u64 = hist.iter().sum();
        let mut w = BitWriter::new();
        let bits = encode(&mut w, hist, d).unwrap();
        let (bytes, blen) = w.finish();
        assert_eq!(bits, blen);
        let mut r = BitReader::with_bit_len(&bytes, blen);
        let got = decode(&mut r, d, hist.len()).unwrap();
        assert_eq!(got, hist, "roundtrip mismatch");
        bits
    }

    #[test]
    fn small_exhaustive_compositions_roundtrip() {
        // all compositions of 5 into 3 parts
        for a in 0..=5u64 {
            for b in 0..=(5 - a) {
                let c = 5 - a - b;
                roundtrip(&[a, b, c]);
            }
        }
    }

    #[test]
    fn ranks_are_unique_and_dense() {
        // d=4, k=3: C(6,2)=15 compositions; ranks must be a permutation of 0..15
        let mut seen = std::collections::HashSet::new();
        for a in 0..=4u64 {
            for b in 0..=(4 - a) {
                let h = [a, b, 4 - a - b];
                let r = rank(&h, 4);
                let as_u = r.to_f64() as u64;
                assert!(seen.insert(as_u), "duplicate rank {as_u} for {h:?}");
                assert!(as_u < 15);
                assert_eq!(unrank(rank(&h, 4), 4, 3), h.to_vec());
            }
        }
        assert_eq!(seen.len(), 15);
    }

    #[test]
    fn header_cost_within_paper_bound() {
        // uniform-ish histogram at the paper's scales
        for (d, k) in [(1024u64, 33usize), (512, 17), (256, 16)] {
            let base = d / k as u64;
            let mut hist = vec![base; k];
            let mut left = d - base * k as u64;
            let mut i = 0;
            while left > 0 {
                hist[i] += 1;
                left -= 1;
                i += 1;
            }
            let bits = roundtrip(&hist);
            let bound = paper_bound_bits(d, k as u64) + 1.0; // +1 selector
            assert!(
                (bits as f64) <= bound,
                "d={d} k={k}: bits={bits} > bound={bound:.1}"
            );
        }
    }

    #[test]
    fn skewed_histogram_picks_delta_mode() {
        // everything in one bin out of many: delta mode should win and be tiny
        let mut hist = vec![0u64; 64];
        hist[0] = 4096;
        let bits = roundtrip(&hist);
        // delta: delta(4097) + 63 * delta(1) = ~25 + 63 = ~88 bits
        assert!(bits < 120, "bits={bits}");
    }

    #[test]
    fn degenerate_shapes() {
        roundtrip(&[7]); // k=1: zero information
        assert_eq!(roundtrip(&[7]), 1); // selector bit only
        roundtrip(&[0, 0]); // d=0
        roundtrip(&[3, 0, 0, 0]);
        roundtrip(&[0, 0, 0, 3]);
    }

    #[test]
    fn sum_mismatch_rejected() {
        let mut w = BitWriter::new();
        assert!(encode(&mut w, &[1, 2], 5).is_err());
    }

    #[test]
    fn prop_random_histograms_roundtrip_under_bound() {
        run_prop("histogram_roundtrip", 100, |g| {
            let k = g.usize_in(1..=40);
            let d = g.usize_in(0..=2000) as u64;
            // random composition of d into k parts
            let mut hist = vec![0u64; k];
            for _ in 0..d {
                let i = g.rng().next_below(k as u32) as usize;
                hist[i] += 1;
            }
            let mut w = BitWriter::new();
            let bits = encode(&mut w, &hist, d).map_err(|e| e.to_string())?;
            let (bytes, blen) = w.finish();
            let mut r = BitReader::with_bit_len(&bytes, blen);
            let got = decode(&mut r, d, k).map_err(|e| e.to_string())?;
            check(got == hist, format!("mismatch {got:?} != {hist:?}"))?;
            let bound = paper_bound_bits(d, k as u64) + 1.0;
            check(
                (bits as f64) <= bound.max(2.0),
                format!("d={d} k={k} bits={bits} bound={bound:.1}"),
            )
        });
    }
}
