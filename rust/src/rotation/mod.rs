//! The paper's structured random rotation `R = HD` (§3): a Rademacher
//! diagonal `D` drawn from **public randomness** followed by the
//! Walsh–Hadamard transform `H`, normalized to be orthogonal. Applying
//! R or R⁻¹ costs O(d log d) time and O(1) extra space.
//!
//! Vectors whose dimension is not a power of two are zero-padded
//! ([`hadamard::pad_dim`]); since R is orthogonal and the server knows d,
//! the inverse rotation restores the padding to (near-)zero and the first
//! d coordinates are returned.
//!
//! The FWHT ships two implementations — a scalar reference and an AVX2
//! radix-4 kernel — selected at runtime through [`crate::simd`]; they
//! are bit-identical by construction, so the dispatch never affects the
//! wire bits (see [`hadamard`] for why the fused passes round the same).

pub mod hadamard;

use crate::rng::Pcg64;

/// A sampled rotation: the Rademacher diagonal of `R = HD` for one round.
/// `H` is implicit (the FWHT); only `D`'s signs are materialized.
#[derive(Clone, Debug)]
pub struct Rotation {
    /// ±1 diagonal, length = padded dimension.
    sign: Vec<f32>,
    /// Original (logical) dimension, ≤ sign.len().
    dim: usize,
}

impl Rotation {
    /// Draw the round's rotation from a public-randomness stream. Every
    /// party calling this with the same stream state derives the same `R`
    /// (footnote 1 of the paper: a shared seed emulates public randomness).
    pub fn sample(dim: usize, public: &mut Pcg64) -> Self {
        let padded = hadamard::pad_dim(dim);
        let mut sign = vec![0.0f32; padded];
        public.fill_rademacher(&mut sign);
        Rotation { sign, dim }
    }

    /// Logical (unpadded) dimension.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Padded power-of-two dimension: the length rotated vectors have.
    pub fn padded_dim(&self) -> usize {
        self.sign.len()
    }

    /// The ±1 diagonal (exposed for the PJRT engine, which passes it as a
    /// tensor input to the compiled `rotate_*` HLO).
    pub fn signs(&self) -> &[f32] {
        &self.sign
    }

    /// `z = R x` (padding x with zeros to the power-of-two length).
    pub fn forward(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.dim, "dimension mismatch");
        let mut z = vec![0.0f32; self.padded_dim()];
        for (zi, (xi, si)) in z.iter_mut().zip(x.iter().zip(&self.sign)) {
            *zi = xi * si;
        }
        hadamard::fwht_normalized(&mut z);
        z
    }

    /// `x = R⁻¹ z`, truncated back to the logical dimension.
    pub fn inverse(&self, z: &[f32]) -> Vec<f32> {
        assert_eq!(z.len(), self.padded_dim(), "padded dimension mismatch");
        let mut x = z.to_vec();
        hadamard::fwht_normalized(&mut x);
        for (xi, si) in x.iter_mut().zip(&self.sign) {
            *xi *= si;
        }
        x.truncate(self.dim);
        x
    }

    /// In-place forward rotation of an already-padded buffer (hot path;
    /// avoids the allocation in [`Rotation::forward`]).
    pub fn forward_in_place(&self, buf: &mut [f32]) {
        assert_eq!(buf.len(), self.padded_dim());
        for (v, s) in buf.iter_mut().zip(&self.sign) {
            *v *= s;
        }
        hadamard::fwht_normalized(buf);
    }

    /// In-place inverse rotation of a padded buffer.
    pub fn inverse_in_place(&self, buf: &mut [f32]) {
        assert_eq!(buf.len(), self.padded_dim());
        hadamard::fwht_normalized(buf);
        for (v, s) in buf.iter_mut().zip(&self.sign) {
            *v *= s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg;
    use crate::rng;
    use crate::testkit::{check, run_prop};

    #[test]
    fn roundtrip_power_of_two() {
        let mut pubrng = rng::public_stream(1, 0);
        let rot = Rotation::sample(64, &mut pubrng);
        let mut rng2 = Pcg64::new(5);
        let mut x = vec![0.0f32; 64];
        rng2.fill_gaussian_f32(&mut x);
        let back = rot.inverse(&rot.forward(&x));
        for (a, b) in back.iter().zip(&x) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn roundtrip_with_padding() {
        let mut pubrng = rng::public_stream(2, 0);
        let rot = Rotation::sample(100, &mut pubrng); // pads to 128
        assert_eq!(rot.padded_dim(), 128);
        let mut rng2 = Pcg64::new(6);
        let mut x = vec![0.0f32; 100];
        rng2.fill_gaussian_f32(&mut x);
        let z = rot.forward(&x);
        assert_eq!(z.len(), 128);
        let back = rot.inverse(&z);
        assert_eq!(back.len(), 100);
        for (a, b) in back.iter().zip(&x) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn norm_preserved_including_padding() {
        let mut pubrng = rng::public_stream(3, 7);
        let rot = Rotation::sample(60, &mut pubrng);
        let x = vec![0.5f32; 60];
        let z = rot.forward(&x);
        assert!((linalg::norm_sq(&z) - linalg::norm_sq(&x)).abs() < 1e-4);
    }

    #[test]
    fn same_public_stream_same_rotation() {
        let a = Rotation::sample(32, &mut rng::public_stream(9, 4));
        let b = Rotation::sample(32, &mut rng::public_stream(9, 4));
        assert_eq!(a.signs(), b.signs());
        let c = Rotation::sample(32, &mut rng::public_stream(9, 5));
        assert_ne!(a.signs(), c.signs());
    }

    #[test]
    fn one_hot_becomes_flat() {
        // Lemma 7 intuition: the rotated one-hot has |z_j| = 1/sqrt(d).
        let mut pubrng = rng::public_stream(4, 0);
        let d = 256;
        let rot = Rotation::sample(d, &mut pubrng);
        let mut x = vec![0.0f32; d];
        x[17] = 1.0;
        let z = rot.forward(&x);
        let expect = 1.0 / (d as f32).sqrt();
        for &v in &z {
            assert!((v.abs() - expect).abs() < 1e-5, "v={v} expect |{expect}|");
        }
    }

    #[test]
    fn in_place_variants_match_allocating() {
        let mut pubrng = rng::public_stream(11, 0);
        let rot = Rotation::sample(128, &mut pubrng);
        let mut rng2 = Pcg64::new(12);
        let mut x = vec![0.0f32; 128];
        rng2.fill_gaussian_f32(&mut x);
        let z = rot.forward(&x);
        let mut buf = x.clone();
        rot.forward_in_place(&mut buf);
        assert_eq!(buf, z);
        let back = rot.inverse(&z);
        rot.inverse_in_place(&mut buf);
        assert_eq!(&buf[..128], back.as_slice());
    }

    #[test]
    fn prop_rotation_is_isometry_any_dim() {
        run_prop("rotation_isometry", 80, |g| {
            let d = g.usize_in(1..=300);
            let seed = g.rng().next_u64();
            let rot = Rotation::sample(d, &mut rng::public_stream(seed, 0));
            let x = g.vec_f32(d..=d, -5.0, 5.0);
            let z = rot.forward(&x);
            let n_x = linalg::norm_sq(&x);
            let n_z = linalg::norm_sq(&z);
            check(
                (n_x - n_z).abs() <= 1e-3 * (1.0 + n_x),
                format!("d={d} norms {n_x} vs {n_z}"),
            )?;
            let back = rot.inverse(&z);
            let err = linalg::dist_sq(&back, &x);
            check(err < 1e-6 * (1.0 + n_x), format!("roundtrip err {err}"))
        });
    }
}
