//! In-place fast Walsh–Hadamard transform: the `H` of the paper's
//! structured rotation `R = HD` (§3), O(d log d) time, O(1) extra space.
//!
//! This is the native-Rust twin of the Pallas kernel
//! (`python/compile/kernels/hadamard.py`); both are validated against the
//! same dense-matrix oracle.
//!
//! Two implementations, dispatched through [`crate::simd`]:
//! [`fwht_scalar`] (the reference; its hot loop is written so LLVM can
//! auto-vectorize the contiguous stride-`h` butterflies) and an AVX2
//! kernel that runs the first three stages in registers and fuses later
//! stages pairwise into radix-4 passes (half the memory sweeps). The two
//! are **bit-identical**: a butterfly is an elementwise `u+v` / `u−v`
//! with a fixed stage order, and the radix-4 fusion evaluates literally
//! the same sums with the same association (`(a+b)+(c+e)` is what two
//! sequential stages compute), so no f32 rounding can differ.

/// Unnormalized in-place FWHT. `x.len()` must be a power of two.
///
/// After the call, `x = H x` with `H` the ±1 Sylvester/Walsh-Hadamard
/// matrix. `fwht(fwht(x)) == d * x`.
pub fn fwht(x: &mut [f32]) {
    let d = x.len();
    assert!(d.is_power_of_two(), "FWHT needs power-of-two length, got {d}");
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if d >= 8 && crate::simd::use_x86_vector() {
        // SAFETY: gated on runtime AVX2 detection.
        unsafe { avx2::fwht(x) };
        return;
    }
    fwht_scalar(x);
}

/// The scalar reference FWHT — the executable specification the AVX2
/// kernel is conformance-tested against.
pub fn fwht_scalar(x: &mut [f32]) {
    let d = x.len();
    assert!(d.is_power_of_two(), "FWHT needs power-of-two length, got {d}");
    let mut h = 1;
    // The h=1 and h=2 stages have 1- and 2-lane butterflies that defeat
    // auto-vectorization when expressed via split_at_mut; fuse them into a
    // single radix-4 pass over contiguous 4-blocks (one load/store per
    // element for two stages, and a vectorizable straight-line body).
    if d >= 4 {
        for q in x.chunks_exact_mut(4) {
            let (a, b, c, e) = (q[0], q[1], q[2], q[3]);
            let (s0, d0, s1, d1) = (a + b, a - b, c + e, c - e);
            q[0] = s0 + s1;
            q[1] = d0 + d1;
            q[2] = s0 - s1;
            q[3] = d0 - d1;
        }
        h = 4;
    } else if d >= 2 {
        for q in x.chunks_exact_mut(2) {
            let (a, b) = (q[0], q[1]);
            q[0] = a + b;
            q[1] = a - b;
        }
        h = 2;
    }
    while h < d {
        let step = h * 2;
        let mut base = 0;
        while base < d {
            // Butterfly the two halves of this block; the compiler
            // vectorizes this loop (no bounds checks after the split).
            let (lo_half, hi_half) = x[base..base + step].split_at_mut(h);
            for (a, b) in lo_half.iter_mut().zip(hi_half.iter_mut()) {
                let u = *a;
                let v = *b;
                *a = u + v;
                *b = u - v;
            }
            base += step;
        }
        h = step;
    }
}

/// Orthonormal FWHT: `x ← (1/√d) H x`. Self-inverse.
pub fn fwht_normalized(x: &mut [f32]) {
    fwht(x);
    let inv = 1.0 / (x.len() as f32).sqrt();
    for v in x.iter_mut() {
        *v *= inv;
    }
}

/// AVX2 FWHT. Stage order and operand order match [`fwht_scalar`]
/// exactly (see the module docs for why the radix-4 fusion cannot change
/// a bit).
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod avx2 {
    #[cfg(target_arch = "x86_64")]
    use std::arch::x86_64::*;

    /// One in-register pass of the h ∈ {1, 2, 4} stages over eight
    /// contiguous lanes: swap partners, add/sub, blend — the partner
    /// order puts `u+v` in the low lane and `u−v` in the high lane,
    /// matching the scalar butterflies.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn stage8(v: __m256) -> __m256 {
        // h=1: partners are adjacent lanes.
        let p = _mm256_permute_ps::<0b10_11_00_01>(v);
        let v = _mm256_blend_ps::<0b1010_1010>(_mm256_add_ps(v, p), _mm256_sub_ps(p, v));
        // h=2: partners are lane pairs.
        let p = _mm256_permute_ps::<0b01_00_11_10>(v);
        let v = _mm256_blend_ps::<0b1100_1100>(_mm256_add_ps(v, p), _mm256_sub_ps(p, v));
        // h=4: partners are 128-bit halves.
        let p = _mm256_permute2f128_ps::<0x01>(v, v);
        _mm256_blend_ps::<0b1111_0000>(_mm256_add_ps(v, p), _mm256_sub_ps(p, v))
    }

    /// SAFETY: caller must ensure AVX2 is available; `x.len()` must be a
    /// power of two ≥ 8.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn fwht(x: &mut [f32]) {
        let d = x.len();
        debug_assert!(d.is_power_of_two() && d >= 8);
        let ptr = x.as_mut_ptr();
        // Stages h = 1, 2, 4 in registers, one load/store per element.
        let mut i = 0;
        while i < d {
            let v = _mm256_loadu_ps(ptr.add(i));
            _mm256_storeu_ps(ptr.add(i), stage8(v));
            i += 8;
        }
        // Stages h >= 8: pairwise-fused radix-4 passes (stages h and 2h
        // in one sweep), with a single radix-2 pass when one stage is
        // left over.
        let mut h = 8;
        while h * 2 < d {
            let step = h * 4;
            let mut base = 0;
            while base < d {
                let mut i = 0;
                while i < h {
                    let p = base + i;
                    let a = _mm256_loadu_ps(ptr.add(p));
                    let b = _mm256_loadu_ps(ptr.add(p + h));
                    let c = _mm256_loadu_ps(ptr.add(p + 2 * h));
                    let e = _mm256_loadu_ps(ptr.add(p + 3 * h));
                    let s0 = _mm256_add_ps(a, b);
                    let d0 = _mm256_sub_ps(a, b);
                    let s1 = _mm256_add_ps(c, e);
                    let d1 = _mm256_sub_ps(c, e);
                    _mm256_storeu_ps(ptr.add(p), _mm256_add_ps(s0, s1));
                    _mm256_storeu_ps(ptr.add(p + h), _mm256_add_ps(d0, d1));
                    _mm256_storeu_ps(ptr.add(p + 2 * h), _mm256_sub_ps(s0, s1));
                    _mm256_storeu_ps(ptr.add(p + 3 * h), _mm256_sub_ps(d0, d1));
                    i += 8;
                }
                base += step;
            }
            h *= 4;
        }
        if h < d {
            // Final lone stage (log2(d/8) was odd).
            let step = h * 2;
            let mut base = 0;
            while base < d {
                let mut i = 0;
                while i < h {
                    let p = base + i;
                    let u = _mm256_loadu_ps(ptr.add(p));
                    let v = _mm256_loadu_ps(ptr.add(p + h));
                    _mm256_storeu_ps(ptr.add(p), _mm256_add_ps(u, v));
                    _mm256_storeu_ps(ptr.add(p + h), _mm256_sub_ps(u, v));
                    i += 8;
                }
                base += step;
            }
        }
    }
}

/// Next power of two ≥ `d` (vectors are zero-padded to this length before
/// rotation; padding survives the round trip because R is orthogonal).
pub fn pad_dim(d: usize) -> usize {
    d.next_power_of_two()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::testkit::{check, run_prop};

    /// Dense H for the oracle (kept tiny; tests use d <= 256).
    fn dense_h(d: usize) -> Vec<Vec<f32>> {
        let mut h = vec![vec![1.0f32]];
        while h.len() < d {
            let n = h.len();
            let mut next = vec![vec![0.0f32; 2 * n]; 2 * n];
            for i in 0..n {
                for j in 0..n {
                    next[i][j] = h[i][j];
                    next[i][j + n] = h[i][j];
                    next[i + n][j] = h[i][j];
                    next[i + n][j + n] = -h[i][j];
                }
            }
            h = next;
        }
        h
    }

    fn dense_apply(x: &[f32]) -> Vec<f32> {
        let h = dense_h(x.len());
        h.iter()
            .map(|row| row.iter().zip(x).map(|(&a, &b)| a * b).sum())
            .collect()
    }

    #[test]
    fn matches_dense_oracle() {
        for d in [1usize, 2, 4, 16, 64, 256] {
            let mut rng = Pcg64::new(d as u64);
            let mut x = vec![0.0f32; d];
            rng.fill_gaussian_f32(&mut x);
            let want = dense_apply(&x);
            fwht(&mut x);
            for (a, b) in x.iter().zip(&want) {
                assert!((a - b).abs() < 1e-3, "d={d}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn hadamard_2x2_by_hand() {
        let mut x = vec![3.0f32, 5.0];
        fwht(&mut x);
        assert_eq!(x, vec![8.0, -2.0]);
    }

    #[test]
    fn self_inverse_up_to_d() {
        let mut rng = Pcg64::new(9);
        let mut x = vec![0.0f32; 128];
        rng.fill_gaussian_f32(&mut x);
        let orig = x.clone();
        fwht(&mut x);
        fwht(&mut x);
        for (a, b) in x.iter().zip(&orig) {
            assert!((a - 128.0 * b).abs() < 1e-2);
        }
    }

    #[test]
    fn normalized_is_isometry_and_involution() {
        let mut rng = Pcg64::new(10);
        let mut x = vec![0.0f32; 64];
        rng.fill_gaussian_f32(&mut x);
        let orig = x.clone();
        let n0: f32 = x.iter().map(|v| v * v).sum();
        fwht_normalized(&mut x);
        let n1: f32 = x.iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() / n0 < 1e-5);
        fwht_normalized(&mut x);
        for (a, b) in x.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    #[should_panic(expected = "power-of-two")]
    fn rejects_non_power_of_two() {
        fwht(&mut [0.0; 12]);
    }

    #[test]
    fn pad_dim_values() {
        assert_eq!(pad_dim(1), 1);
        assert_eq!(pad_dim(2), 2);
        assert_eq!(pad_dim(3), 4);
        assert_eq!(pad_dim(1000), 1024);
        assert_eq!(pad_dim(1024), 1024);
    }

    #[test]
    fn prop_linearity_and_parseval() {
        run_prop("fwht_props", 100, |g| {
            let d = g.pow2(0, 9);
            let mut x = vec![0.0f32; d];
            let mut y = vec![0.0f32; d];
            g.rng().fill_gaussian_f32(&mut x);
            g.rng().fill_gaussian_f32(&mut y);
            // linearity: H(x + y) = Hx + Hy
            let mut xy: Vec<f32> = x.iter().zip(&y).map(|(a, b)| a + b).collect();
            let mut hx = x.clone();
            let mut hy = y.clone();
            fwht(&mut xy);
            fwht(&mut hx);
            fwht(&mut hy);
            for i in 0..d {
                let diff = (xy[i] - hx[i] - hy[i]).abs();
                check(diff < 1e-2 * (d as f32), format!("linearity diff {diff} at {i}"))?;
            }
            // Parseval: ||Hx||^2 = d ||x||^2
            let nx: f64 = x.iter().map(|&v| v as f64 * v as f64).sum();
            let nhx: f64 = hx.iter().map(|&v| v as f64 * v as f64).sum();
            check(
                (nhx - d as f64 * nx).abs() <= 1e-3 * (1.0 + nhx),
                format!("parseval {nhx} vs {}", d as f64 * nx),
            )
        });
    }
}
