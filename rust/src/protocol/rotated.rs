//! π_srk — stochastic rotated quantization (paper §3).
//!
//! Clients and server share the random rotation `R = HD` through public
//! randomness. Each client quantizes `Z_i = R X_i` with the k-level grid;
//! the server averages the dequantized `Y_i` and applies `R⁻¹`. Because
//! the rotation flattens the vector (`Z^max − Z^min = O(√(log d / d))·‖X‖`,
//! Lemma 7), the MSE drops from `O(d/n)` to `O(log d / n)` (Theorem 3) at
//! the same `d⌈log₂k⌉ + Õ(1)` communication cost.
//!
//! Vectors are zero-padded to the next power of two before rotation; the
//! estimate is truncated back after the inverse rotation.

use std::sync::Arc;

use anyhow::{ensure, Result};

use super::klevel::KLevelProtocol;
use super::{Accumulator, EncodeScratch, Frame, Protocol, RoundCtx, RoundState};
use crate::coding::float::ScalarCodec;
use crate::rotation::{hadamard, Rotation};
use crate::runtime::engine::{ComputeBackend, NativeBackend};

/// Stochastic rotated k-level quantization protocol.
pub struct RotatedProtocol {
    dim: usize,
    padded: usize,
    k: u32,
    pub header: ScalarCodec,
    backend: Arc<dyn ComputeBackend>,
}

impl RotatedProtocol {
    pub fn new(dim: usize, k: u32) -> Self {
        assert!(k >= 2, "need k >= 2 levels");
        RotatedProtocol {
            dim,
            padded: hadamard::pad_dim(dim),
            k,
            header: ScalarCodec::Exact32,
            backend: NativeBackend::shared(),
        }
    }

    pub fn with_backend(mut self, backend: Arc<dyn ComputeBackend>) -> Self {
        self.backend = backend;
        self
    }

    pub fn with_header(mut self, header: ScalarCodec) -> Self {
        self.header = header;
        self
    }

    pub fn k(&self) -> u32 {
        self.k
    }

    pub fn padded_dim(&self) -> usize {
        self.padded
    }

    fn bits_per_coord(&self) -> u32 {
        32 - (self.k - 1).leading_zeros()
    }

    /// Exact per-client frame size in bits (over the padded dimension).
    pub fn frame_bits(&self) -> u64 {
        self.padded as u64 * self.bits_per_coord() as u64 + 2 * self.header.bits() as u64
    }

    /// The round's shared rotation (derived from public randomness).
    /// [`Protocol::prepare`] calls this exactly once per round; everything
    /// downstream reuses the sampled signs through the [`RoundState`].
    pub fn rotation(&self, ctx: &RoundCtx) -> Rotation {
        Rotation::sample(self.dim, &mut ctx.public())
    }
}

impl Protocol for RotatedProtocol {
    fn name(&self) -> String {
        format!("rotated(k={})", self.k)
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn prepare(&self, ctx: &RoundCtx) -> RoundState {
        // The ONLY place the round's rotation is sampled: one public-stream
        // draw per round per protocol instance, shared by every client's
        // encode and the server's inverse rotation.
        RoundState::with_rotation(*ctx, self.rotation(ctx))
    }

    fn encode_with(
        &self,
        state: &RoundState,
        scratch: &mut EncodeScratch,
        client_id: u64,
        x: &[f32],
        frame: &mut Frame,
    ) -> bool {
        assert_eq!(x.len(), self.dim, "dimension mismatch");
        let rot = state.rotation();
        let mut private = state.ctx.private(client_id);
        scratch.u.resize(self.padded, 0.0);
        private.fill_uniform_f32(&mut scratch.u);
        // Pad into the reusable workspace and run the fused in-place
        // rotate+quantize on the backend (the PJRT backend executes the
        // AOT-compiled Pallas kernel here).
        scratch.buf.resize(self.padded, 0.0);
        scratch.buf[..self.dim].copy_from_slice(x);
        for v in &mut scratch.buf[self.dim..] {
            *v = 0.0;
        }
        let (xmin, s) = self
            .backend
            .encode_rotated_in_place(
                &mut scratch.buf,
                rot.signs(),
                &scratch.u,
                self.k,
                &mut scratch.bins,
            )
            .expect("backend encode_rotated failed");
        KLevelProtocol::write_frame_into(
            &self.header,
            self.bits_per_coord(),
            xmin,
            s,
            &scratch.bins,
            frame,
        );
        true
    }

    fn new_accumulator(&self) -> Accumulator {
        // Accumulate in the rotated (padded) space; finish rotates back.
        Accumulator::new(self.padded)
    }

    fn internal_dim(&self) -> usize {
        self.padded
    }

    fn accumulate_with(
        &self,
        _state: &RoundState,
        frame: &Frame,
        acc: &mut Accumulator,
    ) -> Result<()> {
        ensure!(acc.sum.len() == self.padded, "accumulator dimension mismatch");
        KLevelProtocol::read_frame_into(
            &self.header,
            self.bits_per_coord(),
            self.k,
            self.padded,
            frame,
            &mut acc.sum,
        )?;
        acc.frames += 1;
        Ok(())
    }

    fn finish_scaled_with(&self, state: &RoundState, acc: Accumulator, divisor: f64) -> Vec<f32> {
        // Scale in place on the accumulator sum (no intermediate vector),
        // then one inverse rotation on the backend (PJRT: rotate_inv_d*),
        // reusing the round's prepared rotation.
        let sum = acc.into_scaled(divisor);
        let mut back = self
            .backend
            .rotate_inv(&sum, state.rotation().signs())
            .expect("backend rotate_inv failed");
        back.truncate(self.dim);
        back
    }

    fn mse_bound(&self, n: usize, avg_norm_sq: f64) -> Option<f64> {
        // Theorem 3: E <= (2 ln d + 2) / (n (k-1)^2) * avg ||X||^2,
        // in the padded dimension (that is what is rotated).
        let km1 = (self.k - 1) as f64;
        let d = self.padded as f64;
        Some((2.0 * d.ln() + 2.0) / (n as f64 * km1 * km1) * avg_norm_sq)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::run_round;
    use crate::protocol::test_support::{gaussian_clients, measure_mse};
    use crate::stats;

    #[test]
    fn frame_cost_is_padded_fixed_width() {
        let proto = RotatedProtocol::new(100, 16); // pads to 128
        assert_eq!(proto.padded_dim(), 128);
        let ctx = RoundCtx::new(0, 1);
        let x = gaussian_clients(1, 100, 2).remove(0);
        let f = proto.encode(&ctx, 0, &x).unwrap();
        assert_eq!(f.bit_len, 128 * 4 + 64);
    }

    #[test]
    fn mse_within_theorem3_bound() {
        let xs = gaussian_clients(8, 256, 5);
        let proto = RotatedProtocol::new(256, 16);
        let (mse, _) = measure_mse(&proto, &xs, 100, 3);
        let bound = proto.mse_bound(xs.len(), stats::avg_norm_sq(&xs)).unwrap();
        assert!(mse <= bound, "mse {mse} > bound {bound}");
    }

    #[test]
    fn beats_unrotated_on_spiky_data() {
        // Spike + small noise: near-worst case for π_sk (a pure one-hot is
        // *exactly* representable by the min-max grid, so noise is needed
        // to expose the d/n error), tamed by rotation.
        let d = 256;
        let n = 8;
        let mut rng = crate::rng::Pcg64::new(404);
        let mut xs = Vec::new();
        for i in 0..n {
            let mut x = vec![0.0f32; d];
            for v in x.iter_mut() {
                *v = rng.gaussian() as f32 * 0.02;
            }
            x[i * 13 % d] = 1.0;
            xs.push(x);
        }
        let (mse_rot, bits_rot) = measure_mse(&RotatedProtocol::new(d, 4), &xs, 120, 7);
        let (mse_uni, bits_uni) =
            measure_mse(&crate::protocol::klevel::KLevelProtocol::new(d, 4), &xs, 120, 7);
        assert_eq!(bits_rot, bits_uni); // same communication cost
        assert!(
            mse_rot < mse_uni / 5.0,
            "rotated {mse_rot} should be far below uniform {mse_uni}"
        );
    }

    #[test]
    fn section7_worked_example_zero_error() {
        // §7: quantizing [-1, 1, 0, 0] at 1 bit/dim (k=2) after rotation has
        // zero error: the rotated vector has exactly two distinct values.
        let x = vec![-1.0f32, 1.0, 0.0, 0.0];
        let xs = vec![x; 3];
        let proto = RotatedProtocol::new(4, 2);
        let truth = stats::true_mean(&xs);
        for t in 0..50 {
            let ctx = RoundCtx::new(t, 99);
            let (est, _) = run_round(&proto, &ctx, &xs).unwrap();
            let err = stats::sq_error(&est, &truth);
            assert!(err < 1e-9, "round {t}: err {err} should be ~0");
        }
    }

    #[test]
    fn padding_roundtrip_unbiased() {
        // Non-power-of-two dim: estimate must stay unbiased.
        let xs = gaussian_clients(5, 60, 21);
        let proto = RotatedProtocol::new(60, 32);
        let truth = stats::true_mean(&xs);
        let mut sums = vec![0.0f64; 60];
        let trials = 600;
        for t in 0..trials {
            let ctx = RoundCtx::new(t, 31);
            let (est, _) = run_round(&proto, &ctx, &xs).unwrap();
            for (s, &e) in sums.iter_mut().zip(&est) {
                *s += e as f64;
            }
        }
        for (j, &s) in sums.iter().enumerate() {
            let mean = s / trials as f64;
            assert!(
                (mean - truth[j] as f64).abs() < 0.05,
                "coord {j}: {mean} vs {}",
                truth[j]
            );
        }
    }

    #[test]
    fn server_and_client_derive_same_rotation() {
        let proto = RotatedProtocol::new(32, 4);
        let ctx = RoundCtx::new(7, 123);
        let r1 = proto.rotation(&ctx);
        let r2 = proto.rotation(&ctx);
        assert_eq!(r1.signs(), r2.signs());
        let other = RoundCtx::new(8, 123);
        assert_ne!(proto.rotation(&other).signs(), r1.signs());
    }
}
