//! π_sk — stochastic k-level quantization (paper §2.2).
//!
//! Coordinates are stochastically rounded onto the uniform grid
//! `B_i(r) = X_i^min + r·s_i/(k−1)` and transmitted as fixed-width
//! `⌈log₂ k⌉`-bit bin indices: `d⌈log₂k⌉ + Õ(1)` bits per client
//! (Lemma 5), MSE `≤ d/(2n(k−1)²) · avg‖X‖²` (Theorem 2).
//!
//! The numeric work (grid + stochastic rounding) runs on a
//! [`ComputeBackend`]: native Rust or the AOT-compiled Pallas kernel via
//! PJRT — both produce identical bins from the same private uniforms.

use std::sync::Arc;

use anyhow::{bail, ensure, Result};

use super::quantizer::Span;
use super::{Accumulator, EncodeScratch, Frame, Protocol, RoundState};
#[cfg(test)]
use super::RoundCtx;
use crate::coding::bitio::BitReader;
#[cfg(test)]
use crate::coding::bitio::BitWriter;
use crate::coding::float::ScalarCodec;
use crate::runtime::engine::{ComputeBackend, NativeBackend};

/// Stochastic k-level quantization protocol.
pub struct KLevelProtocol {
    dim: usize,
    k: u32,
    span: Span,
    pub header: ScalarCodec,
    backend: Arc<dyn ComputeBackend>,
}

impl KLevelProtocol {
    pub fn new(dim: usize, k: u32) -> Self {
        assert!(k >= 2, "need k >= 2 levels");
        KLevelProtocol {
            dim,
            k,
            span: Span::MinMax,
            header: ScalarCodec::Exact32,
            backend: NativeBackend::shared(),
        }
    }

    pub fn with_span(mut self, span: Span) -> Self {
        self.span = span;
        self
    }

    pub fn with_backend(mut self, backend: Arc<dyn ComputeBackend>) -> Self {
        self.backend = backend;
        self
    }

    pub fn with_header(mut self, header: ScalarCodec) -> Self {
        self.header = header;
        self
    }

    pub fn k(&self) -> u32 {
        self.k
    }

    /// Fixed bits per bin index: `⌈log₂ k⌉`.
    pub fn bits_per_coord(&self) -> u32 {
        32 - (self.k - 1).leading_zeros()
    }

    /// Exact per-client frame size in bits.
    pub fn frame_bits(&self) -> u64 {
        self.dim as u64 * self.bits_per_coord() as u64 + 2 * self.header.bits() as u64
    }

    /// Encode a pre-quantized vector into a recycled frame (shared with
    /// the rotated protocol; zero allocation once the buffer has grown).
    pub(crate) fn write_frame_into(
        header: &ScalarCodec,
        bits_per_coord: u32,
        xmin: f32,
        s: f32,
        bins: &[u32],
        frame: &mut Frame,
    ) {
        let mut w = frame.writer();
        header.put(&mut w, xmin);
        header.put(&mut w, s);
        w.put_bits_bulk(bins, bits_per_coord);
        frame.store(w);
    }

    /// Decode a fixed-width frame into (xmin, s, bins-added-to-acc).
    pub(crate) fn read_frame_into(
        header: &ScalarCodec,
        bits_per_coord: u32,
        k: u32,
        dim: usize,
        frame: &Frame,
        acc: &mut [f32],
    ) -> Result<()> {
        let mut r = BitReader::with_bit_len(&frame.bytes, frame.bit_len);
        let xmin = header.get(&mut r)?;
        let s = header.get(&mut r)?;
        ensure!(
            r.bits_remaining() >= dim as u64 * bits_per_coord as u64,
            "frame too short: {} bits remaining, need {}",
            r.bits_remaining(),
            dim as u64 * bits_per_coord as u64
        );
        // Chunked bulk unpack: fields land in a stack buffer, the range
        // check runs over the whole chunk (one predictable branch per 256
        // coords instead of one per coord), and the dequantize-accumulate
        // goes through the dispatched vector kernel. Bit-identical to the
        // per-coordinate loop, including which invalid bin is reported.
        let n = dim.min(acc.len());
        let mut bins = [0u32; 256];
        let mut done = 0;
        while done < n {
            let take = (n - done).min(256);
            let chunk = &mut bins[..take];
            r.get_bits_bulk(bits_per_coord, chunk)?;
            if chunk.iter().any(|&b| b >= k) {
                let b = chunk.iter().copied().find(|&b| b >= k).unwrap();
                bail!("bin index {b} out of range (k={k})");
            }
            super::quantizer::dequantize_add(chunk, xmin, s, k, &mut acc[done..done + take]);
            done += take;
        }
        Ok(())
    }
}

impl Protocol for KLevelProtocol {
    fn name(&self) -> String {
        format!("klevel(k={})", self.k)
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn encode_with(
        &self,
        state: &RoundState,
        scratch: &mut EncodeScratch,
        client_id: u64,
        x: &[f32],
        frame: &mut Frame,
    ) -> bool {
        assert_eq!(x.len(), self.dim, "dimension mismatch");
        let mut private = state.ctx.private(client_id);
        scratch.u.resize(self.dim, 0.0);
        private.fill_uniform_f32(&mut scratch.u);
        let (xmin, s) = self
            .backend
            .quantize_into(x, &scratch.u, self.span, self.k, &mut scratch.bins)
            .expect("backend quantize failed");
        // Re-encode headers through the codec so both sides share the grid.
        Self::write_frame_into(&self.header, self.bits_per_coord(), xmin, s, &scratch.bins, frame);
        true
    }

    fn new_accumulator(&self) -> Accumulator {
        Accumulator::new(self.dim)
    }

    fn internal_dim(&self) -> usize {
        self.dim
    }

    fn accumulate_with(
        &self,
        _state: &RoundState,
        frame: &Frame,
        acc: &mut Accumulator,
    ) -> Result<()> {
        ensure!(acc.sum.len() == self.dim, "accumulator dimension mismatch");
        Self::read_frame_into(
            &self.header,
            self.bits_per_coord(),
            self.k,
            self.dim,
            frame,
            &mut acc.sum,
        )?;
        acc.frames += 1;
        Ok(())
    }

    fn finish_scaled_with(&self, _state: &RoundState, acc: Accumulator, divisor: f64) -> Vec<f32> {
        acc.into_scaled(divisor)
    }

    fn mse_bound(&self, n: usize, avg_norm_sq: f64) -> Option<f64> {
        // Theorem 2: E <= d/(2n(k-1)^2) * avg ||X||^2 (both span choices
        // satisfy the s_i <= sqrt(2)||X_i|| condition).
        let km1 = (self.k - 1) as f64;
        Some(self.dim as f64 / (2.0 * n as f64 * km1 * km1) * avg_norm_sq)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::run_round;
    use crate::protocol::test_support::{gaussian_clients, measure_mse};
    use crate::stats;

    #[test]
    fn frame_cost_matches_lemma5() {
        for (k, bpc) in [(2u32, 1u32), (3, 2), (4, 2), (16, 4), (17, 5), (32, 5)] {
            let proto = KLevelProtocol::new(64, k);
            assert_eq!(proto.bits_per_coord(), bpc, "k={k}");
            let ctx = RoundCtx::new(0, 1);
            let f = proto.encode(&ctx, 0, &gaussian_clients(1, 64, k as u64)[0]).unwrap();
            assert_eq!(f.bit_len, 64 * bpc as u64 + 64, "k={k}");
        }
    }

    #[test]
    fn k2_reduces_to_binary_semantics() {
        // k=2 must behave like π_sb: same MSE scale.
        let xs = gaussian_clients(6, 32, 3);
        let k2 = KLevelProtocol::new(32, 2);
        let sb = crate::protocol::binary::BinaryProtocol::new(32);
        let (mse_k2, _) = measure_mse(&k2, &xs, 200, 5);
        let (mse_sb, _) = measure_mse(&sb, &xs, 200, 5);
        assert!(
            (mse_k2 - mse_sb).abs() / mse_sb < 0.15,
            "k2 {mse_k2} vs binary {mse_sb}"
        );
    }

    #[test]
    fn mse_within_theorem2_bound_both_spans() {
        let xs = gaussian_clients(8, 64, 7);
        for span in [Span::MinMax, Span::Norm] {
            for k in [4u32, 16] {
                let proto = KLevelProtocol::new(64, k).with_span(span);
                let (mse, _) = measure_mse(&proto, &xs, 150, 9);
                let bound = proto.mse_bound(xs.len(), stats::avg_norm_sq(&xs)).unwrap();
                assert!(mse <= bound, "span={span:?} k={k}: mse {mse} > bound {bound}");
            }
        }
    }

    #[test]
    fn error_decreases_quadratically_in_k() {
        let xs = gaussian_clients(4, 128, 11);
        let (mse_k4, _) = measure_mse(&KLevelProtocol::new(128, 4), &xs, 150, 3);
        let (mse_k16, _) = measure_mse(&KLevelProtocol::new(128, 16), &xs, 150, 3);
        // (k-1)^2 ratio: (15/3)^2 = 25; allow wide MC slack
        let ratio = mse_k4 / mse_k16;
        assert!(ratio > 10.0, "ratio {ratio} (expected ~25)");
    }

    #[test]
    fn deterministic_given_ctx() {
        let proto = KLevelProtocol::new(16, 8);
        let ctx = RoundCtx::new(3, 42);
        let x = gaussian_clients(1, 16, 1).remove(0);
        let f1 = proto.encode(&ctx, 5, &x).unwrap();
        let f2 = proto.encode(&ctx, 5, &x).unwrap();
        assert_eq!(f1.bytes, f2.bytes);
        // different client -> different private stream -> (almost surely)
        // different rounding
        let f3 = proto.encode(&ctx, 6, &x).unwrap();
        assert_ne!(f1.bytes, f3.bytes);
    }

    #[test]
    fn corrupt_bin_index_detected() {
        // craft a frame with an out-of-range bin: k=3 (bpc=2), bin 3 invalid
        let proto = KLevelProtocol::new(4, 3);
        let mut w = BitWriter::new();
        let c = ScalarCodec::Exact32;
        c.put(&mut w, 0.0);
        c.put(&mut w, 1.0);
        for _ in 0..4 {
            w.put_bits(3, 2); // invalid bin
        }
        let (bytes, bits) = w.finish();
        let mut acc = proto.new_accumulator();
        let err = proto.accumulate(&RoundCtx::new(0, 0), &Frame::new(bytes, bits), &mut acc);
        assert!(err.is_err());
    }

    #[test]
    fn round_trip_mean_close_at_high_k() {
        let xs = gaussian_clients(10, 64, 13);
        let proto = KLevelProtocol::new(64, 1 << 12);
        let ctx = RoundCtx::new(0, 1);
        let truth = stats::true_mean(&xs);
        let (est, _) = run_round(&proto, &ctx, &xs).unwrap();
        let err = stats::sq_error(&est, &truth);
        assert!(err < 1e-4, "err={err}");
    }
}
