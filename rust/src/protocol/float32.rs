//! Uncompressed float32 baseline: each client sends its raw vector
//! (32 d bits). Zero quantization error — the reference point every figure
//! plots the quantized protocols against.

use anyhow::{ensure, Result};

use super::{Accumulator, EncodeScratch, Frame, Protocol, RoundState};
#[cfg(test)]
use super::RoundCtx;
use crate::coding::bitio::BitReader;

/// Raw f32 transmission (no compression).
#[derive(Clone, Debug)]
pub struct Float32Protocol {
    dim: usize,
}

impl Float32Protocol {
    pub fn new(dim: usize) -> Self {
        Float32Protocol { dim }
    }

    pub fn frame_bits(&self) -> u64 {
        self.dim as u64 * 32
    }
}

impl Protocol for Float32Protocol {
    fn name(&self) -> String {
        "float32".into()
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn encode_with(
        &self,
        _state: &RoundState,
        _scratch: &mut EncodeScratch,
        _client_id: u64,
        x: &[f32],
        frame: &mut Frame,
    ) -> bool {
        assert_eq!(x.len(), self.dim, "dimension mismatch");
        let mut w = frame.writer();
        for &v in x {
            w.put_f32(v);
        }
        frame.store(w);
        true
    }

    fn new_accumulator(&self) -> Accumulator {
        Accumulator::new(self.dim)
    }

    fn internal_dim(&self) -> usize {
        self.dim
    }

    fn accumulate_with(
        &self,
        _state: &RoundState,
        frame: &Frame,
        acc: &mut Accumulator,
    ) -> Result<()> {
        ensure!(acc.sum.len() == self.dim, "accumulator dimension mismatch");
        ensure!(frame.bit_len >= self.frame_bits(), "frame too short");
        let mut r = BitReader::with_bit_len(&frame.bytes, frame.bit_len);
        for a in acc.sum.iter_mut() {
            *a += r.get_f32()?;
        }
        acc.frames += 1;
        Ok(())
    }

    fn finish_scaled_with(&self, _state: &RoundState, acc: Accumulator, divisor: f64) -> Vec<f32> {
        acc.into_scaled(divisor)
    }

    fn mse_bound(&self, _n: usize, _avg_norm_sq: f64) -> Option<f64> {
        Some(0.0) // exact up to f32 accumulation error
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::run_round;
    use crate::protocol::test_support::gaussian_clients;
    use crate::stats;

    #[test]
    fn exact_mean_recovery() {
        let xs = gaussian_clients(8, 32, 3);
        let proto = Float32Protocol::new(32);
        let ctx = RoundCtx::new(0, 1);
        let (est, bits) = run_round(&proto, &ctx, &xs).unwrap();
        let truth = stats::true_mean(&xs);
        assert!(stats::sq_error(&est, &truth) < 1e-10);
        assert_eq!(bits, 8 * 32 * 32);
    }

    #[test]
    fn frame_is_dense_floats() {
        let proto = Float32Protocol::new(4);
        let ctx = RoundCtx::new(0, 1);
        let f = proto.encode(&ctx, 0, &[1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(f.bit_len, 128);
        assert_eq!(f.bytes.len(), 16);
    }
}
