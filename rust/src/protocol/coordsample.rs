//! Coordinate-sampling wrapper — §5's closing remark: "similar analysis
//! also holds for sampling the coordinates."
//!
//! Each client transmits a random fraction `q` of its coordinates (chosen
//! from its private randomness; the indices are *not* transmitted — the
//! server regenerates them from the same stream context is impossible
//! since the stream is private, so the frame carries a seed-free bitmap
//! alternative: we derive the coordinate mask from the client's *auxiliary
//! private stream*, whose seed inputs (seed, round, client id) the server
//! also knows — the paper's footnote-1 shared-seed trick applied per
//! client). The estimator scales surviving coordinates by `1/q`, keeping
//! the estimate unbiased with MSE
//! `E/q + (1−q)/(nq) · avg‖X‖²`-style degradation, mirroring Lemma 8
//! coordinate-wise.

use std::sync::Arc;

use anyhow::Result;

use super::{Accumulator, EncodeScratch, Frame, Protocol, RoundCtx, RoundState};

/// Coordinate-sampling wrapper: transmit each coordinate w.p. `q` through
/// the inner protocol (silenced coordinates are zeroed before encoding and
/// revived as zero contributions server-side).
pub struct CoordSampledProtocol {
    inner: Arc<dyn Protocol>,
    q: f64,
}

impl CoordSampledProtocol {
    pub fn new(inner: Arc<dyn Protocol>, q: f64) -> Self {
        assert!(q > 0.0 && q <= 1.0, "coordinate probability must be in (0, 1]");
        CoordSampledProtocol { inner, q }
    }

    pub fn q(&self) -> f64 {
        self.q
    }
}

impl Protocol for CoordSampledProtocol {
    fn name(&self) -> String {
        format!("coordsampled(q={}, {})", self.q, self.inner.name())
    }

    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn prepare(&self, ctx: &RoundCtx) -> RoundState {
        RoundState::wrapping(*ctx, self.inner.prepare(ctx))
    }

    fn encode_with(
        &self,
        state: &RoundState,
        scratch: &mut EncodeScratch,
        client_id: u64,
        x: &[f32],
        frame: &mut Frame,
    ) -> bool {
        // The coordinate mask is derived from the auxiliary private stream
        // (server and client both can; the mask never crosses the wire).
        // Zero the dropped coordinates; the inner quantizer then encodes a
        // sparser vector (varlen inner protocols get real bit savings, and
        // the zeros shrink the min-max span on one side). The sparse copy
        // lives in the reusable scratch, taken out while the inner encode
        // borrows the rest of it.
        let mut coin = state.ctx.private_aux(client_id ^ 0xc00d);
        let mut sparse = std::mem::take(&mut scratch.sparse);
        sparse.clear();
        sparse.extend(x.iter().map(|&v| if coin.bernoulli(self.q) { v } else { 0.0 }));
        let sent =
            self.inner.encode_with(state.inner_state(), scratch, client_id, &sparse, frame);
        scratch.sparse = sparse;
        sent
    }

    fn new_accumulator(&self) -> Accumulator {
        self.inner.new_accumulator()
    }

    fn internal_dim(&self) -> usize {
        self.inner.internal_dim()
    }

    fn accumulate_with(
        &self,
        state: &RoundState,
        frame: &Frame,
        acc: &mut Accumulator,
    ) -> Result<()> {
        self.inner.accumulate_with(state.inner_state(), frame, acc)
    }

    fn finish_scaled_with(&self, state: &RoundState, acc: Accumulator, divisor: f64) -> Vec<f32> {
        // Inner finish divides by n; surviving coordinates then need the
        // 1/q inflation. NOTE this is only unbiased when the inner
        // protocol is coordinate-separable (all of ours are except the
        // rotated one, which mixes coordinates before quantization —
        // config::build rejects that combination).
        let mut est = self.inner.finish_scaled_with(state.inner_state(), acc, divisor);
        let inv_q = (1.0 / self.q) as f32;
        for v in est.iter_mut() {
            *v *= inv_q;
        }
        est
    }

    fn mse_bound(&self, n: usize, avg_norm_sq: f64) -> Option<f64> {
        // Mirror of Lemma 8 coordinate-wise: inner error inflated by 1/q²
        // on a q-fraction of mass (=> /q), plus Bernoulli sampling variance
        // of the data itself.
        let inner = self.inner.mse_bound(n, avg_norm_sq)?;
        Some(inner / self.q + (1.0 - self.q) / (n as f64 * self.q) * avg_norm_sq)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::klevel::KLevelProtocol;
    use crate::protocol::run_round;
    use crate::protocol::test_support::{gaussian_clients, measure_mse};
    use crate::stats;

    fn wrapped(d: usize, k: u32, q: f64) -> CoordSampledProtocol {
        CoordSampledProtocol::new(Arc::new(KLevelProtocol::new(d, k)), q)
    }

    #[test]
    fn q_one_is_identity() {
        let xs = gaussian_clients(4, 32, 1);
        let ctx = RoundCtx::new(0, 5);
        let (est_w, _) = run_round(&wrapped(32, 16, 1.0), &ctx, &xs).unwrap();
        let (est_i, _) = run_round(&KLevelProtocol::new(32, 16), &ctx, &xs).unwrap();
        for (a, b) in est_w.iter().zip(&est_i) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn unbiased_under_coordinate_sampling() {
        let xs = gaussian_clients(10, 16, 3);
        let truth = stats::true_mean(&xs);
        let proto = wrapped(16, 64, 0.5);
        let trials = 3000;
        let mut sums = vec![0.0f64; 16];
        for t in 0..trials {
            let ctx = RoundCtx::new(t, 7);
            let (est, _) = run_round(&proto, &ctx, &xs).unwrap();
            for (s, &e) in sums.iter_mut().zip(&est) {
                *s += e as f64;
            }
        }
        for (j, &s) in sums.iter().enumerate() {
            let mean = s / trials as f64;
            assert!(
                (mean - truth[j] as f64).abs() < 0.08,
                "coord {j}: {mean} vs {}",
                truth[j]
            );
        }
    }

    #[test]
    fn mse_within_bound() {
        let xs = gaussian_clients(32, 32, 11);
        let avg = stats::avg_norm_sq(&xs);
        for q in [0.25, 0.5, 1.0] {
            let proto = wrapped(32, 16, q);
            let (mse, _) = measure_mse(&proto, &xs, 200, 13);
            let bound = proto.mse_bound(xs.len(), avg).unwrap();
            assert!(mse <= bound * 1.1, "q={q}: {mse} > {bound}");
        }
    }

    #[test]
    fn varlen_inner_saves_bits_on_sparsified_vectors() {
        // Dropped coordinates become zeros -> one bin dominates -> the
        // entropy coder's payload shrinks with q.
        let d = 256;
        let xs = gaussian_clients(4, d, 17);
        let inner = || Arc::new(crate::protocol::varlen::VarlenProtocol::new(d, 17));
        let (_, bits_full) = measure_mse(&CoordSampledProtocol::new(inner(), 1.0), &xs, 10, 3);
        let (_, bits_q25) = measure_mse(&CoordSampledProtocol::new(inner(), 0.25), &xs, 10, 3);
        assert!(
            bits_q25 < bits_full * 0.7,
            "q=0.25 bits {bits_q25} vs full {bits_full}"
        );
    }

    #[test]
    #[should_panic(expected = "coordinate probability")]
    fn zero_q_rejected() {
        wrapped(8, 2, 0.0);
    }
}
