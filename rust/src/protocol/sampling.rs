//! π_p — the client-sampling wrapper (paper §5).
//!
//! Each client transmits independently with probability `p` (a coin from
//! its private randomness); the server scales the sum by `1/(np)` instead
//! of `1/n` (Lemma 8):
//!
//! `E(π_p) = E(π)/p + (1−p)/(np) · (1/n)Σ‖X_i‖²`, `C(π_p) = p · C(π)`.
//!
//! Combined with π_svk at `k = √d + 1`, this achieves the minimax
//! communication–MSE trade-off `Θ(min(1, d/c))` (Theorem 1 / Corollary 1).

use std::sync::Arc;

use anyhow::Result;

use super::{Accumulator, EncodeScratch, Frame, Protocol, RoundCtx, RoundState};

/// Client-sampling wrapper around any inner protocol.
pub struct SampledProtocol {
    inner: Arc<dyn Protocol>,
    p: f64,
}

impl SampledProtocol {
    pub fn new(inner: Arc<dyn Protocol>, p: f64) -> Self {
        assert!(p > 0.0 && p <= 1.0, "sampling probability must be in (0, 1]");
        SampledProtocol { inner, p }
    }

    pub fn p(&self) -> f64 {
        self.p
    }
}

impl Protocol for SampledProtocol {
    fn name(&self) -> String {
        format!("sampled(p={}, {})", self.p, self.inner.name())
    }

    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn prepare(&self, ctx: &RoundCtx) -> RoundState {
        // The coin parameter `p` is static configuration; the only
        // per-round state is the inner protocol's.
        RoundState::wrapping(*ctx, self.inner.prepare(ctx))
    }

    fn encode_with(
        &self,
        state: &RoundState,
        scratch: &mut EncodeScratch,
        client_id: u64,
        x: &[f32],
        frame: &mut Frame,
    ) -> bool {
        // The participation coin comes from the auxiliary private stream so
        // it never aliases the inner protocol's rounding uniforms.
        let mut coin = state.ctx.private_aux(client_id);
        if !coin.bernoulli(self.p) {
            return false;
        }
        self.inner.encode_with(state.inner_state(), scratch, client_id, x, frame)
    }

    fn new_accumulator(&self) -> Accumulator {
        self.inner.new_accumulator()
    }

    fn internal_dim(&self) -> usize {
        self.inner.internal_dim()
    }

    fn accumulate_with(
        &self,
        state: &RoundState,
        frame: &Frame,
        acc: &mut Accumulator,
    ) -> Result<()> {
        self.inner.accumulate_with(state.inner_state(), frame, acc)
    }

    fn finish_scaled_with(&self, state: &RoundState, acc: Accumulator, divisor: f64) -> Vec<f32> {
        // Lemma 8's estimator: divide by n·p, NOT by |S| — this is what
        // keeps the estimate unbiased.
        self.inner.finish_scaled_with(state.inner_state(), acc, divisor * self.p)
    }

    fn mse_bound(&self, n: usize, avg_norm_sq: f64) -> Option<f64> {
        // Lemma 8: E/p + (1-p)/(np) * avg ||X||^2.
        let inner = self.inner.mse_bound(n, avg_norm_sq)?;
        Some(inner / self.p + (1.0 - self.p) / (n as f64 * self.p) * avg_norm_sq)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::klevel::KLevelProtocol;
    use crate::protocol::run_round;
    use crate::protocol::test_support::{gaussian_clients, measure_mse};
    use crate::protocol::varlen::VarlenProtocol;
    use crate::stats;

    fn sampled(d: usize, k: u32, p: f64) -> SampledProtocol {
        SampledProtocol::new(Arc::new(KLevelProtocol::new(d, k)), p)
    }

    #[test]
    fn p_one_is_identity() {
        let xs = gaussian_clients(6, 32, 3);
        let ctx = RoundCtx::new(0, 9);
        let (est_s, bits_s) = run_round(&sampled(32, 8, 1.0), &ctx, &xs).unwrap();
        let (est_i, bits_i) = run_round(&KLevelProtocol::new(32, 8), &ctx, &xs).unwrap();
        assert_eq!(bits_s, bits_i);
        for (a, b) in est_s.iter().zip(&est_i) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn communication_scales_with_p() {
        let xs = gaussian_clients(200, 32, 5);
        let proto = sampled(32, 8, 0.25);
        let (_, bits) = measure_mse(&proto, &xs, 40, 7);
        let full_bits = KLevelProtocol::new(32, 8).frame_bits() as f64 * xs.len() as f64;
        let ratio = bits / full_bits;
        assert!(
            (ratio - 0.25).abs() < 0.05,
            "bits ratio {ratio}, expected ~0.25"
        );
    }

    #[test]
    fn estimate_stays_unbiased_under_sampling() {
        let xs = gaussian_clients(50, 16, 11);
        let truth = stats::true_mean(&xs);
        let proto = sampled(16, 32, 0.5);
        let trials = 2000;
        let mut sums = vec![0.0f64; 16];
        for t in 0..trials {
            let ctx = RoundCtx::new(t, 13);
            let (est, _) = run_round(&proto, &ctx, &xs).unwrap();
            for (s, &e) in sums.iter_mut().zip(&est) {
                *s += e as f64;
            }
        }
        for (j, &s) in sums.iter().enumerate() {
            let mean = s / trials as f64;
            assert!(
                (mean - truth[j] as f64).abs() < 0.06,
                "coord {j}: {mean} vs {}",
                truth[j]
            );
        }
    }

    #[test]
    fn mse_within_lemma8_bound() {
        let xs = gaussian_clients(64, 32, 17);
        let avg = stats::avg_norm_sq(&xs);
        for p in [0.25, 0.5, 1.0] {
            let proto = sampled(32, 16, p);
            let (mse, _) = measure_mse(&proto, &xs, 150, 19);
            let bound = proto.mse_bound(xs.len(), avg).unwrap();
            assert!(mse <= bound * 1.1, "p={p}: mse {mse} > bound {bound}");
        }
    }

    #[test]
    fn minimax_tradeoff_shape_corollary1() {
        // MSE * c should be ~Theta(d * avg) across p (Corollary 1 shape).
        let d = 64;
        let n = 128;
        let xs = gaussian_clients(n, d, 23);
        let mut products = Vec::new();
        for p in [0.25f64, 0.5, 1.0] {
            // Theorem 1's construction uses the Theorem-4 span (norm).
            let inner = Arc::new(
                VarlenProtocol::sqrt_d(d).with_span(crate::protocol::quantizer::Span::Norm),
            );
            let proto = SampledProtocol::new(inner, p);
            let (mse, bits) = measure_mse(&proto, &xs, 120, 29);
            products.push(mse * bits);
        }
        let max = products.iter().cloned().fold(f64::MIN, f64::max);
        let min = products.iter().cloned().fold(f64::MAX, f64::min);
        // "product roughly constant": within a small constant factor
        assert!(max / min < 4.0, "products {products:?}");
    }

    #[test]
    fn sampling_coin_independent_of_rounding() {
        // Same client id, two nested protocols: the coin must not perturb
        // the inner encoding when the client does transmit.
        let xs = gaussian_clients(1, 16, 31);
        let ctx = RoundCtx::new(0, 37);
        let inner = KLevelProtocol::new(16, 8);
        let direct = inner.encode(&ctx, 0, &xs[0]).unwrap();
        let proto = sampled(16, 8, 0.9999);
        let via = proto.encode(&ctx, 0, &xs[0]).unwrap();
        assert_eq!(direct.bytes, via.bytes);
    }

    #[test]
    #[should_panic(expected = "sampling probability")]
    fn zero_p_rejected() {
        sampled(8, 2, 0.0);
    }
}
