//! DRIVE — deterministic one-bit-per-coordinate encoding after a random
//! rotation, with a per-client optimal scale (Vargaftik et al.,
//! "DRIVE: One-bit Distributed Mean Estimation", arXiv 2105.08339).
//!
//! Each client rotates its vector with the round's shared `R = HD`
//! (the same structured rotation π_srk uses), transmits only the *sign*
//! of every rotated coordinate plus one 32-bit scale
//! `S = ‖Rx‖² / ⟨Rx, sign(Rx)⟩ = ‖z‖²/‖z‖₁`, and the server
//! reconstructs `S·sign(z)` per client, sums in rotated space, and
//! applies one `R⁻¹` at the end of the round. The scale choice
//! minimizes the per-client L2 error among all multiples of the sign
//! vector, giving NMSE → π/2 − 1 ≈ 0.57 for rotation-flattened vectors
//! (DRIVE Thm. 5.4) — a *constant*, independent of `d`, at ~1 bit per
//! coordinate. That beats π_sb's Θ(d/n) whenever `d ≳ n`, which is the
//! extreme low-budget regime the rate planner previously had no good
//! candidate for.
//!
//! Like π_srk the encoding pays the padded power-of-two dimension:
//! `d̃ + 32` bits per client (sign bits + one scale header; no `xmin`
//! scalar, hence half the header cost of the k-level frames).

use std::sync::Arc;

use anyhow::{ensure, Result};

use super::{Accumulator, EncodeScratch, Frame, Protocol, RoundCtx, RoundState};
use crate::coding::bitio::BitReader;
use crate::coding::float::ScalarCodec;
use crate::rotation::{hadamard, Rotation};
use crate::runtime::engine::{ComputeBackend, NativeBackend};

/// One-bit-per-coordinate sign encoding with per-client optimal scale.
pub struct DriveProtocol {
    dim: usize,
    padded: usize,
    pub header: ScalarCodec,
    backend: Arc<dyn ComputeBackend>,
}

impl DriveProtocol {
    pub fn new(dim: usize) -> Self {
        DriveProtocol {
            dim,
            padded: hadamard::pad_dim(dim),
            header: ScalarCodec::Exact32,
            backend: NativeBackend::shared(),
        }
    }

    pub fn with_backend(mut self, backend: Arc<dyn ComputeBackend>) -> Self {
        self.backend = backend;
        self
    }

    pub fn padded_dim(&self) -> usize {
        self.padded
    }

    /// Exact per-client frame size in bits: one sign bit per padded
    /// coordinate plus a single scale header.
    pub fn frame_bits(&self) -> u64 {
        self.padded as u64 + self.header.bits() as u64
    }

    /// The round's shared rotation — same public-randomness derivation
    /// as π_srk, sampled exactly once per round by [`Protocol::prepare`].
    pub fn rotation(&self, ctx: &RoundCtx) -> Rotation {
        Rotation::sample(self.dim, &mut ctx.public())
    }
}

impl Protocol for DriveProtocol {
    fn name(&self) -> String {
        "drive".to_string()
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn prepare(&self, ctx: &RoundCtx) -> RoundState {
        RoundState::with_rotation(*ctx, self.rotation(ctx))
    }

    fn encode_with(
        &self,
        state: &RoundState,
        scratch: &mut EncodeScratch,
        _client_id: u64,
        x: &[f32],
        frame: &mut Frame,
    ) -> bool {
        assert_eq!(x.len(), self.dim, "dimension mismatch");
        let rot = state.rotation();
        scratch.buf.resize(self.padded, 0.0);
        scratch.buf[..self.dim].copy_from_slice(x);
        for v in &mut scratch.buf[self.dim..] {
            *v = 0.0;
        }
        let z = self
            .backend
            .rotate_fwd(&scratch.buf, rot.signs())
            .expect("backend rotate_fwd failed");
        // S = ‖z‖²/⟨z, sign(z)⟩ = ‖z‖²/‖z‖₁ — the scale minimizing
        // ‖S·sign(z) − z‖². Sums in f64 so the scale is stable for
        // large d; an all-zero vector degenerates to S = 0 (exact).
        let mut norm_sq = 0.0f64;
        let mut l1 = 0.0f64;
        for &v in &z {
            norm_sq += (v as f64) * (v as f64);
            l1 += v.abs() as f64;
        }
        let scale = if l1 > 0.0 { (norm_sq / l1) as f32 } else { 0.0 };
        let mut w = frame.writer();
        // Encoding is deterministic given the rotation: no private
        // randomness, the single header scalar plus one bit per padded
        // coordinate (bit set ⇔ coordinate non-negative).
        self.header.put(&mut w, scale);
        for &v in &z {
            w.put_bit(v >= 0.0);
        }
        frame.store(w);
        true
    }

    fn new_accumulator(&self) -> Accumulator {
        // Accumulate in the rotated (padded) space; finish rotates back.
        Accumulator::new(self.padded)
    }

    fn internal_dim(&self) -> usize {
        self.padded
    }

    fn accumulate_with(
        &self,
        _state: &RoundState,
        frame: &Frame,
        acc: &mut Accumulator,
    ) -> Result<()> {
        ensure!(acc.sum.len() == self.padded, "accumulator dimension mismatch");
        let mut r = BitReader::with_bit_len(&frame.bytes, frame.bit_len);
        let scale = self.header.get(&mut r)?;
        ensure!(
            r.bits_remaining() >= self.padded as u64,
            "frame too short: {} sign bits remaining, need {}",
            r.bits_remaining(),
            self.padded
        );
        for slot in acc.sum.iter_mut() {
            *slot += if r.get_bit()? { scale } else { -scale };
        }
        acc.frames += 1;
        Ok(())
    }

    fn finish_scaled_with(&self, state: &RoundState, acc: Accumulator, divisor: f64) -> Vec<f32> {
        let sum = acc.into_scaled(divisor);
        let mut back = self
            .backend
            .rotate_inv(&sum, state.rotation().signs())
            .expect("backend rotate_inv failed");
        back.truncate(self.dim);
        back
    }

    fn mse_bound(&self, n: usize, avg_norm_sq: f64) -> Option<f64> {
        // DRIVE Thm 5.4 regime: per-client NMSE → π/2 − 1 for
        // rotation-flattened vectors, with a finite-d slack term for the
        // Hadamard (rather than uniform) rotation. The estimator is
        // deterministic given R and all clients share one R, so the
        // worst case (identical clients) gets no 1/n averaging — the
        // bound is intentionally n-free; Monte-Carlo behavior on
        // heterogeneous data is ≈ (π/2−1)/n·B̄, far below it.
        let _ = n;
        let d = self.padded as f64;
        Some((std::f64::consts::FRAC_PI_2 - 1.0) * (1.0 + 8.0 / d.sqrt()) * avg_norm_sq)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::run_round;
    use crate::protocol::test_support::{gaussian_clients, measure_mse};
    use crate::stats;

    #[test]
    fn frame_cost_is_one_bit_per_padded_coord_plus_scale() {
        let proto = DriveProtocol::new(100); // pads to 128
        assert_eq!(proto.padded_dim(), 128);
        assert_eq!(proto.frame_bits(), 128 + 32);
        let ctx = RoundCtx::new(0, 1);
        let x = gaussian_clients(1, 100, 2).remove(0);
        let f = proto.encode(&ctx, 0, &x).unwrap();
        assert_eq!(f.bit_len, 128 + 32);
    }

    #[test]
    fn mse_within_paper_bound_at_one_bit_per_dim() {
        let xs = gaussian_clients(8, 256, 5);
        let proto = DriveProtocol::new(256);
        let (mse, bits) = measure_mse(&proto, &xs, 100, 3);
        assert_eq!(bits, (8 * (256 + 32)) as f64);
        let bound = proto.mse_bound(xs.len(), stats::avg_norm_sq(&xs)).unwrap();
        assert!(mse <= bound, "mse {mse} > bound {bound}");
    }

    #[test]
    fn beats_binary_at_equal_budget() {
        // The acceptance comparison: at ~1 bit/dim DRIVE's constant NMSE
        // is far below π_sb's Θ(d/n) — and its frame is even 32 bits
        // smaller (one header scalar instead of two).
        let d = 256;
        let xs = gaussian_clients(16, d, 11);
        let (mse_drive, bits_drive) = measure_mse(&DriveProtocol::new(d), &xs, 120, 7);
        let (mse_bin, bits_bin) =
            measure_mse(&crate::protocol::binary::BinaryProtocol::new(d), &xs, 120, 7);
        assert!(bits_drive <= bits_bin, "drive {bits_drive} vs binary {bits_bin} bits");
        assert!(
            mse_drive < mse_bin / 4.0,
            "drive {mse_drive} should be far below binary {mse_bin} at equal budget"
        );
    }

    #[test]
    fn deterministic_given_ctx_and_identical_across_clients() {
        // No private randomness: the frame depends only on (round, x).
        let proto = DriveProtocol::new(64);
        let ctx = RoundCtx::new(3, 42);
        let x = gaussian_clients(1, 64, 1).remove(0);
        let f1 = proto.encode(&ctx, 5, &x).unwrap();
        let f2 = proto.encode(&ctx, 9, &x).unwrap();
        assert_eq!(f1.bytes, f2.bytes);
        let other = proto.encode(&RoundCtx::new(4, 42), 5, &x).unwrap();
        assert_ne!(f1.bytes, other.bytes);
    }

    #[test]
    fn one_hot_is_reconstructed_exactly() {
        // A one-hot vector rotates to a flat ±1/√d vector (Lemma 7), so
        // the sign encoding with S = ‖z‖²/‖z‖₁ = 1/√d is lossless.
        let d = 128;
        let mut x = vec![0.0f32; d];
        x[17] = 1.0;
        let xs = vec![x.clone(); 4];
        let proto = DriveProtocol::new(d);
        for t in 0..20 {
            let ctx = RoundCtx::new(t, 77);
            let (est, _) = run_round(&proto, &ctx, &xs).unwrap();
            let err = stats::sq_error(&est, &x);
            assert!(err < 1e-8, "round {t}: err {err} should be ~0");
        }
    }

    #[test]
    fn zero_vector_encodes_to_zero_scale() {
        let proto = DriveProtocol::new(32);
        let ctx = RoundCtx::new(0, 9);
        let xs = vec![vec![0.0f32; 32]; 2];
        let (est, _) = run_round(&proto, &ctx, &xs).unwrap();
        assert!(est.iter().all(|&v| v == 0.0), "zero in, zero out: {est:?}");
    }

    #[test]
    fn padding_dims_stay_consistent() {
        // Non-power-of-two dims round-trip through the padded space.
        let xs = gaussian_clients(6, 60, 21);
        let proto = DriveProtocol::new(60);
        let ctx = RoundCtx::new(1, 13);
        let (est, _) = run_round(&proto, &ctx, &xs).unwrap();
        assert_eq!(est.len(), 60);
        let truth = stats::true_mean(&xs);
        // Constant-NMSE family: the estimate is in the right ballpark.
        let err = stats::sq_error(&est, &truth);
        let scale = stats::avg_norm_sq(&xs);
        assert!(err < scale, "err {err} vs avg norm {scale}");
    }
}
