//! π_sb — stochastic binary quantization (paper §2.1).
//!
//! Each coordinate is rounded to `X_i^max` w.p. `(X_i(j) − X_i^min)/range`
//! and to `X_i^min` otherwise (unbiased). The frame is two header scalars
//! plus exactly one bit per coordinate: `d + Õ(1)` bits (Lemma 1). The MSE
//! is `Θ(d/n)` × average squared norm (Lemmas 2–4) — the warm-up the
//! rotated and variable-length protocols improve on.

use anyhow::{ensure, Result};

use super::{Accumulator, EncodeScratch, Frame, Protocol, RoundState};
#[cfg(test)]
use super::RoundCtx;
use crate::coding::bitio::BitReader;
use crate::coding::float::ScalarCodec;
use crate::linalg;

/// Stochastic binary quantization protocol.
#[derive(Clone, Debug)]
pub struct BinaryProtocol {
    dim: usize,
    /// Codec for the two header scalars (default exact f32).
    pub header: ScalarCodec,
}

impl BinaryProtocol {
    pub fn new(dim: usize) -> Self {
        BinaryProtocol { dim, header: ScalarCodec::Exact32 }
    }

    pub fn with_header(mut self, header: ScalarCodec) -> Self {
        self.header = header;
        self
    }

    /// Exact per-client frame size in bits.
    pub fn frame_bits(&self) -> u64 {
        self.dim as u64 + 2 * self.header.bits() as u64
    }
}

impl Protocol for BinaryProtocol {
    fn name(&self) -> String {
        "binary".into()
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn encode_with(
        &self,
        state: &RoundState,
        _scratch: &mut EncodeScratch,
        client_id: u64,
        x: &[f32],
        frame: &mut Frame,
    ) -> bool {
        assert_eq!(x.len(), self.dim, "dimension mismatch");
        let mut private = state.ctx.private(client_id);
        let (lo, hi) = linalg::min_max(x);
        let mut w = frame.writer();
        // Header first: quantize against the *decoded* scalars so client
        // and server use identical grid endpoints.
        let lo_t = self.header.put(&mut w, lo);
        let hi_t = self.header.put(&mut w, hi);
        let range = hi_t - lo_t;
        for &xj in x {
            let p = if range > 0.0 { ((xj - lo_t) / range).clamp(0.0, 1.0) } else { 0.0 };
            w.put_bit(private.next_f32() < p);
        }
        frame.store(w);
        true
    }

    fn new_accumulator(&self) -> Accumulator {
        Accumulator::new(self.dim)
    }

    fn internal_dim(&self) -> usize {
        self.dim
    }

    fn accumulate_with(
        &self,
        _state: &RoundState,
        frame: &Frame,
        acc: &mut Accumulator,
    ) -> Result<()> {
        ensure!(acc.sum.len() == self.dim, "accumulator dimension mismatch");
        let mut r = BitReader::with_bit_len(&frame.bytes, frame.bit_len);
        let lo = self.header.get(&mut r)?;
        let hi = self.header.get(&mut r)?;
        ensure!(r.bits_remaining() >= self.dim as u64, "frame too short");
        for a in acc.sum.iter_mut() {
            *a += if r.get_bit()? { hi } else { lo };
        }
        acc.frames += 1;
        Ok(())
    }

    fn finish_scaled_with(&self, _state: &RoundState, acc: Accumulator, divisor: f64) -> Vec<f32> {
        acc.into_scaled(divisor)
    }

    fn mse_bound(&self, n: usize, avg_norm_sq: f64) -> Option<f64> {
        // Lemma 3: E <= d/(2n) * avg ||X||^2.
        Some(self.dim as f64 / (2.0 * n as f64) * avg_norm_sq)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::test_support::{gaussian_clients, measure_mse};
    use crate::protocol::run_round;
    use crate::stats;

    #[test]
    fn frame_cost_is_d_plus_header() {
        let proto = BinaryProtocol::new(64);
        let ctx = RoundCtx::new(0, 1);
        let x = vec![1.0f32; 64];
        let f = proto.encode(&ctx, 0, &x).unwrap();
        assert_eq!(f.bit_len, 64 + 2 * 32);
        assert_eq!(f.bit_len, proto.frame_bits());
    }

    #[test]
    fn constant_vector_decodes_exactly() {
        let proto = BinaryProtocol::new(16);
        let ctx = RoundCtx::new(0, 2);
        let xs = vec![vec![3.5f32; 16]; 4];
        let (est, _) = run_round(&proto, &ctx, &xs).unwrap();
        for v in est {
            assert_eq!(v, 3.5);
        }
    }

    #[test]
    fn estimate_is_unbiased_across_rounds() {
        let proto = BinaryProtocol::new(8);
        let xs = gaussian_clients(4, 8, 3);
        let truth = stats::true_mean(&xs);
        let mut acc_est = vec![0.0f64; 8];
        let trials = 3000;
        for t in 0..trials {
            let ctx = RoundCtx::new(t, 77);
            let (est, _) = run_round(&proto, &ctx, &xs).unwrap();
            for (a, &e) in acc_est.iter_mut().zip(&est) {
                *a += e as f64;
            }
        }
        for (j, &a) in acc_est.iter().enumerate() {
            let mean = a / trials as f64;
            assert!(
                (mean - truth[j] as f64).abs() < 0.05,
                "coord {j}: {mean} vs {}",
                truth[j]
            );
        }
    }

    #[test]
    fn mse_within_lemma3_bound_and_near_lemma2_exact() {
        let d = 32;
        let xs = gaussian_clients(8, d, 5);
        let proto = BinaryProtocol::new(d);
        let (mse, _) = measure_mse(&proto, &xs, 300, 11);
        let bound = proto.mse_bound(xs.len(), stats::avg_norm_sq(&xs)).unwrap();
        assert!(mse <= bound, "mse {mse} > bound {bound}");
        // Lemma 2 exact MSE:
        let exact: f64 = xs
            .iter()
            .map(|x| {
                let (lo, hi) = crate::linalg::min_max(x);
                x.iter()
                    .map(|&v| (hi as f64 - v as f64) * (v as f64 - lo as f64))
                    .sum::<f64>()
            })
            .sum::<f64>()
            / (xs.len() * xs.len()) as f64;
        assert!(
            (mse - exact).abs() / exact < 0.25,
            "measured {mse} vs exact lemma2 {exact}"
        );
    }

    #[test]
    fn lemma4_worst_case_is_near_tight() {
        // X_i = (1/√2, −1/√2, 0, …, 0): Lemma 4 says E >= (d−2)/(2n)·avg‖X‖².
        let d = 32;
        let n = 4;
        let mut x = vec![0.0f32; d];
        x[0] = 1.0 / 2.0f32.sqrt();
        x[1] = -1.0 / 2.0f32.sqrt();
        let xs = vec![x; n];
        let proto = BinaryProtocol::new(d);
        let (mse, _) = measure_mse(&proto, &xs, 400, 13);
        let avg = stats::avg_norm_sq(&xs); // = 1
        let lower = (d as f64 - 2.0) / (2.0 * n as f64) * avg;
        let upper = d as f64 / (2.0 * n as f64) * avg;
        assert!(mse >= lower * 0.85, "mse {mse} << lemma4 lower {lower}");
        assert!(mse <= upper * 1.15, "mse {mse} >> lemma3 upper {upper}");
    }

    #[test]
    fn truncated_frame_is_an_error() {
        let proto = BinaryProtocol::new(16);
        let ctx = RoundCtx::new(0, 1);
        let f = proto.encode(&ctx, 0, &vec![1.0f32, -1.0].repeat(8)).unwrap();
        let cut = Frame::new(f.bytes[..8].to_vec(), 64);
        let mut acc = proto.new_accumulator();
        assert!(proto.accumulate(&ctx, &cut, &mut acc).is_err());
    }
}
