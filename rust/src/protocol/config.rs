//! Protocol configuration and registry: a declarative description of a
//! protocol stack (kind + k + coder + span + sampling + backend) that can
//! be built from code or parsed from a CLI spec string.
//!
//! Spec grammar (used by the `dme` CLI and the bench harness):
//!
//! ```text
//! float32
//! binary
//! klevel:k=16
//! rotated:k=32
//! varlen:k=33,coder=huffman
//! varlen                      # k defaults to sqrt(d)+1
//! klevel:k=16,p=0.25          # any protocol + client sampling
//! drive                       # 1 sign bit/coord + per-client scale
//! correlated:k=4,strata=16    # anti-correlated rounding offsets
//! correlated:base=rotated,k=4 # ... over the rotated quantizer
//! ```

use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use super::binary::BinaryProtocol;
use super::coordsample::CoordSampledProtocol;
use super::correlated::{CorrBase, CorrelatedProtocol};
use super::drive::DriveProtocol;
use super::float32::Float32Protocol;
use super::klevel::KLevelProtocol;
use super::quantizer::Span;
use super::rotated::RotatedProtocol;
use super::qsgd::QsgdProtocol;
use super::sampling::SampledProtocol;
use super::varlen::{Coder, VarlenProtocol};
use super::Protocol;
use crate::runtime::engine::ComputeBackend;

/// Defines [`Kind`], its canonical spec-grammar names, and the derived
/// exhaustive [`Kind::ALL`] list from one variant table. Adding a
/// protocol kind is a one-line change here; the list, its length, and
/// `name()` can never fall out of sync with the enum (the compile-guard
/// test below pins the uniqueness of the names).
macro_rules! kinds {
    ($($(#[$meta:meta])* $variant:ident => $name:literal),+ $(,)?) => {
        /// Which base protocol to build.
        #[derive(Clone, Copy, Debug, PartialEq, Eq)]
        pub enum Kind {
            $($(#[$meta])* $variant,)+
        }

        impl Kind {
            /// How many base protocol kinds exist.
            pub const COUNT: usize = [$($name),+].len();

            /// Every base protocol kind (the rate planner enumerates
            /// these). Derived from the variant table, so it is
            /// exhaustive by construction.
            pub const ALL: [Kind; Self::COUNT] = [$(Kind::$variant),+];

            /// The canonical spec-grammar name (the one
            /// [`ProtocolConfig::parse`] documents; aliases parse but
            /// are never emitted).
            pub fn name(&self) -> &'static str {
                match self {
                    $(Kind::$variant => $name,)+
                }
            }
        }
    };
}

kinds! {
    Float32 => "float32",
    Binary => "binary",
    KLevel => "klevel",
    Rotated => "rotated",
    Varlen => "varlen",
    Qsgd => "qsgd",
    /// DRIVE: 1 sign bit/coord after rotation + per-client scale.
    Drive => "drive",
    /// Correlated quantization: stratified shared rounding offsets.
    Correlated => "correlated",
}

/// Declarative protocol description.
#[derive(Clone)]
pub struct ProtocolConfig {
    pub kind: Kind,
    pub dim: usize,
    /// Quantization levels (ignored by float32/binary). 0 = sqrt(d)+1.
    pub k: u32,
    /// Entropy coder for varlen.
    pub coder: Coder,
    /// Span rule for klevel/varlen.
    pub span: Span,
    /// Client sampling probability (1.0 = no sampling wrapper).
    pub p: f64,
    /// Coordinate sampling probability (1.0 = no wrapper). Incompatible
    /// with `rotated` (the rotation mixes coordinates before quantization).
    pub q: f64,
    /// Base quantizer family for `correlated` (ignored by other kinds).
    pub base: CorrBase,
    /// Offset strata `m` for `correlated` (power of two; plan `m ≥ n`).
    /// Ignored by other kinds.
    pub strata: u32,
    /// Numeric backend (None = native).
    pub backend: Option<Arc<dyn ComputeBackend>>,
}

impl std::fmt::Debug for ProtocolConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ProtocolConfig")
            .field("spec", &self.to_string())
            .field("dim", &self.dim)
            .field("backend", &self.backend.is_some())
            .finish()
    }
}

/// Two configs are equal when they build the same protocol *stack*: every
/// spec-grammar field is compared, the numeric backend is not (backends
/// are execution engines for the same protocol, not protocol identity —
/// and the spec string, which `SpecChange` ships between machines,
/// cannot carry one).
impl PartialEq for ProtocolConfig {
    fn eq(&self, other: &Self) -> bool {
        self.kind == other.kind
            && self.dim == other.dim
            && self.k == other.k
            && self.coder == other.coder
            && self.span == other.span
            && self.p == other.p
            && self.q == other.q
            && self.base == other.base
            && self.strata == other.strata
    }
}

/// The exact spec-grammar string: `parse(cfg.to_string(), cfg.dim)`
/// reconstructs `cfg` field for field (property-tested below). Only the
/// arguments that differ from what parsing the bare kind name would
/// produce are emitted, so defaults stay terse (`binary`, `varlen`) and
/// everything else is explicit (`klevel:k=8,p=0.5`).
impl std::fmt::Display for ProtocolConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.kind.name())?;
        // What `parse(kind.name(), dim)` would default each field to.
        let default_k = if self.kind == Kind::Varlen { 0 } else { 16 };
        let mut sep = ':';
        let mut arg = |f: &mut std::fmt::Formatter<'_>, args: std::fmt::Arguments<'_>| {
            let r = write!(f, "{sep}{args}");
            sep = ',';
            r
        };
        if self.k != default_k {
            arg(f, format_args!("k={}", self.k))?;
        }
        if self.base != CorrBase::KLevel {
            arg(f, format_args!("base={}", self.base.name()))?;
        }
        if self.strata != 16 {
            arg(f, format_args!("strata={}", self.strata))?;
        }
        if self.coder != Coder::Arithmetic {
            arg(f, format_args!("coder=huffman"))?;
        }
        if self.span != Span::MinMax {
            arg(f, format_args!("span=norm"))?;
        }
        if self.p != 1.0 {
            arg(f, format_args!("p={}", self.p))?;
        }
        if self.q != 1.0 {
            arg(f, format_args!("q={}", self.q))?;
        }
        Ok(())
    }
}

impl ProtocolConfig {
    pub fn new(kind: Kind, dim: usize) -> Self {
        ProtocolConfig {
            kind,
            dim,
            k: 16,
            coder: Coder::Arithmetic,
            span: Span::MinMax,
            p: 1.0,
            q: 1.0,
            base: CorrBase::KLevel,
            strata: 16,
            backend: None,
        }
    }

    pub fn float32(dim: usize) -> Self {
        Self::new(Kind::Float32, dim)
    }

    pub fn binary(dim: usize) -> Self {
        Self::new(Kind::Binary, dim)
    }

    pub fn klevel(dim: usize, k: u32) -> Self {
        Self::new(Kind::KLevel, dim).with_k(k)
    }

    pub fn rotated(dim: usize, k: u32) -> Self {
        Self::new(Kind::Rotated, dim).with_k(k)
    }

    pub fn varlen(dim: usize, k: u32) -> Self {
        Self::new(Kind::Varlen, dim).with_k(k)
    }

    pub fn with_k(mut self, k: u32) -> Self {
        self.k = k;
        self
    }

    pub fn with_sampling(mut self, p: f64) -> Self {
        self.p = p;
        self
    }

    pub fn with_coder(mut self, coder: Coder) -> Self {
        self.coder = coder;
        self
    }

    pub fn with_span(mut self, span: Span) -> Self {
        self.span = span;
        self
    }

    pub fn with_backend(mut self, backend: Arc<dyn ComputeBackend>) -> Self {
        self.backend = Some(backend);
        self
    }

    /// Effective k (resolving the `0 = sqrt(d)+1` default).
    pub fn effective_k(&self) -> u32 {
        if self.k == 0 {
            (self.dim as f64).sqrt() as u32 + 1
        } else {
            self.k
        }
    }

    /// Parse a CLI spec like `rotated:k=16,p=0.5` for dimension `dim`.
    pub fn parse(spec: &str, dim: usize) -> Result<Self> {
        let (name, args) = match spec.split_once(':') {
            Some((n, a)) => (n, a),
            None => (spec, ""),
        };
        let kind = match name {
            "float32" | "raw" => Kind::Float32,
            "binary" | "sb" => Kind::Binary,
            "klevel" | "uniform" | "sk" => Kind::KLevel,
            "rotated" | "rotation" | "srk" => Kind::Rotated,
            "varlen" | "variable" | "svk" => Kind::Varlen,
            "qsgd" | "elias" => Kind::Qsgd,
            "drive" | "sign" => Kind::Drive,
            "correlated" | "corr" => Kind::Correlated,
            other => bail!(
                "unknown protocol `{other}` \
                 (try float32|binary|klevel|rotated|varlen|qsgd|drive|correlated)"
            ),
        };
        let mut cfg = Self::new(kind, dim);
        if kind == Kind::Varlen {
            cfg.k = 0; // default sqrt(d)+1 unless overridden
        }
        for kv in args.split(',').filter(|s| !s.is_empty()) {
            let (key, val) = kv
                .split_once('=')
                .with_context(|| format!("bad protocol arg `{kv}` (expected key=value)"))?;
            match key {
                "k" => cfg.k = val.parse().context("bad k")?,
                "p" => cfg.p = val.parse().context("bad p")?,
                "q" => cfg.q = val.parse().context("bad q")?,
                "coder" => {
                    cfg.coder = match val {
                        "arith" | "arithmetic" => Coder::Arithmetic,
                        "huff" | "huffman" => Coder::Huffman,
                        other => bail!("unknown coder `{other}`"),
                    }
                }
                "span" => {
                    cfg.span = match val {
                        "minmax" => Span::MinMax,
                        "norm" => Span::Norm,
                        other => bail!("unknown span `{other}`"),
                    }
                }
                "base" => {
                    cfg.base = match val {
                        "klevel" => CorrBase::KLevel,
                        "rotated" => CorrBase::Rotated,
                        other => bail!("unknown correlated base `{other}` (try klevel|rotated)"),
                    }
                }
                "strata" => cfg.strata = val.parse().context("bad strata")?,
                other => bail!("unknown protocol arg `{other}`"),
            }
        }
        ensure!(cfg.p > 0.0 && cfg.p <= 1.0, "p must be in (0, 1]");
        ensure!(cfg.q > 0.0 && cfg.q <= 1.0, "q must be in (0, 1]");
        ensure!(
            cfg.strata >= 2 && cfg.strata.is_power_of_two(),
            "strata must be a power of two >= 2"
        );
        Ok(cfg)
    }

    /// Build the protocol stack.
    pub fn build(&self) -> Result<Arc<dyn Protocol>> {
        let k = self.effective_k();
        ensure!(self.dim >= 1, "dim must be >= 1");
        if !matches!(self.kind, Kind::Float32 | Kind::Binary) {
            ensure!(k >= 2, "k must be >= 2");
        }
        let base: Arc<dyn Protocol> = match self.kind {
            Kind::Float32 => Arc::new(Float32Protocol::new(self.dim)),
            Kind::Binary => Arc::new(BinaryProtocol::new(self.dim)),
            Kind::KLevel => {
                let mut p = KLevelProtocol::new(self.dim, k).with_span(self.span);
                if let Some(b) = &self.backend {
                    p = p.with_backend(b.clone());
                }
                Arc::new(p)
            }
            Kind::Rotated => {
                let mut p = RotatedProtocol::new(self.dim, k);
                if let Some(b) = &self.backend {
                    p = p.with_backend(b.clone());
                }
                Arc::new(p)
            }
            Kind::Varlen => {
                let mut p = VarlenProtocol::new(self.dim, k)
                    .with_span(self.span)
                    .with_coder(self.coder);
                if let Some(b) = &self.backend {
                    p = p.with_backend(b.clone());
                }
                Arc::new(p)
            }
            Kind::Qsgd => Arc::new(QsgdProtocol::new(self.dim, k)),
            Kind::Drive => {
                let mut p = DriveProtocol::new(self.dim);
                if let Some(b) = &self.backend {
                    p = p.with_backend(b.clone());
                }
                Arc::new(p)
            }
            Kind::Correlated => {
                ensure!(
                    self.strata >= 2 && self.strata.is_power_of_two(),
                    "strata must be a power of two >= 2"
                );
                ensure!(
                    self.base == CorrBase::KLevel || self.span == Span::MinMax,
                    "correlated:base=rotated always quantizes with the min-max span"
                );
                let mut p = CorrelatedProtocol::new(self.dim, k, self.strata, self.base);
                if self.base == CorrBase::KLevel {
                    p = p.with_span(self.span);
                }
                if let Some(b) = &self.backend {
                    p = p.with_backend(b.clone());
                }
                Arc::new(p)
            }
        };
        let rotates = self.kind == Kind::Rotated
            || self.kind == Kind::Drive
            || (self.kind == Kind::Correlated && self.base == CorrBase::Rotated);
        let base = if self.q < 1.0 {
            ensure!(
                !rotates,
                "coordinate sampling (q<1) is incompatible with `{}`: \
                 the rotation mixes coordinates before quantization",
                self.kind.name()
            );
            Arc::new(CoordSampledProtocol::new(base, self.q)) as Arc<dyn Protocol>
        } else {
            base
        };
        Ok(if self.p < 1.0 {
            Arc::new(SampledProtocol::new(base, self.p))
        } else {
            base
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic_specs() {
        for (spec, want_name) in [
            ("float32", "float32"),
            ("binary", "binary"),
            ("klevel:k=8", "klevel(k=8)"),
            ("rotated:k=32", "rotated(k=32)"),
            ("varlen:k=12,coder=huffman", "varlen(k=12, huff)"),
            ("drive", "drive"),
            ("correlated:k=4", "correlated(base=klevel,k=4,m=16)"),
            ("correlated:base=rotated,k=4,strata=8", "correlated(base=rotated,k=4,m=8)"),
        ] {
            let proto = ProtocolConfig::parse(spec, 64).unwrap().build().unwrap();
            assert_eq!(proto.name(), want_name, "spec={spec}");
        }
    }

    #[test]
    fn kind_all_is_exhaustive_and_names_are_unique() {
        // Compile guard: the match must cover every variant, so adding a
        // kind outside the `kinds!` table cannot compile, and a kind
        // added to the table automatically joins `Kind::ALL` (whose
        // length is derived, never hand-counted).
        assert_eq!(Kind::ALL.len(), Kind::COUNT);
        let mut seen = std::collections::HashSet::new();
        for kind in Kind::ALL {
            let name = match kind {
                Kind::Float32 => "float32",
                Kind::Binary => "binary",
                Kind::KLevel => "klevel",
                Kind::Rotated => "rotated",
                Kind::Varlen => "varlen",
                Kind::Qsgd => "qsgd",
                Kind::Drive => "drive",
                Kind::Correlated => "correlated",
            };
            assert_eq!(name, kind.name());
            assert!(seen.insert(name), "duplicate kind name `{name}`");
            // Every canonical name parses back to its own kind.
            assert_eq!(ProtocolConfig::parse(name, 8).unwrap().kind, kind);
        }
    }

    #[test]
    fn varlen_defaults_to_sqrt_d() {
        let cfg = ProtocolConfig::parse("varlen", 256).unwrap();
        assert_eq!(cfg.effective_k(), 17);
        assert_eq!(cfg.build().unwrap().name(), "varlen(k=17, arith)");
    }

    #[test]
    fn sampling_wrapper_applied() {
        let proto = ProtocolConfig::parse("klevel:k=4,p=0.5", 16).unwrap().build().unwrap();
        assert!(proto.name().starts_with("sampled(p=0.5"));
    }

    #[test]
    fn bad_specs_rejected() {
        assert!(ProtocolConfig::parse("nonsense", 8).is_err());
        assert!(ProtocolConfig::parse("klevel:k", 8).is_err());
        assert!(ProtocolConfig::parse("klevel:q=3", 8).is_err());
        assert!(ProtocolConfig::parse("klevel:p=0", 8).is_err());
        assert!(ProtocolConfig::parse("varlen:coder=zip", 8).is_err());
        assert!(ProtocolConfig::klevel(8, 1).build().is_err());
    }

    #[test]
    fn coordinate_sampling_specs() {
        let proto = ProtocolConfig::parse("klevel:k=4,q=0.5", 16).unwrap().build().unwrap();
        assert!(proto.name().starts_with("coordsampled(q=0.5"));
        // stacked: coord sampling inside, client sampling outside
        let proto = ProtocolConfig::parse("klevel:k=4,q=0.5,p=0.5", 16).unwrap().build().unwrap();
        assert!(proto.name().starts_with("sampled(p=0.5, coordsampled"));
        assert!(ProtocolConfig::parse("rotated:k=4,q=0.5", 16).unwrap().build().is_err());
        assert!(ProtocolConfig::parse("klevel:q=0", 16).is_err());
    }

    #[test]
    fn display_emits_exact_spec_grammar() {
        for (spec, want) in [
            ("float32", "float32"),
            ("binary", "binary"),
            ("klevel:k=8", "klevel:k=8"),
            ("sk:k=16", "klevel"), // alias + default k collapse to the canonical name
            ("rotated:k=32,p=0.5", "rotated:k=32,p=0.5"),
            ("varlen", "varlen"),
            ("varlen:k=33,coder=huffman", "varlen:k=33,coder=huffman"),
            ("varlen:span=norm,q=0.25", "varlen:span=norm,q=0.25"),
            ("qsgd:k=4,p=0.125", "qsgd:k=4,p=0.125"),
        ] {
            let cfg = ProtocolConfig::parse(spec, 64).unwrap();
            assert_eq!(cfg.to_string(), want, "spec={spec}");
        }
    }

    #[test]
    fn display_parse_roundtrip_property() {
        // parse(cfg.to_string()) == cfg over the whole discrete config
        // space the planner enumerates — every kind crossed with the
        // wrapper compositions (client sampling × coordinate sampling ×
        // coder/span × correlated's base/strata args), plus awkward
        // float values whose Display must survive the grammar (Rust
        // float formatting is shortest-round-trip, so `p={}` re-parses
        // to the same bits).
        use crate::protocol::quantizer::Span;
        use crate::protocol::varlen::Coder;
        let mut n_checked = 0usize;
        for kind in Kind::ALL {
            for dim in [1usize, 64, 1000] {
                for k in [0u32, 2, 3, 16, 17, 1023] {
                    for coder in [Coder::Arithmetic, Coder::Huffman] {
                        for span in [Span::MinMax, Span::Norm] {
                            for p in [1.0f64, 0.5, 1.0 / 3.0, 0.1234567891234, 1e-9] {
                                for q in [1.0f64, 0.25, 2.0 / 3.0] {
                                    for (base, strata) in [
                                        (CorrBase::KLevel, 16u32),
                                        (CorrBase::KLevel, 64),
                                        (CorrBase::Rotated, 2),
                                    ] {
                                        let mut cfg = ProtocolConfig::new(kind, dim);
                                        cfg.k = k;
                                        cfg.coder = coder;
                                        cfg.span = span;
                                        cfg.p = p;
                                        cfg.q = q;
                                        cfg.base = base;
                                        cfg.strata = strata;
                                        let s = cfg.to_string();
                                        let back = ProtocolConfig::parse(&s, dim)
                                            .unwrap_or_else(|e| {
                                                panic!("`{s}` failed to re-parse: {e}")
                                            });
                                        assert_eq!(back, cfg, "spec `{s}` round-trip diverged");
                                        n_checked += 1;
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        assert!(n_checked > 5000, "property grid unexpectedly small");
    }

    #[test]
    fn all_kinds_build_and_run() {
        use crate::protocol::{run_round, RoundCtx};
        let xs: Vec<Vec<f32>> = (0..4).map(|i| vec![i as f32 * 0.1; 32]).collect();
        // Derived from Kind::ALL so a new kind joins automatically (a
        // kind whose defaults cannot build at small dims would fail here).
        for kind in Kind::ALL {
            let cfg = ProtocolConfig::new(kind, 32).with_k(4);
            let spec = cfg.to_string();
            let proto = cfg.build().unwrap();
            let ctx = RoundCtx::new(0, 7);
            let (est, bits) = run_round(proto.as_ref(), &ctx, &xs).unwrap();
            assert_eq!(est.len(), 32, "spec={spec}");
            assert!(bits > 0, "spec={spec}");
        }
    }

    #[test]
    fn correlated_spec_arguments_validated() {
        // strata must be a power of two ≥ 2, at parse and at build.
        assert!(ProtocolConfig::parse("correlated:strata=3", 8).is_err());
        assert!(ProtocolConfig::parse("correlated:strata=0", 8).is_err());
        assert!(ProtocolConfig::parse("correlated:base=zip", 8).is_err());
        // base=rotated mixes coordinates: q<1 must be rejected, span is
        // pinned to minmax.
        assert!(ProtocolConfig::parse("correlated:base=rotated,q=0.5", 16)
            .unwrap()
            .build()
            .is_err());
        assert!(ProtocolConfig::parse("correlated:base=rotated,span=norm", 16)
            .unwrap()
            .build()
            .is_err());
        assert!(ProtocolConfig::parse("drive:q=0.5", 16).unwrap().build().is_err());
        // klevel base composes with both sampling wrappers.
        let proto = ProtocolConfig::parse("correlated:k=4,q=0.5,p=0.5", 16)
            .unwrap()
            .build()
            .unwrap();
        assert!(proto.name().starts_with("sampled(p=0.5, coordsampled"));
    }
}
