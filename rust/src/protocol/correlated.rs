//! Correlated quantization — anti-correlated stochastic rounding across
//! clients (Suresh et al., "Correlated quantization for distributed mean
//! estimation and optimization", arXiv 2203.04925).
//!
//! Independent stochastic rounding leaves each coordinate of the sum
//! with variance `Σᵢ fᵢ(1−fᵢ)·wᵢ²`: the per-client errors are unbiased
//! but add up. Correlated quantization draws the rounding offsets from
//! *shared* randomness instead and partitions the unit interval among
//! the clients: client `i` of a round rounds coordinate `j` with
//!
//! ```text
//! u_ij = frac(v_j + π(rank_i)/m)
//! ```
//!
//! where `v_j` is a shared per-coordinate uniform, `m` = [`strata`], and
//! `π` is a round-scoped affine permutation of `Z_m` (odd multiplier, so
//! it is a bijection for the power-of-two `m`). Marginally every `u_ij`
//! is still `U[0,1)` — the estimator stays exactly unbiased, even for an
//! arbitrary surviving subset of clients (the churn case Lemma 8's
//! partial estimator relies on) — but jointly the offsets are stratified:
//! any two clients' rounding indicators are non-positively correlated,
//! so the error of the *sum* is at most the independent-randomness
//! variance, with ≈2× reduction for heterogeneous data at `m ≈ n` and
//! near-total cancellation for homogeneous clients.
//!
//! All of this rides on the `shared_seed` the wire's `RoundStart`
//! carries (see [`crate::rng::correlated_stream`]): every client derives
//! `v`, `π` identically, with no extra communication. The wire format,
//! frame layout, and decode path are *identical* to the base quantizer's
//! — same bits, strictly better MSE — so the base `klevel`/`rotated`
//! read/write statics are reused verbatim.

use std::sync::Arc;

use anyhow::{ensure, Result};

use super::klevel::KLevelProtocol;
use super::quantizer::Span;
use super::{Accumulator, EncodeScratch, Frame, Protocol, RoundCtx, RoundState};
use crate::coding::float::ScalarCodec;
use crate::rng;
use crate::rotation::{hadamard, Rotation};
use crate::runtime::engine::{ComputeBackend, NativeBackend};

/// Which base quantizer the correlated offsets drive.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CorrBase {
    /// k-level grid on raw coordinates (π_sk's frame format).
    KLevel,
    /// rotate-then-quantize (π_srk's frame format, padded dimension).
    Rotated,
}

impl CorrBase {
    pub fn name(&self) -> &'static str {
        match self {
            CorrBase::KLevel => "klevel",
            CorrBase::Rotated => "rotated",
        }
    }
}

/// Correlated stochastic k-level quantization over a base family.
pub struct CorrelatedProtocol {
    dim: usize,
    /// Padded dimension for the rotated base; `== dim` for klevel.
    idim: usize,
    k: u32,
    span: Span,
    /// Number of offset strata `m` (power of two). Clients take stratum
    /// `client_id mod m`; gains need distinct strata, so plan `m ≥ n`.
    strata: u32,
    base: CorrBase,
    pub header: ScalarCodec,
    backend: Arc<dyn ComputeBackend>,
}

impl CorrelatedProtocol {
    pub fn new(dim: usize, k: u32, strata: u32, base: CorrBase) -> Self {
        assert!(k >= 2, "need k >= 2 levels");
        assert!(
            strata >= 2 && strata.is_power_of_two(),
            "strata must be a power of two >= 2, got {strata}"
        );
        let idim = match base {
            CorrBase::KLevel => dim,
            CorrBase::Rotated => hadamard::pad_dim(dim),
        };
        CorrelatedProtocol {
            dim,
            idim,
            k,
            span: Span::MinMax,
            strata,
            base,
            header: ScalarCodec::Exact32,
            backend: NativeBackend::shared(),
        }
    }

    pub fn with_span(mut self, span: Span) -> Self {
        assert!(
            self.base == CorrBase::KLevel || span == Span::MinMax,
            "the rotated base always quantizes with the min-max span"
        );
        self.span = span;
        self
    }

    pub fn with_backend(mut self, backend: Arc<dyn ComputeBackend>) -> Self {
        self.backend = backend;
        self
    }

    pub fn k(&self) -> u32 {
        self.k
    }

    pub fn strata(&self) -> u32 {
        self.strata
    }

    pub fn base(&self) -> CorrBase {
        self.base
    }

    fn bits_per_coord(&self) -> u32 {
        32 - (self.k - 1).leading_zeros()
    }

    /// Same frame cost as the base quantizer: the correlation is free.
    pub fn frame_bits(&self) -> u64 {
        self.idim as u64 * self.bits_per_coord() as u64 + 2 * self.header.bits() as u64
    }

    /// Fill `u` with this client's stratified rounding offsets
    /// `u_j = frac(v_j + π(rank)/m)`, all derived from the round's
    /// shared correlated stream.
    fn fill_offsets(&self, ctx: &RoundCtx, client_id: u64, u: &mut [f32]) {
        let mut shared = rng::correlated_stream(ctx.seed, ctx.round);
        shared.fill_uniform_f32(u);
        let m = self.strata as u64;
        // Round-scoped affine permutation of Z_m: odd multiplier `a` is
        // a unit mod any power of two, so π is a bijection and clients
        // with distinct ranks land in distinct strata.
        let a = shared.next_u64() | 1;
        let t = shared.next_u64();
        // The rank is the client-id field of the packed stream id (low
        // 32 bits): slots and sessions of one client share its stratum,
        // while distinct clients of one round spread across strata.
        let rank = client_id & ((1u64 << rng::CLIENT_ID_BITS) - 1) & (m - 1);
        let offset = (a.wrapping_mul(rank).wrapping_add(t) & (m - 1)) as f32 / m as f32;
        for v in u.iter_mut() {
            let shifted = *v + offset;
            *v = if shifted >= 1.0 { shifted - 1.0 } else { shifted };
        }
    }
}

impl Protocol for CorrelatedProtocol {
    fn name(&self) -> String {
        format!("correlated(base={},k={},m={})", self.base.name(), self.k, self.strata)
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn prepare(&self, ctx: &RoundCtx) -> RoundState {
        match self.base {
            CorrBase::KLevel => RoundState::bare(*ctx),
            CorrBase::Rotated => RoundState::with_rotation(
                *ctx,
                Rotation::sample(self.dim, &mut ctx.public()),
            ),
        }
    }

    fn encode_with(
        &self,
        state: &RoundState,
        scratch: &mut EncodeScratch,
        client_id: u64,
        x: &[f32],
        frame: &mut Frame,
    ) -> bool {
        assert_eq!(x.len(), self.dim, "dimension mismatch");
        scratch.u.resize(self.idim, 0.0);
        self.fill_offsets(&state.ctx, client_id, &mut scratch.u);
        let (xmin, s) = match self.base {
            CorrBase::KLevel => self
                .backend
                .quantize_into(x, &scratch.u, self.span, self.k, &mut scratch.bins)
                .expect("backend quantize failed"),
            CorrBase::Rotated => {
                let rot = state.rotation();
                scratch.buf.resize(self.idim, 0.0);
                scratch.buf[..self.dim].copy_from_slice(x);
                for v in &mut scratch.buf[self.dim..] {
                    *v = 0.0;
                }
                self.backend
                    .encode_rotated_in_place(
                        &mut scratch.buf,
                        rot.signs(),
                        &scratch.u,
                        self.k,
                        &mut scratch.bins,
                    )
                    .expect("backend encode_rotated failed")
            }
        };
        KLevelProtocol::write_frame_into(
            &self.header,
            self.bits_per_coord(),
            xmin,
            s,
            &scratch.bins,
            frame,
        );
        true
    }

    fn new_accumulator(&self) -> Accumulator {
        Accumulator::new(self.idim)
    }

    fn internal_dim(&self) -> usize {
        self.idim
    }

    fn accumulate_with(
        &self,
        _state: &RoundState,
        frame: &Frame,
        acc: &mut Accumulator,
    ) -> Result<()> {
        ensure!(acc.sum.len() == self.idim, "accumulator dimension mismatch");
        KLevelProtocol::read_frame_into(
            &self.header,
            self.bits_per_coord(),
            self.k,
            self.idim,
            frame,
            &mut acc.sum,
        )?;
        acc.frames += 1;
        Ok(())
    }

    fn finish_scaled_with(&self, state: &RoundState, acc: Accumulator, divisor: f64) -> Vec<f32> {
        match self.base {
            CorrBase::KLevel => acc.into_scaled(divisor),
            CorrBase::Rotated => {
                let sum = acc.into_scaled(divisor);
                let mut back = self
                    .backend
                    .rotate_inv(&sum, state.rotation().signs())
                    .expect("backend rotate_inv failed");
                back.truncate(self.dim);
                back
            }
        }
    }

    fn mse_bound(&self, n: usize, avg_norm_sq: f64) -> Option<f64> {
        // The independent-randomness bound of the base family remains a
        // valid worst case: stratified offsets are marginally uniform
        // and pairwise non-positively correlated, so the sum's variance
        // never exceeds the independent twin's (Theorem 2 / Theorem 3).
        // The *gain* below the bound is what Calibration measures.
        let km1 = (self.k - 1) as f64;
        match self.base {
            CorrBase::KLevel => {
                Some(self.dim as f64 / (2.0 * n as f64 * km1 * km1) * avg_norm_sq)
            }
            CorrBase::Rotated => {
                let d = self.idim as f64;
                Some((2.0 * d.ln() + 2.0) / (n as f64 * km1 * km1) * avg_norm_sq)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::test_support::{gaussian_clients, measure_mse};
    use crate::stats;

    #[test]
    fn frame_cost_matches_the_base_quantizer() {
        let corr = CorrelatedProtocol::new(64, 4, 16, CorrBase::KLevel);
        assert_eq!(corr.frame_bits(), 64 * 2 + 64);
        let rot = CorrelatedProtocol::new(100, 4, 16, CorrBase::Rotated);
        assert_eq!(rot.frame_bits(), 128 * 2 + 64);
        let ctx = RoundCtx::new(0, 1);
        let x = gaussian_clients(1, 64, 2).remove(0);
        let f = corr.encode(&ctx, 0, &x).unwrap();
        assert_eq!(f.bit_len, 64 * 2 + 64);
    }

    #[test]
    fn beats_independent_twin_at_equal_bits() {
        // The acceptance comparison: same wire bits, strictly lower MSE
        // than the independent-randomness twin at n >= 16.
        let d = 64;
        let n = 16;
        let xs = gaussian_clients(n, d, 11);
        let corr = CorrelatedProtocol::new(d, 4, 16, CorrBase::KLevel);
        let indep = KLevelProtocol::new(d, 4);
        let (mse_corr, bits_corr) = measure_mse(&corr, &xs, 400, 7);
        let (mse_ind, bits_ind) = measure_mse(&indep, &xs, 400, 7);
        assert_eq!(bits_corr, bits_ind, "correlation must be free on the wire");
        assert!(
            mse_corr < mse_ind * 0.85,
            "correlated {mse_corr} should be strictly below independent {mse_ind}"
        );
    }

    #[test]
    fn homogeneous_clients_cancel_almost_entirely() {
        // Identical clients with m = n distinct strata: the per-coordinate
        // rounding indicators sum to floor/ceil of n·f — the error of the
        // sum is O(1) instead of O(√n).
        let d = 32;
        let n = 16;
        let x = gaussian_clients(1, d, 3).remove(0);
        let xs = vec![x; n];
        let corr = CorrelatedProtocol::new(d, 4, 16, CorrBase::KLevel);
        let indep = KLevelProtocol::new(d, 4);
        let (mse_corr, _) = measure_mse(&corr, &xs, 300, 9);
        let (mse_ind, _) = measure_mse(&indep, &xs, 300, 9);
        assert!(
            mse_corr < mse_ind / 3.0,
            "homogeneous cancellation: correlated {mse_corr} vs independent {mse_ind}"
        );
    }

    #[test]
    fn unbiased_for_any_surviving_subset() {
        // Marginal uniformity of every u_ij ⇒ dropping clients cannot
        // bias the partial mean (the shared_seed-under-churn property).
        let d = 16;
        let xs = gaussian_clients(6, d, 21);
        let proto = CorrelatedProtocol::new(d, 4, 16, CorrBase::KLevel);
        // Clients 3..9: ranks neither aligned to 0 nor covering all strata.
        let ids: Vec<u64> = (3..9).collect();
        let truth = stats::true_mean(&xs);
        let mut sums = vec![0.0f64; d];
        let trials = 3000;
        for t in 0..trials {
            let ctx = RoundCtx::new(t, 31);
            let state = proto.prepare(&ctx);
            let mut scratch = EncodeScratch::default();
            let mut acc = proto.new_accumulator();
            for (x, &id) in xs.iter().zip(&ids) {
                let mut frame = Frame::new(Vec::new(), 0);
                assert!(proto.encode_with(&state, &mut scratch, id, x, &mut frame));
                proto.accumulate_with(&state, &frame, &mut acc).unwrap();
            }
            let est = proto.finish_scaled_with(&state, acc, xs.len() as f64);
            for (s, &e) in sums.iter_mut().zip(&est) {
                *s += e as f64;
            }
        }
        for (j, &s) in sums.iter().enumerate() {
            let mean = s / trials as f64;
            assert!(
                (mean - truth[j] as f64).abs() < 0.02,
                "coord {j}: {mean} vs {}",
                truth[j]
            );
        }
    }

    #[test]
    fn rotated_base_stays_within_theorem3_bound() {
        let xs = gaussian_clients(8, 256, 5);
        let proto = CorrelatedProtocol::new(256, 16, 8, CorrBase::Rotated);
        let (mse, _) = measure_mse(&proto, &xs, 100, 3);
        let bound = proto.mse_bound(xs.len(), stats::avg_norm_sq(&xs)).unwrap();
        assert!(mse <= bound, "mse {mse} > bound {bound}");
    }

    #[test]
    fn offsets_are_shared_randomness_only() {
        // Two clients with the same rank (ids 32 apart at m=32) produce
        // identical frames for identical inputs: nothing private leaks in.
        let proto = CorrelatedProtocol::new(16, 4, 32, CorrBase::KLevel);
        let x = gaussian_clients(1, 16, 1).remove(0);
        let mut ranks_diverged = false;
        for t in 0..8 {
            let ctx = RoundCtx::new(t, 77);
            let f1 = proto.encode(&ctx, 3, &x).unwrap();
            let f2 = proto.encode(&ctx, 3 + 32, &x).unwrap();
            assert_eq!(f1.bytes, f2.bytes, "round {t}: same rank must mean same frame");
            // Distinct ranks sit in distinct strata; over several rounds
            // the shifted offsets must change at least one rounding.
            let f3 = proto.encode(&ctx, 4, &x).unwrap();
            ranks_diverged |= f1.bytes != f3.bytes;
        }
        assert!(ranks_diverged, "distinct ranks never changed any rounding");
    }

    #[test]
    fn mse_within_base_bound() {
        let xs = gaussian_clients(8, 64, 7);
        for k in [2u32, 4, 16] {
            let proto = CorrelatedProtocol::new(64, k, 8, CorrBase::KLevel);
            let (mse, _) = measure_mse(&proto, &xs, 150, 9);
            let bound = proto.mse_bound(xs.len(), stats::avg_norm_sq(&xs)).unwrap();
            assert!(mse <= bound, "k={k}: mse {mse} > bound {bound}");
        }
    }
}
