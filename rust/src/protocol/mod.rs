//! The paper's communication protocols for distributed mean estimation,
//! organized around **round sessions**.
//!
//! | Module | Protocol | Paper |
//! |--------|----------|-------|
//! | [`binary`]   | π_sb stochastic binary            | §2.1 |
//! | [`klevel`]   | π_sk stochastic k-level           | §2.2 |
//! | [`rotated`]  | π_srk stochastic rotated k-level  | §3   |
//! | [`varlen`]   | π_svk k-level + entropy coding    | §4   |
//! | [`sampling`] | π_p client-sampling wrapper       | §5   |
//! | [`coordsample`] | coordinate-sampling wrapper    | §5 (remark) |
//! | [`qsgd`]     | QSGD-style Elias comparator       | ref [2] |
//! | [`float32`]  | uncompressed f32 baseline         | —    |
//! | [`drive`]    | DRIVE 1-bit sign + per-client scale | arXiv 2105.08339 |
//! | [`correlated`] | anti-correlated rounding offsets | arXiv 2203.04925 |
//!
//! # Lifecycle: prepare → encode → accumulate → finish
//!
//! Every protocol shares per-round *public* state (the sampled rotation
//! `R = HD`, grid layout) and per-client *private* scratch (rounding
//! uniforms, padded buffers, bin indices). The session API materializes
//! both exactly once:
//!
//! 1. **prepare** — [`Protocol::prepare`] derives the round's shared
//!    state ([`RoundState`]) from public randomness, *once per round*.
//!    For π_srk this is the only place the rotation is sampled.
//! 2. **encode** — an [`Encoder`] (or [`Protocol::encode_with`] with a
//!    caller-owned [`EncodeScratch`]) turns each client vector into a
//!    bit-exact wire [`Frame`], reusing the scratch buffers and the
//!    frame's byte buffer across clients: zero heap allocation per
//!    encode on the native backend.
//! 3. **accumulate** — a streaming [`Decoder`] folds frames into one
//!    [`Accumulator`] without per-frame allocation. Weighted frames are
//!    combined in the protocol's *internal* space (e.g. the rotated,
//!    padded space), so the inverse rotation runs once per round, not
//!    once per frame. The coordinator's aggregation paths instead decode
//!    each frame into a [`SlotPartial`] — an *exactly mergeable* per-slot
//!    state (see below) that any thread, any arrival order, and any
//!    aggregation-tree shape folds to bit-identical bits.
//! 4. **finish** — [`Decoder::finish`] / [`Decoder::finish_weighted`]
//!    divide by the effective count and undo any preprocessing (one
//!    inverse rotation for π_srk).
//!
//! The pre-session one-shot methods ([`Protocol::encode`],
//! [`Protocol::accumulate`], [`Protocol::finish`]) remain as provided
//! conveniences; each call prepares a throwaway round state.
//!
//! # Randomness model (unchanged, §1.2)
//!
//! The **public** stream (shared seed) drives the rotation; each client's
//! **private** stream drives its stochastic rounding and sampling coin.
//! Both derive from [`RoundCtx`]; a frame's bits depend only on
//! `(seed, round, client_id, x)` — never on which thread encoded it.
//!
//! # Determinism guarantees
//!
//! Two mechanisms, for two layers:
//!
//! * **Fixed fold geometry** (client-side simulation): f32 addition is
//!   not associative, so [`run_round`] and [`run_round_par`] shard
//!   clients into contiguous blocks whose size depends only on the
//!   client count (never on the thread count), accumulate each block in
//!   client-id order, and merge the per-block partial sums in block
//!   order. Any thread count therefore produces **bit-identical**
//!   estimates.
//!
//! * **Exact folds** (server-side aggregation): the coordinator's
//!   aggregation paths — the leader's streaming decode pool and the
//!   hierarchical aggregator tier — cannot fix a fold geometry, because
//!   the tree topology itself varies. They instead fold each frame into
//!   a [`SlotPartial`], whose per-coordinate state is an exact
//!   fixed-point sum ([`exact::FixedAcc`]) of the `weight × value`
//!   contributions. Integer addition is associative and commutative, so
//!   **any decode-thread count, any arrival order, and any tree of
//!   partial merges produces bit-identical state**; the single rounding
//!   to floating point happens once, in [`SlotPartial::finish`]. The
//!   serialized form ([`SlotPartial::to_bytes`]) is what aggregators
//!   forward upstream in `PartialUpload` messages.

pub mod binary;
pub mod config;
pub mod coordsample;
pub mod correlated;
pub mod drive;
pub mod exact;
pub mod float32;
pub mod klevel;
pub mod qsgd;
pub mod quantizer;
pub mod rotated;
pub mod sampling;
pub mod varlen;

use anyhow::{bail, ensure, Result};

use crate::coding::bitio::BitWriter;
use crate::rng::{self, Pcg64};
use crate::rotation::Rotation;

/// A client→server wire frame: the exact bits the protocol transmits.
#[derive(Clone, Debug)]
pub struct Frame {
    pub bytes: Vec<u8>,
    /// Exact payload length in bits (≤ bytes.len() * 8; the tail of the
    /// last byte is padding). Experiments account `bit_len`, transports
    /// move `bytes`.
    pub bit_len: u64,
}

impl Frame {
    pub fn new(bytes: Vec<u8>, bit_len: u64) -> Self {
        debug_assert!(bit_len <= bytes.len() as u64 * 8);
        Frame { bytes, bit_len }
    }

    /// An empty frame — the reusable target for [`Encoder::encode_into`].
    pub fn empty() -> Self {
        Frame { bytes: Vec::new(), bit_len: 0 }
    }

    /// Recycle this frame's byte buffer into a fresh [`BitWriter`]
    /// (cleared, capacity kept). Pair with [`Frame::store`] — this is the
    /// allocation-free encode path.
    pub fn writer(&mut self) -> BitWriter {
        self.bit_len = 0;
        BitWriter::over(std::mem::take(&mut self.bytes))
    }

    /// Store a finished writer's output back into this frame.
    pub fn store(&mut self, w: BitWriter) {
        let (bytes, bit_len) = w.finish();
        self.bytes = bytes;
        self.bit_len = bit_len;
    }
}

/// Per-round context: the experiment seed and round index from which all
/// public/private randomness is derived.
#[derive(Clone, Copy, Debug)]
pub struct RoundCtx {
    pub round: u64,
    pub seed: u64,
}

impl RoundCtx {
    pub fn new(round: u64, seed: u64) -> Self {
        RoundCtx { round, seed }
    }

    /// Public (shared) randomness stream for this round.
    pub fn public(&self) -> Pcg64 {
        rng::public_stream(self.seed, self.round)
    }

    /// Private randomness stream of `client` for this round.
    pub fn private(&self, client: u64) -> Pcg64 {
        rng::private_stream(self.seed, self.round, client)
    }

    /// A secondary private stream, domain-separated from [`Self::private`]
    /// (used for the sampling coin so it never aliases rounding uniforms).
    pub fn private_aux(&self, client: u64) -> Pcg64 {
        rng::private_stream(self.seed ^ 0xa5a5_a5a5_a5a5_a5a5, self.round, client)
    }
}

/// The shared state of one protocol round, computed once by
/// [`Protocol::prepare`] and reused by every encode/accumulate/finish of
/// that round: the sampled rotation for π_srk, and the inner protocol's
/// state for wrapper protocols. Derived entirely from public randomness,
/// so every party prepares an identical value.
#[derive(Clone, Debug)]
pub struct RoundState {
    pub ctx: RoundCtx,
    rotation: Option<Rotation>,
    inner: Option<Box<RoundState>>,
}

impl RoundState {
    /// State for a protocol with no shared per-round randomness.
    pub fn bare(ctx: RoundCtx) -> Self {
        RoundState { ctx, rotation: None, inner: None }
    }

    /// State holding the round's shared rotation (π_srk).
    pub fn with_rotation(ctx: RoundCtx, rotation: Rotation) -> Self {
        RoundState { ctx, rotation: Some(rotation), inner: None }
    }

    /// Wrapper-protocol state holding the inner protocol's state.
    pub fn wrapping(ctx: RoundCtx, inner: RoundState) -> Self {
        RoundState { ctx, rotation: None, inner: Some(Box::new(inner)) }
    }

    /// The round's rotation. Panics if this state was prepared by a
    /// protocol without one.
    pub fn rotation(&self) -> &Rotation {
        self.rotation.as_ref().expect("RoundState carries no rotation")
    }

    /// The wrapped protocol's state. Panics for non-wrapper states.
    pub fn inner_state(&self) -> &RoundState {
        self.inner.as_deref().expect("RoundState wraps no inner state")
    }
}

/// Caller-owned reusable encode scratch: every buffer a client-side
/// encode needs, allocated once and reused across clients (and rounds).
/// One instance per encoding thread.
#[derive(Clone, Debug, Default)]
pub struct EncodeScratch {
    /// Rounding uniforms from the client's private stream.
    pub u: Vec<f32>,
    /// Padded/rotated workspace (π_srk).
    pub buf: Vec<f32>,
    /// Quantizer bin indices.
    pub bins: Vec<u32>,
    /// Bin histogram (π_svk).
    pub hist: Vec<u64>,
    /// Sparsified copy of the input (coordinate-sampling wrapper).
    pub sparse: Vec<f32>,
}

/// Server-side partial sum of decoded client vectors.
#[derive(Clone, Debug)]
pub struct Accumulator {
    /// Running coordinate-wise sum (in the protocol's *internal* dimension,
    /// e.g. the padded dimension for rotated protocols).
    pub sum: Vec<f32>,
    /// Number of frames accumulated.
    pub frames: usize,
}

impl Accumulator {
    pub fn new(dim: usize) -> Self {
        Accumulator { sum: vec![0.0; dim], frames: 0 }
    }

    /// Zero the accumulator for reuse (the streaming decoder's weighted
    /// path decodes each frame into a recycled scratch accumulator).
    pub fn reset(&mut self) {
        self.sum.fill(0.0);
        self.frames = 0;
    }

    /// Consume into `sum / divisor`, scaling in place. `divisor <= 0`
    /// yields zeros — the empty-round convention every protocol shares.
    pub fn into_scaled(self, divisor: f64) -> Vec<f32> {
        let inv = if divisor > 0.0 { (1.0 / divisor) as f32 } else { 0.0 };
        let mut sum = self.sum;
        for v in sum.iter_mut() {
            *v *= inv;
        }
        sum
    }
}

/// A distributed mean-estimation protocol (client encode + server decode).
///
/// Implementations are `Send + Sync`: the round engine encodes on many
/// worker threads concurrently against one shared [`RoundState`].
pub trait Protocol: Send + Sync {
    /// Short human-readable name, e.g. `"rotated(k=16)"`.
    fn name(&self) -> String;

    /// The logical data dimension d.
    fn dim(&self) -> usize;

    /// Prepare the round's shared state from public randomness — called
    /// once per round, then reused for every encode/accumulate/finish.
    /// The default is stateless; π_srk samples the rotation here (and
    /// nowhere else), wrappers prepare their inner protocol.
    fn prepare(&self, ctx: &RoundCtx) -> RoundState {
        RoundState::bare(*ctx)
    }

    /// Client-side encode into a caller-owned frame, reusing `scratch`
    /// and the frame's byte buffer. Returns `false` if this client stays
    /// silent this round (client sampling, §5) — the frame's contents are
    /// unspecified then.
    fn encode_with(
        &self,
        state: &RoundState,
        scratch: &mut EncodeScratch,
        client_id: u64,
        x: &[f32],
        frame: &mut Frame,
    ) -> bool;

    /// A fresh accumulator sized for this protocol's internal dimension.
    fn new_accumulator(&self) -> Accumulator;

    /// The internal (accumulation-space) dimension — `new_accumulator`'s
    /// length without allocating it. Implementations override the default
    /// (which does allocate) so hot paths can ask for the dimension alone.
    fn internal_dim(&self) -> usize {
        self.new_accumulator().sum.len()
    }

    /// Server-side decode of one frame into the accumulator.
    fn accumulate_with(
        &self,
        state: &RoundState,
        frame: &Frame,
        acc: &mut Accumulator,
    ) -> Result<()>;

    /// Finish: divide by the *effective* count and undo any preprocessing.
    /// `n_total` is the number of clients that held data this round
    /// (including ones that stayed silent under sampling).
    fn finish_with(&self, state: &RoundState, acc: Accumulator, n_total: usize) -> Vec<f32> {
        self.finish_scaled_with(state, acc, n_total as f64)
    }

    /// Like [`Self::finish_with`] but with an explicit divisor (the
    /// sampling wrapper divides by `n·p` per Lemma 8 instead of n).
    fn finish_scaled_with(&self, state: &RoundState, acc: Accumulator, divisor: f64) -> Vec<f32>;

    /// Analytic worst-case MSE bound for this protocol on vectors with
    /// average squared norm `avg_norm_sq`, with `n` clients — the paper's
    /// guarantee that experiments validate against. `None` if no clean
    /// closed form exists.
    fn mse_bound(&self, n: usize, avg_norm_sq: f64) -> Option<f64>;

    // ---- one-shot conveniences (prepare a throwaway round state) ----

    /// One-shot encode. Prefer an [`Encoder`] over a prepared state when
    /// encoding more than one client: this re-derives the round state
    /// (for π_srk, the rotation) on every call.
    fn encode(&self, ctx: &RoundCtx, client_id: u64, x: &[f32]) -> Option<Frame> {
        let state = self.prepare(ctx);
        let mut scratch = EncodeScratch::default();
        let mut frame = Frame::empty();
        if self.encode_with(&state, &mut scratch, client_id, x, &mut frame) {
            Some(frame)
        } else {
            None
        }
    }

    /// One-shot accumulate (prefer [`Decoder`] over a prepared state).
    fn accumulate(&self, ctx: &RoundCtx, frame: &Frame, acc: &mut Accumulator) -> Result<()> {
        self.accumulate_with(&self.prepare(ctx), frame, acc)
    }

    /// One-shot finish (prefer [`Decoder::finish`]).
    fn finish(&self, ctx: &RoundCtx, acc: Accumulator, n_total: usize) -> Vec<f32> {
        self.finish_with(&self.prepare(ctx), acc, n_total)
    }

    /// One-shot scaled finish (prefer [`Decoder::finish_weighted`]).
    fn finish_scaled(&self, ctx: &RoundCtx, acc: Accumulator, divisor: f64) -> Vec<f32> {
        self.finish_scaled_with(&self.prepare(ctx), acc, divisor)
    }
}

/// Client-side handle for one round session: a protocol, its prepared
/// state, and owned reusable scratch. Encoding `n` clients through one
/// `Encoder` recycles every buffer (uniforms, workspace, bins, the
/// frame's bytes) — the fixed-width protocols perform zero heap
/// allocation per client on the native backend; π_svk only allocates its
/// per-client coder tables.
pub struct Encoder<'a> {
    proto: &'a dyn Protocol,
    state: &'a RoundState,
    scratch: EncodeScratch,
}

impl<'a> Encoder<'a> {
    pub fn new(proto: &'a dyn Protocol, state: &'a RoundState) -> Self {
        Encoder { proto, state, scratch: EncodeScratch::default() }
    }

    /// Encode into a caller-owned frame, reusing its byte buffer.
    /// Returns `false` if the client is silent this round.
    pub fn encode_into(&mut self, client_id: u64, x: &[f32], frame: &mut Frame) -> bool {
        self.proto.encode_with(self.state, &mut self.scratch, client_id, x, frame)
    }

    /// Encode into a fresh frame (for callers that must keep the frame,
    /// e.g. to ship it over a transport).
    pub fn encode(&mut self, client_id: u64, x: &[f32]) -> Option<Frame> {
        let mut frame = Frame::empty();
        if self.encode_into(client_id, x, &mut frame) {
            Some(frame)
        } else {
            None
        }
    }
}

/// Server-side streaming decoder for one round session: folds frames into
/// a single accumulator with no per-frame allocation. Weighted frames are
/// combined in the protocol's internal space, so protocol-level
/// postprocessing (π_srk's inverse rotation) runs once per round in
/// `finish*`, not once per frame.
pub struct Decoder<'a> {
    proto: &'a dyn Protocol,
    state: &'a RoundState,
    acc: Accumulator,
    /// Recycled scratch accumulator for the weighted path (lazy).
    scratch: Option<Accumulator>,
    /// f64 fold of the weight-scaled frames (lazy): disparate weights
    /// (e.g. very unequal cluster sizes) would lose small contributions
    /// in an f32 running sum.
    wsum: Option<Vec<f64>>,
    total_weight: f64,
    frames: usize,
}

impl<'a> Decoder<'a> {
    pub fn new(proto: &'a dyn Protocol, state: &'a RoundState) -> Self {
        Decoder {
            proto,
            state,
            acc: proto.new_accumulator(),
            scratch: None,
            wsum: None,
            total_weight: 0.0,
            frames: 0,
        }
    }

    /// Accumulate one frame with weight 1.
    pub fn push(&mut self, frame: &Frame) -> Result<()> {
        self.proto.accumulate_with(self.state, frame, &mut self.acc)?;
        self.total_weight += 1.0;
        self.frames += 1;
        Ok(())
    }

    /// Accumulate one frame scaled by `weight` (e.g. a cluster size in
    /// distributed Lloyd's). Decodes into a recycled scratch accumulator
    /// and folds it, weight-scaled, into an f64 running sum — no fresh
    /// accumulator, no per-frame inverse rotation, and no precision loss
    /// under disparate weights.
    pub fn push_weighted(&mut self, frame: &Frame, weight: f32) -> Result<()> {
        if weight == 1.0 {
            return self.push(frame);
        }
        let scratch = {
            let proto = self.proto;
            self.scratch.get_or_insert_with(|| proto.new_accumulator())
        };
        scratch.reset();
        self.proto.accumulate_with(self.state, frame, scratch)?;
        let wsum = {
            let dim = scratch.sum.len();
            self.wsum.get_or_insert_with(|| vec![0.0f64; dim])
        };
        for (a, &v) in wsum.iter_mut().zip(&scratch.sum) {
            *a += weight as f64 * v as f64;
        }
        self.acc.frames += 1;
        self.total_weight += weight as f64;
        self.frames += 1;
        Ok(())
    }

    /// Frames accumulated so far.
    pub fn frames(&self) -> usize {
        self.frames
    }

    /// Total weight accumulated so far.
    pub fn total_weight(&self) -> f64 {
        self.total_weight
    }

    /// Finish as a plain mean over `n_total` data-holding clients
    /// (silent sampled clients included — Lemma 8's estimator).
    pub fn finish(self, n_total: usize) -> Vec<f32> {
        let (proto, state) = (self.proto, self.state);
        let acc = self.into_acc();
        proto.finish_with(state, acc, n_total)
    }

    /// Finish as a weighted mean: divide by the accumulated total weight.
    pub fn finish_weighted(mut self) -> Vec<f32> {
        let (proto, state, w) = (self.proto, self.state, self.total_weight);
        if let Some(wsum) = self.wsum.take() {
            // Divide the f64 fold in f64 *before* narrowing to f32 (a huge
            // weighted sum must not overflow on the cast), then hand the
            // already-averaged slot to the protocol with divisor 1 —
            // wrapper scalings (sampling's 1/p) still apply on top.
            let inv = if w > 0.0 { 1.0 / w } else { 0.0 };
            for (a, ws) in self.acc.sum.iter_mut().zip(wsum) {
                *a = ((*a as f64 + ws) * inv) as f32;
            }
            proto.finish_scaled_with(state, self.acc, 1.0)
        } else {
            proto.finish_scaled_with(state, self.acc, w)
        }
    }

    /// Fold the f64 weighted sum (if any) back into the f32 accumulator.
    fn into_acc(mut self) -> Accumulator {
        if let Some(wsum) = self.wsum.take() {
            for (a, w) in self.acc.sum.iter_mut().zip(wsum) {
                *a += w as f32;
            }
        }
        self.acc
    }
}

/// The exactly mergeable per-slot aggregation state: what the leader's
/// decode pool produces per frame, what aggregation-tier nodes fold and
/// forward upstream (serialized inside `PartialUpload` messages), and
/// what the root finishes into a mean.
///
/// Per coordinate it keeps the exact fixed-point sum of the
/// `weight × decoded_value` contributions ([`exact::FixedAcc`]); merging
/// two partials ([`SlotPartial::merge`]) is integer addition plus
/// counter sums — associative and commutative — so **every aggregation
/// tree shape, arrival order, and decode-thread count produces
/// bit-identical state**, and [`SlotPartial::finish`] rounds exactly
/// once. The expensive half of server-side work (bit unpacking +
/// dequantization) happens in [`SlotPartial::decode`], on any thread.
#[derive(Clone, Debug, PartialEq)]
pub struct SlotPartial {
    /// Exact per-coordinate sums of `weight × value`, in the protocol's
    /// internal dimension, kept in carry-save form ([`exact::CarryVec`]):
    /// same-scale contributions cost one 16-byte window add per
    /// coordinate, and the canonical dense value — hence the wire format
    /// and the bit-identical-for-any-fold-order contract — is unchanged.
    sums: exact::CarryVec,
    /// Exact sum of the non-silent frames' weights.
    weight: exact::FixedAcc,
    /// Non-silent frames folded in.
    pub frames: u64,
    /// Clients that held this slot, including silent (sampled-out) ones —
    /// the divisor of the plain-mean path (Lemma 8 counts silent clients).
    pub holders: u64,
    /// Sum of the protocol-level `Accumulator::frames` counters (the
    /// protocol decides whether a frame bumps it).
    pub acc_frames: u64,
    /// True while every non-silent contribution had weight exactly 1.0 —
    /// selects the plain-mean finish branch, exactly like the flat
    /// leader's per-slot `all(weight == 1.0)` test did.
    uniform: bool,
}

/// Serialization version of [`SlotPartial::to_bytes`].
pub const SLOT_PARTIAL_VERSION: u8 = 1;

impl SlotPartial {
    /// The merge identity for a slot of internal dimension `dim`
    /// (contributes nothing, holds nothing).
    pub fn empty(dim: usize) -> Self {
        SlotPartial {
            sums: exact::CarryVec::new(dim),
            weight: exact::FixedAcc::zero(),
            frames: 0,
            holders: 0,
            acc_frames: 0,
            uniform: true,
        }
    }

    /// A silent (sampled-out) client's contribution: no frame, no weight,
    /// but one holder — it still counts in the plain-mean divisor.
    pub fn silent(dim: usize) -> Self {
        let mut p = Self::empty(dim);
        p.holders = 1;
        p
    }

    /// Fold in a silent client without materializing a dense
    /// [`Self::silent`] partial: bit-identical to `merge(&silent(dim))`
    /// (zero sums add nothing; silence never breaks uniformity), at zero
    /// allocation — the common case under heavy sampling.
    pub fn add_silent_holder(&mut self) {
        self.holders += 1;
    }

    /// Decode one frame into a fresh partial. Shares only the immutable
    /// round `state`, so decodes of different frames can run concurrently
    /// on any threads. Rejects non-finite decoded values or weights (an
    /// exact sum cannot carry them; they could only come from non-finite
    /// client data).
    pub fn decode(
        proto: &dyn Protocol,
        state: &RoundState,
        frame: &Frame,
        weight: f32,
    ) -> Result<Self> {
        let mut acc = proto.new_accumulator();
        proto.accumulate_with(state, frame, &mut acc)?;
        let mut p = Self::empty(acc.sum.len());
        p.add_decoded(&acc.sum, weight, acc.frames as u64)?;
        Ok(p)
    }

    /// Build a partial directly from already-decoded values (used by
    /// tests and benches; [`Self::decode`] is the real pipeline).
    pub fn from_decoded(values: &[f32], weight: f32, acc_frames: u64) -> Result<Self> {
        let mut p = Self::empty(values.len());
        p.add_decoded(values, weight, acc_frames)?;
        Ok(p)
    }

    /// Fold one already-decoded frame into this partial through the
    /// carry-save fast path — bit-identical to `merge(&from_decoded(...))`
    /// with no per-frame allocation. All contributions are validated
    /// finite *before* any state mutates, so a rejected frame leaves the
    /// partial exactly as it was.
    pub fn add_decoded(&mut self, values: &[f32], weight: f32, acc_frames: u64) -> Result<()> {
        ensure!(
            values.len() == self.sums.len(),
            "SlotPartial dimension mismatch: {} vs {}",
            self.sums.len(),
            values.len()
        );
        for &v in values {
            ensure!(
                v.is_finite() && weight.is_finite(),
                "non-finite contribution {v} × {weight} cannot be aggregated exactly"
            );
        }
        // Fails (and therefore commits nothing) on a non-finite weight
        // even when `values` is empty.
        self.weight.add_product(weight, 1.0)?;
        for (j, &v) in values.iter().enumerate() {
            self.sums.add_product_unchecked(j, v, weight);
        }
        self.frames += 1;
        self.holders += 1;
        self.acc_frames += acc_frames;
        self.uniform &= weight == 1.0;
        Ok(())
    }

    /// Decode one frame straight into this partial, reusing a
    /// caller-owned scratch accumulator: bit-identical to
    /// `merge(&SlotPartial::decode(...))` with zero per-frame allocation.
    /// A decode or validation error leaves the partial untouched.
    pub fn fold_frame(
        &mut self,
        proto: &dyn Protocol,
        state: &RoundState,
        frame: &Frame,
        weight: f32,
        scratch: &mut Accumulator,
    ) -> Result<()> {
        scratch.reset();
        proto.accumulate_with(state, frame, scratch)?;
        self.add_decoded(&scratch.sum, weight, scratch.frames as u64)
    }

    /// Internal (protocol-space) dimension of this partial.
    pub fn internal_dim(&self) -> usize {
        self.sums.len()
    }

    /// Exact total weight, rounded to f64 once.
    pub fn weight_f64(&self) -> f64 {
        self.weight.to_f64()
    }

    /// Whether every folded contribution had weight 1.0.
    pub fn is_uniform(&self) -> bool {
        self.uniform
    }

    /// Exact merge — associative and commutative, so the result is
    /// independent of the aggregation tree that produced the operands.
    pub fn merge(&mut self, other: &SlotPartial) -> Result<()> {
        ensure!(
            self.sums.len() == other.sums.len(),
            "SlotPartial dimension mismatch: {} vs {}",
            self.sums.len(),
            other.sums.len()
        );
        self.sums.merge(&other.sums);
        self.weight.add(&other.weight);
        self.frames += other.frames;
        self.holders += other.holders;
        self.acc_frames += other.acc_frames;
        self.uniform &= other.uniform;
        Ok(())
    }

    /// Restrict this partial to the contiguous coordinate slice
    /// `[lo, hi)`, keeping every fold counter (frames, holders, weight,
    /// acc_frames, uniformity) — a shard is the same set of folded
    /// contributions seen through fewer coordinates, so
    /// [`Self::concat_shards`] over any partition of
    /// `[0, internal_dim)` rebuilds the original partial bit-identically.
    pub fn slice(&self, lo: usize, hi: usize) -> Result<Self> {
        ensure!(
            lo <= hi && hi <= self.sums.len(),
            "slice [{lo}, {hi}) out of bounds for dimension {}",
            self.sums.len()
        );
        let mut sums = exact::CarryVec::new(hi - lo);
        for j in lo..hi {
            sums.add_fixed(j - lo, &self.sums.canonical(j));
        }
        Ok(SlotPartial {
            sums,
            weight: self.weight,
            frames: self.frames,
            holders: self.holders,
            acc_frames: self.acc_frames,
            uniform: self.uniform,
        })
    }

    /// Reassemble a full-dimension partial from shard slices produced by
    /// [`Self::slice`]-style folds. Each entry pairs a partial with the
    /// coordinate range it covers; the ranges must partition
    /// `[0, internal_dim)` (any order), and every shard must agree on
    /// the fold counters — they describe the same set of frames — or
    /// the concat errors out rather than fabricating a mixed estimate.
    pub fn concat_shards(
        shards: &[((u32, u32), &SlotPartial)],
        internal_dim: usize,
    ) -> Result<Self> {
        ensure!(!shards.is_empty(), "cannot concatenate zero shards");
        let (_, first) = shards[0];
        let mut out = Self::empty(internal_dim);
        out.weight = first.weight;
        out.frames = first.frames;
        out.holders = first.holders;
        out.acc_frames = first.acc_frames;
        out.uniform = first.uniform;
        let mut ordered: Vec<&((u32, u32), &SlotPartial)> = shards.iter().collect();
        ordered.sort_by_key(|((lo, _), _)| *lo);
        let mut cursor = 0u32;
        for &&((lo, hi), part) in &ordered {
            ensure!(
                lo == cursor && hi >= lo,
                "shard ranges do not partition [0, {internal_dim}): gap or overlap at {cursor}"
            );
            ensure!(
                part.internal_dim() == (hi - lo) as usize,
                "shard [{lo}, {hi}) carries {} coordinates",
                part.internal_dim()
            );
            ensure!(
                part.frames == out.frames
                    && part.holders == out.holders
                    && part.acc_frames == out.acc_frames
                    && part.uniform == out.uniform
                    && part.weight == out.weight,
                "shard [{lo}, {hi}) disagrees on fold counters — \
                 shards must cover the same set of frames"
            );
            for j in 0..part.internal_dim() {
                out.sums.add_fixed(lo as usize + j, &part.sums.canonical(j));
            }
            cursor = hi;
        }
        ensure!(
            cursor as usize == internal_dim,
            "shard ranges cover [0, {cursor}) but the dimension is {internal_dim}"
        );
        Ok(out)
    }

    /// Finish the slot at the root: round each exact sum once, divide,
    /// and run the protocol's postprocessing (e.g. π_srk's inverse
    /// rotation). Returns `(mean, total_weight)` where `total_weight` is
    /// the frame count for uniform slots and the exact weight sum
    /// otherwise — the same branch structure the flat leader always had.
    pub fn finish(&self, proto: &dyn Protocol, state: &RoundState) -> (Vec<f32>, f64) {
        let mut acc = Accumulator::new(self.sums.len());
        acc.frames = self.acc_frames as usize;
        if self.uniform {
            for (a, s) in acc.sum.iter_mut().zip(self.sums.iter_canonical()) {
                *a = s.to_f64() as f32;
            }
            let mean = proto.finish_with(state, acc, self.holders as usize);
            (mean, self.frames as f64)
        } else {
            // Divide the exact weighted sum in f64 before narrowing to
            // f32, then hand the already-averaged slot to the protocol
            // with divisor 1 — wrapper scalings (sampling's 1/p) still
            // apply on top.
            let w = self.weight.to_f64();
            let inv = if w > 0.0 { 1.0 / w } else { 0.0 };
            for (a, s) in acc.sum.iter_mut().zip(self.sums.iter_canonical()) {
                *a = (s.to_f64() * inv) as f32;
            }
            let mean = proto.finish_scaled_with(state, acc, 1.0);
            (mean, w)
        }
    }

    /// Serialized size in bytes of [`Self::to_bytes`], without building
    /// the buffer (transports account message sizes on every send).
    pub fn wire_len(&self) -> usize {
        2 + 4
            + 8 * 3
            + self.weight.wire_len()
            + self.sums.iter_canonical().map(|s| s.wire_len()).sum::<usize>()
    }

    /// Versioned serialization: `version u8 | flags u8 | dim u32 |
    /// frames u64 | holders u64 | acc_frames u64 | weight | dim × sums`,
    /// with each exact accumulator in its sparse window encoding.
    pub fn to_bytes(&self) -> Result<Vec<u8>> {
        ensure!(self.sums.len() <= u32::MAX as usize, "SlotPartial dimension exceeds u32");
        let mut out = Vec::with_capacity(self.wire_len());
        out.push(SLOT_PARTIAL_VERSION);
        out.push(self.uniform as u8);
        out.extend_from_slice(&(self.sums.len() as u32).to_le_bytes());
        out.extend_from_slice(&self.frames.to_le_bytes());
        out.extend_from_slice(&self.holders.to_le_bytes());
        out.extend_from_slice(&self.acc_frames.to_le_bytes());
        self.weight.to_bytes_into(&mut out);
        for s in self.sums.iter_canonical() {
            s.to_bytes_into(&mut out);
        }
        Ok(out)
    }

    /// Parse a serialized partial, requiring the buffer to be consumed
    /// exactly. Rejects unknown versions, malformed flags, truncated or
    /// oversized payloads.
    pub fn from_bytes(buf: &[u8]) -> Result<Self> {
        ensure!(buf.len() >= 30, "SlotPartial truncated");
        ensure!(
            buf[0] == SLOT_PARTIAL_VERSION,
            "unsupported SlotPartial version {} (expected {SLOT_PARTIAL_VERSION})",
            buf[0]
        );
        let uniform = match buf[1] {
            0 => false,
            1 => true,
            v => bail!("bad SlotPartial flags byte {v}"),
        };
        let dim = u32::from_le_bytes(buf[2..6].try_into().unwrap()) as usize;
        let frames = u64::from_le_bytes(buf[6..14].try_into().unwrap());
        let holders = u64::from_le_bytes(buf[14..22].try_into().unwrap());
        let acc_frames = u64::from_le_bytes(buf[22..30].try_into().unwrap());
        let mut pos = 30usize;
        // Each accumulator needs ≥ 3 bytes: a corrupt dim cannot reserve
        // more memory than the message already occupies.
        ensure!(
            dim as u64 <= (buf.len() as u64).saturating_sub(pos as u64) / 3,
            "SlotPartial dimension exceeds payload"
        );
        let (weight, used) = exact::FixedAcc::from_slice(&buf[pos..])?;
        pos += used;
        // dim is attacker-controlled, but the ≥3-bytes-per-accumulator
        // guard above bounds the 16·dim window allocation to a small
        // multiple of the received payload.
        let mut sums = exact::CarryVec::new(dim);
        for j in 0..dim {
            let (s, used) = exact::FixedAcc::from_slice(&buf[pos..])?;
            pos += used;
            sums.add_fixed(j, &s);
        }
        ensure!(pos == buf.len(), "trailing bytes in SlotPartial");
        let p = SlotPartial { sums, weight, frames, holders, acc_frames, uniform };
        p.check_invariants()?;
        Ok(p)
    }

    /// Semantic invariants every partial built by [`Self::decode`] /
    /// [`Self::merge`] holds by construction — enforced at the wire
    /// boundary so a structurally valid but inconsistent `PartialUpload`
    /// (e.g. nonzero sums with `holders == 0`) errors out instead of
    /// poisoning the root estimate with a division by zero.
    fn check_invariants(&self) -> Result<()> {
        ensure!(
            self.frames <= self.holders,
            "SlotPartial counts non-silent frames ({}) beyond its holders ({})",
            self.frames,
            self.holders
        );
        if self.frames == 0 {
            ensure!(
                self.weight.is_zero() && self.sums.is_all_zero(),
                "SlotPartial carries contributions but claims zero frames"
            );
        }
        if self.uniform {
            ensure!(
                self.weight.to_f64() == self.frames as f64,
                "uniform SlotPartial weight {} disagrees with its frame count {}",
                self.weight.to_f64(),
                self.frames
            );
        }
        Ok(())
    }
}

/// Shard count of the round engine. The f32 merge tree depends only on
/// the client count — never on the thread count — so every `threads`
/// value (including 1, i.e. [`run_round`]) produces bit-identical output.
const ROUND_SHARDS: usize = 32;

/// Convenience driver used by tests, benches and examples: run one full
/// round of `proto` over the client vectors, returning the mean estimate
/// and the total uplink cost in bits.
///
/// Equivalent to [`run_round_par`] with one thread (same shard structure,
/// bit-identical result).
pub fn run_round(
    proto: &dyn Protocol,
    ctx: &RoundCtx,
    xs: &[Vec<f32>],
) -> Result<(Vec<f32>, u64)> {
    let mut scratch = EncodeScratch::default();
    let mut frame = Frame::empty();
    run_round_with_scratch(proto, ctx, xs, &mut scratch, &mut frame)
}

/// Encode + accumulate one contiguous client shard into its own partial
/// accumulator — the unit of work both round drivers share.
fn run_round_shard(
    proto: &dyn Protocol,
    state: &RoundState,
    xs: &[Vec<f32>],
    shard_len: usize,
    sidx: usize,
    scratch: &mut EncodeScratch,
    frame: &mut Frame,
) -> Result<(Accumulator, u64)> {
    let base = sidx * shard_len;
    let chunk = &xs[base..(base + shard_len).min(xs.len())];
    let mut acc = proto.new_accumulator();
    let mut bits = 0u64;
    for (j, x) in chunk.iter().enumerate() {
        if proto.encode_with(state, scratch, (base + j) as u64, x, frame) {
            bits += frame.bit_len;
            proto.accumulate_with(state, frame, &mut acc)?;
        }
    }
    Ok((acc, bits))
}

/// [`run_round`] with caller-owned encode scratch and frame buffers,
/// reused across calls. The rate-calibration probe path drives hundreds
/// of spec fits × trials through this, so the per-round scratch (the
/// rotation workspace, rounding uniforms, bin buffers, the frame's
/// bytes) is allocated once per `Calibration` instead of once per probe
/// round. Bit-identical to [`run_round`]: same shard geometry, same
/// client-id-order merge.
pub fn run_round_with_scratch(
    proto: &dyn Protocol,
    ctx: &RoundCtx,
    xs: &[Vec<f32>],
    scratch: &mut EncodeScratch,
    frame: &mut Frame,
) -> Result<(Vec<f32>, u64)> {
    let state = proto.prepare(ctx);
    let n = xs.len();
    if n == 0 {
        return Ok((proto.finish_with(&state, proto.new_accumulator(), 0), 0));
    }
    let shard_len = n.div_ceil(ROUND_SHARDS).max(1);
    let n_shards = n.div_ceil(shard_len);
    let (mut acc, mut bits) = run_round_shard(proto, &state, xs, shard_len, 0, scratch, frame)?;
    for sidx in 1..n_shards {
        let (part, b) = run_round_shard(proto, &state, xs, shard_len, sidx, scratch, frame)?;
        for (a, v) in acc.sum.iter_mut().zip(part.sum) {
            *a += v;
        }
        acc.frames += part.frames;
        bits += b;
    }
    Ok((proto.finish_with(&state, acc, n), bits))
}

/// Parallel round engine: prepare once, shard clients across `threads`
/// scoped worker threads (per-thread [`EncodeScratch`] and recycled
/// frame), accumulate each shard into its own partial accumulator, and
/// merge the partials deterministically in client-id order.
///
/// Bit-identical to [`run_round`] for every thread count — see the
/// module-level determinism guarantee.
pub fn run_round_par(
    proto: &dyn Protocol,
    ctx: &RoundCtx,
    xs: &[Vec<f32>],
    threads: usize,
) -> Result<(Vec<f32>, u64)> {
    let n = xs.len();
    if n == 0 {
        return run_round(proto, ctx, xs);
    }
    // Contiguous client shards; the geometry is a function of n alone.
    let shard_len = n.div_ceil(ROUND_SHARDS).max(1);
    let n_shards = n.div_ceil(shard_len);
    let threads = threads.clamp(1, n_shards);
    if threads == 1 {
        return run_round(proto, ctx, xs);
    }
    let state = proto.prepare(ctx);

    // Encode + accumulate one shard into its own partial accumulator.
    let run_shard = |sidx: usize,
                     scratch: &mut EncodeScratch,
                     frame: &mut Frame|
     -> Result<(usize, Accumulator, u64)> {
        run_round_shard(proto, &state, xs, shard_len, sidx, scratch, frame)
            .map(|(acc, bits)| (sidx, acc, bits))
    };

    let mut parts: Vec<(usize, Accumulator, u64)> = {
        let next = std::sync::atomic::AtomicUsize::new(0);
        let run_shard = &run_shard;
        let next = &next;
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..threads)
                .map(|_| {
                    scope.spawn(move || {
                        let mut scratch = EncodeScratch::default();
                        let mut frame = Frame::empty();
                        let mut out = Vec::new();
                        loop {
                            let s = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                            if s >= n_shards {
                                break;
                            }
                            out.push(run_shard(s, &mut scratch, &mut frame));
                        }
                        out
                    })
                })
                .collect();
            let mut all = Vec::with_capacity(n_shards);
            for h in handles {
                for r in h.join().expect("round worker thread panicked") {
                    all.push(r?);
                }
            }
            Ok::<_, anyhow::Error>(all)
        })?
    };

    // Deterministic merge: partial sums folded in shard (client-id) order.
    parts.sort_by_key(|(s, _, _)| *s);
    let mut parts = parts.into_iter();
    let (_, mut acc, mut bits) = parts.next().expect("at least one shard");
    for (_, part, b) in parts {
        for (a, v) in acc.sum.iter_mut().zip(part.sum) {
            *a += v;
        }
        acc.frames += part.frames;
        bits += b;
    }
    Ok((proto.finish_with(&state, acc, n), bits))
}

#[cfg(test)]
pub(crate) mod test_support {
    //! Shared helpers for protocol test modules.
    use super::*;
    use crate::stats;

    /// Measure the empirical MSE of `proto` over `trials` independent
    /// rounds on fixed data, plus the average bits per round.
    pub fn measure_mse(
        proto: &dyn Protocol,
        xs: &[Vec<f32>],
        trials: u64,
        seed: u64,
    ) -> (f64, f64) {
        let truth = stats::true_mean(xs);
        let mut err = stats::Running::new();
        let mut bits = stats::Running::new();
        for t in 0..trials {
            let ctx = RoundCtx::new(t, seed);
            let (est, b) = run_round(proto, &ctx, xs).expect("round failed");
            err.push(stats::sq_error(&est, &truth));
            bits.push(b as f64);
        }
        (err.mean(), bits.mean())
    }

    /// Gaussian client vectors.
    pub fn gaussian_clients(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = crate::rng::Pcg64::new(seed);
        (0..n)
            .map(|_| {
                let mut x = vec![0.0f32; d];
                rng.fill_gaussian_f32(&mut x);
                x
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::test_support::gaussian_clients;
    use super::*;
    use crate::protocol::config::ProtocolConfig;

    #[test]
    fn session_encoder_matches_oneshot_encode() {
        let d = 60;
        let xs = gaussian_clients(6, d, 3);
        for spec in [
            "float32",
            "binary",
            "klevel:k=16",
            "rotated:k=16",
            "varlen:k=8",
            "qsgd:k=8",
            "drive",
            "correlated:k=8,strata=8",
            "correlated:base=rotated,k=8",
        ] {
            let proto = ProtocolConfig::parse(spec, d).unwrap().build().unwrap();
            let ctx = RoundCtx::new(5, 11);
            let state = proto.prepare(&ctx);
            let mut enc = Encoder::new(proto.as_ref(), &state);
            let mut frame = Frame::empty();
            for (i, x) in xs.iter().enumerate() {
                let oneshot = proto.encode(&ctx, i as u64, x).unwrap();
                assert!(enc.encode_into(i as u64, x, &mut frame), "spec={spec}");
                assert_eq!(frame.bytes, oneshot.bytes, "spec={spec} client {i}");
                assert_eq!(frame.bit_len, oneshot.bit_len, "spec={spec} client {i}");
            }
        }
    }

    #[test]
    fn run_round_with_scratch_matches_run_round() {
        // The scratch-reusing driver must be bit-identical to run_round
        // even when the scratch/frame arrive dirty from a *different*
        // spec and dimension (the calibration probe path interleaves
        // specs through one persistent scratch).
        let mut scratch = EncodeScratch::default();
        let mut frame = Frame::empty();
        for (spec, d, n) in [
            ("rotated:k=16", 100, 37),
            ("binary", 33, 5),
            ("klevel:k=16,p=0.5", 64, 64),
            ("varlen:k=8", 48, 3),
            ("qsgd:k=8", 200, 9),
            ("drive", 90, 6),
            ("correlated:k=4,strata=8,p=0.5", 40, 12),
            ("float32", 7, 1),
            ("binary", 12, 0),
        ] {
            let xs = gaussian_clients(n, d, 23);
            let proto = ProtocolConfig::parse(spec, d).unwrap().build().unwrap();
            let ctx = RoundCtx::new(4, 31);
            let fresh = run_round(proto.as_ref(), &ctx, &xs).unwrap();
            let reused =
                run_round_with_scratch(proto.as_ref(), &ctx, &xs, &mut scratch, &mut frame)
                    .unwrap();
            assert_eq!(reused.1, fresh.1, "spec={spec}: bits diverged");
            assert_eq!(
                reused.0.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                fresh.0.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "spec={spec}: estimate not bit-identical with dirty scratch"
            );
        }
    }

    #[test]
    fn decoder_weighted_matches_manual_average() {
        let d = 16;
        let proto = ProtocolConfig::parse("float32", d).unwrap().build().unwrap();
        let ctx = RoundCtx::new(0, 3);
        let xs = gaussian_clients(3, d, 7);
        let ws = [1.0f32, 3.0, 0.5];
        let state = proto.prepare(&ctx);
        let mut enc = Encoder::new(proto.as_ref(), &state);
        let mut dec = Decoder::new(proto.as_ref(), &state);
        for ((i, x), &w) in xs.iter().enumerate().zip(&ws) {
            let f = enc.encode(i as u64, x).unwrap();
            dec.push_weighted(&f, w).unwrap();
        }
        assert_eq!(dec.frames(), 3);
        assert_eq!(dec.total_weight(), 4.5);
        let est = dec.finish_weighted();
        let total: f32 = ws.iter().sum();
        for j in 0..d {
            let want = xs.iter().zip(&ws).map(|(x, &w)| w * x[j]).sum::<f32>() / total;
            assert!((est[j] - want).abs() < 1e-4, "coord {j}: {} vs {want}", est[j]);
        }
    }

    #[test]
    fn weighted_decoder_single_inverse_rotation_is_exact() {
        // The weighted path folds in the rotated space and inverts once;
        // by linearity of R⁻¹ this must match per-frame inversion.
        let d = 32;
        let proto = ProtocolConfig::parse("rotated:k=4096", d).unwrap().build().unwrap();
        let ctx = RoundCtx::new(2, 9);
        let xs = gaussian_clients(4, d, 13);
        let ws = [2.0f32, 1.0, 0.5, 4.0];
        let state = proto.prepare(&ctx);
        let mut enc = Encoder::new(proto.as_ref(), &state);
        let mut dec = Decoder::new(proto.as_ref(), &state);
        let mut manual = vec![0.0f64; d];
        for ((i, x), &w) in xs.iter().enumerate().zip(&ws) {
            let f = enc.encode(i as u64, x).unwrap();
            dec.push_weighted(&f, w).unwrap();
            let mut acc = proto.new_accumulator();
            proto.accumulate_with(&state, &f, &mut acc).unwrap();
            let y = proto.finish_scaled_with(&state, acc, 1.0);
            for (m, &v) in manual.iter_mut().zip(&y) {
                *m += w as f64 * v as f64;
            }
        }
        let total: f64 = ws.iter().map(|&w| w as f64).sum();
        let est = dec.finish_weighted();
        for j in 0..d {
            let want = manual[j] / total;
            assert!(
                (est[j] as f64 - want).abs() < 1e-4,
                "coord {j}: {} vs {want}",
                est[j]
            );
        }
    }

    #[test]
    fn slot_partial_fold_is_grouping_and_order_invariant() {
        // The aggregation-tier contract: folding the same frames through
        // ANY tree of SlotPartial merges — sequential, reversed, paired,
        // lopsided — produces bit-identical state and finishes, for
        // uniform, weighted, and mixed-weight slots, with silent clients
        // interleaved. This is the property the hierarchical tier stands
        // on (see the module docs on exact folds).
        let d = 48;
        let xs = gaussian_clients(6, d, 17);
        for spec in [
            "float32",
            "binary",
            "klevel:k=16",
            "rotated:k=16",
            "varlen:k=8",
            "qsgd:k=8",
            "drive",
            "correlated:k=8,strata=8",
        ] {
            let proto = ProtocolConfig::parse(spec, d).unwrap().build().unwrap();
            let ctx = RoundCtx::new(3, 29);
            let state = proto.prepare(&ctx);
            let dim = proto.new_accumulator().sum.len();
            let mut enc = Encoder::new(proto.as_ref(), &state);
            let frames: Vec<Frame> =
                (0..6).map(|i| enc.encode(i as u64, &xs[i]).unwrap()).collect();
            for weights in [vec![1.0f32; 6], vec![2.0, 1.0, 0.5, 4.0, 1.0, 3.5]] {
                let mut parts: Vec<SlotPartial> = frames
                    .iter()
                    .zip(&weights)
                    .map(|(f, &w)| SlotPartial::decode(proto.as_ref(), &state, f, w).unwrap())
                    .collect();
                parts.push(SlotPartial::silent(dim)); // a sampled-out client
                // Reference: flat sequential fold.
                let mut flat = SlotPartial::empty(dim);
                for p in &parts {
                    flat.merge(p).unwrap();
                }
                // Reversed fold.
                let mut rev = SlotPartial::empty(dim);
                for p in parts.iter().rev() {
                    rev.merge(p).unwrap();
                }
                assert_eq!(rev, flat, "spec={spec}: reversed fold diverged");
                // Two-level tree: pairs merged first, then the pair sums.
                let mut tree = SlotPartial::empty(dim);
                for pair in parts.chunks(2) {
                    let mut agg = SlotPartial::empty(dim);
                    for p in pair {
                        agg.merge(p).unwrap();
                    }
                    tree.merge(&agg).unwrap();
                }
                assert_eq!(tree, flat, "spec={spec}: paired tree diverged");
                // Lopsided tree: one big span plus a singleton.
                let mut left = SlotPartial::empty(dim);
                for p in &parts[..parts.len() - 1] {
                    left.merge(p).unwrap();
                }
                left.merge(&parts[parts.len() - 1]).unwrap();
                assert_eq!(left, flat, "spec={spec}: lopsided tree diverged");
                // Identical state ⇒ identical finish; also sanity-check
                // the finish bits agree across the foldings.
                let (m1, w1) = flat.finish(proto.as_ref(), &state);
                let (m2, w2) = tree.finish(proto.as_ref(), &state);
                assert_eq!(w1, w2, "spec={spec}");
                assert_eq!(
                    m1.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    m2.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "spec={spec}: finish diverges"
                );
                assert_eq!(flat.frames, 6);
                assert_eq!(flat.holders, 7);
            }
        }
    }

    #[test]
    fn slot_partial_finish_tracks_decoder_streaming() {
        // The exact fold replaces the old f32/f64 streaming fold; the two
        // must agree to floating-point accumulation error (the exact path
        // is the more accurate of the two).
        let d = 32;
        let xs = gaussian_clients(5, d, 23);
        let ws = [1.0f32, 3.0, 0.5, 2.0, 1.0];
        for spec in ["float32", "klevel:k=64", "rotated:k=64", "drive", "correlated:k=64"] {
            let proto = ProtocolConfig::parse(spec, d).unwrap().build().unwrap();
            let ctx = RoundCtx::new(1, 7);
            let state = proto.prepare(&ctx);
            let dim = proto.new_accumulator().sum.len();
            let mut enc = Encoder::new(proto.as_ref(), &state);
            let mut dec = Decoder::new(proto.as_ref(), &state);
            let mut part = SlotPartial::empty(dim);
            for ((i, x), &w) in xs.iter().enumerate().zip(&ws) {
                let f = enc.encode(i as u64, x).unwrap();
                dec.push_weighted(&f, w).unwrap();
                part.merge(&SlotPartial::decode(proto.as_ref(), &state, &f, w).unwrap()).unwrap();
            }
            assert_eq!(part.frames, 5);
            assert_eq!(part.weight_f64(), 7.5);
            assert!(!part.is_uniform());
            let streaming = dec.finish_weighted();
            let (exact, w) = part.finish(proto.as_ref(), &state);
            assert_eq!(w, 7.5, "spec={spec}");
            for (j, (a, b)) in exact.iter().zip(&streaming).enumerate() {
                assert!((a - b).abs() < 1e-4, "spec={spec} coord {j}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn slot_partial_wire_roundtrip_is_exact() {
        let d = 40;
        let xs = gaussian_clients(4, d, 31);
        for spec in ["float32", "rotated:k=16", "varlen:k=8", "drive", "correlated:k=16"] {
            let proto = ProtocolConfig::parse(spec, d).unwrap().build().unwrap();
            let ctx = RoundCtx::new(2, 13);
            let state = proto.prepare(&ctx);
            let dim = proto.new_accumulator().sum.len();
            let mut enc = Encoder::new(proto.as_ref(), &state);
            let mut part = SlotPartial::empty(dim);
            for ((i, x), w) in xs.iter().enumerate().zip([1.0f32, 2.5, 1.0, 0.25]) {
                let f = enc.encode(i as u64, x).unwrap();
                part.merge(&SlotPartial::decode(proto.as_ref(), &state, &f, w).unwrap()).unwrap();
            }
            let bytes = part.to_bytes().unwrap();
            assert_eq!(bytes.len(), part.wire_len(), "spec={spec}: wire_len mismatch");
            let back = SlotPartial::from_bytes(&bytes).unwrap();
            assert_eq!(back, part, "spec={spec}: roundtrip diverged");
            // Truncations and trailing garbage must be rejected.
            for cut in [0, 1, 5, 29, bytes.len() / 2, bytes.len() - 1] {
                assert!(
                    SlotPartial::from_bytes(&bytes[..cut]).is_err(),
                    "spec={spec}: truncation at {cut} accepted"
                );
            }
            let mut long = bytes.clone();
            long.push(0);
            assert!(SlotPartial::from_bytes(&long).is_err(), "spec={spec}: trailing byte");
            let mut bad_ver = bytes.clone();
            bad_ver[0] = 99;
            assert!(SlotPartial::from_bytes(&bad_ver).is_err(), "spec={spec}: version");
        }
    }

    #[test]
    fn inconsistent_slot_partials_rejected_at_wire() {
        // Structurally valid but semantically inconsistent payloads — the
        // shapes only a buggy or malicious aggregator can produce — must
        // error at the wire instead of poisoning the root with Inf/NaN.
        let mut part = SlotPartial::from_decoded(&[1.0, -2.0], 1.0, 1).unwrap();
        part.merge(&SlotPartial::from_decoded(&[0.5, 3.0], 2.5, 1).unwrap()).unwrap();
        let bytes = part.to_bytes().unwrap();
        assert!(SlotPartial::from_bytes(&bytes).is_ok());
        // holders (bytes 14..22) zeroed under frames = 2: would divide by 0.
        let mut bad = bytes.clone();
        bad[14..22].fill(0);
        assert!(SlotPartial::from_bytes(&bad).is_err(), "frames beyond holders accepted");
        // Uniform flag forged on a weighted partial: weight 3.5 ≠ frames 2.
        let mut bad = bytes.clone();
        bad[1] = 1;
        assert!(SlotPartial::from_bytes(&bad).is_err(), "forged uniform flag accepted");
        // Zero frames (bytes 6..14) with nonzero sums and weight.
        let mut bad = bytes.clone();
        bad[6..14].fill(0);
        assert!(SlotPartial::from_bytes(&bad).is_err(), "contributions without frames accepted");
    }

    #[test]
    fn add_silent_holder_matches_dense_silent_merge() {
        // The allocation-free silent fold must be bit-identical to
        // merging a dense silent partial — the equivalence the streaming
        // pipeline's Option<SlotPartial> slots rely on.
        let mut dense = SlotPartial::from_decoded(&[1.5, -2.0, 0.25], 2.0, 1).unwrap();
        let mut sparse = dense.clone();
        dense.merge(&SlotPartial::silent(3)).unwrap();
        sparse.add_silent_holder();
        assert_eq!(dense, sparse);
    }

    #[test]
    fn slice_concat_roundtrips_bit_identically() {
        // Slicing a partial into any contiguous partition and
        // concatenating the slices must rebuild the exact same state —
        // the invariant dimension-sharded aggregation trees rely on.
        let mut part = SlotPartial::from_decoded(&[1.5, -2.0, 0.25, 8.0, -0.125], 2.0, 1).unwrap();
        part.merge(&SlotPartial::from_decoded(&[0.5, 3.0, -1.0, 2.0, 7.5], 0.75, 1).unwrap())
            .unwrap();
        part.add_silent_holder();
        for shards in 1u32..=7 {
            let ranges = crate::coordinator::topology::split_ranges(5, shards);
            let slices: Vec<SlotPartial> = ranges
                .iter()
                .map(|&(lo, hi)| part.slice(lo as usize, hi as usize).unwrap())
                .collect();
            let paired: Vec<((u32, u32), &SlotPartial)> =
                ranges.iter().copied().zip(slices.iter()).collect();
            let back = SlotPartial::concat_shards(&paired, 5).unwrap();
            assert_eq!(back, part, "shards={shards}");
            // Arrival order must not matter either.
            let mut reversed = paired.clone();
            reversed.reverse();
            assert_eq!(SlotPartial::concat_shards(&reversed, 5).unwrap(), part);
        }
        assert!(part.slice(3, 2).is_err(), "inverted slice accepted");
        assert!(part.slice(0, 6).is_err(), "out-of-bounds slice accepted");
    }

    #[test]
    fn concat_rejects_inconsistent_shards() {
        let part = SlotPartial::from_decoded(&[1.0, 2.0, 3.0, 4.0], 1.0, 1).unwrap();
        let a = part.slice(0, 2).unwrap();
        let b = part.slice(2, 4).unwrap();
        // Gap, overlap, wrong total, counter disagreement.
        assert!(SlotPartial::concat_shards(&[((0, 2), &a)], 4).is_err(), "gap accepted");
        assert!(
            SlotPartial::concat_shards(&[((0, 2), &a), ((1, 3), &a)], 4).is_err(),
            "overlap accepted"
        );
        assert!(
            SlotPartial::concat_shards(&[((0, 2), &a), ((2, 4), &b)], 5).is_err(),
            "short cover accepted"
        );
        let mut extra = b.clone();
        extra.add_silent_holder();
        assert!(
            SlotPartial::concat_shards(&[((0, 2), &a), ((2, 4), &extra)], 4).is_err(),
            "counter mismatch accepted"
        );
        assert!(SlotPartial::concat_shards(&[], 0).is_err(), "zero shards accepted");
    }

    #[test]
    fn empty_round_yields_zeros() {
        let proto = ProtocolConfig::parse("klevel:k=4", 8).unwrap().build().unwrap();
        let ctx = RoundCtx::new(0, 1);
        let (est, bits) = run_round(proto.as_ref(), &ctx, &[]).unwrap();
        assert_eq!(bits, 0);
        assert_eq!(est, vec![0.0; 8]);
    }

    #[test]
    fn frame_buffer_recycles_capacity() {
        let mut frame = Frame::empty();
        let mut w = frame.writer();
        w.put_bits(0xabcd, 16);
        frame.store(w);
        assert_eq!(frame.bit_len, 16);
        let ptr = frame.bytes.as_ptr();
        let mut w = frame.writer();
        w.put_bits(0x12, 8);
        frame.store(w);
        assert_eq!(frame.bit_len, 8);
        assert_eq!(frame.bytes, vec![0x12]);
        assert_eq!(frame.bytes.as_ptr(), ptr, "buffer was reallocated");
    }
}
