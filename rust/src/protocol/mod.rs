//! The paper's communication protocols for distributed mean estimation,
//! organized around **round sessions**.
//!
//! | Module | Protocol | Paper |
//! |--------|----------|-------|
//! | [`binary`]   | π_sb stochastic binary            | §2.1 |
//! | [`klevel`]   | π_sk stochastic k-level           | §2.2 |
//! | [`rotated`]  | π_srk stochastic rotated k-level  | §3   |
//! | [`varlen`]   | π_svk k-level + entropy coding    | §4   |
//! | [`sampling`] | π_p client-sampling wrapper       | §5   |
//! | [`coordsample`] | coordinate-sampling wrapper    | §5 (remark) |
//! | [`qsgd`]     | QSGD-style Elias comparator       | ref [2] |
//! | [`float32`]  | uncompressed f32 baseline         | —    |
//!
//! # Lifecycle: prepare → encode → accumulate → finish
//!
//! Every protocol shares per-round *public* state (the sampled rotation
//! `R = HD`, grid layout) and per-client *private* scratch (rounding
//! uniforms, padded buffers, bin indices). The session API materializes
//! both exactly once:
//!
//! 1. **prepare** — [`Protocol::prepare`] derives the round's shared
//!    state ([`RoundState`]) from public randomness, *once per round*.
//!    For π_srk this is the only place the rotation is sampled.
//! 2. **encode** — an [`Encoder`] (or [`Protocol::encode_with`] with a
//!    caller-owned [`EncodeScratch`]) turns each client vector into a
//!    bit-exact wire [`Frame`], reusing the scratch buffers and the
//!    frame's byte buffer across clients: zero heap allocation per
//!    encode on the native backend.
//! 3. **accumulate** — a streaming [`Decoder`] folds frames into one
//!    [`Accumulator`] without per-frame allocation. Weighted frames are
//!    combined in the protocol's *internal* space (e.g. the rotated,
//!    padded space), so the inverse rotation runs once per round, not
//!    once per frame. When frames arrive out of order (the leader's
//!    streaming pipeline), each frame can be pre-decoded on any thread
//!    into a [`SlotPartial`] and later folded with
//!    [`Decoder::push_partial`] in client-id order — bit-identical to
//!    decoding in place.
//! 4. **finish** — [`Decoder::finish`] / [`Decoder::finish_weighted`]
//!    divide by the effective count and undo any preprocessing (one
//!    inverse rotation for π_srk).
//!
//! The pre-session one-shot methods ([`Protocol::encode`],
//! [`Protocol::accumulate`], [`Protocol::finish`]) remain as provided
//! conveniences; each call prepares a throwaway round state.
//!
//! # Randomness model (unchanged, §1.2)
//!
//! The **public** stream (shared seed) drives the rotation; each client's
//! **private** stream drives its stochastic rounding and sampling coin.
//! Both derive from [`RoundCtx`]; a frame's bits depend only on
//! `(seed, round, client_id, x)` — never on which thread encoded it.
//!
//! # Determinism guarantee
//!
//! f32 addition is not associative, so the *order* of accumulation is
//! part of a round's contract. [`run_round`] and [`run_round_par`] shard
//! clients into contiguous blocks whose size depends only on the client
//! count (never on the thread count), accumulate each block in client-id
//! order, and merge the per-block partial sums in block order. Any
//! thread count therefore produces **bit-identical** estimates — the
//! leader relies on the same rule when it decodes uploads in client-id
//! order regardless of arrival order.
//!
//! The leader's streaming pipeline extends the rule to *decode* work:
//! every protocol's `accumulate_with` is a per-coordinate `+=` into the
//! accumulator, so decoding a frame into a fresh zeroed accumulator (a
//! [`SlotPartial`], on whichever decode thread picks it up first) and
//! folding the partial later adds `0.0 + v` where in-place decoding
//! would have added `v`. Those are the same f32 ops bit-for-bit: an f32
//! running sum that starts at `+0.0` can never become `-0.0` (IEEE 754
//! round-to-nearest returns `+0.0` for any exact cancellation), so the
//! extra `+0.0` is always the identity. Only the *fold order* of
//! partials matters, and [`Decoder::push_partial`] requires client-id
//! order — decode scheduling is free.

pub mod binary;
pub mod config;
pub mod coordsample;
pub mod float32;
pub mod klevel;
pub mod qsgd;
pub mod quantizer;
pub mod rotated;
pub mod sampling;
pub mod varlen;

use anyhow::Result;

use crate::coding::bitio::BitWriter;
use crate::rng::{self, Pcg64};
use crate::rotation::Rotation;

/// A client→server wire frame: the exact bits the protocol transmits.
#[derive(Clone, Debug)]
pub struct Frame {
    pub bytes: Vec<u8>,
    /// Exact payload length in bits (≤ bytes.len() * 8; the tail of the
    /// last byte is padding). Experiments account `bit_len`, transports
    /// move `bytes`.
    pub bit_len: u64,
}

impl Frame {
    pub fn new(bytes: Vec<u8>, bit_len: u64) -> Self {
        debug_assert!(bit_len <= bytes.len() as u64 * 8);
        Frame { bytes, bit_len }
    }

    /// An empty frame — the reusable target for [`Encoder::encode_into`].
    pub fn empty() -> Self {
        Frame { bytes: Vec::new(), bit_len: 0 }
    }

    /// Recycle this frame's byte buffer into a fresh [`BitWriter`]
    /// (cleared, capacity kept). Pair with [`Frame::store`] — this is the
    /// allocation-free encode path.
    pub fn writer(&mut self) -> BitWriter {
        self.bit_len = 0;
        BitWriter::over(std::mem::take(&mut self.bytes))
    }

    /// Store a finished writer's output back into this frame.
    pub fn store(&mut self, w: BitWriter) {
        let (bytes, bit_len) = w.finish();
        self.bytes = bytes;
        self.bit_len = bit_len;
    }
}

/// Per-round context: the experiment seed and round index from which all
/// public/private randomness is derived.
#[derive(Clone, Copy, Debug)]
pub struct RoundCtx {
    pub round: u64,
    pub seed: u64,
}

impl RoundCtx {
    pub fn new(round: u64, seed: u64) -> Self {
        RoundCtx { round, seed }
    }

    /// Public (shared) randomness stream for this round.
    pub fn public(&self) -> Pcg64 {
        rng::public_stream(self.seed, self.round)
    }

    /// Private randomness stream of `client` for this round.
    pub fn private(&self, client: u64) -> Pcg64 {
        rng::private_stream(self.seed, self.round, client)
    }

    /// A secondary private stream, domain-separated from [`Self::private`]
    /// (used for the sampling coin so it never aliases rounding uniforms).
    pub fn private_aux(&self, client: u64) -> Pcg64 {
        rng::private_stream(self.seed ^ 0xa5a5_a5a5_a5a5_a5a5, self.round, client)
    }
}

/// The shared state of one protocol round, computed once by
/// [`Protocol::prepare`] and reused by every encode/accumulate/finish of
/// that round: the sampled rotation for π_srk, and the inner protocol's
/// state for wrapper protocols. Derived entirely from public randomness,
/// so every party prepares an identical value.
#[derive(Clone, Debug)]
pub struct RoundState {
    pub ctx: RoundCtx,
    rotation: Option<Rotation>,
    inner: Option<Box<RoundState>>,
}

impl RoundState {
    /// State for a protocol with no shared per-round randomness.
    pub fn bare(ctx: RoundCtx) -> Self {
        RoundState { ctx, rotation: None, inner: None }
    }

    /// State holding the round's shared rotation (π_srk).
    pub fn with_rotation(ctx: RoundCtx, rotation: Rotation) -> Self {
        RoundState { ctx, rotation: Some(rotation), inner: None }
    }

    /// Wrapper-protocol state holding the inner protocol's state.
    pub fn wrapping(ctx: RoundCtx, inner: RoundState) -> Self {
        RoundState { ctx, rotation: None, inner: Some(Box::new(inner)) }
    }

    /// The round's rotation. Panics if this state was prepared by a
    /// protocol without one.
    pub fn rotation(&self) -> &Rotation {
        self.rotation.as_ref().expect("RoundState carries no rotation")
    }

    /// The wrapped protocol's state. Panics for non-wrapper states.
    pub fn inner_state(&self) -> &RoundState {
        self.inner.as_deref().expect("RoundState wraps no inner state")
    }
}

/// Caller-owned reusable encode scratch: every buffer a client-side
/// encode needs, allocated once and reused across clients (and rounds).
/// One instance per encoding thread.
#[derive(Clone, Debug, Default)]
pub struct EncodeScratch {
    /// Rounding uniforms from the client's private stream.
    pub u: Vec<f32>,
    /// Padded/rotated workspace (π_srk).
    pub buf: Vec<f32>,
    /// Quantizer bin indices.
    pub bins: Vec<u32>,
    /// Bin histogram (π_svk).
    pub hist: Vec<u64>,
    /// Sparsified copy of the input (coordinate-sampling wrapper).
    pub sparse: Vec<f32>,
}

/// Server-side partial sum of decoded client vectors.
#[derive(Clone, Debug)]
pub struct Accumulator {
    /// Running coordinate-wise sum (in the protocol's *internal* dimension,
    /// e.g. the padded dimension for rotated protocols).
    pub sum: Vec<f32>,
    /// Number of frames accumulated.
    pub frames: usize,
}

impl Accumulator {
    pub fn new(dim: usize) -> Self {
        Accumulator { sum: vec![0.0; dim], frames: 0 }
    }

    /// Zero the accumulator for reuse (the streaming decoder's weighted
    /// path decodes each frame into a recycled scratch accumulator).
    pub fn reset(&mut self) {
        self.sum.fill(0.0);
        self.frames = 0;
    }

    /// Consume into `sum / divisor`, scaling in place. `divisor <= 0`
    /// yields zeros — the empty-round convention every protocol shares.
    pub fn into_scaled(self, divisor: f64) -> Vec<f32> {
        let inv = if divisor > 0.0 { (1.0 / divisor) as f32 } else { 0.0 };
        let mut sum = self.sum;
        for v in sum.iter_mut() {
            *v *= inv;
        }
        sum
    }
}

/// A distributed mean-estimation protocol (client encode + server decode).
///
/// Implementations are `Send + Sync`: the round engine encodes on many
/// worker threads concurrently against one shared [`RoundState`].
pub trait Protocol: Send + Sync {
    /// Short human-readable name, e.g. `"rotated(k=16)"`.
    fn name(&self) -> String;

    /// The logical data dimension d.
    fn dim(&self) -> usize;

    /// Prepare the round's shared state from public randomness — called
    /// once per round, then reused for every encode/accumulate/finish.
    /// The default is stateless; π_srk samples the rotation here (and
    /// nowhere else), wrappers prepare their inner protocol.
    fn prepare(&self, ctx: &RoundCtx) -> RoundState {
        RoundState::bare(*ctx)
    }

    /// Client-side encode into a caller-owned frame, reusing `scratch`
    /// and the frame's byte buffer. Returns `false` if this client stays
    /// silent this round (client sampling, §5) — the frame's contents are
    /// unspecified then.
    fn encode_with(
        &self,
        state: &RoundState,
        scratch: &mut EncodeScratch,
        client_id: u64,
        x: &[f32],
        frame: &mut Frame,
    ) -> bool;

    /// A fresh accumulator sized for this protocol's internal dimension.
    fn new_accumulator(&self) -> Accumulator;

    /// Server-side decode of one frame into the accumulator.
    fn accumulate_with(
        &self,
        state: &RoundState,
        frame: &Frame,
        acc: &mut Accumulator,
    ) -> Result<()>;

    /// Finish: divide by the *effective* count and undo any preprocessing.
    /// `n_total` is the number of clients that held data this round
    /// (including ones that stayed silent under sampling).
    fn finish_with(&self, state: &RoundState, acc: Accumulator, n_total: usize) -> Vec<f32> {
        self.finish_scaled_with(state, acc, n_total as f64)
    }

    /// Like [`Self::finish_with`] but with an explicit divisor (the
    /// sampling wrapper divides by `n·p` per Lemma 8 instead of n).
    fn finish_scaled_with(&self, state: &RoundState, acc: Accumulator, divisor: f64) -> Vec<f32>;

    /// Analytic worst-case MSE bound for this protocol on vectors with
    /// average squared norm `avg_norm_sq`, with `n` clients — the paper's
    /// guarantee that experiments validate against. `None` if no clean
    /// closed form exists.
    fn mse_bound(&self, n: usize, avg_norm_sq: f64) -> Option<f64>;

    // ---- one-shot conveniences (prepare a throwaway round state) ----

    /// One-shot encode. Prefer an [`Encoder`] over a prepared state when
    /// encoding more than one client: this re-derives the round state
    /// (for π_srk, the rotation) on every call.
    fn encode(&self, ctx: &RoundCtx, client_id: u64, x: &[f32]) -> Option<Frame> {
        let state = self.prepare(ctx);
        let mut scratch = EncodeScratch::default();
        let mut frame = Frame::empty();
        if self.encode_with(&state, &mut scratch, client_id, x, &mut frame) {
            Some(frame)
        } else {
            None
        }
    }

    /// One-shot accumulate (prefer [`Decoder`] over a prepared state).
    fn accumulate(&self, ctx: &RoundCtx, frame: &Frame, acc: &mut Accumulator) -> Result<()> {
        self.accumulate_with(&self.prepare(ctx), frame, acc)
    }

    /// One-shot finish (prefer [`Decoder::finish`]).
    fn finish(&self, ctx: &RoundCtx, acc: Accumulator, n_total: usize) -> Vec<f32> {
        self.finish_with(&self.prepare(ctx), acc, n_total)
    }

    /// One-shot scaled finish (prefer [`Decoder::finish_weighted`]).
    fn finish_scaled(&self, ctx: &RoundCtx, acc: Accumulator, divisor: f64) -> Vec<f32> {
        self.finish_scaled_with(&self.prepare(ctx), acc, divisor)
    }
}

/// Client-side handle for one round session: a protocol, its prepared
/// state, and owned reusable scratch. Encoding `n` clients through one
/// `Encoder` recycles every buffer (uniforms, workspace, bins, the
/// frame's bytes) — the fixed-width protocols perform zero heap
/// allocation per client on the native backend; π_svk only allocates its
/// per-client coder tables.
pub struct Encoder<'a> {
    proto: &'a dyn Protocol,
    state: &'a RoundState,
    scratch: EncodeScratch,
}

impl<'a> Encoder<'a> {
    pub fn new(proto: &'a dyn Protocol, state: &'a RoundState) -> Self {
        Encoder { proto, state, scratch: EncodeScratch::default() }
    }

    /// Encode into a caller-owned frame, reusing its byte buffer.
    /// Returns `false` if the client is silent this round.
    pub fn encode_into(&mut self, client_id: u64, x: &[f32], frame: &mut Frame) -> bool {
        self.proto.encode_with(self.state, &mut self.scratch, client_id, x, frame)
    }

    /// Encode into a fresh frame (for callers that must keep the frame,
    /// e.g. to ship it over a transport).
    pub fn encode(&mut self, client_id: u64, x: &[f32]) -> Option<Frame> {
        let mut frame = Frame::empty();
        if self.encode_into(client_id, x, &mut frame) {
            Some(frame)
        } else {
            None
        }
    }
}

/// Server-side streaming decoder for one round session: folds frames into
/// a single accumulator with no per-frame allocation. Weighted frames are
/// combined in the protocol's internal space, so protocol-level
/// postprocessing (π_srk's inverse rotation) runs once per round in
/// `finish*`, not once per frame.
pub struct Decoder<'a> {
    proto: &'a dyn Protocol,
    state: &'a RoundState,
    acc: Accumulator,
    /// Recycled scratch accumulator for the weighted path (lazy).
    scratch: Option<Accumulator>,
    /// f64 fold of the weight-scaled frames (lazy): disparate weights
    /// (e.g. very unequal cluster sizes) would lose small contributions
    /// in an f32 running sum.
    wsum: Option<Vec<f64>>,
    total_weight: f64,
    frames: usize,
}

impl<'a> Decoder<'a> {
    pub fn new(proto: &'a dyn Protocol, state: &'a RoundState) -> Self {
        Decoder {
            proto,
            state,
            acc: proto.new_accumulator(),
            scratch: None,
            wsum: None,
            total_weight: 0.0,
            frames: 0,
        }
    }

    /// Accumulate one frame with weight 1.
    pub fn push(&mut self, frame: &Frame) -> Result<()> {
        self.proto.accumulate_with(self.state, frame, &mut self.acc)?;
        self.total_weight += 1.0;
        self.frames += 1;
        Ok(())
    }

    /// Accumulate one frame scaled by `weight` (e.g. a cluster size in
    /// distributed Lloyd's). Decodes into a recycled scratch accumulator
    /// and folds it, weight-scaled, into an f64 running sum — no fresh
    /// accumulator, no per-frame inverse rotation, and no precision loss
    /// under disparate weights.
    pub fn push_weighted(&mut self, frame: &Frame, weight: f32) -> Result<()> {
        if weight == 1.0 {
            return self.push(frame);
        }
        let scratch = {
            let proto = self.proto;
            self.scratch.get_or_insert_with(|| proto.new_accumulator())
        };
        scratch.reset();
        self.proto.accumulate_with(self.state, frame, scratch)?;
        let wsum = {
            let dim = scratch.sum.len();
            self.wsum.get_or_insert_with(|| vec![0.0f64; dim])
        };
        for (a, &v) in wsum.iter_mut().zip(&scratch.sum) {
            *a += weight as f64 * v as f64;
        }
        self.acc.frames += 1;
        self.total_weight += weight as f64;
        self.frames += 1;
        Ok(())
    }

    /// Frames accumulated so far.
    pub fn frames(&self) -> usize {
        self.frames
    }

    /// Total weight accumulated so far.
    pub fn total_weight(&self) -> f64 {
        self.total_weight
    }

    /// Finish as a plain mean over `n_total` data-holding clients
    /// (silent sampled clients included — Lemma 8's estimator).
    pub fn finish(self, n_total: usize) -> Vec<f32> {
        let (proto, state) = (self.proto, self.state);
        let acc = self.into_acc();
        proto.finish_with(state, acc, n_total)
    }

    /// Finish as a weighted mean: divide by the accumulated total weight.
    pub fn finish_weighted(mut self) -> Vec<f32> {
        let (proto, state, w) = (self.proto, self.state, self.total_weight);
        if let Some(wsum) = self.wsum.take() {
            // Divide the f64 fold in f64 *before* narrowing to f32 (a huge
            // weighted sum must not overflow on the cast), then hand the
            // already-averaged slot to the protocol with divisor 1 —
            // wrapper scalings (sampling's 1/p) still apply on top.
            let inv = if w > 0.0 { 1.0 / w } else { 0.0 };
            for (a, ws) in self.acc.sum.iter_mut().zip(wsum) {
                *a = ((*a as f64 + ws) * inv) as f32;
            }
            proto.finish_scaled_with(state, self.acc, 1.0)
        } else {
            proto.finish_scaled_with(state, self.acc, w)
        }
    }

    /// Fold the f64 weighted sum (if any) back into the f32 accumulator.
    fn into_acc(mut self) -> Accumulator {
        if let Some(wsum) = self.wsum.take() {
            for (a, w) in self.acc.sum.iter_mut().zip(wsum) {
                *a += w as f32;
            }
        }
        self.acc
    }

    /// Fold a pre-decoded partial. Pushing partials in client-id order is
    /// bit-identical to having called [`Self::push`] (weight 1) or
    /// [`Self::push_weighted`] on the original frames in that same order
    /// — see the module-level determinism notes for why.
    pub fn push_partial(&mut self, part: &SlotPartial) {
        debug_assert_eq!(part.acc.sum.len(), self.acc.sum.len(), "partial dimension mismatch");
        if part.weight == 1.0 {
            // Mirrors push(): accumulate_with is a per-coordinate `+=`,
            // and the protocol decides whether a frame bumps acc.frames,
            // so carry the partial's count rather than assuming 1.
            for (a, &v) in self.acc.sum.iter_mut().zip(&part.acc.sum) {
                *a += v;
            }
            self.acc.frames += part.acc.frames;
            self.total_weight += 1.0;
        } else {
            // Mirrors push_weighted(): fold weight-scaled into the f64
            // running sum; the scratch decode's frame count is dropped
            // and the decoder counts exactly one frame.
            let wsum = {
                let dim = part.acc.sum.len();
                self.wsum.get_or_insert_with(|| vec![0.0f64; dim])
            };
            for (a, &v) in wsum.iter_mut().zip(&part.acc.sum) {
                *a += part.weight as f64 * v as f64;
            }
            self.acc.frames += 1;
            self.total_weight += part.weight as f64;
        }
        self.frames += 1;
    }
}

/// One frame decoded into its own zeroed accumulator, tagged with its
/// aggregation weight: the unit of the leader's streaming pipeline. The
/// expensive half of server-side work (bit unpacking + dequantization)
/// happens here, on any thread, in any arrival order; the cheap f32/f64
/// fold is deferred to a deterministic client-id-ordered
/// [`Decoder::push_partial`] pass at the round barrier.
#[derive(Clone, Debug)]
pub struct SlotPartial {
    /// The decoded frame, in the protocol's internal space.
    pub acc: Accumulator,
    /// The frame's aggregation weight (1.0 for plain means).
    pub weight: f32,
}

impl SlotPartial {
    /// Decode one frame into a fresh partial. Shares only the immutable
    /// round `state`, so decodes of different frames can run concurrently.
    pub fn decode(
        proto: &dyn Protocol,
        state: &RoundState,
        frame: &Frame,
        weight: f32,
    ) -> Result<Self> {
        let mut acc = proto.new_accumulator();
        proto.accumulate_with(state, frame, &mut acc)?;
        Ok(SlotPartial { acc, weight })
    }
}

/// Shard count of the round engine. The f32 merge tree depends only on
/// the client count — never on the thread count — so every `threads`
/// value (including 1, i.e. [`run_round`]) produces bit-identical output.
const ROUND_SHARDS: usize = 32;

/// Convenience driver used by tests, benches and examples: run one full
/// round of `proto` over the client vectors, returning the mean estimate
/// and the total uplink cost in bits.
///
/// Equivalent to [`run_round_par`] with one thread (same shard structure,
/// bit-identical result).
pub fn run_round(
    proto: &dyn Protocol,
    ctx: &RoundCtx,
    xs: &[Vec<f32>],
) -> Result<(Vec<f32>, u64)> {
    run_round_par(proto, ctx, xs, 1)
}

/// Parallel round engine: prepare once, shard clients across `threads`
/// scoped worker threads (per-thread [`EncodeScratch`] and recycled
/// frame), accumulate each shard into its own partial accumulator, and
/// merge the partials deterministically in client-id order.
///
/// Bit-identical to [`run_round`] for every thread count — see the
/// module-level determinism guarantee.
pub fn run_round_par(
    proto: &dyn Protocol,
    ctx: &RoundCtx,
    xs: &[Vec<f32>],
    threads: usize,
) -> Result<(Vec<f32>, u64)> {
    let state = proto.prepare(ctx);
    let n = xs.len();
    if n == 0 {
        return Ok((proto.finish_with(&state, proto.new_accumulator(), 0), 0));
    }
    // Contiguous client shards; the geometry is a function of n alone.
    let shard_len = n.div_ceil(ROUND_SHARDS).max(1);
    let n_shards = n.div_ceil(shard_len);
    let threads = threads.clamp(1, n_shards);

    // Encode + accumulate one shard into its own partial accumulator.
    let run_shard = |sidx: usize,
                     scratch: &mut EncodeScratch,
                     frame: &mut Frame|
     -> Result<(usize, Accumulator, u64)> {
        let base = sidx * shard_len;
        let chunk = &xs[base..(base + shard_len).min(n)];
        let mut acc = proto.new_accumulator();
        let mut bits = 0u64;
        for (j, x) in chunk.iter().enumerate() {
            if proto.encode_with(&state, scratch, (base + j) as u64, x, frame) {
                bits += frame.bit_len;
                proto.accumulate_with(&state, frame, &mut acc)?;
            }
        }
        Ok((sidx, acc, bits))
    };

    let mut parts: Vec<(usize, Accumulator, u64)> = if threads == 1 {
        let mut scratch = EncodeScratch::default();
        let mut frame = Frame::empty();
        (0..n_shards)
            .map(|s| run_shard(s, &mut scratch, &mut frame))
            .collect::<Result<_>>()?
    } else {
        let next = std::sync::atomic::AtomicUsize::new(0);
        let run_shard = &run_shard;
        let next = &next;
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..threads)
                .map(|_| {
                    scope.spawn(move || {
                        let mut scratch = EncodeScratch::default();
                        let mut frame = Frame::empty();
                        let mut out = Vec::new();
                        loop {
                            let s = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                            if s >= n_shards {
                                break;
                            }
                            out.push(run_shard(s, &mut scratch, &mut frame));
                        }
                        out
                    })
                })
                .collect();
            let mut all = Vec::with_capacity(n_shards);
            for h in handles {
                for r in h.join().expect("round worker thread panicked") {
                    all.push(r?);
                }
            }
            Ok::<_, anyhow::Error>(all)
        })?
    };

    // Deterministic merge: partial sums folded in shard (client-id) order.
    parts.sort_by_key(|(s, _, _)| *s);
    let mut parts = parts.into_iter();
    let (_, mut acc, mut bits) = parts.next().expect("at least one shard");
    for (_, part, b) in parts {
        for (a, v) in acc.sum.iter_mut().zip(part.sum) {
            *a += v;
        }
        acc.frames += part.frames;
        bits += b;
    }
    Ok((proto.finish_with(&state, acc, n), bits))
}

#[cfg(test)]
pub(crate) mod test_support {
    //! Shared helpers for protocol test modules.
    use super::*;
    use crate::stats;

    /// Measure the empirical MSE of `proto` over `trials` independent
    /// rounds on fixed data, plus the average bits per round.
    pub fn measure_mse(
        proto: &dyn Protocol,
        xs: &[Vec<f32>],
        trials: u64,
        seed: u64,
    ) -> (f64, f64) {
        let truth = stats::true_mean(xs);
        let mut err = stats::Running::new();
        let mut bits = stats::Running::new();
        for t in 0..trials {
            let ctx = RoundCtx::new(t, seed);
            let (est, b) = run_round(proto, &ctx, xs).expect("round failed");
            err.push(stats::sq_error(&est, &truth));
            bits.push(b as f64);
        }
        (err.mean(), bits.mean())
    }

    /// Gaussian client vectors.
    pub fn gaussian_clients(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = crate::rng::Pcg64::new(seed);
        (0..n)
            .map(|_| {
                let mut x = vec![0.0f32; d];
                rng.fill_gaussian_f32(&mut x);
                x
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::test_support::gaussian_clients;
    use super::*;
    use crate::protocol::config::ProtocolConfig;

    #[test]
    fn session_encoder_matches_oneshot_encode() {
        let d = 60;
        let xs = gaussian_clients(6, d, 3);
        for spec in ["float32", "binary", "klevel:k=16", "rotated:k=16", "varlen:k=8", "qsgd:k=8"] {
            let proto = ProtocolConfig::parse(spec, d).unwrap().build().unwrap();
            let ctx = RoundCtx::new(5, 11);
            let state = proto.prepare(&ctx);
            let mut enc = Encoder::new(proto.as_ref(), &state);
            let mut frame = Frame::empty();
            for (i, x) in xs.iter().enumerate() {
                let oneshot = proto.encode(&ctx, i as u64, x).unwrap();
                assert!(enc.encode_into(i as u64, x, &mut frame), "spec={spec}");
                assert_eq!(frame.bytes, oneshot.bytes, "spec={spec} client {i}");
                assert_eq!(frame.bit_len, oneshot.bit_len, "spec={spec} client {i}");
            }
        }
    }

    #[test]
    fn decoder_weighted_matches_manual_average() {
        let d = 16;
        let proto = ProtocolConfig::parse("float32", d).unwrap().build().unwrap();
        let ctx = RoundCtx::new(0, 3);
        let xs = gaussian_clients(3, d, 7);
        let ws = [1.0f32, 3.0, 0.5];
        let state = proto.prepare(&ctx);
        let mut enc = Encoder::new(proto.as_ref(), &state);
        let mut dec = Decoder::new(proto.as_ref(), &state);
        for ((i, x), &w) in xs.iter().enumerate().zip(&ws) {
            let f = enc.encode(i as u64, x).unwrap();
            dec.push_weighted(&f, w).unwrap();
        }
        assert_eq!(dec.frames(), 3);
        assert_eq!(dec.total_weight(), 4.5);
        let est = dec.finish_weighted();
        let total: f32 = ws.iter().sum();
        for j in 0..d {
            let want = xs.iter().zip(&ws).map(|(x, &w)| w * x[j]).sum::<f32>() / total;
            assert!((est[j] - want).abs() < 1e-4, "coord {j}: {} vs {want}", est[j]);
        }
    }

    #[test]
    fn weighted_decoder_single_inverse_rotation_is_exact() {
        // The weighted path folds in the rotated space and inverts once;
        // by linearity of R⁻¹ this must match per-frame inversion.
        let d = 32;
        let proto = ProtocolConfig::parse("rotated:k=4096", d).unwrap().build().unwrap();
        let ctx = RoundCtx::new(2, 9);
        let xs = gaussian_clients(4, d, 13);
        let ws = [2.0f32, 1.0, 0.5, 4.0];
        let state = proto.prepare(&ctx);
        let mut enc = Encoder::new(proto.as_ref(), &state);
        let mut dec = Decoder::new(proto.as_ref(), &state);
        let mut manual = vec![0.0f64; d];
        for ((i, x), &w) in xs.iter().enumerate().zip(&ws) {
            let f = enc.encode(i as u64, x).unwrap();
            dec.push_weighted(&f, w).unwrap();
            let mut acc = proto.new_accumulator();
            proto.accumulate_with(&state, &f, &mut acc).unwrap();
            let y = proto.finish_scaled_with(&state, acc, 1.0);
            for (m, &v) in manual.iter_mut().zip(&y) {
                *m += w as f64 * v as f64;
            }
        }
        let total: f64 = ws.iter().map(|&w| w as f64).sum();
        let est = dec.finish_weighted();
        for j in 0..d {
            let want = manual[j] / total;
            assert!(
                (est[j] as f64 - want).abs() < 1e-4,
                "coord {j}: {} vs {want}",
                est[j]
            );
        }
    }

    #[test]
    fn push_partial_bit_identical_to_streaming_push() {
        // The leader's streaming-merge contract: pre-decoding frames into
        // SlotPartials (in any order) and folding them in client order
        // must reproduce the in-place push/push_weighted bits exactly,
        // for uniform, weighted, and mixed-weight slots.
        let d = 48;
        let xs = gaussian_clients(5, d, 17);
        for spec in ["float32", "binary", "klevel:k=16", "rotated:k=16", "varlen:k=8", "qsgd:k=8"] {
            let proto = ProtocolConfig::parse(spec, d).unwrap().build().unwrap();
            let ctx = RoundCtx::new(3, 29);
            let state = proto.prepare(&ctx);
            let mut enc = Encoder::new(proto.as_ref(), &state);
            let frames: Vec<Frame> =
                (0..5).map(|i| enc.encode(i as u64, &xs[i]).unwrap()).collect();
            for weights in [vec![1.0f32; 5], vec![2.0, 1.0, 0.5, 4.0, 1.0]] {
                let uniform = weights.iter().all(|&w| w == 1.0);
                // In-place streaming decode, client order (the reference).
                let mut dec = Decoder::new(proto.as_ref(), &state);
                for (f, &w) in frames.iter().zip(&weights) {
                    if uniform {
                        dec.push(f).unwrap();
                    } else {
                        dec.push_weighted(f, w).unwrap();
                    }
                }
                // Pre-decode in reverse order, fold in client order.
                let parts: Vec<SlotPartial> = frames
                    .iter()
                    .zip(&weights)
                    .rev()
                    .map(|(f, &w)| SlotPartial::decode(proto.as_ref(), &state, f, w).unwrap())
                    .collect();
                let mut dec_p = Decoder::new(proto.as_ref(), &state);
                for p in parts.iter().rev() {
                    dec_p.push_partial(p);
                }
                assert_eq!(dec_p.frames(), dec.frames(), "spec={spec}");
                assert_eq!(dec_p.total_weight(), dec.total_weight(), "spec={spec}");
                let (a, b) = if uniform {
                    (dec.finish(5), dec_p.finish(5))
                } else {
                    (dec.finish_weighted(), dec_p.finish_weighted())
                };
                assert_eq!(
                    a.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    b.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "spec={spec} uniform={uniform}: partial fold diverges"
                );
            }
        }
    }

    #[test]
    fn empty_round_yields_zeros() {
        let proto = ProtocolConfig::parse("klevel:k=4", 8).unwrap().build().unwrap();
        let ctx = RoundCtx::new(0, 1);
        let (est, bits) = run_round(proto.as_ref(), &ctx, &[]).unwrap();
        assert_eq!(bits, 0);
        assert_eq!(est, vec![0.0; 8]);
    }

    #[test]
    fn frame_buffer_recycles_capacity() {
        let mut frame = Frame::empty();
        let mut w = frame.writer();
        w.put_bits(0xabcd, 16);
        frame.store(w);
        assert_eq!(frame.bit_len, 16);
        let ptr = frame.bytes.as_ptr();
        let mut w = frame.writer();
        w.put_bits(0x12, 8);
        frame.store(w);
        assert_eq!(frame.bit_len, 8);
        assert_eq!(frame.bytes, vec![0x12]);
        assert_eq!(frame.bytes.as_ptr(), ptr, "buffer was reallocated");
    }
}
