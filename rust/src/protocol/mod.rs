//! The paper's communication protocols for distributed mean estimation.
//!
//! Every protocol implements [`Protocol`]: a client turns its vector into a
//! bit-exact wire [`Frame`]; the server feeds frames into an
//! [`Accumulator`] and finishes with the mean estimate. The bits counted in
//! experiments are the bits of the frames actually produced.
//!
//! | Module | Protocol | Paper |
//! |--------|----------|-------|
//! | [`binary`]   | π_sb stochastic binary            | §2.1 |
//! | [`klevel`]   | π_sk stochastic k-level           | §2.2 |
//! | [`rotated`]  | π_srk stochastic rotated k-level  | §3   |
//! | [`varlen`]   | π_svk k-level + entropy coding    | §4   |
//! | [`sampling`] | π_p client-sampling wrapper       | §5   |
//! | [`coordsample`] | coordinate-sampling wrapper    | §5 (remark) |
//! | [`qsgd`]     | QSGD-style Elias comparator       | ref [2] |
//! | [`float32`]  | uncompressed f32 baseline         | —    |
//!
//! Randomness model (§1.2): the **public** stream (shared seed) drives the
//! rotation; each client's **private** stream drives its stochastic
//! rounding and sampling coin. Both derive from [`RoundCtx`].

pub mod binary;
pub mod config;
pub mod coordsample;
pub mod float32;
pub mod klevel;
pub mod qsgd;
pub mod quantizer;
pub mod rotated;
pub mod sampling;
pub mod varlen;

use anyhow::Result;

use crate::rng::{self, Pcg64};

/// A client→server wire frame: the exact bits the protocol transmits.
#[derive(Clone, Debug)]
pub struct Frame {
    pub bytes: Vec<u8>,
    /// Exact payload length in bits (≤ bytes.len() * 8; the tail of the
    /// last byte is padding). Experiments account `bit_len`, transports
    /// move `bytes`.
    pub bit_len: u64,
}

impl Frame {
    pub fn new(bytes: Vec<u8>, bit_len: u64) -> Self {
        debug_assert!(bit_len <= bytes.len() as u64 * 8);
        Frame { bytes, bit_len }
    }
}

/// Per-round context: the experiment seed and round index from which all
/// public/private randomness is derived.
#[derive(Clone, Copy, Debug)]
pub struct RoundCtx {
    pub round: u64,
    pub seed: u64,
}

impl RoundCtx {
    pub fn new(round: u64, seed: u64) -> Self {
        RoundCtx { round, seed }
    }

    /// Public (shared) randomness stream for this round.
    pub fn public(&self) -> Pcg64 {
        rng::public_stream(self.seed, self.round)
    }

    /// Private randomness stream of `client` for this round.
    pub fn private(&self, client: u64) -> Pcg64 {
        rng::private_stream(self.seed, self.round, client)
    }

    /// A secondary private stream, domain-separated from [`Self::private`]
    /// (used for the sampling coin so it never aliases rounding uniforms).
    pub fn private_aux(&self, client: u64) -> Pcg64 {
        rng::private_stream(self.seed ^ 0xa5a5_a5a5_a5a5_a5a5, self.round, client)
    }
}

/// Server-side partial sum of decoded client vectors.
#[derive(Clone, Debug)]
pub struct Accumulator {
    /// Running coordinate-wise sum (in the protocol's *internal* dimension,
    /// e.g. the padded dimension for rotated protocols).
    pub sum: Vec<f32>,
    /// Number of frames accumulated.
    pub frames: usize,
}

impl Accumulator {
    pub fn new(dim: usize) -> Self {
        Accumulator { sum: vec![0.0; dim], frames: 0 }
    }
}

/// A distributed mean-estimation protocol (client encode + server decode).
///
/// Implementations are `Send + Sync`: the coordinator encodes on many
/// worker threads concurrently.
pub trait Protocol: Send + Sync {
    /// Short human-readable name, e.g. `"rotated(k=16)"`.
    fn name(&self) -> String;

    /// The logical data dimension d.
    fn dim(&self) -> usize;

    /// Client-side encode. Returns `None` if this client stays silent this
    /// round (client sampling, §5).
    fn encode(&self, ctx: &RoundCtx, client_id: u64, x: &[f32]) -> Option<Frame>;

    /// A fresh accumulator sized for this protocol's internal dimension.
    fn new_accumulator(&self) -> Accumulator;

    /// Server-side decode of one frame into the accumulator.
    fn accumulate(&self, ctx: &RoundCtx, frame: &Frame, acc: &mut Accumulator) -> Result<()>;

    /// Finish: divide by the *effective* count and undo any preprocessing.
    /// `n_total` is the number of clients that held data this round
    /// (including ones that stayed silent under sampling).
    fn finish(&self, ctx: &RoundCtx, acc: Accumulator, n_total: usize) -> Vec<f32> {
        self.finish_scaled(ctx, acc, n_total as f64)
    }

    /// Like [`Self::finish`] but with an explicit divisor (the sampling
    /// wrapper divides by `n·p` per Lemma 8 instead of n).
    fn finish_scaled(&self, ctx: &RoundCtx, acc: Accumulator, divisor: f64) -> Vec<f32>;

    /// Analytic worst-case MSE bound for this protocol on vectors with
    /// average squared norm `avg_norm_sq`, with `n` clients — the paper's
    /// guarantee that experiments validate against. `None` if no clean
    /// closed form exists.
    fn mse_bound(&self, n: usize, avg_norm_sq: f64) -> Option<f64>;
}

/// Convenience driver used by tests, benches and examples: run one full
/// round of `proto` over the client vectors, returning the mean estimate
/// and the total uplink cost in bits.
pub fn run_round(
    proto: &dyn Protocol,
    ctx: &RoundCtx,
    xs: &[Vec<f32>],
) -> Result<(Vec<f32>, u64)> {
    let mut acc = proto.new_accumulator();
    let mut bits = 0u64;
    for (i, x) in xs.iter().enumerate() {
        if let Some(frame) = proto.encode(ctx, i as u64, x) {
            bits += frame.bit_len;
            proto.accumulate(ctx, &frame, &mut acc)?;
        }
    }
    Ok((proto.finish(ctx, acc, xs.len()), bits))
}

#[cfg(test)]
pub(crate) mod test_support {
    //! Shared helpers for protocol test modules.
    use super::*;
    use crate::stats;

    /// Measure the empirical MSE of `proto` over `trials` independent
    /// rounds on fixed data, plus the average bits per round.
    pub fn measure_mse(
        proto: &dyn Protocol,
        xs: &[Vec<f32>],
        trials: u64,
        seed: u64,
    ) -> (f64, f64) {
        let truth = stats::true_mean(xs);
        let mut err = stats::Running::new();
        let mut bits = stats::Running::new();
        for t in 0..trials {
            let ctx = RoundCtx::new(t, seed);
            let (est, b) = run_round(proto, &ctx, xs).expect("round failed");
            err.push(stats::sq_error(&est, &truth));
            bits.push(b as f64);
        }
        (err.mean(), bits.mean())
    }

    /// Gaussian client vectors.
    pub fn gaussian_clients(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = crate::rng::Pcg64::new(seed);
        (0..n)
            .map(|_| {
                let mut x = vec![0.0f32; d];
                rng.fill_gaussian_f32(&mut x);
                x
            })
            .collect()
    }
}
