//! π_svk — stochastic k-level quantization with variable-length coding
//! (paper §4).
//!
//! Same quantization as π_sk (so Theorem 2's MSE applies verbatim), but the
//! bin indices are entropy-coded: the frame carries the bin histogram
//! `h_0..h_{k−1}` (enumerative or Elias-δ header, ≤ `k log₂((d+k)e/k)`
//! bits) followed by an arithmetic (or Huffman) payload w.r.t.
//! `p_r = h_r/d`. With the Theorem 4 span `s_i = √2‖X_i‖₂`, the expected
//! cost is `O(d(1 + log(k²/d + 1)))` bits — constant bits/dimension even at
//! `k = √d`, where the MSE reaches `O(1/n)`.

use std::sync::Arc;

use anyhow::{ensure, Result};

use super::quantizer::Span;
use super::{Accumulator, EncodeScratch, Frame, Protocol, RoundState};
#[cfg(test)]
use super::RoundCtx;
use crate::coding::bitio::BitReader;
use crate::coding::float::ScalarCodec;
use crate::coding::{arithmetic, histogram, huffman};
use crate::runtime::engine::{ComputeBackend, NativeBackend};

/// Which entropy coder compresses the bin stream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Coder {
    /// Arithmetic coding — the choice Theorem 4's analysis assumes.
    Arithmetic,
    /// Canonical Huffman — within 1 bit/coordinate of arithmetic, faster.
    Huffman,
}

/// Variable-length-coded k-level quantization protocol.
pub struct VarlenProtocol {
    dim: usize,
    k: u32,
    span: Span,
    coder: Coder,
    pub header: ScalarCodec,
    backend: Arc<dyn ComputeBackend>,
}

impl VarlenProtocol {
    /// `k = √d + 1` — the paper's sweet spot (Theorem 4 ⇒ MSE O(1/n) at
    /// O(nd) total bits).
    pub fn sqrt_d(dim: usize) -> Self {
        Self::new(dim, (dim as f64).sqrt() as u32 + 1)
    }

    pub fn new(dim: usize, k: u32) -> Self {
        assert!(k >= 2, "need k >= 2 levels");
        VarlenProtocol {
            dim,
            k,
            // Section 4: "we quantize vectors the same way in pi_sk and
            // pi_svk" -- min-max span by default; the sqrt(2)||x|| span is
            // the Theorem 4 *analysis* choice, selectable via with_span.
            span: Span::MinMax,
            coder: Coder::Arithmetic,
            header: ScalarCodec::Exact32,
            backend: NativeBackend::shared(),
        }
    }

    pub fn with_span(mut self, span: Span) -> Self {
        self.span = span;
        self
    }

    pub fn with_coder(mut self, coder: Coder) -> Self {
        self.coder = coder;
        self
    }

    pub fn with_backend(mut self, backend: Arc<dyn ComputeBackend>) -> Self {
        self.backend = backend;
        self
    }

    pub fn k(&self) -> u32 {
        self.k
    }

    /// Theorem 4's expected per-client bit bound (headers excluded are the
    /// Õ(1) scalars): `d(2 + log₂((k−1)²/2d + 5/4)) + k log₂((d+k)e/k)`.
    pub fn theorem4_bits(&self) -> f64 {
        let d = self.dim as f64;
        let km1 = (self.k - 1) as f64;
        d * (2.0 + (km1 * km1 / (2.0 * d) + 1.25).log2())
            + histogram::paper_bound_bits(self.dim as u64, self.k as u64)
    }
}

impl Protocol for VarlenProtocol {
    fn name(&self) -> String {
        let c = match self.coder {
            Coder::Arithmetic => "arith",
            Coder::Huffman => "huff",
        };
        format!("varlen(k={}, {c})", self.k)
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn encode_with(
        &self,
        state: &RoundState,
        scratch: &mut EncodeScratch,
        client_id: u64,
        x: &[f32],
        frame: &mut Frame,
    ) -> bool {
        assert_eq!(x.len(), self.dim, "dimension mismatch");
        let mut private = state.ctx.private(client_id);
        scratch.u.resize(self.dim, 0.0);
        private.fill_uniform_f32(&mut scratch.u);
        let (xmin, s) = self
            .backend
            .quantize_into(x, &scratch.u, self.span, self.k, &mut scratch.bins)
            .expect("backend quantize failed");

        scratch.hist.clear();
        scratch.hist.resize(self.k as usize, 0);
        for &b in &scratch.bins {
            scratch.hist[b as usize] += 1;
        }

        let mut w = frame.writer();
        self.header.put(&mut w, xmin);
        self.header.put(&mut w, s);
        histogram::encode(&mut w, &scratch.hist, self.dim as u64).expect("histogram encode");
        match self.coder {
            Coder::Arithmetic => {
                let model =
                    arithmetic::CumTable::from_histogram(&scratch.hist).expect("cum table");
                arithmetic::encode(&mut w, &model, &scratch.bins).expect("arith encode");
            }
            Coder::Huffman => {
                let code = huffman::HuffmanCode::from_histogram(&scratch.hist).expect("huffman");
                code.encode(&mut w, &scratch.bins).expect("huffman encode");
            }
        }
        frame.store(w);
        true
    }

    fn new_accumulator(&self) -> Accumulator {
        Accumulator::new(self.dim)
    }

    fn internal_dim(&self) -> usize {
        self.dim
    }

    fn accumulate_with(
        &self,
        _state: &RoundState,
        frame: &Frame,
        acc: &mut Accumulator,
    ) -> Result<()> {
        ensure!(acc.sum.len() == self.dim, "accumulator dimension mismatch");
        let mut r = BitReader::with_bit_len(&frame.bytes, frame.bit_len);
        let xmin = self.header.get(&mut r)?;
        let s = self.header.get(&mut r)?;
        let hist = histogram::decode(&mut r, self.dim as u64, self.k as usize)?;
        let mut bins = Vec::with_capacity(self.dim);
        match self.coder {
            Coder::Arithmetic => {
                let model = arithmetic::CumTable::from_histogram(&hist)?;
                arithmetic::decode(&mut r, &model, self.dim, &mut bins)?;
            }
            Coder::Huffman => {
                let code = huffman::HuffmanCode::from_histogram(&hist)?;
                code.decode(&mut r, self.dim, &mut bins)?;
            }
        }
        super::quantizer::dequantize_add(&bins, xmin, s, self.k, &mut acc.sum);
        acc.frames += 1;
        Ok(())
    }

    fn finish_scaled_with(&self, _state: &RoundState, acc: Accumulator, divisor: f64) -> Vec<f32> {
        acc.into_scaled(divisor)
    }

    fn mse_bound(&self, n: usize, avg_norm_sq: f64) -> Option<f64> {
        // Same quantizer as π_sk ⇒ Theorem 2's bound.
        let km1 = (self.k - 1) as f64;
        Some(self.dim as f64 / (2.0 * n as f64 * km1 * km1) * avg_norm_sq)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::run_round;
    use crate::protocol::test_support::{gaussian_clients, measure_mse};
    use crate::stats;

    #[test]
    fn roundtrip_matches_klevel_mse() {
        // Same quantization as π_sk ⇒ identical MSE given identical streams.
        let d = 64;
        let xs = gaussian_clients(6, d, 3);
        let varlen = VarlenProtocol::new(d, 16).with_span(Span::MinMax);
        let klevel = crate::protocol::klevel::KLevelProtocol::new(d, 16);
        let ctx = RoundCtx::new(0, 5);
        let (est_v, _) = run_round(&varlen, &ctx, &xs).unwrap();
        let (est_k, _) = run_round(&klevel, &ctx, &xs).unwrap();
        for (a, b) in est_v.iter().zip(&est_k) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn both_coders_decode_identically() {
        let d = 128;
        let xs = gaussian_clients(4, d, 9);
        let ctx = RoundCtx::new(0, 7);
        let arith = VarlenProtocol::new(d, 12).with_coder(Coder::Arithmetic);
        let huff = VarlenProtocol::new(d, 12).with_coder(Coder::Huffman);
        let (est_a, bits_a) = run_round(&arith, &ctx, &xs).unwrap();
        let (est_h, bits_h) = run_round(&huff, &ctx, &xs).unwrap();
        for (a, b) in est_a.iter().zip(&est_h) {
            assert!((a - b).abs() < 1e-6);
        }
        // arithmetic should be at least as tight as huffman (up to flush)
        assert!(bits_a <= bits_h + 4 * xs.len() as u64, "arith {bits_a} vs huff {bits_h}");
    }

    #[test]
    fn cost_within_theorem4_bound() {
        // Theorem 4 span (norm): expected bits <= analytic bound.
        let d = 256;
        let k = (d as f64).sqrt() as u32 + 1;
        let xs = gaussian_clients(8, d, 13);
        let proto = VarlenProtocol::new(d, k).with_span(Span::Norm);
        let (_, bits) = measure_mse(&proto, &xs, 30, 3);
        let per_client = bits / xs.len() as f64;
        let bound = proto.theorem4_bits() + 2.0 * 32.0; // + header scalars
        assert!(per_client <= bound, "bits/client {per_client} > bound {bound}");
        // And it must be O(d): way below the naive d log2(k) at k=sqrt(d).
        let naive = d as f64 * (k as f64).log2();
        assert!(per_client < naive * 0.8, "per_client {per_client} vs naive {naive}");
    }

    #[test]
    fn mse_at_sqrt_d_is_order_one_over_n() {
        // MSE(k=sqrt d) <= d/(2n(k-1)^2) * avg ~ avg/(2n): independent of d.
        let n = 8;
        for d in [64usize, 256] {
            let xs = gaussian_clients(n, d, 17);
            let proto = VarlenProtocol::sqrt_d(d);
            let (mse, _) = measure_mse(&proto, &xs, 60, 5);
            let avg = stats::avg_norm_sq(&xs);
            let bound = proto.mse_bound(n, avg).unwrap();
            assert!(mse <= bound, "d={d}: {mse} > {bound}");
            // bound itself is ~avg/(2n) (up to rounding of sqrt d)
            assert!(bound <= avg / (1.2 * n as f64), "d={d}: bound {bound} too big");
        }
    }

    #[test]
    fn skewed_bins_compress_well() {
        // Norm span puts most mass near the middle bins -> low entropy.
        // A constant-ish vector compresses to near the histogram cost alone.
        let d = 256;
        let mut x = vec![0.01f32; d];
        x[0] = 1.0; // one spike
        let xs = vec![x; 4];
        let proto = VarlenProtocol::new(d, 17);
        let ctx = RoundCtx::new(0, 3);
        let (_, bits) = run_round(&proto, &ctx, &xs).unwrap();
        let per_client = bits / 4;
        // fixed-width would be 256 * 5 + 64 = 1344 bits
        assert!(per_client < 600, "per_client {per_client}");
    }

    #[test]
    fn corrupted_frame_rejected_or_detected() {
        let d = 64;
        let xs = gaussian_clients(1, d, 1);
        let proto = VarlenProtocol::new(d, 8);
        let ctx = RoundCtx::new(0, 2);
        let f = proto.encode(&ctx, 0, &xs[0]).unwrap();
        let mut acc = proto.new_accumulator();
        // truncate the frame mid-payload
        let cut_bytes = f.bytes[..f.bytes.len() / 4].to_vec();
        let cut_bits = cut_bytes.len() as u64 * 8;
        let cut = Frame::new(cut_bytes, cut_bits);
        assert!(proto.accumulate(&ctx, &cut, &mut acc).is_err());
    }

    #[test]
    fn prop_roundtrip_many_shapes() {
        crate::testkit::run_prop("varlen_roundtrip", 40, |g| {
            let d = g.usize_in(2..=200);
            let k = g.u32_in(2..=40);
            let coder =
                if g.rng().next_u32() & 1 == 0 { Coder::Arithmetic } else { Coder::Huffman };
            let proto = VarlenProtocol::new(d, k).with_coder(coder);
            let x = g.vec_f32(d..=d, -3.0, 3.0);
            let ctx = RoundCtx::new(g.rng().next_u64(), g.rng().next_u64());
            let f = proto.encode(&ctx, 0, &x).ok_or("no frame")?;
            let mut acc = proto.new_accumulator();
            proto.accumulate(&ctx, &f, &mut acc).map_err(|e| e.to_string())?;
            let est = proto.finish(&ctx, acc, 1);
            // single client: estimate within bin width of the truth
            let (_, s) = super::super::quantizer::grid_params(&x, Span::Norm);
            let width = s / (k - 1) as f32 + 1e-4;
            for (j, (&e, &xi)) in est.iter().zip(&x).enumerate() {
                if (e - xi).abs() > width {
                    return Err(format!("coord {j}: |{e} - {xi}| > {width}"));
                }
            }
            Ok(())
        });
    }
}
