//! Native stochastic k-level quantization (§2.2) — the Rust twin of the
//! Pallas kernel `python/compile/kernels/quantize.py`. Both follow the
//! identical arithmetic (same clipping, same `u < frac` comparison) so the
//! native and PJRT backends produce the same bins given the same uniforms.

/// Span (grid width) rule for the quantizer — which `s_i` the client uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Span {
    /// `s_i = X_i^max − X_i^min` — the natural choice (π_sb, π_sk, π_srk).
    MinMax,
    /// `s_i = √2‖X_i‖₂` — Theorem 4's choice for variable-length coding
    /// (satisfies Theorem 2's condition by Eq. 4).
    Norm,
}

/// A quantized vector: bin indices plus the grid parameters the client
/// transmits. `xmin + bins[j] * s / (k-1)` reconstructs coordinate j.
#[derive(Clone, Debug)]
pub struct Quantized {
    pub bins: Vec<u32>,
    pub xmin: f32,
    pub s: f32,
}

/// Grid parameters for `x` under the given span rule.
pub fn grid_params(x: &[f32], span: Span) -> (f32, f32) {
    let (lo, hi) = crate::linalg::min_max(x);
    match span {
        Span::MinMax => (lo, hi - lo),
        Span::Norm => (lo, (2.0f64.sqrt() * crate::linalg::norm(x)) as f32),
    }
}

/// Stochastically round `x` onto the k-level grid `(xmin, s)` using the
/// iid uniforms `u` (one per coordinate, from the client's private stream).
///
/// Mirrors the Pallas kernel exactly: with `t = (x−xmin)·(k−1)/s`,
/// `lo = clip(⌊t⌋, 0, k−2)`, the bin is `lo + [u < t−lo]`, clipped to
/// `[0, k−1]`. `s ≤ 0` (constant vector) maps everything to bin 0.
pub fn quantize_into(x: &[f32], u: &[f32], xmin: f32, s: f32, k: u32, bins: &mut Vec<u32>) {
    debug_assert_eq!(x.len(), u.len());
    debug_assert!(k >= 2, "need at least 2 quantization levels");
    bins.clear();
    bins.resize(x.len(), 0);
    let km1 = (k - 1) as f32;
    let km1i = (k - 1) as i32;
    let inv = if s > 0.0 { km1 / s } else { 0.0 };
    // t >= 0 by construction (xi >= xmin up to f32 rounding), so the
    // f32->i32 cast truncates toward zero == floor; integer clamps replace
    // the float clamps of the reference formulation (same results, and the
    // loop auto-vectorizes).
    for ((b, &xi), &ui) in bins.iter_mut().zip(x).zip(u) {
        let t = (xi - xmin) * inv;
        let lo = (t as i32).clamp(0, km1i - 1);
        let frac = t - lo as f32;
        let bi = lo + (ui < frac) as i32;
        *b = bi.clamp(0, km1i) as u32;
    }
}

/// Allocating convenience wrapper around [`quantize_into`].
pub fn quantize(x: &[f32], u: &[f32], span: Span, k: u32) -> Quantized {
    let (xmin, s) = grid_params(x, span);
    let mut bins = Vec::new();
    quantize_into(x, u, xmin, s, k, &mut bins);
    Quantized { bins, xmin, s }
}

/// Dequantize bin `b`: `Y(j) = xmin + b·s/(k−1)`.
#[inline]
pub fn dequantize_one(b: u32, xmin: f32, s: f32, k: u32) -> f32 {
    xmin + b as f32 * (s / (k - 1) as f32)
}

/// Add the dequantized vector into `acc` (server-side accumulation).
pub fn dequantize_add(bins: &[u32], xmin: f32, s: f32, k: u32, acc: &mut [f32]) {
    debug_assert!(bins.len() <= acc.len());
    let w = s / (k - 1) as f32;
    for (a, &b) in acc.iter_mut().zip(bins) {
        *a += xmin + b as f32 * w;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::testkit::{check, run_prop};

    fn uniforms(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::new(seed);
        let mut u = vec![0.0; n];
        rng.fill_uniform_f32(&mut u);
        u
    }

    #[test]
    fn bins_in_range_and_reconstruction_within_bin_width() {
        let mut rng = Pcg64::new(3);
        let mut x = vec![0.0f32; 257];
        rng.fill_gaussian_f32(&mut x);
        for k in [2u32, 3, 16, 33] {
            for span in [Span::MinMax, Span::Norm] {
                let u = uniforms(x.len(), k as u64);
                let q = quantize(&x, &u, span, k);
                let width = q.s / (k - 1) as f32;
                assert!(q.bins.iter().all(|&b| b < k));
                for (j, &b) in q.bins.iter().enumerate() {
                    let y = dequantize_one(b, q.xmin, q.s, k);
                    assert!(
                        (y - x[j]).abs() <= width + 1e-4,
                        "k={k} span={span:?} j={j}: |{y} - {}| > {width}",
                        x[j]
                    );
                }
            }
        }
    }

    #[test]
    fn binary_k2_matches_section_2_1() {
        // k=2: bins are {0, 1} = {xmin, xmax}, P(xmax) = (x - xmin)/(range).
        let x = vec![0.0f32, 1.0, 0.25];
        // u = 0.2: coordinate 2 has frac 0.25 -> u < frac -> bin 1
        let u = vec![0.2f32, 0.2, 0.2];
        let q = quantize(&x, &u, Span::MinMax, 2);
        assert_eq!(q.bins, vec![0, 1, 1]);
        // u = 0.3 > 0.25 -> bin 0
        let q2 = quantize(&x, &[0.3, 0.3, 0.3], Span::MinMax, 2);
        assert_eq!(q2.bins, vec![0, 1, 0]);
        assert_eq!(q.xmin, 0.0);
        assert_eq!(q.s, 1.0);
    }

    #[test]
    fn constant_vector_is_exact() {
        let x = vec![2.5f32; 64];
        let u = uniforms(64, 1);
        let q = quantize(&x, &u, Span::MinMax, 16);
        assert_eq!(q.s, 0.0);
        assert!(q.bins.iter().all(|&b| b == 0));
        let mut acc = vec![0.0f32; 64];
        dequantize_add(&q.bins, q.xmin, q.s, 16, &mut acc);
        assert!(acc.iter().all(|&v| v == 2.5));
    }

    #[test]
    fn extremes_map_to_extreme_bins() {
        // xmax must always land in bin k-1 (frac = 1 > u for all u < 1),
        // xmin in bin 0 unless u < 0 never happens.
        let x = vec![-3.0f32, 7.0];
        for k in [2u32, 5, 16] {
            let q = quantize(&x, &[0.999, 0.999], Span::MinMax, k);
            assert_eq!(q.bins[0], 0);
            assert_eq!(q.bins[1], k - 1);
        }
    }

    #[test]
    fn unbiased_monte_carlo() {
        let x = vec![0.3f32, -1.2, 0.7, 2.0, -0.01];
        let k = 4;
        let trials = 20_000;
        let mut sums = vec![0.0f64; x.len()];
        let mut rng = Pcg64::new(99);
        let mut u = vec![0.0f32; x.len()];
        for _ in 0..trials {
            rng.fill_uniform_f32(&mut u);
            let q = quantize(&x, &u, Span::MinMax, k);
            for (s, &b) in sums.iter_mut().zip(&q.bins) {
                *s += dequantize_one(b, q.xmin, q.s, k) as f64;
            }
        }
        let (_, s) = grid_params(&x, Span::MinMax);
        let width = s as f64 / (k - 1) as f64;
        let tol = 5.0 * width / 2.0 / (trials as f64).sqrt();
        for (j, &sum) in sums.iter().enumerate() {
            let mean = sum / trials as f64;
            assert!(
                (mean - x[j] as f64).abs() < tol,
                "j={j}: mean {mean} vs {} (tol {tol})",
                x[j]
            );
        }
    }

    #[test]
    fn variance_within_theorem2_bound() {
        // E(Y_j - X_j)^2 <= s^2 / (4 (k-1)^2) per coordinate.
        let x = vec![0.11f32, -0.93, 0.42, 1.7, -2.2, 0.0, 0.5, -0.5];
        let k = 8;
        let trials = 20_000;
        let mut sq = 0.0f64;
        let mut rng = Pcg64::new(7);
        let mut u = vec![0.0f32; x.len()];
        let (xmin, s) = grid_params(&x, Span::MinMax);
        let mut bins = Vec::new();
        for _ in 0..trials {
            rng.fill_uniform_f32(&mut u);
            quantize_into(&x, &u, xmin, s, k, &mut bins);
            for (j, &b) in bins.iter().enumerate() {
                let e = dequantize_one(b, xmin, s, k) as f64 - x[j] as f64;
                sq += e * e;
            }
        }
        let per_coord = sq / (trials * x.len()) as f64;
        let bound = (s as f64).powi(2) / (4.0 * ((k - 1) as f64).powi(2));
        assert!(per_coord <= bound * 1.05, "var {per_coord} > bound {bound}");
    }

    #[test]
    fn prop_quantizer_invariants() {
        run_prop("quantizer_invariants", 150, |g| {
            let d = g.usize_in(1..=200);
            let k = g.u32_in(2..=64);
            let span = if g.rng().next_u32() & 1 == 0 { Span::MinMax } else { Span::Norm };
            let x = g.vec_f32(d..=d, -100.0, 100.0);
            let u = uniforms(d, g.rng().next_u64());
            let q = quantize(&x, &u, span, k);
            check(q.bins.len() == d, "len")?;
            check(q.bins.iter().all(|&b| b < k), "bin range")?;
            check(q.s >= 0.0, "span nonneg")?;
            // grid covers the data: xmin + s >= xmax (Theorem 2 condition)
            let (lo, hi) = crate::linalg::min_max(&x);
            check(q.xmin <= lo + 1e-3, "xmin <= min")?;
            check(q.xmin + q.s >= hi - 1e-3 * hi.abs().max(1.0), "grid covers max")?;
            let width = q.s / (k - 1) as f32;
            for (j, &b) in q.bins.iter().enumerate() {
                let y = dequantize_one(b, q.xmin, q.s, k);
                if (y - x[j]).abs() > width + 1e-2 {
                    return Err(format!("j={j} err {} > width {width}", (y - x[j]).abs()));
                }
            }
            Ok(())
        });
    }
}
