//! Native stochastic k-level quantization (§2.2) — the Rust twin of the
//! Pallas kernel `python/compile/kernels/quantize.py`. Both follow the
//! identical arithmetic (same clipping, same `u < frac` comparison) so the
//! native and PJRT backends produce the same bins given the same uniforms.
//!
//! The per-coordinate loops ([`quantize_into`], [`dequantize_add`]) are
//! dispatched through [`crate::simd`]: an AVX2 kernel when the build and
//! CPU support it, the scalar reference otherwise — **bit-identical**
//! either way (see the `avx2` module for the two cast edge cases the
//! kernel compensates for).

/// Span (grid width) rule for the quantizer — which `s_i` the client uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Span {
    /// `s_i = X_i^max − X_i^min` — the natural choice (π_sb, π_sk, π_srk).
    MinMax,
    /// `s_i = √2‖X_i‖₂` — Theorem 4's choice for variable-length coding
    /// (satisfies Theorem 2's condition by Eq. 4).
    Norm,
}

/// A quantized vector: bin indices plus the grid parameters the client
/// transmits. `xmin + bins[j] * s / (k-1)` reconstructs coordinate j.
#[derive(Clone, Debug)]
pub struct Quantized {
    pub bins: Vec<u32>,
    pub xmin: f32,
    pub s: f32,
}

/// Grid parameters for `x` under the given span rule — one pass over the
/// data ([`crate::linalg::vector_stats`] fuses min/max and the norm), or
/// zero passes when the caller already has the stats
/// ([`grid_params_from_stats`]).
pub fn grid_params(x: &[f32], span: Span) -> (f32, f32) {
    grid_params_from_stats(&crate::linalg::vector_stats(x), span)
}

/// Grid parameters from precomputed per-vector statistics. Exposed so
/// callers that already scanned the input (e.g. the rate-calibration
/// probes, which compute per-row norms for the MSE fit) don't re-scan it.
pub fn grid_params_from_stats(st: &crate::linalg::VectorStats, span: Span) -> (f32, f32) {
    match span {
        Span::MinMax => (st.lo, st.hi - st.lo),
        Span::Norm => (st.lo, (2.0f64.sqrt() * st.norm_sq.sqrt()) as f32),
    }
}

/// Stochastically round `x` onto the k-level grid `(xmin, s)` using the
/// iid uniforms `u` (one per coordinate, from the client's private stream).
///
/// Mirrors the Pallas kernel exactly: with `t = (x−xmin)·(k−1)/s`,
/// `lo = clip(⌊t⌋, 0, k−2)`, the bin is `lo + [u < t−lo]`, clipped to
/// `[0, k−1]`. `s ≤ 0` (constant vector) maps everything to bin 0.
pub fn quantize_into(x: &[f32], u: &[f32], xmin: f32, s: f32, k: u32, bins: &mut Vec<u32>) {
    debug_assert_eq!(x.len(), u.len());
    debug_assert!(k >= 2, "need at least 2 quantization levels");
    bins.clear();
    bins.resize(x.len(), 0);
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if crate::simd::use_x86_vector() {
        // SAFETY: gated on runtime AVX2 detection.
        unsafe { avx2::quantize_bins(x, u, xmin, s, k, bins) };
        return;
    }
    quantize_bins_scalar(x, u, xmin, s, k, bins);
}

/// The scalar reference quantization loop — the executable specification
/// the AVX2 kernel is conformance-tested against. `bins.len()` must equal
/// `x.len()`.
pub fn quantize_bins_scalar(x: &[f32], u: &[f32], xmin: f32, s: f32, k: u32, bins: &mut [u32]) {
    let km1 = (k - 1) as f32;
    let km1i = (k - 1) as i32;
    let inv = if s > 0.0 { km1 / s } else { 0.0 };
    // t >= 0 by construction (xi >= xmin up to f32 rounding), so the
    // f32->i32 cast truncates toward zero == floor; integer clamps replace
    // the float clamps of the reference formulation (same results, and the
    // loop auto-vectorizes).
    for ((b, &xi), &ui) in bins.iter_mut().zip(x).zip(u) {
        let t = (xi - xmin) * inv;
        let lo = (t as i32).clamp(0, km1i - 1);
        let frac = t - lo as f32;
        let bi = lo + (ui < frac) as i32;
        *b = bi.clamp(0, km1i) as u32;
    }
}

/// Allocating convenience wrapper around [`quantize_into`].
pub fn quantize(x: &[f32], u: &[f32], span: Span, k: u32) -> Quantized {
    let (xmin, s) = grid_params(x, span);
    let mut bins = Vec::new();
    quantize_into(x, u, xmin, s, k, &mut bins);
    Quantized { bins, xmin, s }
}

/// Dequantize bin `b`: `Y(j) = xmin + b·s/(k−1)`.
#[inline]
pub fn dequantize_one(b: u32, xmin: f32, s: f32, k: u32) -> f32 {
    xmin + b as f32 * (s / (k - 1) as f32)
}

/// Add the dequantized vector into `acc` (server-side accumulation).
pub fn dequantize_add(bins: &[u32], xmin: f32, s: f32, k: u32, acc: &mut [f32]) {
    debug_assert!(bins.len() <= acc.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if crate::simd::use_x86_vector() {
        // SAFETY: gated on runtime AVX2 detection.
        unsafe { avx2::dequantize_add(bins, xmin, s, k, acc) };
        return;
    }
    dequantize_add_scalar(bins, xmin, s, k, acc);
}

/// The scalar reference dequantize-accumulate loop.
pub fn dequantize_add_scalar(bins: &[u32], xmin: f32, s: f32, k: u32, acc: &mut [f32]) {
    let w = s / (k - 1) as f32;
    for (a, &b) in acc.iter_mut().zip(bins) {
        *a += xmin + b as f32 * w;
    }
}

/// AVX2 twins of the scalar loops, bit-identical by construction: every
/// f32 operation is the same operation in the same order (explicit
/// intrinsics, so no FMA contraction can change a rounding), and the two
/// places where x86 vector semantics differ from Rust scalar semantics
/// are compensated:
///
/// * `f32 as i32` in Rust saturates (NaN → 0, +overflow → `i32::MAX`,
///   −overflow → `i32::MIN`) while `cvttps2dq` returns `i32::MIN` for
///   NaN and *both* overflow directions. After the `[0, k−2]` clamp the
///   NaN and −overflow cases agree (both clamp to 0); the +overflow case
///   (`t ≥ 2³¹`) is patched by a compare-and-blend to `k−2` — exactly
///   where the saturating cast lands. The ordered (`_OQ`) compare is
///   false on NaN, matching the cast's NaN → 0 route.
/// * `u < frac` uses the ordered `_CMP_LT_OQ` predicate, false on NaN —
///   the same result the scalar `<` produces.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod avx2 {
    #[cfg(target_arch = "x86_64")]
    use std::arch::x86_64::*;

    /// SAFETY: caller must ensure AVX2 is available.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn quantize_bins(
        x: &[f32],
        u: &[f32],
        xmin: f32,
        s: f32,
        k: u32,
        bins: &mut [u32],
    ) {
        debug_assert_eq!(x.len(), bins.len());
        let km1 = (k - 1) as f32;
        let km1i = (k - 1) as i32;
        let inv = if s > 0.0 { km1 / s } else { 0.0 };
        let vxmin = _mm256_set1_ps(xmin);
        let vinv = _mm256_set1_ps(inv);
        let vzero = _mm256_setzero_si256();
        let vkm2 = _mm256_set1_epi32(km1i - 1);
        let vkm1 = _mm256_set1_epi32(km1i);
        // 2^31 as f32 (exact): the first value whose truncation the
        // saturating cast and cvttps2dq disagree on.
        let vbig = _mm256_set1_ps(2147483648.0);
        let n = x.len() & !7;
        let mut i = 0;
        while i < n {
            let xv = _mm256_loadu_ps(x.as_ptr().add(i));
            let uv = _mm256_loadu_ps(u.as_ptr().add(i));
            let t = _mm256_mul_ps(_mm256_sub_ps(xv, vxmin), vinv);
            let lo_raw = _mm256_cvttps_epi32(t);
            let lo_clamped = _mm256_min_epi32(_mm256_max_epi32(lo_raw, vzero), vkm2);
            // Patch t >= 2^31: the saturating cast gives i32::MAX -> k-2.
            let ovf = _mm256_castps_si256(_mm256_cmp_ps::<_CMP_GE_OQ>(t, vbig));
            let lo = _mm256_blendv_epi8(lo_clamped, vkm2, ovf);
            let frac = _mm256_sub_ps(t, _mm256_cvtepi32_ps(lo));
            // All-ones where u < frac; subtracting the mask adds 1 there.
            let hit = _mm256_castps_si256(_mm256_cmp_ps::<_CMP_LT_OQ>(uv, frac));
            let bi = _mm256_sub_epi32(lo, hit);
            let b = _mm256_min_epi32(_mm256_max_epi32(bi, vzero), vkm1);
            _mm256_storeu_si256(bins.as_mut_ptr().add(i) as *mut __m256i, b);
            i += 8;
        }
        super::quantize_bins_scalar(&x[n..], &u[n..], xmin, s, k, &mut bins[n..]);
    }

    /// SAFETY: caller must ensure AVX2 is available.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dequantize_add(
        bins: &[u32],
        xmin: f32,
        s: f32,
        k: u32,
        acc: &mut [f32],
    ) {
        let len = bins.len().min(acc.len());
        let w = s / (k - 1) as f32;
        let vxmin = _mm256_set1_ps(xmin);
        let vw = _mm256_set1_ps(w);
        let n = len & !7;
        let mut i = 0;
        while i < n {
            // Bins are < k <= 2^31 (the quantizer's clamp arithmetic is
            // i32), so the signed epi32 -> ps conversion equals the
            // scalar `b as f32`.
            let b = _mm256_loadu_si256(bins.as_ptr().add(i) as *const __m256i);
            let bf = _mm256_cvtepi32_ps(b);
            let val = _mm256_add_ps(vxmin, _mm256_mul_ps(bf, vw));
            let a = _mm256_loadu_ps(acc.as_ptr().add(i));
            _mm256_storeu_ps(acc.as_mut_ptr().add(i), _mm256_add_ps(a, val));
            i += 8;
        }
        super::dequantize_add_scalar(&bins[n..len], xmin, s, k, &mut acc[n..len]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::testkit::{check, run_prop};

    fn uniforms(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::new(seed);
        let mut u = vec![0.0; n];
        rng.fill_uniform_f32(&mut u);
        u
    }

    #[test]
    fn bins_in_range_and_reconstruction_within_bin_width() {
        let mut rng = Pcg64::new(3);
        let mut x = vec![0.0f32; 257];
        rng.fill_gaussian_f32(&mut x);
        for k in [2u32, 3, 16, 33] {
            for span in [Span::MinMax, Span::Norm] {
                let u = uniforms(x.len(), k as u64);
                let q = quantize(&x, &u, span, k);
                let width = q.s / (k - 1) as f32;
                assert!(q.bins.iter().all(|&b| b < k));
                for (j, &b) in q.bins.iter().enumerate() {
                    let y = dequantize_one(b, q.xmin, q.s, k);
                    assert!(
                        (y - x[j]).abs() <= width + 1e-4,
                        "k={k} span={span:?} j={j}: |{y} - {}| > {width}",
                        x[j]
                    );
                }
            }
        }
    }

    #[test]
    fn binary_k2_matches_section_2_1() {
        // k=2: bins are {0, 1} = {xmin, xmax}, P(xmax) = (x - xmin)/(range).
        let x = vec![0.0f32, 1.0, 0.25];
        // u = 0.2: coordinate 2 has frac 0.25 -> u < frac -> bin 1
        let u = vec![0.2f32, 0.2, 0.2];
        let q = quantize(&x, &u, Span::MinMax, 2);
        assert_eq!(q.bins, vec![0, 1, 1]);
        // u = 0.3 > 0.25 -> bin 0
        let q2 = quantize(&x, &[0.3, 0.3, 0.3], Span::MinMax, 2);
        assert_eq!(q2.bins, vec![0, 1, 0]);
        assert_eq!(q.xmin, 0.0);
        assert_eq!(q.s, 1.0);
    }

    #[test]
    fn constant_vector_is_exact() {
        let x = vec![2.5f32; 64];
        let u = uniforms(64, 1);
        let q = quantize(&x, &u, Span::MinMax, 16);
        assert_eq!(q.s, 0.0);
        assert!(q.bins.iter().all(|&b| b == 0));
        let mut acc = vec![0.0f32; 64];
        dequantize_add(&q.bins, q.xmin, q.s, 16, &mut acc);
        assert!(acc.iter().all(|&v| v == 2.5));
    }

    #[test]
    fn extremes_map_to_extreme_bins() {
        // xmax must always land in bin k-1 (frac = 1 > u for all u < 1),
        // xmin in bin 0 unless u < 0 never happens.
        let x = vec![-3.0f32, 7.0];
        for k in [2u32, 5, 16] {
            let q = quantize(&x, &[0.999, 0.999], Span::MinMax, k);
            assert_eq!(q.bins[0], 0);
            assert_eq!(q.bins[1], k - 1);
        }
    }

    #[test]
    fn unbiased_monte_carlo() {
        let x = vec![0.3f32, -1.2, 0.7, 2.0, -0.01];
        let k = 4;
        let trials = 20_000;
        let mut sums = vec![0.0f64; x.len()];
        let mut rng = Pcg64::new(99);
        let mut u = vec![0.0f32; x.len()];
        for _ in 0..trials {
            rng.fill_uniform_f32(&mut u);
            let q = quantize(&x, &u, Span::MinMax, k);
            for (s, &b) in sums.iter_mut().zip(&q.bins) {
                *s += dequantize_one(b, q.xmin, q.s, k) as f64;
            }
        }
        let (_, s) = grid_params(&x, Span::MinMax);
        let width = s as f64 / (k - 1) as f64;
        let tol = 5.0 * width / 2.0 / (trials as f64).sqrt();
        for (j, &sum) in sums.iter().enumerate() {
            let mean = sum / trials as f64;
            assert!(
                (mean - x[j] as f64).abs() < tol,
                "j={j}: mean {mean} vs {} (tol {tol})",
                x[j]
            );
        }
    }

    #[test]
    fn variance_within_theorem2_bound() {
        // E(Y_j - X_j)^2 <= s^2 / (4 (k-1)^2) per coordinate.
        let x = vec![0.11f32, -0.93, 0.42, 1.7, -2.2, 0.0, 0.5, -0.5];
        let k = 8;
        let trials = 20_000;
        let mut sq = 0.0f64;
        let mut rng = Pcg64::new(7);
        let mut u = vec![0.0f32; x.len()];
        let (xmin, s) = grid_params(&x, Span::MinMax);
        let mut bins = Vec::new();
        for _ in 0..trials {
            rng.fill_uniform_f32(&mut u);
            quantize_into(&x, &u, xmin, s, k, &mut bins);
            for (j, &b) in bins.iter().enumerate() {
                let e = dequantize_one(b, xmin, s, k) as f64 - x[j] as f64;
                sq += e * e;
            }
        }
        let per_coord = sq / (trials * x.len()) as f64;
        let bound = (s as f64).powi(2) / (4.0 * ((k - 1) as f64).powi(2));
        assert!(per_coord <= bound * 1.05, "var {per_coord} > bound {bound}");
    }

    #[test]
    fn prop_quantizer_invariants() {
        run_prop("quantizer_invariants", 150, |g| {
            let d = g.usize_in(1..=200);
            let k = g.u32_in(2..=64);
            let span = if g.rng().next_u32() & 1 == 0 { Span::MinMax } else { Span::Norm };
            let x = g.vec_f32(d..=d, -100.0, 100.0);
            let u = uniforms(d, g.rng().next_u64());
            let q = quantize(&x, &u, span, k);
            check(q.bins.len() == d, "len")?;
            check(q.bins.iter().all(|&b| b < k), "bin range")?;
            check(q.s >= 0.0, "span nonneg")?;
            // grid covers the data: xmin + s >= xmax (Theorem 2 condition)
            let (lo, hi) = crate::linalg::min_max(&x);
            check(q.xmin <= lo + 1e-3, "xmin <= min")?;
            check(q.xmin + q.s >= hi - 1e-3 * hi.abs().max(1.0), "grid covers max")?;
            let width = q.s / (k - 1) as f32;
            for (j, &b) in q.bins.iter().enumerate() {
                let y = dequantize_one(b, q.xmin, q.s, k);
                if (y - x[j]).abs() > width + 1e-2 {
                    return Err(format!("j={j} err {} > width {width}", (y - x[j]).abs()));
                }
            }
            Ok(())
        });
    }
}
