//! QSGD-style comparator protocol (Alistarh et al., the paper's reference
//! [2], discussed in §1.3.1 as concurrent work: "stochastic quantization
//! and Elias coding can be used to obtain communication-optimal SGD").
//!
//! Per vector: transmit `‖x‖₂` (header), then per coordinate a sign bit
//! and the stochastically-rounded magnitude level `l ∈ {0..k−1}` on the
//! grid `l/(k−1)·‖x‖`, Elias-γ coded (level `l` sent as γ(l+1) — small
//! levels dominate for dense Gaussian-like vectors, which is where Elias
//! coding wins; sign bits are skipped for zero levels).
//!
//! Included as the cross-paper baseline the ablation benches compare
//! π_svk against: same unbiasedness contract, different coding strategy.

use anyhow::{ensure, Result};

use super::{Accumulator, EncodeScratch, Frame, Protocol, RoundState};
#[cfg(test)]
use super::RoundCtx;
use crate::coding::bitio::BitReader;
use crate::coding::elias;
use crate::coding::float::ScalarCodec;
use crate::linalg;

/// QSGD-like protocol: sign/magnitude stochastic quantization against the
/// ℓ₂ norm, Elias-γ coded levels.
#[derive(Clone, Debug)]
pub struct QsgdProtocol {
    dim: usize,
    k: u32,
    pub header: ScalarCodec,
}

impl QsgdProtocol {
    pub fn new(dim: usize, k: u32) -> Self {
        assert!(k >= 2, "need k >= 2 levels");
        QsgdProtocol { dim, k, header: ScalarCodec::Exact32 }
    }

    pub fn k(&self) -> u32 {
        self.k
    }
}

impl Protocol for QsgdProtocol {
    fn name(&self) -> String {
        format!("qsgd(k={})", self.k)
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn encode_with(
        &self,
        state: &RoundState,
        _scratch: &mut EncodeScratch,
        client_id: u64,
        x: &[f32],
        frame: &mut Frame,
    ) -> bool {
        assert_eq!(x.len(), self.dim, "dimension mismatch");
        let mut private = state.ctx.private(client_id);
        let norm = linalg::norm(x) as f32;
        let mut w = frame.writer();
        let norm_t = self.header.put(&mut w, norm);
        let km1 = (self.k - 1) as f32;
        let inv = if norm_t > 0.0 { km1 / norm_t } else { 0.0 };
        for &xi in x {
            // stochastic level on |x_i|/norm * (k-1)
            let t = xi.abs() * inv;
            let lo = (t as i32).clamp(0, km1 as i32 - 1);
            let frac = t - lo as f32;
            let level = (lo + (private.next_f32() < frac) as i32).clamp(0, km1 as i32) as u64;
            elias::put_gamma(&mut w, level + 1);
            if level > 0 {
                w.put_bit(xi < 0.0);
            }
        }
        frame.store(w);
        true
    }

    fn new_accumulator(&self) -> Accumulator {
        Accumulator::new(self.dim)
    }

    fn internal_dim(&self) -> usize {
        self.dim
    }

    fn accumulate_with(
        &self,
        _state: &RoundState,
        frame: &Frame,
        acc: &mut Accumulator,
    ) -> Result<()> {
        ensure!(acc.sum.len() == self.dim, "accumulator dimension mismatch");
        let mut r = BitReader::with_bit_len(&frame.bytes, frame.bit_len);
        let norm = self.header.get(&mut r)?;
        let width = norm / (self.k - 1) as f32;
        for a in acc.sum.iter_mut() {
            let level = elias::get_gamma(&mut r)? - 1;
            ensure!(level < self.k as u64, "level {level} out of range");
            if level > 0 {
                let neg = r.get_bit()?;
                let mag = level as f32 * width;
                *a += if neg { -mag } else { mag };
            }
        }
        Ok(())
    }

    fn finish_scaled_with(&self, _state: &RoundState, acc: Accumulator, divisor: f64) -> Vec<f32> {
        acc.into_scaled(divisor)
    }

    fn mse_bound(&self, n: usize, avg_norm_sq: f64) -> Option<f64> {
        // Same grid width ‖x‖/(k−1) per coordinate, variance ≤ width²/4 per
        // coordinate (QSGD Lemma 3.1 gives the analogous min(d/k², √d/k)
        // form; this simple bound suffices for the comparator role).
        let km1 = (self.k - 1) as f64;
        Some(self.dim as f64 / (4.0 * n as f64 * km1 * km1) * avg_norm_sq)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::run_round;
    use crate::protocol::test_support::{gaussian_clients, measure_mse};
    use crate::stats;

    #[test]
    fn roundtrip_and_unbiasedness() {
        let d = 32;
        let xs = gaussian_clients(5, d, 3);
        let truth = stats::true_mean(&xs);
        let proto = QsgdProtocol::new(d, 64);
        let trials = 2000;
        let mut sums = vec![0.0f64; d];
        for t in 0..trials {
            let ctx = RoundCtx::new(t, 9);
            let (est, _) = run_round(&proto, &ctx, &xs).unwrap();
            for (s, &e) in sums.iter_mut().zip(&est) {
                *s += e as f64;
            }
        }
        for (j, &s) in sums.iter().enumerate() {
            let mean = s / trials as f64;
            assert!(
                (mean - truth[j] as f64).abs() < 0.05,
                "coord {j}: {mean} vs {}",
                truth[j]
            );
        }
    }

    #[test]
    fn mse_within_bound() {
        let xs = gaussian_clients(8, 64, 7);
        let proto = QsgdProtocol::new(64, 16);
        let (mse, _) = measure_mse(&proto, &xs, 200, 11);
        let bound = proto.mse_bound(xs.len(), stats::avg_norm_sq(&xs)).unwrap();
        assert!(mse <= bound, "mse {mse} > bound {bound}");
    }

    #[test]
    fn elias_coding_benefits_from_sparsity() {
        // A sparse vector has mostly level-0 coordinates -> ~1 bit each.
        let d = 256;
        let mut x = vec![0.0f32; d];
        x[0] = 1.0;
        x[100] = -1.0;
        let proto = QsgdProtocol::new(d, 16);
        let ctx = RoundCtx::new(0, 1);
        let f = proto.encode(&ctx, 0, &x).unwrap();
        // ~254 level-0 gammas (1 bit) + 2 big levels + header
        assert!(f.bit_len < 350, "bits {}", f.bit_len);
        // dense gaussian costs much more
        let dense = gaussian_clients(1, d, 5).remove(0);
        let fd = proto.encode(&ctx, 0, &dense).unwrap();
        assert!(fd.bit_len > f.bit_len, "dense {} sparse {}", fd.bit_len, f.bit_len);
    }

    #[test]
    fn zero_vector_is_exact() {
        let proto = QsgdProtocol::new(16, 8);
        let ctx = RoundCtx::new(0, 2);
        let xs = vec![vec![0.0f32; 16]; 3];
        let (est, _) = run_round(&proto, &ctx, &xs).unwrap();
        assert!(est.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn truncated_frame_rejected() {
        let proto = QsgdProtocol::new(64, 16);
        let ctx = RoundCtx::new(0, 3);
        let x = gaussian_clients(1, 64, 7).remove(0);
        let f = proto.encode(&ctx, 0, &x).unwrap();
        let cut_bytes = f.bytes[..f.bytes.len() / 3].to_vec();
        let cut_bits = cut_bytes.len() as u64 * 8;
        let mut acc = proto.new_accumulator();
        assert!(proto
            .accumulate(&ctx, &Frame::new(cut_bytes, cut_bits), &mut acc)
            .is_err());
    }
}
