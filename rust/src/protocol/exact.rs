//! Exact fixed-point accumulation of f32 contributions — the arithmetic
//! core of the hierarchical aggregation tier.
//!
//! # Why exact arithmetic
//!
//! The paper's estimators are linear in the client frames, so per-slot
//! partial sums can be merged anywhere in a tree of aggregators, not only
//! at the leader. But floating-point addition is not associative: folding
//! clients 0..8 on one aggregator and 8..16 on another, then adding the
//! two span sums, rounds differently from the flat leader's sequential
//! fold. Any scheme that accumulates in f32 or f64 therefore produces
//! tree-shape-dependent bits, and the repo's determinism contract (the
//! root estimate is bit-identical to the flat reference for *any*
//! topology) becomes unenforceable.
//!
//! The fix is to make the fold exact. Every per-coordinate contribution
//! is a product of two f32s (`weight × decoded_value`; plain means use
//! weight 1.0). Each finite f32 is an integer multiple of 2⁻¹⁴⁹, so each
//! product is an integer multiple of 2⁻²⁹⁸ with magnitude below 2²⁵⁶ —
//! and the f64 product of the two widened f32s is *exact* (48-bit
//! significand ≤ 53). [`FixedAcc`] stores the running sum as a 640-bit
//! two's-complement integer in units of 2⁻²⁹⁸: integer addition is
//! associative and commutative, so **any grouping and any order of
//! contributions yields bit-identical state**, and the single rounding
//! to f64 happens once, at the root, in [`FixedAcc::to_f64`]
//! (round-to-nearest-even, like IEEE arithmetic itself).
//!
//! Capacity: contributions occupy bits `[0, 555)` of the 639 magnitude
//! bits, leaving headroom for more than 2⁸⁰ summands — unreachable in
//! practice.
//!
//! # Wire format
//!
//! A sum of same-scale contributions touches only a couple of the ten
//! limbs, so the serialized form ([`FixedAcc::to_bytes_into`]) stores a
//! sign byte plus the window of limbs that differ from the sign
//! extension: `sign u8 | start u8 | len u8 | len × u64 (LE)`. Typical
//! cost is 11–27 bytes per coordinate instead of the dense 83.

use anyhow::{bail, ensure, Result};

/// Number of 64-bit limbs (640 bits total, two's complement).
pub const LIMBS: usize = 10;

/// Exponent of the least-significant bit: every stored value is an
/// integer multiple of 2^LSB_EXP.
const LSB_EXP: i64 = -298;

/// Exact fixed-point accumulator for sums of f32×f32 products.
///
/// Addition ([`FixedAcc::add`], [`FixedAcc::add_product`]) is exactly
/// associative and commutative, which is what lets aggregation trees of
/// any shape reproduce the flat leader's bits. See the module docs.
#[derive(Clone, Copy, PartialEq, Eq)]
pub struct FixedAcc {
    /// Little-endian limbs; the value is the 640-bit two's-complement
    /// integer times 2⁻²⁹⁸.
    limbs: [u64; LIMBS],
}

impl std::fmt::Debug for FixedAcc {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "FixedAcc({})", self.to_f64())
    }
}

impl Default for FixedAcc {
    fn default() -> Self {
        Self::zero()
    }
}

impl FixedAcc {
    pub fn zero() -> Self {
        FixedAcc { limbs: [0; LIMBS] }
    }

    pub fn is_zero(&self) -> bool {
        self.limbs.iter().all(|&l| l == 0)
    }

    /// Add the exact product `a · b` of two finite f32s. This is the only
    /// way contributions enter the accumulator, which is what guarantees
    /// the fixed-point range invariant (multiple of 2⁻²⁹⁸, below 2²⁵⁶).
    pub fn add_product(&mut self, a: f32, b: f32) -> Result<()> {
        ensure!(
            a.is_finite() && b.is_finite(),
            "non-finite contribution {a} × {b} cannot be aggregated exactly"
        );
        // f32→f64 is exact and the product of two f32-valued f64s has a
        // ≤48-bit significand, so this f64 multiply is exact.
        self.add_f64(a as f64 * b as f64);
        Ok(())
    }

    /// Add a finite f64 that is exactly a product of two f32s (an integer
    /// multiple of 2⁻²⁹⁸ with |v| < 2²⁵⁶). Internal: public entry points
    /// establish the precondition.
    fn add_f64(&mut self, v: f64) {
        if v == 0.0 {
            return;
        }
        let bits = v.to_bits();
        let neg = bits >> 63 == 1;
        let e = ((bits >> 52) & 0x7ff) as i64;
        let frac = bits & ((1u64 << 52) - 1);
        debug_assert!(e != 0x7ff, "non-finite value reached add_f64");
        // v = m × 2^p with m a ≤53-bit integer.
        let (mut m, p) = if e == 0 { (frac, -1074i64) } else { ((1u64 << 52) | frac, e - 1075) };
        let mut sh = p - LSB_EXP;
        if sh < 0 {
            // v is a multiple of 2^LSB_EXP, so the dropped bits are zero.
            debug_assert!(
                (-sh) < 64 && m & ((1u64 << (-sh)) - 1) == 0,
                "value is not a multiple of 2^{LSB_EXP}"
            );
            m >>= (-sh) as u32;
            sh = 0;
        }
        let limb = (sh / 64) as usize;
        let off = (sh % 64) as u32;
        debug_assert!(limb + 1 < LIMBS, "contribution exceeds the fixed-point range");
        let chunk = (m as u128) << off; // ≤ 53 + 63 = 116 bits
        let lo = chunk as u64;
        let hi = (chunk >> 64) as u64;
        if neg {
            self.sub_shifted(limb, lo, hi);
        } else {
            self.add_shifted(limb, lo, hi);
        }
    }

    fn add_shifted(&mut self, limb: usize, lo: u64, hi: u64) {
        let mut carry = 0u128;
        for j in limb..LIMBS {
            let add = if j == limb {
                lo
            } else if j == limb + 1 {
                hi
            } else if carry == 0 {
                break;
            } else {
                0
            };
            let s = self.limbs[j] as u128 + add as u128 + carry;
            self.limbs[j] = s as u64;
            carry = s >> 64;
        }
        // A carry out of the top limb wraps: correct two's-complement
        // behavior (e.g. a positive chunk cancelling a negative sum).
    }

    fn sub_shifted(&mut self, limb: usize, lo: u64, hi: u64) {
        let mut borrow = 0u64;
        for j in limb..LIMBS {
            let sub = if j == limb {
                lo
            } else if j == limb + 1 {
                hi
            } else if borrow == 0 {
                break;
            } else {
                0
            };
            let (d1, b1) = self.limbs[j].overflowing_sub(sub);
            let (d2, b2) = d1.overflowing_sub(borrow);
            self.limbs[j] = d2;
            borrow = (b1 | b2) as u64;
        }
    }

    /// Exact merge: 640-bit two's-complement addition. Associative and
    /// commutative — the property the aggregation tree is built on.
    pub fn add(&mut self, other: &FixedAcc) {
        let mut carry = 0u128;
        for j in 0..LIMBS {
            let s = self.limbs[j] as u128 + other.limbs[j] as u128 + carry;
            self.limbs[j] = s as u64;
            carry = s >> 64;
        }
    }

    /// Magnitude and sign of the two's-complement value.
    fn magnitude(&self) -> ([u64; LIMBS], bool) {
        let neg = self.limbs[LIMBS - 1] >> 63 == 1;
        if !neg {
            return (self.limbs, false);
        }
        let mut mag = [0u64; LIMBS];
        let mut carry = 1u128;
        for j in 0..LIMBS {
            let s = (!self.limbs[j]) as u128 + carry;
            mag[j] = s as u64;
            carry = s >> 64;
        }
        (mag, true)
    }

    /// Round the exact sum to the nearest f64 (ties to even) — the single
    /// rounding step, performed once per round at the root.
    pub fn to_f64(&self) -> f64 {
        let (mag, neg) = self.magnitude();
        // Highest set bit.
        let mut top = None;
        for j in (0..LIMBS).rev() {
            if mag[j] != 0 {
                top = Some(j * 64 + 63 - mag[j].leading_zeros() as usize);
                break;
            }
        }
        let Some(h) = top else { return 0.0 };
        let (m, k) = if h <= 52 {
            // Fits the 53-bit significand exactly: only limb 0 is live.
            (mag[0], LSB_EXP)
        } else {
            // Extract bits [h-52 ..= h], then round on guard + sticky.
            let lo_bit = h - 52;
            let (limb, off) = (lo_bit / 64, (lo_bit % 64) as u32);
            let mut m = mag[limb] >> off;
            if off > 0 && limb + 1 < LIMBS {
                m |= mag[limb + 1] << (64 - off);
            }
            m &= (1u64 << 53) - 1;
            let g_bit = h - 53;
            let guard = (mag[g_bit / 64] >> (g_bit % 64)) & 1 == 1;
            let sticky = {
                let (gl, go) = (g_bit / 64, (g_bit % 64) as u32);
                let below_in_limb = if go == 0 { 0 } else { mag[gl] & ((1u64 << go) - 1) };
                below_in_limb != 0 || mag[..gl].iter().any(|&l| l != 0)
            };
            let mut k = (h - 52) as i64 + LSB_EXP;
            if guard && (sticky || m & 1 == 1) {
                m += 1;
                if m == 1u64 << 53 {
                    m >>= 1;
                    k += 1;
                }
            }
            (m, k)
        };
        // m ≤ 2^53 is exact in f64; 2^k is a normal power of two for every
        // reachable k (k ∈ [-298, 290]), so this multiply is exact.
        debug_assert!((-1022..=1023).contains(&k));
        let pow = f64::from_bits(((k + 1023) as u64) << 52);
        let r = m as f64 * pow;
        if neg {
            -r
        } else {
            r
        }
    }

    /// Serialized size in bytes (sparse window encoding).
    pub fn wire_len(&self) -> usize {
        3 + 8 * self.window().2 as usize
    }

    /// (negative, start, len): the window of limbs that differ from the
    /// sign extension (`0` above the window for non-negative values,
    /// `u64::MAX` for negative ones; limbs below the window are zero).
    fn window(&self) -> (bool, u8, u8) {
        let neg = self.limbs[LIMBS - 1] >> 63 == 1;
        let filler = if neg { u64::MAX } else { 0 };
        let mut hi = LIMBS;
        while hi > 0 && self.limbs[hi - 1] == filler {
            hi -= 1;
        }
        let mut lo = 0;
        while lo < hi && self.limbs[lo] == 0 {
            lo += 1;
        }
        (neg, lo as u8, (hi - lo) as u8)
    }

    /// Append the sparse serialization: `sign u8 | start u8 | len u8 |
    /// len × u64 LE`.
    pub fn to_bytes_into(&self, out: &mut Vec<u8>) {
        let (neg, start, len) = self.window();
        out.push(neg as u8);
        out.push(start);
        out.push(len);
        for j in start..start + len {
            out.extend_from_slice(&self.limbs[j as usize].to_le_bytes());
        }
    }

    /// Parse a sparse serialization from the front of `buf`; returns the
    /// value and the number of bytes consumed. Rejects malformed windows
    /// and truncation.
    pub fn from_slice(buf: &[u8]) -> Result<(Self, usize)> {
        ensure!(buf.len() >= 3, "FixedAcc truncated");
        let neg = match buf[0] {
            0 => false,
            1 => true,
            v => bail!("bad FixedAcc sign byte {v}"),
        };
        let (start, len) = (buf[1] as usize, buf[2] as usize);
        ensure!(start + len <= LIMBS, "FixedAcc window out of range");
        let need = 3 + 8 * len;
        ensure!(buf.len() >= need, "FixedAcc truncated");
        let filler = if neg { u64::MAX } else { 0 };
        let mut limbs = [0u64; LIMBS];
        for (j, limb) in limbs.iter_mut().enumerate() {
            *limb = if j < start {
                0
            } else if j < start + len {
                let at = 3 + 8 * (j - start);
                u64::from_le_bytes(buf[at..at + 8].try_into().unwrap())
            } else {
                filler
            };
        }
        Ok((FixedAcc { limbs }, need))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{check, run_prop};

    fn acc_of(vals: &[(f32, f32)]) -> FixedAcc {
        let mut a = FixedAcc::zero();
        for &(x, w) in vals {
            a.add_product(x, w).unwrap();
        }
        a
    }

    #[test]
    fn simple_sums_are_exact() {
        let a = acc_of(&[(1.5, 1.0), (2.25, 1.0), (-0.75, 1.0)]);
        assert_eq!(a.to_f64(), 3.0);
        let b = acc_of(&[(1.5, 2.0), (0.5, -3.0)]);
        assert_eq!(b.to_f64(), 1.5);
        assert!(FixedAcc::zero().is_zero());
        assert_eq!(FixedAcc::zero().to_f64(), 0.0);
    }

    #[test]
    fn rounding_is_nearest_even_with_sticky() {
        // 2^60 + 2^7 is an exact tie at f64 precision (ulp of 2^60 is
        // 2^8): ties-to-even keeps 2^60. Adding any dust below the guard
        // bit makes it round up — a plain f64 fold loses exactly this.
        let mut a = FixedAcc::zero();
        a.add_product(2.0f32.powi(30), 2.0f32.powi(30)).unwrap();
        a.add_product(2.0f32.powi(7), 1.0).unwrap();
        assert_eq!(a.to_f64(), 2.0f64.powi(60));
        a.add_product(2.0f32.powi(-20), 1.0).unwrap();
        assert_eq!(a.to_f64(), 2.0f64.powi(60) + 2.0f64.powi(8));
        // Negative mirror.
        let mut b = FixedAcc::zero();
        b.add_product(-(2.0f32.powi(30)), 2.0f32.powi(30)).unwrap();
        b.add_product(2.0f32.powi(7), -1.0).unwrap();
        b.add_product(-(2.0f32.powi(-20)), 1.0).unwrap();
        assert_eq!(b.to_f64(), -(2.0f64.powi(60) + 2.0f64.powi(8)));
    }

    #[test]
    fn cancellation_and_extremes() {
        // Exact cancellation down to the least significant unit.
        let tiny = f32::from_bits(1); // 2^-149, the smallest subnormal
        let mut a = FixedAcc::zero();
        a.add_product(1.0, 1.0).unwrap();
        a.add_product(-1.0, 1.0).unwrap();
        a.add_product(-tiny, tiny).unwrap();
        assert!(!a.is_zero());
        assert_eq!(a.to_f64(), -(2.0f64.powi(-298)));
        // -1 unit is the all-ones two's-complement pattern: the sparse
        // window degenerates to len 0 with the negative flag.
        assert_eq!(a.wire_len(), 3);
        // Largest products stay in range.
        let mut b = FixedAcc::zero();
        for _ in 0..100 {
            b.add_product(f32::MAX, f32::MAX).unwrap();
        }
        assert_eq!(b.to_f64(), f32::MAX as f64 * f32::MAX as f64 * 100.0);
        let mut c = FixedAcc::zero();
        c.add_product(tiny, tiny).unwrap();
        assert_eq!(c.to_f64(), 2.0f64.powi(-298));
    }

    #[test]
    fn non_finite_contributions_are_rejected() {
        let mut a = FixedAcc::zero();
        assert!(a.add_product(f32::NAN, 1.0).is_err());
        assert!(a.add_product(1.0, f32::INFINITY).is_err());
        assert!(a.add_product(f32::NEG_INFINITY, 2.0).is_err());
        assert!(a.is_zero(), "rejected contributions must not alter state");
    }

    #[test]
    fn prop_grouping_and_order_invariant() {
        // The load-bearing property: any shuffle and any tree grouping of
        // the same contributions produces bit-identical state. This is
        // what makes the aggregation tier topology-independent.
        run_prop("fixedacc_grouping", 60, |g| {
            let n = g.usize_in(2..=40);
            let mut vals = Vec::with_capacity(n);
            for _ in 0..n {
                let scale = 2.0f32.powi(g.u32_in(0..=60) as i32 - 30);
                vals.push((g.f32_in(-4.0, 4.0) * scale, g.f32_in(-3.0, 3.0)));
            }
            let base = acc_of(&vals);
            // Shuffled sequential fold.
            let mut shuffled = vals.clone();
            for i in (1..shuffled.len()).rev() {
                let j = (g.rng().next_u64() % (i as u64 + 1)) as usize;
                shuffled.swap(i, j);
            }
            check(acc_of(&shuffled) == base, "shuffle diverged")?;
            // Random binary-tree grouping via pairwise merges.
            let mut parts: Vec<FixedAcc> =
                shuffled.iter().map(|&(x, w)| acc_of(&[(x, w)])).collect();
            while parts.len() > 1 {
                let i = (g.rng().next_u64() % (parts.len() as u64 - 1)) as usize;
                let right = parts.remove(i + 1);
                parts[i].add(&right);
            }
            check(parts[0] == base, "tree grouping diverged")
        });
    }

    #[test]
    fn prop_exact_vs_f64_on_safe_range() {
        // Against an independent oracle: when every contribution is an
        // integer (exactly representable, no rounding in a plain f64 sum
        // of this size), the fixed-point sum must agree with f64 exactly.
        run_prop("fixedacc_integer_oracle", 100, |g| {
            let n = g.usize_in(1..=50);
            let mut acc = FixedAcc::zero();
            let mut oracle = 0.0f64;
            for _ in 0..n {
                let x = (g.rng().next_u64() % 2000) as f32 - 1000.0;
                let w = (g.rng().next_u64() % 9) as f32 - 4.0;
                acc.add_product(x, w).unwrap();
                oracle += x as f64 * w as f64;
            }
            check(acc.to_f64() == oracle, format!("{} vs {oracle}", acc.to_f64()))
        });
    }

    #[test]
    fn prop_wire_roundtrip() {
        run_prop("fixedacc_wire", 120, |g| {
            let n = g.usize_in(0..=12);
            let mut acc = FixedAcc::zero();
            for _ in 0..n {
                let scale = 2.0f32.powi(g.u32_in(0..=100) as i32 - 50);
                acc.add_product(g.f32_in(-8.0, 8.0) * scale, g.f32_in(-2.0, 2.0)).unwrap();
            }
            let mut bytes = Vec::new();
            acc.to_bytes_into(&mut bytes);
            check(bytes.len() == acc.wire_len(), "wire_len mismatch")?;
            let (back, used) = FixedAcc::from_slice(&bytes).unwrap();
            check(used == bytes.len(), "partial consume")?;
            check(back == acc, "roundtrip diverged")
        });
    }

    #[test]
    fn malformed_wire_rejected() {
        assert!(FixedAcc::from_slice(&[]).is_err());
        assert!(FixedAcc::from_slice(&[0, 0]).is_err());
        assert!(FixedAcc::from_slice(&[2, 0, 0]).is_err(), "bad sign byte");
        assert!(FixedAcc::from_slice(&[0, 8, 3]).is_err(), "window out of range");
        assert!(FixedAcc::from_slice(&[0, 0, 1, 1, 2, 3]).is_err(), "truncated limbs");
        // A valid window parses and consumes exactly its own bytes.
        let mut bytes = vec![0u8, 1, 1];
        bytes.extend_from_slice(&7u64.to_le_bytes());
        bytes.push(0xab); // trailing byte belongs to the caller
        let (v, used) = FixedAcc::from_slice(&bytes).unwrap();
        assert_eq!(used, 11);
        assert_eq!(v.to_f64(), 7.0 * 2.0f64.powi(64) * 2.0f64.powi(-298));
    }
}
