//! Exact fixed-point accumulation of f32 contributions — the arithmetic
//! core of the hierarchical aggregation tier.
//!
//! # Why exact arithmetic
//!
//! The paper's estimators are linear in the client frames, so per-slot
//! partial sums can be merged anywhere in a tree of aggregators, not only
//! at the leader. But floating-point addition is not associative: folding
//! clients 0..8 on one aggregator and 8..16 on another, then adding the
//! two span sums, rounds differently from the flat leader's sequential
//! fold. Any scheme that accumulates in f32 or f64 therefore produces
//! tree-shape-dependent bits, and the repo's determinism contract (the
//! root estimate is bit-identical to the flat reference for *any*
//! topology) becomes unenforceable.
//!
//! The fix is to make the fold exact. Every per-coordinate contribution
//! is a product of two f32s (`weight × decoded_value`; plain means use
//! weight 1.0). Each finite f32 is an integer multiple of 2⁻¹⁴⁹, so each
//! product is an integer multiple of 2⁻²⁹⁸ with magnitude below 2²⁵⁶ —
//! and the f64 product of the two widened f32s is *exact* (48-bit
//! significand ≤ 53). [`FixedAcc`] stores the running sum as a 640-bit
//! two's-complement integer in units of 2⁻²⁹⁸: integer addition is
//! associative and commutative, so **any grouping and any order of
//! contributions yields bit-identical state**, and the single rounding
//! to f64 happens once, at the root, in [`FixedAcc::to_f64`]
//! (round-to-nearest-even, like IEEE arithmetic itself).
//!
//! Capacity: contributions occupy bits `[0, 555)` of the 639 magnitude
//! bits, leaving headroom for more than 2⁸⁰ summands — unreachable in
//! practice.
//!
//! # Wire format
//!
//! A sum of same-scale contributions touches only a couple of the ten
//! limbs, so the serialized form ([`FixedAcc::to_bytes_into`]) stores a
//! sign byte plus the window of limbs that differ from the sign
//! extension: `sign u8 | start u8 | len u8 | len × u64 (LE)`. Typical
//! cost is 11–27 bytes per coordinate instead of the dense 83.
//!
//! # Carry-save fast path
//!
//! The dense representation makes every add touch up to ten limbs and
//! costs 80 bytes per coordinate even though a same-scale f32×f32
//! product occupies at most 117 consecutive bits. [`CarryVec`] exploits
//! this: each coordinate keeps a 16-byte *window* — a signed 124-bit
//! value `W` anchored at limb base `b`, representing `W · 2^(64b)`
//! fixed-point units — and contributions whose bits land inside the
//! current window are absorbed with one `i128` add. Only when a
//! contribution's base differs or the window saturates does the window
//! *flush* into a lazily-allocated dense [`FixedAcc`] spill lane (the
//! deferred carry), after which accumulation restarts fresh. Because
//! `value(j) = window(j) + spill(j)` holds exactly at every step and
//! 640-bit integer addition is associative, the canonical value
//! recovered by [`CarryVec::canonical`] is bit-identical to a dense
//! [`FixedAcc`] fold of the same contributions in any order — carries
//! are *deferred*, never lost, so the determinism contract and the wire
//! format are completely unchanged.

use anyhow::{bail, ensure, Result};

/// Number of 64-bit limbs (640 bits total, two's complement).
pub const LIMBS: usize = 10;

/// Exponent of the least-significant bit: every stored value is an
/// integer multiple of 2^LSB_EXP.
const LSB_EXP: i64 = -298;

/// Exact fixed-point accumulator for sums of f32×f32 products.
///
/// Addition ([`FixedAcc::add`], [`FixedAcc::add_product`]) is exactly
/// associative and commutative, which is what lets aggregation trees of
/// any shape reproduce the flat leader's bits. See the module docs.
#[derive(Clone, Copy, PartialEq, Eq)]
pub struct FixedAcc {
    /// Little-endian limbs; the value is the 640-bit two's-complement
    /// integer times 2⁻²⁹⁸.
    limbs: [u64; LIMBS],
}

impl std::fmt::Debug for FixedAcc {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "FixedAcc({})", self.to_f64())
    }
}

impl Default for FixedAcc {
    fn default() -> Self {
        Self::zero()
    }
}

impl FixedAcc {
    pub fn zero() -> Self {
        FixedAcc { limbs: [0; LIMBS] }
    }

    pub fn is_zero(&self) -> bool {
        self.limbs.iter().all(|&l| l == 0)
    }

    /// Add the exact product `a · b` of two finite f32s. This is the only
    /// way contributions enter the accumulator, which is what guarantees
    /// the fixed-point range invariant (multiple of 2⁻²⁹⁸, below 2²⁵⁶).
    pub fn add_product(&mut self, a: f32, b: f32) -> Result<()> {
        ensure!(
            a.is_finite() && b.is_finite(),
            "non-finite contribution {a} × {b} cannot be aggregated exactly"
        );
        // f32→f64 is exact and the product of two f32-valued f64s has a
        // ≤48-bit significand, so this f64 multiply is exact.
        self.add_f64(a as f64 * b as f64);
        Ok(())
    }

    /// Add a finite f64 that is exactly a product of two f32s (an integer
    /// multiple of 2⁻²⁹⁸ with |v| < 2²⁵⁶). Internal: public entry points
    /// establish the precondition.
    fn add_f64(&mut self, v: f64) {
        if v == 0.0 {
            return;
        }
        let bits = v.to_bits();
        let neg = bits >> 63 == 1;
        let e = ((bits >> 52) & 0x7ff) as i64;
        let frac = bits & ((1u64 << 52) - 1);
        debug_assert!(e != 0x7ff, "non-finite value reached add_f64");
        // v = m × 2^p with m a ≤53-bit integer.
        let (mut m, p) = if e == 0 { (frac, -1074i64) } else { ((1u64 << 52) | frac, e - 1075) };
        let mut sh = p - LSB_EXP;
        if sh < 0 {
            // v is a multiple of 2^LSB_EXP, so the dropped bits are zero.
            debug_assert!(
                (-sh) < 64 && m & ((1u64 << (-sh)) - 1) == 0,
                "value is not a multiple of 2^{LSB_EXP}"
            );
            m >>= (-sh) as u32;
            sh = 0;
        }
        let limb = (sh / 64) as usize;
        let off = (sh % 64) as u32;
        debug_assert!(limb + 1 < LIMBS, "contribution exceeds the fixed-point range");
        let chunk = (m as u128) << off; // ≤ 53 + 63 = 116 bits
        let lo = chunk as u64;
        let hi = (chunk >> 64) as u64;
        if neg {
            self.sub_shifted(limb, lo, hi);
        } else {
            self.add_shifted(limb, lo, hi);
        }
    }

    fn add_shifted(&mut self, limb: usize, lo: u64, hi: u64) {
        let mut carry = 0u128;
        for j in limb..LIMBS {
            let add = if j == limb {
                lo
            } else if j == limb + 1 {
                hi
            } else if carry == 0 {
                break;
            } else {
                0
            };
            let s = self.limbs[j] as u128 + add as u128 + carry;
            self.limbs[j] = s as u64;
            carry = s >> 64;
        }
        // A carry out of the top limb wraps: correct two's-complement
        // behavior (e.g. a positive chunk cancelling a negative sum).
    }

    fn sub_shifted(&mut self, limb: usize, lo: u64, hi: u64) {
        let mut borrow = 0u64;
        for j in limb..LIMBS {
            let sub = if j == limb {
                lo
            } else if j == limb + 1 {
                hi
            } else if borrow == 0 {
                break;
            } else {
                0
            };
            let (d1, b1) = self.limbs[j].overflowing_sub(sub);
            let (d2, b2) = d1.overflowing_sub(borrow);
            self.limbs[j] = d2;
            borrow = (b1 | b2) as u64;
        }
    }

    /// Exact merge: 640-bit two's-complement addition. Associative and
    /// commutative — the property the aggregation tree is built on.
    pub fn add(&mut self, other: &FixedAcc) {
        let mut carry = 0u128;
        for j in 0..LIMBS {
            let s = self.limbs[j] as u128 + other.limbs[j] as u128 + carry;
            self.limbs[j] = s as u64;
            carry = s >> 64;
        }
    }

    /// Magnitude and sign of the two's-complement value.
    fn magnitude(&self) -> ([u64; LIMBS], bool) {
        let neg = self.limbs[LIMBS - 1] >> 63 == 1;
        if !neg {
            return (self.limbs, false);
        }
        let mut mag = [0u64; LIMBS];
        let mut carry = 1u128;
        for j in 0..LIMBS {
            let s = (!self.limbs[j]) as u128 + carry;
            mag[j] = s as u64;
            carry = s >> 64;
        }
        (mag, true)
    }

    /// Round the exact sum to the nearest f64 (ties to even) — the single
    /// rounding step, performed once per round at the root.
    pub fn to_f64(&self) -> f64 {
        let (mag, neg) = self.magnitude();
        // Highest set bit.
        let mut top = None;
        for j in (0..LIMBS).rev() {
            if mag[j] != 0 {
                top = Some(j * 64 + 63 - mag[j].leading_zeros() as usize);
                break;
            }
        }
        let Some(h) = top else { return 0.0 };
        let (m, k) = if h <= 52 {
            // Fits the 53-bit significand exactly: only limb 0 is live.
            (mag[0], LSB_EXP)
        } else {
            // Extract bits [h-52 ..= h], then round on guard + sticky.
            let lo_bit = h - 52;
            let (limb, off) = (lo_bit / 64, (lo_bit % 64) as u32);
            let mut m = mag[limb] >> off;
            if off > 0 && limb + 1 < LIMBS {
                m |= mag[limb + 1] << (64 - off);
            }
            m &= (1u64 << 53) - 1;
            let g_bit = h - 53;
            let guard = (mag[g_bit / 64] >> (g_bit % 64)) & 1 == 1;
            let sticky = {
                let (gl, go) = (g_bit / 64, (g_bit % 64) as u32);
                let below_in_limb = if go == 0 { 0 } else { mag[gl] & ((1u64 << go) - 1) };
                below_in_limb != 0 || mag[..gl].iter().any(|&l| l != 0)
            };
            let mut k = (h - 52) as i64 + LSB_EXP;
            if guard && (sticky || m & 1 == 1) {
                m += 1;
                if m == 1u64 << 53 {
                    m >>= 1;
                    k += 1;
                }
            }
            (m, k)
        };
        // m ≤ 2^53 is exact in f64; 2^k is a normal power of two for every
        // reachable k (k ∈ [-298, 290]), so this multiply is exact.
        debug_assert!((-1022..=1023).contains(&k));
        let pow = f64::from_bits(((k + 1023) as u64) << 52);
        let r = m as f64 * pow;
        if neg {
            -r
        } else {
            r
        }
    }

    /// Serialized size in bytes (sparse window encoding).
    pub fn wire_len(&self) -> usize {
        3 + 8 * self.window().2 as usize
    }

    /// (negative, start, len): the window of limbs that differ from the
    /// sign extension (`0` above the window for non-negative values,
    /// `u64::MAX` for negative ones; limbs below the window are zero).
    fn window(&self) -> (bool, u8, u8) {
        let neg = self.limbs[LIMBS - 1] >> 63 == 1;
        let filler = if neg { u64::MAX } else { 0 };
        let mut hi = LIMBS;
        while hi > 0 && self.limbs[hi - 1] == filler {
            hi -= 1;
        }
        let mut lo = 0;
        while lo < hi && self.limbs[lo] == 0 {
            lo += 1;
        }
        (neg, lo as u8, (hi - lo) as u8)
    }

    /// Append the sparse serialization: `sign u8 | start u8 | len u8 |
    /// len × u64 LE`.
    pub fn to_bytes_into(&self, out: &mut Vec<u8>) {
        let (neg, start, len) = self.window();
        out.push(neg as u8);
        out.push(start);
        out.push(len);
        for j in start..start + len {
            out.extend_from_slice(&self.limbs[j as usize].to_le_bytes());
        }
    }

    /// Parse a sparse serialization from the front of `buf`; returns the
    /// value and the number of bytes consumed. Rejects malformed windows
    /// and truncation.
    pub fn from_slice(buf: &[u8]) -> Result<(Self, usize)> {
        ensure!(buf.len() >= 3, "FixedAcc truncated");
        let neg = match buf[0] {
            0 => false,
            1 => true,
            v => bail!("bad FixedAcc sign byte {v}"),
        };
        let (start, len) = (buf[1] as usize, buf[2] as usize);
        ensure!(start + len <= LIMBS, "FixedAcc window out of range");
        let need = 3 + 8 * len;
        ensure!(buf.len() >= need, "FixedAcc truncated");
        let filler = if neg { u64::MAX } else { 0 };
        let mut limbs = [0u64; LIMBS];
        for (j, limb) in limbs.iter_mut().enumerate() {
            *limb = if j < start {
                0
            } else if j < start + len {
                let at = 3 + 8 * (j - start);
                u64::from_le_bytes(buf[at..at + 8].try_into().unwrap())
            } else {
                filler
            };
        }
        Ok((FixedAcc { limbs }, need))
    }
}

/// Low 60 bits of a window's `hi` word (bits 60..64 hold the limb base).
const MASK60: u64 = (1 << 60) - 1;

/// One coordinate's carry-save window: a signed 124-bit accumulator `W`
/// anchored at limb base `b ∈ [0, 8]`, representing `W · 2^(64b)` units
/// of 2^LSB_EXP. `lo` holds bits 0..64 of `W`, `hi` bits 64..124 plus
/// the base in bits 60..64 of the high word.
#[derive(Clone, Copy, Default)]
struct Window {
    lo: u64,
    hi: u64,
}

impl Window {
    /// True when `W == 0` (the base bits are then meaningless).
    #[inline]
    fn is_zero(self) -> bool {
        self.lo | (self.hi & MASK60) == 0
    }

    #[inline]
    fn base(self) -> usize {
        (self.hi >> 60) as usize
    }

    /// Sign-extend the 124-bit window to i128.
    #[inline]
    fn value(self) -> i128 {
        (((((self.hi & MASK60) as u128) << 64) | self.lo as u128) as i128) << 4 >> 4
    }

    /// Pack a window value that is known to fit 124 signed bits.
    #[inline]
    fn pack(base: usize, w: i128) -> Window {
        debug_assert!(base + 1 < LIMBS);
        debug_assert!(w >> 123 == 0 || w >> 123 == -1, "window value out of range");
        Window { lo: w as u64, hi: (((w >> 64) as u64) & MASK60) | ((base as u64) << 60) }
    }
}

/// Carry-save vector of exact accumulators — the hot-path form of one
/// [`FixedAcc`] per coordinate.
///
/// Each coordinate holds a 16-byte [`Window`] plus a share of a
/// lazily-allocated dense spill lane; `value(j) = window(j) + spill(j)`
/// exactly. Same-scale streams (the common case: clients contribute
/// values of comparable magnitude per coordinate) never allocate the
/// spill and each add costs one f64 decompose plus one `i128` add.
/// [`CarryVec::canonical`] resolves the deferred carries, yielding a
/// value bit-identical to the dense fold for any grouping or order of
/// the same contributions — see the module docs.
#[derive(Clone)]
pub struct CarryVec {
    win: Vec<Window>,
    spill: Option<Box<[FixedAcc]>>,
}

impl CarryVec {
    pub fn new(dim: usize) -> Self {
        CarryVec { win: vec![Window::default(); dim], spill: None }
    }

    pub fn len(&self) -> usize {
        self.win.len()
    }

    pub fn is_empty(&self) -> bool {
        self.win.is_empty()
    }

    /// Whether the spill lane has been materialized (diagnostics/tests).
    pub fn spilled(&self) -> bool {
        self.spill.is_some()
    }

    /// Expand a window into its dense equivalent.
    fn expand(base: usize, w: i128) -> FixedAcc {
        let fill = if w < 0 { u64::MAX } else { 0 };
        let mut limbs = [fill; LIMBS];
        limbs[..base].fill(0);
        limbs[base] = w as u64;
        // i128 >> is arithmetic, so the high limb sign-extends correctly.
        limbs[base + 1] = (w >> 64) as u64;
        FixedAcc { limbs }
    }

    /// Express a dense value as a window when it fits: the low 124
    /// signed bits of the limb pair at the lowest nonzero limb must
    /// cover the whole value. Returns `None` (→ spill path) otherwise.
    fn window_of(v: &FixedAcc) -> Option<(usize, i128)> {
        let limbs = &v.limbs;
        let lb = limbs.iter().position(|&l| l != 0)?;
        if lb + 1 >= LIMBS {
            return None;
        }
        let neg = limbs[LIMBS - 1] >> 63 == 1;
        let fill = if neg { u64::MAX } else { 0 };
        if limbs[lb + 2..].iter().any(|&l| l != fill) {
            return None;
        }
        let pair = ((limbs[lb + 1] as u128) << 64) | limbs[lb] as u128;
        let t = (pair >> 123) as u32;
        // The top five bits must be a pure sign extension of bit 123 AND
        // agree with the value's true sign: a negative value whose pair
        // happens to look non-negative (all fill limbs above) must not be
        // misread as a small positive window.
        if (t != 0 && t != 0x1f) || ((t == 0x1f) != neg) {
            return None;
        }
        Some((lb, pair as i128))
    }

    #[inline]
    fn add_window(&mut self, j: usize, base: usize, c: i128) {
        let w = self.win[j];
        if w.is_zero() {
            self.win[j] = Window::pack(base, c);
            return;
        }
        if w.base() == base {
            // |W| < 2^123 and |c| ≤ 2^123, so the i128 add cannot wrap.
            let w2 = w.value() + c;
            let t = w2 >> 123;
            if t == 0 || t == -1 {
                self.win[j] = Window::pack(base, w2);
                return;
            }
        }
        self.flush(j, w);
        self.win[j] = Window::pack(base, c);
    }

    /// Defer the live window's carries into the dense spill lane.
    #[cold]
    fn flush(&mut self, j: usize, w: Window) {
        let n = self.win.len();
        let spill =
            self.spill.get_or_insert_with(|| vec![FixedAcc::zero(); n].into_boxed_slice());
        spill[j].add(&Self::expand(w.base(), w.value()));
    }

    /// Add the exact product `a · b` to coordinate `j`. The caller must
    /// have validated both factors finite ([`FixedAcc::add_product`]
    /// semantics without the per-add branch); the decomposition below is
    /// identical to [`FixedAcc::add_f64`].
    #[inline]
    pub fn add_product_unchecked(&mut self, j: usize, a: f32, b: f32) {
        let p = a as f64 * b as f64;
        if p == 0.0 {
            return;
        }
        let bits = p.to_bits();
        let neg = bits >> 63 == 1;
        let e = ((bits >> 52) & 0x7ff) as i64;
        let frac = bits & ((1u64 << 52) - 1);
        debug_assert!(e != 0x7ff, "non-finite product reached the unchecked fold");
        let (mut m, pexp) = if e == 0 { (frac, -1074i64) } else { ((1u64 << 52) | frac, e - 1075) };
        let mut sh = pexp - LSB_EXP;
        if sh < 0 {
            debug_assert!((-sh) < 64 && m & ((1u64 << (-sh)) - 1) == 0);
            m >>= (-sh) as u32;
            sh = 0;
        }
        let limb = (sh / 64) as usize;
        let off = (sh % 64) as u32;
        let chunk = (m as u128) << off; // ≤ 53 + 63 = 116 bits
        // Branchless conditional negate: s is 0 or -1.
        let s = -(neg as i128);
        let c = (chunk as i128 ^ s) - s;
        self.add_window(j, limb, c);
    }

    /// Add a dense value (e.g. parsed off the wire) to coordinate `j`.
    pub fn add_fixed(&mut self, j: usize, v: &FixedAcc) {
        if v.is_zero() {
            return;
        }
        match Self::window_of(v) {
            Some((base, w)) => self.add_window(j, base, w),
            None => {
                let n = self.win.len();
                let spill =
                    self.spill.get_or_insert_with(|| vec![FixedAcc::zero(); n].into_boxed_slice());
                spill[j].add(v);
            }
        }
    }

    /// Exact coordinate-wise merge. Windows merge through the same
    /// absorb-or-flush path as contributions; spill lanes add densely.
    pub fn merge(&mut self, other: &CarryVec) {
        assert_eq!(self.win.len(), other.win.len(), "CarryVec length mismatch");
        for j in 0..other.win.len() {
            let w = other.win[j];
            if !w.is_zero() {
                self.add_window(j, w.base(), w.value());
            }
        }
        if let Some(os) = &other.spill {
            let n = self.win.len();
            let spill =
                self.spill.get_or_insert_with(|| vec![FixedAcc::zero(); n].into_boxed_slice());
            for (s, o) in spill.iter_mut().zip(os.iter()) {
                s.add(o);
            }
        }
    }

    /// Resolve coordinate `j` to its canonical dense value — the value a
    /// plain [`FixedAcc`] fold of the same contributions would hold.
    pub fn canonical(&self, j: usize) -> FixedAcc {
        let w = self.win[j];
        let mut acc =
            if w.is_zero() { FixedAcc::zero() } else { Self::expand(w.base(), w.value()) };
        if let Some(s) = &self.spill {
            acc.add(&s[j]);
        }
        acc
    }

    /// Canonical values for all coordinates, in order.
    pub fn iter_canonical(&self) -> impl Iterator<Item = FixedAcc> + '_ {
        (0..self.win.len()).map(|j| self.canonical(j))
    }

    /// True when every coordinate's canonical value is zero. (Window and
    /// spill may be individually nonzero yet cancel exactly.)
    pub fn is_all_zero(&self) -> bool {
        (0..self.win.len()).all(|j| self.canonical(j).is_zero())
    }
}

impl PartialEq for CarryVec {
    /// Canonical-value equality: two accumulators are equal when they
    /// represent the same exact sums, regardless of how the carries are
    /// currently split between window and spill.
    fn eq(&self, other: &Self) -> bool {
        self.win.len() == other.win.len()
            && (0..self.win.len()).all(|j| self.canonical(j) == other.canonical(j))
    }
}

impl std::fmt::Debug for CarryVec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "CarryVec(dim={}, spilled={})", self.win.len(), self.spill.is_some())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{check, run_prop};

    fn acc_of(vals: &[(f32, f32)]) -> FixedAcc {
        let mut a = FixedAcc::zero();
        for &(x, w) in vals {
            a.add_product(x, w).unwrap();
        }
        a
    }

    #[test]
    fn simple_sums_are_exact() {
        let a = acc_of(&[(1.5, 1.0), (2.25, 1.0), (-0.75, 1.0)]);
        assert_eq!(a.to_f64(), 3.0);
        let b = acc_of(&[(1.5, 2.0), (0.5, -3.0)]);
        assert_eq!(b.to_f64(), 1.5);
        assert!(FixedAcc::zero().is_zero());
        assert_eq!(FixedAcc::zero().to_f64(), 0.0);
    }

    #[test]
    fn rounding_is_nearest_even_with_sticky() {
        // 2^60 + 2^7 is an exact tie at f64 precision (ulp of 2^60 is
        // 2^8): ties-to-even keeps 2^60. Adding any dust below the guard
        // bit makes it round up — a plain f64 fold loses exactly this.
        let mut a = FixedAcc::zero();
        a.add_product(2.0f32.powi(30), 2.0f32.powi(30)).unwrap();
        a.add_product(2.0f32.powi(7), 1.0).unwrap();
        assert_eq!(a.to_f64(), 2.0f64.powi(60));
        a.add_product(2.0f32.powi(-20), 1.0).unwrap();
        assert_eq!(a.to_f64(), 2.0f64.powi(60) + 2.0f64.powi(8));
        // Negative mirror.
        let mut b = FixedAcc::zero();
        b.add_product(-(2.0f32.powi(30)), 2.0f32.powi(30)).unwrap();
        b.add_product(2.0f32.powi(7), -1.0).unwrap();
        b.add_product(-(2.0f32.powi(-20)), 1.0).unwrap();
        assert_eq!(b.to_f64(), -(2.0f64.powi(60) + 2.0f64.powi(8)));
    }

    #[test]
    fn cancellation_and_extremes() {
        // Exact cancellation down to the least significant unit.
        let tiny = f32::from_bits(1); // 2^-149, the smallest subnormal
        let mut a = FixedAcc::zero();
        a.add_product(1.0, 1.0).unwrap();
        a.add_product(-1.0, 1.0).unwrap();
        a.add_product(-tiny, tiny).unwrap();
        assert!(!a.is_zero());
        assert_eq!(a.to_f64(), -(2.0f64.powi(-298)));
        // -1 unit is the all-ones two's-complement pattern: the sparse
        // window degenerates to len 0 with the negative flag.
        assert_eq!(a.wire_len(), 3);
        // Largest products stay in range.
        let mut b = FixedAcc::zero();
        for _ in 0..100 {
            b.add_product(f32::MAX, f32::MAX).unwrap();
        }
        assert_eq!(b.to_f64(), f32::MAX as f64 * f32::MAX as f64 * 100.0);
        let mut c = FixedAcc::zero();
        c.add_product(tiny, tiny).unwrap();
        assert_eq!(c.to_f64(), 2.0f64.powi(-298));
    }

    #[test]
    fn non_finite_contributions_are_rejected() {
        let mut a = FixedAcc::zero();
        assert!(a.add_product(f32::NAN, 1.0).is_err());
        assert!(a.add_product(1.0, f32::INFINITY).is_err());
        assert!(a.add_product(f32::NEG_INFINITY, 2.0).is_err());
        assert!(a.is_zero(), "rejected contributions must not alter state");
    }

    #[test]
    fn prop_grouping_and_order_invariant() {
        // The load-bearing property: any shuffle and any tree grouping of
        // the same contributions produces bit-identical state. This is
        // what makes the aggregation tier topology-independent.
        run_prop("fixedacc_grouping", 60, |g| {
            let n = g.usize_in(2..=40);
            let mut vals = Vec::with_capacity(n);
            for _ in 0..n {
                let scale = 2.0f32.powi(g.u32_in(0..=60) as i32 - 30);
                vals.push((g.f32_in(-4.0, 4.0) * scale, g.f32_in(-3.0, 3.0)));
            }
            let base = acc_of(&vals);
            // Shuffled sequential fold.
            let mut shuffled = vals.clone();
            for i in (1..shuffled.len()).rev() {
                let j = (g.rng().next_u64() % (i as u64 + 1)) as usize;
                shuffled.swap(i, j);
            }
            check(acc_of(&shuffled) == base, "shuffle diverged")?;
            // Random binary-tree grouping via pairwise merges.
            let mut parts: Vec<FixedAcc> =
                shuffled.iter().map(|&(x, w)| acc_of(&[(x, w)])).collect();
            while parts.len() > 1 {
                let i = (g.rng().next_u64() % (parts.len() as u64 - 1)) as usize;
                let right = parts.remove(i + 1);
                parts[i].add(&right);
            }
            check(parts[0] == base, "tree grouping diverged")
        });
    }

    #[test]
    fn prop_exact_vs_f64_on_safe_range() {
        // Against an independent oracle: when every contribution is an
        // integer (exactly representable, no rounding in a plain f64 sum
        // of this size), the fixed-point sum must agree with f64 exactly.
        run_prop("fixedacc_integer_oracle", 100, |g| {
            let n = g.usize_in(1..=50);
            let mut acc = FixedAcc::zero();
            let mut oracle = 0.0f64;
            for _ in 0..n {
                let x = (g.rng().next_u64() % 2000) as f32 - 1000.0;
                let w = (g.rng().next_u64() % 9) as f32 - 4.0;
                acc.add_product(x, w).unwrap();
                oracle += x as f64 * w as f64;
            }
            check(acc.to_f64() == oracle, format!("{} vs {oracle}", acc.to_f64()))
        });
    }

    #[test]
    fn prop_wire_roundtrip() {
        run_prop("fixedacc_wire", 120, |g| {
            let n = g.usize_in(0..=12);
            let mut acc = FixedAcc::zero();
            for _ in 0..n {
                let scale = 2.0f32.powi(g.u32_in(0..=100) as i32 - 50);
                acc.add_product(g.f32_in(-8.0, 8.0) * scale, g.f32_in(-2.0, 2.0)).unwrap();
            }
            let mut bytes = Vec::new();
            acc.to_bytes_into(&mut bytes);
            check(bytes.len() == acc.wire_len(), "wire_len mismatch")?;
            let (back, used) = FixedAcc::from_slice(&bytes).unwrap();
            check(used == bytes.len(), "partial consume")?;
            check(back == acc, "roundtrip diverged")
        });
    }

    #[test]
    fn prop_carryvec_matches_dense_oracle() {
        // The carry-save fast path must be bit-identical to the dense
        // fold for every stream, including scale mixes that force window
        // flushes and spill allocation.
        run_prop("carryvec_oracle", 40, |g| {
            let dim = g.usize_in(1..=6);
            let mut cv = CarryVec::new(dim);
            let mut oracle = vec![FixedAcc::zero(); dim];
            let n = g.usize_in(1..=120);
            for _ in 0..n {
                let j = g.usize_in(0..=dim - 1);
                let scale = 2.0f32.powi(g.u32_in(0..=220) as i32 - 110);
                let x = g.f32_in(-4.0, 4.0) * scale;
                let w = g.f32_in(-3.0, 3.0);
                cv.add_product_unchecked(j, x, w);
                oracle[j].add_product(x, w).unwrap();
            }
            for (j, want) in oracle.iter().enumerate() {
                check(cv.canonical(j) == *want, format!("coord {j} diverged"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_carryvec_merge_matches_dense() {
        // Random partitions merged in a random tree must equal the flat
        // dense fold — the SlotPartial topology-independence property,
        // exercised at the accumulator level.
        run_prop("carryvec_merge", 30, |g| {
            let dim = g.usize_in(1..=4);
            let nparts = g.usize_in(2..=8);
            let mut oracle = vec![FixedAcc::zero(); dim];
            let mut parts = Vec::with_capacity(nparts);
            for _ in 0..nparts {
                let mut cv = CarryVec::new(dim);
                for _ in 0..g.usize_in(0..=40) {
                    let j = g.usize_in(0..=dim - 1);
                    let scale = 2.0f32.powi(g.u32_in(0..=160) as i32 - 80);
                    let x = g.f32_in(-4.0, 4.0) * scale;
                    let w = g.f32_in(-2.0, 2.0);
                    cv.add_product_unchecked(j, x, w);
                    oracle[j].add_product(x, w).unwrap();
                }
                parts.push(cv);
            }
            while parts.len() > 1 {
                let i = (g.rng().next_u64() % (parts.len() as u64 - 1)) as usize;
                let right = parts.remove(i + 1);
                parts[i].merge(&right);
            }
            for (j, want) in oracle.iter().enumerate() {
                check(parts[0].canonical(j) == *want, format!("coord {j} diverged"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_carryvec_add_fixed_matches_dense() {
        // Wire-ingest path: dense values fed through add_fixed (window
        // form when they fit, spill otherwise) must match dense adds.
        run_prop("carryvec_add_fixed", 40, |g| {
            let mut cv = CarryVec::new(1);
            let mut oracle = FixedAcc::zero();
            for _ in 0..g.usize_in(1..=20) {
                let mut v = FixedAcc::zero();
                for _ in 0..g.usize_in(0..=6) {
                    let scale = 2.0f32.powi(g.u32_in(0..=240) as i32 - 120);
                    v.add_product(g.f32_in(-8.0, 8.0) * scale, g.f32_in(-2.0, 2.0)).unwrap();
                }
                cv.add_fixed(0, &v);
                oracle.add(&v);
            }
            check(cv.canonical(0) == oracle, "add_fixed diverged")
        });
    }

    #[test]
    fn carryvec_window_overflow_flushes_exactly() {
        // Enough same-sign max-magnitude products overflow the 124-bit
        // window; the flush must defer the carries without losing a bit.
        let mut cv = CarryVec::new(1);
        let mut oracle = FixedAcc::zero();
        // Each product contributes ≈2^106 window units at base 7, so the
        // signed 124-bit window saturates after ≈2^17 same-sign adds.
        for _ in 0..150_000 {
            cv.add_product_unchecked(0, f32::MAX, f32::MAX);
            oracle.add_product(f32::MAX, f32::MAX).unwrap();
        }
        assert!(cv.spilled(), "expected a window overflow flush");
        assert!(cv.canonical(0) == oracle);
        assert_eq!(cv.canonical(0).to_f64(), oracle.to_f64());
    }

    #[test]
    fn carryvec_scale_jumps_spill_and_stay_exact() {
        // Alternating distant scales forces a flush on nearly every add —
        // the worst case for carry-save — and must still be exact.
        let tiny = f32::from_bits(1);
        let mut cv = CarryVec::new(1);
        let mut oracle = FixedAcc::zero();
        for i in 0..50 {
            let (x, w) = if i % 2 == 0 { (1.5f32, 2.0f32) } else { (tiny, tiny) };
            cv.add_product_unchecked(0, x, w);
            oracle.add_product(x, w).unwrap();
        }
        assert!(cv.spilled());
        assert!(cv.canonical(0) == oracle);
    }

    #[test]
    fn carryvec_cancellation_reports_all_zero() {
        // Window and spill may be individually nonzero yet cancel: the
        // canonical view (and is_all_zero) must see through the split.
        let tiny = f32::from_bits(1);
        let mut cv = CarryVec::new(2);
        cv.add_product_unchecked(0, 1.0, 1.0);
        cv.add_product_unchecked(0, tiny, tiny); // flush 1.0 to spill
        cv.add_product_unchecked(0, -tiny, tiny);
        cv.add_product_unchecked(0, -1.0, 1.0);
        assert!(cv.spilled());
        assert!(cv.is_all_zero());
        assert!(cv.canonical(0).is_zero());
        assert_eq!(cv, CarryVec::new(2));
    }

    #[test]
    fn carryvec_add_fixed_sign_consistency_edge() {
        // Value 5 − 2^128 units: limbs [5, 0, MAX…]. The limb pair at the
        // lowest nonzero limb reads as small-positive even though the
        // value is negative — window_of must refuse it (spill path) or
        // the sign flips. This is the adversarial case for the window
        // parser.
        let tiny = f32::from_bits(1); // 1 unit = tiny·tiny
        let mut v = FixedAcc::zero();
        for _ in 0..5 {
            v.add_product(tiny, tiny).unwrap();
        }
        // −2^128 units = −2^-170 = −2^-85 · 2^-85.
        v.add_product(-(2.0f32.powi(-85)), 2.0f32.powi(-85)).unwrap();
        let mut cv = CarryVec::new(1);
        cv.add_fixed(0, &v);
        assert!(cv.canonical(0) == v);
        assert_eq!(cv.canonical(0).to_f64(), v.to_f64());
    }

    #[test]
    fn malformed_wire_rejected() {
        assert!(FixedAcc::from_slice(&[]).is_err());
        assert!(FixedAcc::from_slice(&[0, 0]).is_err());
        assert!(FixedAcc::from_slice(&[2, 0, 0]).is_err(), "bad sign byte");
        assert!(FixedAcc::from_slice(&[0, 8, 3]).is_err(), "window out of range");
        assert!(FixedAcc::from_slice(&[0, 0, 1, 1, 2, 3]).is_err(), "truncated limbs");
        // A valid window parses and consumes exactly its own bytes.
        let mut bytes = vec![0u8, 1, 1];
        bytes.extend_from_slice(&7u64.to_le_bytes());
        bytes.push(0xab); // trailing byte belongs to the caller
        let (v, used) = FixedAcc::from_slice(&bytes).unwrap();
        assert_eq!(used, 11);
        assert_eq!(v.to_f64(), 7.0 * 2.0f64.powi(64) * 2.0f64.powi(-298));
    }
}
