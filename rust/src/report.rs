//! Experiment result writers: CSV (for plotting) and a minimal JSON
//! emitter (no serde in the offline crate set). Every bench writes its
//! series here so figures can be regenerated outside Rust.

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

/// A value in a report row.
#[derive(Clone, Debug)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
}

impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::Str(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::Str(s)
    }
}
impl From<i64> for Value {
    fn from(v: i64) -> Self {
        Value::Int(v)
    }
}
impl From<u64> for Value {
    fn from(v: u64) -> Self {
        Value::Int(v as i64)
    }
}
impl From<usize> for Value {
    fn from(v: usize) -> Self {
        Value::Int(v as i64)
    }
}
impl From<f64> for Value {
    fn from(v: f64) -> Self {
        Value::Float(v)
    }
}

impl Value {
    fn csv(&self) -> String {
        match self {
            Value::Str(s) => {
                if s.contains(',') || s.contains('"') {
                    format!("\"{}\"", s.replace('"', "\"\""))
                } else {
                    s.clone()
                }
            }
            Value::Int(v) => v.to_string(),
            Value::Float(v) => format!("{v}"),
        }
    }

    fn json(&self) -> String {
        match self {
            Value::Str(s) => format!(
                "\"{}\"",
                s.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
            ),
            Value::Int(v) => v.to_string(),
            Value::Float(v) => {
                if v.is_finite() {
                    format!("{v}")
                } else {
                    "null".into()
                }
            }
        }
    }
}

/// A tabular report: named columns, appendable rows.
#[derive(Clone, Debug)]
pub struct Report {
    pub name: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<Value>>,
}

impl Report {
    pub fn new(name: impl Into<String>, columns: &[&str]) -> Self {
        Report {
            name: name.into(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push(&mut self, row: Vec<Value>) {
        debug_assert_eq!(row.len(), self.columns.len(), "row arity mismatch");
        self.rows.push(row);
    }

    pub fn to_csv(&self) -> String {
        let mut out = self.columns.join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(Value::csv).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    pub fn to_json(&self) -> String {
        let mut out = String::from("[\n");
        for (i, row) in self.rows.iter().enumerate() {
            let fields: Vec<String> = self
                .columns
                .iter()
                .zip(row)
                .map(|(c, v)| format!("\"{}\": {}", c, v.json()))
                .collect();
            out.push_str("  {");
            out.push_str(&fields.join(", "));
            out.push('}');
            if i + 1 < self.rows.len() {
                out.push(',');
            }
            out.push('\n');
        }
        out.push(']');
        out
    }

    /// Write `<dir>/<name>.csv` and `<dir>/<name>.json`.
    pub fn write(&self, dir: impl AsRef<Path>) -> Result<()> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {}", dir.display()))?;
        let mut csv = std::fs::File::create(dir.join(format!("{}.csv", self.name)))?;
        csv.write_all(self.to_csv().as_bytes())?;
        let mut json = std::fs::File::create(dir.join(format!("{}.json", self.name)))?;
        json.write_all(self.to_json().as_bytes())?;
        Ok(())
    }
}

/// Default report directory: `$DME_REPORTS` or `./reports`.
pub fn default_dir() -> std::path::PathBuf {
    std::env::var_os("DME_REPORTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| "reports".into())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_and_json_shapes() {
        let mut r = Report::new("t", &["proto", "bits", "mse"]);
        r.push(vec!["a,b".into(), 128u64.into(), 0.5f64.into()]);
        r.push(vec!["plain".into(), 64u64.into(), f64::NAN.into()]);
        let csv = r.to_csv();
        assert!(csv.starts_with("proto,bits,mse\n"));
        assert!(csv.contains("\"a,b\",128,0.5"));
        let json = r.to_json();
        assert!(json.contains("\"proto\": \"a,b\""));
        assert!(json.contains("\"mse\": null")); // NaN -> null
    }

    #[test]
    fn write_creates_files() {
        let dir = std::env::temp_dir().join(format!("dme_report_{}", std::process::id()));
        let mut r = Report::new("x", &["a"]);
        r.push(vec![1u64.into()]);
        r.write(&dir).unwrap();
        assert!(dir.join("x.csv").exists());
        assert!(dir.join("x.json").exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
