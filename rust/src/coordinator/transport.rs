//! Transport: moves [`Message`]s between the leader and its workers with
//! exact byte accounting.
//!
//! Three implementations behind [`TransportHub`]:
//!
//! * [`LoopbackHub`] — in-process channels; workers are threads. This is
//!   the default for experiments: zero copies beyond the frames
//!   themselves (broadcast payloads are `Arc`-shared, not cloned per
//!   worker), deterministic, and every byte is still accounted as if it
//!   had crossed a network.
//! * [`TcpHub`] — the thread-per-connection socket transport
//!   (length-prefixed messages over blocking `std::net::TcpStream`, one
//!   reader thread per worker), so workers can run as separate
//!   `dme worker` processes on other machines. [`TcpHub::bind`] exposes
//!   the real listen address before accepting, so tests can bind port 0.
//! * [`ReactorHub`](super::reactor::ReactorHub) (Linux) — the same
//!   sockets served by **one** event-driven reactor thread: non-blocking
//!   I/O behind epoll readiness, per-connection staging queues that
//!   coalesce small frames and flush once per wakeup (one `writev`, not
//!   one syscall per message), and a zero-copy broadcast path that
//!   serializes each message once for all n connections. This is the
//!   default for `--transport` and the only hub whose thread count does
//!   not grow with n. The readiness state machine (READING ⇄ WRITING →
//!   DEAD), the batching/flush contract, and the backpressure rule (a
//!   stalled connection is killed at a 1 GiB staging cap rather than
//!   buffering unboundedly — the reactor's analogue of a blocking write
//!   eventually erroring) are documented in [`super::reactor`].
//!
//! [`Transport`] selects between the two TCP hubs at the CLI
//! (`--transport reactor|threads`); [`HubBinding`] is the
//! transport-agnostic bind → `local_addr` → accept flow. Both TCP hubs
//! share the wire format, the validate-on-send rule, the silent-kill
//! contract for malformed peers, and exact `framed_len` accounting, so
//! every conformance test runs verbatim against either.
//!
//! Wire format (identical for every transport, little-endian). Every
//! message body starts with the **versioned envelope header**:
//!
//! ```text
//! magic "DM" (2 bytes) | u8 version (= 2) | u16 session_id | u8 tag | payload
//!
//! tag 1 RoundStart: u64 round, u64 shared_seed (the round's shared
//!                   randomness root: rotation sampling and the
//!                   correlated-quantization offsets derive from it, so
//!                   every client and every aggregation hop agree on the
//!                   round's public state by construction),
//!                   u32 n_floats, u32 dim (> 0),
//!                   then n_floats f32 (the flattened broadcast payload;
//!                   its length is serialized directly, so ragged
//!                   payloads — n_floats not a multiple of dim — survive
//!                   the wire unchanged)
//! tag 2 Upload:     u64 client, u64 round, u32 n_frames,
//!                   then per frame: u64 bit_len, u32 n_bytes, f32 weight, bytes
//! tag 3 Shutdown
//! tag 4 PartialUpload: u64 agg_id, u64 round, u64 span.0, u64 span.1,
//!                   u64 uplink_bits, u64 n_frames, u32 shard.0,
//!                   u32 shard.1 (the dimension shard `[shard.0, shard.1)`
//!                   the slots cover; `(0, internal_dim)` when unsharded),
//!                   u32 n_slots, then per slot: u32 n_bytes + a versioned
//!                   SlotPartial serialization (see `SlotPartial::to_bytes`)
//! tag 5 SpecChange: u64 round, u32 n_bytes, then the UTF-8 protocol spec
//!                   string (the `ProtocolConfig` grammar, ≤ 1024 bytes;
//!                   both ends re-validate it through the spec parser, so
//!                   a forged or garbled spec errors at the wire instead
//!                   of poisoning a protocol rebuild)
//! ```
//!
//! The envelope fields are checked *first* on every parse: a wrong magic
//! or an unsupported version is a **typed rejection**
//! ([`WireError::BadMagic`] / [`WireError::UnknownVersion`], downcastable
//! from the returned error) that hubs surface to their receiver instead
//! of silently killing the connection; an envelope whose `session_id`
//! names a session the receiver does not host is likewise rejected as
//! [`WireError::UnknownSession`] by the session router (see
//! `coordinator::session`). The session id is how one transport and one
//! aggregator tree serve many concurrent estimation sessions (tenants):
//! every hop preserves it verbatim, and `session 0` is the root session
//! single-tenant deployments use implicitly.
//!
//! On the wire every message is preceded by a u32 length prefix
//! ([`Message::framed_len`] = serialized size + 4, header included).
//! *Both* hubs account `framed_len` per message, so a loopback run and a
//! TCP run of the same experiment report identical `bytes_moved` —
//! conformance-tested in `tests/coordinator_integration.rs`.

use std::io::{BufReader, BufWriter, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};

use crate::protocol::{Frame, SlotPartial};

/// A weighted encoded vector (weight matters for weighted averages, e.g.
/// cluster sizes in distributed Lloyd's; 1.0 for plain means).
#[derive(Clone, Debug)]
pub struct WeightedFrame {
    pub frame: Frame,
    pub weight: f32,
}

/// The two magic bytes every wire message starts with. Framing bugs and
/// foreign protocols speaking to our port fail here, as a typed
/// [`WireError::BadMagic`], before any length field is trusted.
pub const WIRE_MAGIC: [u8; 2] = *b"DM";

/// The envelope version this build speaks. Bumped when the grammar
/// changes incompatibly; a peer from the future is rejected as
/// [`WireError::UnknownVersion`] instead of being misparsed.
/// Version history: 1 = original envelope; 2 = `RoundStart` carries the
/// round's `shared_seed` (the shared-randomness handshake the
/// correlated-quantization family requires).
pub const WIRE_VERSION: u8 = 2;

/// Envelope header size: magic (2) + version (1) + session id (2) +
/// tag (1).
pub const ENVELOPE_HEADER_LEN: u64 = 6;

/// The implicit session id of single-tenant deployments. Every
/// `Message`-level (non-envelope) send addresses this session.
pub const ROOT_SESSION: u16 = 0;

/// Typed envelope rejections. Surfaced as the error cause (downcastable
/// via `anyhow::Error::downcast_ref::<WireError>`) so receivers can tell
/// a protocol-identity failure apart from a merely truncated or forged
/// payload — the former is *reported* to the hub's consumer, never a
/// silent connection kill.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireError {
    /// The first two bytes were not [`WIRE_MAGIC`].
    BadMagic([u8; 2]),
    /// The version byte named a grammar this build does not speak.
    UnknownVersion(u8),
    /// The envelope addressed a session this node does not host. Raised
    /// by the session router (`coordinator::session`), not the parser —
    /// the wire cannot know which sessions exist.
    UnknownSession(u16),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::BadMagic(m) => {
                write!(f, "bad envelope magic {m:02x?} (expected {WIRE_MAGIC:02x?})")
            }
            WireError::UnknownVersion(v) => {
                write!(f, "unknown wire version {v} (this build speaks {WIRE_VERSION})")
            }
            WireError::UnknownSession(s) => write!(f, "envelope addresses unknown session {s}"),
        }
    }
}

impl std::error::Error for WireError {}

/// A [`Message`] addressed to a session: what actually crosses the wire.
/// Every hop — worker, aggregator tier, hub — preserves the session id
/// verbatim, which is what lets one transport and one aggregator tree
/// serve many concurrent estimation sessions.
#[derive(Clone, Debug)]
pub struct Envelope {
    pub session: u16,
    pub msg: Message,
}

impl Envelope {
    /// Wrap a message for the root (single-tenant) session.
    pub fn root(msg: Message) -> Self {
        Envelope { session: ROOT_SESSION, msg }
    }

    /// Serialize (header + payload). Errors on whatever
    /// [`Message::validate`] rejects.
    pub fn to_bytes(&self) -> Result<Vec<u8>> {
        self.msg.to_bytes_for(self.session)
    }

    /// On-the-wire size including the u32 length prefix.
    pub fn framed_len(&self) -> u64 {
        self.msg.framed_len()
    }

    /// Parse a full envelope (header checks first: magic, then version —
    /// both typed rejections — then the session id and tag).
    pub fn from_bytes(buf: &[u8]) -> Result<Self> {
        let mut c = Cursor { buf, pos: 0 };
        let magic: [u8; 2] = c.take(2).context("message too short for envelope magic")?
            .try_into()
            .unwrap();
        if magic != WIRE_MAGIC {
            return Err(WireError::BadMagic(magic).into());
        }
        let version = c.u8()?;
        if version != WIRE_VERSION {
            return Err(WireError::UnknownVersion(version).into());
        }
        let session = c.u16()?;
        let msg = Message::parse_body(&mut c)?;
        Ok(Envelope { session, msg })
    }
}

/// Coordinator messages.
#[derive(Clone, Debug)]
pub enum Message {
    /// Leader → workers: new round with the broadcast state
    /// (`n_slots` vectors of `dim` f32s, flattened). The payload is
    /// `Arc`-shared so broadcasting to n loopback workers clones a
    /// pointer, not `n_slots × dim` floats per worker. `shared_seed` is
    /// the round's shared-randomness root: every client derives the
    /// rotation and its correlated rounding offsets from it (not from
    /// local configuration), so a whole tree agrees on the round's
    /// public state by construction — the shared-randomness handshake.
    RoundStart { round: u64, shared_seed: u64, dim: u32, payload: Arc<[f32]> },
    /// Worker → leader: the round's encoded updates. A worker that the
    /// sampling layer silenced still uploads an empty frame list (the
    /// leader needs the barrier).
    Upload { client: u64, round: u64, frames: Vec<WeightedFrame> },
    /// Aggregator → parent: one exactly-merged `SlotPartial` per slot for
    /// the aggregator's whole client span `[span.0, span.1)`, plus the
    /// span's client-edge accounting (`uplink_bits`, `n_frames`) so the
    /// root still reports the paper's per-client communication cost.
    PartialUpload {
        agg_id: u64,
        round: u64,
        span: (u64, u64),
        uplink_bits: u64,
        n_frames: u64,
        /// The dimension shard `[shard.0, shard.1)` (in protocol-internal
        /// coordinates) the slots cover: `(0, internal_dim)` when the
        /// tree is unsharded. A dimension-sharded subtree folds only its
        /// slice; the root concatenates sibling shards back into the
        /// full vector, so each partial must carry which slice it is.
        shard: (u32, u32),
        slots: Vec<SlotPartial>,
    },
    /// Leader → children (relayed down every aggregation tier): switch
    /// the active protocol to `spec` (the `ProtocolConfig` grammar
    /// string) starting at round `round`. Sent *before* the `RoundStart`
    /// it first applies to; transports are FIFO, so applying the switch
    /// on receipt is race-free. See `rate::controller` for the policy
    /// that emits these.
    SpecChange { round: u64, spec: String },
    /// Leader → workers: tear down.
    Shutdown,
}

/// Hard cap on a `SpecChange` spec string. Real specs are tens of bytes;
/// the cap bounds what a forged length field can make a receiver buffer.
pub const MAX_SPEC_LEN: usize = 1024;

/// The wire-boundary legality checks for a `SpecChange` spec string:
/// bounded, and accepted by the spec grammar. Run on send (validate) and
/// on parse, exactly like the tag-4 forgery checks.
fn check_spec_string(spec: &str) -> Result<()> {
    ensure!(!spec.is_empty(), "SpecChange spec is empty");
    ensure!(
        spec.len() <= MAX_SPEC_LEN,
        "SpecChange spec exceeds {MAX_SPEC_LEN} bytes"
    );
    // Grammar + structural checks. The build runs at dim 1 (dim is a
    // session property the transport does not know; every structural
    // constraint — k >= 2, coordinate sampling vs rotation — is
    // dim-independent), so a spec that passes here can only fail at the
    // receiver for session-level reasons.
    let cfg = crate::protocol::config::ProtocolConfig::parse(spec, 1)
        .context("SpecChange spec rejected by the protocol grammar")?;
    cfg.build().map(|_| ()).context("SpecChange spec rejected by the protocol builder")
}

impl Message {
    /// Check the wire-format invariants without serializing: everything
    /// the serialize or parse path would reject (a length field over
    /// `u32::MAX`, a `RoundStart` with `dim == 0`, a frame whose
    /// `bit_len` overruns its bytes, a total size beyond the framing
    /// cap). The loopback transport runs this on every send, so a
    /// message that cannot cross TCP cannot cross loopback either —
    /// transports never diverge on legality.
    pub fn validate(&self) -> Result<()> {
        match self {
            Message::RoundStart { dim, payload, .. } => {
                ensure!(*dim > 0, "RoundStart dim must be > 0");
                ensure_u32(payload.len())?;
            }
            Message::Upload { frames, .. } => {
                ensure_u32(frames.len())?;
                for wf in frames {
                    ensure_u32(wf.frame.bytes.len())?;
                    ensure!(
                        wf.frame.bit_len <= wf.frame.bytes.len() as u64 * 8,
                        "bit_len exceeds payload"
                    );
                }
            }
            Message::PartialUpload { span, shard, slots, .. } => {
                ensure!(span.0 <= span.1, "PartialUpload span is inverted");
                ensure_u32(slots.len())?;
                check_partial_holders(*span, slots)?;
                check_partial_shard(*shard, slots)?;
                for s in slots {
                    ensure_u32(s.wire_len())?;
                }
            }
            Message::SpecChange { spec, .. } => check_spec_string(spec)?,
            Message::Shutdown => {}
        }
        // Same cap the receive path enforces (read_msg rejects frames
        // over 1 GiB): catching it at send keeps the u32 length prefix
        // from silently wrapping and desyncing the stream.
        ensure!(self.wire_len() <= 1 << 30, "message too large for the wire format");
        Ok(())
    }

    /// Serialize to the wire format addressed to the root session. Used
    /// by the TCP transport and by tests; the loopback transport accounts
    /// the same bytes via [`Self::wire_len`]. Errors on whatever
    /// [`Self::validate`] rejects.
    pub fn to_bytes(&self) -> Result<Vec<u8>> {
        self.to_bytes_for(ROOT_SESSION)
    }

    /// Serialize to the wire format addressed to `session`: the envelope
    /// header (magic, version, session id) followed by the tag byte and
    /// the tag's payload.
    pub fn to_bytes_for(&self, session: u16) -> Result<Vec<u8>> {
        self.validate()?;
        let mut out = Vec::with_capacity(self.wire_len() as usize);
        out.extend_from_slice(&WIRE_MAGIC);
        out.push(WIRE_VERSION);
        out.extend_from_slice(&session.to_le_bytes());
        match self {
            Message::RoundStart { round, shared_seed, dim, payload } => {
                out.push(1u8);
                out.extend_from_slice(&round.to_le_bytes());
                out.extend_from_slice(&shared_seed.to_le_bytes());
                out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
                out.extend_from_slice(&dim.to_le_bytes());
                for v in payload.iter() {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
            Message::Upload { client, round, frames } => {
                out.push(2u8);
                out.extend_from_slice(&client.to_le_bytes());
                out.extend_from_slice(&round.to_le_bytes());
                out.extend_from_slice(&(frames.len() as u32).to_le_bytes());
                for wf in frames {
                    out.extend_from_slice(&wf.frame.bit_len.to_le_bytes());
                    out.extend_from_slice(&(wf.frame.bytes.len() as u32).to_le_bytes());
                    out.extend_from_slice(&wf.weight.to_le_bytes());
                    out.extend_from_slice(&wf.frame.bytes);
                }
            }
            Message::PartialUpload { agg_id, round, span, uplink_bits, n_frames, shard, slots } => {
                out.push(4u8);
                out.extend_from_slice(&agg_id.to_le_bytes());
                out.extend_from_slice(&round.to_le_bytes());
                out.extend_from_slice(&span.0.to_le_bytes());
                out.extend_from_slice(&span.1.to_le_bytes());
                out.extend_from_slice(&uplink_bits.to_le_bytes());
                out.extend_from_slice(&n_frames.to_le_bytes());
                out.extend_from_slice(&shard.0.to_le_bytes());
                out.extend_from_slice(&shard.1.to_le_bytes());
                out.extend_from_slice(&(slots.len() as u32).to_le_bytes());
                for s in slots {
                    let bytes = s.to_bytes()?;
                    out.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
                    out.extend_from_slice(&bytes);
                }
            }
            Message::SpecChange { round, spec } => {
                out.push(5u8);
                out.extend_from_slice(&round.to_le_bytes());
                out.extend_from_slice(&(spec.len() as u32).to_le_bytes());
                out.extend_from_slice(spec.as_bytes());
            }
            Message::Shutdown => out.push(3u8),
        }
        Ok(out)
    }

    /// Serialized size in bytes without materializing the buffer (the
    /// loopback transport accounts bytes on every send; building the full
    /// serialization just to measure it dominated small-round profiles).
    pub fn wire_len(&self) -> u64 {
        const H: u64 = ENVELOPE_HEADER_LEN; // magic + version + session + tag
        match self {
            Message::RoundStart { payload, .. } => H + 8 + 8 + 4 + 4 + payload.len() as u64 * 4,
            Message::Upload { frames, .. } => Self::upload_wire_len(frames),
            Message::PartialUpload { slots, .. } => {
                H + 8 * 6 + 4 * 2 + 4 + slots.iter().map(|s| 4 + s.wire_len() as u64).sum::<u64>()
            }
            Message::SpecChange { spec, .. } => H + 8 + 4 + spec.len() as u64,
            Message::Shutdown => H,
        }
    }

    /// On-the-wire size including the u32 length prefix every transport
    /// frame carries. Both hubs account this, so loopback and TCP report
    /// identical `bytes_moved` for identical traffic.
    pub fn framed_len(&self) -> u64 {
        self.wire_len() + 4
    }

    /// Wire size of an `Upload` carrying `frames`, from borrowed frames —
    /// accounting paths (the tree simulator) measure what a message
    /// *would* cost without cloning the payload into one.
    pub fn upload_wire_len(frames: &[WeightedFrame]) -> u64 {
        ENVELOPE_HEADER_LEN
            + 8
            + 8
            + 4
            + frames
                .iter()
                .map(|wf| 8 + 4 + 4 + wf.frame.bytes.len() as u64)
                .sum::<u64>()
    }

    /// Parse from the wire format, discarding the session id (the
    /// single-tenant convenience — session-aware receivers use
    /// [`Envelope::from_bytes`]). Envelope header checks still run:
    /// bad magic or version is a typed [`WireError`].
    pub fn from_bytes(buf: &[u8]) -> Result<Self> {
        Ok(Envelope::from_bytes(buf)?.msg)
    }

    /// Parse a message body (tag + payload) from a cursor positioned
    /// just past the envelope's session id.
    fn parse_body(c: &mut Cursor<'_>) -> Result<Self> {
        let tag = c.u8()?;
        match tag {
            1 => {
                let round = c.u64()?;
                let shared_seed = c.u64()?;
                let n_floats = c.u32()? as usize;
                let dim = c.u32()?;
                ensure!(dim > 0, "RoundStart dim must be > 0");
                // Validate before allocating: a corrupt header must not
                // reserve gigabytes.
                ensure!(
                    c.remaining() as u64 == n_floats as u64 * 4,
                    "RoundStart payload length mismatch"
                );
                let mut payload = Vec::with_capacity(n_floats);
                for _ in 0..n_floats {
                    payload.push(c.f32()?);
                }
                c.done()?;
                Ok(Message::RoundStart { round, shared_seed, dim, payload: payload.into() })
            }
            2 => {
                let client = c.u64()?;
                let round = c.u64()?;
                let n = c.u32()? as usize;
                // Validate before allocating (as for RoundStart): every
                // frame needs at least 16 header bytes, so a corrupt
                // count cannot reserve gigabytes.
                ensure!(
                    n as u64 <= c.remaining() as u64 / 16,
                    "Upload frame count exceeds message size"
                );
                let mut frames = Vec::with_capacity(n);
                for _ in 0..n {
                    let bit_len = c.u64()?;
                    let n_bytes = c.u32()? as usize;
                    let weight = c.f32()?;
                    let bytes = c.take(n_bytes)?.to_vec();
                    ensure!(bit_len <= bytes.len() as u64 * 8, "bit_len exceeds payload");
                    frames.push(WeightedFrame { frame: Frame::new(bytes, bit_len), weight });
                }
                c.done()?;
                Ok(Message::Upload { client, round, frames })
            }
            3 => {
                c.done()?;
                Ok(Message::Shutdown)
            }
            4 => {
                let agg_id = c.u64()?;
                let round = c.u64()?;
                let span = (c.u64()?, c.u64()?);
                ensure!(span.0 <= span.1, "PartialUpload span is inverted");
                let uplink_bits = c.u64()?;
                let n_frames = c.u64()?;
                let shard = (c.u32()?, c.u32()?);
                ensure!(shard.0 <= shard.1, "PartialUpload shard range is inverted");
                let n = c.u32()? as usize;
                // Validate before allocating (as for Upload): every slot
                // needs at least a 4-byte length prefix.
                ensure!(
                    n as u64 <= c.remaining() as u64 / 4,
                    "PartialUpload slot count exceeds message size"
                );
                // n is attacker-controlled and a parsed SlotPartial takes
                // far more memory than its 4-byte floor on the wire:
                // reserve modestly and let growth track parsed bytes.
                let mut slots = Vec::with_capacity(n.min(1 + c.remaining() / 64));
                for _ in 0..n {
                    let len = c.u32()? as usize;
                    slots.push(SlotPartial::from_bytes(c.take(len)?)?);
                }
                c.done()?;
                check_partial_holders(span, &slots)?;
                check_partial_shard(shard, &slots)?;
                Ok(Message::PartialUpload {
                    agg_id,
                    round,
                    span,
                    uplink_bits,
                    n_frames,
                    shard,
                    slots,
                })
            }
            5 => {
                let round = c.u64()?;
                let n = c.u32()? as usize;
                ensure!(n <= MAX_SPEC_LEN, "SpecChange spec exceeds {MAX_SPEC_LEN} bytes");
                let spec = std::str::from_utf8(c.take(n)?)
                    .context("SpecChange spec is not valid UTF-8")?
                    .to_string();
                c.done()?;
                check_spec_string(&spec)?;
                Ok(Message::SpecChange { round, spec })
            }
            t => bail!("unknown message tag {t}"),
        }
    }
}

/// A `PartialUpload`'s slots cannot claim more holders than the span has
/// clients — each client holds a slot at most once, however deep the
/// tree. Checked on send (validate) and on parse, so a forged span
/// cannot inflate the root's plain-mean divisor.
fn check_partial_holders(span: (u64, u64), slots: &[SlotPartial]) -> Result<()> {
    let width = span.1 - span.0;
    for s in slots {
        ensure!(
            s.holders <= width,
            "PartialUpload claims {} slot holders for a span of {width} clients",
            s.holders
        );
    }
    Ok(())
}

/// A `PartialUpload`'s slots must actually be the dimension slice its
/// shard range claims: every slot's internal dim equals the range width.
/// Checked on send (validate) and on parse, so a forged shard range
/// cannot make the root concatenate misaligned slices.
fn check_partial_shard(shard: (u32, u32), slots: &[SlotPartial]) -> Result<()> {
    ensure!(shard.0 <= shard.1, "PartialUpload shard range is inverted");
    let width = (shard.1 - shard.0) as usize;
    for s in slots {
        ensure!(
            s.internal_dim() == width,
            "PartialUpload slot spans {} dims but its shard range [{}, {}) spans {width}",
            s.internal_dim(),
            shard.0,
            shard.1
        );
    }
    Ok(())
}

/// Checked narrowing for wire-format length fields: an oversized frame is
/// a serialization error the caller can surface, never a worker-thread
/// panic.
fn ensure_u32(v: usize) -> Result<u32> {
    ensure!(v <= u32::MAX as usize, "field too large for wire format ({v} > u32::MAX)");
    Ok(v as u32)
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(self.pos + n <= self.buf.len(), "message truncated");
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn done(&self) -> Result<()> {
        ensure!(self.pos == self.buf.len(), "trailing bytes in message");
        Ok(())
    }
}

/// Leader-side view of a transport: broadcast to all workers, receive
/// uploads, with cumulative byte accounting. "Workers" here means the
/// node's direct children — real workers, or aggregation-tier nodes
/// forwarding `PartialUpload`s.
pub trait TransportHub: Send {
    /// Number of connected workers.
    fn n_workers(&self) -> usize;
    /// Send a message to every worker, addressed to `session`.
    fn broadcast_session(&mut self, session: u16, msg: &Message) -> Result<()>;
    /// Block for the next upload, with its envelope session.
    fn recv_env(&mut self) -> Result<Envelope>;
    /// Block for the next upload, up to `timeout`: `Ok(None)` means the
    /// deadline passed with no message (the barrier-liveness path —
    /// callers turn it into an error naming the missing children).
    fn recv_env_timeout(&mut self, timeout: Duration) -> Result<Option<Envelope>>;
    /// Cumulative (downlink, uplink) bytes moved so far.
    fn bytes_moved(&self) -> (u64, u64);

    /// Send a message to every worker on the root session (the
    /// single-tenant convenience every pre-envelope caller uses).
    fn broadcast(&mut self, msg: &Message) -> Result<()> {
        self.broadcast_session(ROOT_SESSION, msg)
    }
    /// Block for the next upload, discarding the session id.
    fn recv(&mut self) -> Result<Message> {
        Ok(self.recv_env()?.msg)
    }
    /// [`Self::recv_env_timeout`], discarding the session id.
    fn recv_timeout(&mut self, timeout: Duration) -> Result<Option<Message>> {
        Ok(self.recv_env_timeout(timeout)?.map(|e| e.msg))
    }
}

/// Child-side view of a transport link to the parent node: what a worker
/// (or an aggregation-tier node talking to *its* parent) holds. One
/// abstraction for both the in-process and the TCP endpoint, so the
/// worker/aggregator loops are written once.
pub trait Endpoint: Send {
    /// Send a message upstream, addressed to `session`.
    fn send_env(&mut self, session: u16, msg: Message) -> Result<()>;
    /// Block for the next downstream message, with its envelope session.
    fn recv_env(&mut self) -> Result<Envelope>;

    /// Send a message upstream on the root session.
    fn send_msg(&mut self, msg: Message) -> Result<()> {
        self.send_env(ROOT_SESSION, msg)
    }
    /// Block for the next downstream message, discarding the session id.
    fn recv_msg(&mut self) -> Result<Message> {
        Ok(self.recv_env()?.msg)
    }
}

// ---------------------------------------------------------------------------
// Loopback
// ---------------------------------------------------------------------------

/// In-process hub: workers are threads holding [`LoopbackEndpoint`]s.
pub struct LoopbackHub {
    to_workers: Vec<Sender<Envelope>>,
    from_workers: Receiver<Envelope>,
    down_bytes: u64,
    up_bytes: Arc<Mutex<u64>>,
}

/// Worker-side endpoint of a loopback hub.
pub struct LoopbackEndpoint {
    pub rx: Receiver<Envelope>,
    tx: Sender<Envelope>,
    up_bytes: Arc<Mutex<u64>>,
}

impl LoopbackEndpoint {
    pub fn send(&self, msg: Message) -> Result<()> {
        self.send_session(ROOT_SESSION, msg)
    }
    pub fn send_session(&self, session: u16, msg: Message) -> Result<()> {
        // Same legality as TCP: a message the wire format cannot carry
        // must not slip through in-process either.
        msg.validate()?;
        *self.up_bytes.lock().unwrap() += msg.framed_len();
        self.tx.send(Envelope { session, msg }).context("leader hung up")
    }
    pub fn recv(&self) -> Result<Message> {
        Ok(self.recv_envelope()?.msg)
    }
    pub fn recv_envelope(&self) -> Result<Envelope> {
        self.rx.recv().context("leader hung up")
    }
}

impl Endpoint for LoopbackEndpoint {
    fn send_env(&mut self, session: u16, msg: Message) -> Result<()> {
        LoopbackEndpoint::send_session(self, session, msg)
    }
    fn recv_env(&mut self) -> Result<Envelope> {
        LoopbackEndpoint::recv_envelope(self)
    }
}

impl LoopbackHub {
    /// Create a hub with `n` worker endpoints.
    pub fn new(n: usize) -> (Self, Vec<LoopbackEndpoint>) {
        let (up_tx, up_rx) = std::sync::mpsc::channel();
        let up_bytes = Arc::new(Mutex::new(0u64));
        let mut to_workers = Vec::with_capacity(n);
        let mut endpoints = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = std::sync::mpsc::channel();
            to_workers.push(tx);
            endpoints.push(LoopbackEndpoint {
                rx,
                tx: up_tx.clone(),
                up_bytes: up_bytes.clone(),
            });
        }
        (
            LoopbackHub { to_workers, from_workers: up_rx, down_bytes: 0, up_bytes },
            endpoints,
        )
    }
}

impl TransportHub for LoopbackHub {
    fn n_workers(&self) -> usize {
        self.to_workers.len()
    }

    fn broadcast_session(&mut self, session: u16, msg: &Message) -> Result<()> {
        // Account the broadcast once per worker (the paper's footnote 4
        // notes broadcast downlink can be cheaper; metrics report both).
        // The clone itself is cheap: RoundStart payloads are Arc-shared,
        // so n workers share one allocation instead of n copies.
        //
        // Same legality as TCP (which validates inside write_msg).
        msg.validate()?;
        // Best-effort across endpoints: a worker that died mid-round must
        // not prevent the others from receiving the message — Shutdown in
        // particular — so send to every endpoint first and report the
        // failure afterwards.
        let mut any_dead = false;
        for tx in &self.to_workers {
            if tx.send(Envelope { session, msg: msg.clone() }).is_ok() {
                self.down_bytes += msg.framed_len();
            } else {
                any_dead = true;
            }
        }
        ensure!(!any_dead, "worker hung up");
        Ok(())
    }

    fn recv_env(&mut self) -> Result<Envelope> {
        self.from_workers.recv().context("all workers hung up")
    }

    fn recv_env_timeout(&mut self, timeout: Duration) -> Result<Option<Envelope>> {
        match self.from_workers.recv_timeout(timeout) {
            Ok(m) => Ok(Some(m)),
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => bail!("all workers hung up"),
        }
    }

    fn bytes_moved(&self) -> (u64, u64) {
        (self.down_bytes, *self.up_bytes.lock().unwrap())
    }
}

// ---------------------------------------------------------------------------
// TCP
// ---------------------------------------------------------------------------

fn write_msg(stream: &mut impl Write, session: u16, msg: &Message) -> Result<u64> {
    let bytes = msg.to_bytes_for(session)?;
    stream.write_all(&(bytes.len() as u32).to_le_bytes())?;
    stream.write_all(&bytes)?;
    stream.flush()?;
    Ok(bytes.len() as u64 + 4)
}

fn read_msg(stream: &mut impl Read) -> Result<(Envelope, u64)> {
    let mut len_buf = [0u8; 4];
    stream.read_exact(&mut len_buf)?;
    let len = u32::from_le_bytes(len_buf) as usize;
    ensure!(len <= 1 << 30, "message too large");
    let mut buf = vec![0u8; len];
    stream.read_exact(&mut buf)?;
    Ok((Envelope::from_bytes(&buf)?, len as u64 + 4))
}

/// A bound-but-not-yet-accepting TCP hub: created by [`TcpHub::bind`].
/// Exposes the real listen address (essential after binding port 0, and
/// the natural ready signal for tests — once `bind` returns, connects
/// queue in the OS backlog even before [`Self::accept`] runs).
pub struct TcpHubBinding {
    listener: TcpListener,
}

impl TcpHubBinding {
    /// The address the listener actually bound (with the OS-assigned port
    /// when the caller asked for port 0).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Accept exactly `n` worker connections and start serving.
    pub fn accept(self, n: usize) -> Result<TcpHub> {
        let (tx, rx) = std::sync::mpsc::channel();
        let up_bytes = Arc::new(Mutex::new(0u64));
        let mut writers = Vec::with_capacity(n);
        let mut reader_threads = Vec::with_capacity(n);
        for i in 0..n {
            let (stream, peer) = self.listener.accept().context("accepting worker")?;
            stream.set_nodelay(true).ok();
            let reader = stream.try_clone().context("cloning stream")?;
            writers.push(BufWriter::new(stream));
            let tx = tx.clone();
            let up = up_bytes.clone();
            reader_threads.push(
                std::thread::Builder::new()
                    .name(format!("dme-tcp-reader-{i}"))
                    .spawn(move || {
                        let mut r = BufReader::new(reader);
                        loop {
                            match read_msg(&mut r) {
                                Ok((env, n)) => {
                                    *up.lock().unwrap() += n;
                                    if tx.send(Ok(env)).is_err() {
                                        return;
                                    }
                                }
                                // A protocol-identity failure (bad magic
                                // or unknown version) is *reported* to
                                // the hub's consumer — a typed rejection,
                                // never a silent kill. Anything else (a
                                // closed socket, a truncated or forged
                                // payload) keeps the silent-kill
                                // contract: drop the connection, let the
                                // barrier name the missing child.
                                Err(e) => {
                                    if e.downcast_ref::<WireError>().is_some() {
                                        let _ = tx.send(Err(e));
                                    }
                                    return;
                                }
                            }
                        }
                    })
                    .with_context(|| format!("spawning reader for {peer}"))?,
            );
        }
        Ok(TcpHub { writers, from_workers: rx, reader_threads, down_bytes: 0, up_bytes })
    }
}

/// TCP hub: listens, accepts `n` workers, then serves rounds.
pub struct TcpHub {
    writers: Vec<BufWriter<TcpStream>>,
    from_workers: Receiver<Result<Envelope>>,
    reader_threads: Vec<std::thread::JoinHandle<()>>,
    down_bytes: u64,
    up_bytes: Arc<Mutex<u64>>,
}

impl TcpHub {
    /// Bind `addr` without accepting yet; use [`TcpHubBinding::local_addr`]
    /// to learn the real address (port 0 supported).
    pub fn bind(addr: &str) -> Result<TcpHubBinding> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        Ok(TcpHubBinding { listener })
    }

    /// Bind `addr` and accept exactly `n` worker connections.
    pub fn listen(addr: &str, n: usize) -> Result<Self> {
        Self::bind(addr)?.accept(n)
    }
}

impl Drop for TcpHub {
    fn drop(&mut self) {
        let _ = self.broadcast(&Message::Shutdown);
        self.writers.clear(); // close sockets so readers exit
        for t in self.reader_threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl TransportHub for TcpHub {
    fn n_workers(&self) -> usize {
        self.writers.len()
    }

    fn broadcast_session(&mut self, session: u16, msg: &Message) -> Result<()> {
        // Best-effort like the loopback hub: write to every live worker
        // before surfacing the first failure, so one dead connection
        // cannot starve the others of Shutdown.
        let mut first_err = None;
        for w in &mut self.writers {
            match write_msg(w, session, msg) {
                Ok(n) => self.down_bytes += n,
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        match first_err {
            None => Ok(()),
            Some(e) => Err(e),
        }
    }

    fn recv_env(&mut self) -> Result<Envelope> {
        self.from_workers.recv().context("all workers disconnected")?
    }

    fn recv_env_timeout(&mut self, timeout: Duration) -> Result<Option<Envelope>> {
        match self.from_workers.recv_timeout(timeout) {
            Ok(m) => Ok(Some(m?)),
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => bail!("all workers disconnected"),
        }
    }

    fn bytes_moved(&self) -> (u64, u64) {
        (self.down_bytes, *self.up_bytes.lock().unwrap())
    }
}

/// Default retry count for [`TcpEndpoint::connect_with_backoff`]: seven
/// retries at 50 ms → 1.6 s capped doubling ≈ 4.75 s of total waiting,
/// enough for a leader that is still binding on the other side of a
/// process launch race.
pub const DEFAULT_CONNECT_RETRIES: u32 = 7;

/// Multiplicative jitter for one backoff sleep, in `[0.5, 1.5)`: ±50%
/// around the nominal delay, derived from `salt` by one splitmix64
/// step (uniform over the 53-bit mantissa grid). Pure, so the bounds
/// are unit-testable; callers feed a per-process random salt mixed
/// with the attempt number so that thousands of swarm clients kicked
/// off by the same flapping aggregator fan their reconnect storm out
/// instead of thundering in lockstep at every doubled interval.
pub fn backoff_jitter_factor(salt: u64) -> f64 {
    let mut s = salt;
    let z = crate::rng::splitmix64(&mut s);
    0.5 + (z >> 11) as f64 / (1u64 << 53) as f64
}

/// Worker-side TCP endpoint (used by the `dme worker` process).
pub struct TcpEndpoint {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl TcpEndpoint {
    /// Connect once, failing immediately on refusal (tests bind first,
    /// so a refusal there is a bug, not a race). Process-level commands
    /// use [`Self::connect_with_backoff`] instead.
    pub fn connect(addr: &str) -> Result<Self> {
        Self::connect_with_backoff(addr, 0)
    }

    /// Connect with up to `retries` retries under capped exponential
    /// backoff (50 ms doubling to a 1.6 s ceiling), each sleep jittered
    /// by ±50% ([`backoff_jitter_factor`]) so a reconnect storm against
    /// a flapping parent desynchronizes instead of re-arriving in the
    /// same doubled waves. A worker or mid-tier aggregator started
    /// moments before its parent listens no longer dies with a raw
    /// connection refusal; if every attempt fails, the error names the
    /// address and the attempt count.
    pub fn connect_with_backoff(addr: &str, retries: u32) -> Result<Self> {
        // Per-process/per-call entropy: distinct clients must jitter
        // differently, which is exactly what the seeded-determinism
        // contract does NOT cover (sleeps never reach the estimate).
        let salt = std::hash::BuildHasher::hash_one(
            &std::collections::hash_map::RandomState::new(),
            std::thread::current().id(),
        );
        let mut delay = Duration::from_millis(50);
        let mut attempt = 0u32;
        loop {
            match TcpStream::connect(addr) {
                Ok(stream) => {
                    stream.set_nodelay(true).ok();
                    let reader = BufReader::new(stream.try_clone()?);
                    return Ok(TcpEndpoint { reader, writer: BufWriter::new(stream) });
                }
                Err(e) => {
                    attempt += 1;
                    if attempt > retries {
                        return Err(e).with_context(|| {
                            format!("connecting {addr} failed after {attempt} attempt(s)")
                        });
                    }
                    let factor = backoff_jitter_factor(salt ^ u64::from(attempt));
                    std::thread::sleep(delay.mul_f64(factor));
                    delay = (delay * 2).min(Duration::from_millis(1600));
                }
            }
        }
    }

    pub fn send(&mut self, msg: &Message) -> Result<()> {
        self.send_session(ROOT_SESSION, msg)
    }

    pub fn send_session(&mut self, session: u16, msg: &Message) -> Result<()> {
        write_msg(&mut self.writer, session, msg)?;
        Ok(())
    }

    pub fn recv(&mut self) -> Result<Message> {
        Ok(self.recv_envelope()?.msg)
    }

    pub fn recv_envelope(&mut self) -> Result<Envelope> {
        Ok(read_msg(&mut self.reader)?.0)
    }
}

impl Endpoint for TcpEndpoint {
    fn send_env(&mut self, session: u16, msg: Message) -> Result<()> {
        TcpEndpoint::send_session(self, session, &msg)
    }
    fn recv_env(&mut self) -> Result<Envelope> {
        TcpEndpoint::recv_envelope(self)
    }
}

/// Which TCP hub implementation serves `dme serve` / `dme aggregate`
/// (`--transport reactor|threads`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Transport {
    /// Thread-per-connection blocking hub ([`TcpHub`]): one reader
    /// thread and one `write`+`flush` syscall pair per message per
    /// connection. Portable, simple, fine up to a few thousand workers.
    Threads,
    /// Single-threaded epoll reactor
    /// ([`ReactorHub`](super::reactor::ReactorHub)): batched vectored
    /// writes, zero-copy broadcast, thread count independent of n.
    #[cfg(target_os = "linux")]
    Reactor,
}

impl Default for Transport {
    /// The reactor where it exists (Linux), threads elsewhere.
    #[cfg(target_os = "linux")]
    fn default() -> Self {
        Transport::Reactor
    }
    /// The reactor where it exists (Linux), threads elsewhere.
    #[cfg(not(target_os = "linux"))]
    fn default() -> Self {
        Transport::Threads
    }
}

impl std::str::FromStr for Transport {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "threads" => Ok(Transport::Threads),
            #[cfg(target_os = "linux")]
            "reactor" => Ok(Transport::Reactor),
            #[cfg(not(target_os = "linux"))]
            "reactor" => bail!("the reactor transport requires Linux (epoll)"),
            other => bail!("unknown transport {other:?} (expected \"reactor\" or \"threads\")"),
        }
    }
}

impl std::fmt::Display for Transport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Transport::Threads => write!(f, "threads"),
            #[cfg(target_os = "linux")]
            Transport::Reactor => write!(f, "reactor"),
        }
    }
}

/// Transport-agnostic bind → [`Self::local_addr`] → [`Self::accept`]
/// flow: what `dme serve`/`dme aggregate` and the parameterized
/// conformance tests use so the choice of hub is one enum value, not a
/// code path.
pub enum HubBinding {
    /// A pending [`TcpHub`].
    Threads(TcpHubBinding),
    /// A pending [`ReactorHub`](super::reactor::ReactorHub).
    #[cfg(target_os = "linux")]
    Reactor(super::reactor::ReactorBinding),
}

impl HubBinding {
    /// Bind `addr` (port 0 supported) without accepting yet.
    pub fn bind(transport: Transport, addr: &str) -> Result<Self> {
        match transport {
            Transport::Threads => Ok(HubBinding::Threads(TcpHub::bind(addr)?)),
            #[cfg(target_os = "linux")]
            Transport::Reactor => {
                Ok(HubBinding::Reactor(super::reactor::ReactorBinding::bind(addr)?))
            }
        }
    }

    /// The address the listener actually bound.
    pub fn local_addr(&self) -> Result<SocketAddr> {
        match self {
            HubBinding::Threads(b) => b.local_addr(),
            #[cfg(target_os = "linux")]
            HubBinding::Reactor(b) => b.local_addr(),
        }
    }

    /// Accept exactly `n` worker connections and start serving.
    pub fn accept(self, n: usize) -> Result<Box<dyn TransportHub>> {
        match self {
            HubBinding::Threads(b) => Ok(Box::new(b.accept(n)?)),
            #[cfg(target_os = "linux")]
            HubBinding::Reactor(b) => Ok(Box::new(b.accept(n)?)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame(bytes: Vec<u8>, bits: u64) -> WeightedFrame {
        WeightedFrame { frame: Frame::new(bytes, bits), weight: 2.5 }
    }

    #[test]
    fn backoff_jitter_stays_within_half_to_three_halves() {
        let mut sum = 0.0;
        let mut distinct = std::collections::HashSet::new();
        for salt in 0..10_000u64 {
            let f = backoff_jitter_factor(salt);
            assert!((0.5..1.5).contains(&f), "salt {salt}: factor {f} out of [0.5, 1.5)");
            sum += f;
            distinct.insert(f.to_bits());
        }
        // Uniform over [0.5, 1.5): the mean sits near 1 and the factors
        // actually vary (a constant factor would keep the storm in sync).
        let mean = sum / 10_000.0;
        assert!((mean - 1.0).abs() < 0.02, "mean jitter {mean} far from 1.0");
        assert!(distinct.len() > 9_000, "only {} distinct factors", distinct.len());
    }

    fn assert_roundtrip(m: &Message) {
        let bytes = m.to_bytes().unwrap();
        let back = Message::from_bytes(&bytes).unwrap();
        match (m, &back) {
            (
                Message::RoundStart { round: r1, shared_seed: s1, dim: d1, payload: p1 },
                Message::RoundStart { round: r2, shared_seed: s2, dim: d2, payload: p2 },
            ) => {
                assert_eq!((r1, s1, d1), (r2, s2, d2));
                assert_eq!(&p1[..], &p2[..]);
            }
            (
                Message::Upload { client: c1, round: r1, frames: f1 },
                Message::Upload { client: c2, round: r2, frames: f2 },
            ) => {
                assert_eq!((c1, r1), (c2, r2));
                assert_eq!(f1.len(), f2.len());
                for (a, b) in f1.iter().zip(f2) {
                    assert_eq!(a.frame.bytes, b.frame.bytes);
                    assert_eq!(a.frame.bit_len, b.frame.bit_len);
                    assert_eq!(a.weight, b.weight);
                }
            }
            (
                Message::PartialUpload {
                    agg_id: a1,
                    round: r1,
                    span: s1,
                    uplink_bits: u1,
                    n_frames: n1,
                    shard: sh1,
                    slots: sl1,
                },
                Message::PartialUpload {
                    agg_id: a2,
                    round: r2,
                    span: s2,
                    uplink_bits: u2,
                    n_frames: n2,
                    shard: sh2,
                    slots: sl2,
                },
            ) => {
                assert_eq!((a1, r1, s1, u1, n1, sh1), (a2, r2, s2, u2, n2, sh2));
                assert_eq!(sl1, sl2, "slots must round-trip exactly");
            }
            (
                Message::SpecChange { round: r1, spec: s1 },
                Message::SpecChange { round: r2, spec: s2 },
            ) => {
                assert_eq!((r1, s1), (r2, s2));
            }
            (Message::Shutdown, Message::Shutdown) => {}
            _ => panic!("variant mismatch"),
        }
    }

    /// A PartialUpload with merged, weighted, and silent slots — the
    /// shapes an aggregation-tier node actually produces.
    fn partial_upload() -> Message {
        let mut merged = SlotPartial::from_decoded(&[1.5, -2.25, 0.5], 1.0, 1).unwrap();
        merged.merge(&SlotPartial::from_decoded(&[0.25, 1e-3, -7.0], 2.5, 1).unwrap()).unwrap();
        merged.merge(&SlotPartial::silent(3)).unwrap();
        let uniform = SlotPartial::from_decoded(&[4.0, 0.0, -0.125], 1.0, 1).unwrap();
        Message::PartialUpload {
            agg_id: 9,
            round: 3,
            span: (16, 48),
            uplink_bits: 12345,
            n_frames: 2,
            shard: (0, 3),
            slots: vec![merged, uniform, SlotPartial::silent(3)],
        }
    }

    /// The envelope header a legal root-session message of tag `tag`
    /// starts with — prefix for handcrafted adversarial payloads.
    fn raw(tag: u8) -> Vec<u8> {
        let mut v = WIRE_MAGIC.to_vec();
        v.push(WIRE_VERSION);
        v.extend_from_slice(&ROOT_SESSION.to_le_bytes());
        v.push(tag);
        v
    }

    /// Every message shape the leader (or a worker) can legally build:
    /// the wire format must round-trip each of them exactly.
    fn legal_messages() -> Vec<Message> {
        vec![
            Message::RoundStart {
                round: 7,
                shared_seed: 0xdead_beef_1234_5678,
                dim: 2,
                payload: vec![1.0, -2.0, 3.5, 0.0].into(),
            },
            // Ragged payload: length not a multiple of dim. The leader
            // sends these legally (e.g. a single d-vector broadcast with
            // protocol-internal dim); the header counts floats, not
            // vectors, so nothing is truncated or rejected.
            Message::RoundStart {
                round: 1,
                shared_seed: 42,
                dim: 2,
                payload: vec![9.0, 1.0, 3.5].into(),
            },
            // Payload shorter than one vector, and an empty payload. A
            // zero shared_seed is legal (it is a seed, not a sentinel).
            Message::RoundStart { round: 2, shared_seed: 0, dim: 7, payload: vec![4.0].into() },
            Message::RoundStart {
                round: 3,
                shared_seed: u64::MAX,
                dim: 64,
                payload: Vec::new().into(),
            },
            Message::Upload {
                client: 3,
                round: 7,
                frames: vec![frame(vec![0xab, 0xcd], 12), frame(vec![], 0)],
            },
            Message::Upload { client: 0, round: 0, frames: vec![] },
            partial_upload(),
            // A span-degenerate, slotless partial (an aggregator whose
            // whole span was silent this round).
            Message::PartialUpload {
                agg_id: 0,
                round: 0,
                span: (5, 5),
                uplink_bits: 0,
                n_frames: 0,
                shard: (0, 0),
                slots: vec![],
            },
            // A dimension-sharded partial: the slice [4, 7) of a larger
            // vector — its slots span 3 dims starting at offset 4.
            Message::PartialUpload {
                agg_id: 2,
                round: 1,
                span: (0, 4),
                uplink_bits: 99,
                n_frames: 4,
                shard: (4, 7),
                slots: vec![SlotPartial::from_decoded(&[0.5, -1.0, 2.0], 2.0, 2).unwrap()],
            },
            Message::SpecChange { round: 4, spec: "rotated:k=16".into() },
            Message::SpecChange {
                round: 0,
                spec: "varlen:k=33,coder=huffman,p=0.5,q=0.25".into(),
            },
            Message::Shutdown,
        ]
    }

    #[test]
    fn message_roundtrip_all_variants() {
        for m in legal_messages() {
            assert_roundtrip(&m);
        }
    }

    #[test]
    fn ragged_round_start_roundtrips() {
        // Regression: the old header encoded payload.len()/dim, so a
        // payload that was not a multiple of dim serialized more floats
        // than the header admitted and from_bytes failed with "trailing
        // bytes" — fine over loopback (which never serializes), broken
        // over TCP.
        let m = Message::RoundStart {
            round: 5,
            shared_seed: 11,
            dim: 3,
            payload: vec![1.0, 2.0, 3.0, 4.0].into(),
        };
        assert_roundtrip(&m);
    }

    #[test]
    fn round_start_dim_zero_rejected() {
        let m = Message::RoundStart { round: 0, shared_seed: 1, dim: 0, payload: vec![1.0].into() };
        assert!(m.to_bytes().is_err(), "dim == 0 must not serialize");
        // Loopback enforces the same legality as TCP: the invalid
        // message is rejected by both hub directions, not just by
        // serialization.
        let (mut hub, eps) = LoopbackHub::new(1);
        assert!(hub.broadcast(&m).is_err());
        assert!(eps[0].send(m).is_err());
        // And a handcrafted dim-0 header must not parse (it used to
        // divide by zero before reaching any check).
        let mut bytes = raw(1);
        bytes.extend_from_slice(&0u64.to_le_bytes()); // round
        bytes.extend_from_slice(&0u64.to_le_bytes()); // shared_seed
        bytes.extend_from_slice(&1u32.to_le_bytes()); // n_floats
        bytes.extend_from_slice(&0u32.to_le_bytes()); // dim = 0
        bytes.extend_from_slice(&1.0f32.to_le_bytes());
        assert!(Message::from_bytes(&bytes).is_err());
    }

    #[test]
    fn oversized_fields_error_instead_of_panicking() {
        // An oversized length field must surface as Err from to_bytes,
        // never assert/panic the sending thread. Exercising it end to end
        // would need a >4 GiB allocation, so test the guard at its exact
        // boundary plus a legal message through the checked path.
        assert!(ensure_u32(u32::MAX as usize).is_ok());
        assert!(ensure_u32(u32::MAX as usize + 1).is_err());
        let m = Message::Upload { client: 1, round: 1, frames: vec![frame(vec![1, 2, 3], 20)] };
        assert!(m.to_bytes().is_ok());
    }

    #[test]
    fn wire_len_matches_serialization() {
        let msgs = vec![
            Message::RoundStart { round: 7, shared_seed: 5, dim: 3, payload: vec![1.0; 9].into() },
            Message::RoundStart {
                round: 7,
                shared_seed: 5,
                dim: 3,
                payload: vec![1.0; 10].into(),
            },
            Message::Upload {
                client: 3,
                round: 7,
                frames: vec![frame(vec![0xab; 17], 130), frame(vec![], 0)],
            },
            Message::Upload { client: 0, round: 0, frames: vec![] },
            partial_upload(),
            Message::SpecChange { round: 9, spec: "klevel:k=8,p=0.5".into() },
            Message::Shutdown,
        ];
        for m in msgs {
            assert_eq!(m.wire_len(), m.to_bytes().unwrap().len() as u64);
            assert_eq!(m.framed_len(), m.wire_len() + 4);
        }
    }

    #[test]
    fn forged_spec_changes_rejected() {
        // The tag-5 forgery gate: a spec the grammar (or builder) rejects
        // must fail at validate/to_bytes on send — the same gate both
        // hubs run — and at from_bytes on receive.
        for bad in [
            "",                        // empty
            "nonsense",                // unknown protocol
            "klevel:k",                // malformed arg
            "klevel:k=1",              // builder rejects k < 2
            "klevel:p=0",              // p out of range
            "rotated:k=4,q=0.5",       // structural: rotation + coord sampling
            "varlen:coder=zip",        // unknown coder
        ] {
            let m = Message::SpecChange { round: 0, spec: bad.to_string() };
            assert!(m.validate().is_err(), "spec `{bad}` accepted by validate");
            assert!(m.to_bytes().is_err(), "spec `{bad}` serialized");
            let (mut hub, eps) = LoopbackHub::new(1);
            assert!(hub.broadcast(&m).is_err(), "spec `{bad}` crossed loopback");
            drop(eps);
        }
        // Oversized spec: rejected on send and before the parser ever
        // sees the payload on receive.
        let long = format!("klevel:k=16{}", " ".repeat(MAX_SPEC_LEN));
        let m = Message::SpecChange { round: 0, spec: long };
        assert!(m.validate().is_err());
        // Handcrafted wire payloads: bad UTF-8, truncation, trailing
        // garbage, and a length field overrunning the message.
        let good = Message::SpecChange { round: 3, spec: "binary".into() }.to_bytes().unwrap();
        assert!(Message::from_bytes(&good).is_ok());
        let mut bad_utf8 = good.clone();
        *bad_utf8.last_mut().unwrap() = 0xff;
        assert!(Message::from_bytes(&bad_utf8).is_err(), "bad UTF-8 accepted");
        for cut in [1usize, 9, 12, good.len() - 1] {
            assert!(Message::from_bytes(&good[..cut]).is_err(), "truncation at {cut} accepted");
        }
        let mut long = good.clone();
        long.push(b'x');
        assert!(Message::from_bytes(&long).is_err(), "trailing byte accepted");
        let mut huge_len = good.clone();
        // Spec length field sits after header (6) + round (8).
        huge_len[14..18].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(Message::from_bytes(&huge_len).is_err(), "oversized length accepted");
    }

    #[test]
    fn malformed_partial_uploads_rejected() {
        // Inverted span: rejected by validate() on send — which is the
        // same gate both hubs run — and by the parser.
        let inverted = Message::PartialUpload {
            agg_id: 1,
            round: 0,
            span: (8, 4),
            uplink_bits: 0,
            n_frames: 0,
            shard: (0, 0),
            slots: vec![],
        };
        assert!(inverted.validate().is_err());
        assert!(inverted.to_bytes().is_err());
        // Inverted shard range: same three gates.
        let bad_shard = Message::PartialUpload {
            agg_id: 1,
            round: 0,
            span: (0, 4),
            uplink_bits: 0,
            n_frames: 0,
            shard: (7, 4),
            slots: vec![],
        };
        assert!(bad_shard.validate().is_err());
        assert!(bad_shard.to_bytes().is_err());
        // Shard range whose width disagrees with the slots' dim: a
        // forged slice must not reach the root's concatenation.
        let misaligned = Message::PartialUpload {
            agg_id: 1,
            round: 0,
            span: (0, 4),
            uplink_bits: 0,
            n_frames: 0,
            shard: (0, 2),
            slots: vec![SlotPartial::silent(3)],
        };
        assert!(misaligned.validate().is_err());
        assert!(misaligned.to_bytes().is_err());
        let (mut hub, eps) = LoopbackHub::new(1);
        assert!(hub.broadcast(&inverted).is_err());
        assert!(eps[0].send(inverted).is_err());
        // Slot count larger than the message could hold: rejected before
        // any allocation.
        let mut bytes = raw(4);
        bytes.extend_from_slice(&0u64.to_le_bytes()); // agg_id
        bytes.extend_from_slice(&0u64.to_le_bytes()); // round
        bytes.extend_from_slice(&0u64.to_le_bytes()); // span.0
        bytes.extend_from_slice(&9u64.to_le_bytes()); // span.1
        bytes.extend_from_slice(&0u64.to_le_bytes()); // uplink_bits
        bytes.extend_from_slice(&0u64.to_le_bytes()); // n_frames
        bytes.extend_from_slice(&0u32.to_le_bytes()); // shard.0
        bytes.extend_from_slice(&3u32.to_le_bytes()); // shard.1
        bytes.extend_from_slice(&u32::MAX.to_le_bytes()); // n_slots
        assert!(Message::from_bytes(&bytes).is_err());
        // Truncations of a valid message are rejected at every cut the
        // wire could realistically produce.
        let good = partial_upload().to_bytes().unwrap();
        for cut in [1usize, 9, 40, 53, 55, good.len() / 2, good.len() - 1] {
            assert!(
                Message::from_bytes(&good[..cut]).is_err(),
                "truncation at {cut} accepted"
            );
        }
        let mut long = good.clone();
        long.push(7);
        assert!(Message::from_bytes(&long).is_err(), "trailing garbage accepted");
    }

    #[test]
    fn hub_recv_timeout_elapses_and_delivers() {
        let (mut hub, eps) = LoopbackHub::new(1);
        assert!(hub.recv_timeout(Duration::from_millis(10)).unwrap().is_none());
        eps[0].send(Message::Upload { client: 3, round: 0, frames: vec![] }).unwrap();
        match hub.recv_timeout(Duration::from_millis(100)).unwrap() {
            Some(Message::Upload { client, .. }) => assert_eq!(client, 3),
            other => panic!("expected the queued upload, got {other:?}"),
        }
        drop(eps);
        assert!(hub.recv_timeout(Duration::from_millis(10)).is_err(), "disconnected");
    }

    #[test]
    fn malformed_messages_rejected() {
        assert!(Message::from_bytes(&[]).is_err());
        assert!(Message::from_bytes(&[9]).is_err());
        assert!(Message::from_bytes(&[1, 0]).is_err()); // truncated
        // trailing garbage
        let mut ok = Message::Shutdown.to_bytes().unwrap();
        ok.push(0);
        assert!(Message::from_bytes(&ok).is_err());
        // RoundStart header/payload length mismatch (one float missing)
        let full = Message::RoundStart {
            round: 0,
            shared_seed: 0,
            dim: 1,
            payload: vec![1.0, 2.0].into(),
        };
        let mut bytes = full.to_bytes().unwrap();
        bytes.truncate(bytes.len() - 4);
        assert!(Message::from_bytes(&bytes).is_err());
        // Upload frame count larger than the message could possibly hold
        // (must be rejected before any allocation happens).
        let mut bytes = raw(2);
        bytes.extend_from_slice(&0u64.to_le_bytes()); // client
        bytes.extend_from_slice(&0u64.to_le_bytes()); // round
        bytes.extend_from_slice(&u32::MAX.to_le_bytes()); // n_frames
        assert!(Message::from_bytes(&bytes).is_err());
        // bit_len > bytes*8: rejected on send (validate) and on parse.
        let bad = Message::Upload {
            client: 0,
            round: 0,
            frames: vec![WeightedFrame {
                frame: Frame { bytes: vec![1], bit_len: 9 },
                weight: 1.0,
            }],
        };
        assert!(bad.to_bytes().is_err());
        let mut bytes = raw(2);
        bytes.extend_from_slice(&0u64.to_le_bytes()); // client
        bytes.extend_from_slice(&0u64.to_le_bytes()); // round
        bytes.extend_from_slice(&1u32.to_le_bytes()); // n_frames
        bytes.extend_from_slice(&9u64.to_le_bytes()); // bit_len
        bytes.extend_from_slice(&1u32.to_le_bytes()); // n_bytes
        bytes.extend_from_slice(&1.0f32.to_le_bytes()); // weight
        bytes.push(1);
        assert!(Message::from_bytes(&bytes).is_err());
    }

    #[test]
    fn loopback_accounts_framed_bytes_exactly() {
        let (mut hub, eps) = LoopbackHub::new(3);
        let msg =
            Message::RoundStart { round: 0, shared_seed: 0, dim: 4, payload: vec![0.0; 4].into() };
        let msg_len = msg.framed_len();
        assert_eq!(msg_len, msg.to_bytes().unwrap().len() as u64 + 4);
        hub.broadcast(&msg).unwrap();
        for ep in &eps {
            let got = ep.recv().unwrap();
            matches!(got, Message::RoundStart { .. });
        }
        let up_msg = Message::Upload { client: 1, round: 0, frames: vec![] };
        let up_len = up_msg.framed_len();
        eps[1].send(up_msg).unwrap();
        hub.recv().unwrap();
        let (down, up) = hub.bytes_moved();
        assert_eq!(down, msg_len * 3);
        assert_eq!(up, up_len);
    }

    #[test]
    fn broadcast_payload_is_shared_not_cloned() {
        let (mut hub, eps) = LoopbackHub::new(4);
        let payload: Arc<[f32]> = vec![1.0f32; 64].into();
        let msg =
            Message::RoundStart { round: 0, shared_seed: 0, dim: 8, payload: payload.clone() };
        hub.broadcast(&msg).unwrap();
        for ep in &eps {
            match ep.recv().unwrap() {
                Message::RoundStart { payload: p, .. } => {
                    assert!(
                        Arc::ptr_eq(&p, &payload),
                        "loopback broadcast must share the payload allocation"
                    );
                }
                _ => panic!("expected RoundStart"),
            }
        }
    }

    #[test]
    fn tcp_hub_round_trip() {
        // Bind port 0 and read the real address back — no hardcoded port
        // (parallel test runs collide), no sleep (the bound listener is
        // the ready signal: connects queue in the backlog before accept).
        let binding = TcpHub::bind("127.0.0.1:0").unwrap();
        let addr = binding.local_addr().unwrap();
        let hub_thread = std::thread::spawn(move || {
            let mut hub = binding.accept(2).unwrap();
            // Ragged payload over real sockets: regression for the
            // n_vecs-based header.
            hub.broadcast(&Message::RoundStart {
                round: 1,
                shared_seed: 123,
                dim: 2,
                payload: vec![9.0, 1.0, 3.5].into(),
            })
            .unwrap();
            let mut clients = Vec::new();
            for _ in 0..2 {
                if let Message::Upload { client, .. } = hub.recv().unwrap() {
                    clients.push(client);
                }
            }
            clients.sort_unstable();
            hub.broadcast(&Message::Shutdown).unwrap();
            (clients, hub.bytes_moved())
        });
        let mut workers = Vec::new();
        for id in 0..2u64 {
            workers.push(std::thread::spawn(move || {
                let mut ep = TcpEndpoint::connect(&addr.to_string()).unwrap();
                match ep.recv().unwrap() {
                    Message::RoundStart { round, payload, .. } => {
                        assert_eq!(round, 1);
                        assert_eq!(&payload[..], &[9.0, 1.0, 3.5]);
                    }
                    _ => panic!("expected RoundStart"),
                }
                ep.send(&Message::Upload {
                    client: id,
                    round: 1,
                    frames: vec![frame(vec![id as u8; 3], 20)],
                })
                .unwrap();
                matches!(ep.recv().unwrap(), Message::Shutdown);
            }));
        }
        let (clients, (down, up)) = hub_thread.join().unwrap();
        for w in workers {
            w.join().unwrap();
        }
        assert_eq!(clients, vec![0, 1]);
        assert!(down > 0 && up > 0);
    }

    #[test]
    fn every_legal_message_survives_tcp() {
        // The serialization regression suite, but over real sockets: each
        // legal message is framed, written, read, and parsed back.
        let binding = TcpHub::bind("127.0.0.1:0").unwrap();
        let addr = binding.local_addr().unwrap();
        let msgs = legal_messages();
        let n_msgs = msgs.len();
        let echo = std::thread::spawn(move || {
            let (stream, _) = binding.listener.accept().unwrap();
            let mut r = BufReader::new(stream.try_clone().unwrap());
            let mut received = Vec::new();
            for _ in 0..n_msgs {
                received.push(read_msg(&mut r).unwrap().0.msg);
            }
            received
        });
        let stream = TcpStream::connect(addr).unwrap();
        let mut w = BufWriter::new(stream);
        for m in &msgs {
            write_msg(&mut w, ROOT_SESSION, m).unwrap();
        }
        drop(w);
        let received = echo.join().unwrap();
        assert_eq!(received.len(), msgs.len());
        for (sent, got) in msgs.iter().zip(&received) {
            // Compare via the canonical serialization.
            assert_eq!(sent.to_bytes().unwrap(), got.to_bytes().unwrap());
        }
    }

    #[test]
    fn envelope_session_round_trips_every_variant() {
        for m in legal_messages() {
            for session in [0u16, 1, 7, u16::MAX] {
                let bytes = m.to_bytes_for(session).unwrap();
                assert_eq!(bytes.len() as u64, m.wire_len(), "wire_len is session-independent");
                let env = Envelope::from_bytes(&bytes).unwrap();
                assert_eq!(env.session, session);
                // The body is byte-identical whatever the session.
                assert_eq!(env.msg.to_bytes().unwrap(), m.to_bytes().unwrap());
            }
        }
    }

    #[test]
    fn bad_magic_and_unknown_version_are_typed_rejections() {
        let good = Message::Shutdown.to_bytes().unwrap();
        assert_eq!(good.len() as u64, ENVELOPE_HEADER_LEN);

        let mut bad_magic = good.clone();
        bad_magic[0] = b'X';
        let err = Message::from_bytes(&bad_magic).unwrap_err();
        match err.downcast_ref::<WireError>() {
            Some(WireError::BadMagic(m)) => assert_eq!(m, &[b'X', b'M']),
            other => panic!("expected typed BadMagic, got {other:?}"),
        }

        let mut future = good.clone();
        future[2] = WIRE_VERSION + 1;
        let err = Message::from_bytes(&future).unwrap_err();
        match err.downcast_ref::<WireError>() {
            Some(WireError::UnknownVersion(v)) => assert_eq!(*v, WIRE_VERSION + 1),
            other => panic!("expected typed UnknownVersion, got {other:?}"),
        }

        // A *stale* peer is rejected the same way: version 1 predates the
        // RoundStart shared_seed field, so parsing its tag-1 bodies with
        // the v2 grammar would misread every field after `round`.
        let mut stale = good.clone();
        stale[2] = 1;
        let err = Message::from_bytes(&stale).unwrap_err();
        match err.downcast_ref::<WireError>() {
            Some(WireError::UnknownVersion(v)) => assert_eq!(*v, 1),
            other => panic!("expected typed UnknownVersion for v1, got {other:?}"),
        }

        // A merely truncated or forged payload is NOT a WireError: the
        // typed channel is reserved for protocol-identity failures.
        let err = Message::from_bytes(&good[..3]).unwrap_err();
        assert!(err.downcast_ref::<WireError>().is_none());
        let mut bad_tag = good.clone();
        bad_tag[5] = 99;
        let err = Message::from_bytes(&bad_tag).unwrap_err();
        assert!(err.downcast_ref::<WireError>().is_none());
    }

    #[test]
    fn loopback_preserves_sessions_in_both_directions() {
        let (mut hub, eps) = LoopbackHub::new(2);
        hub.broadcast_session(9, &Message::Shutdown).unwrap();
        for ep in &eps {
            let env = ep.recv_envelope().unwrap();
            assert_eq!(env.session, 9);
        }
        eps[0]
            .send_session(3, Message::Upload { client: 1, round: 0, frames: vec![] })
            .unwrap();
        eps[1]
            .send_session(5, Message::Upload { client: 2, round: 0, frames: vec![] })
            .unwrap();
        let mut sessions = vec![
            hub.recv_env().unwrap().session,
            hub.recv_env().unwrap().session,
        ];
        sessions.sort_unstable();
        assert_eq!(sessions, vec![3, 5]);
    }

    #[test]
    fn tcp_hub_surfaces_typed_envelope_errors() {
        // A peer speaking a future wire version must produce a *reported*
        // typed rejection at the hub, not a silent connection kill.
        let binding = TcpHub::bind("127.0.0.1:0").unwrap();
        let addr = binding.local_addr().unwrap();
        let peer = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            let mut bytes = Message::Shutdown.to_bytes().unwrap();
            bytes[2] = WIRE_VERSION + 1; // future version
            s.write_all(&(bytes.len() as u32).to_le_bytes()).unwrap();
            s.write_all(&bytes).unwrap();
            s.flush().unwrap();
            // Hold the socket open so EOF cannot race the parse error.
            s
        });
        let mut hub = binding.accept(1).unwrap();
        let err = hub.recv_env().unwrap_err();
        match err.downcast_ref::<WireError>() {
            Some(WireError::UnknownVersion(v)) => assert_eq!(*v, WIRE_VERSION + 1),
            other => panic!("expected typed UnknownVersion from the hub, got {other:?}"),
        }
        drop(peer.join().unwrap());
    }
}
