//! Transport: moves [`Message`]s between the leader and its workers with
//! exact byte accounting.
//!
//! Two implementations behind [`TransportHub`]:
//!
//! * [`LoopbackHub`] — in-process channels; workers are threads. This is
//!   the default for experiments: zero copies beyond the frames
//!   themselves, deterministic, and every byte is still accounted as if it
//!   had crossed a network.
//! * [`TcpHub`] — a real socket transport (length-prefixed messages over
//!   `std::net::TcpStream`), so workers can run as separate `dme worker`
//!   processes on other machines.
//!
//! Wire format (identical for both transports, little-endian):
//!
//! ```text
//! u8 tag | payload
//! tag 1 RoundStart: u64 round, u32 n_vecs, u32 dim, then n_vecs*dim f32
//! tag 2 Upload:     u64 client, u64 round, u32 n_frames,
//!                   then per frame: u64 bit_len, u32 n_bytes, f32 weight, bytes
//! tag 3 Shutdown
//! ```

use std::io::{BufReader, BufWriter, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Mutex};

use anyhow::{bail, ensure, Context, Result};

use crate::protocol::Frame;

/// A weighted encoded vector (weight matters for weighted averages, e.g.
/// cluster sizes in distributed Lloyd's; 1.0 for plain means).
#[derive(Clone, Debug)]
pub struct WeightedFrame {
    pub frame: Frame,
    pub weight: f32,
}

/// Coordinator messages.
#[derive(Clone, Debug)]
pub enum Message {
    /// Leader → workers: new round with the broadcast state
    /// (`n_vecs` vectors of `dim` f32s, flattened).
    RoundStart { round: u64, dim: u32, payload: Vec<f32> },
    /// Worker → leader: the round's encoded updates. A worker that the
    /// sampling layer silenced still uploads an empty frame list (the
    /// leader needs the barrier).
    Upload { client: u64, round: u64, frames: Vec<WeightedFrame> },
    /// Leader → workers: tear down.
    Shutdown,
}

impl Message {
    /// Serialize to the wire format. Used by the TCP transport and by the
    /// loopback accounting (so both report identical byte counts).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        match self {
            Message::RoundStart { round, dim, payload } => {
                out.push(1u8);
                out.extend_from_slice(&round.to_le_bytes());
                ensure_u32(payload.len() / *dim as usize);
                out.extend_from_slice(&((payload.len() / *dim as usize) as u32).to_le_bytes());
                out.extend_from_slice(&dim.to_le_bytes());
                for v in payload {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
            Message::Upload { client, round, frames } => {
                out.push(2u8);
                out.extend_from_slice(&client.to_le_bytes());
                out.extend_from_slice(&round.to_le_bytes());
                ensure_u32(frames.len());
                out.extend_from_slice(&(frames.len() as u32).to_le_bytes());
                for wf in frames {
                    out.extend_from_slice(&wf.frame.bit_len.to_le_bytes());
                    ensure_u32(wf.frame.bytes.len());
                    out.extend_from_slice(&(wf.frame.bytes.len() as u32).to_le_bytes());
                    out.extend_from_slice(&wf.weight.to_le_bytes());
                    out.extend_from_slice(&wf.frame.bytes);
                }
            }
            Message::Shutdown => out.push(3u8),
        }
        out
    }

    /// Serialized size in bytes without materializing the buffer (the
    /// loopback transport accounts bytes on every send; building the full
    /// serialization just to measure it dominated small-round profiles).
    pub fn wire_len(&self) -> u64 {
        match self {
            Message::RoundStart { payload, .. } => 1 + 8 + 4 + 4 + payload.len() as u64 * 4,
            Message::Upload { frames, .. } => {
                1 + 8
                    + 8
                    + 4
                    + frames
                        .iter()
                        .map(|wf| 8 + 4 + 4 + wf.frame.bytes.len() as u64)
                        .sum::<u64>()
            }
            Message::Shutdown => 1,
        }
    }

    /// Parse from the wire format.
    pub fn from_bytes(buf: &[u8]) -> Result<Self> {
        let mut c = Cursor { buf, pos: 0 };
        let tag = c.u8()?;
        match tag {
            1 => {
                let round = c.u64()?;
                let n_vecs = c.u32()? as usize;
                let dim = c.u32()?;
                let mut payload = Vec::with_capacity(n_vecs * dim as usize);
                for _ in 0..n_vecs * dim as usize {
                    payload.push(c.f32()?);
                }
                c.done()?;
                Ok(Message::RoundStart { round, dim, payload })
            }
            2 => {
                let client = c.u64()?;
                let round = c.u64()?;
                let n = c.u32()? as usize;
                let mut frames = Vec::with_capacity(n);
                for _ in 0..n {
                    let bit_len = c.u64()?;
                    let n_bytes = c.u32()? as usize;
                    let weight = c.f32()?;
                    let bytes = c.take(n_bytes)?.to_vec();
                    ensure!(bit_len <= bytes.len() as u64 * 8, "bit_len exceeds payload");
                    frames.push(WeightedFrame { frame: Frame::new(bytes, bit_len), weight });
                }
                c.done()?;
                Ok(Message::Upload { client, round, frames })
            }
            3 => {
                c.done()?;
                Ok(Message::Shutdown)
            }
            t => bail!("unknown message tag {t}"),
        }
    }
}

fn ensure_u32(v: usize) {
    assert!(v <= u32::MAX as usize, "field too large for wire format");
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(self.pos + n <= self.buf.len(), "message truncated");
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn done(&self) -> Result<()> {
        ensure!(self.pos == self.buf.len(), "trailing bytes in message");
        Ok(())
    }
}

/// Leader-side view of a transport: broadcast to all workers, receive
/// uploads, with cumulative byte accounting.
pub trait TransportHub: Send {
    /// Number of connected workers.
    fn n_workers(&self) -> usize;
    /// Send a message to every worker.
    fn broadcast(&mut self, msg: &Message) -> Result<()>;
    /// Block for the next upload.
    fn recv(&mut self) -> Result<Message>;
    /// Cumulative (downlink, uplink) bytes moved so far.
    fn bytes_moved(&self) -> (u64, u64);
}

// ---------------------------------------------------------------------------
// Loopback
// ---------------------------------------------------------------------------

/// In-process hub: workers are threads holding [`LoopbackEndpoint`]s.
pub struct LoopbackHub {
    to_workers: Vec<Sender<Message>>,
    from_workers: Receiver<Message>,
    down_bytes: u64,
    up_bytes: Arc<Mutex<u64>>,
}

/// Worker-side endpoint of a loopback hub.
pub struct LoopbackEndpoint {
    pub rx: Receiver<Message>,
    tx: Sender<Message>,
    up_bytes: Arc<Mutex<u64>>,
}

impl LoopbackEndpoint {
    pub fn send(&self, msg: Message) -> Result<()> {
        *self.up_bytes.lock().unwrap() += msg.wire_len();
        self.tx.send(msg).context("leader hung up")
    }
    pub fn recv(&self) -> Result<Message> {
        self.rx.recv().context("leader hung up")
    }
}

impl LoopbackHub {
    /// Create a hub with `n` worker endpoints.
    pub fn new(n: usize) -> (Self, Vec<LoopbackEndpoint>) {
        let (up_tx, up_rx) = std::sync::mpsc::channel();
        let up_bytes = Arc::new(Mutex::new(0u64));
        let mut to_workers = Vec::with_capacity(n);
        let mut endpoints = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = std::sync::mpsc::channel();
            to_workers.push(tx);
            endpoints.push(LoopbackEndpoint {
                rx,
                tx: up_tx.clone(),
                up_bytes: up_bytes.clone(),
            });
        }
        (
            LoopbackHub { to_workers, from_workers: up_rx, down_bytes: 0, up_bytes },
            endpoints,
        )
    }
}

impl TransportHub for LoopbackHub {
    fn n_workers(&self) -> usize {
        self.to_workers.len()
    }

    fn broadcast(&mut self, msg: &Message) -> Result<()> {
        // Account the broadcast once per worker (the paper's footnote 4
        // notes broadcast downlink can be cheaper; metrics report both).
        self.down_bytes += msg.wire_len() * self.to_workers.len() as u64;
        for tx in &self.to_workers {
            tx.send(msg.clone()).context("worker hung up")?;
        }
        Ok(())
    }

    fn recv(&mut self) -> Result<Message> {
        self.from_workers.recv().context("all workers hung up")
    }

    fn bytes_moved(&self) -> (u64, u64) {
        (self.down_bytes, *self.up_bytes.lock().unwrap())
    }
}

// ---------------------------------------------------------------------------
// TCP
// ---------------------------------------------------------------------------

fn write_msg(stream: &mut impl Write, msg: &Message) -> Result<u64> {
    let bytes = msg.to_bytes();
    stream.write_all(&(bytes.len() as u32).to_le_bytes())?;
    stream.write_all(&bytes)?;
    stream.flush()?;
    Ok(bytes.len() as u64 + 4)
}

fn read_msg(stream: &mut impl Read) -> Result<(Message, u64)> {
    let mut len_buf = [0u8; 4];
    stream.read_exact(&mut len_buf)?;
    let len = u32::from_le_bytes(len_buf) as usize;
    ensure!(len <= 1 << 30, "message too large");
    let mut buf = vec![0u8; len];
    stream.read_exact(&mut buf)?;
    Ok((Message::from_bytes(&buf)?, len as u64 + 4))
}

/// TCP hub: listens, accepts `n` workers, then serves rounds.
pub struct TcpHub {
    writers: Vec<BufWriter<TcpStream>>,
    from_workers: Receiver<Result<Message>>,
    reader_threads: Vec<std::thread::JoinHandle<()>>,
    down_bytes: u64,
    up_bytes: Arc<Mutex<u64>>,
}

impl TcpHub {
    /// Bind `addr` and accept exactly `n` worker connections.
    pub fn listen(addr: &str, n: usize) -> Result<Self> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let (tx, rx) = std::sync::mpsc::channel();
        let up_bytes = Arc::new(Mutex::new(0u64));
        let mut writers = Vec::with_capacity(n);
        let mut reader_threads = Vec::with_capacity(n);
        for i in 0..n {
            let (stream, peer) = listener.accept().context("accepting worker")?;
            stream.set_nodelay(true).ok();
            let reader = stream.try_clone().context("cloning stream")?;
            writers.push(BufWriter::new(stream));
            let tx = tx.clone();
            let up = up_bytes.clone();
            reader_threads.push(
                std::thread::Builder::new()
                    .name(format!("dme-tcp-reader-{i}"))
                    .spawn(move || {
                        let mut r = BufReader::new(reader);
                        loop {
                            match read_msg(&mut r) {
                                Ok((msg, n)) => {
                                    *up.lock().unwrap() += n;
                                    if tx.send(Ok(msg)).is_err() {
                                        return;
                                    }
                                }
                                Err(_) => return, // peer closed
                            }
                        }
                    })
                    .with_context(|| format!("spawning reader for {peer}"))?,
            );
        }
        Ok(TcpHub { writers, from_workers: rx, reader_threads, down_bytes: 0, up_bytes })
    }
}

impl Drop for TcpHub {
    fn drop(&mut self) {
        let _ = self.broadcast(&Message::Shutdown);
        self.writers.clear(); // close sockets so readers exit
        for t in self.reader_threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl TransportHub for TcpHub {
    fn n_workers(&self) -> usize {
        self.writers.len()
    }

    fn broadcast(&mut self, msg: &Message) -> Result<()> {
        for w in &mut self.writers {
            self.down_bytes += write_msg(w, msg)?;
        }
        Ok(())
    }

    fn recv(&mut self) -> Result<Message> {
        self.from_workers.recv().context("all workers disconnected")?
    }

    fn bytes_moved(&self) -> (u64, u64) {
        (self.down_bytes, *self.up_bytes.lock().unwrap())
    }
}

/// Worker-side TCP endpoint (used by the `dme worker` process).
pub struct TcpEndpoint {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl TcpEndpoint {
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        stream.set_nodelay(true).ok();
        let reader = BufReader::new(stream.try_clone()?);
        Ok(TcpEndpoint { reader, writer: BufWriter::new(stream) })
    }

    pub fn send(&mut self, msg: &Message) -> Result<()> {
        write_msg(&mut self.writer, msg)?;
        Ok(())
    }

    pub fn recv(&mut self) -> Result<Message> {
        Ok(read_msg(&mut self.reader)?.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame(bytes: Vec<u8>, bits: u64) -> WeightedFrame {
        WeightedFrame { frame: Frame::new(bytes, bits), weight: 2.5 }
    }

    #[test]
    fn message_roundtrip_all_variants() {
        let msgs = vec![
            Message::RoundStart { round: 7, dim: 2, payload: vec![1.0, -2.0, 3.5, 0.0] },
            Message::Upload {
                client: 3,
                round: 7,
                frames: vec![frame(vec![0xab, 0xcd], 12), frame(vec![], 0)],
            },
            Message::Upload { client: 0, round: 0, frames: vec![] },
            Message::Shutdown,
        ];
        for m in msgs {
            let bytes = m.to_bytes();
            let back = Message::from_bytes(&bytes).unwrap();
            match (&m, &back) {
                (
                    Message::RoundStart { round: r1, dim: d1, payload: p1 },
                    Message::RoundStart { round: r2, dim: d2, payload: p2 },
                ) => {
                    assert_eq!((r1, d1, p1), (r2, d2, p2));
                }
                (
                    Message::Upload { client: c1, round: r1, frames: f1 },
                    Message::Upload { client: c2, round: r2, frames: f2 },
                ) => {
                    assert_eq!((c1, r1), (c2, r2));
                    assert_eq!(f1.len(), f2.len());
                    for (a, b) in f1.iter().zip(f2) {
                        assert_eq!(a.frame.bytes, b.frame.bytes);
                        assert_eq!(a.frame.bit_len, b.frame.bit_len);
                        assert_eq!(a.weight, b.weight);
                    }
                }
                (Message::Shutdown, Message::Shutdown) => {}
                _ => panic!("variant mismatch"),
            }
        }
    }

    #[test]
    fn wire_len_matches_serialization() {
        let msgs = vec![
            Message::RoundStart { round: 7, dim: 3, payload: vec![1.0; 9] },
            Message::Upload {
                client: 3,
                round: 7,
                frames: vec![frame(vec![0xab; 17], 130), frame(vec![], 0)],
            },
            Message::Upload { client: 0, round: 0, frames: vec![] },
            Message::Shutdown,
        ];
        for m in msgs {
            assert_eq!(m.wire_len(), m.to_bytes().len() as u64);
        }
    }

    #[test]
    fn malformed_messages_rejected() {
        assert!(Message::from_bytes(&[]).is_err());
        assert!(Message::from_bytes(&[9]).is_err());
        assert!(Message::from_bytes(&[1, 0]).is_err()); // truncated
        // trailing garbage
        let mut ok = Message::Shutdown.to_bytes();
        ok.push(0);
        assert!(Message::from_bytes(&ok).is_err());
        // bit_len > bytes
        let bad = Message::Upload {
            client: 0,
            round: 0,
            frames: vec![WeightedFrame {
                frame: Frame { bytes: vec![1], bit_len: 9 },
                weight: 1.0,
            }],
        };
        assert!(Message::from_bytes(&bad.to_bytes()).is_err());
    }

    #[test]
    fn loopback_accounts_bytes_exactly() {
        let (mut hub, eps) = LoopbackHub::new(3);
        let msg = Message::RoundStart { round: 0, dim: 4, payload: vec![0.0; 4] };
        let msg_len = msg.to_bytes().len() as u64;
        hub.broadcast(&msg).unwrap();
        for ep in &eps {
            let got = ep.recv().unwrap();
            matches!(got, Message::RoundStart { .. });
        }
        let up_msg = Message::Upload { client: 1, round: 0, frames: vec![] };
        let up_len = up_msg.to_bytes().len() as u64;
        eps[1].send(up_msg).unwrap();
        hub.recv().unwrap();
        let (down, up) = hub.bytes_moved();
        assert_eq!(down, msg_len * 3);
        assert_eq!(up, up_len);
    }

    #[test]
    fn tcp_hub_round_trip() {
        let hub_thread = std::thread::spawn(|| {
            let mut hub = TcpHub::listen("127.0.0.1:47231", 2).unwrap();
            hub.broadcast(&Message::RoundStart { round: 1, dim: 1, payload: vec![9.0] })
                .unwrap();
            let mut clients = Vec::new();
            for _ in 0..2 {
                if let Message::Upload { client, .. } = hub.recv().unwrap() {
                    clients.push(client);
                }
            }
            clients.sort_unstable();
            hub.broadcast(&Message::Shutdown).unwrap();
            (clients, hub.bytes_moved())
        });
        std::thread::sleep(std::time::Duration::from_millis(100));
        let mut workers = Vec::new();
        for id in 0..2u64 {
            workers.push(std::thread::spawn(move || {
                let mut ep = TcpEndpoint::connect("127.0.0.1:47231").unwrap();
                match ep.recv().unwrap() {
                    Message::RoundStart { round, payload, .. } => {
                        assert_eq!(round, 1);
                        assert_eq!(payload, vec![9.0]);
                    }
                    _ => panic!("expected RoundStart"),
                }
                ep.send(&Message::Upload {
                    client: id,
                    round: 1,
                    frames: vec![frame(vec![id as u8; 3], 20)],
                })
                .unwrap();
                matches!(ep.recv().unwrap(), Message::Shutdown);
            }));
        }
        let (clients, (down, up)) = hub_thread.join().unwrap();
        for w in workers {
            w.join().unwrap();
        }
        assert_eq!(clients, vec![0, 1]);
        assert!(down > 0 && up > 0);
    }
}
