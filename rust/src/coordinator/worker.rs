//! The worker (client) side of the coordinator: holds a data shard,
//! computes a local update from each broadcast state, and uploads the
//! protocol-encoded frames.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::transport::{
    Endpoint, LoopbackEndpoint, Message, WeightedFrame, WireError, ROOT_SESSION,
};
use crate::protocol::config::ProtocolConfig;
use crate::protocol::{EncodeScratch, Frame, Protocol, RoundCtx};
use crate::rng;

/// The application hook: given the broadcast state (`n_vecs × dim`,
/// flattened) and the worker's local shard, produce the update vectors to
/// transmit, each with a weight (e.g. cluster sizes in Lloyd's; 1.0 for
/// plain mean estimation).
pub type UpdateFn =
    Arc<dyn Fn(&[f32], u32, &[Vec<f32>]) -> Vec<(Vec<f32>, f32)> + Send + Sync>;

/// A worker: one simulated client.
pub struct Worker {
    pub client_id: u64,
    pub shard: Vec<Vec<f32>>,
    pub protocol: Arc<dyn Protocol>,
    pub update: UpdateFn,
    /// Experiment seed (must match the leader's so public randomness —
    /// the rotation — agrees).
    pub seed: u64,
}

impl Worker {
    /// Compute and encode this round's upload on the root session.
    /// Errors if the client id cannot be combined with a slot index into
    /// a collision-free private-stream id (see
    /// [`rng::client_slot_stream_id`]).
    pub fn step(&self, round: u64, dim: u32, broadcast: &[f32]) -> Result<Message> {
        self.step_with(round, dim, broadcast, &mut EncodeScratch::default())
    }

    /// [`Worker::step`] with caller-owned encode scratch. The worker
    /// loop ([`Worker::run`]) keeps one [`EncodeScratch`] alive for its
    /// whole lifetime, so the rotation workspace, rounding uniforms and
    /// bin buffers are allocated once per worker — not once per round.
    /// (Frames still allocate: they are moved into the upload message.)
    pub fn step_with(
        &self,
        round: u64,
        dim: u32,
        broadcast: &[f32],
        scratch: &mut EncodeScratch,
    ) -> Result<Message> {
        self.step_for(ROOT_SESSION, round, dim, broadcast, scratch)
    }

    /// [`Worker::step_with`] on an explicit session: the session id joins
    /// the private-stream derivation, so the same client and slot encode
    /// with *different* rounding noise under different tenants — and with
    /// *identical* noise whenever the session id matches, which is what
    /// makes a muxed tenant bit-identical to its solo run.
    pub fn step_for(
        &self,
        session: u16,
        round: u64,
        dim: u32,
        broadcast: &[f32],
        scratch: &mut EncodeScratch,
    ) -> Result<Message> {
        self.step_seeded(session, round, self.seed, dim, broadcast, scratch)
    }

    /// [`Worker::step_for`] with an explicit round seed — the wire
    /// handshake entry point. The worker loops pass the `shared_seed`
    /// carried in `RoundStart`, so the round's public randomness (the
    /// rotation, and the correlated rounding offsets of
    /// [`crate::protocol::correlated`]) is rooted in what the leader
    /// *broadcast*, not in local configuration: a whole tree agrees on
    /// the round's shared state by construction, and a worker with a
    /// stale `seed` field cannot silently desynchronize the rotation.
    pub fn step_seeded(
        &self,
        session: u16,
        round: u64,
        shared_seed: u64,
        dim: u32,
        broadcast: &[f32],
        scratch: &mut EncodeScratch,
    ) -> Result<Message> {
        let ctx = RoundCtx::new(round, shared_seed);
        // One round session per step: the shared state (the rotation for
        // π_srk) is prepared once and reused across every slot, and the
        // scratch buffers are reused across slots (and rounds).
        let state = self.protocol.prepare(&ctx);
        let updates = (self.update)(broadcast, dim, &self.shard);
        let mut frames = Vec::with_capacity(updates.len());
        for (slot, (vec, weight)) in updates.into_iter().enumerate() {
            debug_assert_eq!(vec.len(), self.protocol.dim(), "update has wrong dim");
            // Each slot (e.g. cluster index) gets its own private stream
            // so rounding noise is independent across slots. The packing
            // is checked: an out-of-range client id is an explicit error,
            // never a silent merge of two clients' randomness streams.
            let stream_id = rng::client_slot_stream_id(session, self.client_id, slot as u64)?;
            let mut frame = Frame::empty();
            if self.protocol.encode_with(&state, scratch, stream_id, &vec, &mut frame) {
                frames.push(WeightedFrame { frame, weight });
            } else {
                // Sampling silenced this slot: an empty frame keeps slot
                // alignment (weight 0 contributes nothing server-side).
                frames.push(WeightedFrame { frame: Frame::new(Vec::new(), 0), weight: 0.0 });
            }
        }
        Ok(Message::Upload { client: self.client_id, round, frames })
    }

    /// Rebuild the protocol handle from a `SpecChange` spec string at
    /// the same data dimension. The rebuild is total — no state crosses
    /// the switch — so subsequent rounds are bit-identical to a fresh
    /// session started at `spec` (the tag-5 conformance contract).
    /// Rebuilds land on the native backend: the spec string is the
    /// protocol's identity, and a backend is an execution engine the
    /// wire cannot (and need not) carry.
    pub fn apply_spec(&mut self, spec: &str) -> Result<()> {
        let dim = self.protocol.dim();
        self.protocol = ProtocolConfig::parse(spec, dim)
            .and_then(|cfg| cfg.build())
            .with_context(|| format!("worker {} rebuilding protocol `{spec}`", self.client_id))?;
        Ok(())
    }

    /// Run the worker loop over any endpoint until Shutdown: the one
    /// loop both transports (and both parents — leader or aggregator)
    /// share. Session-transparent: every reply goes out on the session
    /// the request arrived on, and that session feeds the private-stream
    /// derivation — so the same worker serves a solo leader and a muxed
    /// one identically.
    pub fn run(&mut self, ep: &mut dyn Endpoint) -> Result<()> {
        // One encode scratch for the worker's lifetime; encoders resize
        // it per call, so it survives SpecChange rebuilds unchanged.
        let mut scratch = EncodeScratch::default();
        loop {
            let env = ep.recv_env()?;
            let session = env.session;
            match env.msg {
                Message::RoundStart { round, shared_seed, dim, payload } => {
                    match self.step_seeded(session, round, shared_seed, dim, &payload, &mut scratch)
                    {
                        Ok(reply) => ep.send_env(session, reply)?,
                        Err(e) => {
                            // Wake the parent's barrier before dying: an
                            // unexpected Shutdown from a worker makes the
                            // parent error out instead of waiting forever
                            // for an upload that will never come. (Over
                            // TCP this matters even more: a lone dead
                            // worker does not close the parent's upload
                            // channel — other readers keep it open.)
                            let _ = ep.send_env(session, Message::Shutdown);
                            return Err(e);
                        }
                    }
                }
                Message::SpecChange { spec, .. } => {
                    // Applied on receipt: the transport is FIFO, so this
                    // lands before the first RoundStart it governs. No
                    // reply — the parent is not at a barrier.
                    if let Err(e) = self.apply_spec(&spec) {
                        // Same dying courtesy as a failed step: wake the
                        // parent's next barrier instead of hanging it.
                        let _ = ep.send_env(session, Message::Shutdown);
                        return Err(e);
                    }
                }
                Message::Shutdown => return Ok(()),
                Message::Upload { .. } | Message::PartialUpload { .. } => {
                    bail!("worker received an upstream-only message")
                }
            }
        }
    }

    /// Run the worker loop over a loopback endpoint until Shutdown.
    pub fn run_loopback(mut self, ep: LoopbackEndpoint) -> Result<()> {
        let mut ep = ep;
        self.run(&mut ep)
    }

    /// Run the worker loop over TCP (the `dme worker` subcommand),
    /// connecting immediately (no retries).
    pub fn run_tcp(self, addr: &str) -> Result<()> {
        self.run_tcp_with_retries(addr, 0)
    }

    /// Run the worker loop over TCP, retrying the initial connect with
    /// capped exponential backoff — so a worker launched moments before
    /// its leader listens waits instead of dying with a refusal.
    pub fn run_tcp_with_retries(mut self, addr: &str, retries: u32) -> Result<()> {
        let mut ep = super::transport::TcpEndpoint::connect_with_backoff(addr, retries)?;
        self.run(&mut ep)
    }
}

/// A multi-tenant worker: one endpoint (one socket, one thread), many
/// per-session [`Worker`] states. Each tenant session owns its protocol
/// handle, shard, and update hook, so a `SpecChange` addressed to tenant
/// A rebuilds only A's protocol — tenant B's encoding is untouched (the
/// isolation the multi-tenant conformance tests pin bit-identically).
pub struct MuxWorker {
    sessions: std::collections::HashMap<u16, Worker>,
}

impl MuxWorker {
    /// An empty mux; add tenants with [`Self::insert`].
    pub fn new() -> Self {
        MuxWorker { sessions: std::collections::HashMap::new() }
    }

    /// Host `worker` on `session`. Replaces any previous tenant there.
    pub fn insert(&mut self, session: u16, worker: Worker) {
        self.sessions.insert(session, worker);
    }

    /// Run until every hosted session has been shut down. A message
    /// addressed to a session this worker does not host is a typed
    /// [`WireError::UnknownSession`] — the router contract: never
    /// silently dropped, never misattributed to another tenant.
    /// `Shutdown` is per-session: it retires that tenant, and the loop
    /// ends when the last one is gone.
    pub fn run(&mut self, ep: &mut dyn Endpoint) -> Result<()> {
        let mut scratch = EncodeScratch::default();
        while !self.sessions.is_empty() {
            let env = ep.recv_env()?;
            let session = env.session;
            if matches!(env.msg, Message::Shutdown) {
                self.sessions.remove(&session);
                continue;
            }
            let worker = match self.sessions.get_mut(&session) {
                Some(w) => w,
                None => return Err(WireError::UnknownSession(session).into()),
            };
            match env.msg {
                Message::RoundStart { round, shared_seed, dim, payload } => {
                    match worker
                        .step_seeded(session, round, shared_seed, dim, &payload, &mut scratch)
                    {
                        Ok(reply) => ep.send_env(session, reply)?,
                        Err(e) => {
                            let _ = ep.send_env(session, Message::Shutdown);
                            return Err(e);
                        }
                    }
                }
                Message::SpecChange { spec, .. } => {
                    if let Err(e) = worker.apply_spec(&spec) {
                        let _ = ep.send_env(session, Message::Shutdown);
                        return Err(e);
                    }
                }
                Message::Shutdown => unreachable!("handled above"),
                Message::Upload { .. } | Message::PartialUpload { .. } => {
                    bail!("worker received an upstream-only message")
                }
            }
        }
        Ok(())
    }

    /// Run over a loopback endpoint until every session shuts down.
    pub fn run_loopback(mut self, ep: LoopbackEndpoint) -> Result<()> {
        let mut ep = ep;
        self.run(&mut ep)
    }
}

impl Default for MuxWorker {
    fn default() -> Self {
        Self::new()
    }
}

/// The identity update: ignore the broadcast and transmit the shard mean
/// (plain distributed mean estimation of per-client vectors).
pub fn mean_update() -> UpdateFn {
    Arc::new(|_broadcast, _dim, shard| {
        if shard.is_empty() {
            return Vec::new();
        }
        let refs: Vec<&[f32]> = shard.iter().map(|v| v.as_slice()).collect();
        vec![(crate::linalg::mean_of(&refs), 1.0)]
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::config::ProtocolConfig;

    #[test]
    fn step_produces_one_frame_per_update() {
        let proto = ProtocolConfig::parse("klevel:k=4", 8).unwrap().build().unwrap();
        let w = Worker {
            client_id: 3,
            shard: vec![vec![1.0; 8], vec![3.0; 8]],
            protocol: proto,
            update: mean_update(),
            seed: 1,
        };
        match w.step(0, 8, &[]).unwrap() {
            Message::Upload { client, round, frames } => {
                assert_eq!(client, 3);
                assert_eq!(round, 0);
                assert_eq!(frames.len(), 1);
                assert!(frames[0].frame.bit_len > 0);
                assert_eq!(frames[0].weight, 1.0);
            }
            _ => panic!("expected Upload"),
        }
    }

    #[test]
    fn step_with_reused_scratch_is_bit_identical() {
        // The worker loop reuses one scratch across rounds (and spec
        // changes); its uploads must match a fresh-scratch step exactly.
        let proto = ProtocolConfig::parse("rotated:k=4", 8).unwrap().build().unwrap();
        let w = Worker {
            client_id: 2,
            shard: vec![vec![0.3; 8], vec![1.7; 8]],
            protocol: proto,
            update: mean_update(),
            seed: 9,
        };
        let mut scratch = EncodeScratch::default();
        for round in 0..3 {
            let fresh = w.step(round, 8, &[]).unwrap();
            let reused = w.step_with(round, 8, &[], &mut scratch).unwrap();
            match (fresh, reused) {
                (Message::Upload { frames: a, .. }, Message::Upload { frames: b, .. }) => {
                    assert_eq!(a.len(), b.len());
                    for (fa, fb) in a.iter().zip(&b) {
                        assert_eq!(fa.frame.bytes, fb.frame.bytes, "round {round}");
                        assert_eq!(fa.frame.bit_len, fb.frame.bit_len, "round {round}");
                    }
                }
                _ => panic!("expected Upload"),
            }
        }
    }

    #[test]
    fn out_of_range_client_id_errors_instead_of_aliasing() {
        // client_id = 2^40 used to silently collide with (client 0,
        // slot 1) in the stream-id packing, merging private randomness
        // across clients; it must now be an explicit error.
        let proto = ProtocolConfig::parse("klevel:k=4", 8).unwrap().build().unwrap();
        let w = Worker {
            client_id: 1 << 40,
            shard: vec![vec![1.0; 8]],
            protocol: proto,
            update: mean_update(),
            seed: 1,
        };
        assert!(w.step(0, 8, &[]).is_err());
    }

    #[test]
    fn sessions_use_distinct_private_streams() {
        // The same client, slot, round, and vector must encode with
        // different rounding noise under different tenant sessions — and
        // identically when the session matches (solo-vs-mux identity).
        let proto = ProtocolConfig::parse("klevel:k=4", 8).unwrap().build().unwrap();
        let update: UpdateFn = Arc::new(|_, _, _| {
            let v: Vec<f32> = (0..8).map(|i| i as f32 * 0.23).collect();
            vec![(v, 1.0)]
        });
        let w = Worker { client_id: 6, shard: vec![], protocol: proto, update, seed: 5 };
        let bytes_of = |session: u16| {
            let mut scratch = EncodeScratch::default();
            match w.step_for(session, 0, 8, &[], &mut scratch).unwrap() {
                Message::Upload { frames, .. } => frames[0].frame.bytes.clone(),
                _ => panic!("expected Upload"),
            }
        };
        assert_eq!(bytes_of(1), bytes_of(1), "same session must reproduce bits");
        assert_ne!(bytes_of(1), bytes_of(2), "tenants must not share rounding noise");
        // The root session is what the session-less step() aliases.
        assert_eq!(bytes_of(ROOT_SESSION), match w.step(0, 8, &[]).unwrap() {
            Message::Upload { frames, .. } => frames[0].frame.bytes.clone(),
            _ => panic!("expected Upload"),
        });
    }

    #[test]
    fn empty_shard_uploads_nothing() {
        let proto = ProtocolConfig::parse("binary", 4).unwrap().build().unwrap();
        let w = Worker {
            client_id: 0,
            shard: vec![],
            protocol: proto,
            update: mean_update(),
            seed: 1,
        };
        match w.step(0, 4, &[]).unwrap() {
            Message::Upload { frames, .. } => assert!(frames.is_empty()),
            _ => panic!("expected Upload"),
        }
    }

    #[test]
    fn slots_use_distinct_private_streams() {
        // Two identical update vectors in different slots must encode with
        // different rounding noise.
        let proto = ProtocolConfig::parse("klevel:k=4", 8).unwrap().build().unwrap();
        let update: UpdateFn = Arc::new(|_, _, _| {
            vec![(vec![0.3; 8], 1.0), (vec![0.3; 8], 1.0)]
        });
        let w =
            Worker { client_id: 1, shard: vec![vec![0.0; 8]], protocol: proto, update, seed: 5 };
        match w.step(0, 8, &[]).unwrap() {
            Message::Upload { frames, .. } => {
                assert_eq!(frames.len(), 2);
                // constant vectors quantize exactly -> frames equal; use a
                // non-constant vector instead for the real assertion below
            }
            _ => panic!(),
        }
        let proto2 = ProtocolConfig::parse("klevel:k=4", 8).unwrap().build().unwrap();
        let update2: UpdateFn = Arc::new(|_, _, _| {
            let v: Vec<f32> = (0..8).map(|i| i as f32 * 0.17).collect();
            vec![(v.clone(), 1.0), (v, 1.0)]
        });
        let w2 = Worker { client_id: 1, shard: vec![], protocol: proto2, update: update2, seed: 5 };
        match w2.step(0, 8, &[]).unwrap() {
            Message::Upload { frames, .. } => {
                assert_ne!(frames[0].frame.bytes, frames[1].frame.bytes);
            }
            _ => panic!(),
        }
    }
}
