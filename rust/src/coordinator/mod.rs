//! The leader/worker coordinator: the deployment shell around the
//! protocols.
//!
//! The paper's communication model is synchronous and round-based: the
//! leader broadcasts the current model state (cluster centers, eigenvector
//! estimate, …), every worker computes a local update from its data shard,
//! encodes it with the configured [`Protocol`](crate::protocol::Protocol),
//! and uploads the frame; the leader decodes, aggregates, and advances to
//! the next round.
//!
//! * [`transport`] — the wire: an in-process loopback with exact byte
//!   accounting, and a TCP transport for running workers as separate
//!   processes. One message format for both.
//! * [`worker`] — the client side: shard + update function + encoder.
//! * [`leader`] — the server side: round barrier, decode, aggregate.
//! * [`metrics`] — per-round and cumulative communication/latency metrics.
//!
//! Threading: plain `std::thread` + channels. The round barrier is the
//! natural synchronization point of the paper's model (all clients answer
//! every round — or stay silent under sampling, which the protocol layer
//! decides); an async runtime would buy nothing here.

pub mod leader;
pub mod metrics;
pub mod transport;
pub mod worker;

pub use leader::{Leader, RoundOutcome};
pub use metrics::{ExperimentMetrics, RoundMetrics};
pub use transport::{LoopbackHub, Message, TcpHub, TransportHub};
pub use worker::{UpdateFn, Worker};
