//! The leader/worker coordinator: the deployment shell around the
//! protocols, now with an optional **hierarchical aggregation tier**.
//!
//! The paper's communication model is synchronous and round-based: the
//! leader broadcasts the current model state (cluster centers, eigenvector
//! estimate, …), every worker computes a local update from its data shard,
//! encodes it with the configured [`Protocol`](crate::protocol::Protocol),
//! and uploads the frame; the leader decodes, aggregates, and advances to
//! the next round.
//!
//! # The tier model
//!
//! Because the paper's estimators are linear in the client frames, the
//! per-slot decoded partials can be merged anywhere — not only at the
//! leader. A [`Topology`](topology::Topology) arranges workers →
//! aggregators → leader in an arbitrary-depth tree of contiguous client
//! spans. Each [`Aggregator`](aggregator::Aggregator) runs the same
//! streaming barrier + decode pool as the leader over its own children,
//! folds the results into one exactly-mergeable
//! [`SlotPartial`](crate::protocol::SlotPartial) per slot, and forwards a
//! single `PartialUpload` for its whole span; the leader absorbs worker
//! uploads and partial uploads interchangeably. The per-slot fold is an
//! exact fixed-point sum (`protocol::exact`), so the root estimate is
//! **bit-identical to the flat topology for every tree shape, fan-in,
//! arrival order, and decode-thread count** — the tier is purely a
//! scaling lever, shrinking root ingest from O(n · frames) to
//! O(root-fan-in · slots).
//!
//! # Modules
//!
//! * [`transport`] — the wire: an in-process loopback with exact byte
//!   accounting, and a TCP transport for running workers/aggregators as
//!   separate processes. One message format for both, one `framed_len`
//!   accounting rule for both (so loopback and TCP report identical
//!   `bytes_moved`), `Arc`-shared broadcast payloads, and the
//!   [`Endpoint`](transport::Endpoint) abstraction every child node
//!   (worker or aggregator) speaks to its parent through. The
//!   [`transport::Transport`] enum picks which TCP hub serves a
//!   process: thread-per-connection, or the epoll reactor.
//! * [`reactor`] (Linux) — the event-driven TCP hub: one thread, n
//!   non-blocking sockets, per-connection staging queues flushed once
//!   per readiness wakeup, zero-copy broadcast. The hub that makes
//!   n = 100k participants per aggregator a transport non-event.
//! * [`swarm`] (Linux) — synthetic client driver for benches and soak
//!   tests: thousands of protocol-correct TCP clients multiplexed on
//!   one thread, so scale tests measure the hub rather than the
//!   harness.
//! * [`session`] — session multiplexing: a [`session::SessionMux`]
//!   splits one hub into per-tenant [`TransportHub`] views, demuxing
//!   upstream envelopes by session id with per-tenant byte accounting —
//!   the piece that lets several concurrent sessions (different specs,
//!   different rate budgets) share one transport and one tree.
//! * [`worker`] — the client side: shard + update function + encoder,
//!   plus the multi-tenant [`worker::MuxWorker`] hosting one `Worker`
//!   per session over a single endpoint.
//! * [`leader`] — the tree root: round barrier (optionally with a
//!   liveness timeout that names missing children) + the streaming
//!   decode pipeline, with
//!   [`leader::aggregate_uploads_reference`] retained as the flat
//!   sequential specification every aggregation path must reproduce
//!   bit for bit.
//! * [`aggregator`] — the aggregation-tier node, the in-process tree
//!   spawner ([`aggregator::spawn_local_tree`]), and the transportless
//!   tree simulator ([`aggregator::aggregate_tree`]) benches and
//!   conformance tests drive.
//! * [`topology`] — tree descriptors ([`topology::Topology::uniform`])
//!   and their structural invariants.
//! * [`metrics`] — per-round and cumulative communication/latency
//!   metrics, including the barrier-wait vs decode-work split and the
//!   per-tier rollup ([`metrics::TierMetrics`]).
//!
//! Threading: plain `std::thread` + channels for the protocol logic —
//! the round barrier is the natural synchronization point of the
//! paper's model, and an async *runtime* would buy nothing here. The
//! one place concurrency itself was the scaling limit is connection
//! handling, and that is event-driven instead: the [`reactor`] hub
//! serves every socket from a single thread, so thread count follows
//! decode parallelism, never client count. Every barrier node (leader
//! or aggregator) owns a per-round set of scoped decode threads fed by
//! its receive loop — at millions-of-users scale the server's decode
//! path, not the clients' encode path, is the bottleneck, and the tier
//! spreads that work across as many nodes as the topology provides
//! without touching the determinism contract.

pub mod aggregator;
pub mod leader;
pub mod metrics;
#[cfg(target_os = "linux")]
pub mod reactor;
pub mod session;
#[cfg(target_os = "linux")]
pub mod swarm;
pub mod topology;
pub mod transport;
pub mod worker;

pub use aggregator::{
    aggregate_tree, spawn_local_tree, spawn_mux_tree, Aggregator, AggregatorReport,
};
pub use leader::{BarrierPolicy, ChildKey, Leader, RoundOutcome};
pub use metrics::{ExperimentMetrics, RoundMetrics, TenantMetrics, TierMetrics};
#[cfg(target_os = "linux")]
pub use reactor::ReactorHub;
pub use session::{SessionHubView, SessionMux};
pub use topology::Topology;
pub use transport::{
    Endpoint, Envelope, HubBinding, LoopbackHub, Message, TcpEndpoint, TcpHub, Transport,
    TransportHub, WireError, ROOT_SESSION,
};
pub use worker::{MuxWorker, UpdateFn, Worker};
