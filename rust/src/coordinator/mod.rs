//! The leader/worker coordinator: the deployment shell around the
//! protocols.
//!
//! The paper's communication model is synchronous and round-based: the
//! leader broadcasts the current model state (cluster centers, eigenvector
//! estimate, …), every worker computes a local update from its data shard,
//! encodes it with the configured [`Protocol`](crate::protocol::Protocol),
//! and uploads the frame; the leader decodes, aggregates, and advances to
//! the next round.
//!
//! * [`transport`] — the wire: an in-process loopback with exact byte
//!   accounting, and a TCP transport for running workers as separate
//!   processes. One message format for both, one `framed_len` accounting
//!   rule for both (so loopback and TCP report identical `bytes_moved`),
//!   and `Arc`-shared broadcast payloads so fan-out never clones the
//!   model state per worker.
//! * [`worker`] — the client side: shard + update function + encoder.
//! * [`leader`] — the server side: round barrier + the streaming decode
//!   pipeline. Uploads are decoded the moment they arrive, on a decode
//!   pool that overlaps the barrier wait; the per-slot partials are then
//!   merged in client-id order, so the outcome is bit-identical for any
//!   arrival order and any decode-thread count (see
//!   [`leader::aggregate_uploads_reference`], the retained sequential
//!   specification).
//! * [`metrics`] — per-round and cumulative communication/latency
//!   metrics, including the barrier-wait vs decode-work split.
//!
//! Threading: plain `std::thread` + channels. The round barrier is the
//! natural synchronization point of the paper's model (all clients answer
//! every round — or stay silent under sampling, which the protocol layer
//! decides); an async runtime would buy nothing here. The leader's decode
//! pool is a per-round set of scoped threads fed by the receive loop —
//! at millions-of-users scale the server's decode path, not the clients'
//! encode path, is the bottleneck, and it parallelizes without touching
//! the determinism contract.

pub mod leader;
pub mod metrics;
pub mod transport;
pub mod worker;

pub use leader::{Leader, RoundOutcome};
pub use metrics::{ExperimentMetrics, RoundMetrics};
pub use transport::{LoopbackHub, Message, TcpHub, TransportHub};
pub use worker::{UpdateFn, Worker};
