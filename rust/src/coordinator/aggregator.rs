//! The aggregation-tier node: a partial-merging relay between workers
//! and the leader (or between tiers of itself).
//!
//! An [`Aggregator`] owns a [`TransportHub`] over its children (workers
//! or lower-tier aggregators — the same hubs the leader uses) and an
//! upstream [`Endpoint`] to its parent. Per round it:
//!
//! 1. relays the parent's `RoundStart` downstream (the broadcast payload
//!    stays `Arc`-shared over loopback),
//! 2. runs the same streaming barrier + decode pool as the leader
//!    (`collect_round`): worker uploads decode on the pool, child
//!    `PartialUpload`s are absorbed directly,
//! 3. folds everything into one exactly-mergeable `SlotPartial` per slot
//!    (`fold_spans`) and forwards a single `PartialUpload` for its whole
//!    client span.
//!
//! Because the fold is exact (see `protocol::exact`), the root estimate
//! is **bit-identical to the flat leader for every tree shape** — the
//! tier is purely a throughput/deployment lever: root ingest drops from
//! O(n · frames) to O(root-fan-in · slots), and decode work spreads
//! across the tier (`tests/tree_aggregation.rs` is the conformance
//! suite).
//!
//! [`spawn_local_tree`] wires a whole tree of loopback hubs in one
//! process (the `dme serve --fanout` path); [`aggregate_tree`] is the
//! transportless simulator used by benches and conformance tests —
//! every hop still passes through the real `PartialUpload` wire
//! serialization.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use super::leader::{
    collect_round, decode_all, fold_spans, merge_decoded, BarrierTimeout, ChildKey,
    DecodedUpload, Leader, RoundOutcome,
};
use super::metrics::{ExperimentMetrics, RoundMetrics, TierMetrics};
use super::topology::{Child, Topology};
use super::transport::{Endpoint, LoopbackHub, Message, TransportHub, WeightedFrame};
use crate::protocol::{Protocol, RoundCtx};

/// A partial-merging aggregation node.
pub struct Aggregator {
    protocol: Arc<dyn Protocol>,
    /// Experiment seed — must match the leader's and the workers' so the
    /// round's public randomness (e.g. the π_srk rotation) agrees.
    seed: u64,
    agg_id: u64,
    span: (u64, u64),
    /// Topology level (0 = directly above the workers); only used to
    /// attribute metrics to a tier.
    level: usize,
    decode_threads: usize,
    round_timeout: Option<Duration>,
}

/// What an aggregator hands back when its tree shuts down: per-round
/// metrics plus its hub's cumulative byte accounting.
#[derive(Clone, Debug)]
pub struct AggregatorReport {
    pub agg_id: u64,
    pub level: usize,
    pub span: (u64, u64),
    pub metrics: ExperimentMetrics,
    /// Bytes this node sent down to its children.
    pub down_bytes: u64,
    /// Bytes this node ingested from its children.
    pub up_bytes: u64,
}

impl Aggregator {
    pub fn new(protocol: Arc<dyn Protocol>, seed: u64, agg_id: u64, span: (u64, u64)) -> Self {
        Aggregator {
            protocol,
            seed,
            agg_id,
            span,
            level: 0,
            decode_threads: 1,
            round_timeout: None,
        }
    }

    /// Tag this node with its topology level (for tier metrics).
    pub fn with_level(mut self, level: usize) -> Self {
        self.level = level;
        self
    }

    /// Width of this node's decode pool; any value is bit-identical.
    pub fn with_decode_threads(mut self, n: usize) -> Self {
        self.decode_threads = n.max(1);
        self
    }

    /// Arm a per-round barrier deadline over this node's span (default:
    /// wait forever, like the leader). A timed-out round is *skipped* —
    /// this node answers nothing and stays alive — so the parent (and
    /// every ancestor up to the root) **must also arm a deadline**: its
    /// timeout is what names this node and advances the tree to the
    /// next round. A child-tier deadline under a wait-forever parent
    /// trades a late round for a hung one.
    pub fn with_round_timeout(mut self, timeout: Duration) -> Self {
        self.round_timeout = Some(timeout);
        self
    }

    /// Rebuild this node's protocol handle from a `SpecChange` spec (the
    /// same total rebuild the workers perform — see
    /// `Worker::apply_spec`).
    fn apply_spec(&mut self, spec: &str) -> Result<()> {
        let dim = self.protocol.dim();
        self.protocol = crate::protocol::config::ProtocolConfig::parse(spec, dim)
            .and_then(|cfg| cfg.build())
            .with_context(|| format!("aggregator {} rebuilding protocol `{spec}`", self.agg_id))?;
        Ok(())
    }

    /// Serve rounds until the parent sends `Shutdown` (which is relayed
    /// to the children), then return this node's report. On a mid-round
    /// failure the parent's barrier is woken first (an unexpected
    /// `Shutdown` upstream) so the tree errors out instead of hanging.
    pub fn run(
        mut self,
        mut hub: Box<dyn TransportHub>,
        up: &mut dyn Endpoint,
    ) -> Result<AggregatorReport> {
        let mut metrics = ExperimentMetrics::default();
        let mut expected: Vec<ChildKey> = Vec::new();
        loop {
            match up.recv_msg()? {
                Message::RoundStart { round, dim, payload } => {
                    let reply = self.one_round(
                        hub.as_mut(),
                        round,
                        dim,
                        payload,
                        &mut expected,
                        &mut metrics,
                    );
                    match reply {
                        Ok(msg) => up.send_msg(msg)?,
                        Err(e) if e.downcast_ref::<BarrierTimeout>().is_some() => {
                            // A timed-out span is survivable: answer
                            // nothing (the parent's own deadline names
                            // this node), stay alive, and serve the next
                            // round — its barrier drops the stale answers
                            // this round leaves behind. Dying here would
                            // turn one transiently slow worker into the
                            // loss of the whole tree.
                            eprintln!(
                                "aggregator {} skipping round {round}: {e:#}",
                                self.agg_id
                            );
                        }
                        Err(e) => {
                            // Tear the subtree down — children blocked in
                            // recv would otherwise wait forever — then
                            // wake the parent's barrier before surfacing
                            // the failure (mirrors the worker loop).
                            let _ = hub.broadcast(&Message::Shutdown);
                            let _ = up.send_msg(Message::Shutdown);
                            return Err(e);
                        }
                    }
                }
                Message::SpecChange { round, spec } => {
                    // Relay downstream first — the subtree rebuilds on
                    // receipt, ahead of the RoundStart that follows on
                    // the same FIFO links — then rebuild this node. Any
                    // failure takes the mid-round teardown path below.
                    let relay = hub
                        .broadcast(&Message::SpecChange { round, spec: spec.clone() })
                        .and_then(|()| self.apply_spec(&spec));
                    if let Err(e) = relay {
                        let _ = hub.broadcast(&Message::Shutdown);
                        let _ = up.send_msg(Message::Shutdown);
                        return Err(e);
                    }
                }
                Message::Shutdown => {
                    hub.broadcast(&Message::Shutdown)?;
                    let (down_bytes, up_bytes) = hub.bytes_moved();
                    return Ok(AggregatorReport {
                        agg_id: self.agg_id,
                        level: self.level,
                        span: self.span,
                        metrics,
                        down_bytes,
                        up_bytes,
                    });
                }
                Message::Upload { .. } | Message::PartialUpload { .. } => {
                    bail!("aggregator received an upstream-only message from its parent")
                }
            }
        }
    }

    fn one_round(
        &self,
        hub: &mut dyn TransportHub,
        round: u64,
        dim: u32,
        payload: Arc<[f32]>,
        expected: &mut Vec<ChildKey>,
        metrics: &mut ExperimentMetrics,
    ) -> Result<Message> {
        let t0 = Instant::now();
        hub.broadcast(&Message::RoundStart { round, dim, payload })?;
        let ctx = RoundCtx::new(round, self.seed);
        let state = self.protocol.prepare(&ctx);
        let collected = collect_round(
            hub,
            self.protocol.as_ref(),
            &state,
            round,
            self.decode_threads,
            self.round_timeout,
            expected,
        )?;
        // The barrier checked the children against each other; they must
        // also fit inside the span this node forwards upstream, or a
        // miswired TCP tree double-counts clients another branch covers.
        for key in &collected.seen {
            let (lo, hi) = key.span();
            ensure!(
                lo >= self.span.0 && hi <= self.span.1,
                "aggregator {} [{}..{}) received {key}, which is outside its span",
                self.agg_id,
                self.span.0,
                self.span.1,
            );
        }
        *expected = collected.seen.clone();
        let t_merge = Instant::now();
        let uplink_bits = collected.folded.uplink_bits();
        let n_frames = collected.folded.n_frames() as usize;
        let slots = collected.folded.into_slots();
        let decode_wall = collected.decode_wall + t_merge.elapsed();
        let (down, up) = hub.bytes_moved();
        metrics.push(RoundMetrics {
            round,
            uplink_bits,
            n_frames,
            wall: t0.elapsed(),
            wait_wall: collected.wait_wall,
            decode_wall,
            cum_down_bytes: down,
            cum_up_bytes: up,
        });
        Ok(Message::PartialUpload {
            agg_id: self.agg_id,
            round,
            span: self.span,
            uplink_bits,
            n_frames: n_frames as u64,
            slots,
        })
    }
}

/// Join handles of a [`spawn_local_tree`] cluster.
pub struct LocalTree {
    pub workers: Vec<std::thread::JoinHandle<Result<()>>>,
    pub aggregators: Vec<std::thread::JoinHandle<Result<AggregatorReport>>>,
    /// Number of aggregator levels (for tier attribution).
    pub n_levels: usize,
}

impl LocalTree {
    /// Join every thread, propagating the first failure; on success
    /// returns the aggregator reports.
    pub fn join(self) -> Result<Vec<AggregatorReport>> {
        let mut reports = Vec::with_capacity(self.aggregators.len());
        for h in self.aggregators {
            reports.push(h.join().expect("aggregator thread panicked")?);
        }
        for h in self.workers {
            h.join().expect("worker thread panicked")?;
        }
        Ok(reports)
    }

    /// Assemble per-tier metrics (tier 0 = root) from the leader's view
    /// and the aggregator reports gathered by [`LocalTree::join`].
    pub fn tier_metrics(
        n_levels: usize,
        leader_metrics: &ExperimentMetrics,
        leader_bytes: (u64, u64),
        reports: &[AggregatorReport],
    ) -> Vec<TierMetrics> {
        let mut tiers = vec![TierMetrics {
            tier: 0,
            nodes: 1,
            down_bytes: leader_bytes.0,
            up_bytes: leader_bytes.1,
            wait_wall: leader_metrics.total_wait_wall(),
            decode_wall: leader_metrics.total_decode_wall(),
        }];
        for tier in 1..=n_levels {
            let level = n_levels - tier; // topology level for this tier
            let mut tm = TierMetrics {
                tier,
                nodes: 0,
                down_bytes: 0,
                up_bytes: 0,
                wait_wall: Duration::ZERO,
                decode_wall: Duration::ZERO,
            };
            for r in reports.iter().filter(|r| r.level == level) {
                tm.nodes += 1;
                tm.down_bytes += r.down_bytes;
                tm.up_bytes += r.up_bytes;
                tm.wait_wall += r.metrics.total_wait_wall();
                tm.decode_wall += r.metrics.total_decode_wall();
            }
            tiers.push(tm);
        }
        tiers
    }
}

/// Spawn a whole aggregation tree — workers, aggregators, leader — as
/// loopback threads in this process: the tree-shaped sibling of
/// `spawn_local_cluster`. `shards[c]` is client `c`'s data; the
/// topology decides who reports to whom. `decode_threads` and
/// `round_timeout` apply to the leader and every aggregator, so a
/// timeout error names the missing child at the barrier nearest to it.
pub fn spawn_local_tree(
    protocol: Arc<dyn Protocol>,
    shards: Vec<Vec<Vec<f32>>>,
    update: super::worker::UpdateFn,
    seed: u64,
    topo: &Topology,
    decode_threads: usize,
    round_timeout: Option<Duration>,
) -> Result<(Leader, LocalTree)> {
    ensure!(
        shards.len() as u64 == topo.n_clients(),
        "topology covers {} clients but {} shards were provided",
        topo.n_clients(),
        shards.len()
    );
    topo.validate()?;
    let mut shards: Vec<Option<Vec<Vec<f32>>>> = shards.into_iter().map(Some).collect();
    let mut tree = LocalTree {
        workers: Vec::new(),
        aggregators: Vec::new(),
        n_levels: topo.levels().len(),
    };

    // Recursive wiring, top-down: creating a node's hub yields the
    // endpoints its children run on.
    #[allow(clippy::too_many_arguments)]
    fn spawn_child(
        child: &Child,
        ep: super::transport::LoopbackEndpoint,
        topo: &Topology,
        protocol: &Arc<dyn Protocol>,
        update: &super::worker::UpdateFn,
        seed: u64,
        decode_threads: usize,
        round_timeout: Option<Duration>,
        shards: &mut Vec<Option<Vec<Vec<f32>>>>,
        tree: &mut LocalTree,
    ) -> Result<()> {
        match child {
            Child::Worker(c) => {
                let shard = shards[*c as usize].take().expect("shard handed out twice");
                let worker = super::worker::Worker {
                    client_id: *c,
                    shard,
                    protocol: protocol.clone(),
                    update: update.clone(),
                    seed,
                };
                tree.workers.push(
                    std::thread::Builder::new()
                        .name(format!("dme-worker-{c}"))
                        .spawn(move || worker.run_loopback(ep))
                        .context("spawning worker thread")?,
                );
            }
            Child::Agg { level, index } => {
                let spec = topo.spec(*level, *index);
                let (hub, endpoints) = LoopbackHub::new(spec.children.len());
                for (grandchild, gep) in spec.children.iter().zip(endpoints) {
                    spawn_child(
                        grandchild,
                        gep,
                        topo,
                        protocol,
                        update,
                        seed,
                        decode_threads,
                        round_timeout,
                        shards,
                        tree,
                    )?;
                }
                let mut agg = Aggregator::new(protocol.clone(), seed, spec.id, spec.span)
                    .with_level(*level)
                    .with_decode_threads(decode_threads);
                if let Some(t) = round_timeout {
                    agg = agg.with_round_timeout(t);
                }
                let name = format!("dme-agg-{}", spec.id);
                tree.aggregators.push(
                    std::thread::Builder::new()
                        .name(name)
                        .spawn(move || {
                            let mut ep = ep;
                            agg.run(Box::new(hub), &mut ep)
                        })
                        .context("spawning aggregator thread")?,
                );
            }
        }
        Ok(())
    }

    let root_children = topo.root_children();
    let (hub, endpoints) = LoopbackHub::new(root_children.len());
    for (child, ep) in root_children.iter().zip(endpoints) {
        spawn_child(
            child,
            ep,
            topo,
            &protocol,
            &update,
            seed,
            decode_threads,
            round_timeout,
            &mut shards,
            &mut tree,
        )?;
    }
    let expected = root_children
        .iter()
        .map(|c| match c {
            Child::Worker(id) => ChildKey::Client(*id),
            Child::Agg { level, index } => {
                let spec = topo.spec(*level, *index);
                ChildKey::Aggregator { id: spec.id, span: spec.span }
            }
        })
        .collect();
    let mut leader = Leader::new(protocol, Box::new(hub), seed)
        .with_decode_threads(decode_threads)
        .with_expected_children(expected);
    if let Some(t) = round_timeout {
        leader = leader.with_round_timeout(t);
    }
    Ok((leader, tree))
}

/// One round of tree aggregation over already-encoded uploads, without
/// transports or threads-per-node: the deterministic simulator used by
/// benches and the conformance suite. Every aggregator hop still
/// round-trips its `PartialUpload` through the real wire serialization,
/// so serialization fidelity is on the tested path.
pub struct TreeOutcome {
    pub outcome: RoundOutcome,
    /// `tier_ingress[0]` is the framed transport bytes crossing into the
    /// root; higher indices are the tiers below, ending with the leaf
    /// aggregators' ingress from the workers. For a flat topology the
    /// single entry is the workers' direct ingress at the root.
    pub tier_ingress: Vec<u64>,
}

pub fn aggregate_tree(
    proto: &dyn Protocol,
    state: &crate::protocol::RoundState,
    uploads: &[(u64, Vec<WeightedFrame>)],
    topo: &Topology,
    decode_threads: usize,
) -> Result<TreeOutcome> {
    topo.validate()?;
    ensure!(
        uploads.iter().all(|(c, _)| *c < topo.n_clients()),
        "upload client id outside the topology's client range"
    );
    let round = state.ctx.round;
    // Leaf ingress accounting: what the workers' Upload messages cost on
    // the wire wherever they land (leaf aggregators, or the root when
    // flat).
    let worker_ingress: u64 = uploads
        .iter()
        .map(|(_, frames)| Message::upload_wire_len(frames) + 4) // + u32 frame prefix
        .sum();
    // Decode once — the same work the leaf tier's pools would do.
    let mut current = decode_all(proto, state, uploads, decode_threads)?;
    let mut ingress_rev = vec![worker_ingress];
    for tier in topo.levels() {
        // Route every child into the aggregator whose span contains it.
        let mut buckets: Vec<Vec<DecodedUpload>> = (0..tier.len()).map(|_| Vec::new()).collect();
        for d in current.drain(..) {
            let (lo, hi) = d.origin.span();
            let idx = tier.partition_point(|s| s.span.1 <= lo);
            ensure!(
                idx < tier.len() && lo >= tier[idx].span.0 && hi <= tier[idx].span.1,
                "child span [{lo}, {hi}) fits no aggregator at this tier"
            );
            buckets[idx].push(d);
        }
        let mut tier_bytes = 0u64;
        let mut next = Vec::with_capacity(tier.len());
        for (spec, mine) in tier.iter().zip(buckets) {
            if mine.is_empty() {
                continue; // a span with no uploads present sends nothing
            }
            let uplink_bits: u64 = mine.iter().map(|d| d.uplink_bits).sum();
            let n_frames: usize = mine.iter().map(|d| d.n_frames).sum();
            let slots = fold_spans(proto, &mine)?;
            let msg = Message::PartialUpload {
                agg_id: spec.id,
                round,
                span: spec.span,
                uplink_bits,
                n_frames: n_frames as u64,
                slots,
            };
            tier_bytes += msg.framed_len();
            // The wire round-trip: prove the serialized partials carry
            // the exact state.
            let bytes = msg.to_bytes()?;
            let Message::PartialUpload { agg_id, span, uplink_bits, n_frames, slots, .. } =
                Message::from_bytes(&bytes)?
            else {
                bail!("PartialUpload did not survive the wire")
            };
            next.push(DecodedUpload {
                origin: ChildKey::Aggregator { id: agg_id, span },
                slots: slots.into_iter().map(Some).collect(),
                uplink_bits,
                n_frames: n_frames as usize,
            });
        }
        ingress_rev.push(tier_bytes);
        current = next;
    }
    let outcome = merge_decoded(proto, state, current)?;
    ingress_rev.reverse(); // root first
    Ok(TreeOutcome { outcome, tier_ingress: ingress_rev })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::leader::aggregate_uploads_reference;
    use crate::coordinator::worker::mean_update;
    use crate::protocol::config::ProtocolConfig;
    use crate::protocol::Encoder;
    use crate::rng::Pcg64;

    fn gaussian_shards(n: usize, d: usize, seed: u64) -> Vec<Vec<Vec<f32>>> {
        let mut rng = Pcg64::new(seed);
        (0..n)
            .map(|_| {
                let mut x = vec![0.0f32; d];
                rng.fill_gaussian_f32(&mut x);
                vec![x]
            })
            .collect()
    }

    fn bits_of(means: &[Vec<f32>]) -> Vec<Vec<u32>> {
        means.iter().map(|m| m.iter().map(|v| v.to_bits()).collect()).collect()
    }

    #[test]
    fn local_tree_matches_flat_cluster_bits() {
        let d = 32;
        let n = 11;
        let spec = "rotated:k=16";
        let proto = ProtocolConfig::parse(spec, d).unwrap().build().unwrap();
        let shards = gaussian_shards(n, d, 5);
        let (mut flat_leader, flat_handles) =
            super::super::leader::spawn_local_cluster(proto, shards.clone(), mean_update(), 9);
        let mut flat_means = Vec::new();
        for r in 0..2 {
            flat_means.push(flat_leader.round(r, d as u32, &[]).unwrap().means);
        }
        flat_leader.shutdown().unwrap();
        for h in flat_handles {
            h.join().unwrap().unwrap();
        }

        let topo = Topology::uniform(n as u64, 4, 3).unwrap();
        let proto = ProtocolConfig::parse(spec, d).unwrap().build().unwrap();
        let (mut leader, tree) =
            spawn_local_tree(proto, shards, mean_update(), 9, &topo, 2, None).unwrap();
        for (r, want) in flat_means.iter().enumerate() {
            let got = leader.round(r as u64, d as u32, &[]).unwrap();
            assert_eq!(bits_of(&got.means), bits_of(want), "round {r} diverged");
        }
        leader.shutdown().unwrap();
        let reports = tree.join().unwrap();
        assert_eq!(reports.len(), topo.n_aggregators());
        assert!(reports.iter().all(|r| r.metrics.rounds.len() == 2));
        assert!(reports.iter().all(|r| r.up_bytes > 0 && r.down_bytes > 0));
    }

    #[test]
    fn aggregate_tree_matches_reference_and_accounts_ingress() {
        let d = 24;
        let n = 20;
        let spec = "klevel:k=16";
        let proto = ProtocolConfig::parse(spec, d).unwrap().build().unwrap();
        let ctx = RoundCtx::new(0, 77);
        let state = proto.prepare(&ctx);
        let mut enc = Encoder::new(proto.as_ref(), &state);
        let mut rng = Pcg64::new(13);
        let uploads: Vec<(u64, Vec<WeightedFrame>)> = (0..n)
            .map(|i| {
                let mut x = vec![0.0f32; d];
                rng.fill_gaussian_f32(&mut x);
                let frame = enc.encode(i, &x).unwrap();
                (i, vec![WeightedFrame { frame, weight: 1.0 }])
            })
            .collect();
        let want = aggregate_uploads_reference(proto.as_ref(), &state, uploads.clone()).unwrap();
        let topo = Topology::uniform(n, 5, 2).unwrap();
        let got = aggregate_tree(proto.as_ref(), &state, &uploads, &topo, 2).unwrap();
        assert_eq!(bits_of(&got.outcome.means), bits_of(&want.means));
        assert_eq!(got.outcome.weights, want.weights);
        assert_eq!(got.outcome.uplink_bits, want.uplink_bits);
        assert_eq!(got.tier_ingress.len(), 2);
        assert!(got.tier_ingress[1] > 0, "worker-edge ingress must be accounted");
        // Flat "tree": single ingress entry, equal to the workers' cost.
        let flat = aggregate_tree(proto.as_ref(), &state, &uploads, &Topology::flat(n), 1).unwrap();
        assert_eq!(flat.tier_ingress.len(), 1);
        assert_eq!(flat.tier_ingress[0], got.tier_ingress[1]);
    }
}
