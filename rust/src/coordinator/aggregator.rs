//! The aggregation-tier node: a partial-merging relay between workers
//! and the leader (or between tiers of itself).
//!
//! An [`Aggregator`] owns a [`TransportHub`] over its children (workers
//! or lower-tier aggregators — the same hubs the leader uses) and an
//! upstream [`Endpoint`] to its parent. Per round it:
//!
//! 1. relays the parent's `RoundStart` downstream (the broadcast payload
//!    stays `Arc`-shared over loopback),
//! 2. runs the same streaming barrier + decode pool as the leader
//!    (`collect_round`): worker uploads decode on the pool, child
//!    `PartialUpload`s are absorbed directly,
//! 3. folds everything into one exactly-mergeable `SlotPartial` per slot
//!    (`fold_spans`) and forwards a single `PartialUpload` for its whole
//!    client span.
//!
//! Because the fold is exact (see `protocol::exact`), the root estimate
//! is **bit-identical to the flat leader for every tree shape** — the
//! tier is purely a throughput/deployment lever: root ingest drops from
//! O(n · frames) to O(root-fan-in · slots), and decode work spreads
//! across the tier (`tests/tree_aggregation.rs` is the conformance
//! suite).
//!
//! [`spawn_local_tree`] wires a whole tree of loopback hubs in one
//! process (the `dme serve --fanout` path); [`aggregate_tree`] is the
//! transportless simulator used by benches and conformance tests —
//! every hop still passes through the real `PartialUpload` wire
//! serialization.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use super::leader::{
    collect_round, decode_all, fold_spans, BarrierPolicy, BarrierTimeout, ChildKey, DecodedUpload,
    Leader, RoundOutcome, SpanAccum,
};
use super::metrics::{ExperimentMetrics, RoundMetrics, TierMetrics};
use super::session::SessionMux;
use super::topology::{split_ranges, Child, Topology};
use super::transport::{
    Endpoint, LoopbackHub, Message, TransportHub, WeightedFrame, WireError, ROOT_SESSION,
};
use crate::protocol::{Protocol, RoundCtx, SlotPartial};

/// A partial-merging aggregation node.
pub struct Aggregator {
    protocol: Arc<dyn Protocol>,
    /// Locally-configured experiment seed. Since wire v2, each round's
    /// public randomness (the π_srk rotation, correlated offsets) comes
    /// from the `shared_seed` the incoming `RoundStart` carries — the
    /// handshake makes the tree agree by construction — so this field is
    /// informational (see [`Self::seed`]), retained for constructor
    /// stability and diagnostics.
    seed: u64,
    agg_id: u64,
    span: (u64, u64),
    /// Topology level (0 = directly above the workers); only used to
    /// attribute metrics to a tier.
    level: usize,
    decode_threads: usize,
    round_timeout: Option<Duration>,
    /// How many dimension shards this node splits its upstream report
    /// into (1 = one full-dimension `PartialUpload`, the default). With
    /// `s > 1` every round answers with `s` messages, one exact fold
    /// per contiguous coordinate range; the parent barrier concatenates
    /// them, bit-identically to the unsharded report.
    dim_shards: u32,
    /// Wire sessions this node serves (default: just [`ROOT_SESSION`]).
    /// Each session keeps its own protocol handle, so a tenant's
    /// `SpecChange` rebuilds only that tenant; the node exits when every
    /// session has been shut down.
    sessions: Vec<u16>,
    /// Per-session starting protocols for tenants whose specs differ
    /// (sessions absent here start on `self.protocol`).
    session_protocols: HashMap<u16, Arc<dyn Protocol>>,
    /// What a timed-out barrier over this node's span does: skip the
    /// round entirely ([`BarrierPolicy::Strict`], the default) or
    /// forward a partial fold of the surviving children
    /// ([`BarrierPolicy::Partial`]) so the root can still finalize with
    /// the Lemma 8 rescale.
    barrier_policy: BarrierPolicy,
}

/// What an aggregator hands back when its tree shuts down: per-round
/// metrics plus its hub's cumulative byte accounting.
#[derive(Clone, Debug)]
pub struct AggregatorReport {
    pub agg_id: u64,
    pub level: usize,
    pub span: (u64, u64),
    pub metrics: ExperimentMetrics,
    /// Bytes this node sent down to its children.
    pub down_bytes: u64,
    /// Bytes this node ingested from its children.
    pub up_bytes: u64,
    /// Dimension shards this node split its report into (1 = unsharded).
    pub dim_shards: u32,
}

impl Aggregator {
    pub fn new(protocol: Arc<dyn Protocol>, seed: u64, agg_id: u64, span: (u64, u64)) -> Self {
        Aggregator {
            protocol,
            seed,
            agg_id,
            span,
            level: 0,
            decode_threads: 1,
            round_timeout: None,
            dim_shards: 1,
            sessions: vec![ROOT_SESSION],
            session_protocols: HashMap::new(),
            barrier_policy: BarrierPolicy::default(),
        }
    }

    /// The locally-configured experiment seed. Rounds no longer consume
    /// it — decode randomness is rooted in each `RoundStart`'s
    /// `shared_seed` — but it still names the experiment this node was
    /// launched for.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Choose this node's barrier-timeout behavior (builder style); see
    /// the field docs. Requires [`Self::with_round_timeout`] to ever
    /// trigger. A round in which *no* child of this node answered still
    /// takes the skip path — there is no partial fold to forward.
    pub fn with_barrier_policy(mut self, policy: BarrierPolicy) -> Self {
        self.barrier_policy = policy;
        self
    }

    /// Split this node's upstream report into `shards` dimension slices
    /// (builder style); see the field docs.
    pub fn with_dim_shards(mut self, shards: u32) -> Self {
        self.dim_shards = shards.max(1);
        self
    }

    /// Declare the wire sessions this node serves (builder style). The
    /// default is the sole [`ROOT_SESSION`]; a multiplexed tree lists
    /// every tenant's session id up front so the node knows when the
    /// last tenant has shut down.
    pub fn with_sessions(mut self, sessions: Vec<u16>) -> Self {
        if !sessions.is_empty() {
            self.sessions = sessions;
        }
        self
    }

    /// [`Self::with_sessions`], with each tenant starting on its own
    /// protocol handle — the multiplexed-tree form for tenants running
    /// different specs over the same tree.
    pub fn with_session_protocols(mut self, tenants: &[(u16, Arc<dyn Protocol>)]) -> Self {
        if !tenants.is_empty() {
            self.sessions = tenants.iter().map(|(s, _)| *s).collect();
            self.session_protocols = tenants.iter().cloned().collect();
        }
        self
    }

    /// Tag this node with its topology level (for tier metrics).
    pub fn with_level(mut self, level: usize) -> Self {
        self.level = level;
        self
    }

    /// Width of this node's decode pool; any value is bit-identical.
    pub fn with_decode_threads(mut self, n: usize) -> Self {
        self.decode_threads = n.max(1);
        self
    }

    /// Arm a per-round barrier deadline over this node's span (default:
    /// wait forever, like the leader). A timed-out round is *skipped* —
    /// this node answers nothing and stays alive — so the parent (and
    /// every ancestor up to the root) **must also arm a deadline**: its
    /// timeout is what names this node and advances the tree to the
    /// next round. A child-tier deadline under a wait-forever parent
    /// trades a late round for a hung one.
    pub fn with_round_timeout(mut self, timeout: Duration) -> Self {
        self.round_timeout = Some(timeout);
        self
    }

    /// Rebuild one session's protocol handle from a `SpecChange` spec
    /// (the same total rebuild the workers perform — see
    /// `Worker::apply_spec`).
    fn rebuild_protocol(&self, current: &Arc<dyn Protocol>, spec: &str) -> Result<Arc<dyn Protocol>> {
        let dim = current.dim();
        crate::protocol::config::ProtocolConfig::parse(spec, dim)
            .and_then(|cfg| cfg.build())
            .with_context(|| format!("aggregator {} rebuilding protocol `{spec}`", self.agg_id))
    }

    /// Serve rounds until the parent has shut down every session (each
    /// `Shutdown` is relayed to the children on its session), then
    /// return this node's report. On a mid-round failure the parent's
    /// barrier is woken first (an unexpected `Shutdown` upstream) so the
    /// tree errors out instead of hanging.
    pub fn run(
        self,
        mut hub: Box<dyn TransportHub>,
        up: &mut dyn Endpoint,
    ) -> Result<AggregatorReport> {
        let mut metrics = ExperimentMetrics::default();
        // Per-session protocol handle and barrier expectation list: a
        // tenant's SpecChange rebuilds only its own entry.
        let mut sessions: HashMap<u16, (Arc<dyn Protocol>, Vec<ChildKey>)> = self
            .sessions
            .iter()
            .map(|&s| {
                let proto = self
                    .session_protocols
                    .get(&s)
                    .cloned()
                    .unwrap_or_else(|| self.protocol.clone());
                (s, (proto, Vec::new()))
            })
            .collect();
        let report = |hub: &dyn TransportHub, metrics: ExperimentMetrics| AggregatorReport {
            agg_id: self.agg_id,
            level: self.level,
            span: self.span,
            metrics,
            down_bytes: hub.bytes_moved().0,
            up_bytes: hub.bytes_moved().1,
            dim_shards: self.dim_shards,
        };
        loop {
            let env = up.recv_env()?;
            let session = env.session;
            if !sessions.contains_key(&session) && !matches!(env.msg, Message::Shutdown) {
                // A session this node was never told about is a routing
                // bug: tear down and surface the typed rejection.
                let _ = hub.broadcast_session(session, &Message::Shutdown);
                let _ = up.send_env(session, Message::Shutdown);
                return Err(WireError::UnknownSession(session).into());
            }
            match env.msg {
                Message::RoundStart { round, shared_seed, dim, payload } => {
                    let (proto, expected) = sessions.get_mut(&session).unwrap();
                    let proto = proto.clone();
                    let reply = self.one_round(
                        hub.as_mut(),
                        session,
                        &proto,
                        round,
                        shared_seed,
                        dim,
                        payload,
                        expected,
                        &mut metrics,
                    );
                    match reply {
                        Ok(msgs) => {
                            for msg in msgs {
                                up.send_env(session, msg)?;
                            }
                        }
                        Err(e) if e.downcast_ref::<BarrierTimeout>().is_some() => {
                            // A timed-out span is survivable: answer
                            // nothing (the parent's own deadline names
                            // this node), stay alive, and serve the next
                            // round — its barrier drops the stale answers
                            // this round leaves behind. Dying here would
                            // turn one transiently slow worker into the
                            // loss of the whole tree.
                            eprintln!(
                                "aggregator {} skipping round {round}: {e:#}",
                                self.agg_id
                            );
                        }
                        Err(e) => {
                            // Tear the subtree down — children blocked in
                            // recv would otherwise wait forever — then
                            // wake the parent's barrier before surfacing
                            // the failure (mirrors the worker loop).
                            let _ = hub.broadcast_session(session, &Message::Shutdown);
                            let _ = up.send_env(session, Message::Shutdown);
                            return Err(e);
                        }
                    }
                }
                Message::SpecChange { round, spec } => {
                    // Relay downstream first — the subtree rebuilds on
                    // receipt, ahead of the RoundStart that follows on
                    // the same FIFO links — then rebuild this session's
                    // handle (the other tenants' protocols are
                    // untouched). Any failure takes the mid-round
                    // teardown path below.
                    let relay = hub
                        .broadcast_session(
                            session,
                            &Message::SpecChange { round, spec: spec.clone() },
                        )
                        .and_then(|()| {
                            let entry = sessions.get_mut(&session).unwrap();
                            entry.0 = self.rebuild_protocol(&entry.0, &spec)?;
                            Ok(())
                        });
                    if let Err(e) = relay {
                        let _ = hub.broadcast_session(session, &Message::Shutdown);
                        let _ = up.send_env(session, Message::Shutdown);
                        return Err(e);
                    }
                }
                Message::Shutdown => {
                    let relay = hub.broadcast_session(session, &Message::Shutdown);
                    if let Err(e) = relay {
                        // Children that already hung up (scenario
                        // disconnect faults) cannot block the live
                        // ones' shutdown: the hubs stage to every live
                        // child before surfacing the dead.
                        if self.barrier_policy == BarrierPolicy::Partial {
                            eprintln!(
                                "aggregator {} shutdown: broadcast saw departed children ({e:#})",
                                self.agg_id
                            );
                        } else {
                            return Err(e);
                        }
                    }
                    sessions.remove(&session);
                    if sessions.is_empty() {
                        return Ok(report(hub.as_ref(), metrics));
                    }
                }
                Message::Upload { .. } | Message::PartialUpload { .. } => {
                    bail!("aggregator received an upstream-only message from its parent")
                }
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn one_round(
        &self,
        hub: &mut dyn TransportHub,
        session: u16,
        proto: &Arc<dyn Protocol>,
        round: u64,
        shared_seed: u64,
        dim: u32,
        payload: Arc<[f32]>,
        expected: &mut Vec<ChildKey>,
        metrics: &mut ExperimentMetrics,
    ) -> Result<Vec<Message>> {
        let t0 = Instant::now();
        // Relay the round's shared_seed verbatim: every tier of the tree
        // decodes against the same public randomness the leader chose.
        let bcast = hub.broadcast_session(
            session,
            &Message::RoundStart { round, shared_seed, dim, payload },
        );
        if let Err(e) = bcast {
            // Hubs stage to every live child before surfacing dead
            // ones; under the partial policy a dead child is exactly
            // what the barrier finalizes around, so carry on and let
            // the survivors answer.
            if self.barrier_policy == BarrierPolicy::Partial {
                eprintln!(
                    "aggregator {} round {round}: broadcast saw departed children ({e:#})",
                    self.agg_id
                );
            } else {
                return Err(e);
            }
        }
        let ctx = RoundCtx::new(round, shared_seed);
        let state = proto.prepare(&ctx);
        let n_msgs = hub.n_workers();
        let collected = collect_round(
            hub,
            proto.as_ref(),
            &state,
            session,
            round,
            self.decode_threads,
            self.round_timeout,
            expected,
            n_msgs,
            self.barrier_policy,
        )?;
        // The barrier checked the children against each other; they must
        // also fit inside the span this node forwards upstream, or a
        // miswired TCP tree double-counts clients another branch covers.
        for key in &collected.seen {
            let (lo, hi) = key.span();
            ensure!(
                lo >= self.span.0 && hi <= self.span.1,
                "aggregator {} [{}..{}) received {key}, which is outside its span",
                self.agg_id,
                self.span.0,
                self.span.1,
            );
        }
        match self.barrier_policy {
            BarrierPolicy::Strict => *expected = collected.seen.clone(),
            BarrierPolicy::Partial => {
                // Union, never replacement: children missing from a
                // partial round stay expected for the next one.
                for k in &collected.seen {
                    if !expected.contains(k) {
                        expected.push(*k);
                    }
                }
            }
        }
        // This node's observed participation over its own span: the
        // fold's holder counts are the survivor total |S| (silent
        // sampled-out frames included).
        let span_width = (self.span.1 - self.span.0).max(1);
        let holders = collected.folded.max_holders();
        let participation = if holders > 0 {
            (holders as f64 / span_width as f64).min(1.0)
        } else {
            let answered: u64 = collected.seen.iter().map(|k| k.span().1 - k.span().0).sum();
            (answered as f64 / span_width as f64).min(1.0)
        };
        let t_merge = Instant::now();
        let uplink_bits = collected.folded.uplink_bits();
        let n_frames = collected.folded.n_frames() as usize;
        let slots = collected.folded.into_slots();
        let decode_wall = collected.decode_wall + t_merge.elapsed();
        let (down, up) = hub.bytes_moved();
        metrics.push(RoundMetrics {
            round,
            uplink_bits,
            n_frames,
            wall: t0.elapsed(),
            wait_wall: collected.wait_wall,
            decode_wall,
            cum_down_bytes: down,
            cum_up_bytes: up,
            participation,
            duplicate_uploads: collected.duplicate_uploads,
        });
        let internal_dim = proto.internal_dim();
        if self.dim_shards <= 1 {
            return Ok(vec![Message::PartialUpload {
                agg_id: self.agg_id,
                round,
                span: self.span,
                uplink_bits,
                n_frames: n_frames as u64,
                shard: (0, internal_dim as u32),
                slots,
            }]);
        }
        // Sharded report: one message per coordinate range, each an
        // independent exact fold the parent concatenates. The span's
        // client-edge accounting rides on the first shard only, so the
        // root's totals match the unsharded run exactly.
        split_ranges(internal_dim, self.dim_shards)
            .into_iter()
            .enumerate()
            .map(|(k, (lo, hi))| {
                let sliced: Vec<SlotPartial> = slots
                    .iter()
                    .map(|p| p.slice(lo as usize, hi as usize))
                    .collect::<Result<_>>()?;
                Ok(Message::PartialUpload {
                    agg_id: self.agg_id,
                    round,
                    span: self.span,
                    uplink_bits: if k == 0 { uplink_bits } else { 0 },
                    n_frames: if k == 0 { n_frames as u64 } else { 0 },
                    shard: (lo, hi),
                    slots: sliced,
                })
            })
            .collect()
    }
}

/// Join handles of a [`spawn_local_tree`] cluster.
pub struct LocalTree {
    pub workers: Vec<std::thread::JoinHandle<Result<()>>>,
    pub aggregators: Vec<std::thread::JoinHandle<Result<AggregatorReport>>>,
    /// Number of aggregator levels (for tier attribution).
    pub n_levels: usize,
}

impl LocalTree {
    /// Join every thread, propagating the first failure; on success
    /// returns the aggregator reports.
    pub fn join(self) -> Result<Vec<AggregatorReport>> {
        let mut reports = Vec::with_capacity(self.aggregators.len());
        for h in self.aggregators {
            reports.push(h.join().expect("aggregator thread panicked")?);
        }
        for h in self.workers {
            h.join().expect("worker thread panicked")?;
        }
        Ok(reports)
    }

    /// Assemble per-tier metrics (tier 0 = root) from the leader's view
    /// and the aggregator reports gathered by [`LocalTree::join`].
    pub fn tier_metrics(
        n_levels: usize,
        leader_metrics: &ExperimentMetrics,
        leader_bytes: (u64, u64),
        reports: &[AggregatorReport],
    ) -> Vec<TierMetrics> {
        let mut tiers = vec![TierMetrics {
            tier: 0,
            nodes: 1,
            down_bytes: leader_bytes.0,
            up_bytes: leader_bytes.1,
            wait_wall: leader_metrics.total_wait_wall(),
            decode_wall: leader_metrics.total_decode_wall(),
            dim_shards: 1,
        }];
        for tier in 1..=n_levels {
            let level = n_levels - tier; // topology level for this tier
            let mut tm = TierMetrics {
                tier,
                nodes: 0,
                down_bytes: 0,
                up_bytes: 0,
                wait_wall: Duration::ZERO,
                decode_wall: Duration::ZERO,
                dim_shards: 1,
            };
            for r in reports.iter().filter(|r| r.level == level) {
                tm.nodes += 1;
                tm.down_bytes += r.down_bytes;
                tm.up_bytes += r.up_bytes;
                tm.wait_wall += r.metrics.total_wait_wall();
                tm.decode_wall += r.metrics.total_decode_wall();
                tm.dim_shards = tm.dim_shards.max(r.dim_shards);
            }
            tiers.push(tm);
        }
        tiers
    }
}

/// Spawn a whole aggregation tree — workers, aggregators, leader — as
/// loopback threads in this process: the tree-shaped sibling of
/// `spawn_local_cluster`. `shards[c]` is client `c`'s data; the
/// topology decides who reports to whom. `decode_threads` and
/// `round_timeout` apply to the leader and every aggregator, so a
/// timeout error names the missing child at the barrier nearest to it.
pub fn spawn_local_tree(
    protocol: Arc<dyn Protocol>,
    shards: Vec<Vec<Vec<f32>>>,
    update: super::worker::UpdateFn,
    seed: u64,
    topo: &Topology,
    decode_threads: usize,
    round_timeout: Option<Duration>,
) -> Result<(Leader, LocalTree)> {
    ensure!(
        shards.len() as u64 == topo.n_clients(),
        "topology covers {} clients but {} shards were provided",
        topo.n_clients(),
        shards.len()
    );
    topo.validate()?;
    let mut shards: Vec<Option<Vec<Vec<f32>>>> = shards.into_iter().map(Some).collect();
    let mut tree = LocalTree {
        workers: Vec::new(),
        aggregators: Vec::new(),
        n_levels: topo.levels().len(),
    };

    // Recursive wiring, top-down: creating a node's hub yields the
    // endpoints its children run on. Only aggregators directly below
    // the root shard their reports (`at_root`): the root barrier is
    // where shard slices concatenate back to full dimension.
    #[allow(clippy::too_many_arguments)]
    fn spawn_child(
        child: &Child,
        ep: super::transport::LoopbackEndpoint,
        at_root: bool,
        topo: &Topology,
        protocol: &Arc<dyn Protocol>,
        update: &super::worker::UpdateFn,
        seed: u64,
        decode_threads: usize,
        round_timeout: Option<Duration>,
        shards: &mut Vec<Option<Vec<Vec<f32>>>>,
        tree: &mut LocalTree,
    ) -> Result<()> {
        match child {
            Child::Worker(c) => {
                let shard = shards[*c as usize].take().expect("shard handed out twice");
                let worker = super::worker::Worker {
                    client_id: *c,
                    shard,
                    protocol: protocol.clone(),
                    update: update.clone(),
                    seed,
                };
                tree.workers.push(
                    std::thread::Builder::new()
                        .name(format!("dme-worker-{c}"))
                        .spawn(move || worker.run_loopback(ep))
                        .context("spawning worker thread")?,
                );
            }
            Child::Agg { level, index } => {
                let spec = topo.spec(*level, *index);
                let (hub, endpoints) = LoopbackHub::new(spec.children.len());
                for (grandchild, gep) in spec.children.iter().zip(endpoints) {
                    spawn_child(
                        grandchild,
                        gep,
                        false,
                        topo,
                        protocol,
                        update,
                        seed,
                        decode_threads,
                        round_timeout,
                        shards,
                        tree,
                    )?;
                }
                let mut agg = Aggregator::new(protocol.clone(), seed, spec.id, spec.span)
                    .with_level(*level)
                    .with_decode_threads(decode_threads);
                if at_root {
                    agg = agg.with_dim_shards(topo.dim_shards());
                }
                if let Some(t) = round_timeout {
                    agg = agg.with_round_timeout(t);
                }
                let name = format!("dme-agg-{}", spec.id);
                tree.aggregators.push(
                    std::thread::Builder::new()
                        .name(name)
                        .spawn(move || {
                            let mut ep = ep;
                            agg.run(Box::new(hub), &mut ep)
                        })
                        .context("spawning aggregator thread")?,
                );
            }
        }
        Ok(())
    }

    let root_children = topo.root_children();
    let (hub, endpoints) = LoopbackHub::new(root_children.len());
    for (child, ep) in root_children.iter().zip(endpoints) {
        spawn_child(
            child,
            ep,
            true,
            topo,
            &protocol,
            &update,
            seed,
            decode_threads,
            round_timeout,
            &mut shards,
            &mut tree,
        )?;
    }
    let expected = root_children
        .iter()
        .map(|c| match c {
            Child::Worker(id) => ChildKey::Client(*id),
            Child::Agg { level, index } => {
                let spec = topo.spec(*level, *index);
                ChildKey::Aggregator { id: spec.id, span: spec.span }
            }
        })
        .collect();
    // Sharded root children answer with one message per shard range;
    // direct workers (flat topology) always answer once.
    let barrier_msgs: usize = root_children
        .iter()
        .map(|c| match c {
            Child::Worker(_) => 1,
            Child::Agg { .. } => topo.dim_shards() as usize,
        })
        .sum();
    let mut leader = Leader::new(protocol, Box::new(hub), seed)
        .with_decode_threads(decode_threads)
        .with_expected_children(expected)
        .with_barrier_messages(barrier_msgs);
    if let Some(t) = round_timeout {
        leader = leader.with_round_timeout(t);
    }
    Ok((leader, tree))
}

/// [`spawn_local_tree`] for a multi-tenant run: every tenant session in
/// `tenants` shares the one loopback tree — leaves run a
/// [`MuxWorker`](super::worker::MuxWorker) hosting one `Worker` per
/// tenant over each tenant's own protocol, aggregators serve every
/// session with per-session protocol handles, and the root hub is split
/// by a [`SessionMux`] into one [`Leader`] per tenant (returned in
/// `tenants` order, each pinned to its session). Each tenant's rounds
/// are bit-identical to a solo [`spawn_local_tree`] run of that tenant
/// at the same session id — the mux multiplexes the wire, never the
/// math. Drive the leaders from one thread (interleaved rounds); shut
/// each tenant down with its own leader's `shutdown()`, and `join` the
/// tree after the last one.
pub fn spawn_mux_tree(
    tenants: &[(u16, Arc<dyn Protocol>)],
    shards: Vec<Vec<Vec<f32>>>,
    update: super::worker::UpdateFn,
    seed: u64,
    topo: &Topology,
    decode_threads: usize,
    round_timeout: Option<Duration>,
) -> Result<(SessionMux, Vec<Leader>, LocalTree)> {
    ensure!(!tenants.is_empty(), "at least one tenant is required");
    ensure!(
        tenants.iter().enumerate().all(|(i, (s, _))| tenants[..i].iter().all(|(t, _)| t != s)),
        "tenant session ids must be unique"
    );
    ensure!(
        shards.len() as u64 == topo.n_clients(),
        "topology covers {} clients but {} shards were provided",
        topo.n_clients(),
        shards.len()
    );
    topo.validate()?;
    let mut shards: Vec<Option<Vec<Vec<f32>>>> = shards.into_iter().map(Some).collect();
    let mut tree = LocalTree {
        workers: Vec::new(),
        aggregators: Vec::new(),
        n_levels: topo.levels().len(),
    };

    #[allow(clippy::too_many_arguments)]
    fn spawn_child(
        child: &Child,
        ep: super::transport::LoopbackEndpoint,
        at_root: bool,
        topo: &Topology,
        tenants: &[(u16, Arc<dyn Protocol>)],
        update: &super::worker::UpdateFn,
        seed: u64,
        decode_threads: usize,
        round_timeout: Option<Duration>,
        shards: &mut Vec<Option<Vec<Vec<f32>>>>,
        tree: &mut LocalTree,
    ) -> Result<()> {
        match child {
            Child::Worker(c) => {
                let shard = shards[*c as usize].take().expect("shard handed out twice");
                let mut mux = super::worker::MuxWorker::new();
                for (session, proto) in tenants {
                    mux.insert(
                        *session,
                        super::worker::Worker {
                            client_id: *c,
                            shard: shard.clone(),
                            protocol: proto.clone(),
                            update: update.clone(),
                            seed,
                        },
                    );
                }
                tree.workers.push(
                    std::thread::Builder::new()
                        .name(format!("dme-muxworker-{c}"))
                        .spawn(move || mux.run_loopback(ep))
                        .context("spawning mux worker thread")?,
                );
            }
            Child::Agg { level, index } => {
                let spec = topo.spec(*level, *index);
                let (hub, endpoints) = LoopbackHub::new(spec.children.len());
                for (grandchild, gep) in spec.children.iter().zip(endpoints) {
                    spawn_child(
                        grandchild,
                        gep,
                        false,
                        topo,
                        tenants,
                        update,
                        seed,
                        decode_threads,
                        round_timeout,
                        shards,
                        tree,
                    )?;
                }
                let mut agg = Aggregator::new(tenants[0].1.clone(), seed, spec.id, spec.span)
                    .with_level(*level)
                    .with_decode_threads(decode_threads)
                    .with_session_protocols(tenants);
                if at_root {
                    agg = agg.with_dim_shards(topo.dim_shards());
                }
                if let Some(t) = round_timeout {
                    agg = agg.with_round_timeout(t);
                }
                let name = format!("dme-agg-{}", spec.id);
                tree.aggregators.push(
                    std::thread::Builder::new()
                        .name(name)
                        .spawn(move || {
                            let mut ep = ep;
                            agg.run(Box::new(hub), &mut ep)
                        })
                        .context("spawning aggregator thread")?,
                );
            }
        }
        Ok(())
    }

    let root_children = topo.root_children();
    let (hub, endpoints) = LoopbackHub::new(root_children.len());
    for (child, ep) in root_children.iter().zip(endpoints) {
        spawn_child(
            child,
            ep,
            true,
            topo,
            tenants,
            &update,
            seed,
            decode_threads,
            round_timeout,
            &mut shards,
            &mut tree,
        )?;
    }
    let expected: Vec<ChildKey> = root_children
        .iter()
        .map(|c| match c {
            Child::Worker(id) => ChildKey::Client(*id),
            Child::Agg { level, index } => {
                let spec = topo.spec(*level, *index);
                ChildKey::Aggregator { id: spec.id, span: spec.span }
            }
        })
        .collect();
    let barrier_msgs: usize = root_children
        .iter()
        .map(|c| match c {
            Child::Worker(_) => 1,
            Child::Agg { .. } => topo.dim_shards() as usize,
        })
        .sum();
    let mux = SessionMux::new(Box::new(hub));
    let mut leaders = Vec::with_capacity(tenants.len());
    for (session, proto) in tenants {
        let mut leader = Leader::new(proto.clone(), Box::new(mux.view(*session)), seed)
            .with_session(*session)
            .with_decode_threads(decode_threads)
            .with_expected_children(expected.clone())
            .with_barrier_messages(barrier_msgs);
        if let Some(t) = round_timeout {
            leader = leader.with_round_timeout(t);
        }
        leaders.push(leader);
    }
    Ok((mux, leaders, tree))
}

/// One round of tree aggregation over already-encoded uploads, without
/// transports or threads-per-node: the deterministic simulator used by
/// benches and the conformance suite. Every aggregator hop still
/// round-trips its `PartialUpload` through the real wire serialization,
/// so serialization fidelity is on the tested path.
pub struct TreeOutcome {
    pub outcome: RoundOutcome,
    /// `tier_ingress[0]` is the framed transport bytes crossing into the
    /// root; higher indices are the tiers below, ending with the leaf
    /// aggregators' ingress from the workers. For a flat topology the
    /// single entry is the workers' direct ingress at the root.
    pub tier_ingress: Vec<u64>,
}

pub fn aggregate_tree(
    proto: &dyn Protocol,
    state: &crate::protocol::RoundState,
    uploads: &[(u64, Vec<WeightedFrame>)],
    topo: &Topology,
    decode_threads: usize,
) -> Result<TreeOutcome> {
    topo.validate()?;
    ensure!(
        uploads.iter().all(|(c, _)| *c < topo.n_clients()),
        "upload client id outside the topology's client range"
    );
    let round = state.ctx.round;
    let internal_dim = proto.internal_dim();
    let full_range = (0u32, internal_dim as u32);
    // Leaf ingress accounting: what the workers' Upload messages cost on
    // the wire wherever they land (leaf aggregators, or the root when
    // flat).
    let worker_ingress: u64 = uploads
        .iter()
        .map(|(_, frames)| Message::upload_wire_len(frames) + 4) // + u32 frame prefix
        .sum();
    // Decode once — the same work the leaf tier's pools would do. Each
    // in-flight child carries the shard range it folded; everything is
    // full-dimension until the tier below the root slices its reports.
    let mut current: Vec<((u32, u32), DecodedUpload)> = decode_all(
        proto,
        state,
        uploads,
        decode_threads,
    )?
    .into_iter()
    .map(|d| (full_range, d))
    .collect();
    let mut ingress_rev = vec![worker_ingress];
    for (t_idx, tier) in topo.levels().iter().enumerate() {
        // Only the tier directly below the root shards its report: each
        // shard is an independent exact fold the root concatenates.
        let is_top = t_idx + 1 == topo.levels().len();
        let out_ranges = if is_top && topo.dim_shards() > 1 {
            topo.shard_ranges(internal_dim)
        } else {
            vec![full_range]
        };
        // Route every child into the aggregator whose span contains it.
        let mut buckets: Vec<Vec<DecodedUpload>> = (0..tier.len()).map(|_| Vec::new()).collect();
        for (_, d) in current.drain(..) {
            let (lo, hi) = d.origin.span();
            let idx = tier.partition_point(|s| s.span.1 <= lo);
            ensure!(
                idx < tier.len() && lo >= tier[idx].span.0 && hi <= tier[idx].span.1,
                "child span [{lo}, {hi}) fits no aggregator at this tier"
            );
            buckets[idx].push(d);
        }
        let mut tier_bytes = 0u64;
        let mut next = Vec::with_capacity(tier.len() * out_ranges.len());
        for (spec, mine) in tier.iter().zip(buckets) {
            if mine.is_empty() {
                continue; // a span with no uploads present sends nothing
            }
            let uplink_bits: u64 = mine.iter().map(|d| d.uplink_bits).sum();
            let n_frames: usize = mine.iter().map(|d| d.n_frames).sum();
            let slots = fold_spans(proto, &mine)?;
            for (k, &(lo, hi)) in out_ranges.iter().enumerate() {
                let shard_slots: Vec<SlotPartial> = if out_ranges.len() == 1 {
                    slots.clone()
                } else {
                    slots
                        .iter()
                        .map(|p| p.slice(lo as usize, hi as usize))
                        .collect::<Result<_>>()?
                };
                let msg = Message::PartialUpload {
                    agg_id: spec.id,
                    round,
                    span: spec.span,
                    // Client-edge accounting rides on the first shard
                    // only, so the root totals match the unsharded run.
                    uplink_bits: if k == 0 { uplink_bits } else { 0 },
                    n_frames: if k == 0 { n_frames as u64 } else { 0 },
                    shard: (lo, hi),
                    slots: shard_slots,
                };
                tier_bytes += msg.framed_len();
                // The wire round-trip: prove the serialized partials
                // carry the exact state.
                let bytes = msg.to_bytes()?;
                let Message::PartialUpload {
                    agg_id,
                    span,
                    uplink_bits,
                    n_frames,
                    shard,
                    slots,
                    ..
                } = Message::from_bytes(&bytes)?
                else {
                    bail!("PartialUpload did not survive the wire")
                };
                next.push((
                    shard,
                    DecodedUpload {
                        origin: ChildKey::Aggregator { id: agg_id, span },
                        slots: slots.into_iter().map(Some).collect(),
                        uplink_bits,
                        n_frames: n_frames as usize,
                    },
                ));
            }
        }
        ingress_rev.push(tier_bytes);
        current = next;
    }
    // Root fold: full-dimension children merge directly; sharded ones
    // fold per range and are concatenated back — bit-identical to the
    // unsharded fold ([`SpanAccum::absorb_sharded`]).
    let mut main = SpanAccum::new(internal_dim);
    let mut shard_accs: Vec<((u32, u32), SpanAccum)> = Vec::new();
    for (range, d) in current {
        if range == full_range || d.slots.is_empty() {
            main.fold(&d)?;
        } else {
            let width = (range.1 - range.0) as usize;
            let pos = match shard_accs.iter().position(|(r, _)| *r == range) {
                Some(p) => p,
                None => {
                    shard_accs.push((range, SpanAccum::new(width)));
                    shard_accs.len() - 1
                }
            };
            shard_accs[pos].1.fold(&d)?;
        }
    }
    main.absorb_sharded(&mut shard_accs)?;
    let outcome = main.finish(proto, state);
    ingress_rev.reverse(); // root first
    Ok(TreeOutcome { outcome, tier_ingress: ingress_rev })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::leader::aggregate_uploads_reference;
    use crate::coordinator::worker::mean_update;
    use crate::protocol::config::ProtocolConfig;
    use crate::protocol::Encoder;
    use crate::rng::Pcg64;

    fn gaussian_shards(n: usize, d: usize, seed: u64) -> Vec<Vec<Vec<f32>>> {
        let mut rng = Pcg64::new(seed);
        (0..n)
            .map(|_| {
                let mut x = vec![0.0f32; d];
                rng.fill_gaussian_f32(&mut x);
                vec![x]
            })
            .collect()
    }

    fn bits_of(means: &[Vec<f32>]) -> Vec<Vec<u32>> {
        means.iter().map(|m| m.iter().map(|v| v.to_bits()).collect()).collect()
    }

    #[test]
    fn local_tree_matches_flat_cluster_bits() {
        let d = 32;
        let n = 11;
        let spec = "rotated:k=16";
        let proto = ProtocolConfig::parse(spec, d).unwrap().build().unwrap();
        let shards = gaussian_shards(n, d, 5);
        let (mut flat_leader, flat_handles) =
            super::super::leader::spawn_local_cluster(proto, shards.clone(), mean_update(), 9);
        let mut flat_means = Vec::new();
        for r in 0..2 {
            flat_means.push(flat_leader.round(r, d as u32, &[]).unwrap().means);
        }
        flat_leader.shutdown().unwrap();
        for h in flat_handles {
            h.join().unwrap().unwrap();
        }

        let topo = Topology::uniform(n as u64, 4, 3).unwrap();
        let proto = ProtocolConfig::parse(spec, d).unwrap().build().unwrap();
        let (mut leader, tree) =
            spawn_local_tree(proto, shards, mean_update(), 9, &topo, 2, None).unwrap();
        for (r, want) in flat_means.iter().enumerate() {
            let got = leader.round(r as u64, d as u32, &[]).unwrap();
            assert_eq!(bits_of(&got.means), bits_of(want), "round {r} diverged");
        }
        leader.shutdown().unwrap();
        let reports = tree.join().unwrap();
        assert_eq!(reports.len(), topo.n_aggregators());
        assert!(reports.iter().all(|r| r.metrics.rounds.len() == 2));
        assert!(reports.iter().all(|r| r.up_bytes > 0 && r.down_bytes > 0));
    }

    #[test]
    fn aggregate_tree_matches_reference_and_accounts_ingress() {
        let d = 24;
        let n = 20;
        let spec = "klevel:k=16";
        let proto = ProtocolConfig::parse(spec, d).unwrap().build().unwrap();
        let ctx = RoundCtx::new(0, 77);
        let state = proto.prepare(&ctx);
        let mut enc = Encoder::new(proto.as_ref(), &state);
        let mut rng = Pcg64::new(13);
        let uploads: Vec<(u64, Vec<WeightedFrame>)> = (0..n)
            .map(|i| {
                let mut x = vec![0.0f32; d];
                rng.fill_gaussian_f32(&mut x);
                let frame = enc.encode(i, &x).unwrap();
                (i, vec![WeightedFrame { frame, weight: 1.0 }])
            })
            .collect();
        let want = aggregate_uploads_reference(proto.as_ref(), &state, uploads.clone()).unwrap();
        let topo = Topology::uniform(n, 5, 2).unwrap();
        let got = aggregate_tree(proto.as_ref(), &state, &uploads, &topo, 2).unwrap();
        assert_eq!(bits_of(&got.outcome.means), bits_of(&want.means));
        assert_eq!(got.outcome.weights, want.weights);
        assert_eq!(got.outcome.uplink_bits, want.uplink_bits);
        assert_eq!(got.tier_ingress.len(), 2);
        assert!(got.tier_ingress[1] > 0, "worker-edge ingress must be accounted");
        // Flat "tree": single ingress entry, equal to the workers' cost.
        let flat = aggregate_tree(proto.as_ref(), &state, &uploads, &Topology::flat(n), 1).unwrap();
        assert_eq!(flat.tier_ingress.len(), 1);
        assert_eq!(flat.tier_ingress[0], got.tier_ingress[1]);
    }
}
