//! Event-driven TCP hub: one reactor thread serves every worker
//! connection behind the same [`TransportHub`] contract as
//! [`TcpHub`](super::transport::TcpHub).
//!
//! The thread-per-connection hub spends one OS thread and one
//! `write_all` + `flush` syscall pair per connection per message — fine
//! at n = 512, dead at n = 100k. This module replaces those internals
//! with readiness polling over non-blocking sockets (epoll, via the thin
//! [`sys`] shim below — no new dependencies) while leaving every call
//! site untouched: `Leader`, `Aggregator`, and `Worker` still speak
//! `TransportHub`/`Endpoint`.
//!
//! # Readiness state machine
//!
//! Each accepted connection lives in exactly one of three states, driven
//! level-triggered from the single reactor thread:
//!
//! ```text
//!             readable (EPOLLIN | EPOLLRDHUP | EPOLLERR | EPOLLHUP)
//!                │  read until WouldBlock → FrameDecoder → Message
//!                ▼
//!   ┌──────── READING ────────┐     stage bytes, partial write
//!   │ (EPOLLIN only: nothing  │ ─────────────────────────────► WRITING
//!   │  staged for this conn)  │ ◄───────────────────────────── (EPOLLIN|
//!   └─────────────────────────┘     out-queue drained            EPOLLOUT)
//!                │
//!                ▼ EOF / parse error / write error / staging cap
//!              DEAD (deregistered, socket closed, counted in `n_dead`)
//! ```
//!
//! `EPOLLOUT` is armed only while a connection has staged bytes the
//! kernel would not take, so an idle round costs zero wakeups beyond the
//! uploads themselves.
//!
//! # Write batching and the flush contract
//!
//! Sends never hit the socket one message at a time. [`ReactorHub::broadcast`]
//! serializes a message **once**, hands the framed bytes to the reactor,
//! and the reactor stages them per connection in an [`OutQueue`]:
//! small frames are memcpy-coalesced into the queue's tail buffer, large
//! frames (a `RoundStart` payload) are enqueued as `Arc`-shared slices —
//! zero copies, every connection writes the same allocation. Each queue
//! is flushed with a single `writev` per readiness wakeup, so k messages
//! staged between wakeups cost one syscall, not k. The contract is
//! ordering + completeness, not immediacy: bytes leave in staging order,
//! and a `Stop` drains every queue (bounded grace) before the reactor
//! exits, so a final `Shutdown` broadcast is never lost.
//!
//! # Backpressure
//!
//! A connection whose peer stops reading accumulates staged bytes; at
//! [`MAX_STAGED_BYTES`] the reactor declares it dead instead of letting
//! one stalled worker grow an unbounded buffer. This mirrors the
//! thread-per-connection hub, where a stalled peer eventually errors the
//! blocking write — here the error is just detected at the staging cap
//! instead of at the socket buffer.
//!
//! # Accounting parity
//!
//! Byte accounting is identical to both other transports: every message
//! counts [`Message::framed_len`] (serialized size + the u32 length
//! prefix), downlink per live connection at broadcast, uplink per
//! completed frame. Conformance tests run the same rounds over loopback,
//! threads, and reactor and assert equal `bytes_moved`.

use std::collections::VecDeque;
use std::io::{self, IoSlice, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::fd::{AsRawFd, FromRawFd, OwnedFd, RawFd};
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use super::transport::{Envelope, Message, TransportHub, WireError};

/// Raw epoll / rlimit bindings. `std` already links libc; these are the
/// five calls the reactor needs, declared directly so no new crate is
/// pulled in.
mod sys {
    use std::os::fd::RawFd;

    /// Mirror of glibc's `struct epoll_event`. On x86-64 the kernel ABI
    /// packs it to 12 bytes; elsewhere it has natural alignment.
    #[derive(Clone, Copy)]
    #[cfg_attr(target_arch = "x86_64", repr(C, packed))]
    #[cfg_attr(not(target_arch = "x86_64"), repr(C))]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLOUT: u32 = 0x004;
    pub const EPOLLERR: u32 = 0x008;
    pub const EPOLLHUP: u32 = 0x010;
    pub const EPOLLRDHUP: u32 = 0x2000;

    pub const EPOLL_CTL_ADD: i32 = 1;
    pub const EPOLL_CTL_DEL: i32 = 2;
    pub const EPOLL_CTL_MOD: i32 = 3;
    pub const EPOLL_CLOEXEC: i32 = 0o2000000;

    pub const RLIMIT_NOFILE: i32 = 7;

    #[repr(C)]
    pub struct RLimit {
        pub cur: u64,
        pub max: u64,
    }

    extern "C" {
        pub fn epoll_create1(flags: i32) -> i32;
        pub fn epoll_ctl(epfd: i32, op: i32, fd: RawFd, event: *mut EpollEvent) -> i32;
        pub fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32) -> i32;
        pub fn getrlimit(resource: i32, rlim: *mut RLimit) -> i32;
        pub fn setrlimit(resource: i32, rlim: *const RLimit) -> i32;
    }
}

/// Readable-readiness mask (data, peer half-close, or error — all of
/// which the read path must observe).
pub const READABLE: u32 = sys::EPOLLIN | sys::EPOLLRDHUP | sys::EPOLLERR | sys::EPOLLHUP;
/// Writable-readiness bit, for checking returned event masks.
pub const WRITABLE: u32 = sys::EPOLLOUT;
/// Base interest for every connection.
pub const INTEREST_READ: u32 = sys::EPOLLIN | sys::EPOLLRDHUP;
/// Interest while the out-queue has residual bytes.
pub const INTEREST_READ_WRITE: u32 = INTEREST_READ | sys::EPOLLOUT;

/// epoll token reserved for the facade's wake pipe.
const WAKE_TOKEN: u64 = u64::MAX;

/// Same framing cap as the blocking transport's `read_msg`: a length
/// prefix beyond this is rejected **before** any buffer is grown.
const MAX_FRAME_LEN: usize = 1 << 30;

/// Per-connection staged-bytes cap (see the module docs on backpressure).
pub const MAX_STAGED_BYTES: usize = 1 << 30;

/// Raise `RLIMIT_NOFILE`'s soft limit to the hard limit and return
/// `(soft, hard)` after the attempt. Synthetic-client benches call this
/// before opening tens of thousands of sockets; failures are non-fatal
/// (the caller clamps its fan-out to whatever came back).
pub fn raise_nofile_limit() -> (u64, u64) {
    let mut rl = sys::RLimit { cur: 0, max: 0 };
    if unsafe { sys::getrlimit(sys::RLIMIT_NOFILE, &mut rl) } != 0 {
        return (1024, 1024);
    }
    if rl.cur < rl.max {
        let want = sys::RLimit { cur: rl.max, max: rl.max };
        if unsafe { sys::setrlimit(sys::RLIMIT_NOFILE, &want) } == 0 {
            rl.cur = rl.max;
        }
    }
    (rl.cur, rl.max)
}

/// Thin safe wrapper over an epoll instance. Level-triggered only — the
/// reactor always drains to `WouldBlock`, so edge-triggering would buy
/// nothing and cost a starvation class.
pub struct Epoll {
    fd: OwnedFd,
    raw: Vec<sys::EpollEvent>,
}

impl Epoll {
    /// Create a close-on-exec epoll instance.
    pub fn new() -> io::Result<Self> {
        let fd = unsafe { sys::epoll_create1(sys::EPOLL_CLOEXEC) };
        if fd < 0 {
            return Err(io::Error::last_os_error());
        }
        let fd = unsafe { OwnedFd::from_raw_fd(fd) };
        Ok(Epoll { fd, raw: vec![sys::EpollEvent { events: 0, data: 0 }; 512] })
    }

    fn ctl(&self, op: i32, fd: RawFd, token: u64, interest: u32) -> io::Result<()> {
        let mut ev = sys::EpollEvent { events: interest, data: token };
        let rc = unsafe { sys::epoll_ctl(self.fd.as_raw_fd(), op, fd, &mut ev) };
        if rc == 0 {
            Ok(())
        } else {
            Err(io::Error::last_os_error())
        }
    }

    /// Register `fd` with `token` and the given interest mask.
    pub fn add(&self, fd: RawFd, token: u64, interest: u32) -> io::Result<()> {
        self.ctl(sys::EPOLL_CTL_ADD, fd, token, interest)
    }

    /// Change the interest mask of a registered fd.
    pub fn modify(&self, fd: RawFd, token: u64, interest: u32) -> io::Result<()> {
        self.ctl(sys::EPOLL_CTL_MOD, fd, token, interest)
    }

    /// Deregister a fd.
    pub fn del(&self, fd: RawFd) -> io::Result<()> {
        let rc = unsafe {
            sys::epoll_ctl(self.fd.as_raw_fd(), sys::EPOLL_CTL_DEL, fd, std::ptr::null_mut())
        };
        if rc == 0 {
            Ok(())
        } else {
            Err(io::Error::last_os_error())
        }
    }

    /// Wait up to `timeout_ms` (`-1` = forever) and append the ready
    /// `(token, events)` pairs to `out`. EINTR is retried internally.
    pub fn wait_into(&mut self, out: &mut Vec<(u64, u32)>, timeout_ms: i32) -> io::Result<()> {
        let n = loop {
            let rc = unsafe {
                sys::epoll_wait(
                    self.fd.as_raw_fd(),
                    self.raw.as_mut_ptr(),
                    self.raw.len() as i32,
                    timeout_ms,
                )
            };
            if rc >= 0 {
                break rc as usize;
            }
            let err = io::Error::last_os_error();
            if err.kind() != io::ErrorKind::Interrupted {
                return Err(err);
            }
        };
        out.clear();
        for ev in &self.raw[..n] {
            let token = ev.data;
            let events = ev.events;
            out.push((token, events));
        }
        Ok(())
    }
}

/// Incremental frame decoder for the length-prefixed wire format: feed
/// arbitrary byte slices as the socket delivers them (down to one byte
/// at a time), take complete frames out. The length prefix is validated
/// against [`MAX_FRAME_LEN`] as soon as its four bytes are present —
/// before any frame-sized buffer growth — so a forged prefix cannot
/// reserve gigabytes.
pub struct FrameDecoder {
    buf: Vec<u8>,
    start: usize,
}

impl FrameDecoder {
    /// An empty decoder.
    pub fn new() -> Self {
        FrameDecoder { buf: Vec::new(), start: 0 }
    }

    /// Append freshly read bytes.
    pub fn feed(&mut self, bytes: &[u8]) {
        if self.start == self.buf.len() {
            self.buf.clear();
            self.start = 0;
        } else if self.start > 0 {
            // Drop consumed frames before growing; `start` only lags the
            // buffer while a frame is incomplete, so this is amortized
            // O(bytes), not O(bytes^2), even under one-byte feeds.
            self.buf.drain(..self.start);
            self.start = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// The next complete frame body (without its length prefix), if one
    /// is buffered. `Ok(None)` means "need more bytes"; `Err` means the
    /// stream is poisoned (oversized prefix) and the connection must die.
    pub fn next_frame(&mut self) -> Result<Option<&[u8]>> {
        let avail = self.buf.len() - self.start;
        if avail < 4 {
            return Ok(None);
        }
        let prefix: [u8; 4] = self.buf[self.start..self.start + 4].try_into().unwrap();
        let len = u32::from_le_bytes(prefix) as usize;
        ensure!(len <= MAX_FRAME_LEN, "message too large");
        if avail < 4 + len {
            return Ok(None);
        }
        let frame = &self.buf[self.start + 4..self.start + 4 + len];
        self.start += 4 + len;
        Ok(Some(frame))
    }

    /// Bytes buffered but not yet consumed as frames.
    pub fn pending(&self) -> usize {
        self.buf.len() - self.start
    }
}

impl Default for FrameDecoder {
    fn default() -> Self {
        Self::new()
    }
}

enum Chunk {
    /// Coalesced small frames — one memcpy in, one writev slice out.
    Owned(Vec<u8>),
    /// A large frame shared across every connection (the zero-copy
    /// broadcast path): all queues point at the same allocation.
    Shared(Arc<[u8]>),
}

impl Chunk {
    fn bytes(&self) -> &[u8] {
        match self {
            Chunk::Owned(v) => v,
            Chunk::Shared(a) => a,
        }
    }
}

/// Frames below this are memcpy-coalesced into the tail [`Chunk::Owned`]
/// buffer; at or above it they are enqueued `Arc`-shared. The crossover
/// is where one more writev slice stops being cheaper than the copy.
const COALESCE_LIMIT: usize = 4096;
/// Soft cap on the tail coalescing buffer before a new chunk is started
/// (keeps single chunks from growing unboundedly and re-allocating).
const TAIL_TARGET: usize = 64 * 1024;
/// Max slices per writev call (IOV_MAX is 1024 on Linux; 64 keeps the
/// stack frame small and is already far past the syscall's sweet spot).
const MAX_IOV: usize = 64;

/// Per-connection staged-write queue: what the batching contract in the
/// module docs is made of.
pub struct OutQueue {
    chunks: VecDeque<Chunk>,
    /// Bytes of `chunks[0]` already written.
    head: usize,
    /// Total unwritten bytes across all chunks.
    len: usize,
}

impl OutQueue {
    /// An empty queue.
    pub fn new() -> Self {
        OutQueue { chunks: VecDeque::new(), head: 0, len: 0 }
    }

    /// Total staged (unwritten) bytes.
    pub fn staged(&self) -> usize {
        self.len
    }

    /// True when nothing is waiting to be written.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Stage one framed message. Small frames coalesce into the tail
    /// buffer; large ones enqueue the shared allocation as-is. Errors
    /// when the connection is past [`MAX_STAGED_BYTES`] (backpressure).
    pub fn stage(&mut self, frame: &Arc<[u8]>) -> Result<()> {
        ensure!(
            self.len + frame.len() <= MAX_STAGED_BYTES,
            "connection stalled: {} bytes staged past the {} byte cap",
            self.len,
            MAX_STAGED_BYTES
        );
        self.len += frame.len();
        if frame.len() < COALESCE_LIMIT {
            if let Some(Chunk::Owned(tail)) = self.chunks.back_mut() {
                if tail.len() < TAIL_TARGET {
                    tail.extend_from_slice(frame);
                    return Ok(());
                }
            }
            let mut v = Vec::with_capacity(frame.len().max(1024));
            v.extend_from_slice(frame);
            self.chunks.push_back(Chunk::Owned(v));
        } else {
            self.chunks.push_back(Chunk::Shared(frame.clone()));
        }
        Ok(())
    }

    fn consume(&mut self, mut n: usize) {
        self.len -= n;
        while n > 0 {
            let avail = self.chunks[0].bytes().len() - self.head;
            if n >= avail {
                n -= avail;
                self.head = 0;
                self.chunks.pop_front();
            } else {
                self.head += n;
                n = 0;
            }
        }
    }

    /// Write as much as the sink takes in as few calls as possible
    /// (vectored). Returns `Ok(true)` when the queue drained, `Ok(false)`
    /// on `WouldBlock` with residual bytes (arm `EPOLLOUT`).
    pub fn flush<W: Write>(&mut self, w: &mut W) -> io::Result<bool> {
        loop {
            if self.chunks.is_empty() {
                return Ok(true);
            }
            let mut slices: Vec<IoSlice> = Vec::with_capacity(self.chunks.len().min(MAX_IOV));
            for (i, c) in self.chunks.iter().take(MAX_IOV).enumerate() {
                let bytes = c.bytes();
                if i == 0 {
                    slices.push(IoSlice::new(&bytes[self.head..]));
                } else {
                    slices.push(IoSlice::new(bytes));
                }
            }
            let wrote = match w.write_vectored(&slices) {
                Ok(0) => return Err(io::ErrorKind::WriteZero.into()),
                Ok(n) => n,
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return Ok(false),
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            };
            self.consume(wrote);
        }
    }
}

impl Default for OutQueue {
    fn default() -> Self {
        Self::new()
    }
}

enum Cmd {
    /// Framed bytes (length prefix included), serialized once by the
    /// facade; the reactor stages the same `Arc` on every live queue.
    Broadcast(Arc<[u8]>),
    Stop,
}

struct Conn {
    stream: TcpStream,
    dec: FrameDecoder,
    out: OutQueue,
    interest: u32,
}

struct Reactor {
    epoll: Epoll,
    conns: Vec<Option<Conn>>,
    live: usize,
    wake_rx: UnixStream,
    cmd_rx: Receiver<Cmd>,
    /// Dropped when the last connection dies, so the facade's `recv`
    /// fails with "all workers disconnected" exactly like the
    /// thread-per-connection hub. Carries `Result` so typed envelope
    /// rejections (bad magic / unknown version) reach the facade too.
    msg_tx: Option<Sender<Result<Envelope>>>,
    up: Arc<AtomicU64>,
    n_dead: Arc<AtomicUsize>,
    stopping: bool,
    read_buf: Vec<u8>,
}

impl Reactor {
    fn run(mut self) {
        let mut ready: Vec<(u64, u32)> = Vec::with_capacity(512);
        let mut stop_deadline: Option<Instant> = None;
        loop {
            let timeout = if self.stopping { 20 } else { -1 };
            if self.epoll.wait_into(&mut ready, timeout).is_err() {
                return;
            }
            for &(token, revents) in &ready {
                if token == WAKE_TOKEN {
                    self.drain_wake();
                } else {
                    let i = token as usize;
                    if revents & READABLE != 0 {
                        self.read_conn(i);
                    }
                    if revents & sys::EPOLLOUT != 0 {
                        self.write_conn(i);
                    }
                }
            }
            // Drained every pass, not only on wake events: a wake byte
            // may be consumed by a pass that ran before its command was
            // queued, and the stop path relies on polling.
            self.drain_cmds();
            if self.stopping {
                let deadline =
                    *stop_deadline.get_or_insert_with(|| Instant::now() + Duration::from_secs(2));
                let pending = self.conns.iter().flatten().any(|c| !c.out.is_empty());
                if self.live == 0 || !pending || Instant::now() >= deadline {
                    return;
                }
            }
        }
    }

    fn drain_wake(&mut self) {
        let mut buf = [0u8; 256];
        loop {
            match self.wake_rx.read(&mut buf) {
                Ok(0) => return, // facade dropped its end
                Ok(_) => continue,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => return, // WouldBlock: fully drained
            }
        }
    }

    fn drain_cmds(&mut self) {
        loop {
            match self.cmd_rx.try_recv() {
                Ok(Cmd::Broadcast(frame)) => self.stage_broadcast(frame),
                Ok(Cmd::Stop) => self.stopping = true,
                Err(_) => return,
            }
        }
    }

    fn stage_broadcast(&mut self, frame: Arc<[u8]>) {
        for i in 0..self.conns.len() {
            let staged = match self.conns[i].as_mut() {
                Some(c) => c.out.stage(&frame).is_ok(),
                None => continue,
            };
            if !staged {
                // Past the backpressure cap: the connection is stalled
                // beyond salvage (see module docs).
                self.kill(i);
                continue;
            }
            // Opportunistic flush: the socket is almost always writable,
            // so the common case is one writev now and no EPOLLOUT
            // round-trip at all.
            self.write_conn(i);
        }
    }

    fn read_conn(&mut self, i: usize) {
        loop {
            let res = match self.conns[i].as_mut() {
                Some(c) => c.stream.read(&mut self.read_buf),
                None => return,
            };
            match res {
                Ok(0) => {
                    self.kill(i);
                    return;
                }
                Ok(n) => {
                    // Parse errors kill the connection — after `ingest`
                    // forwarded any *typed* envelope rejection (bad magic
                    // or unknown version) to the facade, matching the
                    // per-connection reader threads. Everything else
                    // keeps the silent-kill contract.
                    if self.ingest(i, n).is_err() {
                        self.kill(i);
                        return;
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.kill(i);
                    return;
                }
            }
        }
    }

    fn ingest(&mut self, i: usize, n: usize) -> Result<()> {
        let conn = match self.conns[i].as_mut() {
            Some(c) => c,
            None => return Ok(()),
        };
        conn.dec.feed(&self.read_buf[..n]);
        while let Some(frame) = conn.dec.next_frame()? {
            self.up.fetch_add(frame.len() as u64 + 4, Ordering::Relaxed);
            match Envelope::from_bytes(frame) {
                Ok(env) => {
                    if let Some(tx) = &self.msg_tx {
                        // A dropped receiver just means the facade is
                        // going away; the stop command follows.
                        let _ = tx.send(Ok(env));
                    }
                }
                Err(e) => {
                    // A protocol-identity failure is *reported* before
                    // the connection dies — typed rejection, never a
                    // silent kill. Other parse errors stay silent.
                    let typed = e.downcast_ref::<WireError>().is_some();
                    if typed {
                        if let Some(tx) = &self.msg_tx {
                            let _ = tx.send(Err(e));
                        }
                        bail!("typed envelope rejection");
                    }
                    return Err(e);
                }
            }
        }
        Ok(())
    }

    fn write_conn(&mut self, i: usize) {
        let flushed = match self.conns[i].as_mut() {
            Some(c) => c.out.flush(&mut c.stream),
            None => return,
        };
        match flushed {
            Ok(true) => self.set_interest(i, INTEREST_READ),
            Ok(false) => self.set_interest(i, INTEREST_READ_WRITE),
            Err(_) => self.kill(i),
        }
    }

    fn set_interest(&mut self, i: usize, want: u32) {
        let (fd, cur) = match self.conns[i].as_ref() {
            Some(c) => (c.stream.as_raw_fd(), c.interest),
            None => return,
        };
        if want == cur {
            return;
        }
        if self.epoll.modify(fd, i as u64, want).is_ok() {
            if let Some(c) = self.conns[i].as_mut() {
                c.interest = want;
            }
        } else {
            self.kill(i);
        }
    }

    fn kill(&mut self, i: usize) {
        if let Some(conn) = self.conns[i].take() {
            let _ = self.epoll.del(conn.stream.as_raw_fd());
            self.live -= 1;
            self.n_dead.fetch_add(1, Ordering::Release);
            if self.live == 0 {
                self.msg_tx = None;
            }
        }
    }
}

/// A bound-but-not-yet-accepting reactor hub, mirroring
/// [`TcpHubBinding`](super::transport::TcpHubBinding): bind port 0,
/// read the real address, then accept.
pub struct ReactorBinding {
    listener: TcpListener,
}

impl ReactorBinding {
    /// Bind `addr` without accepting yet.
    pub fn bind(addr: &str) -> Result<Self> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        Ok(ReactorBinding { listener })
    }

    /// The address the listener actually bound.
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Accept exactly `n` worker connections, register them with the
    /// reactor, and start serving. Peak thread count is 1 (the reactor),
    /// independent of `n`.
    pub fn accept(self, n: usize) -> Result<ReactorHub> {
        let epoll = Epoll::new().context("creating epoll instance")?;
        let mut conns = Vec::with_capacity(n);
        for i in 0..n {
            let (stream, _peer) = self.listener.accept().context("accepting worker")?;
            stream.set_nodelay(true).ok();
            stream.set_nonblocking(true).context("setting nonblocking")?;
            epoll
                .add(stream.as_raw_fd(), i as u64, INTEREST_READ)
                .context("registering worker socket")?;
            conns.push(Some(Conn {
                stream,
                dec: FrameDecoder::new(),
                out: OutQueue::new(),
                interest: INTEREST_READ,
            }));
        }
        let (wake_tx, wake_rx) = UnixStream::pair().context("creating wake pipe")?;
        wake_tx.set_nonblocking(true)?;
        wake_rx.set_nonblocking(true)?;
        epoll.add(wake_rx.as_raw_fd(), WAKE_TOKEN, sys::EPOLLIN).context("registering wake")?;
        let (cmd_tx, cmd_rx) = std::sync::mpsc::channel();
        let (msg_tx, msg_rx) = std::sync::mpsc::channel();
        let down = Arc::new(AtomicU64::new(0));
        let up = Arc::new(AtomicU64::new(0));
        let n_dead = Arc::new(AtomicUsize::new(0));
        let reactor = Reactor {
            epoll,
            conns,
            live: n,
            wake_rx,
            cmd_rx,
            // Zero workers means zero possible uploads: match the
            // threads hub, whose upload channel disconnects immediately.
            msg_tx: if n == 0 { None } else { Some(msg_tx) },
            up: up.clone(),
            n_dead: n_dead.clone(),
            stopping: false,
            read_buf: vec![0u8; 256 * 1024],
        };
        let handle = std::thread::Builder::new()
            .name("dme-reactor".to_string())
            .spawn(move || reactor.run())
            .context("spawning reactor thread")?;
        Ok(ReactorHub {
            n,
            cmd_tx,
            wake_tx,
            from_workers: msg_rx,
            down,
            up,
            n_dead,
            reactor: Some(handle),
        })
    }
}

/// The leader-side facade over the reactor thread: implements
/// [`TransportHub`] with the exact semantics of
/// [`TcpHub`](super::transport::TcpHub) — same byte accounting, same
/// error surface — over one thread instead of one per connection.
pub struct ReactorHub {
    n: usize,
    cmd_tx: Sender<Cmd>,
    wake_tx: UnixStream,
    from_workers: Receiver<Result<Envelope>>,
    down: Arc<AtomicU64>,
    up: Arc<AtomicU64>,
    n_dead: Arc<AtomicUsize>,
    reactor: Option<std::thread::JoinHandle<()>>,
}

impl ReactorHub {
    /// Bind `addr` and accept exactly `n` worker connections.
    pub fn listen(addr: &str, n: usize) -> Result<Self> {
        ReactorBinding::bind(addr)?.accept(n)
    }

    fn wake(&self) {
        // A full pipe already guarantees a pending wakeup, so WouldBlock
        // (and any other failure: the reactor exiting closes its end) is
        // fine to ignore.
        let _ = (&self.wake_tx).write(&[1u8]);
    }
}

impl TransportHub for ReactorHub {
    fn n_workers(&self) -> usize {
        self.n
    }

    fn broadcast_session(&mut self, session: u16, msg: &Message) -> Result<()> {
        // Serialize once (validating, like both other hubs); every
        // connection shares these bytes.
        let body = msg.to_bytes_for(session)?;
        let mut framed = Vec::with_capacity(body.len() + 4);
        framed.extend_from_slice(&(body.len() as u32).to_le_bytes());
        framed.extend_from_slice(&body);
        let framed: Arc<[u8]> = framed.into();
        let framed_len = framed.len() as u64;
        // Account before handing off, against the connections known to
        // be live: identical to the threads hub in every all-live round,
        // and `bytes_moved` never lags a completed broadcast.
        let dead = self.n_dead.load(Ordering::Acquire);
        self.down.fetch_add(framed_len * (self.n - dead.min(self.n)) as u64, Ordering::Relaxed);
        self.cmd_tx
            .send(Cmd::Broadcast(framed))
            .map_err(|_| anyhow::anyhow!("reactor thread exited"))?;
        self.wake();
        // Best-effort like the threads hub: the live connections got the
        // message staged; a known-dead one is still a send error.
        ensure!(dead == 0, "worker disconnected");
        Ok(())
    }

    fn recv_env(&mut self) -> Result<Envelope> {
        self.from_workers.recv().context("all workers disconnected")?
    }

    fn recv_env_timeout(&mut self, timeout: Duration) -> Result<Option<Envelope>> {
        match self.from_workers.recv_timeout(timeout) {
            Ok(m) => Ok(Some(m?)),
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => bail!("all workers disconnected"),
        }
    }

    fn bytes_moved(&self) -> (u64, u64) {
        (self.down.load(Ordering::Acquire), self.up.load(Ordering::Acquire))
    }
}

impl Drop for ReactorHub {
    fn drop(&mut self) {
        // Same teardown as the threads hub: a final Shutdown broadcast,
        // then stop. The reactor drains staged bytes (bounded grace)
        // before closing the sockets, so the Shutdown actually lands.
        let _ = self.broadcast(&Message::Shutdown);
        let _ = self.cmd_tx.send(Cmd::Stop);
        self.wake();
        if let Some(h) = self.reactor.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::transport::{TcpEndpoint, WeightedFrame};
    use super::*;
    use crate::protocol::Frame;

    fn upload(client: u64) -> Message {
        Message::Upload {
            client,
            round: 1,
            frames: vec![WeightedFrame {
                frame: Frame::new(vec![client as u8; 5], 37),
                weight: 1.0,
            }],
        }
    }

    fn framed(msg: &Message) -> Vec<u8> {
        let body = msg.to_bytes().unwrap();
        let mut out = (body.len() as u32).to_le_bytes().to_vec();
        out.extend_from_slice(&body);
        out
    }

    #[test]
    fn decoder_handles_one_byte_dribble() {
        // Every legal delivery schedule must produce the same frames; the
        // worst case is one byte at a time, with splits falling inside
        // the length prefix itself.
        let msgs = vec![
            upload(3),
            Message::Shutdown,
            Message::SpecChange { round: 2, spec: "binary".into() },
        ];
        let mut wire = Vec::new();
        for m in &msgs {
            wire.extend_from_slice(&framed(m));
        }
        let mut dec = FrameDecoder::new();
        let mut got = Vec::new();
        for b in &wire {
            dec.feed(std::slice::from_ref(b));
            while let Some(frame) = dec.next_frame().unwrap() {
                got.push(Message::from_bytes(frame).unwrap());
            }
        }
        assert_eq!(got.len(), msgs.len());
        for (sent, back) in msgs.iter().zip(&got) {
            assert_eq!(sent.to_bytes().unwrap(), back.to_bytes().unwrap());
        }
        assert_eq!(dec.pending(), 0);
    }

    #[test]
    fn decoder_handles_split_inside_length_prefix() {
        let wire = framed(&upload(9));
        for cut in 1..4 {
            let mut dec = FrameDecoder::new();
            dec.feed(&wire[..cut]);
            assert!(dec.next_frame().unwrap().is_none(), "cut {cut}: no full prefix yet");
            dec.feed(&wire[cut..]);
            let frame = dec.next_frame().unwrap().expect("complete frame");
            assert_eq!(Message::from_bytes(frame).unwrap().to_bytes().unwrap(), wire[4..]);
        }
    }

    #[test]
    fn decoder_rejects_oversized_prefix_before_allocating() {
        let mut dec = FrameDecoder::new();
        dec.feed(&u32::MAX.to_le_bytes());
        assert!(dec.next_frame().is_err(), "oversized prefix accepted");
        // The rejection happened on the 4 header bytes alone: nothing
        // frame-sized was ever reserved.
        assert!(dec.buf.capacity() < 1024, "decoder reserved {} bytes", dec.buf.capacity());
        // Exactly at the cap is still legal (the frame just never
        // completes here).
        let mut dec = FrameDecoder::new();
        dec.feed(&(MAX_FRAME_LEN as u32).to_le_bytes());
        assert!(dec.next_frame().unwrap().is_none());
    }

    #[test]
    fn out_queue_coalesces_small_frames() {
        let mut q = OutQueue::new();
        for i in 0..100u8 {
            let frame: Arc<[u8]> = vec![i; 10].into();
            q.stage(&frame).unwrap();
        }
        assert_eq!(q.staged(), 1000);
        assert_eq!(q.chunks.len(), 1, "small frames must coalesce into one chunk");
        let mut sink = Vec::new();
        assert!(q.flush(&mut sink).unwrap());
        assert_eq!(sink.len(), 1000);
        assert!(q.is_empty());
    }

    #[test]
    fn out_queue_shares_large_frames() {
        let big: Arc<[u8]> = vec![7u8; COALESCE_LIMIT * 2].into();
        let mut queues: Vec<OutQueue> = (0..3).map(|_| OutQueue::new()).collect();
        for q in &mut queues {
            q.stage(&big).unwrap();
        }
        // One allocation, three queues, zero copies.
        assert_eq!(Arc::strong_count(&big), 4);
        for q in &mut queues {
            let mut sink = Vec::new();
            assert!(q.flush(&mut sink).unwrap());
            assert_eq!(sink.len(), big.len());
        }
    }

    #[test]
    fn out_queue_enforces_staging_cap() {
        let mut q = OutQueue::new();
        let frame: Arc<[u8]> = vec![0u8; COALESCE_LIMIT].into();
        q.len = MAX_STAGED_BYTES - COALESCE_LIMIT / 2; // simulate a stalled peer
        assert!(q.stage(&frame).is_err(), "staging past the cap must error");
    }

    #[test]
    fn reactor_hub_round_trip() {
        let binding = ReactorBinding::bind("127.0.0.1:0").unwrap();
        let addr = binding.local_addr().unwrap();
        let hub_thread = std::thread::spawn(move || {
            let mut hub = binding.accept(2).unwrap();
            hub.broadcast(&Message::RoundStart {
                round: 1,
                shared_seed: 77,
                dim: 2,
                payload: vec![9.0, 1.0, 3.5].into(),
            })
            .unwrap();
            let mut clients = Vec::new();
            for _ in 0..2 {
                if let Message::Upload { client, .. } = hub.recv().unwrap() {
                    clients.push(client);
                }
            }
            clients.sort_unstable();
            let moved = hub.bytes_moved();
            (clients, moved)
        });
        let mut workers = Vec::new();
        for id in 0..2u64 {
            workers.push(std::thread::spawn(move || {
                let mut ep = TcpEndpoint::connect(&addr.to_string()).unwrap();
                match ep.recv().unwrap() {
                    Message::RoundStart { round, payload, .. } => {
                        assert_eq!(round, 1);
                        assert_eq!(&payload[..], &[9.0, 1.0, 3.5]);
                    }
                    other => panic!("expected RoundStart, got {other:?}"),
                }
                ep.send(&upload(id)).unwrap();
                assert!(matches!(ep.recv().unwrap(), Message::Shutdown));
            }));
        }
        let (clients, (down, up)) = hub_thread.join().unwrap();
        for w in workers {
            w.join().unwrap();
        }
        assert_eq!(clients, vec![0, 1]);
        // Exact accounting: one RoundStart down to each of 2 workers
        // (the Shutdown lands after bytes_moved was read), one upload up
        // from each.
        let rs = Message::RoundStart {
            round: 1,
            shared_seed: 77,
            dim: 2,
            payload: vec![9.0, 1.0, 3.5].into(),
        };
        assert_eq!(down, rs.framed_len() * 2);
        assert_eq!(up, upload(0).framed_len() + upload(1).framed_len());
    }

    #[test]
    fn reactor_survives_one_byte_deliveries_end_to_end() {
        let binding = ReactorBinding::bind("127.0.0.1:0").unwrap();
        let addr = binding.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let mut stream = std::net::TcpStream::connect(addr).unwrap();
            stream.set_nodelay(true).unwrap();
            for b in framed(&upload(5)) {
                stream.write_all(&[b]).unwrap();
            }
            stream
        });
        let mut hub = binding.accept(1).unwrap();
        match hub.recv().unwrap() {
            Message::Upload { client, .. } => assert_eq!(client, 5),
            other => panic!("expected Upload, got {other:?}"),
        }
        drop(client.join().unwrap());
    }

    #[test]
    fn reactor_kills_connection_on_oversized_prefix() {
        let binding = ReactorBinding::bind("127.0.0.1:0").unwrap();
        let addr = binding.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let mut stream = std::net::TcpStream::connect(addr).unwrap();
            stream.write_all(&u32::MAX.to_le_bytes()).unwrap();
            stream
        });
        let mut hub = binding.accept(1).unwrap();
        // The poisoned connection was the only one, so the upload
        // channel must disconnect rather than hang.
        assert!(hub.recv().is_err(), "oversized prefix must kill the stream");
        drop(client.join().unwrap());
    }

    #[test]
    fn reactor_surfaces_typed_envelope_errors() {
        // A peer speaking a future wire version is a *reported* typed
        // rejection at the facade — not a silent connection kill.
        let binding = ReactorBinding::bind("127.0.0.1:0").unwrap();
        let addr = binding.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let mut stream = std::net::TcpStream::connect(addr).unwrap();
            let mut bytes = Message::Shutdown.to_bytes().unwrap();
            bytes[2] = bytes[2].wrapping_add(1); // future wire version
            let mut framed = (bytes.len() as u32).to_le_bytes().to_vec();
            framed.extend_from_slice(&bytes);
            stream.write_all(&framed).unwrap();
            stream
        });
        let mut hub = binding.accept(1).unwrap();
        let err = hub.recv_env().unwrap_err();
        assert!(
            matches!(err.downcast_ref::<WireError>(), Some(WireError::UnknownVersion(_))),
            "expected typed UnknownVersion, got {err:?}"
        );
        drop(client.join().unwrap());
    }

    #[test]
    fn reactor_preserves_envelope_sessions() {
        let binding = ReactorBinding::bind("127.0.0.1:0").unwrap();
        let addr = binding.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let mut ep = TcpEndpoint::connect(&addr.to_string()).unwrap();
            let env = ep.recv_envelope().unwrap();
            assert_eq!(env.session, 11, "downlink session must survive the reactor");
            ep.send_session(23, upload(1)).unwrap();
            ep
        });
        let mut hub = binding.accept(1).unwrap();
        hub.broadcast_session(11, &Message::RoundStart {
            round: 0,
            shared_seed: 0,
            dim: 1,
            payload: vec![1.0].into(),
        })
        .unwrap();
        let env = hub.recv_env().unwrap();
        assert_eq!(env.session, 23, "uplink session must survive the reactor");
        drop(client.join().unwrap());
    }

    #[test]
    fn reactor_recv_errors_when_all_workers_hang_up() {
        let binding = ReactorBinding::bind("127.0.0.1:0").unwrap();
        let addr = binding.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let stream = std::net::TcpStream::connect(addr).unwrap();
            drop(stream);
        });
        let mut hub = binding.accept(1).unwrap();
        client.join().unwrap();
        assert!(hub.recv().is_err(), "EOF on the last connection must error recv");
        // And a subsequent broadcast reports the death.
        assert!(hub.broadcast(&Message::Shutdown).is_err());
    }

    #[test]
    fn raise_nofile_reports_sane_limits() {
        let (soft, hard) = raise_nofile_limit();
        assert!(soft >= 256, "soft fd limit {soft} suspiciously low");
        assert!(hard >= soft);
    }
}
