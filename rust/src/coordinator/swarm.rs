//! Synthetic client swarm: thousands of protocol-correct TCP clients
//! driven by **one** thread.
//!
//! Benches and soak tests need to show a single hub sustaining rounds at
//! n in the tens of thousands; spawning that many real `Worker` threads
//! would measure the harness, not the hub. This driver opens `n` real
//! sockets, multiplexes them over the same epoll/[`FrameDecoder`]/
//! [`OutQueue`] machinery as the reactor hub, and delegates protocol
//! behavior to a caller-supplied callback — which may be as cheap as an
//! empty `Upload` (transport benches) or a full `Worker::step_with`
//! encode (soak tests).
//!
//! Lifecycle: connect all `n` (blocking, sequential — the listener must
//! already be bound), then serve readiness events until every
//! connection has been closed by a `Shutdown` message or by the peer.

use std::io::{self, Read};
use std::net::{SocketAddr, TcpStream};
use std::os::fd::AsRawFd;
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{bail, Context, Result};

use super::reactor::{
    Epoll, FrameDecoder, INTEREST_READ, INTEREST_READ_WRITE, OutQueue, READABLE, WRITABLE,
};
use super::transport::{Envelope, Message};

/// What one swarm client does with one received message — the scenario
/// engine's fault-injection surface ([`Swarm::spawn_actions`]).
#[derive(Debug, Clone)]
pub enum SwarmAction {
    /// Answer with this envelope (the protocol-correct path).
    Reply(Envelope),
    /// Say nothing: a per-round dropout. The connection stays open, so
    /// the parent's barrier has to time out on this client.
    Silent,
    /// Close the connection immediately: a mid-round disconnect. The
    /// parent's hub discovers a dead child on its next broadcast.
    Hangup,
}

/// What a finished swarm observed, for bench/soak assertions.
#[derive(Debug, Clone, Copy)]
pub struct SwarmReport {
    /// Connections successfully opened (always the requested `n`).
    pub connected: usize,
    /// Messages the callback answered with.
    pub replies_sent: u64,
    /// Complete frames received across all connections.
    pub frames_received: u64,
}

/// Handle to a running swarm driver thread.
pub struct Swarm {
    handle: JoinHandle<Result<SwarmReport>>,
}

impl Swarm {
    /// Connect `n` clients to `addr` and serve them from one driver
    /// thread. For each received message, `reply(client_index, &msg)`
    /// decides the response (`None` = stay silent); `Shutdown` closes
    /// the connection and is never passed to the callback. The callback
    /// runs on the driver thread, so heavy work in it serializes the
    /// swarm — by design, that is still how a 16k-client bench stays at
    /// two threads instead of 16k.
    pub fn spawn<F>(addr: SocketAddr, n: usize, mut reply: F) -> Result<Swarm>
    where
        F: FnMut(usize, &Message) -> Option<Message> + Send + 'static,
    {
        // Message-level replies answer on the session they were asked
        // on — exactly what a protocol-correct client does, including
        // under session multiplexing.
        Self::spawn_env(addr, n, move |i, env: &Envelope| {
            reply(i, &env.msg).map(|msg| Envelope { session: env.session, msg })
        })
    }

    /// [`Self::spawn`], with full envelope visibility: the callback sees
    /// each message's session id and chooses the session of its reply.
    pub fn spawn_env<F>(addr: SocketAddr, n: usize, reply: F) -> Result<Swarm>
    where
        F: FnMut(usize, &Envelope) -> Option<Envelope> + Send + 'static,
    {
        Self::spawn_mux(addr, n, 1, reply)
    }

    /// [`Self::spawn_env`] for multi-tenant links: each client serves
    /// `sessions` concurrent sessions over its one connection and hangs
    /// up only after a `Shutdown` has arrived for every one of them. A
    /// protocol-correct multiplexed client never closes the shared
    /// socket while a co-tenant is still live — the parent's reactor
    /// treats a broadcast into a dead connection as a worker loss.
    pub fn spawn_mux<F>(addr: SocketAddr, n: usize, sessions: usize, mut reply: F) -> Result<Swarm>
    where
        F: FnMut(usize, &Envelope) -> Option<Envelope> + Send + 'static,
    {
        Self::spawn_actions(addr, n, sessions, move |i, env| match reply(i, env) {
            Some(resp) => SwarmAction::Reply(resp),
            None => SwarmAction::Silent,
        })
    }

    /// [`Self::spawn_mux`] with the full fault-injection surface: the
    /// callback may answer, stay silent, or hang up the connection —
    /// what the scenario engine uses to turn one driver thread into a
    /// deterministic churn/straggler population.
    pub fn spawn_actions<F>(addr: SocketAddr, n: usize, sessions: usize, reply: F) -> Result<Swarm>
    where
        F: FnMut(usize, &Envelope) -> SwarmAction + Send + 'static,
    {
        let handle = std::thread::Builder::new()
            .name("dme-swarm".to_string())
            .spawn(move || -> Result<SwarmReport> {
                let epoll = Epoll::new().context("creating swarm epoll")?;
                let mut clients = Vec::with_capacity(n);
                for i in 0..n {
                    let stream = TcpStream::connect(addr)
                        .with_context(|| format!("swarm client {i} connecting {addr}"))?;
                    stream.set_nodelay(true).ok();
                    stream.set_nonblocking(true).context("setting nonblocking")?;
                    epoll
                        .add(stream.as_raw_fd(), i as u64, INTEREST_READ)
                        .context("registering swarm client")?;
                    clients.push(Some(Client {
                        stream,
                        dec: FrameDecoder::new(),
                        out: OutQueue::new(),
                        interest: INTEREST_READ,
                        shutdowns_seen: 0,
                    }));
                }
                let driver = Driver {
                    epoll,
                    clients,
                    live: n,
                    reply,
                    read_buf: vec![0u8; 64 * 1024],
                    shutdowns_to_close: sessions.max(1),
                    replies_sent: 0,
                    frames_received: 0,
                };
                Ok(driver.run())
            })
            .context("spawning swarm thread")?;
        Ok(Swarm { handle })
    }

    /// Wait for every client to disconnect and return the tally.
    pub fn join(self) -> Result<SwarmReport> {
        match self.handle.join() {
            Ok(report) => report,
            Err(_) => bail!("swarm thread panicked"),
        }
    }
}

struct Client {
    stream: TcpStream,
    dec: FrameDecoder,
    out: OutQueue,
    interest: u32,
    /// Shutdowns received so far; the connection closes at
    /// `Driver::shutdowns_to_close` (one per hosted session).
    shutdowns_seen: usize,
}

struct Driver<F> {
    epoll: Epoll,
    clients: Vec<Option<Client>>,
    live: usize,
    reply: F,
    read_buf: Vec<u8>,
    shutdowns_to_close: usize,
    replies_sent: u64,
    frames_received: u64,
}

impl<F: FnMut(usize, &Envelope) -> SwarmAction> Driver<F> {
    fn run(mut self) -> SwarmReport {
        let mut ready: Vec<(u64, u32)> = Vec::with_capacity(512);
        while self.live > 0 {
            if self.epoll.wait_into(&mut ready, -1).is_err() {
                break;
            }
            for &(token, revents) in &ready {
                let i = token as usize;
                if revents & READABLE != 0 {
                    self.pump(i);
                }
                if revents & WRITABLE != 0 {
                    self.flush(i);
                }
            }
        }
        SwarmReport {
            connected: self.clients.len(),
            replies_sent: self.replies_sent,
            frames_received: self.frames_received,
        }
    }

    /// Read until `WouldBlock`, answering complete messages as they
    /// appear, then flush whatever the answers staged.
    fn pump(&mut self, i: usize) {
        loop {
            let res = match self.clients[i].as_mut() {
                Some(c) => c.stream.read(&mut self.read_buf),
                None => return,
            };
            match res {
                Ok(0) => return self.kill(i),
                Ok(n) => match self.ingest(i, n) {
                    Ok(true) => {}
                    // Shutdown received, or the stream is poisoned:
                    // either way this client is done.
                    Ok(false) | Err(_) => return self.kill(i),
                },
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => return self.kill(i),
            }
        }
        self.flush(i);
    }

    /// Returns `Ok(false)` when the connection should close (Shutdown).
    fn ingest(&mut self, i: usize, n: usize) -> Result<bool> {
        let client = match self.clients[i].as_mut() {
            Some(c) => c,
            None => return Ok(true),
        };
        client.dec.feed(&self.read_buf[..n]);
        while let Some(frame) = client.dec.next_frame()? {
            self.frames_received += 1;
            let env = Envelope::from_bytes(frame)?;
            if matches!(env.msg, Message::Shutdown) {
                client.shutdowns_seen += 1;
                if client.shutdowns_seen >= self.shutdowns_to_close {
                    return Ok(false);
                }
                continue;
            }
            match (self.reply)(i, &env) {
                SwarmAction::Reply(resp) => {
                    let body = resp.to_bytes()?;
                    let mut framed = Vec::with_capacity(body.len() + 4);
                    framed.extend_from_slice(&(body.len() as u32).to_le_bytes());
                    framed.extend_from_slice(&body);
                    let framed: Arc<[u8]> = framed.into();
                    client.out.stage(&framed)?;
                    self.replies_sent += 1;
                }
                SwarmAction::Silent => {}
                // Mid-round disconnect: close like a Shutdown would.
                SwarmAction::Hangup => return Ok(false),
            }
        }
        Ok(true)
    }

    fn flush(&mut self, i: usize) {
        let (fd, cur, res) = match self.clients[i].as_mut() {
            Some(c) => (c.stream.as_raw_fd(), c.interest, c.out.flush(&mut c.stream)),
            None => return,
        };
        let want = match res {
            Ok(true) => INTEREST_READ,
            Ok(false) => INTEREST_READ_WRITE,
            Err(_) => return self.kill(i),
        };
        if want == cur {
            return;
        }
        if self.epoll.modify(fd, i as u64, want).is_ok() {
            if let Some(c) = self.clients[i].as_mut() {
                c.interest = want;
            }
        } else {
            self.kill(i);
        }
    }

    fn kill(&mut self, i: usize) {
        if let Some(c) = self.clients[i].take() {
            let _ = self.epoll.del(c.stream.as_raw_fd());
            self.live -= 1;
        }
    }
}
