//! Coordinator metrics: per-round and cumulative communication/latency
//! accounting, printed by the CLI and consumed by the bench harness —
//! plus the per-tier rollup for aggregation trees ([`TierMetrics`]),
//! which makes the point of the tier visible: root ingest shrinks from
//! O(n · frames) to O(root-fan-in · slots) while decode work spreads
//! across the tree.

use std::time::Duration;

/// One round's numbers.
#[derive(Clone, Debug)]
pub struct RoundMetrics {
    pub round: u64,
    /// Exact protocol payload bits this round (excludes transport framing).
    pub uplink_bits: u64,
    /// Non-silent frames decoded.
    pub n_frames: usize,
    /// Leader-observed wall time for the round.
    pub wall: Duration,
    /// Time the leader thread spent blocked waiting for uploads (barrier
    /// wait: worker compute + network). With the streaming pipeline,
    /// decode overlaps this wait instead of running after it.
    pub wait_wall: Duration,
    /// Time spent decoding uploads and merging partials, summed across
    /// decode threads — CPU time, so it can exceed `wall` when the
    /// leader runs more than one decode thread.
    pub decode_wall: Duration,
    /// Cumulative transport-level bytes after this round.
    pub cum_down_bytes: u64,
    pub cum_up_bytes: u64,
    /// Fraction of enrolled clients whose contribution made this round's
    /// fold: p̂ = |S| / n. 1.0 for a full round; < 1.0 when a
    /// partial-round barrier (`BarrierPolicy::Partial`) finalized from
    /// the survivors — the Lemma 8 sampling rate the estimate was
    /// rescaled by.
    pub participation: f64,
    /// Duplicate `Upload`s for the *current* round that arrived after
    /// the barrier had already counted that client — dropped, not folded
    /// twice.
    pub duplicate_uploads: u64,
}

/// Whole-experiment metrics.
#[derive(Clone, Debug, Default)]
pub struct ExperimentMetrics {
    pub rounds: Vec<RoundMetrics>,
    /// Mid-session protocol switches: `(first round the spec governs,
    /// spec string)` — the session's rate-control trajectory, in order.
    pub spec_changes: Vec<(u64, String)>,
}

impl ExperimentMetrics {
    pub fn push(&mut self, m: RoundMetrics) {
        self.rounds.push(m);
    }

    /// Record a mid-session spec switch (called by `Leader::switch_spec`).
    pub fn note_spec_change(&mut self, round: u64, spec: &str) {
        self.spec_changes.push((round, spec.to_string()));
    }

    /// Total protocol payload bits across all rounds.
    pub fn total_uplink_bits(&self) -> u64 {
        self.rounds.iter().map(|m| m.uplink_bits).sum()
    }

    /// Total wall time across rounds.
    pub fn total_wall(&self) -> Duration {
        self.rounds.iter().map(|m| m.wall).sum()
    }

    /// Total leader-side barrier wait across rounds.
    pub fn total_wait_wall(&self) -> Duration {
        self.rounds.iter().map(|m| m.wait_wall).sum()
    }

    /// Total decode CPU time across rounds (summed over decode threads).
    pub fn total_decode_wall(&self) -> Duration {
        self.rounds.iter().map(|m| m.decode_wall).sum()
    }

    /// Mean per-round participation p̂ (1.0 when every round was full).
    pub fn avg_participation(&self) -> f64 {
        if self.rounds.is_empty() {
            1.0
        } else {
            self.rounds.iter().map(|m| m.participation).sum::<f64>() / self.rounds.len() as f64
        }
    }

    /// Total duplicate uploads dropped across rounds.
    pub fn total_duplicate_uploads(&self) -> u64 {
        self.rounds.iter().map(|m| m.duplicate_uploads).sum()
    }

    /// Average bits per round.
    pub fn avg_bits_per_round(&self) -> f64 {
        if self.rounds.is_empty() {
            0.0
        } else {
            self.total_uplink_bits() as f64 / self.rounds.len() as f64
        }
    }

    /// Rounds per second over the whole run.
    pub fn rounds_per_sec(&self) -> f64 {
        let secs = self.total_wall().as_secs_f64();
        if secs > 0.0 {
            self.rounds.len() as f64 / secs
        } else {
            0.0
        }
    }

    /// Transport overhead ratio: transport bytes vs payload bytes on the
    /// uplink (framing, weights, headers).
    pub fn uplink_overhead(&self) -> f64 {
        let payload = self.total_uplink_bits() as f64 / 8.0;
        let wire = self.rounds.last().map(|m| m.cum_up_bytes).unwrap_or(0) as f64;
        if payload > 0.0 {
            wire / payload
        } else {
            0.0
        }
    }

    /// One-line human summary (plus the spec-switch trajectory when the
    /// session retuned mid-flight).
    pub fn summary(&self) -> String {
        let mut s = format!(
            "{} rounds, {:.2} Mbit uplink ({:.1} kbit/round), {:.1} rounds/s, \
             transport overhead {:.2}x, wait {:.1} ms + decode {:.1} ms (cpu)",
            self.rounds.len(),
            self.total_uplink_bits() as f64 / 1e6,
            self.avg_bits_per_round() / 1e3,
            self.rounds_per_sec(),
            self.uplink_overhead(),
            self.total_wait_wall().as_secs_f64() * 1e3,
            self.total_decode_wall().as_secs_f64() * 1e3,
        );
        if !self.spec_changes.is_empty() {
            let traj: Vec<String> = self
                .spec_changes
                .iter()
                .map(|(r, spec)| format!("round {r} -> {spec}"))
                .collect();
            s.push_str(&format!("; spec switches: {}", traj.join(", ")));
        }
        s
    }
}

/// One tier of an aggregation tree, rolled up across its nodes. Tier 0
/// is the root (leader); the last tier is the aggregators directly above
/// the workers (or the leader itself when flat).
#[derive(Clone, Debug)]
pub struct TierMetrics {
    pub tier: usize,
    /// Nodes in this tier (1 for the root).
    pub nodes: usize,
    /// Bytes this tier's nodes sent down to their children.
    pub down_bytes: u64,
    /// Bytes this tier's nodes ingested from their children — the
    /// per-tier `bytes_moved` that the tree exists to shrink at the root.
    pub up_bytes: u64,
    /// Summed barrier-wait wall time across the tier's nodes.
    pub wait_wall: Duration,
    /// Summed decode+merge CPU time across the tier's nodes.
    pub decode_wall: Duration,
    /// Dimension shards each node in this tier splits its upstream report
    /// into (1 everywhere except the tier directly below a sharded root).
    pub dim_shards: u32,
}

/// One tenant session of a multiplexed run, rolled up across its rounds.
/// Printed by `dme serve --tenants`: the per-tenant split of a wire every
/// tenant shares, plus how the realized uplink compares to the bits the
/// rate planner allocated.
#[derive(Clone, Debug)]
pub struct TenantMetrics {
    /// The tenant's wire session id.
    pub session: u16,
    /// The spec the tenant ended the run on.
    pub spec: String,
    /// Rounds this tenant completed.
    pub rounds: usize,
    /// Framed bytes broadcast down on this session (across all workers).
    pub down_bytes: u64,
    /// Framed bytes received up on this session.
    pub up_bytes: u64,
    /// Realized protocol payload bits per round (averaged).
    pub realized_bits: f64,
    /// Bits per round the rate planner allocated to this tenant
    /// (0 when no planner ran).
    pub allocated_bits: f64,
    /// Analytic MSE proxy of the tenant's operating point (the planner's
    /// model, not an empirical residual; 0 when no planner ran).
    pub mse_proxy: f64,
}

/// Human-readable table of a multiplexed run's tenants.
pub fn format_tenant_table(tenants: &[TenantMetrics]) -> String {
    let mut s = format!(
        "{:<8} {:<24} {:>6} {:>12} {:>12} {:>14} {:>14} {:>12}\n",
        "tenant", "spec", "rounds", "down bytes", "up bytes", "realized b/r", "allocated b/r",
        "mse proxy"
    );
    for t in tenants {
        s.push_str(&format!(
            "{:<8} {:<24} {:>6} {:>12} {:>12} {:>14.0} {:>14.0} {:>12.3e}\n",
            t.session,
            t.spec,
            t.rounds,
            t.down_bytes,
            t.up_bytes,
            t.realized_bits,
            t.allocated_bits,
            t.mse_proxy,
        ));
    }
    s
}

/// Human-readable table of a tree run's tiers.
pub fn format_tier_table(tiers: &[TierMetrics]) -> String {
    let mut s = format!(
        "{:<6} {:>6} {:>7} {:>14} {:>14} {:>12} {:>12}\n",
        "tier", "nodes", "shards", "ingress bytes", "egress bytes", "wait ms", "decode ms"
    );
    for t in tiers {
        let label = if t.tier == 0 { "root".to_string() } else { format!("agg-{}", t.tier) };
        s.push_str(&format!(
            "{:<6} {:>6} {:>7} {:>14} {:>14} {:>12.1} {:>12.1}\n",
            label,
            t.nodes,
            t.dim_shards,
            t.up_bytes,
            t.down_bytes,
            t.wait_wall.as_secs_f64() * 1e3,
            t.decode_wall.as_secs_f64() * 1e3,
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(round: u64, bits: u64, up: u64) -> RoundMetrics {
        RoundMetrics {
            round,
            uplink_bits: bits,
            n_frames: 2,
            wall: Duration::from_millis(10),
            wait_wall: Duration::from_millis(6),
            decode_wall: Duration::from_millis(3),
            cum_down_bytes: 100,
            cum_up_bytes: up,
            participation: 1.0,
            duplicate_uploads: 0,
        }
    }

    #[test]
    fn aggregates() {
        let mut em = ExperimentMetrics::default();
        em.push(m(0, 800, 150));
        em.push(m(1, 1200, 350));
        assert_eq!(em.total_uplink_bits(), 2000);
        assert_eq!(em.avg_bits_per_round(), 1000.0);
        assert!(em.rounds_per_sec() > 0.0);
        // payload = 250 bytes, wire = 350
        assert!((em.uplink_overhead() - 1.4).abs() < 1e-9);
        assert_eq!(em.total_wait_wall(), Duration::from_millis(12));
        assert_eq!(em.total_decode_wall(), Duration::from_millis(6));
        assert_eq!(em.avg_participation(), 1.0);
        assert_eq!(em.total_duplicate_uploads(), 0);
        assert!(em.summary().contains("2 rounds"));
    }

    #[test]
    fn empty_metrics_are_zero() {
        let em = ExperimentMetrics::default();
        assert_eq!(em.avg_bits_per_round(), 0.0);
        assert_eq!(em.uplink_overhead(), 0.0);
        assert_eq!(em.rounds_per_sec(), 0.0);
    }

    #[test]
    fn tier_table_renders_every_tier() {
        let tiers = vec![
            TierMetrics {
                tier: 0,
                nodes: 1,
                down_bytes: 10,
                up_bytes: 2_000,
                wait_wall: Duration::from_millis(4),
                decode_wall: Duration::from_millis(2),
                dim_shards: 1,
            },
            TierMetrics {
                tier: 1,
                nodes: 8,
                down_bytes: 80,
                up_bytes: 64_000,
                wait_wall: Duration::from_millis(9),
                decode_wall: Duration::from_millis(31),
                dim_shards: 4,
            },
        ];
        let table = format_tier_table(&tiers);
        assert!(table.contains("root"));
        assert!(table.contains("agg-1"));
        assert!(table.contains("64000"));
        assert!(table.contains("shards"));
    }

    #[test]
    fn tenant_table_renders_every_tenant() {
        let tenants = vec![
            TenantMetrics {
                session: 1,
                spec: "klevel:k=4".into(),
                rounds: 10,
                down_bytes: 1_000,
                up_bytes: 52_000,
                realized_bits: 4096.0,
                allocated_bits: 5000.0,
                mse_proxy: 1.25e-3,
            },
            TenantMetrics {
                session: 2,
                spec: "rotated:k=2".into(),
                rounds: 10,
                down_bytes: 1_000,
                up_bytes: 26_000,
                realized_bits: 2048.0,
                allocated_bits: 2048.0,
                mse_proxy: 4.0e-3,
            },
        ];
        let table = format_tenant_table(&tenants);
        assert!(table.contains("klevel:k=4"));
        assert!(table.contains("rotated:k=2"));
        assert!(table.contains("52000"));
        assert!(table.contains("tenant"));
    }
}
